"""L2: Llama-style decoder with a pluggable FP8 precision recipe.

Architecture follows the paper's experimental setup (Llama-2 /
Touvron et al. 2023): pre-norm RMSNorm, rotary position embeddings,
multi-head attention, SwiGLU MLP, untied LM head. A GeLU-MLP variant
(GPT-3-like, paper Fig. 12) shares everything but the MLP.

The **precision recipe** decides what gets quantized and how — it is
the axis the paper's experiments sweep:

=============  =====================================================
recipe field   effect
=============  =====================================================
quant_linear   quantize every linear-layer matmul: E4M3 operands fwd
               (``ste_qdq``), E5M2 cotangents bwd (``grad_q``)
w3_input       'fp8'  — quantize the SwiGLU product with a *delayed
                        per-tensor* scale (the configuration that
                        diverges after enough tokens, Fig. 2a)
               'bf16' — leave it in bf16 ("FP8(1)", Fig. 3)
               'smooth' — per-channel JIT scaling, the paper's
                        Smooth-SwiGLU (Fig. 4b / eq. 3)
saturating     clamp-to-±max vs NaN-on-overflow conversion
activation     'swiglu' | 'gelu' (Fig. 12 control)
smooth_pallas  route Smooth-SwiGLU through the Pallas kernel (L1) or
               the pure-jnp reference — bit-identical (tested), so
               this is a lowering/perf choice only
smooth_pow2    pow2 vs exact per-channel scales (exact is the BF16
               Fig. 10 variant)
=============  =====================================================

Scale/amax plumbing: every quantization site has an index into one flat
``scales`` f32[NS] input vector, and reports an amax into the matching
slot of the ``amax`` f32[NS] output vector (forward sites directly,
gradient sites via the cotangent trick in ``quant_ops.grad_q``). The
Rust coordinator owns the amax→scale policy between steps. Site layout
is defined here and exported in the artifact manifest so both sides
agree by construction.

Everything is f32 "master" with bf16 casts at matmuls (matching
BF16-mixed-precision baselines); FP8 lives on value grids (DESIGN.md).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .formats import E4M3, compute_scale
from .kernels.ref import gelu, smooth_swiglu_ref, swiglu
from .kernels.smooth_swiglu import smooth_swiglu_pallas
from .quant_ops import grad_q, ste_attach, ste_qdq

# --------------------------------------------------------------------------
# configuration


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (one of DESIGN.md's size presets)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    rope_base: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self, activation: str = "swiglu") -> int:
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        per_layer = 4 * d * d + 2 * d
        per_layer += (3 if activation == "swiglu" else 2) * d * f
        return L * per_layer + 2 * V * d + d


@dataclass(frozen=True)
class Recipe:
    """Precision recipe — the paper's experimental axis."""

    name: str
    quant_linear: bool = True
    w3_input: str = "fp8"  # 'fp8' | 'bf16' | 'smooth'
    saturating: bool = True
    activation: str = "swiglu"  # 'swiglu' | 'gelu'
    smooth_pallas: bool = True
    smooth_pow2: bool = True
    # Adam moment formats ('' = fp32); consumed by adam.py/aot.py.
    m_fmt: str = "e4m3"
    v_fmt: str = "e5m2"
    # matmul compute dtype when not quantizing (and for attention core)
    compute_dtype: str = "bfloat16"


RECIPES = {
    # paper BF16 mixed-precision baseline
    "bf16": Recipe("bf16", quant_linear=False, w3_input="bf16", m_fmt="", v_fmt=""),
    # BF16 + Smooth-SwiGLU (Fig. 10/11 study; exact per-channel scales)
    "bf16_smooth": Recipe(
        "bf16_smooth", quant_linear=False, w3_input="smooth",
        smooth_pow2=False, m_fmt="", v_fmt="",
    ),
    # standard FP8 — the configuration that diverges (Fig. 2a)
    "fp8": Recipe("fp8", m_fmt="", v_fmt=""),
    # standard FP8 with NaN-on-overflow conversion (no saturation):
    # the hard-failure mode of a stale delayed scale, for ablations
    "fp8_nosat": Recipe("fp8_nosat", saturating=False, m_fmt="", v_fmt=""),
    # FP8 with the SwiGLU output kept in BF16 — "FP8(1)" (Fig. 3)
    "fp8_noq3": Recipe("fp8_noq3", w3_input="bf16", m_fmt="", v_fmt=""),
    # nosat counterparts: identical overflow semantics to fp8_nosat with
    # only the w3-input handling changed — isolates the paper's claim
    # that the instability lives in that single tensor
    "fp8_noq3_nosat": Recipe("fp8_noq3_nosat", w3_input="bf16",
                             saturating=False, m_fmt="", v_fmt=""),
    "fp8_smooth_nosat": Recipe("fp8_smooth_nosat", w3_input="smooth",
                               saturating=False, m_fmt="", v_fmt=""),
    # FP8 + Smooth-SwiGLU, FP32 Adam moments
    "fp8_smooth": Recipe("fp8_smooth", w3_input="smooth", m_fmt="", v_fmt=""),
    # the paper's full scheme — "FP8(2)": Smooth-SwiGLU + FP8 Adam moments
    "fp8_full": Recipe("fp8_full", w3_input="smooth"),
    # GPT-3-like GeLU control (Fig. 12): FP8 is stable without SwiGLU
    "gelu_fp8": Recipe("gelu_fp8", activation="gelu", m_fmt="", v_fmt=""),
    "gelu_bf16": Recipe(
        "gelu_bf16", quant_linear=False, activation="gelu", m_fmt="", v_fmt="",
    ),
}
# Fig. 5 grid: Adam moment format combinations on top of fp8_smooth.
for _m in ("e4m3", "e5m2"):
    for _v in ("e4m3", "e5m2"):
        RECIPES[f"fp8_adam_{_m}_{_v}"] = Recipe(
            f"fp8_adam_{_m}_{_v}", w3_input="smooth", m_fmt=_m, v_fmt=_v,
        )


SIZES = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4,
                        d_ff=172, seq_len=64),
    "s1m": ModelConfig("s1m", vocab=512, d_model=128, n_layers=3, n_heads=4,
                       d_ff=344, seq_len=128),
    "s8m": ModelConfig("s8m", vocab=2048, d_model=256, n_layers=4, n_heads=8,
                       d_ff=688, seq_len=128),
    "m100": ModelConfig("m100", vocab=8192, d_model=768, n_layers=12, n_heads=12,
                        d_ff=2048, seq_len=256),
}

# --------------------------------------------------------------------------
# scale-site layout (shared contract with rust/src/scaling via the manifest)

FWD_SITES = [
    "x_attn", "wq", "wk", "wv", "x_wo", "wo",
    "x_mlp", "w1", "w2", "w3_in", "w3",
]
GRAD_SITES = ["g_qkv", "g_wo", "g_w1", "g_w2", "g_w3"]
SITES_PER_LAYER = FWD_SITES + GRAD_SITES


def n_scale_sites(cfg: ModelConfig) -> int:
    return cfg.n_layers * len(SITES_PER_LAYER)


def site_index(layer: int, site: str) -> int:
    return layer * len(SITES_PER_LAYER) + SITES_PER_LAYER.index(site)


# --------------------------------------------------------------------------
# parameter tree (canonical ordering = sorted names; the AOT manifest
# freezes it for the Rust side)


def param_specs(cfg: ModelConfig, recipe: Recipe) -> dict:
    """name -> (shape, init_std). Layer params are stacked on axis 0.

    init_std == -1.0 marks "init to ones" (norm gains).
    """
    d, f, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    std = 0.02
    resid_std = std / (2 * L) ** 0.5  # GPT-2-style residual-out scaling
    specs = {
        "embed": ((V, d), std),
        "head": ((d, V), std),
        "ln_f": ((d,), -1.0),
        "ln_1": ((L, d), -1.0),
        "ln_2": ((L, d), -1.0),
        "wq": ((L, d, d), std),
        "wk": ((L, d, d), std),
        "wv": ((L, d, d), std),
        "wo": ((L, d, d), resid_std),
        "w1": ((L, d, f), std),
        "w3": ((L, f, d), resid_std),
    }
    if recipe.activation == "swiglu":
        specs["w2"] = ((L, d, f), std)
    return specs


# --------------------------------------------------------------------------
# building blocks


def rmsnorm(x, gain, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def rope(x, base):
    """Rotary embeddings over [B, S, H, hd]."""
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _cast_mm(x, w, dtype):
    """Unquantized matmul in the recipe's compute dtype, f32 accumulate."""
    return jnp.dot(
        x.astype(dtype), w.astype(dtype), preferred_element_type=jnp.float32
    )


class _QuantCtx:
    """Per-block quantization context: slices the flat scales vector and
    collects forward amaxes (grad amaxes arrive via cotangents)."""

    def __init__(self, scales_vec, recipe: Recipe, layer_offset):
        self.scales = scales_vec
        self.recipe = recipe
        self.off = layer_offset  # dynamic: layer index * stride
        self.fwd_amax = {}  # site_local_idx -> amax value

    def scale(self, site):
        idx = self.off + SITES_PER_LAYER.index(site)
        return jax.lax.dynamic_index_in_dim(self.scales, idx, keepdims=False)

    def report(self, site, tensor):
        self.fwd_amax[SITES_PER_LAYER.index(site)] = jnp.max(
            jnp.abs(jax.lax.stop_gradient(tensor))
        ).astype(jnp.float32)

    def q_fwd(self, x, site):
        """E4M3-quantize a forward operand (and report its amax)."""
        self.report(site, x)
        if not self.recipe.quant_linear:
            return x.astype(self.recipe.compute_dtype).astype(jnp.float32)
        return ste_qdq(x, self.scale(site), "e4m3", self.recipe.saturating)

    def q_grad(self, y, site):
        """Mark a matmul output: its cotangent is E5M2-quantized in bwd."""
        if not self.recipe.quant_linear:
            return y
        return grad_q(y, self.scale(site), "e5m2", self.recipe.saturating)

    def amax_vector(self):
        out = jnp.zeros((len(SITES_PER_LAYER),), jnp.float32)
        for idx, val in self.fwd_amax.items():
            out = out.at[idx].set(val)
        return out


def _mlp(x2, p, ctx: _QuantCtx, recipe: Recipe, dtype):
    """MLP with the three w3-input handling modes (the paper's core).

    Returns (mlp_out, swiglu_product) — the product is monitored for
    the Fig. 1 activation-max signal.
    """
    xq = ctx.q_fwd(x2, "x_mlp")
    w1q = ctx.q_fwd(p["w1"], "w1")
    a1 = ctx.q_grad(jnp.dot(xq, w1q, preferred_element_type=jnp.float32), "g_w1")

    if recipe.activation == "gelu":
        h = gelu(a1)
        ctx.report("w3_in", h)  # monitored even though GeLU never spikes
        if recipe.quant_linear:
            hq = ste_qdq(h, ctx.scale("w3_in"), "e4m3", recipe.saturating)
        else:
            hq = h.astype(dtype).astype(jnp.float32)
        w3q = ctx.q_fwd(p["w3"], "w3")
        y = ctx.q_grad(jnp.dot(hq, w3q, preferred_element_type=jnp.float32), "g_w3")
        return y, h

    w2q = ctx.q_fwd(p["w2"], "w2")
    a2 = ctx.q_grad(jnp.dot(xq, w2q, preferred_element_type=jnp.float32), "g_w2")
    h = swiglu(a1, a2)
    ctx.report("w3_in", h)  # Fig. 1's per-layer activation-max signal
    w3q = ctx.q_fwd(p["w3"], "w3")

    if recipe.w3_input == "bf16":
        # FP8(1): leave the SwiGLU product unquantized (Fig. 3)
        hq = h.astype(dtype).astype(jnp.float32)
    elif recipe.w3_input == "fp8":
        # standard FP8: delayed per-tensor scale — the diverging path
        hq = ste_qdq(h, ctx.scale("w3_in"), "e4m3", recipe.saturating)
    else:  # 'smooth'
        # Smooth-SwiGLU (eq. 3 / Fig. 4b): per-channel JIT scaling
        shape = h.shape
        tokens = h.reshape(-1, shape[-1])
        if recipe.quant_linear:
            a1f = jax.lax.stop_gradient(a1).reshape(-1, shape[-1])
            a2f = jax.lax.stop_gradient(a2).reshape(-1, shape[-1])
            fn = smooth_swiglu_pallas if recipe.smooth_pallas else smooth_swiglu_ref
            q, s = fn(a1f, a2f, pow2=recipe.smooth_pow2)
            hq = ste_attach(tokens, q / s[None, :]).reshape(shape)
        else:
            # BF16 variant (Fig. 10): per-channel normalize → bf16 → undo
            amax = jnp.max(jnp.abs(jax.lax.stop_gradient(tokens)), axis=0)
            s = compute_scale(amax, E4M3, pow2=recipe.smooth_pow2)
            hn = (tokens * s[None, :]).astype(dtype).astype(jnp.float32) / s[None, :]
            hq = ste_attach(tokens, hn).reshape(shape)
    y = ctx.q_grad(jnp.dot(hq, w3q, preferred_element_type=jnp.float32), "g_w3")
    return y, h


def _block(x, p, scales_vec, layer_idx, cfg: ModelConfig, recipe: Recipe):
    """One transformer block. Returns (x_out, local_amax, monitor[3])."""
    dtype = recipe.compute_dtype
    stride = len(SITES_PER_LAYER)
    ctx = _QuantCtx(scales_vec, recipe, layer_idx * stride)

    # ---- attention
    xn = rmsnorm(x, p["ln_1"], cfg.norm_eps)
    xq = ctx.q_fwd(xn, "x_attn")
    q = ctx.q_grad(jnp.dot(xq, ctx.q_fwd(p["wq"], "wq"),
                           preferred_element_type=jnp.float32), "g_qkv")
    k = ctx.q_grad(jnp.dot(xq, ctx.q_fwd(p["wk"], "wk"),
                           preferred_element_type=jnp.float32), "g_qkv")
    v = ctx.q_grad(jnp.dot(xq, ctx.q_fwd(p["wv"], "wv"),
                           preferred_element_type=jnp.float32), "g_qkv")

    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    q = rope(q.reshape(b, s, nh, hd), cfg.rope_base)
    k = rope(k.reshape(b, s, nh, hd), cfg.rope_base)
    v = v.reshape(b, s, nh, hd)

    # attention core in the compute dtype (unquantized, as in the paper)
    att = jnp.einsum("bqhe,bkhe->bhqk", q.astype(dtype), k.astype(dtype),
                     preferred_element_type=jnp.float32)
    att = att / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhe->bqhe", att.astype(dtype), v.astype(dtype),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, s, d)

    oq = ctx.q_fwd(out, "x_wo")
    woq = ctx.q_fwd(p["wo"], "wo")
    x = x + ctx.q_grad(jnp.dot(oq, woq, preferred_element_type=jnp.float32), "g_wo")

    # ---- MLP
    x2 = rmsnorm(x, p["ln_2"], cfg.norm_eps)
    mlp_out, h_act = _mlp(x2, p, ctx, recipe, dtype)
    x = x + mlp_out

    local_amax = ctx.amax_vector()
    monitor = jnp.stack([
        jnp.max(jnp.abs(jax.lax.stop_gradient(h_act))),    # SwiGLU product amax (Fig. 1)
        jnp.max(jnp.abs(jax.lax.stop_gradient(x))),        # residual-stream amax
        jnp.max(jnp.abs(jax.lax.stop_gradient(mlp_out))),  # MLP output amax
    ])
    return x, local_amax, monitor


LAYER_PARAMS = ("ln_1", "ln_2", "wq", "wk", "wv", "wo", "w1", "w2", "w3")


def forward(params, scales_vec, tokens, cfg: ModelConfig, recipe: Recipe):
    """Full forward pass.

    tokens: i32 [B, S]. Returns (logits f32 [B, S, V],
    amax_vec f32 [NS], monitor f32 [L, 3]).
    """
    x = params["embed"][tokens]  # [B, S, d]

    layer_params = {k: params[k] for k in LAYER_PARAMS if k in params}

    def body(carry, inputs):
        layer_idx, lp = inputs
        y, local_amax, monitor = _block(carry, lp, scales_vec, layer_idx, cfg, recipe)
        return y, (local_amax, monitor)

    x, (amax_stack, monitor) = jax.lax.scan(
        body, x, (jnp.arange(cfg.n_layers), layer_params)
    )

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = _cast_mm(x, params["head"], recipe.compute_dtype)

    amax_vec = amax_stack.reshape(-1)  # scan order == site-layout order
    return logits, amax_vec, monitor


def loss_fn(params, scales_vec, batch, cfg: ModelConfig, recipe: Recipe):
    """Causal-LM cross entropy over batch i32 [B, S+1].

    Returns (loss, (amax_vec, monitor)).
    """
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits, amax_vec, monitor = forward(params, scales_vec, tokens, cfg, recipe)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), (amax_vec, monitor)
