"""FP8 format definitions and quantization primitives.

Two FP8 formats standardized by Micikevicius et al. (2022) and used
throughout the paper:

* **E4M3** (4 exponent bits, 3 mantissa bits, bias 7, max 448, no inf,
  NaN only): forward-path weights/activations and the Adam first moment.
* **E5M2** (5 exponent bits, 2 mantissa bits, bias 15, max 57344, IEEE
  inf/NaN): gradients and the Adam second moment (needs the extra
  exponent bit because of the inverse-sqrt in the update).

Two interchangeable quantizers are provided:

* :func:`quantize_grid` — XLA's native ``convert`` to the fp8 dtype and
  back. Fast, used on the AOT model path.
* :func:`quantize_grid_arith` — an arithmetic round-to-nearest-even
  implementation via int32 bit manipulation. This is the form authored
  inside the Pallas kernels (bitcast + integer ops vectorize on the VPU)
  and is verified bit-exact against ``quantize_grid`` by
  ``python/tests/test_formats.py``.

Both return *dequantized* float32 values lying exactly on the fp8 grid;
the fp8-ness of a tensor in this codebase is the value grid, matching how
Gaudi2/TE-style mixed precision keeps an f32/bf16 compute type around
fp8 storage.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Fp8Format:
    """Static description of an FP8 binary interchange format."""

    name: str
    exp_bits: int
    man_bits: int
    bias: int
    max: float  # largest finite magnitude
    min_normal: float  # smallest normal magnitude
    min_subnormal: float  # smallest subnormal magnitude (= grid step at 0)
    has_inf: bool  # E5M2 keeps IEEE inf; E4M3(FN) does not

    @property
    def dtype(self):
        return {"e4m3": jnp.float8_e4m3fn, "e5m2": jnp.float8_e5m2}[self.name]


E4M3 = Fp8Format(
    name="e4m3",
    exp_bits=4,
    man_bits=3,
    bias=7,
    max=448.0,
    min_normal=2.0**-6,
    min_subnormal=2.0**-9,
    has_inf=False,
)

E5M2 = Fp8Format(
    name="e5m2",
    exp_bits=5,
    man_bits=2,
    bias=15,
    max=57344.0,
    min_normal=2.0**-14,
    min_subnormal=2.0**-16,
    has_inf=True,
)

FORMATS = {"e4m3": E4M3, "e5m2": E5M2}


def quantize_grid(x: jax.Array, fmt: Fp8Format) -> jax.Array:
    """Round ``x`` (f32) to the fp8 value grid via native XLA convert.

    Overflow follows the format semantics: E4M3 → NaN, E5M2 → ±inf
    (matching both ml_dtypes and XLA ``convert``).
    """
    return x.astype(fmt.dtype).astype(jnp.float32)


def quantize_grid_arith(x: jax.Array, fmt: Fp8Format) -> jax.Array:
    """Arithmetic RNE rounding of f32 onto the fp8 grid.

    Bit-exact equivalent of :func:`quantize_grid`, written with
    ``bitcast_convert_type`` + integer ops only, so the identical code
    runs inside Pallas kernels (interpret mode and, structurally, on the
    TPU VPU).
    """
    assert x.dtype == jnp.float32, f"expected f32, got {x.dtype}"
    man_shift = 23 - fmt.man_bits

    i = jax.lax.bitcast_convert_type(x, jnp.int32)
    sign = i & jnp.int32(-0x80000000)
    mag = i & jnp.int32(0x7FFFFFFF)

    # Round-to-nearest-even on the f32 mantissa, keeping man_bits bits.
    round_bias = ((1 << (man_shift - 1)) - 1) + ((mag >> man_shift) & 1)
    mag_r = (mag + round_bias) & ~jnp.int32((1 << man_shift) - 1)
    v = jax.lax.bitcast_convert_type(sign | mag_r, jnp.float32)

    # Subnormal region of the fp8 format: uniform grid of min_subnormal.
    # jnp.round is round-half-to-even, matching the normal-path RNE.
    sub = jnp.round(x / fmt.min_subnormal) * fmt.min_subnormal

    absx = jnp.abs(x)
    out = jnp.where(absx < fmt.min_normal, sub, v)

    # Overflow handling (compare the *rounded* magnitude).
    overflow = jnp.abs(v) > fmt.max
    if fmt.has_inf:
        ovf_val = jnp.sign(x) * jnp.inf
    else:
        ovf_val = jnp.float32(jnp.nan)
    out = jnp.where(overflow, ovf_val, out)

    # Non-finite inputs.
    out = jnp.where(jnp.isnan(x), jnp.nan, out)
    inf_val = jnp.sign(x) * jnp.inf if fmt.has_inf else jnp.float32(jnp.nan)
    out = jnp.where(jnp.isinf(x), inf_val, out)
    return out


def saturate(x: jax.Array, fmt: Fp8Format) -> jax.Array:
    """Clamp to ±fmt.max. TE-style saturating conversion applies this
    before the grid rounding so overflow clips instead of NaN/inf-ing."""
    return jnp.clip(x, -fmt.max, fmt.max)


def qdq(
    x: jax.Array,
    fmt: Fp8Format,
    scale: jax.Array | float = 1.0,
    saturating: bool = True,
) -> jax.Array:
    """Quantize-dequantize: ``Q(x·scale)/scale`` on the fp8 grid.

    ``scale`` is the (externally chosen, e.g. delayed) scaling factor
    that positions the tensor inside the format's dynamic range.
    ``saturating`` selects clamp-vs-NaN overflow, per recipe.
    """
    y = x * scale
    if saturating:
        y = saturate(y, fmt)
    return quantize_grid(y, fmt) / scale


def compute_scale(
    amax: jax.Array, fmt: Fp8Format, margin: float = 1.0, pow2: bool = True
) -> jax.Array:
    """Just-in-time scale from an amax: 2^floor(log2(max/(margin·amax))).

    Matches the Rust delayed-scaling policy (`rust/src/scaling/policy.rs`);
    used where the paper computes scales just-in-time (Smooth-SwiGLU
    channels, Adam moments). ``pow2=False`` returns the exact ratio
    (used by the BF16 Smooth-SwiGLU study, Fig 10, where the point is
    renormalizing channel magnitudes rather than hitting an FP8 grid).
    """
    amax = jnp.maximum(amax, 1e-12)
    if not pow2:
        return fmt.max / (margin * amax)
    # ldexp with an integer exponent is exact; exp2 on f32 is not.
    e = jnp.floor(jnp.log2(fmt.max / (margin * amax))).astype(jnp.int32)
    s = jnp.ldexp(jnp.float32(1.0), e)
    # guard against log2 rounding up across an integer boundary
    return jnp.where(amax * s > fmt.max, s * 0.5, s)
