"""L2: the exported AdamW chunk update (FP8 moments, paper §5).

The optimizer artifact is **model-agnostic**: it updates one flat f32
chunk of the parameter space. The Rust coordinator range-shards the
flat space across data-parallel workers (ZeRO-1) and streams chunks
through this artifact; each chunk's moments get their own JIT pow2
scale, which is strictly finer than the paper's per-tensor scaling (a
chunk never spans more dynamic range than its parent tensor).

Runtime scalars arrive in one f32[4] vector: [lr, weight_decay, step,
grad_scale]; ``grad_scale`` folds global-norm clipping (computed by
Rust over all shards) into the same pass. Moment formats are static
per artifact variant (the Fig. 5 grid).
"""

import jax.numpy as jnp

from .formats import FORMATS
from .kernels.adam_fp8 import adam_fp8_pallas


def make_adam_step(
    m_fmt: str,
    v_fmt: str,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    use_pallas: bool = True,
    block: int = 65536,
):
    """Returns adam_step(p, m, v, g, scalars[4]) -> (p', m', v').

    ``m_fmt``/``v_fmt``: 'e4m3' | 'e5m2' | '' (fp32).
    """
    mf = FORMATS.get(m_fmt)
    vf = FORMATS.get(v_fmt)

    def adam_step(p, m, v, g, scalars):
        lr, wd, step, grad_scale = (scalars[i] for i in range(4))
        g = g * grad_scale
        if use_pallas:
            return adam_fp8_pallas(
                p, m, v, g, lr,
                beta1=beta1, beta2=beta2, eps=eps, weight_decay=wd,
                step=step, m_fmt=mf, v_fmt=vf, block=block,
            )
        from .kernels.ref import adam_fp8_ref

        return adam_fp8_ref(
            p, m, v, g, lr,
            beta1=beta1, beta2=beta2, eps=eps, weight_decay=wd,
            step=step, m_fmt=mf, v_fmt=vf,
        )

    return adam_step
