"""Pallas kernel: tiled FP8 matmul (quantize → MXU dot → f32 accumulate).

The Gaudi2 MME consumes FP8 operands with per-tensor scales and
accumulates in f32. TPU mapping: (i, j, k) grid over (M, N, K) tiles;
each (bm×bk) x-tile and (bk×bn) w-tile is quantized to the E4M3 grid in
VMEM (arithmetic RNE — same VPU code as fp8_quant), fed to the MXU dot,
and accumulated into the (bm×bn) output tile that stays resident in
VMEM across the K loop (K is the innermost/sequential grid axis, the
standard Pallas accumulation pattern).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import E4M3, Fp8Format, quantize_grid_arith


def _mm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, *, fmt: Fp8Format, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sx = sx_ref[0]
    sw = sw_ref[0]
    xq = quantize_grid_arith(jnp.clip(x_ref[...] * sx, -fmt.max, fmt.max), fmt) / sx
    wq = quantize_grid_arith(jnp.clip(w_ref[...] * sw, -fmt.max, fmt.max), fmt) / sw
    o_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def fp8_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    sx: jax.Array,
    sw: jax.Array,
    fmt: Fp8Format = E4M3,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """``dequant(Q(x·sx)) @ dequant(Q(w·sw))`` with f32 accumulation.

    ``sx``/``sw`` are shape-(1,) delayed scales from the Rust manager.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)

    # Zero-pad ragged tiles (interpret mode NaN-pads out-of-bounds reads,
    # which would poison the K-axis accumulation; zeros are additive
    # identity and Q(0)=0). Padded output rows/cols are sliced away.
    def pad_to(t, b0, b1):
        p0 = (-t.shape[0]) % b0
        p1 = (-t.shape[1]) % b1
        return jnp.pad(t, ((0, p0), (0, p1))) if (p0 or p1) else t

    x = pad_to(x, bm, bk)
    w = pad_to(w, bk, bn)
    mp, kp = x.shape
    _, np_ = w.shape
    grid = (pl.cdiv(mp, bm), pl.cdiv(np_, bn), pl.cdiv(kp, bk))
    return pl.pallas_call(
        functools.partial(_mm_kernel, fmt=fmt, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(x, w, sx, sw)[:m, :n]
