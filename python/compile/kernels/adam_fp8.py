"""Pallas kernel: AdamW update with FP8-quantized moments (paper §5).

Both Adam moments are stored on FP8 grids — m on E4M3 (precision),
v on E5M2 (dynamic range, because the inverse sqrt makes the *smallest*
v entries the most influential). Per-tensor JIT scales position each
moment in its format's range; the scales are computed from the new
moments' amaxes (host-side cheap reduce) and passed in, the elementwise
update streams through VMEM in 1-D tiles.

The optimizer is memory-bound, so the win the paper reports (Table 4,
~30% total memory) comes from the 1-byte storage; the Rust checkpoint
layer (`rust/src/fp8`) packs these grid values into real u8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import E4M3, E5M2, Fp8Format, quantize_grid_arith


def _adam_kernel(
    p_ref, m_ref, v_ref, g_ref, sc_ref, o_p, o_m, o_v,
    *, beta1, beta2, eps, m_fmt, v_fmt,
):
    p = p_ref[...]
    g = g_ref[...]
    lr, wd, bc1, bc2, sm, sv = (sc_ref[i] for i in range(6))

    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    if m_fmt is not None:
        m = quantize_grid_arith(jnp.clip(m * sm, -m_fmt.max, m_fmt.max), m_fmt) / sm
    if v_fmt is not None:
        v = quantize_grid_arith(jnp.clip(v * sv, -v_fmt.max, v_fmt.max), v_fmt) / sv

    mhat = m * bc1  # bc1 = 1/(1-beta1^t), precomputed
    vhat = v * bc2
    o_p[...] = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    o_m[...] = m
    o_v[...] = v


def adam_fp8_pallas(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    lr,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    step: int = 1,
    m_fmt: Fp8Format | None = E4M3,
    v_fmt: Fp8Format | None = E5M2,
    block: int = 4096,
    interpret: bool = True,
):
    """One AdamW step over flat 1-D tensors; returns (p', m', v').

    Matches ``ref.adam_fp8_ref`` bit-for-bit (same JIT pow2 moment
    scales, computed here from the pre-quantization new moments).
    """
    assert p.ndim == 1 and p.shape == m.shape == v.shape == g.shape
    n = p.shape[0]
    block = min(block, n)

    step_f = jnp.asarray(step, jnp.float32)
    m_new_full = beta1 * m + (1.0 - beta1) * g
    v_new_full = beta2 * v + (1.0 - beta2) * g * g

    def jit_scale(t, fmt):
        if fmt is None:
            return jnp.float32(1.0)
        from ..formats import compute_scale

        return compute_scale(jnp.max(jnp.abs(t)), fmt)

    scalars = jnp.stack(
        [
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(weight_decay, jnp.float32),
            1.0 / (1.0 - beta1**step_f),
            1.0 / (1.0 - beta2**step_f),
            jit_scale(m_new_full, m_fmt),
            jit_scale(v_new_full, v_fmt),
        ]
    )

    spec = pl.BlockSpec((block,), lambda i: (i,))
    kernel = functools.partial(
        _adam_kernel, beta1=beta1, beta2=beta2, eps=eps, m_fmt=m_fmt, v_fmt=v_fmt
    )
    out_shape = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(n, block),),
        in_specs=[spec, spec, spec, spec, pl.BlockSpec((6,), lambda i: (0,))],
        out_specs=[spec, spec, spec],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=interpret,
    )(p, m, v, g, scalars)
