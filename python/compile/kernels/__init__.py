"""L1 Pallas kernels for the FP8-training hot spots.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); their BlockSpecs encode the TPU HBM↔VMEM schedule and are
costed structurally in ``rust/src/perfmodel`` / DESIGN.md §Perf.
"""

from .adam_fp8 import adam_fp8_pallas
from .fp8_quant import fp8_amax_pallas, fp8_qdq_pallas
from .matmul_fp8 import fp8_matmul_pallas
from .smooth_swiglu import smooth_swiglu_pallas, swiglu_pallas

__all__ = [
    "adam_fp8_pallas",
    "fp8_amax_pallas",
    "fp8_qdq_pallas",
    "fp8_matmul_pallas",
    "smooth_swiglu_pallas",
    "swiglu_pallas",
]
