"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: each function here is the
simplest possible jnp expression of the kernel's contract, and pytest
(``python/tests/``) asserts the Pallas implementations match bit-exactly
(quantization grids) or to f32 tolerance (accumulations).
"""

import jax
import jax.numpy as jnp

from ..formats import E4M3, E5M2, Fp8Format, compute_scale, qdq, quantize_grid, saturate


def swish(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def swiglu(a1: jax.Array, a2: jax.Array) -> jax.Array:
    """SwiGLU product given the two linear-branch outputs a1 = x·w1,
    a2 = x·w2 (paper §4.1)."""
    return a1 * swish(a2)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def fp8_quantize_ref(x: jax.Array, fmt: Fp8Format, scale, saturating: bool = True) -> jax.Array:
    """qdq with a per-tensor scale — oracle for kernels/fp8_quant.py."""
    return qdq(x, fmt, scale, saturating)


def smooth_swiglu_ref(
    a1: jax.Array, a2: jax.Array, fmt: Fp8Format = E4M3, margin: float = 1.0,
    pow2: bool = True,
):
    """Oracle for the fused Smooth-SwiGLU kernel (paper eq. 3).

    Returns ``(q, s)`` where ``s[i]`` is the per-channel pow2 scale from
    the channel's JIT amax and ``q = Q(h·s)`` lies on the E4M3 grid
    *still scaled* — the w3 matmul consumes ``q`` and folds ``s⁻¹`` into
    its dequant (zero-cost at inference, §4.4).
    """
    h = swiglu(a1, a2)  # [tokens, channels]
    amax = jnp.max(jnp.abs(h), axis=0)  # per-channel
    s = compute_scale(amax, fmt, margin, pow2)  # [channels]
    q = quantize_grid(saturate(h * s[None, :], fmt), fmt)
    return q, s


def fp8_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    sx,
    sw,
    fmt: Fp8Format = E4M3,
) -> jax.Array:
    """Oracle for the tiled fp8 matmul kernel: quantize both operands
    with their scales, dequantize, accumulate in f32."""
    xq = qdq(x, fmt, sx)
    wq = qdq(w, fmt, sw)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def adam_fp8_ref(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    lr,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    step=1,
    m_fmt: Fp8Format | None = E4M3,
    v_fmt: Fp8Format | None = E5M2,
):
    """Oracle for the FP8-moment Adam kernel (paper §5).

    Moments are stored on an fp8 grid with a per-tensor JIT scale
    (E4M3 for m: precision; E5M2 for v: range under the inverse sqrt).
    ``None`` format keeps the moment in f32 (the BF16-baseline recipe).
    Decoupled weight decay (AdamW), as Llama-2 training uses.
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    if m_fmt is not None:
        sm = compute_scale(jnp.max(jnp.abs(m_new)), m_fmt)
        m_new = qdq(m_new, m_fmt, sm)
    if v_fmt is not None:
        sv = compute_scale(jnp.max(jnp.abs(v_new)), v_fmt)
        v_new = qdq(v_new, v_fmt, sv)
    step = jnp.asarray(step, jnp.float32)
    mhat = m_new / (1.0 - beta1**step)
    vhat = v_new / (1.0 - beta2**step)
    update = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p
    p_new = p - lr * update
    return p_new, m_new, v_new
