"""Pallas kernels: SwiGLU and the fused Smooth-SwiGLU (paper §4.4).

Smooth-SwiGLU is the paper's core fix: the SwiGLU product
``h = (x·w1) ⊙ swish(x·w2)`` develops per-channel outliers late in
training (Theorem 1 weight alignment), so quantizing it with one
delayed per-tensor scale overflows. Instead each channel i gets a
just-in-time scale s_i from its own amax; ``Q(h·s)`` is handed to the
w3 matmul which folds ``s⁻¹`` into its dequantization. The function is
unchanged; only the quantization grid is per-channel.

Hardware adaptation (Gaudi2 MME epilogue → TPU Pallas):

* channels ride the minor/lane axis, so the per-channel |·| max is a
  lane-parallel VPU reduce;
* a per-channel max needs *all* tokens, so the kernel is two-pass over
  token-tiles: pass 1 accumulates per-tile channel maxima into a small
  [n_tiles, channels] buffer, the (cheap) cross-tile max and pow2 scale
  happen at f32, pass 2 re-streams the tiles to scale+quantize. Each
  pass touches a tile of VMEM once — the BlockSpec is the HBM↔VMEM
  schedule the paper expressed with per-channel chunk parallelism.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import E4M3, Fp8Format, quantize_grid_arith


def _swiglu_kernel(a1_ref, a2_ref, o_ref):
    a1 = a1_ref[...]
    a2 = a2_ref[...]
    o_ref[...] = a1 * a2 * jax.nn.sigmoid(a2)


def swiglu_pallas(
    a1: jax.Array, a2: jax.Array, block_rows: int = 128, interpret: bool = True
) -> jax.Array:
    """Plain SwiGLU product (the unstable original, for the `fp8` recipe)."""
    assert a1.shape == a2.shape and a1.ndim == 2
    rows, cols = a1.shape
    block_rows = min(block_rows, rows)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(pl.cdiv(rows, block_rows),),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(a1, a2)


def _channel_max_kernel(a1_ref, a2_ref, o_ref):
    a1 = a1_ref[...]
    a2 = a2_ref[...]
    h = a1 * a2 * jax.nn.sigmoid(a2)
    o_ref[...] = jnp.max(jnp.abs(h), axis=0, keepdims=True)


def _scale_quant_kernel(a1_ref, a2_ref, s_ref, o_ref, *, fmt: Fp8Format):
    a1 = a1_ref[...]
    a2 = a2_ref[...]
    h = a1 * a2 * jax.nn.sigmoid(a2)
    y = h * s_ref[...]  # s broadcasts over the token axis
    y = jnp.clip(y, -fmt.max, fmt.max)
    o_ref[...] = quantize_grid_arith(y, fmt)


def smooth_swiglu_pallas(
    a1: jax.Array,
    a2: jax.Array,
    fmt: Fp8Format = E4M3,
    margin: float = 1.0,
    block_rows: int = 128,
    interpret: bool = True,
    pow2: bool = True,
):
    """Fused Smooth-SwiGLU: returns ``(q, s)``.

    ``q`` [tokens, channels] — E4M3-grid values of ``h·s`` (still
    scaled; the consumer folds ``s⁻¹``), ``s`` [channels] — pow2
    per-channel scales.
    """
    assert a1.shape == a2.shape and a1.ndim == 2
    rows, cols = a1.shape
    block_rows = min(block_rows, rows)
    # Zero-pad ragged token tiles (interpret mode NaN-pads otherwise);
    # swiglu(0,0)=0 so padded rows never win the per-channel max, and the
    # padded output rows are sliced away below.
    rem = rows % block_rows
    padded_rows = rows if rem == 0 else rows + (block_rows - rem)
    if rem:
        a1 = jnp.pad(a1, ((0, padded_rows - rows), (0, 0)))
        a2 = jnp.pad(a2, ((0, padded_rows - rows), (0, 0)))
    n_tiles = pl.cdiv(padded_rows, block_rows)
    in_spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))

    # Pass 1: per-tile, per-channel amax of the SwiGLU product.
    tile_max = pl.pallas_call(
        _channel_max_kernel,
        grid=(n_tiles,),
        in_specs=[in_spec, in_spec],
        out_specs=pl.BlockSpec((1, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, cols), jnp.float32),
        interpret=interpret,
    )(a1, a2)

    amax = jnp.max(tile_max, axis=0)  # [channels]
    from ..formats import compute_scale

    s = compute_scale(amax, fmt, margin, pow2)  # JIT scale, exact via ldexp

    # Pass 2: scale + quantize each tile with the channel scales.
    q = pl.pallas_call(
        functools.partial(_scale_quant_kernel, fmt=fmt),
        grid=(n_tiles,),
        in_specs=[in_spec, in_spec, pl.BlockSpec((1, cols), lambda i: (0, 0))],
        out_specs=in_spec,
        out_shape=jax.ShapeDtypeStruct((padded_rows, cols), jnp.float32),
        interpret=interpret,
    )(a1, a2, s[None, :])
    return q[:rows], s
