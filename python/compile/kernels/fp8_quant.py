"""Pallas kernel: FP8 quantize-dequantize with a per-tensor scale.

The paper's FP8 recipe quantizes every matmul operand (E4M3 forward,
E5M2 backward) with delayed per-tensor scales. On Gaudi2 this is fused
into the MME pipeline; the TPU-style mapping here tiles the tensor
through VMEM and applies the arithmetic RNE grid rounding on the VPU
(integer bitcast ops — see ``formats.quantize_grid_arith``), so the
conversion never round-trips HBM at full precision.

Grid: 1-D over row-tiles. Block shape (block_rows, cols): the minor
(lane) axis is kept whole so the VPU sees contiguous vectors.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import Fp8Format, quantize_grid_arith


def _qdq_kernel(x_ref, scale_ref, o_ref, *, fmt: Fp8Format, saturating: bool):
    x = x_ref[...]
    scale = scale_ref[0]
    y = x * scale
    if saturating:
        y = jnp.clip(y, -fmt.max, fmt.max)
    q = quantize_grid_arith(y, fmt)
    o_ref[...] = q / scale


def fp8_qdq_pallas(
    x: jax.Array,
    scale: jax.Array,
    fmt: Fp8Format,
    saturating: bool = True,
    block_rows: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Quantize-dequantize ``x`` (2-D f32) on the fp8 grid.

    ``scale`` is a shape-(1,) f32 array (the delayed scale chosen by the
    Rust scaling manager). Returns f32 values exactly on the
    ``Q(x·scale)/scale`` grid.
    """
    assert x.ndim == 2, f"expected 2-D input, got {x.shape}"
    rows, cols = x.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    kernel = functools.partial(_qdq_kernel, fmt=fmt, saturating=saturating)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(x, scale)


def _amax_kernel(x_ref, o_ref):
    # Per-tile amax; the host-side jnp.max over tiles completes the
    # reduction (two-pass pattern, cf. smooth_swiglu kernel).
    o_ref[0] = jnp.max(jnp.abs(x_ref[...]))


def fp8_amax_pallas(x: jax.Array, block_rows: int = 128, interpret: bool = True) -> jax.Array:
    """Tensor amax via a tiled Pallas reduction (reported to the Rust
    delayed-scaling history alongside each quantization)."""
    assert x.ndim == 2
    rows, cols = x.shape
    block_rows = min(block_rows, rows)
    # Interpret mode NaN-pads ragged tiles; zero-pad explicitly so the
    # reduction is unaffected (|0| never wins a max against real data).
    rem = rows % block_rows
    if rem:
        x = jnp.pad(x, ((0, block_rows - rem), (0, 0)))
        rows = x.shape[0]
    n_tiles = pl.cdiv(rows, block_rows)
    partial = pl.pallas_call(
        _amax_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_tiles,), jnp.float32),
        interpret=interpret,
    )(x)
    return jnp.max(partial)
