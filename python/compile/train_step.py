"""L2: the exported computations (gradient step, eval, probes).

Three artifact families, all pure functions of their inputs so Rust owns
every piece of state between calls:

* ``grad_step`` — fwd+bwd. One ``jax.value_and_grad`` over
  ``model.loss_fn`` returns the loss, parameter grads, forward amaxes
  (aux) and gradient amaxes (cotangent of the scales vector — see
  ``quant_ops.grad_q``). Note the ``g_qkv`` slot is shared by the three
  QKV matmuls, so its cotangent is the *sum* of three amaxes — a ≤4×
  conservative (pow2) scale, documented here and accounted for in the
  Rust policy.
* ``eval_step`` — fwd only: summed NLL + top-1 hits for perplexity /
  accuracy suites (Table 2 substitute).
* ``probe_step`` — fwd with per-layer SwiGLU pre-activations exposed
  (|w2ᵀx| histograms, paper Fig. 9; channel data for Fig. 2c/d).
"""

import jax
import jax.numpy as jnp

from . import model as M
from .kernels.ref import swiglu


def make_grad_step(cfg: M.ModelConfig, recipe: M.Recipe):
    """Returns grad_step(params_dict, scales, batch) ->
    (loss, grads_dict, amax_vec, monitor)."""

    def grad_step(params, scales_vec, batch):
        (loss, (fwd_amax, monitor)), (gparams, gscales) = jax.value_and_grad(
            M.loss_fn, argnums=(0, 1), has_aux=True
        )(params, scales_vec, batch, cfg, recipe)
        # fwd slots carry zeros in gscales and vice versa → sum merges.
        # The `0·scales` term pins the scales argument in the jaxpr even
        # for recipes that never quantize (bf16) — without it jax prunes
        # the parameter and the artifact arity diverges from the manifest.
        amax_vec = fwd_amax + gscales + 0.0 * scales_vec
        return loss, gparams, amax_vec, monitor

    return grad_step


def make_eval_step(cfg: M.ModelConfig, recipe: M.Recipe):
    """Returns eval_step(params, scales, batch) -> (nll_sum, n_correct, n_tokens)."""

    def eval_step(params, scales_vec, batch):
        tokens, targets = batch[:, :-1], batch[:, 1:]
        logits, _, _ = M.forward(params, scales_vec, tokens, cfg, recipe)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        correct = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
        n = jnp.float32(targets.size)
        # pin the scales argument (see make_grad_step)
        return jnp.sum(nll) + 0.0 * scales_vec[0], jnp.sum(correct), n

    return eval_step


def make_probe_step(cfg: M.ModelConfig, recipe: M.Recipe, layer: int):
    """Returns probe_step(params, scales, batch) ->
    (preact2 [T, ff], product [T, ff]) at the given layer.

    ``preact2 = x·w2`` is the gate input whose |·| distribution Fig. 9
    histograms; ``product`` is the SwiGLU output whose channels Fig. 2
    tracks. Runs the unquantized forward (probing is an analysis pass).
    """

    def probe_step(params, scales_vec, batch):
        tokens = batch[:, :-1]
        # pin every parameter in the jaxpr (the probe's truncated forward
        # would otherwise let jax prune head/ln_f/w3 and change the
        # artifact arity vs the manifest)
        pin = sum(0.0 * p.reshape(-1)[0] for p in params.values())
        x = params["embed"][tokens] + pin
        # run layers 0..layer-1 fully, then recompute the MLP entry of
        # `layer` to expose its internals
        bf16_recipe = M.RECIPES["bf16"]
        for li in range(layer + 1):
            lp = {k: params[k][li] for k in M.LAYER_PARAMS if k in params}
            if li < layer:
                x, _, _ = M._block(x, lp, scales_vec, li, cfg, bf16_recipe)
            else:
                # replicate the attention half to land exactly at the
                # MLP input of the target layer, then expose internals
                x2 = M.rmsnorm(x + _attn_half(x, lp, cfg), lp["ln_2"], cfg.norm_eps)
                a1 = jnp.dot(x2, lp["w1"], preferred_element_type=jnp.float32)
                a2 = jnp.dot(x2, lp["w2"], preferred_element_type=jnp.float32)
                prod = swiglu(a1, a2)
                f = cfg.d_ff
                # pin the scales argument (see make_grad_step)
                return a2.reshape(-1, f) + 0.0 * scales_vec[0], prod.reshape(-1, f)
        raise AssertionError("unreachable")

    def _attn_half(x, lp, cfg):
        """Attention residual branch only (f32), to position the probe
        exactly at the MLP input of the target layer."""
        recipe = M.RECIPES["bf16"]
        dtype = recipe.compute_dtype
        xn = M.rmsnorm(x, lp["ln_1"], cfg.norm_eps)
        q = jnp.dot(xn, lp["wq"], preferred_element_type=jnp.float32)
        k = jnp.dot(xn, lp["wk"], preferred_element_type=jnp.float32)
        v = jnp.dot(xn, lp["wv"], preferred_element_type=jnp.float32)
        b, s, d = x.shape
        nh, hd = cfg.n_heads, cfg.head_dim
        q = M.rope(q.reshape(b, s, nh, hd), cfg.rope_base)
        k = M.rope(k.reshape(b, s, nh, hd), cfg.rope_base)
        v = v.reshape(b, s, nh, hd)
        att = jnp.einsum("bqhe,bkhe->bhqk", q.astype(dtype), k.astype(dtype),
                         preferred_element_type=jnp.float32) / jnp.sqrt(jnp.float32(hd))
        mask = jnp.tril(jnp.ones((s, s), bool))
        att = jax.nn.softmax(jnp.where(mask[None, None], att, jnp.float32(-1e30)), axis=-1)
        out = jnp.einsum("bhqk,bkhe->bqhe", att.astype(dtype), v.astype(dtype),
                         preferred_element_type=jnp.float32).reshape(b, s, d)
        return jnp.dot(out, lp["wo"], preferred_element_type=jnp.float32)

    return probe_step


# --------------------------------------------------------------------------
# Theorem-1 microbench: a single SwiGLU layer trained with explicit ℓ2
# (paper §4.2) — exported so the Rust harness can sweep μ and watch
# w1 → ±w2.


def make_theorem1_step(d: int, f: int, n_out: int):
    """Returns step(w1, w2, w3, x, y, lr, mu, tau) ->
    (loss, w1', w2', w3', corr, r1, r2, sp, gnorm).

    Model: ŷ = (x·w1) ⊙ a2 ⊙ σ(a2/τ) @ w3 with a2 = x·w2 — SwiGLU at
    τ=1, and a harder-gated GLU variant as τ→0 (the paper notes the
    theorem covers all GLU variants since no Swish-specific property is
    used; τ controls the σ′-activity the theorem assumes away).
    Squared loss + explicit μ/2·Σ‖w‖² (paper eq. 1), full-batch SGD.

    Per-channel diagnostics of Theorem 1's stationary-point structure,
    with A_j = −μ⁻¹ Σ_n δ_nj σ(a2_nj/τ) x_n x_nᵀ:

    * ``corr[j]`` — cosine(w1_j, w2_j) (the alignment observable);
    * ``r1[j]``  — ‖A_j w2_j − w1_j‖/‖w1_j‖ (eq. I: exact at any
      stationary point, ∝ the remaining gradient otherwise);
    * ``r2[j]``  — ‖A_j w1_j − w2_j‖/‖w2_j‖ (eq. II *without* the σ′
      term: its residual at stationarity measures exactly the defect
      the theorem's σ′→0 assumption removes);
    * ``sp[j]``  — relative magnitude of the neglected σ′ term.
    """

    def gated(a1, a2, tau):
        return a1 * a2 * jax.nn.sigmoid(a2 / tau)

    def loss(w1, w2, w3, x, y, mu, tau):
        h = gated(x @ w1, x @ w2, tau)  # [N, f]
        pred = h @ w3  # [N, n_out]
        data = 0.5 * jnp.mean(jnp.sum((pred - y) ** 2, axis=-1))
        reg = 0.5 * mu * (jnp.sum(w1**2) + jnp.sum(w2**2) + jnp.sum(w3**2))
        return data + reg

    def step(w1, w2, w3, x, y, lr, mu, tau):
        l, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(
            w1, w2, w3, x, y, mu, tau
        )
        gnorm = jnp.sqrt(sum(jnp.sum(g**2) for g in grads))

        # --- Theorem-1 diagnostics at the *current* point (pre-update),
        # so the autodiff grads above are the exact ground truth.
        n = x.shape[0]
        a1 = x @ w1
        a2 = x @ w2
        sig = jax.nn.sigmoid(a2 / tau)
        h = a1 * a2 * sig
        delta = ((h @ w3) - y) @ w3.T / n  # [N, f] = ∂data/∂h

        # Eq. I: ∇_{w1_j} = Σ_n δ_nj g(a2_nj) x_n + μ w1_j with
        # g(a2_nj) = σ_nj·(x_nᵀw2_j) ⇒ (w1_j − A_j w2_j) ≡ ∇_{w1_j}/μ,
        # A_j = −μ⁻¹ Σ_n δ_nj σ_nj x_n x_nᵀ (the proof's symmetric matrix).
        w_eq = -delta * sig / mu  # [N, f]

        def apply_A(v):  # v: [d, f], applies each channel's A_j to v_j
            xv = x @ v  # [N, f]
            return jnp.einsum("nj,nd->dj", w_eq * xv, x)

        Aw2 = apply_A(w2)
        Aw1 = apply_A(w1)
        n1 = jnp.linalg.norm(w1, axis=0) + 1e-12
        n2 = jnp.linalg.norm(w2, axis=0) + 1e-12

        # neglected σ′ term of eq. II: SP_j = −μ⁻¹ Σ_n δ a1 a2 σ′ x_n
        sigp = sig * (1.0 - sig) / tau
        sp_term = jnp.einsum("nj,nd->dj", (-delta * a2 * sigp / mu) * a1, x)
        sp = jnp.linalg.norm(sp_term, axis=0) / n2

        # exact identities (validate the proof's algebra against autodiff):
        #   id1_j = ‖(w1_j − A_j w2_j) − ∇w1_j/μ‖ / ‖w1_j‖  → 0
        #   id2_j = ‖(w2_j − A_j w1_j) − ∇w2_j/μ − SP_j‖ / ‖w2_j‖ → 0
        id1 = jnp.linalg.norm((w1 - Aw2) - grads[0] / mu, axis=0) / n1
        id2 = jnp.linalg.norm((w2 - Aw1) - grads[1] / mu - sp_term, axis=0) / n2

        # eq. I residual itself (→ 0 as stationarity is approached)
        r1 = jnp.linalg.norm(w1 - Aw2, axis=0) / n1

        corr = jnp.sum(w1 * w2, axis=0) / (n1 * n2)

        w1n = w1 - lr * grads[0]
        w2n = w2 - lr * grads[1]
        w3n = w3 - lr * grads[2]
        return l, w1n, w2n, w3n, corr, id1, id2, sp, r1, gnorm

    return step
