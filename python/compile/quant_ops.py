"""Differentiable FP8 quantization ops for the training graph.

Three primitives implement the paper's mixed-precision recipe inside a
single ``jax.grad``:

* :func:`ste_qdq` — quantize-dequantize with a straight-through
  estimator backward. Used on E4M3 forward operands (activations and
  weights entering matmuls).
* :func:`grad_q` — identity forward; backward quantizes the incoming
  cotangent to E5M2 **and reports its amax as the cotangent of the
  scale argument** (the Transformer-Engine JAX trick). One grad call
  therefore yields parameter grads *and* every gradient amax the Rust
  delayed-scaling manager needs, with no extra passes.
* :func:`ste_attach` — generic straight-through value attachment,
  used to splice Pallas-kernel outputs (e.g. Smooth-SwiGLU's per-channel
  quantized product) into the autodiff graph.
"""

import functools

import jax
import jax.numpy as jnp

from .formats import FORMATS, qdq


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def ste_qdq(x, scale, fmt_name: str, saturating: bool = True):
    """``Q(x·scale)/scale`` forward, identity backward (STE)."""
    return qdq(x, FORMATS[fmt_name], scale, saturating)


def _ste_qdq_fwd(x, scale, fmt_name, saturating):
    return ste_qdq(x, scale, fmt_name, saturating), None


def _ste_qdq_bwd(fmt_name, saturating, _res, g):
    return g, jnp.zeros((), jnp.float32)


ste_qdq.defvjp(_ste_qdq_fwd, _ste_qdq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def grad_q(y, scale_g, fmt_name: str = "e5m2", saturating: bool = True):
    """Identity fwd; bwd quantizes the cotangent to ``fmt_name`` with
    ``scale_g`` and emits ``amax(g)`` as the cotangent of ``scale_g``."""
    del scale_g
    return y


def _grad_q_fwd(y, scale_g, fmt_name, saturating):
    return y, scale_g


def _grad_q_bwd(fmt_name, saturating, scale_g, g):
    amax_g = jnp.max(jnp.abs(g)).astype(jnp.float32)
    gq = qdq(g, FORMATS[fmt_name], scale_g, saturating)
    return gq, amax_g


grad_q.defvjp(_grad_q_fwd, _grad_q_bwd)


def ste_attach(value_diff: jax.Array, value_exact: jax.Array) -> jax.Array:
    """Forward ``value_exact``, backward d/d(value_diff) (straight-through).

    ``value_exact`` is typically a Pallas kernel output whose
    quantization step has no useful derivative; ``value_diff`` is the
    differentiable jnp expression of the same quantity.
    """
    return value_diff + jax.lax.stop_gradient(value_exact - value_diff)
