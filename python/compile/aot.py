"""AOT exporter: lower every artifact to HLO **text** + JSON manifest.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact gets:

* ``artifacts/<name>.hlo.txt``   — the lowered module
* ``artifacts/<name>.manifest.json`` — input/output layout, parameter
  init specs, scale-site table, model config, FLOPs estimate. This is
  the single contract the Rust runtime parses; nothing about tensor
  ordering is implicit.

Artifacts are content-stamped: re-running is a no-op unless the
``python/compile`` sources changed (``make artifacts`` idempotence).

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import fnmatch
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .adam import make_adam_step
from .train_step import make_eval_step, make_grad_step, make_probe_step, make_theorem1_step

# ---------------------------------------------------------------- registry

# batch size per model size (training-step token counts)
BATCH = {"tiny": 2, "s1m": 8, "s8m": 8, "m100": 2}

# grad/eval graph depends only on these Recipe fields; dedupe variants
GRAD_RECIPES = {
    "tiny": ["bf16", "fp8", "fp8_smooth"],
    "s1m": ["bf16", "bf16_smooth", "fp8", "fp8_nosat", "fp8_noq3",
            "fp8_noq3_nosat", "fp8_smooth", "fp8_smooth_nosat",
            "gelu_fp8", "gelu_bf16"],
    "s8m": ["bf16", "fp8", "fp8_noq3", "fp8_smooth"],
    "m100": ["bf16", "fp8_smooth"],
}
EVAL_RECIPES = {
    "tiny": ["bf16"],
    "s1m": ["bf16", "fp8_noq3", "fp8_smooth"],
    "m100": ["fp8_smooth"],
}
# Adam variants: (m_fmt, v_fmt) — '' means fp32 (the Fig. 5 grid + baseline)
ADAM_VARIANTS = [("", ""), ("e4m3", "e5m2"), ("e4m3", "e4m3"),
                 ("e5m2", "e5m2"), ("e5m2", "e4m3")]
ADAM_CHUNKS = [262144, 4194304]

THEOREM1_SHAPE = dict(d=16, f=4, n_out=4, n=512)


def flops_per_grad_step(cfg: M.ModelConfig, batch: int, activation: str) -> int:
    """6·params·tokens rule (fwd 2 + bwd 4), attention excluded —
    matches how the paper's TFLOPS column is computed."""
    tokens = batch * cfg.seq_len
    return 6 * cfg.param_count(activation) * tokens


# ---------------------------------------------------------------- lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_tree_specs(cfg, recipe):
    specs = M.param_specs(cfg, recipe)
    return {k: _spec(shape) for k, (shape, _) in specs.items()}


def _manifest_params(cfg, recipe):
    """Parameter entries in jax pytree-flatten order (sorted names)."""
    specs = M.param_specs(cfg, recipe)
    out = []
    for k in sorted(specs):
        shape, std = specs[k]
        out.append({"name": k, "shape": list(shape), "init_std": std})
    return out


def build_grad(size: str, recipe_name: str):
    cfg = M.SIZES[size]
    recipe = M.RECIPES[recipe_name]
    batch = BATCH[size]
    ns = M.n_scale_sites(cfg)
    fn = make_grad_step(cfg, recipe)
    lowered = jax.jit(fn).lower(
        _param_tree_specs(cfg, recipe),
        _spec((ns,)),
        _spec((batch, cfg.seq_len + 1), jnp.int32),
    )
    params = _manifest_params(cfg, recipe)
    manifest = {
        "kind": "grad",
        "size": size,
        "recipe": recipe_name,
        "batch": batch,
        "seq_len": cfg.seq_len,
        "n_scales": ns,
        "n_layers": cfg.n_layers,
        "sites_per_layer": M.SITES_PER_LAYER,
        "params": params,
        "inputs": [f"param:{p['name']}" for p in params] + ["scales", "batch"],
        "outputs": ["loss"] + [f"grad:{p['name']}" for p in params]
                   + ["amax", "monitor"],
        "monitor_shape": [cfg.n_layers, 3],
        "model": cfg.__dict__,
        "param_count": cfg.param_count(recipe.activation),
        "flops_per_step": flops_per_grad_step(cfg, batch, recipe.activation),
    }
    return lowered, manifest


def build_eval(size: str, recipe_name: str):
    cfg = M.SIZES[size]
    recipe = M.RECIPES[recipe_name]
    batch = BATCH[size]
    ns = M.n_scale_sites(cfg)
    fn = make_eval_step(cfg, recipe)
    lowered = jax.jit(fn).lower(
        _param_tree_specs(cfg, recipe),
        _spec((ns,)),
        _spec((batch, cfg.seq_len + 1), jnp.int32),
    )
    params = _manifest_params(cfg, recipe)
    manifest = {
        "kind": "eval",
        "size": size,
        "recipe": recipe_name,
        "batch": batch,
        "seq_len": cfg.seq_len,
        "n_scales": ns,
        "params": params,
        "inputs": [f"param:{p['name']}" for p in params] + ["scales", "batch"],
        "outputs": ["nll_sum", "n_correct", "n_tokens"],
        "model": cfg.__dict__,
    }
    return lowered, manifest


def build_adam(m_fmt: str, v_fmt: str, chunk: int):
    # block == chunk: one grid step. Interpret-mode pallas materializes a
    # full-buffer dynamic-update-slice per grid step, so multi-step grids
    # are quadratic in chunk size on CPU (measured 3.2s vs 25ms/call).
    # On real hardware the BlockSpec would tile VMEM instead.
    # The big (4M) perf variant lowers through the pure-jnp reference —
    # native f8 converts vectorize far better on the runtime's XLA than
    # the arithmetic RNE chain; the Pallas kernel path stays in the 256K
    # variant (validated bit-identical by python/tests).
    fn = make_adam_step(m_fmt, v_fmt, block=chunk, use_pallas=(chunk <= 262144))
    s = _spec((chunk,))
    lowered = jax.jit(fn).lower(s, s, s, s, _spec((4,)))
    manifest = {
        "kind": "adam",
        "m_fmt": m_fmt or "fp32",
        "v_fmt": v_fmt or "fp32",
        "chunk": chunk,
        "beta1": 0.9,
        "beta2": 0.95,
        "eps": 1e-8,
        "inputs": ["p", "m", "v", "g", "scalars[lr,wd,step,grad_scale]"],
        "outputs": ["p", "m", "v"],
    }
    return lowered, manifest


def build_probe(size: str, layer: int):
    cfg = M.SIZES[size]
    recipe = M.RECIPES["bf16"]
    batch = BATCH[size]
    ns = M.n_scale_sites(cfg)
    fn = make_probe_step(cfg, recipe, layer)
    lowered = jax.jit(fn).lower(
        _param_tree_specs(cfg, recipe),
        _spec((ns,)),
        _spec((batch, cfg.seq_len + 1), jnp.int32),
    )
    params = _manifest_params(cfg, recipe)
    manifest = {
        "kind": "probe",
        "size": size,
        "layer": layer,
        "batch": batch,
        "n_scales": ns,
        "params": params,
        "inputs": [f"param:{p['name']}" for p in params] + ["scales", "batch"],
        "outputs": ["preact2", "product"],
        "tokens": batch * cfg.seq_len,
        "d_ff": cfg.d_ff,
        "model": cfg.__dict__,
    }
    return lowered, manifest


def build_theorem1():
    sh = THEOREM1_SHAPE
    fn = make_theorem1_step(sh["d"], sh["f"], sh["n_out"])
    lowered = jax.jit(fn).lower(
        _spec((sh["d"], sh["f"])),
        _spec((sh["d"], sh["f"])),
        _spec((sh["f"], sh["n_out"])),
        _spec((sh["n"], sh["d"])),
        _spec((sh["n"], sh["n_out"])),
        _spec(()),
        _spec(()),
        _spec(()),
    )
    manifest = {
        "kind": "theorem1",
        **sh,
        "inputs": ["w1", "w2", "w3", "x", "y", "lr", "mu", "tau"],
        "outputs": ["loss", "w1", "w2", "w3", "corr", "id1", "id2", "sp", "r1", "gnorm"],
    }
    return lowered, manifest


def registry():
    """name -> builder thunk."""
    reg = {}
    for size, recipes in GRAD_RECIPES.items():
        for r in recipes:
            reg[f"grad_{size}_{r}"] = (lambda s=size, rr=r: build_grad(s, rr))
    for size, recipes in EVAL_RECIPES.items():
        for r in recipes:
            reg[f"eval_{size}_{r}"] = (lambda s=size, rr=r: build_eval(s, rr))
    for m_fmt, v_fmt in ADAM_VARIANTS:
        for chunk in ADAM_CHUNKS:
            mf = m_fmt or "fp32"
            vf = v_fmt or "fp32"
            reg[f"adam_{mf}_{vf}_c{chunk}"] = (
                lambda m=m_fmt, v=v_fmt, c=chunk: build_adam(m, v, c)
            )
    for layer in range(M.SIZES["s1m"].n_layers):
        reg[f"probe_s1m_l{layer}"] = (lambda l=layer: build_probe("s1m", l))
    reg["theorem1"] = build_theorem1
    return reg


# ---------------------------------------------------------------- driver


def _source_stamp() -> str:
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="*", help="glob over artifact names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    reg = registry()
    names = sorted(n for n in reg if fnmatch.fnmatch(n, args.only))
    if args.list:
        print("\n".join(names))
        return 0

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    stamp = _source_stamp()

    n_built = n_skipped = 0
    for name in names:
        hlo_path = out / f"{name}.hlo.txt"
        man_path = out / f"{name}.manifest.json"
        if not args.force and hlo_path.exists() and man_path.exists():
            try:
                if json.loads(man_path.read_text()).get("_stamp") == stamp:
                    n_skipped += 1
                    continue
            except json.JSONDecodeError:
                pass
        print(f"[aot] building {name} ...", flush=True)
        lowered, manifest = reg[name]()
        text = to_hlo_text(lowered)
        manifest["_stamp"] = stamp
        hlo_path.write_text(text)
        man_path.write_text(json.dumps(manifest, indent=1))
        print(f"[aot]   wrote {hlo_path.name} ({len(text)//1024} KiB)", flush=True)
        n_built += 1

    print(f"[aot] done: {n_built} built, {n_skipped} up-to-date")
    return 0


if __name__ == "__main__":
    sys.exit(main())
