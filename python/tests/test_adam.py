"""Adam step builder (the exported optimizer artifact's function)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.adam import make_adam_step
from compile.kernels.ref import adam_fp8_ref


def _state(n=1000, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    p = jax.random.normal(ks[0], (n,))
    m = 0.01 * jax.random.normal(ks[1], (n,))
    v = jnp.abs(1e-4 * jax.random.normal(ks[2], (n,)))
    g = 0.02 * jax.random.normal(ks[3], (n,))
    return p, m, v, g


@pytest.mark.parametrize("fmts", [("", ""), ("e4m3", "e5m2")])
def test_matches_ref(fmts):
    m_fmt, v_fmt = fmts
    p, m, v, g = _state()
    step = make_adam_step(m_fmt, v_fmt, use_pallas=True, block=256)
    scalars = jnp.asarray([1e-3, 0.1, 7.0, 1.0], jnp.float32)
    p1, m1, v1 = step(p, m, v, g, scalars)
    from compile.formats import FORMATS

    p2, m2, v2 = adam_fp8_ref(
        p, m, v, g, 1e-3, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
        step=7, m_fmt=FORMATS.get(m_fmt), v_fmt=FORMATS.get(v_fmt),
    )
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6, atol=1e-12)


def test_grad_scale_folds_clipping():
    p, m, v, g = _state()
    step = make_adam_step("", "")
    full = step(p, m, v, g, jnp.asarray([1e-3, 0.0, 1.0, 1.0], jnp.float32))
    halved = step(p, m, v, 0.5 * g, jnp.asarray([1e-3, 0.0, 1.0, 1.0], jnp.float32))
    scaled = step(p, m, v, g, jnp.asarray([1e-3, 0.0, 1.0, 0.5], jnp.float32))
    np.testing.assert_allclose(np.asarray(scaled[0]), np.asarray(halved[0]), rtol=1e-6)
    with np.testing.assert_raises(AssertionError):
        np.testing.assert_allclose(np.asarray(scaled[0]), np.asarray(full[0]), rtol=1e-6)


def test_zero_grad_pure_decay():
    p, m, v, _ = _state()
    m = jnp.zeros_like(m)
    v = jnp.zeros_like(v)
    step = make_adam_step("", "")
    scalars = jnp.asarray([1e-2, 0.5, 1.0, 1.0], jnp.float32)
    p1, m1, v1 = step(p, m, v, jnp.zeros_like(p), scalars)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p) * (1 - 1e-2 * 0.5), rtol=1e-6)
    assert float(jnp.max(jnp.abs(m1))) == 0.0
    assert float(jnp.max(jnp.abs(v1))) == 0.0


def test_padding_chunk_is_inert():
    """Zero-padded tail (how Rust pads the last chunk) must stay zero."""
    p, m, v, g = _state(512)
    pad = 128
    z = jnp.zeros((pad,))
    pp = jnp.concatenate([p, z])
    mm = jnp.concatenate([m, z])
    vv = jnp.concatenate([v, z])
    gg = jnp.concatenate([g, z])
    step = make_adam_step("e4m3", "e5m2")
    scalars = jnp.asarray([1e-3, 0.1, 3.0, 1.0], jnp.float32)
    p1, m1, v1 = step(pp, mm, vv, gg, scalars)
    assert float(jnp.max(jnp.abs(p1[-pad:]))) == 0.0
    assert float(jnp.max(jnp.abs(m1[-pad:]))) == 0.0
    # and the live head must match the unpadded run
    p2, _, _ = step(p, m, v, g, scalars)
    np.testing.assert_allclose(np.asarray(p1[:512]), np.asarray(p2), rtol=1e-6, atol=1e-8)


def test_fp8_moments_drift_bounded():
    """Long-run moment quantization must not bias the trajectory badly:
    100 steps of fp8-moment Adam stays close to fp32-moment Adam."""
    p, m, v, _ = _state(256, seed=3)
    m = jnp.zeros_like(m)
    v = jnp.zeros_like(v)
    fp32 = make_adam_step("", "")
    fp8 = make_adam_step("e4m3", "e5m2")
    p_a = p_b = p
    m_a = m_b = m
    v_a = v_b = v
    key = jax.random.key(9)
    for t in range(100):
        key, sub = jax.random.split(key)
        g = 0.02 * jax.random.normal(sub, p.shape)
        scal = jnp.asarray([1e-3, 0.0, t + 1.0, 1.0], jnp.float32)
        p_a, m_a, v_a = fp32(p_a, m_a, v_a, g, scal)
        p_b, m_b, v_b = fp8(p_b, m_b, v_b, g, scal)
    drift = float(jnp.linalg.norm(p_a - p_b) / jnp.linalg.norm(p_a - p))
    assert drift < 0.2, f"fp8-moment trajectory drift {drift}"
