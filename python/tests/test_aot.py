"""AOT exporter contracts: registry coverage, manifest consistency,
and the HLO-text interchange invariants the Rust loader depends on."""

import json
import pathlib

import pytest

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_registry_covers_every_experiment():
    reg = aot.registry()
    # every grad/eval recipe listed must resolve to a known Recipe
    for size, recipes in {**aot.GRAD_RECIPES, **aot.EVAL_RECIPES}.items():
        assert size in M.SIZES
        for r in recipes:
            assert r in M.RECIPES, r
    # the Fig. 5 grid and the fp32 baseline must be among adam variants
    fmts = {(m or "fp32", v or "fp32") for m, v in aot.ADAM_VARIANTS}
    assert ("fp32", "fp32") in fmts
    for m in ("e4m3", "e5m2"):
        for v in ("e4m3", "e5m2"):
            assert (m, v) in fmts
    assert "theorem1" in reg


def test_grad_build_manifest_is_consistent():
    lowered, manifest = aot.build_grad("tiny", "fp8")
    cfg = M.SIZES["tiny"]
    assert manifest["n_scales"] == M.n_scale_sites(cfg)
    assert manifest["sites_per_layer"] == M.SITES_PER_LAYER
    names = [p["name"] for p in manifest["params"]]
    assert names == sorted(names), "manifest order must be the pytree (sorted) order"
    assert manifest["inputs"][-2:] == ["scales", "batch"]
    assert manifest["outputs"][0] == "loss"
    # flops: 6 * params * tokens
    assert manifest["flops_per_step"] == 6 * manifest["param_count"] * (
        manifest["batch"] * cfg.seq_len
    )


def _entry_param_count(text: str) -> int:
    """Number of parameters of the ENTRY computation (what the Rust
    runtime feeds positionally). In this HLO text dialect the ENTRY
    block lists them as `%name = ty[dims] parameter(K)` instructions."""
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    count = 0
    for l in lines[start + 1:]:
        if l.strip() == "}":
            break
        if " parameter(" in l:
            count += 1
    return count


def test_hlo_text_has_expected_parameter_count():
    lowered, manifest = aot.build_grad("tiny", "bf16")
    text = aot.to_hlo_text(lowered)
    expected = len(manifest["params"]) + 2  # + scales + batch
    got = _entry_param_count(text)
    assert got == expected, f"{got} vs {expected} (argument pruning?)"


def test_fp8_recipe_lowers_fp8_converts():
    lowered, _ = aot.build_grad("tiny", "fp8")
    text = aot.to_hlo_text(lowered)
    assert "f8e4m3" in text, "forward quantization must lower to f8e4m3 converts"
    assert "f8e5m2" in text, "gradient quantization must lower to f8e5m2 converts"


def test_bf16_recipe_has_no_fp8_ops():
    lowered, _ = aot.build_grad("tiny", "bf16")
    text = aot.to_hlo_text(lowered)
    assert "f8e4m3" not in text and "f8e5m2" not in text


@pytest.mark.skipif(not ARTIFACTS.is_dir(), reason="run `make artifacts` first")
def test_on_disk_manifests_parse_and_pair():
    hlos = {p.stem.replace(".hlo", "") for p in ARTIFACTS.glob("*.hlo.txt")}
    mans = {p.stem.replace(".manifest", "") for p in ARTIFACTS.glob("*.manifest.json")}
    assert hlos == mans, f"unpaired artifacts: {hlos ^ mans}"
    for p in ARTIFACTS.glob("*.manifest.json"):
        m = json.loads(p.read_text())
        assert "kind" in m and "inputs" in m and "outputs" in m, p.name


def test_adam_artifact_signature():
    lowered, manifest = aot.build_adam("e4m3", "e5m2", 1024)
    text = aot.to_hlo_text(lowered)
    assert manifest["chunk"] == 1024
    assert _entry_param_count(text) == 5  # p, m, v, g, scalars
