"""Kernel-vs-oracle: every Pallas kernel against its pure-jnp ref.

Quantization grids must match *bit-exactly*; matmul accumulation is
compared to f32 tolerance (tile-order-dependent summation).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from compile.formats import E4M3, E5M2, FORMATS
from compile.kernels import (
    adam_fp8_pallas,
    fp8_amax_pallas,
    fp8_matmul_pallas,
    fp8_qdq_pallas,
    smooth_swiglu_pallas,
    swiglu_pallas,
)
from compile.kernels import ref


def _rand(key, shape, scale=3.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


def _bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return ((a == b) | (np.isnan(a) & np.isnan(b))).all()


# ---------------------------------------------------------------- fp8_qdq


@pytest.mark.parametrize("fmt", [E4M3, E5M2], ids=lambda f: f.name)
@pytest.mark.parametrize("shape", [(8, 16), (128, 64), (100, 33), (1, 7)])
def test_qdq_kernel_matches_ref(fmt, shape):
    x = _rand(jax.random.key(0), shape, scale=100.0)
    scale = jnp.asarray([0.5], jnp.float32)
    got = fp8_qdq_pallas(x, scale, fmt)
    want = ref.fp8_quantize_ref(x, fmt, scale[0])
    assert _bitwise_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    cols=st.integers(1, 40),
    log2_scale=st.integers(-6, 6),
    fmt_name=st.sampled_from(["e4m3", "e5m2"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qdq_kernel_hypothesis(rows, cols, log2_scale, fmt_name, seed):
    fmt = FORMATS[fmt_name]
    x = _rand(jax.random.key(seed), (rows, cols), scale=500.0)
    scale = jnp.asarray([2.0**log2_scale], jnp.float32)
    got = fp8_qdq_pallas(x, scale, fmt, block_rows=32)
    want = ref.fp8_quantize_ref(x, fmt, scale[0])
    assert _bitwise_equal(got, want)


@pytest.mark.parametrize("shape", [(8, 16), (130, 17)])
def test_amax_kernel(shape):
    x = _rand(jax.random.key(3), shape, scale=7.0)
    got = fp8_amax_pallas(x, block_rows=32)
    assert float(got) == float(jnp.max(jnp.abs(x)))


# ------------------------------------------------------------ swiglu path


@pytest.mark.parametrize("shape", [(16, 32), (128, 344), (65, 11)])
def test_swiglu_kernel_matches_ref(shape):
    k1, k2 = jax.random.split(jax.random.key(1))
    a1, a2 = _rand(k1, shape), _rand(k2, shape)
    got = swiglu_pallas(a1, a2, block_rows=32)
    want = ref.swiglu(a1, a2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(16, 32), (128, 344), (65, 11), (256, 128)])
def test_smooth_swiglu_matches_ref(shape):
    k1, k2 = jax.random.split(jax.random.key(2))
    a1, a2 = _rand(k1, shape, scale=5.0), _rand(k2, shape, scale=5.0)
    q_got, s_got = smooth_swiglu_pallas(a1, a2, block_rows=32)
    q_want, s_want = ref.smooth_swiglu_ref(a1, a2)
    assert _bitwise_equal(s_got, s_want)
    assert _bitwise_equal(q_got, q_want)


def test_smooth_swiglu_no_overflow_with_outlier():
    """The paper's motivating property: even a 1e6 outlier channel stays
    finite and on-grid after per-channel scaling (plain per-tensor
    quantization would NaN the whole tensor)."""
    k1, k2 = jax.random.split(jax.random.key(4))
    a1, a2 = _rand(k1, (64, 16)), _rand(k2, (64, 16))
    a1 = a1.at[:, 3].mul(1e6)  # outlier channel, as alignment produces
    q, s = smooth_swiglu_pallas(a1, a2, block_rows=16)
    assert np.isfinite(np.asarray(q)).all()
    assert (np.abs(np.asarray(q)) <= E4M3.max).all()
    # and the dequantized product still reconstructs the outlier scale
    h = np.asarray(ref.swiglu(a1, a2))
    deq = np.asarray(q) / np.asarray(s)[None, :]
    rel = np.abs(deq - h) / (np.abs(h) + 1e-3)
    assert np.median(rel) < 0.1


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(2, 80),
    cols=st.integers(1, 48),
    amp=st.floats(0.1, 1e4),
    seed=st.integers(0, 2**31 - 1),
)
def test_smooth_swiglu_hypothesis(rows, cols, amp, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    a1 = _rand(k1, (rows, cols), scale=amp)
    a2 = _rand(k2, (rows, cols))
    q_got, s_got = smooth_swiglu_pallas(a1, a2, block_rows=32)
    q_want, s_want = ref.smooth_swiglu_ref(a1, a2)
    assert _bitwise_equal(s_got, s_want)
    assert _bitwise_equal(q_got, q_want)


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize(
    "m,k,n", [(16, 16, 16), (128, 64, 32), (33, 65, 17), (256, 128, 256)]
)
def test_fp8_matmul_matches_ref(m, k, n):
    k1, k2 = jax.random.split(jax.random.key(5))
    x, w = _rand(k1, (m, k)), _rand(k2, (k, n), scale=0.5)
    sx = jnp.asarray([2.0], jnp.float32)
    sw = jnp.asarray([8.0], jnp.float32)
    got = fp8_matmul_pallas(x, w, sx, sw, block_m=32, block_n=32, block_k=32)
    want = ref.fp8_matmul_ref(x, w, sx[0], sw[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------------ adam


@pytest.mark.parametrize("mv", [(E4M3, E5M2), (None, None), (E4M3, E4M3), (E5M2, E5M2)],
                         ids=["e4m3-e5m2", "fp32", "e4m3-e4m3", "e5m2-e5m2"])
@pytest.mark.parametrize("n", [64, 4097])
def test_adam_kernel_matches_ref(mv, n):
    m_fmt, v_fmt = mv
    keys = jax.random.split(jax.random.key(6), 4)
    p, m, v, g = (_rand(k, (n,), s) for k, s in zip(keys, (1.0, 0.01, 1e-4, 0.02)))
    v = jnp.abs(v)
    args = dict(lr=1e-3, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1, step=7,
                m_fmt=m_fmt, v_fmt=v_fmt)
    p1, m1, v1 = adam_fp8_pallas(p, m, v, g, block=1024, **args)
    p2, m2, v2 = ref.adam_fp8_ref(p, m, v, g, **args)
    if m_fmt is not None:
        # grid snapping makes the comparison exact
        assert _bitwise_equal(m1, m2)
        assert _bitwise_equal(v1, v2)
    else:
        # pure-f32 path: XLA may fuse mul+add differently in the two
        # lowerings, so allow last-ulp drift
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6, atol=1e-12)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-6, atol=1e-7)


def test_adam_moments_on_fp8_grid():
    """Stored moments must be exactly representable in their formats —
    this is what lets the Rust checkpointer pack them into u8."""
    import ml_dtypes

    keys = jax.random.split(jax.random.key(7), 4)
    p, m, v, g = (_rand(k, (512,), s) for k, s in zip(keys, (1.0, 0.01, 1e-4, 0.02)))
    v = jnp.abs(v)
    _, m1, v1 = adam_fp8_pallas(p, m, v, g, lr=1e-3)
    # scale by the same JIT pow2 scale and check fixed-point under cast
    for t, fmt, np_dt in ((m1, E4M3, ml_dtypes.float8_e4m3fn), (v1, E5M2, ml_dtypes.float8_e5m2)):
        amax = float(jnp.max(jnp.abs(t)))
        s = 2.0 ** np.floor(np.log2(fmt.max / max(amax, 1e-12)))
        scaled = np.asarray(t) * s
        assert _bitwise_equal(scaled.astype(np_dt).astype(np.float32), scaled)
