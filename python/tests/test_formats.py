"""Format-level correctness: the arithmetic RNE quantizer must be
bit-exact against the native XLA/ml_dtypes conversion, everywhere."""

import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.formats import E4M3, E5M2, FORMATS, compute_scale, qdq, quantize_grid, quantize_grid_arith

FMTS = [E4M3, E5M2]
NP_DTYPES = {"e4m3": ml_dtypes.float8_e4m3fn, "e5m2": ml_dtypes.float8_e5m2}


def _assert_bitwise_equal(a, b):
    a = np.asarray(a)
    b = np.asarray(b)
    both_nan = np.isnan(a) & np.isnan(b)
    eq = (a == b) | both_nan
    # +0/-0 compare equal under ==, which is what we want.
    assert eq.all(), f"mismatch at {np.argwhere(~eq)[:10]}: {a[~eq][:10]} vs {b[~eq][:10]}"


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_all_grid_points_roundtrip(fmt):
    """Every representable fp8 value must be a fixed point of both quantizers."""
    codes = np.arange(256, dtype=np.uint8).view(NP_DTYPES[fmt.name])
    vals = codes.astype(np.float32)
    finite = vals[np.isfinite(vals)]
    _assert_bitwise_equal(quantize_grid(jnp.asarray(finite), fmt), finite)
    _assert_bitwise_equal(quantize_grid_arith(jnp.asarray(finite), fmt), finite)


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_midpoints_round_to_even(fmt):
    """Exact midpoints between adjacent grid points must round to even
    (the tie-break delayed scaling relies on for unbiasedness)."""
    codes = np.arange(0, 254, dtype=np.uint8).view(NP_DTYPES[fmt.name])
    vals = codes.astype(np.float32)
    ok = np.isfinite(vals) & np.isfinite(np.roll(vals, -1)) & (np.roll(vals, -1) > vals)
    lo, hi = vals[:-1][ok[:-1]], np.roll(vals, -1)[:-1][ok[:-1]]
    mid = (lo.astype(np.float64) + hi) / 2.0
    mid = mid.astype(np.float32)
    want = mid.astype(NP_DTYPES[fmt.name]).astype(np.float32)
    got = np.asarray(quantize_grid_arith(jnp.asarray(mid), fmt))
    _assert_bitwise_equal(got, want)


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_special_values(fmt):
    x = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, fmt.max, -fmt.max,
                  fmt.max * 1.0001, fmt.min_subnormal / 2, fmt.min_subnormal * 0.75],
                 np.float32)
    want = x.astype(NP_DTYPES[fmt.name]).astype(np.float32)
    got = np.asarray(quantize_grid_arith(jnp.asarray(x), fmt))
    _assert_bitwise_equal(got, want)


@settings(max_examples=200, deadline=None)
@given(
    st.lists(
        st.floats(width=32, allow_nan=True, allow_infinity=True),
        min_size=1,
        max_size=64,
    ),
    st.sampled_from(["e4m3", "e5m2"]),
)
def test_arith_matches_native_hypothesis(vals, fmt_name):
    """Property: arithmetic quantizer == ml_dtypes cast for arbitrary f32."""
    fmt = FORMATS[fmt_name]
    x = np.asarray(vals, np.float32)
    want = x.astype(NP_DTYPES[fmt_name]).astype(np.float32)
    got = np.asarray(quantize_grid_arith(jnp.asarray(x), fmt))
    _assert_bitwise_equal(got, want)


@settings(max_examples=100, deadline=None)
@given(
    st.lists(st.floats(-1e4, 1e4, width=32), min_size=1, max_size=64),
    st.sampled_from(["e4m3", "e5m2"]),
    st.integers(-8, 8),
)
def test_qdq_error_bound(vals, fmt_name, log2_scale):
    """Property: saturating qdq error ≤ half a grid step at the value's
    binade (the bound the paper's scaling policy is designed around)."""
    fmt = FORMATS[fmt_name]
    scale = float(2.0**log2_scale)
    x = np.asarray(vals, np.float32)
    q = np.asarray(qdq(jnp.asarray(x), fmt, scale))
    assert np.isfinite(q).all()
    y = np.clip(x * scale, -fmt.max, fmt.max)
    step = np.maximum(2.0 ** (np.floor(np.log2(np.maximum(np.abs(y), fmt.min_normal)))) * 2.0**-fmt.man_bits,
                      fmt.min_subnormal)
    err = np.abs(q * scale - y)
    assert (err <= step / 2 + 1e-12).all()


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_compute_scale_positions_amax_in_range(fmt):
    """scale(amax)·amax must land in (max/4, max] for pow2 scales."""
    for amax in [1e-8, 1e-3, 0.5, 1.0, 37.0, 448.0, 1e6]:
        s = float(compute_scale(jnp.float32(amax), fmt))
        assert s == 2.0 ** round(np.log2(s)), "scale must be a power of two"
        assert amax * s <= fmt.max * (1 + 1e-6)
        assert amax * s > fmt.max / 4


def test_formats_constants():
    assert E4M3.max == 448.0 and E5M2.max == 57344.0
    assert E4M3.min_subnormal == 2.0**-9 and E5M2.min_subnormal == 2.0**-16
    assert not E4M3.has_inf and E5M2.has_inf
