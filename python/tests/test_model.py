"""Model-level tests: shapes, recipes, monitoring, and quantization
semantics of the Llama-style decoder."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile.train_step import make_eval_step, make_grad_step


def init_params(cfg, recipe, seed=0):
    specs = M.param_specs(cfg, recipe)
    key = jax.random.key(seed)
    params = {}
    for k in sorted(specs):
        shape, std = specs[k]
        key, sub = jax.random.split(key)
        params[k] = jnp.ones(shape) if std < 0 else std * jax.random.normal(sub, shape)
    return params


CFG = M.SIZES["tiny"]


def batch_for(cfg, b=2, seed=1):
    return jax.random.randint(jax.random.key(seed), (b, cfg.seq_len + 1), 0, cfg.vocab)


@pytest.fixture(scope="module")
def tiny_setup():
    recipe = M.RECIPES["fp8"]
    params = init_params(CFG, recipe)
    scales = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    return params, scales


def test_forward_shapes(tiny_setup):
    params, scales = tiny_setup
    tokens = batch_for(CFG)[:, :-1]
    logits, amax, monitor = M.forward(params, scales, tokens, CFG, M.RECIPES["fp8"])
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert amax.shape == (M.n_scale_sites(CFG),)
    assert monitor.shape == (CFG.n_layers, 3)


def test_initial_loss_near_uniform(tiny_setup):
    params, scales = tiny_setup
    loss, _ = M.loss_fn(params, scales, batch_for(CFG), CFG, M.RECIPES["fp8"])
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.25


@pytest.mark.parametrize("rname", ["bf16", "fp8", "fp8_noq3", "fp8_smooth",
                                   "fp8_nosat", "bf16_smooth"])
def test_recipes_agree_at_init(rname):
    """With well-conditioned activations every recipe's loss must sit
    within quantization noise of the bf16 baseline."""
    recipe = M.RECIPES[rname]
    params = init_params(CFG, recipe)
    scales = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    loss, _ = M.loss_fn(params, scales, batch_for(CFG), CFG, recipe)
    base = M.loss_fn(params, scales, batch_for(CFG), CFG, M.RECIPES["bf16"])[0]
    assert abs(float(loss) - float(base)) < 0.05, rname


@pytest.mark.parametrize("rname", ["gelu_bf16", "gelu_fp8"])
def test_gelu_variant(rname):
    recipe = M.RECIPES[rname]
    assert "w2" not in M.param_specs(CFG, recipe)
    params = init_params(CFG, recipe)
    scales = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    loss, (amax, monitor) = M.loss_fn(params, scales, batch_for(CFG), CFG, recipe)
    assert np.isfinite(float(loss))


def test_grads_match_autodiff_without_quant():
    """bf16 recipe custom_vjp paths must not alter gradients: compare
    against a recipe-free reimplementation via the same loss."""
    recipe = M.RECIPES["bf16"]
    params = init_params(CFG, recipe)
    scales = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    batch = batch_for(CFG)
    step = make_grad_step(CFG, recipe)
    loss, grads, _, _ = step(params, scales, batch)
    # direct autodiff of the same loss_fn
    g2 = jax.grad(lambda p: M.loss_fn(p, scales, batch, CFG, recipe)[0])(params)
    for k in grads:
        np.testing.assert_allclose(
            np.asarray(grads[k]), np.asarray(g2[k]), rtol=1e-5, atol=1e-7, err_msg=k
        )


def test_grad_amax_slots_populated_for_fp8():
    recipe = M.RECIPES["fp8"]
    params = init_params(CFG, recipe)
    scales = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    step = make_grad_step(CFG, recipe)
    _, _, amax, _ = step(params, scales, batch_for(CFG))
    amax = np.asarray(amax).reshape(CFG.n_layers, len(M.SITES_PER_LAYER))
    for li in range(CFG.n_layers):
        for si, site in enumerate(M.SITES_PER_LAYER):
            assert amax[li, si] > 0, f"layer {li} site {site} amax missing"


def test_bad_scale_degrades_only_fp8():
    """Tiny scales flush every quantized tensor to zero in the fp8
    recipe (all block outputs die, so block-weight grads vanish) but
    leave bf16 — which ignores scales — untouched: the knob the Rust
    scaling manager owns really is load-bearing."""
    params = init_params(CFG, M.RECIPES["fp8"])
    tiny_scales = jnp.full((M.n_scale_sites(CFG),), 1e-6, jnp.float32)
    ones = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    batch = batch_for(CFG)

    def w1_grad_norm(recipe, scales):
        step = make_grad_step(CFG, recipe)
        _, grads, _, _ = step(params, scales, batch)
        return float(jnp.linalg.norm(grads["w1"]))

    good = w1_grad_norm(M.RECIPES["fp8"], ones)
    bad = w1_grad_norm(M.RECIPES["fp8"], tiny_scales)
    bf16_bad = w1_grad_norm(M.RECIPES["bf16"], tiny_scales)
    assert bf16_bad == pytest.approx(w1_grad_norm(M.RECIPES["bf16"], ones), rel=1e-5)
    assert bad < good / 10.0, f"flushed scales must kill fp8 signal ({bad} vs {good})"


def test_monitor_tracks_swiglu_amax():
    """Injecting an outlier channel must show up in the monitor's
    SwiGLU-product slot (the Fig. 1 signal)."""
    recipe = M.RECIPES["fp8_noq3"]
    params = init_params(CFG, recipe)
    params["w1"] = params["w1"].at[0, :, 3].mul(100.0)
    params["w2"] = params["w2"].at[0, :, 3].mul(100.0)
    scales = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    _, (_, monitor) = M.loss_fn(params, scales, batch_for(CFG), CFG, recipe)
    assert float(monitor[0, 0]) > 10.0 * float(monitor[1, 0])


def test_smooth_never_overflows_with_outlier():
    """Smooth-SwiGLU keeps the whole forward finite under an outlier
    channel even in the NaN-overflow regime."""
    recipe = M.RECIPES["fp8_smooth_nosat"]
    params = init_params(CFG, recipe)
    params["w1"] = params["w1"].at[0, :, 3].mul(500.0)
    params["w2"] = params["w2"].at[0, :, 3].mul(500.0)
    scales = jnp.ones((M.n_scale_sites(CFG),), jnp.float32)
    loss, _ = M.loss_fn(params, scales, batch_for(CFG), CFG, recipe)
    assert np.isfinite(float(loss))
    # the same configuration with per-tensor delayed scaling (scale 1 is
    # stale for a 500x outlier) must overflow to NaN
    loss_std, _ = M.loss_fn(params, scales, batch_for(CFG), CFG, M.RECIPES["fp8_nosat"])
    assert not np.isfinite(float(loss_std))


def test_eval_step_counts(tiny_setup):
    params, scales = tiny_setup
    ev = make_eval_step(CFG, M.RECIPES["bf16"])
    nll, correct, n = ev(params, scales, batch_for(CFG))
    assert float(n) == 2 * CFG.seq_len
    assert 0.0 <= float(correct) <= float(n)
    assert float(nll) / float(n) == pytest.approx(np.log(CFG.vocab), rel=0.1)


def test_site_index_layout():
    assert M.site_index(0, "x_attn") == 0
    assert M.site_index(1, "x_attn") == len(M.SITES_PER_LAYER)
    assert M.n_scale_sites(CFG) == CFG.n_layers * len(M.SITES_PER_LAYER)


def test_param_count_matches_specs():
    for rname in ["bf16", "gelu_bf16"]:
        recipe = M.RECIPES[rname]
        specs = M.param_specs(CFG, recipe)
        total = sum(np.prod(s) for s, _ in specs.values())
        assert total == CFG.param_count(recipe.activation)
