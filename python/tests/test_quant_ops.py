"""quant_ops: STE semantics and the amax-as-cotangent trick."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.formats import E4M3, E5M2, qdq
from compile.quant_ops import grad_q, ste_attach, ste_qdq


def test_ste_qdq_forward_matches_qdq():
    x = jnp.linspace(-500, 500, 101, dtype=jnp.float32)
    s = jnp.float32(0.5)
    np.testing.assert_array_equal(
        np.asarray(ste_qdq(x, s, "e4m3", True)), np.asarray(qdq(x, E4M3, s))
    )


def test_ste_qdq_backward_is_identity():
    x = jnp.asarray([0.3, -2.0, 100.0], jnp.float32)
    g = jax.grad(lambda t: jnp.sum(ste_qdq(t, jnp.float32(1.0), "e4m3", True) * 3.0))(x)
    np.testing.assert_array_equal(np.asarray(g), np.full(3, 3.0, np.float32))


def test_ste_qdq_scale_gets_zero_cotangent():
    x = jnp.ones((4,), jnp.float32)
    gs = jax.grad(
        lambda s: jnp.sum(ste_qdq(x, s, "e4m3", True)), argnums=0
    )(jnp.float32(2.0))
    assert float(gs) == 0.0


def test_grad_q_forward_identity():
    y = jnp.asarray([1.0, -2.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(grad_q(y, jnp.float32(4.0))), np.asarray(y))


def test_grad_q_quantizes_cotangent_and_reports_amax():
    y = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    w = jnp.asarray([0.37, -1.4, 2.2], jnp.float32)  # cotangent of y will be w
    scale = jnp.float32(8.0)

    def f(y, s):
        return jnp.sum(grad_q(y, s, "e5m2", True) * w)

    gy, gs = jax.grad(f, argnums=(0, 1))(y, scale)
    # cotangent quantized on the E5M2 grid at the given scale
    np.testing.assert_array_equal(np.asarray(gy), np.asarray(qdq(w, E5M2, scale)))
    # scale cotangent = amax of the raw cotangent
    assert float(gs) == pytest.approx(2.2)


def test_grad_q_amax_sums_over_shared_scale():
    # two grad_q sites sharing one scale slot -> cotangents add
    y = jnp.ones((2,), jnp.float32)

    def f(s):
        a = grad_q(y, s, "e5m2", True) * 3.0
        b = grad_q(y, s, "e5m2", True) * 5.0
        return jnp.sum(a) + jnp.sum(b)

    gs = jax.grad(f)(jnp.float32(1.0))
    assert float(gs) == pytest.approx(8.0)  # 3 + 5 (documented conservatism)


def test_ste_attach_value_and_grad():
    xd = jnp.asarray([1.0, 2.0], jnp.float32)
    xe = jnp.asarray([1.5, 2.5], jnp.float32)
    out = ste_attach(xd, xe)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(xe))
    g = jax.grad(lambda t: jnp.sum(ste_attach(t, xe) ** 2))(xd)
    # d/dxd of sum(xe_attached²) with value xe: 2·xe (chain through value)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(xe), rtol=1e-6)
