//! Ablation (DESIGN.md design-choice list): the delayed-scaling
//! hyperparameters the paper inherits from TE — amax-history length
//! and scale margin — swept under the outlier workload. Shows *why*
//! delayed scaling breaks: shorter histories forget the spike floor
//! faster (more overflow events), larger margins buy headroom at the
//! cost of resolution.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{bench_steps, run_curve};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(120);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let mut csv = CsvWriter::create(
        "results/ablation_scaling.csv",
        &["history", "margin_pow2", "final_loss", "diverged_at", "overflows"],
    )?;
    println!("Delayed-scaling ablation (s1m fp8, seeded outlier, {steps} steps):");
    println!(
        "{:>8} {:>8} {:>12} {:>12} {:>10}",
        "history", "margin", "final", "diverged@", "overflows"
    );

    let mut rows = Vec::new();
    for &history in &[1usize, 4, 16] {
        for &margin in &[0i32, 2] {
            let cfg = TrainConfig {
                size: "s1m".into(),
                recipe: "fp8".into(), // saturating: overflow shows as clamping noise
                steps,
                warmup_steps: 10,
                lr: 8e-4,
                weight_decay: 0.3,
                seed_outlier_channel: true,
                seed_outlier_gain: 3.0,
                amax_history: history,
                margin_pow2: margin,
                out_dir: format!("runs/bench_ablation/h{history}_m{margin}"),
                ..Default::default()
            };
            let c = run_curve(&rt, cfg, 10, 5)?;
            let overflows = c.rows.last().map(|r| r.4).unwrap_or(0);
            println!(
                "{:>8} {:>8} {:>12.4} {:>12} {:>10}",
                history,
                margin,
                c.final_loss(),
                c.diverged_at.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
                overflows
            );
            csv.row(&[
                history as f64,
                margin as f64,
                c.final_loss() as f64,
                c.diverged_at.map(|s| s as f64).unwrap_or(-1.0),
                overflows as f64,
            ])?;
            rows.push((history, margin, c));
        }
    }
    csv.flush()?;

    // longer histories must not do worse than history=1 on final loss
    let h1 = rows.iter().find(|r| r.0 == 1 && r.1 == 0).unwrap().2.tail_loss(3);
    let h16 = rows.iter().find(|r| r.0 == 16 && r.1 == 0).unwrap().2.tail_loss(3);
    println!("\ntail loss history=1: {h1:.4}, history=16: {h16:.4}");
    assert!(
        h16.is_finite(),
        "the paper's default (history 16) must stay finite under the outlier"
    );
    println!("ablation data in results/ablation_scaling.csv");
    Ok(())
}
