//! Fig. 3 — disabling quantization of the SwiGLU output (the w3
//! matmul input) rescues standard FP8: the instability is located at
//! that single tensor, not in RMSNorm/MHA/etc.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{bench_steps, print_summary, run_curve, write_curves_csv};
use fp8_trainer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(400);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let base = TrainConfig {
        size: "s1m".into(),
        steps,
        warmup_steps: 20,
        lr: 8e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 3.0,
        skip_nonfinite_updates: false,
        out_dir: "runs/bench_fig3".into(),
        ..Default::default()
    };
    let mut curves = Vec::new();
    for recipe in ["fp8_nosat", "fp8", "fp8_noq3"] {
        println!("running {recipe} ...");
        curves.push(run_curve(
            &rt,
            TrainConfig { recipe: recipe.into(), ..base.clone() },
            5,
            10,
        )?);
    }
    write_curves_csv("results/fig3_loss.csv", &curves)?;
    print_summary("Fig. 3 — w3-input quantization on/off", &curves);

    let noq3 = &curves[2];
    assert!(
        noq3.diverged_at.is_none(),
        "FP8 with SwiGLU output in BF16 must converge (paper Fig. 3)"
    );
    assert!(
        curves[..2].iter().any(|c| c.diverged_at.is_some()),
        "standard FP8 must destabilize under the outlier channel"
    );
    println!("Fig. 3 shape ✓ — the w3 input is the unstable tensor");
    Ok(())
}
