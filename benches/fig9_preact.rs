//! Fig. 9 — histogram of |w2ᵀx| at an outlier channel: the paper finds
//! ~1% of tokens below 1 (so σ′(w2ᵀx) ≈ 0 for almost all tokens —
//! Theorem 1's operative assumption). Reproduced by running the probe
//! artifact (fwd pass with the MLP pre-activations exposed) on a
//! trained-with-outlier model.

use std::sync::Arc;

use fp8_trainer::analysis::histogram::LogHistogram;
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::bench_steps;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(150);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let cfg = TrainConfig {
        size: "s1m".into(),
        recipe: "bf16".into(),
        steps,
        warmup_steps: 15,
        lr: 6e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 8.0,
        out_dir: "runs/bench_fig9".into(),
        ..Default::default()
    };
    let mut t = Trainer::new(rt.clone(), cfg)?;
    for _ in 0..steps {
        t.step()?;
    }

    // probe layer 0 (where the channel was seeded)
    let probe = rt.load("probe_s1m_l0")?;
    let d_ff = probe.manifest.raw.usize_of("d_ff").unwrap();
    let mut inputs: Vec<_> = t.params.tensors.to_vec();
    inputs.push(t.scales_tensor());
    inputs.push(t.batch_tensor(0));
    let out = probe.run(&inputs)?;
    let preact2 = out[0].f32s(); // [tokens, d_ff] row-major
    let product = out[1].f32s();
    let tokens = preact2.len() / d_ff;

    // the outlier channel = argmax over channels of the product amax
    let mut ch = 0;
    let mut best = 0.0f32;
    for j in 0..d_ff {
        let amax = (0..tokens).map(|t_| product[t_ * d_ff + j].abs()).fold(0.0f32, f32::max);
        if amax > best {
            best = amax;
            ch = j;
        }
    }

    let mut hist = LogHistogram::new(-8.0, 8.0, 120);
    for t_ in 0..tokens {
        hist.add(preact2[t_ * d_ff + ch]);
    }
    let below_1 = hist.fraction_below(1.0);
    let below_e = hist.fraction_below(std::f64::consts::E);

    let mut csv = CsvWriter::create("results/fig9_hist.csv", &["ln_center", "count"])?;
    for (c, n) in hist.rows() {
        csv.row(&[c, n as f64])?;
    }
    csv.flush()?;

    println!("Fig. 9 — |w2ᵀx| at the outlier channel ({tokens} tokens, channel {ch}):");
    println!("  fraction below 1: {:.3} (paper ~0.01)", below_1);
    println!("  fraction below e: {:.3} (paper ~0.035)", below_e);
    assert!(
        below_1 < 0.30,
        "most tokens must drive the outlier channel hard (σ′ → 0)"
    );
    println!("Fig. 9 shape ✓ — histogram in results/fig9_hist.csv");
    Ok(())
}
