//! Table 5: throughput by precision configuration on 8× A6000 Ada
//! (appendix A.2) — analytic model, calibrated to the paper's BF16 row
//! (3.22 samples/s, 76 TFLOPS).

use fp8_trainer::perfmodel::{throughput_table, Workload, A6000_ADA};
use fp8_trainer::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    println!("Table 5 — A6000 Ada model (paper: 3.22 / +27.6% / +34.2% / +37.6%):");
    println!("{:34} {:>11} {:>9} {:>8}  status", "configuration", "samples/s", "speedup", "TFLOPS");
    let mut csv = CsvWriter::create(
        "results/table5_a6000.csv",
        &["config", "samples_per_s", "speedup_pct", "tflops", "converges"],
    )?;
    let rows = throughput_table(&A6000_ADA, &Workload::llama7b(), 8.0);
    for row in &rows {
        println!(
            "{:34} {:>11.2} {:>8.1}% {:>8.0}  {}",
            row.config.label(),
            row.throughput,
            row.speedup_pct,
            row.tflops,
            if row.converges { "converge" } else { "DIVERGE" }
        );
        csv.row_mixed(&[
            row.config.label().into(),
            row.throughput.to_string(),
            row.speedup_pct.to_string(),
            row.tflops.to_string(),
            row.converges.to_string(),
        ])?;
    }
    csv.flush()?;
    // paper-shape assertions
    assert!(rows[1].speedup_pct > 20.0 && rows[1].speedup_pct < 33.0);
    assert!(rows[3].speedup_pct > rows[2].speedup_pct);
    assert!((rows[0].tflops - 76.0).abs() < 15.0);
    println!("shape ✓");
    Ok(())
}
