//! Fig. 1 — per-layer activation maxima across training windows:
//! stable early, sporadic large outliers late (once alignment has
//! progressed). Reproduced with the seeded-alignment run: a 50-step
//! window at the start vs a 50-step window at the end of training,
//! recording the SwiGLU-product amax per layer per step.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::bench_steps;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(300);
    let window = 50usize.min(steps / 3);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let cfg = TrainConfig {
        size: "s1m".into(),
        recipe: "fp8_noq3".into(), // converging config so late window exists
        steps,
        warmup_steps: 20,
        lr: 8e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 3.0,
        out_dir: "runs/bench_fig1".into(),
        ..Default::default()
    };
    let mut t = Trainer::new(rt, cfg)?;

    let mut csv = CsvWriter::create(
        "results/fig1_actmax.csv",
        &["window", "step", "layer", "swiglu_amax"],
    )?;
    let mut early_max = 0.0f32;
    let mut late_max = 0.0f32;
    let mut early_med = Vec::new();
    let mut late_med = Vec::new();
    for s in 0..steps {
        let o = t.step()?;
        let win = if s < window {
            "early"
        } else if s >= steps - window {
            "late"
        } else {
            continue;
        };
        for (l, m) in o.monitor.iter().enumerate() {
            csv.row_mixed(&[win.into(), s.to_string(), l.to_string(), m[0].to_string()])?;
            if win == "early" {
                early_max = early_max.max(m[0]);
                early_med.push(m[0]);
            } else {
                late_max = late_max.max(m[0]);
                late_med.push(m[0]);
            }
        }
    }
    csv.flush()?;
    let med = |v: &mut Vec<f32>| {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let em = med(&mut early_med);
    let lm = med(&mut late_med);
    println!("Fig. 1 — SwiGLU activation maxima across layers:");
    println!("  early window: median {em:.3}, max {early_max:.3}");
    println!("  late window:  median {lm:.3}, max {late_max:.3}");
    println!(
        "  late/early peak ratio: {:.1}x (paper: z-axis rescales ~10x after 200B tokens)",
        late_max / early_max.max(1e-9)
    );
    assert!(
        late_max > early_max,
        "late-training outliers must exceed the early-window peak"
    );
    println!("Fig. 1 shape ✓ — data in results/fig1_actmax.csv");
    Ok(())
}
