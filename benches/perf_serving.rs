//! §Serving — end-to-end latency/throughput of the folded-FP8 HTTP
//! serving layer, emitting `BENCH_serving.json` (methodology:
//! rust/EXPERIMENTS.md §Serving).
//!
//! Records per batch size ∈ {1, 8, 32}: request p50/p99 over a real
//! socket, QPS, and generated tokens/s — with that many concurrent
//! clients against a server whose batcher window matches, so the
//! batched-forward amortization is what gets measured.
//!
//! Floors folded into `speedup_floors_met` (deterministic — wall-clock
//! numbers are recorded ungated because a shared runner's latency says
//! nothing about the deployment):
//! * FP8 residency: the artifact's f32-equivalent weight bytes ÷
//!   resident FP8 bytes ≥ 3.0 (the Table-4-shaped memory story for the
//!   serving tier; norm gains stay f32, so exactly 4.0 is not claimed);
//! * every benched request returns 200 and the folded engine's
//!   generation is bit-identical to the scaled reference on a spot
//!   probe (the export gate's invariant, re-checked where the numbers
//!   are produced).
//!
//! A floor miss exits non-zero and writes `speedup_floors_met = false`
//! — the CI bench-smoke job gates on both. `BENCH_QUICK=1` shrinks the
//! model and the request counts (CI smoke mode).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use fp8_trainer::fp8::E4M3;
use fp8_trainer::runtime::manifest::ModelDims;
use fp8_trainer::serving::export::synth_state_for;
use fp8_trainer::serving::{
    export_state, serve, Engine, ExportOptions, ExportReport, ServeConfig, ServeMode,
};
use fp8_trainer::util::bench::write_json_report;
use fp8_trainer::util::json::{obj, Json};
use fp8_trainer::util::prng::Rng;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

fn bench_dims() -> ModelDims {
    if quick() {
        ModelDims { vocab: 64, d_model: 32, n_layers: 2, n_heads: 4, d_ff: 24, seq_len: 32 }
    } else {
        // the tiny campaign preset — the smallest shape the training
        // tier actually runs
        ModelDims { vocab: 256, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 172, seq_len: 64 }
    }
}

/// One blocking request over a fresh connection; returns (status,
/// latency). The body is drained to EOF so the server's close is the
/// end-of-response signal, exactly as the conformance suite does it.
fn timed_request(addr: SocketAddr, body: &str) -> (u16, Duration) {
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: b\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("write");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read");
    let elapsed = t0.elapsed();
    let head_end = raw.windows(4).position(|w| w == b"\r\n\r\n").unwrap_or(0);
    let status: u16 = std::str::from_utf8(&raw[..head_end])
        .ok()
        .and_then(|h| h.lines().next())
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    (status, elapsed)
}

fn percentile(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx].as_secs_f64()
}

fn prompts_for(dims: &ModelDims, n: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = 2 + (rng.next_u64() % 6) as usize;
            (0..len).map(|_| rng.below(dims.vocab as u64) as usize).collect()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let dims = bench_dims();
    let dir = std::env::temp_dir().join(format!("fp8_bench_serving_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("model.fp8m");

    println!(
        "== export (fold gate + quantize, {}x{} x{}L) ==",
        dims.vocab, dims.d_model, dims.n_layers
    );
    let st = synth_state_for(if quick() { "custom" } else { "tiny" }, &dims, 0xbe4c);
    let opts = ExportOptions {
        fmt: E4M3,
        probe_tokens: 8,
        dims: Some(dims.clone()),
        ..Default::default()
    };
    let t0 = Instant::now();
    let report: ExportReport = export_state(&st, &path, &opts)?;
    let export_s = t0.elapsed().as_secs_f64();
    let mem_ratio = report.f32_equiv_bytes as f64 / report.resident_fp8_bytes.max(1) as f64;
    let mem_ok = mem_ratio >= 3.0;
    println!(
        "  export {export_s:.2}s; resident FP8 {} B vs f32-equivalent {} B \
         ({mem_ratio:.2}x, floor 3.0x) {}",
        report.resident_fp8_bytes,
        report.f32_equiv_bytes,
        if mem_ok { "PASS" } else { "FAIL" }
    );

    // ---- fold bit-identity spot probe, where the numbers are made
    let spot = prompts_for(&dims, 3, 0x5b07);
    let max_new_spot: Vec<usize> = vec![6; spot.len()];
    let mut folded = Engine::load(&path, ServeMode::Folded)?;
    let mut reference = Engine::load(&path, ServeMode::ScaledReference)?;
    let rf = folded.generate_batch(&spot, &max_new_spot, |_, _, _, _| {})?;
    let rr = reference.generate_batch(&spot, &max_new_spot, |_, _, _, _| {})?;
    let fold_ok = rf
        .iter()
        .zip(&rr)
        .all(|(a, b)| a.tokens == b.tokens && a.crcs == b.crcs);
    println!(
        "  fold spot probe: folded vs scaled-reference {}",
        if fold_ok { "bit-identical PASS" } else { "DIVERGED FAIL" }
    );

    let mut records: Vec<Json> = Vec::new();
    records.push(obj(vec![
        ("name", Json::Str("serving export".into())),
        ("export_s", Json::Num(export_s)),
        ("file_bytes", Json::Num(report.file_bytes as f64)),
        ("resident_fp8_bytes", Json::Num(report.resident_fp8_bytes as f64)),
        ("f32_equiv_bytes", Json::Num(report.f32_equiv_bytes as f64)),
        ("memory_ratio", Json::Num(mem_ratio)),
        ("target_memory_ratio", Json::Num(3.0)),
        ("pass", Json::Bool(mem_ok)),
    ]));

    // ---- latency/QPS at batch ∈ {1, 8, 32}
    let batches: &[usize] = if quick() { &[1, 8] } else { &[1, 8, 32] };
    let per_client = if quick() { 4usize } else { 12 };
    let max_new = if quick() { 4usize } else { 12 };
    let mut all_ok = true;
    for &b in batches {
        let engine = Engine::load(&path, ServeMode::Folded)?;
        let cfg = ServeConfig { batch: b, batch_wait_ms: 2, ..ServeConfig::default() };
        let server = serve(engine, &cfg)?;
        let addr = server.addr();
        let prompts = prompts_for(&dims, b, 0xc11e47 + b as u64);

        // warmup: one request per client prompt, serially
        for p in &prompts {
            let body = body_for(p, max_new);
            let (status, _) = timed_request(addr, &body);
            all_ok &= status == 200;
        }

        let wall0 = Instant::now();
        let handles: Vec<_> = prompts
            .iter()
            .cloned()
            .map(|p| {
                std::thread::spawn(move || {
                    let body = body_for(&p, max_new);
                    let mut lats = Vec::with_capacity(per_client);
                    let mut ok = true;
                    for _ in 0..per_client {
                        let (status, lat) = timed_request(addr, &body);
                        ok &= status == 200;
                        lats.push(lat);
                    }
                    (ok, lats)
                })
            })
            .collect();
        let mut lats: Vec<Duration> = Vec::new();
        for h in handles {
            let (ok, l) = h.join().expect("client thread");
            all_ok &= ok;
            lats.extend(l);
        }
        let wall = wall0.elapsed().as_secs_f64();
        lats.sort();
        let p50 = percentile(&lats, 0.50);
        let p99 = percentile(&lats, 0.99);
        let n_req = lats.len();
        let qps = n_req as f64 / wall;
        let toks_per_s = (n_req * max_new) as f64 / wall;
        println!(
            "  batch={b}: {n_req} reqs in {wall:.2}s — p50 {:.1} ms, p99 {:.1} ms, \
             {qps:.1} req/s, {toks_per_s:.0} tok/s",
            p50 * 1e3,
            p99 * 1e3
        );
        records.push(obj(vec![
            ("name", Json::Str(format!("serving generate batch={b}"))),
            ("batch", Json::Num(b as f64)),
            ("requests", Json::Num(n_req as f64)),
            ("max_new_tokens", Json::Num(max_new as f64)),
            ("p50_s", Json::Num(p50)),
            ("p99_s", Json::Num(p99)),
            ("qps", Json::Num(qps)),
            ("generated_tokens_per_s", Json::Num(toks_per_s)),
        ]));
        server.shutdown();
    }
    if !all_ok {
        eprintln!("  FLOOR MISS: a benched request did not return 200");
    }

    let floors = mem_ok && fold_ok && all_ok;
    write_json_report(
        "BENCH_serving.json",
        vec![
            ("suite", Json::Str("serving".into())),
            ("size", Json::Str(if quick() { "custom".into() } else { "tiny".into() })),
            ("quick", Json::Bool(quick())),
            ("speedup_floors_met", Json::Bool(floors)),
            ("memory_floor_met", Json::Bool(mem_ok)),
            ("fold_bit_identity_met", Json::Bool(fold_ok)),
            ("all_requests_ok", Json::Bool(all_ok)),
        ],
        records,
    )?;
    println!("wrote BENCH_serving.json");
    std::fs::remove_dir_all(&dir).ok();
    if !floors {
        eprintln!(
            "FAIL: serving floors not met (memory >=3.0x: {mem_ok}; \
             fold bit-identity: {fold_ok}; all 200s: {all_ok})"
        );
        std::process::exit(1);
    }
    Ok(())
}

fn body_for(prompt: &[usize], max_new: usize) -> String {
    let ids: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"prompt\":[{}],\"max_new\":{max_new}}}", ids.join(","))
}
