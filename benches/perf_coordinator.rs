//! §Perf — L3 coordinator micro/meso benchmarks: where does a training
//! step's wall-clock go, and is the Rust side ever the bottleneck?
//! (Target from DESIGN.md: coordinator overhead < 5% of execute time.)

use std::sync::Arc;
use std::time::Duration;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::allreduce::{allreduce_mean, global_norm, reduce_mean_into_rank0};
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::fp8::{self, E4M3};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);

    // ---- end-to-end step vs pure artifact execute (s1m)
    let cfg = TrainConfig {
        size: "s1m".into(),
        recipe: "fp8_full".into(),
        steps: 1,
        out_dir: "runs/bench_perf".into(),
        ..Default::default()
    };
    let mut t = Trainer::new(rt.clone(), cfg)?;
    t.step()?; // warm caches

    let full = bench("trainer.step (s1m fp8_full)", 1, 20, Duration::from_secs(15), || {
        t.step().unwrap();
    });
    full.report();

    let grad = rt.load("grad_s1m_fp8_smooth")?;
    let mut inputs: Vec<_> = t.params.tensors.to_vec();
    inputs.push(t.scales_tensor());
    inputs.push(t.batch_tensor(0));
    let exec = bench("grad artifact execute only", 1, 20, Duration::from_secs(15), || {
        grad.run(&inputs).unwrap();
    });
    exec.report();

    let n_params = t.params.total_elems();

    // ---- coordinator primitives at m100 scale (97M params)
    let big = 97_000_000usize;
    let mut bufs: Vec<Vec<f32>> = (0..2).map(|r| vec![r as f32 * 0.1 + 0.5; big / 8]).collect();
    let ar = bench("allreduce_mean 2x12M f32", 1, 10, Duration::from_secs(10), || {
        allreduce_mean(&mut bufs);
    });
    ar.report();

    // the broadcast-free variant the step loop actually uses
    // (deeper comparison lives in benches/perf_hotpath.rs)
    let mut bufs0: Vec<Vec<f32>> = (0..2).map(|r| vec![r as f32 * 0.1 + 0.5; big / 8]).collect();
    let r0 = bench("reduce_mean_into_rank0 2x12M f32", 1, 10, Duration::from_secs(10), || {
        reduce_mean_into_rank0(&mut bufs0);
    });
    r0.report();

    let flat = vec![0.01f32; big / 8];
    let gn = bench("global_norm 12M f32", 1, 20, Duration::from_secs(10), || {
        std::hint::black_box(global_norm(&flat));
    });
    gn.report();

    let data = vec![0.0123f32; 1_000_000];
    let pk = bench("fp8 pack 1M f32 -> u8", 1, 20, Duration::from_secs(10), || {
        std::hint::black_box(fp8::pack_scaled(E4M3, &data));
    });
    pk.report();

    // ---- the §Perf headline ratio
    let overhead = (full.mean_secs() - exec.mean_secs()).max(0.0)
        / full.mean_secs().max(1e-12);
    println!(
        "\ncoordinator share of step time (s1m, grad+adam+scaling+data): {:.1}%  \
         [grad execute {:.1}ms of {:.1}ms step; adam artifact calls included in remainder]",
        overhead * 100.0,
        exec.mean_secs() * 1e3,
        full.mean_secs() * 1e3
    );
    println!("params: {n_params}; step tokens: {}", t.tokens_per_step());
    Ok(())
}
