//! Table 3: throughput by precision configuration on Gaudi2.
//!
//! Two halves:
//! 1. the analytic Gaudi2 model (absolute samples/s, speedup %, TFLOPS
//!    — the paper's numbers; hardware substitution per DESIGN.md);
//! 2. measured CPU step times for the same four configs on the s8m
//!    preset. The CPU *cannot* show the FP8 speedup (fake-quant adds
//!    work instead of removing it) — what it shows is the per-config
//!    relative overhead ordering of the quantization machinery, which
//!    is reported for transparency.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::bench_steps;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::perfmodel::{throughput_table, Workload, GAUDI2};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    // ---- analytic table (the paper's numbers)
    println!("Table 3 — Gaudi2 model (paper: 12.65 / +27.0% / +33.5% / +37.1%):");
    println!("{:34} {:>11} {:>9} {:>8}  status", "configuration", "samples/s", "speedup", "TFLOPS");
    let mut csv = CsvWriter::create(
        "results/table3_gaudi2.csv",
        &["config", "samples_per_s", "speedup_pct", "tflops", "converges"],
    )?;
    for row in throughput_table(&GAUDI2, &Workload::llama7b(), 8.0) {
        println!(
            "{:34} {:>11.2} {:>8.1}% {:>8.0}  {}",
            row.config.label(),
            row.throughput,
            row.speedup_pct,
            row.tflops,
            if row.converges { "converge" } else { "DIVERGE" }
        );
        csv.row_mixed(&[
            row.config.label().into(),
            row.throughput.to_string(),
            row.speedup_pct.to_string(),
            row.tflops.to_string(),
            row.converges.to_string(),
        ])?;
    }
    csv.flush()?;

    // ---- measured CPU relative step times (simulation overhead)
    let steps = bench_steps(8).min(16);
    let rt = Arc::new(Runtime::new("artifacts")?);
    println!(
        "\nmeasured CPU step time (s8m, {steps} steps each; fake-quant overhead, not HPU speedup):"
    );
    for recipe in ["bf16", "fp8_noq3", "fp8_smooth", "fp8"] {
        let cfg = TrainConfig {
            size: "s8m".into(),
            recipe: recipe.into(),
            steps,
            warmup_steps: 2,
            out_dir: format!("runs/bench_table3/{recipe}"),
            ..Default::default()
        };
        let mut t = Trainer::new(rt.clone(), cfg)?;
        t.step()?; // warmup (compile/caches)
        let t0 = std::time::Instant::now();
        for _ in 1..steps {
            t.step()?;
        }
        let per = t0.elapsed().as_secs_f64() / (steps - 1) as f64;
        println!(
            "  {:12} {:>8.3} s/step  {:>9.0} tok/s",
            recipe,
            per,
            t.tokens_per_step() as f64 / per
        );
    }
    Ok(())
}
