//! Table 2 substitute: downstream parity between BF16 and the FP8
//! schemes. Paper metric: zero-shot accuracy/perplexity on Lambada,
//! HellaSwag, etc. Here (no external datasets offline): held-out
//! perplexity + next-token accuracy on the synthetic corpus, same
//! parity question — FP8(1) and FP8(2) must land on par with BF16.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::bench_steps;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(300);
    let rt = Arc::new(Runtime::new("artifacts")?);
    println!("Table 2 substitute — downstream parity after {steps} steps (s1m):");
    println!("{:12} {:>12} {:>12}  (paper: BF16 61.98 acc / FP8 variants on par)",
             "precision", "ppl ↓", "acc ↑");

    let mut results = Vec::new();
    for (label, recipe) in [
        ("BF16", "bf16"),
        ("FP8 (1)", "fp8_noq3"),   // FP8 + SwiGLU output in BF16
        ("FP8 (2)", "fp8_full"),   // FP8 + Smooth-SwiGLU + FP8 optimizer
    ] {
        let cfg = TrainConfig {
            size: "s1m".into(),
            recipe: recipe.into(),
            steps,
            warmup_steps: (steps / 10).max(5),
            lr: 5e-4,
            out_dir: format!("runs/bench_table2/{recipe}"),
            ..Default::default()
        };
        let mut t = Trainer::new(rt.clone(), cfg)?;
        for _ in 0..steps {
            t.step()?;
        }
        let eval_recipe = match recipe {
            "bf16" => "bf16",
            "fp8_noq3" => "fp8_noq3",
            _ => "fp8_smooth",
        };
        let (ppl, acc) = t.eval(eval_recipe, 8)?;
        println!("{:12} {:>12.3} {:>12.4}", label, ppl, acc * 100.0);
        results.push((label, ppl, acc));
    }

    // parity check: FP8 variants within a few percent of BF16 ppl
    let base = results[0].1;
    for (label, ppl, _) in &results[1..] {
        let rel = (ppl - base).abs() / base;
        println!("{label}: |Δppl|/ppl vs BF16 = {:.3}", rel);
        assert!(rel < 0.10, "{label} perplexity deviates >10% from BF16");
    }
    println!("parity ✓ (all FP8 variants within 10% of BF16 perplexity)");
    Ok(())
}
