//! Fig. 6 — the headline result: the full proposed scheme
//! (Smooth-SwiGLU + both Adam moments in FP8) tracks the BF16 baseline
//! through the regime where standard FP8 destabilizes.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{bench_steps, print_summary, run_curve, write_curves_csv};
use fp8_trainer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(500);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let base = TrainConfig {
        size: "s1m".into(),
        steps,
        warmup_steps: 25,
        lr: 8e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 3.0,
        skip_nonfinite_updates: false,
        out_dir: "runs/bench_fig6".into(),
        ..Default::default()
    };
    let mut curves = Vec::new();
    for recipe in ["bf16", "fp8_nosat", "fp8_full"] {
        println!("running {recipe} ...");
        curves.push(run_curve(&rt, TrainConfig { recipe: recipe.into(), ..base.clone() }, 5, 10)?);
    }
    write_curves_csv("results/fig6_loss.csv", &curves)?;
    print_summary("Fig. 6 — full scheme vs baseline vs standard FP8", &curves);

    let bf16 = &curves[0];
    let fp8_std = &curves[1];
    let full = &curves[2];
    assert!(bf16.diverged_at.is_none());
    assert!(full.diverged_at.is_none(), "the full scheme must stay stable (paper Fig. 6)");
    assert!(fp8_std.diverged_at.is_some(), "standard FP8 must destabilize");
    let gap = (full.tail_loss(5) - bf16.tail_loss(5)).abs();
    println!("\n|FP8(2) − BF16| tail-loss gap: {gap:.4}");
    assert!(gap < 0.15, "the full scheme must track BF16");
    println!("Fig. 6 shape ✓ — data in results/fig6_loss.csv");
    Ok(())
}
