//! Figs. 10/11 — Smooth-SwiGLU on *BF16* training across learning
//! rates: the per-channel renormalization smooths the loss curve and
//! reaches lower loss, especially at elevated LR. (Fig. 11 is the
//! zoom of the same data — one CSV serves both.)

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{
    bench_steps, print_summary, run_curve, write_curves_csv, Curve,
};
use fp8_trainer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(300);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let mut curves: Vec<Curve> = Vec::new();
    for lr in [2.5e-4f32, 1e-3] {
        for recipe in ["bf16", "bf16_smooth"] {
            let cfg = TrainConfig {
                size: "s1m".into(),
                recipe: recipe.into(),
                steps,
                warmup_steps: 20,
                lr,
                out_dir: format!("runs/bench_fig10/{recipe}_{lr}"),
                ..Default::default()
            };
            println!("running {recipe} @ lr={lr} ...");
            let mut c = run_curve(&rt, cfg, 5, 0)?;
            c.label = format!("{recipe}_lr{lr}");
            curves.push(c);
        }
    }
    write_curves_csv("results/fig10_lr_sweep.csv", &curves)?;
    print_summary("Figs. 10/11 — Smooth-SwiGLU under BF16", &curves);

    // roughness metric: mean |Δloss| between consecutive samples
    let rough = |c: &Curve| {
        c.rows.windows(2).map(|w| (w[1].1 - w[0].1).abs() as f64).sum::<f64>()
            / (c.rows.len() - 1).max(1) as f64
    };
    for pair in curves.chunks(2) {
        let (plain, smooth) = (&pair[0], &pair[1]);
        println!(
            "{}: roughness {:.4} -> {:.4} with smooth; tail loss {:.4} -> {:.4}",
            plain.label,
            rough(plain),
            rough(smooth),
            plain.tail_loss(5),
            smooth.tail_loss(5)
        );
    }
    // shape assertion: both variants converge; smooth not worse at high LR
    let plain_hi = curves[2].tail_loss(5);
    let smooth_hi = curves[3].tail_loss(5);
    assert!(
        smooth_hi < plain_hi + 0.05,
        "Smooth-SwiGLU must not hurt BF16 training at high LR (paper Figs. 10/11)"
    );
    println!("Figs. 10/11 shape ✓ — data in results/fig10_lr_sweep.csv");
    Ok(())
}
