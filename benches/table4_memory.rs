//! Table 4: memory reduction from the FP8 optimizer.
//!
//! 1. the analytic 7B / 8-worker / ZeRO-1 model (paper: 63.25 →
//!    44.08 GB/HPU);
//! 2. **measured bytes**: real checkpoints of an s1m run under each
//!    configuration, written through the u8 FP8 codec.

use std::sync::Arc;

use fp8_trainer::checkpoint::{Dtype, Writer};
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::optimizer::{MemoryModel, MomentStore};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;
use fp8_trainer::util::json::obj;

fn main() -> anyhow::Result<()> {
    // ---- analytic table at paper scale
    let base = MemoryModel {
        params: 6_740_000_000,
        master_bytes_per_param: 4.0,
        m_store: MomentStore::F32,
        v_store: MomentStore::F32,
        dp_workers: 8,
        weight_bytes_per_param: 2.0,
        grad_bytes_per_param: 2.0,
    };
    let fp8_opt = MemoryModel {
        master_bytes_per_param: 2.0,
        m_store: MomentStore::from_name("e4m3"),
        v_store: MomentStore::from_name("e5m2"),
        ..base.clone()
    };
    println!("Table 4 — model-state memory, 7B params, 8 workers, ZeRO-1:");
    println!("{:44} {:>14}", "configuration", "GB per HPU");
    let mut csv = CsvWriter::create("results/table4_memory.csv", &["config", "gb_per_hpu"])?;
    for (label, m) in [
        ("FP32 master + FP32 moments (baseline)", &base),
        ("FP16 master + FP8 moments (ours)", &fp8_opt),
    ] {
        let gb = m.total_bytes_per_worker() / 1e9;
        println!("{:44} {:>14.2}", label, gb);
        csv.row_mixed(&[label.into(), gb.to_string()])?;
    }
    println!("(paper: 63.25 baseline -> 44.08 with the FP8 optimizer, ~30% lower)");
    let ratio = fp8_opt.total_bytes_per_worker() / base.total_bytes_per_worker();
    println!("modeled ratio {:.3} vs paper 44.08/63.25 = 0.697", ratio);
    assert!((ratio - 0.697).abs() < 0.06);

    // ---- measured checkpoint bytes
    let rt = Arc::new(Runtime::new("artifacts")?);
    let cfg = TrainConfig {
        size: "s1m".into(),
        recipe: "fp8_full".into(),
        steps: 3,
        warmup_steps: 1,
        out_dir: "runs/bench_table4".into(),
        ..Default::default()
    };
    let mut t = Trainer::new(rt, cfg)?;
    for _ in 0..3 {
        t.step()?;
    }
    println!(
        "\nmeasured optimizer-state checkpoint bytes (s1m, {} params):",
        t.params.total_elems()
    );
    let mut flat = Vec::new();
    t.params.flatten_into(&mut flat);
    let variants: [(&str, Dtype, Dtype, Dtype); 2] = [
        ("baseline: f32 master + f32 moments", Dtype::F32, Dtype::F32, Dtype::F32),
        ("ours:     f16 master + e4m3/e5m2", Dtype::F16, Dtype::E4M3, Dtype::E5M2),
    ];
    let (m, v) = t.moments_flat(); // gather the ZeRO-1 moment shards
    let mut sizes = Vec::new();
    for (label, master, m_dt, v_dt) in variants {
        let mut w = Writer::new(&obj(vec![]));
        w.tensor("master", master, &flat)
            .tensor("adam.m", m_dt, &m)
            .tensor("adam.v", v_dt, &v);
        println!("  {:40} {:>10} KiB", label, w.size_bytes() / 1024);
        sizes.push(w.size_bytes() as f64);
    }
    let measured = sizes[1] / sizes[0];
    println!("measured optimizer-state ratio: {:.3} (12 B/param -> 4 B/param = 0.333)", measured);
    assert!(measured < 0.36);
    csv.flush()?;
    Ok(())
}
