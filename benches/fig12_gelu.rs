//! Fig. 12 — the GeLU control: a GPT-3-style model (GeLU MLP, no
//! gating) shows no FP8 instability even under the same aggressive
//! hyperparameters, because GeLU is at-most-linear in its input —
//! the quadratic SwiGLU path is the necessary ingredient.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{bench_steps, print_summary, run_curve, write_curves_csv};
use fp8_trainer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(400);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let base = TrainConfig {
        size: "s1m".into(),
        steps,
        warmup_steps: 20,
        lr: 6e-4,
        weight_decay: 0.3,
        // no outlier channel to seed: the GeLU model has no w2 at all,
        // and that is the point — same aggressive hypers as Fig. 2
        out_dir: "runs/bench_fig12".into(),
        ..Default::default()
    };
    let mut curves = Vec::new();
    for recipe in ["gelu_bf16", "gelu_fp8"] {
        println!("running {recipe} ...");
        curves.push(run_curve(&rt, TrainConfig { recipe: recipe.into(), ..base.clone() }, 5, 10)?);
    }
    write_curves_csv("results/fig12_gelu.csv", &curves)?;
    print_summary("Fig. 12 — GeLU (GPT-3-like) control", &curves);

    assert!(curves[1].diverged_at.is_none(), "GeLU FP8 must converge (paper Fig. 12)");
    let gap = (curves[1].tail_loss(5) - curves[0].tail_loss(5)).abs();
    println!("\n|FP8 − BF16| tail-loss gap (GeLU): {gap:.4}");
    assert!(gap < 0.15, "GeLU FP8 must track its BF16 baseline");
    println!("Fig. 12 shape ✓ — data in results/fig12_gelu.csv");
    Ok(())
}
