//! Fig. 2 — the paper's central diagnostic, four panels:
//!
//! * (a) training loss: BF16 stays healthy, standard FP8 destabilizes
//!   once the outlier channel is active;
//! * (b) w1/w2 norm + correlation dynamics of the outlier channel;
//! * (c) scatter of the outlier channel's (w1_i, w2_i) pairs early vs
//!   late in training;
//! * (d) histogram of the outlier channel's w1 values early vs late.
//!
//! The 200B-token alignment is compressed by seeding a partially
//! aligned channel (α = 0.7) and training with elevated wd/LR; the
//! *dynamics* — correlation completing to ~1, norm growth, FP8 loss
//! instability while BF16 is fine — are the reproduced content.

use std::sync::Arc;

use fp8_trainer::analysis::correlation::channel_correlations;
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{bench_steps, print_summary, write_curves_csv, Curve};
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(400);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let base = TrainConfig {
        size: "s1m".into(),
        steps,
        warmup_steps: 20,
        lr: 8e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 3.0,
        skip_nonfinite_updates: false,
        out_dir: "runs/bench_fig2".into(),
        ..Default::default()
    };

    // ---- panel (a): loss curves, plus (b) tracked weight stats
    let mut curves: Vec<Curve> = Vec::new();
    let mut dyn_csv = CsvWriter::create(
        "results/fig2b_dynamics.csv",
        &["series", "step", "norm1", "norm2", "cosine"],
    )?;
    let mut early_late: Vec<(String, Vec<f32>, Vec<f32>)> = Vec::new();

    for recipe in ["bf16", "fp8", "fp8_nosat"] {
        let cfg = TrainConfig { recipe: recipe.into(), ..base.clone() };
        let mut t = Trainer::new(rt.clone(), cfg)?;
        let ch = {
            // the seeded channel is f/2 in layer 0 (see ParamStore)
            let (_, _, f) = t.params.layer_slice("w1", 0)?;
            f / 2
        };
        let mut curve = Curve { label: format!("s1m_{recipe}"), ..Default::default() };
        let mut after_div = 0;
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let o = t.step()?;
            if s % 5 == 0 || s + 1 == steps {
                let swiglu = o.monitor.iter().map(|m| m[0]).fold(0.0f32, f32::max);
                curve.rows.push((s, o.loss, o.grad_norm, swiglu, t.scale_mgr.overflow_events));
                let (w1, d, f) = t.params.layer_slice("w1", 0)?;
                let (w2, _, _) = t.params.layer_slice("w2", 0)?;
                let stats = channel_correlations(&w1, &w2, d, f);
                dyn_csv.row_mixed(&[
                    format!("s1m_{recipe}"),
                    s.to_string(),
                    stats[ch].norm1.to_string(),
                    stats[ch].norm2.to_string(),
                    stats[ch].cosine.to_string(),
                ])?;
            }
            // snapshot the channel pairs early + late for panels (c)/(d)
            if s == 10 || s + 2 == steps {
                let (w1, d, f) = t.params.layer_slice("w1", 0)?;
                let (w2, _, _) = t.params.layer_slice("w2", 0)?;
                let col1: Vec<f32> = (0..d).map(|i| w1[i * f + ch]).collect();
                let col2: Vec<f32> = (0..d).map(|i| w2[i * f + ch]).collect();
                early_late.push((format!("{recipe}_step{s}"), col1, col2));
            }
            if t.detector.has_diverged() {
                curve.diverged_at = curve.diverged_at.or(t.detector.diverged_at);
                after_div += 1;
                if after_div > 10 {
                    break;
                }
            }
        }
        curve.wall_s = t0.elapsed().as_secs_f64();
        curve.mean_step_s = curve.wall_s / (t.step.max(1)) as f64;
        curves.push(curve);
    }
    write_curves_csv("results/fig2a_loss.csv", &curves)?;
    print_summary("Fig. 2a — loss under seeded outlier channel", &curves);

    // ---- panels (c)/(d): scatter + histogram data
    let mut sc = CsvWriter::create("results/fig2cd_channel.csv", &["snapshot", "w1", "w2"])?;
    for (label, col1, col2) in &early_late {
        for (a, b) in col1.iter().zip(col2) {
            sc.row_mixed(&[label.clone(), a.to_string(), b.to_string()])?;
        }
    }
    sc.flush()?;

    // ---- paper-shape assertions
    let bf16 = &curves[0];
    assert!(bf16.diverged_at.is_none(), "BF16 must stay healthy (paper Fig. 2a)");
    let fp8_unstable = curves[1..].iter().any(|c| c.diverged_at.is_some());
    println!(
        "\nFP8 instability observed: {fp8_unstable} (fp8 diverged at {:?}, fp8_nosat at {:?})",
        curves[1].diverged_at, curves[2].diverged_at
    );
    assert!(fp8_unstable, "standard FP8 must destabilize under the outlier channel");
    println!("Fig. 2 shape ✓ — CSVs in results/fig2*.csv");
    Ok(())
}
