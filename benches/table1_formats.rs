//! Table 1: Adam optimizer moment datatypes — ours vs prior work —
//! plus a live verification that a real training run's moments are
//! exactly representable in the claimed formats (that is what lets the
//! checkpointer store one byte per moment).

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::fp8::{self, E4M3, E5M2};
use fp8_trainer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    println!("Table 1 — Adam moment datatypes:");
    println!("{:28} {:>8} {:>8}", "scheme", "mom 1", "mom 2");
    println!("{:28} {:>8} {:>8}", "BF16 (baseline)", "FP32", "FP32");
    println!("{:28} {:>8} {:>8}", "FP8-LM (Peng et al. 2023)", "FP8", "FP16");
    println!("{:28} {:>8} {:>8}", "FP8 (this work)", "FP8", "FP8");

    // live check: train fp8_full briefly; every stored moment value
    // must be a fixed point of its format's per-chunk-scaled grid
    let rt = Arc::new(Runtime::new("artifacts")?);
    let cfg = TrainConfig {
        size: "tiny".into(),
        recipe: "fp8_full".into(),
        steps: 5,
        warmup_steps: 1,
        lr: 1e-3,
        out_dir: "runs/bench_table1".into(),
        ..Default::default()
    };
    let mut t = Trainer::new(rt, cfg)?;
    for _ in 0..5 {
        t.step()?;
    }
    // every stored moment must have an FP8-width mantissa (≤3 bits for
    // E4M3, ≤2 for E5M2): checked with a per-value pow2 scale, which
    // makes the test independent of the optimizer's chunk boundaries
    // (scales are per decay-group chunk piece — see trainer::apply_adam)
    let mut checked = 0usize;
    let (m_gather, v_gather) = t.moments_flat(); // gather the ZeRO-1 shards
    for (flat, fmt) in [(&m_gather, E4M3), (&v_gather, E5M2)] {
        for &x in flat.iter() {
            if x == 0.0 {
                continue;
            }
            let s = fp8::compute_scale(fmt, x.abs());
            let q = fmt.decode(fmt.encode(x * s)) / s;
            assert!(
                (q - x).abs() <= x.abs() * 1e-6,
                "moment {x} has more than a {fmt:?} mantissa"
            );
            checked += 1;
        }
    }
    println!("\nverified {checked} moment values carry FP8-width mantissas ✓");
    Ok(())
}
