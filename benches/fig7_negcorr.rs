//! Fig. 7 — Theorem 1 allows w1 → −w2 as well as w1 → +w2; the paper
//! observes both signs among outlier channels. Reproduced by seeding
//! *negative* initial alignment (α = −0.7): training must complete the
//! anti-alignment (cosine → −1), mirroring the positive case.

use std::sync::Arc;

use fp8_trainer::analysis::correlation::channel_correlations;
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::bench_steps;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(300);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let cfg = TrainConfig {
        size: "s1m".into(),
        recipe: "bf16".into(), // precision-independent dynamics
        steps,
        warmup_steps: 20,
        lr: 6e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 8.0,
        out_dir: "runs/bench_fig7".into(),
        ..Default::default()
    };
    let mut t = Trainer::new(rt, cfg)?;
    // flip w1's seeded column to start anti-aligned
    let (w1_idx, shape) = t.params.index_of("w1")?;
    let (d, f) = (shape[1], shape[2]);
    let ch = f / 2;
    {
        let w1 = t.params.tensors[w1_idx].f32s_mut();
        for i in 0..d {
            w1[i * f + ch] = -w1[i * f + ch];
        }
    }

    let early = {
        let (w1, _, _) = t.params.layer_slice("w1", 0)?;
        let (w2, _, _) = t.params.layer_slice("w2", 0)?;
        channel_correlations(&w1, &w2, d, f)[ch].clone()
    };
    let mut csv =
        CsvWriter::create("results/fig7_negcorr.csv", &["step", "cosine", "norm1", "norm2"])?;
    for s in 0..steps {
        t.step()?;
        if s % 10 == 0 || s + 1 == steps {
            let (w1, _, _) = t.params.layer_slice("w1", 0)?;
            let (w2, _, _) = t.params.layer_slice("w2", 0)?;
            let c = &channel_correlations(&w1, &w2, d, f)[ch];
            csv.row(&[s as f64, c.cosine as f64, c.norm1 as f64, c.norm2 as f64])?;
        }
    }
    csv.flush()?;
    let (w1, _, _) = t.params.layer_slice("w1", 0)?;
    let (w2, _, _) = t.params.layer_slice("w2", 0)?;
    let late = channel_correlations(&w1, &w2, d, f)[ch].clone();
    println!("Fig. 7 — negative-alignment channel:");
    println!("  early cosine {:.3}  ->  late cosine {:.3}", early.cosine, late.cosine);
    assert!(early.cosine < -0.6);
    assert!(
        late.cosine < early.cosine + 0.05,
        "anti-alignment must persist/deepen (Theorem 1 allows both signs)"
    );
    println!("Fig. 7 shape ✓ — dynamics in results/fig7_negcorr.csv");
    Ok(())
}
