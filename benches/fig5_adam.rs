//! Fig. 5 — all four standard-FP8 combinations for the Adam moments.
//! Paper finding: only m=E4M3 / v=E5M2 tracks the baseline; putting
//! the second moment in E4M3 fails (not enough dynamic range under the
//! inverse sqrt), and E5M2 for the first moment is noticeably worse
//! (not enough mantissa).

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{bench_steps, print_summary, run_curve, write_curves_csv};
use fp8_trainer::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps = bench_steps(300);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let base = TrainConfig {
        size: "s1m".into(),
        steps,
        warmup_steps: 20,
        lr: 5e-4,
        out_dir: "runs/bench_fig5".into(),
        ..Default::default()
    };
    let mut curves = Vec::new();
    for recipe in [
        "fp8_smooth", // FP32/FP32 baseline
        "fp8_adam_e4m3_e5m2",
        "fp8_adam_e4m3_e4m3",
        "fp8_adam_e5m2_e5m2",
        "fp8_adam_e5m2_e4m3",
    ] {
        println!("running {recipe} ...");
        curves.push(run_curve(&rt, TrainConfig { recipe: recipe.into(), ..base.clone() }, 10, 5)?);
    }
    write_curves_csv("results/fig5_adam.csv", &curves)?;
    print_summary("Fig. 5 — Adam moment format grid", &curves);

    let baseline = curves[0].tail_loss(5);
    let good = curves[1].tail_loss(5); // e4m3/e5m2
    println!("\nbaseline tail loss {baseline:.4}, E4M3/E5M2 tail loss {good:.4}");
    assert!(
        (good - baseline).abs() < 0.15,
        "E4M3/E5M2 must track the FP32-moment baseline (paper Fig. 5)"
    );
    // v in E4M3 must be strictly worse than v in E5M2 at equal m format
    let v_e4m3 = curves[2].tail_loss(5);
    println!("E4M3/E4M3 tail loss {v_e4m3:.4} (range-starved second moment)");
    assert!(
        v_e4m3 > good - 0.02,
        "restricting the second moment's range must not help"
    );
    println!("Fig. 5 shape ✓ — data in results/fig5_adam.csv");
    Ok(())
}
