//! Theorem 1 validation — the paper's analytical core, tested on the
//! structure the proof actually establishes.
//!
//! With A_j = −μ⁻¹ Σ_n δ_nj σ(a2_nj) x_n x_nᵀ (the proof's symmetric
//! matrix), the derivation's two equations are, *at any point*:
//!
//! * eq. I:  (w1_j − A_j w2_j) ≡ ∇w1_j / μ            — checked to
//!   machine precision against autodiff (`id1` ≈ 0);
//! * eq. II: (w2_j − A_j w1_j) ≡ ∇w2_j / μ + SP_j     — likewise
//!   (`id2` ≈ 0), where SP is the σ′ term the theorem assumes away.
//!
//! At stationarity (∇→0) these become the paper's w1 = A w2 and
//! w2 = A w1 + SP: so the bench (a) validates the identities exactly,
//! (b) shows the eq.-I residual r1 shrinking as gnorm decays, and
//! (c) shows the σ′ defect SP shrinking as the gate sharpens (τ→0 —
//! the paper notes the proof covers every GLU variant), which is the
//! condition under which the symmetric-eigenvector argument forces
//! w1 → ±w2. Channel cosines are reported alongside; full alignment
//! additionally needs the ±1-eigenspace non-degeneracy the paper
//! observes empirically at 7B scale (see EXPERIMENTS.md).

use std::sync::Arc;

use fp8_trainer::coordinator::runner::bench_steps;
use fp8_trainer::runtime::tensor::HostTensor;
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::csv::CsvWriter;
use fp8_trainer::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // floor at 1500: the stationarity-trend assertions need enough SGD
    // steps regardless of the global FP8_BENCH_STEPS budget
    let steps = bench_steps(6_000).max(1_500);
    let rt = Arc::new(Runtime::new("artifacts")?);
    let art = rt.load("theorem1")?;
    let m = &art.manifest.raw;
    let (d, f, n_out, n) = (
        m.usize_of("d").unwrap(),
        m.usize_of("f").unwrap(),
        m.usize_of("n_out").unwrap(),
        m.usize_of("n").unwrap(),
    );

    let mut csv = CsvWriter::create(
        "results/theorem1.csv",
        &["tau", "step", "loss", "gnorm", "id1", "id2", "sp", "r1", "max_abs_cos"],
    )?;
    println!("Theorem 1 — identities + asymptotics (d={d}, f={f}, N={n}, {steps} SGD steps/τ):");
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "tau", "gnorm", "id1", "id2", "sp (σ')", "r1 (eq I)", "max |cos|"
    );

    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    let mut summary = Vec::new();
    for &tau in &[1.0f32, 0.25, 0.1] {
        let mu = 1e-2f32;
        let mut rng = Rng::new(777);
        let mut mk = |shape: &[usize], std: f32| {
            let mut v = vec![0.0f32; shape.iter().product()];
            rng.fill_normal(&mut v, std);
            HostTensor::from_f32(shape, v)
        };
        let mut w1 = mk(&[d, f], 1.0);
        let mut w2 = mk(&[d, f], 1.0);
        let mut w3 = mk(&[f, n_out], 1.0 / f as f32);
        let x = mk(&[n, d], 1.0);
        let y = mk(&[n, n_out], 10.0);
        let mu_t = HostTensor::scalar(mu);
        let tau_t = HostTensor::scalar(tau);

        let mut r1_early = 0.0f32;
        let mut last = vec![0.0f32; 7];
        let mut max_id = 0.0f32;
        for s in 0..steps {
            let lr = if s < steps / 2 { 5e-3 } else { 1e-3 };
            let out = art.run(&[
                w1.clone(),
                w2.clone(),
                w3.clone(),
                x.clone(),
                y.clone(),
                HostTensor::scalar(lr),
                mu_t.clone(),
                tau_t.clone(),
            ])?;
            w1 = out[1].clone();
            w2 = out[2].clone();
            w3 = out[3].clone();
            let corr = out[4].f32s();
            let max_cos = corr.iter().fold(0.0f32, |a, &c| a.max(c.abs()));
            last = vec![
                out[0].scalar_f32(),
                out[9].scalar_f32(),
                mean(out[5].f32s()),
                mean(out[6].f32s()),
                mean(out[7].f32s()),
                mean(out[8].f32s()),
                max_cos,
            ];
            // identities hold only after δ is meaningful; track their max
            if s > 10 {
                max_id = max_id.max(last[2]).max(last[3]);
            }
            if s == 50 {
                r1_early = last[5];
            }
            if s % (steps / 40).max(1) == 0 || s + 1 == steps {
                csv.row(&[
                    tau as f64, s as f64, last[0] as f64, last[1] as f64,
                    last[2] as f64, last[3] as f64, last[4] as f64,
                    last[5] as f64, last[6] as f64,
                ])?;
            }
        }
        println!(
            "{:>6} {:>10.2e} {:>10.2e} {:>10.2e} {:>10.3} {:>10.3} {:>10.3}",
            tau, last[1], last[2], last[3], last[4], last[5], last[6]
        );
        summary.push((tau, r1_early, last, max_id));
    }
    csv.flush()?;

    for (tau, r1_early, last, max_id) in &summary {
        // (a) the proof's algebra must match autodiff to numerical noise
        assert!(
            *max_id < 1e-3,
            "tau={tau}: identity residual {max_id} — eq. I/II algebra must match autodiff"
        );
        // (b) approaching stationarity must shrink the eq.-I residual
        assert!(
            last[5] < *r1_early,
            "tau={tau}: r1 must decrease toward stationarity ({r1_early} -> {})",
            last[5]
        );
    }
    // (c) sharpening the gate must shrink the σ′ defect (theorem's limit)
    let sp_swish = summary[0].2[4];
    let sp_sharp = summary[2].2[4];
    println!("\nσ′ defect: swish(τ=1) {sp_swish:.3} -> sharp gate(τ=0.1) {sp_sharp:.3}");
    assert!(sp_sharp < sp_swish, "σ'→0 must be realized by the sharp gate");
    println!("Theorem 1 ✓ — proof identities verified; data in results/theorem1.csv");
    Ok(())
}
