//! §Perf — hot-path microbenchmarks for the bulk FP8 codec, the
//! collective, the ZeRO-1 shard layer, and the parallel step pipeline,
//! emitting `BENCH_hotpath.json` so future PRs are judged against a
//! machine-readable trajectory (methodology: rust/EXPERIMENTS.md §Perf
//! and §Sharding).
//!
//! Acceptance targets for this harness:
//! * bulk decode ≥ 5x the scalar codec on a 1M-element buffer, bulk
//!   encode ≥ 2x (ISSUE 1);
//! * per-worker resident Adam-moment bytes reduced by ≥ (W-1)/W vs
//!   the replicated-f32 baseline at W ∈ {1, 2, 4}, and the FP8
//!   collective's bytes-on-the-wire ratio < 0.3 (ISSUE 4);
//! * overlapped bucket pipeline ≥ phased steps/s at W ∈ {2, 4} ×
//!   pods ∈ {1, 2}, with the measured hidden-comms fraction within 2x
//!   of the `perfmodel::interconnect::overlap_from_times` prediction
//!   (ISSUE 6);
//! * tile-wise FP8 GEMM bit-exact vs its scalar reference and ≥ 0.5x
//!   the f32-tiled steps/s on the host path, with the 128 tile
//!   fitting double-buffered VMEM per the roofline model (ISSUE 8);
//! * journal streaming: the parser's peak line buffer stays within
//!   `MAX_LINE_BYTES` on a ~100 MB synthetic journal (O(1)-memory
//!   proxy), and `tail(64)` on that journal costs no more than
//!   max(10x its cost on a small journal, 50 ms) — the end-seek must
//!   not scale with file size (ISSUE 9; events/s recorded ungated).
//!
//! A floor miss exits non-zero and writes `speedup_floors_met = false`
//! into the report — the CI bench-smoke job gates on both.
//!
//! `BENCH_QUICK=1` caps the big-buffer sections (CI smoke mode); the
//! step-rate section needs `make artifacts` and is skipped (with a
//! note) when the artifacts directory is missing, so the codec and
//! shard numbers are still collected on a bare checkout.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fp8_trainer::campaign::journal::{self, stream::JournalStream, Journal};
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::allreduce::{
    allreduce_mean, global_norm, grad_collective, reduce_mean_into_rank0, CollectiveScratch,
};
use fp8_trainer::coordinator::pipeline::{BucketSchedule, NormStream};
use fp8_trainer::coordinator::topology::{
    hier_bucket_collective, hier_grad_collective, PodTopology,
};
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::gemm::{matmul_f32, matmul_fp8, matmul_fp8_ref, TileQuant};
use fp8_trainer::perfmodel::interconnect::{overlap_cost, overlap_from_times, GAUDI2_LINKS};
use fp8_trainer::perfmodel::roofline;
use fp8_trainer::fp8::{self, bulk, Fp8Format, E4M3, E5M2};
use fp8_trainer::optimizer::{MomentBuffer, MomentStore, ShardLayout};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::bench::{bench, write_json_report, BenchResult};
use fp8_trainer::util::json::{obj, Json};
use fp8_trainer::util::par::max_threads;
use fp8_trainer::util::prng::Rng;

const N: usize = 1 << 20; // 1M elements

/// CI smoke mode: cap the big-buffer sections so the whole harness
/// stays in tens of seconds on a shared runner.
fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

fn codec_data(n: usize) -> Vec<f32> {
    // deterministic, mostly-normal-range values with a subnormal and
    // large-magnitude sprinkle — the optimizer-moment distribution shape
    let mut rng = Rng::new(0xf8f8);
    (0..n)
        .map(|i| {
            let x = (rng.normal() as f32) * 0.02;
            match i % 97 {
                0 => x * 1e-6, // subnormal territory after scaling
                1 => x * 300.0,
                _ => x,
            }
        })
        .collect()
}

struct Report {
    records: Vec<Json>,
}

impl Report {
    fn push(&mut self, r: &BenchResult, extra: Vec<(&str, Json)>) {
        r.report();
        self.records.push(r.to_json(extra));
    }
}

fn gbs(bytes: usize, r: &BenchResult) -> f64 {
    bytes as f64 / r.mean_secs() / 1e9
}

/// Returns whether this format met the ISSUE-1 speedup floors
/// (decode ≥ 5x, encode ≥ 2x vs the scalar codec).
fn codec_benches(report: &mut Report, fmt: Fp8Format, tag: &str) -> bool {
    let xs = codec_data(N);
    let mut bytes = Vec::new();
    bulk::encode_slice_into(fmt, &xs, &mut bytes);
    let mut out_f32 = vec![0.0f32; N];
    let mut out_u8 = vec![0u8; N];

    // ---- encode: scalar reference vs bulk
    let enc_scalar = bench(&format!("{tag} encode 1M scalar"), 1, 20, Duration::from_secs(8), || {
        for (d, &x) in out_u8.iter_mut().zip(&xs) {
            *d = fmt.encode(x);
        }
        std::hint::black_box(&out_u8);
    });
    report.push(&enc_scalar, vec![("gbs", Json::Num(gbs(N * 4, &enc_scalar)))]);

    let mut enc_buf = Vec::with_capacity(N);
    let enc_bulk = bench(&format!("{tag} encode 1M bulk"), 1, 50, Duration::from_secs(8), || {
        bulk::encode_slice_into(fmt, &xs, &mut enc_buf);
        std::hint::black_box(&enc_buf);
    });
    let enc_speedup = enc_scalar.mean_secs() / enc_bulk.mean_secs();
    report.push(
        &enc_bulk,
        vec![
            ("gbs", Json::Num(gbs(N * 4, &enc_bulk))),
            ("speedup_vs_scalar", Json::Num(enc_speedup)),
            ("target_speedup", Json::Num(2.0)),
            ("pass", Json::Bool(enc_speedup >= 2.0)),
        ],
    );

    // ---- decode: scalar reference vs bulk LUT
    let dec_scalar = bench(&format!("{tag} decode 1M scalar"), 1, 20, Duration::from_secs(8), || {
        for (d, &b) in out_f32.iter_mut().zip(&bytes) {
            *d = fmt.decode(b);
        }
        std::hint::black_box(&out_f32);
    });
    report.push(&dec_scalar, vec![("gbs", Json::Num(gbs(N * 4, &dec_scalar)))]);

    let mut dec_buf = Vec::with_capacity(N);
    let dec_bulk = bench(&format!("{tag} decode 1M bulk"), 1, 50, Duration::from_secs(8), || {
        bulk::decode_slice_into(fmt, &bytes, &mut dec_buf);
        std::hint::black_box(&dec_buf);
    });
    let dec_speedup = dec_scalar.mean_secs() / dec_bulk.mean_secs();
    report.push(
        &dec_bulk,
        vec![
            ("gbs", Json::Num(gbs(N * 4, &dec_bulk))),
            ("speedup_vs_scalar", Json::Num(dec_speedup)),
            ("target_speedup", Json::Num(5.0)),
            ("pass", Json::Bool(dec_speedup >= 5.0)),
        ],
    );

    // ---- pack/unpack (amax + scale + scaled encode; LUT + descale)
    let mut pk_buf = Vec::with_capacity(N);
    let pk = bench(&format!("{tag} pack_scaled 1M"), 1, 50, Duration::from_secs(8), || {
        std::hint::black_box(bulk::pack_scaled_into(fmt, &xs, &mut pk_buf));
    });
    report.push(&pk, vec![("gbs", Json::Num(gbs(N * 4, &pk)))]);

    let scale = bulk::pack_scaled_into(fmt, &xs, &mut pk_buf);
    let mut up_buf = Vec::with_capacity(N);
    let up = bench(&format!("{tag} unpack_scaled 1M"), 1, 50, Duration::from_secs(8), || {
        bulk::unpack_scaled_into(fmt, &pk_buf, scale, &mut up_buf);
        std::hint::black_box(&up_buf);
    });
    report.push(&up, vec![("gbs", Json::Num(gbs(N * 4, &up)))]);

    let verdict = |ok| if ok { "PASS" } else { "FAIL" };
    println!(
        "  {tag} bulk-vs-scalar: decode {:.1}x (target >=5x {}) | encode {:.1}x (target >=2x {})\n",
        dec_speedup,
        verdict(dec_speedup >= 5.0),
        enc_speedup,
        verdict(enc_speedup >= 2.0),
    );
    dec_speedup >= 5.0 && enc_speedup >= 2.0
}

/// ISSUE-8 §GEMM records: the tile-wise-scaled FP8 matmul
/// (`gemm::matmul_fp8`) vs the f32 tiled reference at a few model-ish
/// shapes — steps/s, operand GB/s, and the one-off per-tile quantize
/// throughput — next to the `perfmodel::roofline::tiled_gemm`
/// structural prediction so the measured-vs-predicted gap is a
/// tracked artifact. Floors folded into `speedup_floors_met`:
/// * FP8-tiled ≥ 0.5x the f32-tiled steps/s at every shape (the host
///   path trades LUT decode + per-tile descale against 4x smaller
///   operand reads; parity-ish is the honest CPU floor — the 2x win
///   is the MXU's, and lives in the roofline record);
/// * the default 128 tile double-buffers in VMEM (`vmem_ok`);
/// * a bit-exactness probe: the blocked kernel reproduces the scalar
///   serial reference exactly on the benched operands (belt over
///   rust/tests/gemm.rs before any number is recorded).
fn gemm_benches(report: &mut Report) -> bool {
    let mut ok = true;
    let tile = 128usize;
    // (m, n, k): a square mid-size GEMM, a skinny dX-like one, and a
    // ragged shape that exercises partial edge tiles
    let shapes: &[(usize, usize, usize)] =
        if quick() { &[(256, 256, 256), (384, 192, 96)] } else { &[(256, 256, 256), (512, 256, 128), (384, 192, 96)] };
    let iters = if quick() { 8 } else { 30 };
    println!("== tile-wise FP8 GEMM (t{tile}, e4m3 x e4m3) ==");
    for &(m, n, k) in shapes {
        let mk_data = |seed: u64, len: usize| -> Vec<f32> {
            let mut rng = Rng::new(seed);
            (0..len).map(|_| (rng.normal() as f32) * 0.05).collect()
        };
        let a = mk_data(0x9e31 + m as u64, m * k);
        let b = mk_data(0x9e32 + n as u64, k * n);
        // operand + output traffic of one GEMM pass, f32 storage
        let f32_bytes = (m * k + k * n + m * n) * 4;

        let r_f32 = bench(
            &format!("gemm f32-tiled {m}x{n}x{k}"),
            1,
            iters,
            Duration::from_secs(8),
            || {
                std::hint::black_box(matmul_f32(&a, m, k, false, &b, k, n, false).unwrap());
            },
        );
        report.push(&r_f32, vec![("gbs", Json::Num(gbs(f32_bytes, &r_f32)))]);

        // one-off per-step cost: putting both operands on the tile grid
        let r_q = bench(
            &format!("gemm quantize t{tile} {m}x{k}+{k}x{n}"),
            1,
            iters,
            Duration::from_secs(8),
            || {
                std::hint::black_box(TileQuant::quantize(E4M3, tile, &a, m, k));
                std::hint::black_box(TileQuant::quantize(E4M3, tile, &b, k, n));
            },
        );
        report.push(&r_q, vec![("gbs", Json::Num(gbs((m * k + k * n) * 4, &r_q)))]);

        let aq = TileQuant::quantize(E4M3, tile, &a, m, k);
        let bq = TileQuant::quantize(E4M3, tile, &b, k, n);
        // bit-exactness probe before recording: blocked == scalar serial
        let y_blk = matmul_fp8(&aq, false, &bq, false).unwrap();
        let y_ref = matmul_fp8_ref(&aq, false, &bq, false).unwrap();
        let bits_ok = y_blk
            .data
            .iter()
            .zip(&y_ref.data)
            .all(|(p, q)| p.to_bits() == q.to_bits());
        ok &= bits_ok;

        let r_fp8 = bench(
            &format!("gemm fp8-tiled t{tile} {m}x{n}x{k}"),
            1,
            iters,
            Duration::from_secs(8),
            || {
                std::hint::black_box(matmul_fp8(&aq, false, &bq, false).unwrap());
            },
        );
        // fp8 moves 1-byte operands + f32 output + the per-tile scales
        let t_r = m.div_ceil(tile) * k.div_ceil(tile) + k.div_ceil(tile) * n.div_ceil(tile);
        let fp8_bytes = m * k + k * n + m * n * 4 + t_r * 4;
        let sps_f32 = 1.0 / r_f32.mean_secs();
        let sps_fp8 = 1.0 / r_fp8.mean_secs();
        let speedup = sps_fp8 / sps_f32;
        let est = roofline::tiled_gemm(m, n, k, tile);
        let pass = bits_ok && speedup >= 0.5 && est.vmem_ok;
        ok &= pass;
        println!(
            "  {m}x{n}x{k}: f32 {:.1}/s vs fp8 {:.1}/s ({speedup:.2}x, floor 0.5x) | \
             bits {} | roofline {:.2} ({}, vmem {}) {}",
            sps_f32,
            sps_fp8,
            if bits_ok { "exact" } else { "MISMATCH" },
            est.roofline_fraction,
            est.bound,
            if est.vmem_ok { "ok" } else { "OVER" },
            if pass { "PASS" } else { "FAIL" }
        );
        report.push(
            &r_fp8,
            vec![
                ("gbs", Json::Num(gbs(fp8_bytes, &r_fp8))),
                ("m", Json::Num(m as f64)),
                ("n", Json::Num(n as f64)),
                ("k", Json::Num(k as f64)),
                ("tile", Json::Num(tile as f64)),
                ("steps_per_s_f32", Json::Num(sps_f32)),
                ("steps_per_s_fp8", Json::Num(sps_fp8)),
                ("speedup_vs_f32", Json::Num(speedup)),
                ("target_speedup", Json::Num(0.5)),
                ("bit_exact_vs_reference", Json::Bool(bits_ok)),
                ("roofline_fraction", Json::Num(est.roofline_fraction)),
                ("roofline_bound", Json::Str(est.bound.into())),
                ("vmem_ok", Json::Bool(est.vmem_ok)),
                ("pass", Json::Bool(pass)),
            ],
        );
    }
    println!();
    ok
}

/// ISSUE-4 §Sharding records: per-worker resident Adam-moment bytes on
/// the chunk-aligned ZeRO-1 layout (exact-FP8-packed shards holding
/// on-grid data, the trainer's steady state) vs the replicated-f32
/// baseline, plus the FP8 collective's wire-byte ratio. Returns
/// whether every floor held.
fn shard_collective_benches(report: &mut Report) -> bool {
    let mut ok = true;
    let chunk = 262_144usize;
    let total = if quick() { chunk * 8 } else { chunk * 32 };

    // on-grid values (what the chunked Adam artifact emits): quantize
    // a normal-ish distribution onto per-chunk pow2-scaled grids of
    // each moment's storage format so exact-mode packing takes the
    // 1-byte path, as in a real fp8_full run (m: E4M3, v: E5M2)
    let mut rng = Rng::new(0x54a7d);
    let raw: Vec<f32> = (0..total).map(|_| (rng.normal() as f32) * 2e-3).collect();
    let grids: Vec<(Fp8Format, Vec<f32>)> = [E4M3, E5M2]
        .into_iter()
        .map(|fmt| {
            let mut vals = raw.clone();
            let mut bytes_tmp = Vec::new();
            for c in vals.chunks_mut(chunk) {
                let scale = bulk::pack_scaled_into(fmt, c, &mut bytes_tmp);
                bulk::unpack_scaled_buf(fmt, &bytes_tmp, scale, c);
            }
            (fmt, vals)
        })
        .collect();

    println!("== ZeRO-1 per-worker moment memory (total {total} elems, chunk {chunk}) ==");
    for w in [1usize, 2, 4] {
        let layout = ShardLayout::chunk_aligned(total, w, chunk);
        let mut per_worker = 0usize;
        for &(off, len) in &layout.shards {
            // m + v shards for this worker, packed
            let mut worker_bytes = 0usize;
            for (fmt, vals) in &grids {
                let mut b = MomentBuffer::zeros_exact(len, MomentStore::Fp8(*fmt), chunk);
                b.load_from(&vals[off..off + len]);
                b.pack();
                worker_bytes += b.resident_bytes();
            }
            per_worker = per_worker.max(worker_bytes);
        }
        let replicated = total * 8; // two f32 moments, every worker
        let reduction = 1.0 - per_worker as f64 / replicated as f64;
        let floor = (w as f64 - 1.0) / w as f64;
        let pass = reduction >= floor;
        ok &= pass;
        println!(
            "  dp_workers={w}: {per_worker} B/worker vs {replicated} B replicated \
             ({:.1}% reduction, floor {:.1}%) {}",
            reduction * 100.0,
            floor * 100.0,
            if pass { "PASS" } else { "FAIL" }
        );
        report.records.push(obj(vec![
            ("name", Json::Str(format!("moment_bytes_per_worker dp{w}"))),
            ("dp_workers", Json::Num(w as f64)),
            ("elems", Json::Num(total as f64)),
            ("moment_bytes_per_worker", Json::Num(per_worker as f64)),
            ("replicated_f32_bytes", Json::Num(replicated as f64)),
            ("reduction", Json::Num(reduction)),
            ("target_reduction", Json::Num(floor)),
            ("pass", Json::Bool(pass)),
        ]));
    }

    println!("== FP8 gradient collective (wire bytes + rate) ==");
    let n = if quick() { 1 << 20 } else { 1 << 22 };
    for w in [2usize, 4] {
        let mk = |seed: u64| -> Vec<Vec<f32>> {
            let mut rng = Rng::new(seed);
            (0..w).map(|_| (0..n).map(|_| (rng.normal() as f32) * 0.01).collect()).collect()
        };
        let mut f32_bufs = mk(1);
        let f32_r = bench(
            &format!("grad_collective f32 {w}x{}M", n >> 20),
            1,
            10,
            Duration::from_secs(8),
            || {
                std::hint::black_box(grad_collective(&mut f32_bufs, None, chunk));
            },
        );
        report.push(&f32_r, vec![("gbs", Json::Num(gbs(n * 4 * w, &f32_r)))]);

        let mut fp8_bufs = mk(1);
        let mut stats = fp8_trainer::coordinator::allreduce::CollectiveStats::default();
        let fp8_r = bench(
            &format!("grad_collective fp8 {w}x{}M", n >> 20),
            1,
            10,
            Duration::from_secs(8),
            || {
                stats = grad_collective(&mut fp8_bufs, Some(E5M2), chunk);
            },
        );
        let ratio = stats.wire_ratio();
        let pass = ratio < 0.3;
        ok &= pass;
        println!(
            "  dp_workers={w}: {} wire bytes vs {} f32 (ratio {ratio:.4}) {}",
            stats.wire_bytes(),
            stats.wire_bytes_f32(),
            if pass { "PASS" } else { "FAIL" }
        );
        report.push(
            &fp8_r,
            vec![
                ("gbs", Json::Num(gbs(n * 4 * w, &fp8_r))),
                ("dp_workers", Json::Num(w as f64)),
                ("wire_bytes", Json::Num(stats.wire_bytes() as f64)),
                ("wire_bytes_f32", Json::Num(stats.wire_bytes_f32() as f64)),
                ("wire_ratio", Json::Num(ratio)),
                ("target_wire_ratio", Json::Num(0.3)),
                ("pass", Json::Bool(pass)),
            ],
        );
    }
    println!();
    ok
}

/// ISSUE-5 §Topology records: per-level (intra/inter), per-leg
/// (reduce-scatter/all-gather) wire bytes of the two-level collective
/// at pods ∈ {1, 2, 4} over an 8-worker pool, in the default
/// compression mix (intra f32, inter FP8 — the thin-pipe rule).
/// Floors folded into `speedup_floors_met`:
/// * every recorded level matches its closed form
///   (`intra = 2·pods·(P-1)·4n`, `inter = 2·(pods-1)·(n + 4·⌈n/chunk⌉)`);
/// * the inter level compresses below 0.3 of its f32 baseline
///   whenever it exists;
/// * the executed mix never moves more total bytes than the flat f32
///   collective would.
fn topology_benches(report: &mut Report) -> bool {
    let mut ok = true;
    let chunk = 262_144usize;
    let n = if quick() { 1 << 20 } else { 1 << 22 };
    let w = 8usize;
    println!("== two-level collective (intra f32 / inter fp8, {w} workers x {}M) ==", n >> 20);
    let flat_f32_bytes = 2 * (w as u64 - 1) * n as u64 * 4;
    for pods in [1usize, 2, 4] {
        let topo = PodTopology::new(w, pods).unwrap();
        let p = topo.workers_per_pod() as u64;
        let mk = || -> Vec<Vec<f32>> {
            let mut rng = Rng::new(0x70d0 + pods as u64);
            (0..w).map(|_| (0..n).map(|_| (rng.normal() as f32) * 0.01).collect()).collect()
        };
        let mut bufs = mk();
        let mut stats = fp8_trainer::coordinator::allreduce::CollectiveStats::default();
        let r = bench(
            &format!("hier_collective pods={pods} {w}x{}M", n >> 20),
            1,
            10,
            Duration::from_secs(8),
            || {
                stats = hier_grad_collective(&mut bufs, topo, None, Some(E5M2), chunk);
            },
        );
        // closed forms the records must pin
        let n_chunks = n.div_ceil(chunk) as u64;
        let intra_leg = pods as u64 * (p - 1) * n as u64 * 4;
        let inter_leg = (pods as u64 - 1) * (n as u64 + 4 * n_chunks);
        let shape_ok = stats.intra.reduce_scatter == intra_leg
            && stats.intra.all_gather == intra_leg
            && stats.inter.reduce_scatter == inter_leg
            && stats.inter.all_gather == inter_leg;
        let inter_ok = pods == 1 || stats.inter_wire_ratio() < 0.3;
        let total_ok = stats.wire_bytes() <= flat_f32_bytes;
        let pass = shape_ok && inter_ok && total_ok;
        ok &= pass;
        println!(
            "  pods={pods}: intra {} B (rs+ag), inter {} B (rs+ag, ratio {:.4}), \
             total {} B vs flat-f32 {} B {}",
            stats.intra.total(),
            stats.inter.total(),
            stats.inter_wire_ratio(),
            stats.wire_bytes(),
            flat_f32_bytes,
            if pass { "PASS" } else { "FAIL" }
        );
        report.push(
            &r,
            vec![
                ("gbs", Json::Num(gbs(n * 4 * w, &r))),
                ("dp_workers", Json::Num(w as f64)),
                ("pods", Json::Num(pods as f64)),
                ("intra_rs_bytes", Json::Num(stats.intra.reduce_scatter as f64)),
                ("intra_ag_bytes", Json::Num(stats.intra.all_gather as f64)),
                ("inter_rs_bytes", Json::Num(stats.inter.reduce_scatter as f64)),
                ("inter_ag_bytes", Json::Num(stats.inter.all_gather as f64)),
                ("inter_wire_ratio", Json::Num(stats.inter_wire_ratio())),
                ("wire_bytes", Json::Num(stats.wire_bytes() as f64)),
                ("wire_bytes_flat_f32", Json::Num(flat_f32_bytes as f64)),
                ("pass", Json::Bool(pass)),
            ],
        );
    }
    println!();
    ok
}

/// ISSUE-6 §Overlap records: synthetic phased-vs-overlapped step
/// tails (collective + norm + an Adam-weight elementwise pass, the
/// same downstream work in both schedules) at W ∈ {2, 4} ×
/// pods ∈ {1, 2}, on the trainer's real per-bucket collective
/// (`hier_bucket_collective`) and norm stream. Floors folded into
/// `speedup_floors_met`:
/// * overlapped steps/s ≥ phased steps/s (with a 3% noise band —
///   the raw numbers are recorded so a scripted gate can tighten it);
/// * the pipeline model's predicted hidden-comms fraction, fed the
///   *measured* per-stage seconds (`overlap_from_times`), lands
///   within 2x of the measured hidden fraction.
/// The GAUDI2-wire `overlap_cost` prediction is recorded ungated —
/// wire seconds on a CPU host say nothing about the deployment, but
/// the record keeps the analytic trajectory next to the measured one.
fn overlap_benches(report: &mut Report) -> bool {
    let mut ok = true;
    let chunk = 262_144usize;
    // 4 buckets either way: quick = 1-chunk buckets over 4 chunks,
    // full = 4-chunk (4 MiB) buckets over 16 chunks
    let (n, bucket_bytes) =
        if quick() { (chunk * 4, chunk * 4) } else { (chunk * 16, chunk * 4 * 4) };
    let sched = BucketSchedule::new(n, bucket_bytes, chunk);
    let n_buckets = sched.len();
    // the downstream compute the collective hides behind: the norm
    // fold plus a few Adam-weight elementwise passes over the bucket
    const OPT_PASSES: usize = 4;
    println!(
        "== overlapped bucket pipeline (synthetic, {n_buckets} buckets x {} elems) ==",
        sched.elems_per_bucket
    );
    for (w, pods) in [(2usize, 1usize), (2, 2), (4, 1), (4, 2)] {
        let topo = PodTopology::new(w, pods).unwrap();
        let src: Vec<Vec<f32>> = {
            let mut rng = Rng::new(0x0ea1 + (w * 16 + pods) as u64);
            (0..w).map(|_| (0..n).map(|_| (rng.normal() as f32) * 0.01).collect()).collect()
        };
        let mut params = vec![0.0f32; n];

        // ---- phased reference: whole-buffer collective, then norm +
        //      opt — every collective second is exposed stall
        let mut bufs: Vec<Vec<f32>> = src.clone();
        let mut ph_comm = 0.0f64;
        let mut ph_compute = 0.0f64;
        let r_ph = bench(
            &format!("overlap phased w={w} pods={pods}"),
            1,
            10,
            Duration::from_secs(8),
            || {
                for (b, s) in bufs.iter_mut().zip(&src) {
                    b.copy_from_slice(s);
                }
                let t0 = Instant::now();
                hier_grad_collective(&mut bufs, topo, None, Some(E5M2), chunk);
                ph_comm = t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                std::hint::black_box(global_norm(&bufs[0]));
                for _ in 0..OPT_PASSES {
                    for (p, g) in params.iter_mut().zip(&bufs[0]) {
                        *p = *p * 0.999 + *g * 1e-3;
                    }
                }
                ph_compute = t1.elapsed().as_secs_f64();
            },
        );
        report.push(
            &r_ph,
            vec![
                ("dp_workers", Json::Num(w as f64)),
                ("pods", Json::Num(pods as f64)),
                ("comm_s", Json::Num(ph_comm)),
                ("compute_s", Json::Num(ph_compute)),
            ],
        );

        // ---- overlapped: a comms thread runs bucket k's collective
        //      while the main thread norms + opts bucket k-1
        let mut bufs_ov: Vec<Vec<f32>> = src.clone();
        let mut scratch = (CollectiveScratch::default(), CollectiveScratch::default());
        let mut ov_comm = 0.0f64;
        let mut ov_compute = 0.0f64;
        let mut ov_exposed = 0.0f64;
        let r_ov = bench(
            &format!("overlap pipelined w={w} pods={pods}"),
            1,
            10,
            Duration::from_secs(8),
            || {
                for (b, s) in bufs_ov.iter_mut().zip(&src) {
                    b.copy_from_slice(s);
                }
                let mut per_bucket: Vec<Vec<&mut [f32]>> =
                    (0..n_buckets).map(|_| Vec::with_capacity(w)).collect();
                for buf in bufs_ov.iter_mut() {
                    let mut rest = buf.as_mut_slice();
                    for (k, &(_, len)) in sched.buckets.iter().enumerate() {
                        let (win, tail) = rest.split_at_mut(len);
                        rest = tail;
                        per_bucket[k].push(win);
                    }
                }
                let (tx, rx) = mpsc::channel::<(usize, &mut [f32], Instant)>();
                let mut compute_s = 0.0f64;
                let mut exposed_s = 0.0f64;
                let mut comm_busy = 0.0f64;
                std::thread::scope(|s| {
                    let (scr0, scr1) = (&mut scratch.0, &mut scratch.1);
                    let sched_ref = &sched;
                    let comms = s.spawn(move || -> f64 {
                        let mut busy = 0.0f64;
                        for (k, mut wins) in per_bucket.into_iter().enumerate() {
                            let scr = if k % 2 == 0 { &mut *scr0 } else { &mut *scr1 };
                            let started = Instant::now();
                            hier_bucket_collective(
                                &mut wins,
                                sched_ref.buckets[k].0,
                                topo,
                                None,
                                Some(E5M2),
                                chunk,
                                scr,
                            );
                            busy += started.elapsed().as_secs_f64();
                            let rank0 = wins.swap_remove(0);
                            if tx.send((k, rank0, started)).is_err() {
                                break;
                            }
                        }
                        busy
                    });
                    let mut norm = NormStream::new();
                    for _ in 0..n_buckets {
                        let wait0 = Instant::now();
                        let Ok((k, win, started)) = rx.recv() else { break };
                        let done = Instant::now();
                        let from = if started > wait0 { started } else { wait0 };
                        exposed_s += done.duration_since(from).as_secs_f64();
                        let t1 = Instant::now();
                        norm.push(win);
                        let (off, len) = sched.buckets[k];
                        for _ in 0..OPT_PASSES {
                            for (p, g) in params[off..off + len].iter_mut().zip(&*win) {
                                *p = *p * 0.999 + *g * 1e-3;
                            }
                        }
                        compute_s += t1.elapsed().as_secs_f64();
                    }
                    std::hint::black_box(norm.finish());
                    comm_busy = comms.join().expect("bench comms thread");
                });
                ov_comm = comm_busy;
                ov_compute = compute_s;
                ov_exposed = exposed_s;
            },
        );

        let sps_ph = 1.0 / r_ph.mean_secs();
        let sps_ov = 1.0 / r_ov.mean_secs();
        // 3% noise band on the steps/s floor: scoped threads + a CI
        // runner add jitter; the raw numbers are in the record
        let faster = sps_ov >= sps_ph * 0.97;
        let meas_hidden = if ov_comm <= 0.0 {
            1.0
        } else {
            (1.0 - ov_exposed / ov_comm).clamp(0.0, 1.0)
        };
        let pred = overlap_from_times(ov_comm, ov_compute, n_buckets);
        // prediction floor: within 2x of measured (both-near-zero is a
        // trivial pass — nothing to hide, nothing to predict)
        let within_2x = if pred.hidden_fraction < 0.05 && meas_hidden < 0.05 {
            true
        } else {
            let lo = pred.hidden_fraction.min(meas_hidden);
            let hi = pred.hidden_fraction.max(meas_hidden);
            lo > 0.0 && hi / lo <= 2.0
        };
        let pass = faster && within_2x;
        ok &= pass;
        // deployment-shape prediction (GAUDI2 wire model), ungated
        let g2 = overlap_cost(n, pods, w / pods, false, true, true, n_buckets, &GAUDI2_LINKS);
        println!(
            "  w={w} pods={pods}: phased {:.1}/s vs overlapped {:.1}/s ({:.2}x) | \
             hidden comms: measured {:.2} vs predicted {:.2} (gaudi2 model {:.2}) {}",
            sps_ph,
            sps_ov,
            sps_ov / sps_ph,
            meas_hidden,
            pred.hidden_fraction,
            g2.hidden_fraction,
            if pass { "PASS" } else { "FAIL" }
        );
        report.push(
            &r_ov,
            vec![
                ("dp_workers", Json::Num(w as f64)),
                ("pods", Json::Num(pods as f64)),
                ("buckets", Json::Num(n_buckets as f64)),
                ("steps_per_s_phased", Json::Num(sps_ph)),
                ("steps_per_s_overlapped", Json::Num(sps_ov)),
                ("speedup_vs_phased", Json::Num(sps_ov / sps_ph)),
                ("comm_s", Json::Num(ov_comm)),
                ("compute_s", Json::Num(ov_compute)),
                ("comm_exposed_s", Json::Num(ov_exposed)),
                ("hidden_fraction_measured", Json::Num(meas_hidden)),
                ("hidden_fraction_predicted", Json::Num(pred.hidden_fraction)),
                ("hidden_fraction_gaudi2_model", Json::Num(g2.hidden_fraction)),
                ("pass", Json::Bool(pass)),
            ],
        );
    }
    println!();
    ok
}

fn collective_benches(report: &mut Report) {
    let big = if quick() { 2_000_000usize } else { 12_000_000usize };
    let mk = |w: usize| -> Vec<Vec<f32>> {
        (0..w).map(|r| vec![r as f32 * 0.1 + 0.5; big]).collect()
    };

    let mut bufs = mk(4);
    let ar = bench(
        &format!("allreduce_mean 4x{}M (broadcast)", big / 1_000_000),
        1,
        10,
        Duration::from_secs(10),
        || {
            allreduce_mean(&mut bufs);
        },
    );
    report.push(&ar, vec![("gbs", Json::Num(gbs(big * 4 * 4, &ar)))]);

    let mut bufs0 = mk(4);
    let r0 = bench(
        &format!("reduce_mean_into_rank0 4x{}M", big / 1_000_000),
        1,
        10,
        Duration::from_secs(10),
        || {
            reduce_mean_into_rank0(&mut bufs0);
        },
    );
    let ar_speedup = ar.mean_secs() / r0.mean_secs();
    report.push(
        &r0,
        vec![
            ("gbs", Json::Num(gbs(big * 4 * 4, &r0))),
            ("speedup_vs_broadcast", Json::Num(ar_speedup)),
        ],
    );

    let flat = vec![0.01f32; big];
    let gn = bench(
        &format!("global_norm {}M (chunked parallel)", big / 1_000_000),
        1,
        20,
        Duration::from_secs(8),
        || {
            std::hint::black_box(global_norm(&flat));
        },
    );
    report.push(&gn, vec![("gbs", Json::Num(gbs(big * 4, &gn)))]);

    println!("  reduce_mean_into_rank0 vs broadcast allreduce: {ar_speedup:.2}x\n");
}

fn step_benches(report: &mut Report) -> anyhow::Result<()> {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("  [skip] step-rate section: {e}");
            return Ok(());
        }
    };
    for dp in [1usize, 2, 4] {
        let cfg = TrainConfig {
            size: "s1m".into(),
            recipe: "fp8_full".into(),
            steps: 1,
            dp_workers: dp,
            out_dir: format!("runs/bench_hotpath/dp{dp}"),
            ..Default::default()
        };
        let mut t = Trainer::new(rt.clone(), cfg)?;
        t.step()?; // warm caches / compile
        let tokens = t.tokens_per_step() as f64;
        let r = bench(
            &format!("trainer.step s1m dp_workers={dp}"),
            1,
            15,
            Duration::from_secs(15),
            || {
                t.step().unwrap();
            },
        );
        let steps_per_s = 1.0 / r.mean_secs();
        report.push(
            &r,
            vec![
                ("dp_workers", Json::Num(dp as f64)),
                ("steps_per_s", Json::Num(steps_per_s)),
                ("tokens_per_s", Json::Num(tokens * steps_per_s)),
            ],
        );
        // per-phase timer records from the live trainer, overlapped
        // default vs forced-phased (ungated — artifact-dependent wall
        // clocks; the gated overlap floors live in overlap_benches)
        for phased in [true, false] {
            t.force_phased_step = phased;
            let out = t.step()?;
            let tm = out.timers;
            report.records.push(obj(vec![
                (
                    "name",
                    Json::Str(format!(
                        "step_phase_timers s1m dp{dp} {}",
                        if tm.overlapped { "overlapped" } else { "phased" }
                    )),
                ),
                ("dp_workers", Json::Num(dp as f64)),
                ("overlapped", Json::Bool(tm.overlapped)),
                ("buckets", Json::Num(tm.buckets as f64)),
                ("grad_s", Json::Num(tm.grad_s)),
                ("collective_s", Json::Num(tm.collective_s)),
                ("norm_s", Json::Num(tm.norm_s)),
                ("adam_s", Json::Num(tm.adam_s)),
                ("comm_exposed_s", Json::Num(tm.comm_exposed_s)),
                ("hidden_comm_fraction", Json::Num(tm.hidden_comm_fraction())),
            ]));
        }
        t.force_phased_step = false;
    }
    Ok(())
}

/// §Journal streaming (ISSUE 9) — the observability layer's own hot
/// path: a trillion-token campaign's journal is read by `status` /
/// `fleet` on every operator query, so the parser's throughput and
/// memory shape are tracked like any other hot path. Floors folded
/// into `speedup_floors_met`:
/// * O(1)-memory proxy: the stream's peak line buffer stays within
///   `MAX_LINE_BYTES` on the ~100 MB journal (the line buffer is the
///   parser's only growing allocation, so its peak bounds residency);
/// * end-seek: `tail(64)` on the ~100 MB journal costs no more than
///   max(10x its cost on a small journal, 50 ms absolute) — the tail
///   must not scale with file size.
/// Events/s and GB/s are recorded ungated (machine-dependent).
fn journal_benches(report: &mut Report) -> bool {
    let dir = std::env::temp_dir().join(format!("fp8_bench_journal_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let big_events: usize = if quick() { 50_000 } else { 800_000 };
    let small_events: usize = 2_000;
    // realistic line shape: the dominant kind over a long campaign is
    // the periodic snapshot record (~130 B/line)
    let write = |path: &std::path::Path, n: usize| -> anyhow::Result<u64> {
        let mut j = Journal::open(path)?;
        j.record("campaign_start", 0, vec![])?;
        for i in 1..n {
            j.record(
                "snapshot",
                i * 10,
                vec![
                    ("reason", Json::Str("periodic".into())),
                    ("path", Json::Str(format!("snapshots/snap_{:08}.ckpt", i * 10))),
                    ("bytes", Json::Num(123_456_789.0)),
                    ("loss", Json::Num(3.0 - (i % 1000) as f64 * 1e-3)),
                ],
            )?;
        }
        j.flush()?;
        Ok(std::fs::metadata(path)?.len())
    };
    let big = dir.join("big.jsonl");
    let small = dir.join("small.jsonl");
    let (big_bytes, small_bytes) = match (write(&big, big_events), write(&small, small_events)) {
        (Ok(a), Ok(b)) => (a, b),
        _ => {
            println!("  [skip] journal section: could not build synthetic journals in temp dir");
            return true; // environment problem, not a perf regression
        }
    };
    println!(
        "  synthetic journals: big {} events / {:.1} MiB, small {} events / {:.1} MiB",
        big_events,
        big_bytes as f64 / 1048576.0,
        small_events,
        small_bytes as f64 / 1048576.0
    );

    // ---- full streaming scan: events/s + the O(1)-memory proxy
    let mut peak = 0usize;
    let mut events_seen = 0usize;
    let scan = bench("journal stream scan (full file)", 1, 5, Duration::from_secs(12), || {
        let mut s = JournalStream::from_path(&big).unwrap();
        let mut n = 0usize;
        while let Some(e) = s.next_event().unwrap() {
            std::hint::black_box(&e);
            n += 1;
        }
        assert_eq!(s.skipped(), 0);
        peak = peak.max(s.peak_line_bytes());
        events_seen = n;
    });
    let events_per_s = events_seen as f64 / scan.mean_secs();
    report.push(
        &scan,
        vec![
            ("journal_bytes", Json::Num(big_bytes as f64)),
            ("events", Json::Num(events_seen as f64)),
            ("events_per_s", Json::Num(events_per_s)),
            ("gbs", Json::Num(big_bytes as f64 / scan.mean_secs() / 1e9)),
            ("peak_line_bytes", Json::Num(peak as f64)),
        ],
    );
    let mem_ok = peak > 0 && peak <= journal::stream::MAX_LINE_BYTES;

    // ---- tail(64): end-seeked, must not scale with file size
    let tail_n = 64usize;
    let t_small = bench("journal tail(64) small file", 1, 50, Duration::from_secs(4), || {
        let out = journal::tail(&small, tail_n).unwrap();
        assert_eq!(out.events.len(), tail_n);
        std::hint::black_box(&out);
    });
    report.push(
        &t_small,
        vec![
            ("journal_bytes", Json::Num(small_bytes as f64)),
            ("tail_n", Json::Num(tail_n as f64)),
        ],
    );
    let t_big = bench("journal tail(64) big file", 1, 50, Duration::from_secs(4), || {
        let out = journal::tail(&big, tail_n).unwrap();
        assert_eq!(out.events.len(), tail_n);
        std::hint::black_box(&out);
    });
    let ratio = t_big.mean_secs() / t_small.mean_secs();
    let scan_vs_tail = scan.mean_secs() / t_big.mean_secs();
    report.push(
        &t_big,
        vec![
            ("journal_bytes", Json::Num(big_bytes as f64)),
            ("tail_n", Json::Num(tail_n as f64)),
            ("vs_small_ratio", Json::Num(ratio)),
            ("full_scan_vs_tail", Json::Num(scan_vs_tail)),
        ],
    );
    // either branch proves the cost is bounded by the tail, not the
    // file: the ratio on a quiet machine, the absolute guard against
    // shared-runner timer noise on the sub-millisecond small case
    let tail_ok = ratio <= 10.0 || t_big.mean_secs() < 0.050;
    println!(
        "  scan: {:.0} events/s; tail(64) big/small {ratio:.2}x (floor: <=10x or <50 ms); \
         full scan / tail: {scan_vs_tail:.0}x; peak line {peak} B (bound {})\n",
        events_per_s,
        journal::stream::MAX_LINE_BYTES
    );
    std::fs::remove_dir_all(&dir).ok();
    if !mem_ok {
        eprintln!("  FLOOR MISS: journal stream peak line {peak} B exceeds MAX_LINE_BYTES");
    }
    if !tail_ok {
        eprintln!("  FLOOR MISS: journal tail scales with file size ({ratio:.2}x big/small)");
    }
    mem_ok && tail_ok
}

fn main() -> anyhow::Result<()> {
    let mut report = Report { records: Vec::new() };

    println!("== bulk FP8 codec (1M elements) ==");
    let mut floors_met = true;
    floors_met &= codec_benches(&mut report, E4M3, "e4m3");
    floors_met &= codec_benches(&mut report, E5M2, "e5m2");

    // sanity: bulk must agree with the scalar reference before any
    // number is recorded (belt over the dedicated equivalence tests)
    let xs = codec_data(1 << 16);
    for fmt in [E4M3, E5M2] {
        let mut b = Vec::new();
        bulk::encode_slice_into(fmt, &xs, &mut b);
        for (i, (&x, &code)) in xs.iter().zip(&b).enumerate() {
            assert_eq!(code, fp8::encode(fmt, x), "{fmt:?} mismatch at {i}");
        }
    }

    println!("== collective ==");
    collective_benches(&mut report);

    let gemm_floors_met = gemm_benches(&mut report);
    let shard_floors_met = shard_collective_benches(&mut report);
    let topology_floors_met = topology_benches(&mut report);
    let overlap_floors_met = overlap_benches(&mut report);

    println!("== journal streaming (~100 MB synthetic journal) ==");
    let journal_floors_met = journal_benches(&mut report);

    println!("== step rate (needs artifacts) ==");
    step_benches(&mut report)?;

    let all_met = floors_met
        && gemm_floors_met
        && shard_floors_met
        && topology_floors_met
        && overlap_floors_met
        && journal_floors_met;
    write_json_report(
        "BENCH_hotpath.json",
        vec![
            ("suite", Json::Str("hotpath".into())),
            ("elements", Json::Num(N as f64)),
            ("threads", Json::Num(max_threads() as f64)),
            ("quick", Json::Bool(quick())),
            // the CI bench-smoke gate: codec speedups AND the ISSUE-4
            // shard-memory / wire-ratio floors, all in one flag
            ("speedup_floors_met", Json::Bool(all_met)),
            ("codec_floors_met", Json::Bool(floors_met)),
            ("gemm_floors_met", Json::Bool(gemm_floors_met)),
            ("shard_collective_floors_met", Json::Bool(shard_floors_met)),
            ("topology_floors_met", Json::Bool(topology_floors_met)),
            ("overlap_floors_met", Json::Bool(overlap_floors_met)),
            ("journal_floors_met", Json::Bool(journal_floors_met)),
        ],
        report.records,
    )?;
    println!("wrote BENCH_hotpath.json");
    if !all_met {
        // make the acceptance floors enforceable by scripted perf gates
        eprintln!(
            "FAIL: perf floors not met (codec >=5x decode / >=2x encode: {floors_met}; \
             tiled FP8 GEMM bit-exact + >=0.5x f32 + vmem: {gemm_floors_met}; \
             shard memory (W-1)/W + wire ratio < 0.3: {shard_floors_met}; \
             topology per-level wire floors: {topology_floors_met}; \
             overlapped >= phased steps/s + hidden-fraction prediction within 2x: \
             {overlap_floors_met}; \
             journal stream O(1) memory + size-independent tail: {journal_floors_met})"
        );
        std::process::exit(1);
    }
    Ok(())
}
