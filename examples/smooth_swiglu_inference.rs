//! Smooth-SwiGLU inference folding (paper §4.4): the per-channel
//! scales s_i can be absorbed into w1 (w̃1 = s·w1) and w3
//! (w̃3 = s⁻¹·w3), so inference pays **zero** cost for the fix.
//!
//! This example demonstrates the algebra numerically in Rust using the
//! fp8 codec: per-channel-scaled quantization of the SwiGLU product is
//! exactly equivalent to running the plain SwiGLU with folded weights,
//! for pow2 scales.
//!
//! ```text
//! cargo run --release --example smooth_swiglu_inference
//! ```

use fp8_trainer::fp8::{self, E4M3};
use fp8_trainer::util::prng::Rng;

fn swish(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn main() {
    let d = 32;
    let f = 16;
    let n_tokens = 64;
    let mut rng = Rng::new(42);

    // weights, with one outlier channel (as post-alignment training makes)
    let mut w1 = vec![0.0f32; d * f];
    let mut w2 = vec![0.0f32; d * f];
    rng.fill_normal(&mut w1, 0.4);
    rng.fill_normal(&mut w2, 0.4);
    for i in 0..d {
        let a = w2[i * f + 3] * 20.0;
        w1[i * f + 3] = a; // aligned + large: the quadratic blow-up
        w2[i * f + 3] = a;
    }
    let mut xs = vec![0.0f32; n_tokens * d];
    rng.fill_normal(&mut xs, 1.0);

    // SwiGLU products per token/channel
    let mut h = vec![0.0f32; n_tokens * f];
    for t in 0..n_tokens {
        for j in 0..f {
            let (mut a1, mut a2) = (0.0f32, 0.0f32);
            for i in 0..d {
                a1 += xs[t * d + i] * w1[i * f + j];
                a2 += xs[t * d + i] * w2[i * f + j];
            }
            h[t * f + j] = a1 * swish(a2);
        }
    }

    // per-channel JIT scales (training-time Smooth-SwiGLU)
    let mut s = vec![1.0f32; f];
    for j in 0..f {
        let amax = (0..n_tokens).map(|t| h[t * f + j].abs()).fold(0.0f32, f32::max);
        s[j] = fp8::compute_scale(E4M3, amax);
    }

    // (a) training-style: q = Q(h·s), consumer folds s⁻¹
    // (b) inference-style: fold s into the *stored quantized weights'
    //     output* — Q(s·h)/s must equal the per-channel dequant exactly
    // quantization error normalized by each channel's own amax — the
    // quantity per-channel scaling controls (per-value relative error
    // is unbounded for any fixed-point-in-range scheme)
    let mut max_rel = 0.0f32;
    let mut plain_overflows = 0usize;
    let g = fp8::compute_scale(E4M3, h.iter().fold(0.0f32, |a, &x| a.max(x.abs())));
    for t in 0..n_tokens {
        for j in 0..f {
            let v = h[t * f + j];
            let amax_j = E4M3.max() / s[j];
            let smooth = E4M3.decode(E4M3.encode((v * s[j]).clamp(-E4M3.max(), E4M3.max()))) / s[j];
            // per-tensor quantization for contrast (scale from global amax)
            let plain = E4M3.decode(E4M3.encode(v * g)) / g;
            if !plain.is_finite() {
                plain_overflows += 1;
            }
            max_rel = max_rel.max((smooth - v).abs() / amax_j);
        }
    }
    println!("tokens={n_tokens}, channels={f}, outlier channel 3 scale s={}", s[3]);
    println!(
        "Smooth-SwiGLU max quantization error / channel amax: {max_rel:.4} (E4M3 top-binade step = 0.0625)"
    );

    // folding exactness: Q(s·h)/s == (1/s)·Q(s·h) is trivially exact;
    // the substantive check is that per-channel error stays bounded
    // while per-tensor quantization crushes the small channels
    let g = fp8::compute_scale(E4M3, h.iter().fold(0.0f32, |a, &x| a.max(x.abs())));
    let mut crushed = 0usize;
    for t in 0..n_tokens {
        for j in 0..f {
            if j == 3 {
                continue;
            }
            let v = h[t * f + j];
            let plain = E4M3.decode(E4M3.encode(v * g)) / g;
            if v.abs() > 1e-3 && plain == 0.0 {
                crushed += 1;
            }
        }
    }
    println!(
        "per-tensor scaling under the outlier: {crushed} non-outlier values flushed to zero, {plain_overflows} overflows"
    );
    println!(
        "per-channel scaling (Smooth-SwiGLU): all channels keep full E4M3 resolution — \
         zero inference cost after folding"
    );
    assert!(max_rel < 0.07, "smooth error must stay within one top-binade E4M3 step");
}
