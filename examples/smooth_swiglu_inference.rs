//! Smooth-SwiGLU inference folding (paper §4.4): the per-channel
//! scales s_i can be absorbed into w1 (w̃1 = s·w1) and w3
//! (w̃3 = s⁻¹·w3), so inference pays **zero** cost for the fix.
//!
//! Thin demo wrapper over the library pieces that now own this
//! algebra: `serving::swiglu_products` / `serving::channel_scales`
//! (calibration) and `coordinator::folding::fold_scales` (the fold).
//! The asserted version of this demonstration — exact bit-equality of
//! folded vs per-channel-scaled SwiGLU, NaN/−0.0/outlier payloads —
//! lives in `rust/tests/property.rs`; the end-to-end served form in
//! `rust/tests/serving.rs`.
//!
//! ```text
//! cargo run --release --example smooth_swiglu_inference
//! ```

use fp8_trainer::coordinator::folding::fold_scales;
use fp8_trainer::fp8::E4M3;
use fp8_trainer::serving::{channel_scales, swiglu_products};
use fp8_trainer::util::prng::Rng;

fn main() {
    let d = 32;
    let f = 16;
    let n_tokens = 64;
    let mut rng = Rng::new(42);

    // weights, with one outlier channel (as post-alignment training makes)
    let mut w1 = vec![0.0f32; d * f];
    let mut w2 = vec![0.0f32; d * f];
    let mut w3 = vec![0.0f32; f * d];
    rng.fill_normal(&mut w1, 0.4);
    rng.fill_normal(&mut w2, 0.4);
    rng.fill_normal(&mut w3, 0.4);
    for i in 0..d {
        let a = w2[i * f + 3] * 20.0;
        w1[i * f + 3] = a; // aligned + large: the quadratic blow-up
        w2[i * f + 3] = a;
    }
    let mut xs = vec![0.0f32; n_tokens * d];
    rng.fill_normal(&mut xs, 1.0);

    // calibrate: SwiGLU products → per-channel pow2 smoothing scales
    let h = swiglu_products(&xs, &w1, &w2, n_tokens, d, f);
    let s = channel_scales(E4M3, &h, n_tokens, f);
    println!("tokens={n_tokens}, channels={f}, outlier channel 3 scale s={}", s[3]);

    // fold: w̃1 = s·w1 and w̃3 = s⁻¹·w3 — the inference-time form
    let mut w1f = w1.clone();
    let mut w3f = w3.clone();
    fold_scales(&mut w1f, &mut w3f, &[s.clone()], d, f).unwrap();

    // the §4.4 claim, checked bitwise: the folded plain-SwiGLU product
    // IS the per-channel-scaled product, exactly (pow2 multiplication
    // commutes with f32 rounding)
    let hf = swiglu_products(&xs, &w1f, &w2, n_tokens, d, f);
    let mut mismatches = 0usize;
    for t in 0..n_tokens {
        for j in 0..f {
            let scaled = h[t * f + j] * s[j];
            if scaled.to_bits() != hf[t * f + j].to_bits() {
                mismatches += 1;
            }
        }
    }
    println!(
        "folded SwiGLU vs per-channel-scaled SwiGLU: {mismatches} bit mismatches \
         over {} products",
        n_tokens * f
    );
    assert_eq!(mismatches, 0, "pow2 folding must be bit-exact");
    println!(
        "per-channel scaling (Smooth-SwiGLU): all channels keep full E4M3 resolution — \
         zero inference cost after folding"
    );
}
