//! FP8 Adam moments demo (paper §5): train the same model with all
//! four standard-FP8 moment format combinations plus the FP32
//! baseline, then show the memory side: real packed-u8 checkpoint
//! sizes and the Table 4 device-memory model.
//!
//! ```text
//! cargo run --release --example fp8_optimizer_demo [steps]
//! ```

use std::sync::Arc;

use anyhow::Result;
use fp8_trainer::checkpoint::{Dtype, Writer};
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{print_summary, run_curve};
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::optimizer::{MemoryModel, MomentStore};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::json::obj;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let rt = Arc::new(Runtime::new("artifacts")?);

    // --- convergence across moment formats (paper Fig. 5)
    let base = TrainConfig {
        size: "s1m".into(),
        steps,
        warmup_steps: 20,
        lr: 5e-4,
        out_dir: "runs/fp8_optimizer_demo".into(),
        ..Default::default()
    };
    let mut curves = Vec::new();
    for recipe in [
        "fp8_smooth", // fp32 moments baseline
        "fp8_adam_e4m3_e5m2",
        "fp8_adam_e4m3_e4m3",
        "fp8_adam_e5m2_e5m2",
        "fp8_adam_e5m2_e4m3",
    ] {
        println!("running {recipe} ...");
        curves.push(run_curve(&rt, TrainConfig { recipe: recipe.into(), ..base.clone() }, 10, 5)?);
    }
    print_summary("Adam moment formats (Fig. 5)", &curves);

    // --- memory: measured checkpoint bytes for the winning combo
    let cfg = TrainConfig { recipe: "fp8_full".into(), steps: 3, ..base.clone() };
    let mut t = Trainer::new(rt, cfg)?;
    for _ in 0..3 {
        t.step()?;
    }
    let (m, v) = t.moments_flat(); // gather the ZeRO-1 moment shards
    let n = m.len();
    let mut w32 = Writer::new(&obj(vec![]));
    w32.tensor("m", Dtype::F32, &m).tensor("v", Dtype::F32, &v);
    let mut w8 = Writer::new(&obj(vec![]));
    w8.tensor("m", Dtype::E4M3, &m).tensor("v", Dtype::E5M2, &v);
    println!(
        "\nmoment storage for {n} params: FP32 {} KiB -> FP8 {} KiB ({:.1}x smaller, real bytes)",
        w32.size_bytes() / 1024,
        w8.size_bytes() / 1024,
        w32.size_bytes() as f64 / w8.size_bytes() as f64
    );

    // --- the Table 4 device model at paper scale
    let base_mem = MemoryModel {
        params: 7_000_000_000,
        master_bytes_per_param: 4.0,
        m_store: MomentStore::F32,
        v_store: MomentStore::F32,
        dp_workers: 8,
        weight_bytes_per_param: 2.0,
        grad_bytes_per_param: 2.0,
    };
    let ours = MemoryModel {
        master_bytes_per_param: 2.0,
        m_store: MomentStore::from_name("e4m3"),
        v_store: MomentStore::from_name("e5m2"),
        ..base_mem.clone()
    };
    println!(
        "7B/8-worker model-state memory: {:.1} GB/HPU -> {:.1} GB/HPU (paper: 63.25 -> 44.08)",
        base_mem.total_bytes_per_worker() / 1e9,
        ours.total_bytes_per_worker() / 1e9
    );
    Ok(())
}
