//! End-to-end driver: train the ~100M-parameter preset with the
//! paper's full FP8 scheme for a few hundred steps on the synthetic
//! corpus, logging the loss curve — the repo's proof that all layers
//! compose (Pallas kernels → JAX graph → HLO artifact → PJRT runtime →
//! Rust coordinator with delayed scaling, all-reduce, FP8 Adam).
//!
//! ```text
//! cargo run --release --example train_e2e [steps] [recipe]
//! ```
//! Results land in runs/m100_e2e/ (metrics.jsonl + loss.csv) and are
//! recorded in EXPERIMENTS.md.

use std::sync::Arc;

use anyhow::Result;
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{print_summary, run_curve, write_curves_csv};
use fp8_trainer::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let recipe = args.get(1).cloned().unwrap_or_else(|| "fp8_full".to_string());

    let rt = Arc::new(Runtime::new("artifacts")?);
    let cfg = TrainConfig {
        size: "m100".into(),
        recipe,
        steps,
        warmup_steps: (steps / 10).max(10),
        lr: 3e-4,
        weight_decay: 0.1,
        out_dir: "runs/m100_e2e".into(),
        ..Default::default()
    };

    println!(
        "e2e: m100 ({}M params) / {} for {} steps — this is CPU XLA, expect minutes",
        97, cfg.recipe, steps
    );
    let curve = run_curve(&rt, cfg, 5, 0)?;
    print_summary("m100 end-to-end", std::slice::from_ref(&curve));
    std::fs::create_dir_all("runs/m100_e2e")?;
    write_curves_csv("runs/m100_e2e/loss.csv", std::slice::from_ref(&curve))?;
    println!(
        "loss {:.4} -> {:.4} over {} steps ({:.2} s/step); curve at runs/m100_e2e/loss.csv",
        curve.rows.first().map(|r| r.1).unwrap_or(f32::NAN),
        curve.final_loss(),
        curve.rows.last().map(|r| r.0 + 1).unwrap_or(0),
        curve.mean_step_s,
    );
    assert!(
        curve.final_loss() < curve.rows.first().map(|r| r.1).unwrap_or(f32::NAN),
        "loss must decrease over the run"
    );
    Ok(())
}
