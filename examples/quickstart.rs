//! Quickstart: load an AOT artifact, train a tiny model for 30 steps
//! with the paper's full FP8 scheme, evaluate, save a checkpoint.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use anyhow::Result;
use fp8_trainer::checkpoint::{Checkpoint, Dtype, Writer};
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::json::{obj, Json};

fn main() -> Result<()> {
    // 1. runtime over the artifacts directory (PJRT CPU client)
    let rt = Arc::new(Runtime::new("artifacts")?);

    // 2. a training config: tiny model, FP8(2) recipe — Smooth-SwiGLU
    //    + E4M3/E5M2 Adam moments, exactly the paper's scheme
    let cfg = TrainConfig {
        size: "tiny".into(),
        recipe: "fp8_full".into(),
        steps: 30,
        warmup_steps: 5,
        lr: 1e-3,
        out_dir: "runs/quickstart".into(),
        ..Default::default()
    };

    // 3. train
    let mut t = Trainer::new(rt, cfg)?;
    println!(
        "model: {} parameters, {} FP8 scale sites",
        t.params.total_elems(),
        t.scale_mgr.n_sites()
    );
    let first = t.step()?;
    println!("step 0: loss {:.4} (≈ ln(vocab) = {:.4})", first.loss, (256f32).ln());
    for _ in 1..30 {
        let o = t.step()?;
        if o.step % 10 == 0 {
            println!(
                "step {:2}: loss {:.4}, grad-norm {:.3}, verdict {:?}",
                o.step, o.loss, o.grad_norm, o.verdict
            );
        }
    }

    // 4. the delayed-scaling state the Rust side owns
    let scales = t.scale_mgr.scales();
    println!("first few delayed scales: {:?}", &scales[..4.min(scales.len())]);

    // 5. checkpoint with real-u8 FP8 moment storage + reload
    let meta = obj(vec![("example", Json::Str("quickstart".into()))]);
    let mut w = Writer::new(&meta);
    let (m, v) = t.moments_flat(); // gather the ZeRO-1 moment shards
    w.tensor("adam.m", Dtype::E4M3, &m);
    w.tensor("adam.v", Dtype::E5M2, &v);
    let path = std::path::Path::new("runs/quickstart/moments.ckpt");
    let bytes = w.finish(path)?;
    let per_moment = bytes as f64 / (2 * m.len()) as f64;
    println!(
        "FP8 moment checkpoint: {} bytes (~{per_moment:.2} B per moment vs 4.0 for FP32)",
        bytes
    );
    let back = Checkpoint::load(path)?;
    assert_eq!(back.tensor("adam.m")?.len(), m.len());
    println!("quickstart OK");
    Ok(())
}
