//! Divergence demo — the paper's core narrative in one binary:
//!
//! 1. standard FP8 (delayed per-tensor scaling of the SwiGLU output)
//!    destabilizes once an aligned outlier channel is present;
//! 2. the same run with **Smooth-SwiGLU** (per-channel JIT scales)
//!    stays healthy;
//! 3. so does FP8 with the w3 input left in BF16 (the paper's
//!    diagnostic config, Fig. 3).
//!
//! The outlier channel is seeded at init (compressed-time analog of
//! the paper's 200B-token Theorem-1 alignment — see DESIGN.md).
//!
//! ```text
//! cargo run --release --example divergence_demo [steps]
//! ```

use std::sync::Arc;

use anyhow::Result;
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::runner::{print_summary, run_curve, write_curves_csv};
use fp8_trainer::runtime::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let rt = Arc::new(Runtime::new("artifacts")?);

    let base = TrainConfig {
        size: "s1m".into(),
        steps,
        warmup_steps: 20,
        lr: 8e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 3.0,
        skip_nonfinite_updates: false,
        out_dir: "runs/divergence_demo".into(),
        ..Default::default()
    };

    let mut curves = Vec::new();
    for recipe in ["fp8_nosat", "fp8", "fp8_smooth", "fp8_noq3", "bf16"] {
        let cfg = TrainConfig { recipe: recipe.into(), ..base.clone() };
        println!("running {recipe} ...");
        curves.push(run_curve(&rt, cfg, 5, 10)?);
    }
    print_summary("divergence demo (seeded outlier channel)", &curves);
    std::fs::create_dir_all("runs/divergence_demo")?;
    write_curves_csv("runs/divergence_demo/curves.csv", &curves)?;

    let nosat = &curves[0];
    let smooth = &curves[2];
    println!(
        "\nstandard FP8 (NaN overflow): diverged at {:?}; Smooth-SwiGLU: {:?} — the paper's fix.",
        nosat.diverged_at, smooth.diverged_at
    );
    Ok(())
}
