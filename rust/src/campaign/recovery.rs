//! Divergence-recovery backoff: what "re-enter with a perturbed
//! scaling policy" concretely means.
//!
//! The delayed-scaling failure mode is a fresh amax spike quantized
//! with a scale chosen from the pre-spike history. Two knobs attack
//! exactly that after a rollback:
//!
//! * **scale backoff** — `margin_pow2` grows by
//!   `margin_backoff × attempt`, leaving more headroom below the
//!   format max so the replayed spike saturates instead of
//!   overflowing (the paper's FP8(2)-style mitigation direction);
//! * **shorter amax history** — the window shrinks geometrically
//!   (`history_shrink ^ attempt`, floored at 2), so stale pre-spike
//!   amaxes stop dictating the scale sooner.
//!
//! Backoff is always computed from the *base* policy the campaign
//! started under — attempts don't compound on each other, so attempt
//! k is deterministic regardless of the rollback history that led to
//! it.

use crate::config::TrainConfig;
use crate::scaling::Policy;

/// The campaign's recovery budget and backoff shape (built from the
/// `campaign.*` config keys).
#[derive(Clone, Copy, Debug)]
pub struct RecoveryPolicy {
    /// give up (orderly abort, not an error) after this many rollbacks
    pub max_recoveries: usize,
    /// pow2 margin added per attempt (scale backoff)
    pub margin_backoff: i32,
    /// geometric amax-window shrink per attempt, in (0, 1]
    pub history_shrink: f64,
}

impl RecoveryPolicy {
    /// Extract the recovery knobs from a training config.
    pub fn from_cfg(cfg: &TrainConfig) -> Self {
        Self {
            max_recoveries: cfg.max_recoveries,
            margin_backoff: cfg.recovery_margin_backoff,
            history_shrink: cfg.recovery_history_shrink,
        }
    }

    /// The scaling policy for recovery attempt `level` (1-based),
    /// derived from the campaign's base policy.
    ///
    /// Invariants: `level = 0` returns `base` unchanged; the history
    /// length never drops below 2 (a length-1 window would degenerate
    /// delayed scaling into just-in-time scaling and hide the
    /// mechanism under study); the margin grows linearly in `level`.
    pub fn scaling_policy(&self, base: Policy, level: usize) -> Policy {
        let shrink = self.history_shrink.powi(level as i32);
        let history_len = ((base.history_len as f64 * shrink).floor() as usize).max(2);
        Policy {
            history_len,
            margin_pow2: base.margin_pow2 + self.margin_backoff * level as i32,
            ..base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pol() -> RecoveryPolicy {
        RecoveryPolicy { max_recoveries: 4, margin_backoff: 1, history_shrink: 0.5 }
    }

    #[test]
    fn level_zero_is_identity() {
        let base = Policy { history_len: 16, margin_pow2: 1, ..Default::default() };
        let p = pol().scaling_policy(base, 0);
        assert_eq!(p.history_len, 16);
        assert_eq!(p.margin_pow2, 1);
    }

    #[test]
    fn backoff_escalates_and_floors() {
        let base = Policy { history_len: 16, margin_pow2: 0, ..Default::default() };
        let p1 = pol().scaling_policy(base, 1);
        let p2 = pol().scaling_policy(base, 2);
        let p9 = pol().scaling_policy(base, 9);
        assert_eq!(p1.history_len, 8);
        assert_eq!(p1.margin_pow2, 1);
        assert_eq!(p2.history_len, 4);
        assert_eq!(p2.margin_pow2, 2);
        assert_eq!(p9.history_len, 2, "window floors at 2");
        assert_eq!(p9.margin_pow2, 9);
    }

    #[test]
    fn attempts_do_not_compound() {
        // attempt k from base must not depend on attempts < k
        let base = Policy { history_len: 12, margin_pow2: 0, ..Default::default() };
        let direct = pol().scaling_policy(base, 3);
        let via = pol().scaling_policy(base, 3); // same call — determinism
        assert_eq!(direct.history_len, via.history_len);
        assert_eq!(direct.margin_pow2, via.margin_pow2);
        assert_eq!(direct.history_len, ((12f64 * 0.125).floor() as usize).max(2));
    }
}
