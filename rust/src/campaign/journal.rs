//! The campaign journal: an append-only, machine-readable JSONL log
//! of every operationally significant event (snapshots, divergence
//! trips, rollbacks, recoveries, completion).
//!
//! One JSON object per line, always carrying `event`, `step`, and
//! `unix_ms`; event-specific fields ride alongside. Append-only means
//! a resumed campaign extends the same file — the journal is the
//! single chronological record of the whole campaign across process
//! restarts, which is what the `status` CLI subcommand and the
//! §Campaigns analysis read.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::metrics::JsonlSink;
use crate::util::json::Json;

/// Append-only writer for one campaign's journal file.
pub struct Journal {
    sink: JsonlSink,
    path: PathBuf,
}

impl Journal {
    /// Open (creating or appending to) the journal at `path`.
    ///
    /// If a previous process crashed mid-flush, the file ends in a
    /// torn line with no newline; a plain append would glue the next
    /// event onto that fragment and corrupt *two* records. Open
    /// repairs this by terminating an unterminated tail first, so the
    /// tear stays confined to the one line being written at crash
    /// time (which [`read`] then skips).
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        repair_torn_tail(&path)?;
        let sink = JsonlSink::create(&path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(Self { sink, path })
    }

    /// The journal file location.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one event. `fields` are event-specific extras; `event`,
    /// `step`, and a wall-clock `unix_ms` stamp are always present.
    pub fn record(&mut self, event: &str, step: usize, fields: Vec<(&str, Json)>) -> Result<()> {
        let unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        let mut all = vec![
            ("event", Json::Str(event.to_string())),
            ("step", Json::Num(step as f64)),
            ("unix_ms", Json::Num(unix_ms)),
        ];
        all.extend(fields);
        self.sink.record(all)?;
        Ok(())
    }

    /// Flush buffered lines to disk (call after every event that a
    /// crash must not lose — the campaign driver flushes on snapshot
    /// and recovery boundaries).
    pub fn flush(&mut self) -> Result<()> {
        self.sink.flush()?;
        Ok(())
    }
}

/// Terminate an unterminated final line (crash tear) so appends can
/// never glue onto a fragment. No-op on a missing/empty/clean file.
fn repair_torn_tail(path: &Path) -> Result<()> {
    use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
    let needs_newline = match std::fs::File::open(path) {
        Ok(mut f) => {
            let len = f.metadata().map(|m| m.len()).unwrap_or(0);
            if len == 0 {
                false
            } else {
                let mut b = [0u8; 1];
                f.seek(SeekFrom::End(-1)).is_ok()
                    && f.read_exact(&mut b).is_ok()
                    && b[0] != b'\n'
            }
        }
        Err(_) => false, // no file yet
    };
    if needs_newline {
        std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .and_then(|mut f| f.write_all(b"\n"))
            .with_context(|| format!("repairing torn journal tail {}", path.display()))?;
    }
    Ok(())
}

/// Parse a journal file back into its event objects, in order.
///
/// Unparseable lines are skipped rather than erroring: the journal is
/// written one line per event with [`Journal::open`] repairing torn
/// tails, so a malformed line can only be the fragment of a line
/// that was being written when a process died — and `status` must
/// stay usable after the very crashes the campaign layer exists to
/// survive. All intact events around a tear are returned.
pub fn read<P: AsRef<Path>>(path: P) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading journal {}", path.as_ref().display()))?;
    Ok(text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| Json::parse(l).ok())
        .collect())
}

/// Count events of one kind (`"snapshot"`, `"recovery"`, …) in a
/// parsed journal.
pub fn count(events: &[Json], kind: &str) -> usize {
    events
        .iter()
        .filter(|e| e.get("event").and_then(|v| v.as_str()) == Some(kind))
        .count()
}

/// The last event of one kind, if any.
pub fn last<'a>(events: &'a [Json], kind: &str) -> Option<&'a Json> {
    events
        .iter()
        .rev()
        .find(|e| e.get("event").and_then(|v| v.as_str()) == Some(kind))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_counts() {
        let dir = std::env::temp_dir().join("fp8_campaign_journal_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("journal.jsonl");
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("campaign_start", 0, vec![]).unwrap();
            j.record("snapshot", 10, vec![("reason", Json::Str("periodic".into()))]).unwrap();
            j.record("divergence", 17, vec![("injected", Json::Bool(true))]).unwrap();
            j.record("recovery", 10, vec![("attempt", Json::Num(1.0))]).unwrap();
            j.record("snapshot", 20, vec![("reason", Json::Str("final".into()))]).unwrap();
            j.flush().unwrap();
        }
        // append-only across reopen (the resume case)
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("complete", 20, vec![]).unwrap();
            j.flush().unwrap();
        }
        let events = read(&path).unwrap();
        assert_eq!(events.len(), 6);
        assert_eq!(count(&events, "snapshot"), 2);
        assert_eq!(count(&events, "recovery"), 1);
        let lastsnap = last(&events, "snapshot").unwrap();
        assert_eq!(lastsnap.usize_of("step").unwrap(), 20);
        assert_eq!(lastsnap.str_of("reason").unwrap(), "final");
        assert!(events.iter().all(|e| e.get("unix_ms").is_some()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_repaired_and_skipped() {
        let dir = std::env::temp_dir().join("fp8_campaign_journal_torn");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("journal.jsonl");
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("campaign_start", 0, vec![]).unwrap();
            j.record("snapshot", 5, vec![]).unwrap();
            j.flush().unwrap();
        }
        // simulate a crash mid-flush: a torn, newline-less final line
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"event\":\"snapsh").unwrap();
        }
        // status stays usable: intact events readable, tear skipped
        let events = read(&path).unwrap();
        assert_eq!(events.len(), 2);
        // reopen (resume path) must not glue onto the fragment
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("resume", 5, vec![]).unwrap();
            j.flush().unwrap();
        }
        let events = read(&path).unwrap();
        assert_eq!(events.len(), 3, "post-crash append must be its own intact line");
        assert_eq!(count(&events, "resume"), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
