//! The campaign journal: an append-only, machine-readable JSONL log
//! of every operationally significant event (snapshots, divergence
//! trips, rollbacks, recoveries, reshards, completion).
//!
//! One JSON object per line, always carrying `event`, `step`, and
//! `unix_ms`; event-specific fields ride alongside. Append-only means
//! a resumed campaign extends the same file — the journal is the
//! single chronological record of the whole campaign across process
//! restarts, which is what the `status`/`fleet` CLI subcommands and
//! the §Campaigns analysis read.
//!
//! The on-disk format is specified in `docs/JOURNAL.md` (framing,
//! torn-tail semantics, compatibility rules, and a field-by-field
//! schema per event kind — `scripts/check_journal_docs.sh` keeps that
//! spec complete). Consumers go through [`stream`]: a trillion-token
//! campaign's journal does not fit in memory, so every read path here
//! is an event-at-a-time parse in O(1) memory — [`read`] is a
//! convenience that collects the stream, [`tail`] seeks from the end.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::metrics::JsonlSink;
use crate::util::json::Json;

/// Append-only writer for one campaign's journal file.
pub struct Journal {
    sink: JsonlSink,
    path: PathBuf,
}

impl Journal {
    /// Open (creating or appending to) the journal at `path`.
    ///
    /// If a previous process crashed mid-flush, the file ends in a
    /// torn line with no newline; a plain append would glue the next
    /// event onto that fragment and corrupt *two* records. Open
    /// repairs this by terminating an unterminated tail first, so the
    /// tear stays confined to the one line being written at crash
    /// time (which the [`stream`] readers then skip and count). The
    /// repair itself is journaled as a `tail_repaired` event — the
    /// on-disk record of "a tear happened here", so an elevated
    /// skipped-line count in `status` can be dated.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let repaired = repair_torn_tail(&path)?;
        let sink = JsonlSink::create(&path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        let mut j = Self { sink, path };
        if repaired {
            // step is unknowable at open time (the snapshot has not
            // been read yet) — 0 by convention, see docs/JOURNAL.md
            j.record("tail_repaired", 0, vec![])?;
            j.flush()?;
        }
        Ok(j)
    }

    /// The journal file location.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one event. `fields` are event-specific extras; `event`,
    /// `step`, and a wall-clock `unix_ms` stamp are always present.
    pub fn record(&mut self, event: &str, step: usize, fields: Vec<(&str, Json)>) -> Result<()> {
        let unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        let mut all = vec![
            ("event", Json::Str(event.to_string())),
            ("step", Json::Num(step as f64)),
            ("unix_ms", Json::Num(unix_ms)),
        ];
        all.extend(fields);
        self.sink.record(all)?;
        Ok(())
    }

    /// Flush buffered lines to disk (call after every event that a
    /// crash must not lose — the campaign driver flushes on snapshot
    /// and recovery boundaries).
    pub fn flush(&mut self) -> Result<()> {
        self.sink.flush()?;
        Ok(())
    }
}

/// Terminate an unterminated final line (crash tear) so appends can
/// never glue onto a fragment. No-op on a missing/empty/clean file;
/// returns whether a repair was performed.
fn repair_torn_tail(path: &Path) -> Result<bool> {
    use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
    let needs_newline = match std::fs::File::open(path) {
        Ok(mut f) => {
            let len = f.metadata().map(|m| m.len()).unwrap_or(0);
            if len == 0 {
                false
            } else {
                let mut b = [0u8; 1];
                f.seek(SeekFrom::End(-1)).is_ok()
                    && f.read_exact(&mut b).is_ok()
                    && b[0] != b'\n'
            }
        }
        Err(_) => false, // no file yet
    };
    if needs_newline {
        std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .and_then(|mut f| f.write_all(b"\n"))
            .with_context(|| format!("repairing torn journal tail {}", path.display()))?;
    }
    Ok(needs_newline)
}

pub mod stream {
    //! Incremental, O(1)-memory journal reader.
    //!
    //! [`JournalStream`] parses one event at a time off any
    //! [`BufRead`], never holding more than one line in memory. The
    //! line buffer is bounded ([`MAX_LINE_BYTES`]): a line beyond the
    //! bound is an explicit [`OversizedLine`] error rather than an
    //! unbounded allocation, because a journal whose lines do not fit
    //! the bound is not a journal (the writer emits events of a few
    //! hundred bytes; the only multi-KiB record is the config echo).
    //!
    //! Damage tolerance is unified with the writer's torn-tail repair
    //! ([`super::Journal::open`]): a line that does not parse — a
    //! crash tear, a fragment from a mid-record power loss — is
    //! skipped and *counted* ([`JournalStream::skipped`]), never
    //! fatal, so `status` stays usable after the very crashes the
    //! campaign layer exists to survive, while the operator can still
    //! tell a healthy journal (0–1 skips across the campaign) from a
    //! damaged one.

    use std::collections::VecDeque;
    use std::fs::File;
    use std::io::{BufRead, BufReader, Read as _, Seek as _, SeekFrom};
    use std::path::Path;

    use anyhow::{Context, Result};

    use crate::util::json::Json;

    /// Upper bound on one journal line. The writer's largest record is
    /// the `campaign_start` config echo (a few KiB); 1 MiB leaves two
    /// orders of magnitude of headroom while keeping a garbage file
    /// (or a binary accidentally pointed at) from ballooning memory.
    pub const MAX_LINE_BYTES: usize = 1 << 20;

    /// Block size for the backward newline scan in [`tail`](super::tail).
    const TAIL_BLOCK: usize = 64 * 1024;

    /// A journal line exceeded the per-line buffer bound — the file is
    /// not a journal (or is corrupt beyond line-level damage). Typed
    /// so callers can distinguish "refuse this file" from I/O errors.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct OversizedLine {
        /// 1-based line number of the offending line.
        pub line: usize,
        /// Bytes seen before giving up (>= `limit`).
        pub len_at_least: usize,
        /// The configured bound the line exceeded.
        pub limit: usize,
    }

    impl std::fmt::Display for OversizedLine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(
                f,
                "journal line {} exceeds the {}-byte line bound ({}+ bytes) — \
                 not a journal, or corrupt beyond line-level damage",
                self.line, self.limit, self.len_at_least
            )
        }
    }

    impl std::error::Error for OversizedLine {}

    /// Event-at-a-time journal parser over any [`BufRead`], O(1)
    /// memory: one reusable line buffer, bounded by the configured
    /// line limit. See the [module docs](self) for the damage model.
    pub struct JournalStream<R: BufRead> {
        r: R,
        buf: Vec<u8>,
        max_line: usize,
        peak_line: usize,
        lines: usize,
        skipped: usize,
        done: bool,
    }

    impl JournalStream<BufReader<File>> {
        /// Stream the journal file at `path` from the beginning.
        pub fn from_path<P: AsRef<Path>>(path: P) -> Result<Self> {
            let f = File::open(&path)
                .with_context(|| format!("reading journal {}", path.as_ref().display()))?;
            Ok(Self::new(BufReader::new(f)))
        }
    }

    impl<R: BufRead> JournalStream<R> {
        /// Stream events off `r` with the default [`MAX_LINE_BYTES`]
        /// line bound.
        pub fn new(r: R) -> Self {
            Self::with_max_line(r, MAX_LINE_BYTES)
        }

        /// [`new`](JournalStream::new) with an explicit line bound
        /// (tests exercise the oversized refusal without writing a
        /// megabyte).
        pub fn with_max_line(r: R, max_line: usize) -> Self {
            Self { r, buf: Vec::new(), max_line, peak_line: 0, lines: 0, skipped: 0, done: false }
        }

        /// The next parsed event, or `Ok(None)` at end of input.
        ///
        /// Blank lines are ignored; a non-blank line that is not valid
        /// JSON (torn tail, mid-record crash fragment, invalid UTF-8)
        /// is skipped and counted in [`skipped`](Self::skipped) —
        /// identical acceptance to the historical whole-file reader. A
        /// line beyond the bound returns an [`OversizedLine`] error
        /// and ends the stream.
        pub fn next_event(&mut self) -> Result<Option<Json>> {
            if self.done {
                return Ok(None);
            }
            loop {
                if !self.fill_line()? {
                    self.done = true;
                    return Ok(None);
                }
                self.lines += 1;
                self.peak_line = self.peak_line.max(self.buf.len());
                let Ok(s) = std::str::from_utf8(&self.buf) else {
                    self.skipped += 1;
                    continue;
                };
                if s.trim().is_empty() {
                    continue;
                }
                match Json::parse(s) {
                    Ok(v) => return Ok(Some(v)),
                    Err(_) => {
                        self.skipped += 1;
                        continue;
                    }
                }
            }
        }

        /// Non-blank lines skipped so far because they did not parse
        /// (torn tails, crash fragments). A healthy journal shows 0;
        /// one tear per hard crash is the expected worst case — more
        /// means damage (see docs/JOURNAL.md).
        pub fn skipped(&self) -> usize {
            self.skipped
        }

        /// Lines consumed so far (parsed + skipped + blank).
        pub fn lines_seen(&self) -> usize {
            self.lines
        }

        /// Largest single line seen, in bytes — the stream's resident
        /// footprint proxy (the only growing allocation is the line
        /// buffer, and it is bounded by the line limit).
        pub fn peak_line_bytes(&self) -> usize {
            self.peak_line
        }

        /// Pull one line (sans newline) into `self.buf`. Returns false
        /// at clean EOF with no pending bytes; a final newline-less
        /// fragment is returned as a line (the caller's parse-or-skip
        /// handles it, matching the writer's torn-tail model).
        fn fill_line(&mut self) -> Result<bool> {
            self.buf.clear();
            loop {
                let chunk = self.r.fill_buf().context("reading journal stream")?;
                if chunk.is_empty() {
                    return Ok(!self.buf.is_empty());
                }
                let (take, terminated) = match chunk.iter().position(|&b| b == b'\n') {
                    Some(i) => (i, true),
                    None => (chunk.len(), false),
                };
                if self.buf.len() + take > self.max_line {
                    let err = OversizedLine {
                        line: self.lines + 1,
                        len_at_least: self.buf.len() + take,
                        limit: self.max_line,
                    };
                    self.done = true;
                    return Err(anyhow::Error::new(err));
                }
                self.buf.extend_from_slice(&chunk[..take]);
                self.r.consume(take + usize::from(terminated));
                if terminated {
                    return Ok(true);
                }
            }
        }
    }

    impl<R: BufRead> Iterator for JournalStream<R> {
        type Item = Result<Json>;

        fn next(&mut self) -> Option<Result<Json>> {
            self.next_event().transpose()
        }
    }

    /// Byte offset of the start of the `k`-th-from-last line candidate
    /// (a trailing newline-less fragment counts as one), found by
    /// scanning backward in [`TAIL_BLOCK`] chunks — work proportional
    /// to the tail scanned, not the file size. 0 when the file holds
    /// fewer than `k` lines.
    fn offset_of_last_lines(f: &File, len: u64, k: usize) -> Result<u64> {
        if len == 0 || k == 0 {
            return Ok(0);
        }
        let mut r = f;
        // a newline as the very last byte terminates the final line —
        // it starts no candidate, so the scan begins just before it
        let mut b = [0u8; 1];
        r.seek(SeekFrom::Start(len - 1)).context("journal tail seek")?;
        r.read_exact(&mut b).context("journal tail read")?;
        let mut pos = if b[0] == b'\n' { len - 1 } else { len };
        let mut found = 0usize;
        let mut block = vec![0u8; TAIL_BLOCK];
        while pos > 0 {
            let start = pos.saturating_sub(TAIL_BLOCK as u64);
            let n = (pos - start) as usize;
            r.seek(SeekFrom::Start(start)).context("journal tail seek")?;
            r.read_exact(&mut block[..n]).context("journal tail read")?;
            for i in (0..n).rev() {
                if block[i] == b'\n' {
                    found += 1;
                    if found == k {
                        return Ok(start + i as u64 + 1);
                    }
                }
            }
            pos = start;
        }
        Ok(0) // fewer than k lines: the whole file is the tail
    }

    /// The last `n` parsed events of the journal at `path`, seeking
    /// from the end — cost scales with the tail read, not the file
    /// size, which is what lets `status` answer instantly on a
    /// trillion-token campaign's journal.
    ///
    /// Starts `n+1` line candidates from the end and doubles the
    /// window while unparseable/blank lines leave fewer than `n`
    /// events (bounded by walking back to the start of the file), so
    /// the result is exactly `min(n, total events)` events in
    /// chronological order. The returned
    /// [`skipped`](super::ReadOutcome::skipped) counts only the region
    /// scanned.
    pub fn tail<P: AsRef<Path>>(path: P, n: usize) -> Result<super::ReadOutcome> {
        let f = File::open(&path)
            .with_context(|| format!("reading journal {}", path.as_ref().display()))?;
        let len = f.metadata().context("journal metadata")?.len();
        if n == 0 || len == 0 {
            return Ok(super::ReadOutcome::default());
        }
        let mut want = n + 1;
        loop {
            let start = offset_of_last_lines(&f, len, want)?;
            let mut r = &f;
            r.seek(SeekFrom::Start(start)).context("journal tail seek")?;
            let mut s = JournalStream::new(BufReader::new(r));
            let mut events: VecDeque<Json> = VecDeque::with_capacity(n.min(1024));
            while let Some(e) = s.next_event()? {
                if events.len() == n {
                    events.pop_front();
                }
                events.push_back(e);
            }
            if events.len() >= n || start == 0 {
                return Ok(super::ReadOutcome {
                    events: events.into(),
                    skipped: s.skipped(),
                });
            }
            want = want.saturating_mul(2);
        }
    }
}

/// A fully-collected journal read: the parsed events plus the count
/// of non-blank lines that did not parse (torn tails, crash
/// fragments) — the damage signal `status` and the fleet aggregator
/// surface to operators.
#[derive(Clone, Debug, Default)]
pub struct ReadOutcome {
    /// Parsed events in file (= chronological) order.
    pub events: Vec<Json>,
    /// Non-blank unparseable lines encountered.
    pub skipped: usize,
}

/// Parse a journal file back into its event objects, in order,
/// reporting how many damaged lines were skipped on the way.
///
/// Unparseable lines are skipped rather than erroring: the journal is
/// written one line per event with [`Journal::open`] repairing torn
/// tails, so a malformed line can only be the fragment of a line that
/// was being written when a process died — and `status` must stay
/// usable after the very crashes the campaign layer exists to
/// survive. All intact events around a tear are returned. Collects
/// the [`stream`] parser, so memory is O(events), never O(file) —
/// callers that only fold (status, fleet) should stream instead.
pub fn read_counted<P: AsRef<Path>>(path: P) -> Result<ReadOutcome> {
    let mut s = stream::JournalStream::from_path(&path)?;
    let mut events = Vec::new();
    while let Some(e) = s.next_event()? {
        events.push(e);
    }
    Ok(ReadOutcome { events, skipped: s.skipped() })
}

/// [`read_counted`] without the damage count — the historical
/// convenience signature most tests use.
pub fn read<P: AsRef<Path>>(path: P) -> Result<Vec<Json>> {
    Ok(read_counted(path)?.events)
}

/// The last `n` events, seeking from the end of the file — see
/// [`stream::tail`].
pub fn tail<P: AsRef<Path>>(path: P, n: usize) -> Result<ReadOutcome> {
    stream::tail(path, n)
}

/// Count events of one kind (`"snapshot"`, `"recovery"`, …) in a
/// parsed journal.
pub fn count(events: &[Json], kind: &str) -> usize {
    events
        .iter()
        .filter(|e| e.get("event").and_then(|v| v.as_str()) == Some(kind))
        .count()
}

/// The last event of one kind, if any.
pub fn last<'a>(events: &'a [Json], kind: &str) -> Option<&'a Json> {
    events
        .iter()
        .rev()
        .find(|e| e.get("event").and_then(|v| v.as_str()) == Some(kind))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_counts() {
        let dir = std::env::temp_dir().join("fp8_campaign_journal_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("journal.jsonl");
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("campaign_start", 0, vec![]).unwrap();
            j.record("snapshot", 10, vec![("reason", Json::Str("periodic".into()))]).unwrap();
            j.record("divergence", 17, vec![("injected", Json::Bool(true))]).unwrap();
            j.record("recovery", 10, vec![("attempt", Json::Num(1.0))]).unwrap();
            j.record("snapshot", 20, vec![("reason", Json::Str("final".into()))]).unwrap();
            j.flush().unwrap();
        }
        // append-only across reopen (the resume case)
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("complete", 20, vec![]).unwrap();
            j.flush().unwrap();
        }
        let out = read_counted(&path).unwrap();
        let events = out.events;
        assert_eq!(events.len(), 6);
        assert_eq!(out.skipped, 0, "clean journal reads with zero skips");
        assert_eq!(count(&events, "snapshot"), 2);
        assert_eq!(count(&events, "recovery"), 1);
        let lastsnap = last(&events, "snapshot").unwrap();
        assert_eq!(lastsnap.usize_of("step").unwrap(), 20);
        assert_eq!(lastsnap.str_of("reason").unwrap(), "final");
        assert!(events.iter().all(|e| e.get("unix_ms").is_some()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_repaired_skipped_and_counted() {
        let dir = std::env::temp_dir().join("fp8_campaign_journal_torn");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("journal.jsonl");
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("campaign_start", 0, vec![]).unwrap();
            j.record("snapshot", 5, vec![]).unwrap();
            j.flush().unwrap();
        }
        // simulate a crash mid-flush: a torn, newline-less final line
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"event\":\"snapsh").unwrap();
        }
        // status stays usable: intact events readable, tear skipped
        // AND surfaced in the damage count
        let out = read_counted(&path).unwrap();
        assert_eq!(out.events.len(), 2);
        assert_eq!(out.skipped, 1, "the tear must be counted, not silently dropped");
        // reopen (resume path) must not glue onto the fragment, and
        // must journal the repair
        {
            let mut j = Journal::open(&path).unwrap();
            j.record("resume", 5, vec![]).unwrap();
            j.flush().unwrap();
        }
        let out = read_counted(&path).unwrap();
        assert_eq!(out.events.len(), 4, "post-crash appends are their own intact lines");
        assert_eq!(count(&out.events, "resume"), 1);
        assert_eq!(count(&out.events, "tail_repaired"), 1, "the repair is journaled");
        assert_eq!(out.skipped, 1, "exactly the one tear");
        // a clean reopen does not journal another repair
        {
            let mut j = Journal::open(&path).unwrap();
            j.flush().unwrap();
        }
        assert_eq!(count(&read(&path).unwrap(), "tail_repaired"), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_line_is_a_typed_refusal() {
        use std::io::Cursor;
        let line = format!("{{\"event\":\"x\",\"pad\":\"{}\"}}\n", "y".repeat(256));
        let mut s = stream::JournalStream::with_max_line(Cursor::new(line.into_bytes()), 64);
        let err = s.next_event().unwrap_err();
        let o = err.downcast_ref::<stream::OversizedLine>().expect("typed OversizedLine");
        assert_eq!(o.limit, 64);
        assert!(o.len_at_least >= 64);
        assert_eq!(o.line, 1);
        // the stream ends rather than spinning on the same line
        assert!(s.next_event().unwrap().is_none());
    }

    #[test]
    fn tail_seeks_the_last_n_events() {
        let dir = std::env::temp_dir().join("fp8_campaign_journal_tail");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("journal.jsonl");
        {
            let mut j = Journal::open(&path).unwrap();
            for i in 0..100 {
                j.record("snapshot", i, vec![("reason", Json::Str("periodic".into()))]).unwrap();
            }
            j.flush().unwrap();
        }
        let all = read(&path).unwrap();
        for n in [0, 1, 7, 100, 500] {
            let t = tail(&path, n).unwrap();
            let want = &all[all.len().saturating_sub(n)..];
            assert_eq!(t.events, want, "tail({n}) == last {n} events");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
