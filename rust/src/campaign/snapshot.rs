//! Full-training-state snapshots — everything a bit-exact resume
//! needs, serialized through the extended `checkpoint::` manifest.
//!
//! A [`TrainState`] captures:
//! * **params** — every named parameter tensor, raw f32 (lossless);
//! * **Adam moments** — gathered from the trainer's per-worker ZeRO-1
//!   shards into the flat layout (the chunk-aligned owner map makes
//!   gather/scatter bit-preserving and grid-aligned), then stored
//!   through the chunked exact-FP8 checkpoint sections
//!   ([`Writer::tensor_fp8_exact`]) when the recipe stores moments in
//!   FP8: the moment values lie on per-chunk FP8 grids (the chunked
//!   Adam artifact quantizes its outputs), so they pack at ~1
//!   byte/element *and* restore bit-exactly; recipes with f32 moments
//!   store raw f32. The shard layout itself and the collective
//!   compression config ride in the numerics fingerprint (the
//!   compressed collective's per-chunk scales are JIT — stateless
//!   across steps — so the flag + format is the complete collective
//!   identity);
//! * **delayed-scaling state** — per-site amax ring buffers (in push
//!   order), current scales, and the overflow counter;
//! * **divergence-detector state** — the loss EMA (bit-exact), warmed
//!   flag, and latch;
//! * **positions** — the step counter (which is also the LR-schedule
//!   position and, because the data pipeline is stateless, the entire
//!   data-corpus PRNG cursor together with the recorded corpus seed);
//! * **identity** — recipe/size/seed/topology/schedule config, checked
//!   on [`TrainState::apply_to`] so a resume under a different config
//!   fails loudly instead of silently forking the curve.
//!
//! Contract (pinned by `rust/tests/campaign.rs`): `capture` → `save`
//! → `load` → `apply_to` onto a fresh trainer reproduces the
//! uninterrupted run's loss curve bit-for-bit.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{Checkpoint, Dtype, Writer};
use crate::coordinator::{DetectorState, Trainer};
use crate::fp8::{Fp8Format, E4M3, E5M2};
use crate::scaling::{Policy, ScaleState};
use crate::util::json::{obj, Json};

/// Fallback chunk size for exact-FP8 moment sections, used only when
/// a snapshot's metadata lacks a recorded `moment_chunk` (or when a
/// state is built by hand in tests). Live captures record the actual
/// Adam artifact chunk ([`Trainer::adam_chunk`]) so storage chunks
/// line up with the per-chunk grids the kernel produced regardless of
/// which artifact variant is in use.
pub const MOMENT_CHUNK: usize = 262_144;

/// Snapshot format version (bumped on incompatible layout changes).
/// 1.1: the numerics fingerprint gained the ZeRO-1 shard layout
/// (Adam chunk × dp_workers) and the collective compression config —
/// a resume under a changed sharding or collective setup now refuses
/// instead of forking the curve.
/// 1.2: the fingerprint gained the collective topology (`pods`) and
/// the per-level compression flags
/// (`collective_fp8_intra`/`collective_fp8_inter`) — a resume under a
/// changed pod arrangement refuses.
/// 1.3: the fingerprint gained the gradient bucket schedule
/// (`bucket=b{bucket_bytes}`) — a resume under a changed bucket
/// partition refuses (conservatively: the partition is designed to be
/// bit-invisible, but it changes per-bucket wire framing and the
/// pipeline's dispatch windows, so it is pinned like the topology).
/// 1.4: the fingerprint split in two. The **numerics** term keeps
/// everything the loss curve is a function of — including the logical
/// gradient-stream plan (`streams=s{S}p{Π}`, replacing the physical
/// worker/pod terms) and the absolute Adam chunk grid (`grid=c{…}`,
/// pulled out of the old `shard=` term). The **topology** term
/// (`shard=w…;topo=p…;bucket=b…`, a separate meta field) holds the
/// physical shard/pod/bucket arrangement, which is proven
/// bit-invisible and may be transformed by `campaign resume
/// --reshard`; a plain resume still refuses a topology mismatch, but
/// with an actionable hint instead of a bare refusal.
/// `overlap_comm` is deliberately NOT in either fingerprint — toggling
/// the schedule is proven bit-invisible, so it must never refuse a
/// resume. Older snapshots still load; their fingerprint will not
/// match a newer binary's, so applying them refuses — conservative by
/// design.
/// 1.5: the numerics fingerprint gained the tile-wise GEMM compute
/// path (`gemm=off` or `gemm=t{tile}:w{fmt}:x{fmt}:g{fmt}`) — under an
/// `fp8_gemm` recipe every per-tile pow2 grid is a function of the
/// tile size and the per-operand formats, so a resume under a changed
/// GEMM setup refuses with the term diff naming the `gemm` key.
pub const SNAPSHOT_VERSION: f64 = 1.5;

/// Identity and position metadata of one snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotMeta {
    /// step counter at capture (steps completed; also the LR-schedule
    /// position and the data cursor's step component)
    pub step: usize,
    /// training recipe name (must match on resume)
    pub recipe: String,
    /// model size preset (must match on resume)
    pub size: String,
    /// run seed (must match on resume — parameter init and data
    /// derive from it)
    pub seed: u64,
    /// derived corpus PRNG root — with `step`, the complete
    /// data-corpus cursor (the batcher is stateless)
    pub corpus_seed: u64,
    /// **physical** data-parallel worker count at capture (ZeRO-1
    /// shard count + thread lanes). NOT batch identity since the
    /// logical/physical split — `streams` is; this field is part of
    /// the reshardable topology term and `--reshard` rewrites it
    pub dp_workers: usize,
    /// **logical** gradient-stream count (batch identity, merge
    /// denominator, collective replica count) — pinned for the life of
    /// the campaign; `--reshard` adopts it into the resuming config
    pub streams: usize,
    /// **logical** plan-pod count of the collective reduction tree
    /// (with `streams`, the complete summation-plan identity)
    pub stream_pods: usize,
    /// gradient-accumulation microbatches (part of batch identity)
    pub grad_accum: usize,
    /// total schedule length (the LR curve depends on it)
    pub steps: usize,
    /// warmup length (ditto)
    pub warmup_steps: usize,
    /// *effective* amax window at capture — the base config value, or
    /// the recovery-shrunk one if a rollback re-entered with backoff
    pub amax_history: usize,
    /// effective pow2 scale margin at capture (see `amax_history`)
    pub margin_pow2: i32,
    /// divergence recoveries consumed so far in the campaign
    pub recoveries: usize,
    /// moment storage formats ("f32" | "e4m3" | "e5m2")
    pub m_fmt: String,
    /// see `m_fmt`
    pub v_fmt: String,
    /// chunk size of the exact-FP8 moment sections — the Adam
    /// artifact's quantization granularity at capture time (storage
    /// detail, not identity: apply never validates it, the sections
    /// are self-describing)
    pub moment_chunk: usize,
    /// fingerprint of every remaining numerics-relevant config field
    /// (lr/min_lr_frac/weight_decay/grad_clip as exact f32 bits,
    /// corpus knobs, outlier seeding, non-finite-update policy, base
    /// scaling config, the absolute Adam chunk grid, the logical
    /// stream plan, and the collective compression setup) — compared
    /// wholesale on apply so a resume under any changed numeric
    /// silently forking the curve is impossible
    pub numerics: String,
    /// fingerprint of the **physical** topology at capture
    /// (`shard=w…;topo=p…;bucket=b…`) — the only term `campaign resume
    /// --reshard` may transform; a plain resume refuses a mismatch
    /// with a hint to rerun with the flag
    pub topology: String,
}

/// Canonical **numerics** fingerprint: the config fields the loss
/// curve is a function of that are not individually recorded in
/// [`SnapshotMeta`]. f32/f64 fields go in as exact bit patterns.
/// `shard_chunk` is the live Adam artifact chunk
/// ([`Trainer::adam_chunk`]) — the absolute quantization grid every
/// per-chunk FP8 moment/wire scale lives on (`grid=c…`), so a resume
/// under a different chunk granularity refuses. The logical stream
/// plan (`streams=s{S}p{Π}`, the *effective*
/// `TrainConfig::streams`/`stream_pod_count` values) is the
/// data-parallel identity: batch streams, merge denominator, and the
/// collective's two-level summation tree — including which legs the
/// per-level compression flags
/// (`collective_fp8_intra`/`collective_fp8_inter`/`collective_fmt`,
/// the `cfp8=` term) put a qdq pass on. Physical `dp_workers`/`pods`/
/// `bucket_bytes` are deliberately NOT here — they live in
/// [`topology_fingerprint`], the reshardable term. `pack_moments` and
/// `overlap_comm` are deliberately **excluded entirely**
/// (exact-verified packing is bit-preserving, and the overlapped
/// schedule is test-pinned bit-identical to the phased one — toggling
/// either must never refuse a resume), and the compressed collective's
/// per-chunk scales are JIT — recomputed every step from the step's
/// own gradients — so there is no cross-step collective scale state to
/// capture.
pub fn numerics_fingerprint(cfg: &crate::config::TrainConfig, shard_chunk: usize) -> String {
    // the tile-wise GEMM compute path is numerics identity whenever a
    // gemm recipe is active: the tile size and per-operand formats
    // decide every per-tile pow2 grid the weights and grads land on.
    // Other recipes pin `off` so the term diffs cleanly (not <absent>)
    // when a resume switches the compute path itself.
    let gemm = if crate::config::is_gemm_recipe(&cfg.recipe) {
        format!("t{}:w{}:x{}:g{}", cfg.gemm_tile, cfg.gemm_w_fmt, cfg.gemm_x_fmt, cfg.gemm_g_fmt)
    } else {
        "off".to_string()
    };
    format!(
        "lr={:08x};minfrac={:08x};wd={:08x};clip={:08x};order={};skew={:016x};\
         outlier={}:{:08x};skipnf={};amax={};margin={};grid=c{};streams=s{}p{};\
         cfp8=i{}:x{}:{};gemm={gemm}",
        cfg.lr.to_bits(),
        cfg.min_lr_frac.to_bits(),
        cfg.weight_decay.to_bits(),
        cfg.grad_clip.to_bits(),
        cfg.corpus_order,
        cfg.corpus_skew.to_bits(),
        cfg.seed_outlier_channel,
        cfg.seed_outlier_gain.to_bits(),
        cfg.skip_nonfinite_updates,
        cfg.amax_history,
        cfg.margin_pow2,
        shard_chunk,
        cfg.streams(),
        cfg.stream_pod_count(),
        cfg.collective_fp8_intra,
        cfg.collective_fp8_inter,
        cfg.collective_fmt,
    )
}

/// Canonical **topology** fingerprint: the physical arrangement —
/// ZeRO-1 shard count (`shard=w…`), pod placement (`topo=p…`), and the
/// overlapped pipeline's bucket partition (`bucket=b…`). All three are
/// proven bit-invisible to the loss curve (chunk grids are absolute,
/// the collective plan is logical, and per-bucket ≡ whole-buffer was
/// pinned when the pipeline landed), so this is the one term `campaign
/// resume --reshard` may transform; a plain resume still refuses a
/// mismatch, with a hint naming the flag.
pub fn topology_fingerprint(cfg: &crate::config::TrainConfig) -> String {
    format!("shard=w{};topo=p{};bucket=b{}", cfg.dp_workers, cfg.pods, cfg.bucket_bytes)
}

/// Diff two canonical `key=value;…` fingerprints term-by-term:
/// `(key, snapshot value, config value)` for every term that differs
/// (`<absent>` when one side lacks the key). Both refusal paths print
/// this instead of the two opaque strings, so the operator sees *what*
/// changed — the actionable-diagnostics half of the reshard story.
pub fn diff_fingerprint_terms(snap: &str, cfg: &str) -> Vec<(String, String, String)> {
    let parse = |s: &str| -> Vec<(String, String)> {
        s.split(';')
            .filter(|t| !t.is_empty())
            .map(|t| match t.split_once('=') {
                Some((k, v)) => (k.to_string(), v.to_string()),
                None => (t.to_string(), String::new()),
            })
            .collect()
    };
    let a = parse(snap);
    let b = parse(cfg);
    let mut out = Vec::new();
    for (k, va) in &a {
        match b.iter().find(|(kb, _)| kb == k) {
            Some((_, vb)) if vb == va => {}
            Some((_, vb)) => out.push((k.clone(), va.clone(), vb.clone())),
            None => out.push((k.clone(), va.clone(), "<absent>".into())),
        }
    }
    for (k, vb) in &b {
        if !a.iter().any(|(ka, _)| ka == k) {
            out.push((k.clone(), "<absent>".into(), vb.clone()));
        }
    }
    out
}

/// Render a [`diff_fingerprint_terms`] result for an error message.
pub fn render_term_diff(diff: &[(String, String, String)]) -> String {
    diff.iter()
        .map(|(k, s, c)| format!("{k}: snapshot has '{s}', config has '{c}'"))
        .collect::<Vec<_>>()
        .join("; ")
}

/// A complete, serializable training state (see the module docs).
#[derive(Clone, Debug)]
pub struct TrainState {
    /// identity + position
    pub meta: SnapshotMeta,
    /// named parameter tensors, manifest order, raw f32
    pub params: Vec<(String, Vec<f32>)>,
    /// flat first Adam moment
    pub m: Vec<f32>,
    /// flat second Adam moment
    pub v: Vec<f32>,
    /// delayed-scaling state (rings in push order)
    pub scale: ScaleState,
    /// divergence-detector state
    pub detector: DetectorState,
}

fn moment_storage(fmt: &str) -> Option<Fp8Format> {
    match fmt {
        "e4m3" => Some(E4M3),
        "e5m2" => Some(E5M2),
        _ => None,
    }
}

/// Move one section's data out of the decoded checkpoint map.
fn take_section(
    sections: &mut std::collections::BTreeMap<String, (Dtype, Vec<f32>)>,
    name: &str,
) -> Result<Vec<f32>> {
    sections
        .remove(name)
        .map(|(_, d)| d)
        .ok_or_else(|| anyhow!("checkpoint missing tensor '{name}'"))
}

impl TrainState {
    /// Capture the trainer's complete state. `recoveries` is campaign
    /// bookkeeping carried through the snapshot so a resumed campaign
    /// keeps its recovery budget.
    ///
    /// Memory note: this copies params + both moments by value
    /// (transiently ~2x the state footprint, plus the writer's
    /// serialization buffer). The by-value `TrainState` is what makes
    /// save→load→apply a closed, property-testable round trip; if
    /// snapshot peak memory ever matters at large scale, add a
    /// borrow-based `save_direct(&Trainer, path)` fast path beside
    /// this rather than reshaping the type.
    pub fn capture(t: &Trainer, recoveries: usize) -> Self {
        let rc = t.cfg.recipe_config();
        let policy = t.scale_mgr.policy();
        let norm = |f: &str| if moment_storage(f).is_some() { f.to_string() } else { "f32".into() };
        // gather the ZeRO-1 moment shards into the flat layout the
        // snapshot stores; the shard map is chunk-aligned, so the
        // gathered buffer keeps the absolute per-chunk FP8 grids and
        // the exact-FP8 sections below stay grid-aligned
        let (m, v) = t.moments_flat();
        Self {
            meta: SnapshotMeta {
                step: t.step,
                recipe: t.cfg.recipe.clone(),
                size: t.cfg.size.clone(),
                seed: t.cfg.seed,
                corpus_seed: t.cfg.corpus_seed(),
                dp_workers: t.cfg.dp_workers,
                streams: t.cfg.streams(),
                stream_pods: t.cfg.stream_pod_count(),
                grad_accum: t.cfg.grad_accum,
                steps: t.cfg.steps,
                warmup_steps: t.cfg.warmup_steps,
                amax_history: policy.history_len,
                margin_pow2: policy.margin_pow2,
                recoveries,
                m_fmt: norm(&rc.m_fmt),
                v_fmt: norm(&rc.v_fmt),
                moment_chunk: t.adam_chunk().max(1),
                numerics: numerics_fingerprint(&t.cfg, t.adam_chunk()),
                topology: topology_fingerprint(&t.cfg),
            },
            params: t
                .params
                .specs
                .iter()
                .zip(&t.params.tensors)
                .map(|(s, tt)| (s.name.clone(), tt.f32s().to_vec()))
                .collect(),
            m,
            v,
            scale: t.scale_mgr.export_state(),
            detector: t.detector.export_state(),
        }
    }

    /// Serialize to a checkpoint file; returns the file size in bytes.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<u64> {
        let m = &self.meta;
        let meta = obj(vec![
            ("kind", Json::Str("campaign_snapshot".into())),
            ("version", Json::Num(SNAPSHOT_VERSION)),
            ("step", Json::Num(m.step as f64)),
            ("recipe", Json::Str(m.recipe.clone())),
            ("size", Json::Str(m.size.clone())),
            // seeds are u64: stored as strings so no f64 precision cliff
            ("seed", Json::Str(m.seed.to_string())),
            ("corpus_seed", Json::Str(m.corpus_seed.to_string())),
            ("dp_workers", Json::Num(m.dp_workers as f64)),
            ("streams", Json::Num(m.streams as f64)),
            ("stream_pods", Json::Num(m.stream_pods as f64)),
            ("grad_accum", Json::Num(m.grad_accum as f64)),
            ("steps", Json::Num(m.steps as f64)),
            ("warmup_steps", Json::Num(m.warmup_steps as f64)),
            ("amax_history", Json::Num(m.amax_history as f64)),
            ("margin_pow2", Json::Num(m.margin_pow2 as f64)),
            ("recoveries", Json::Num(m.recoveries as f64)),
            ("m_fmt", Json::Str(m.m_fmt.clone())),
            ("v_fmt", Json::Str(m.v_fmt.clone())),
            ("moment_chunk", Json::Num(m.moment_chunk as f64)),
            ("numerics", Json::Str(m.numerics.clone())),
            ("topology", Json::Str(m.topology.clone())),
            // f32 state that must restore bit-exactly rides as bits
            ("detector_ema_bits", Json::Num(self.detector.ema.to_bits() as f64)),
            ("detector_warmed", Json::Bool(self.detector.warmed)),
            (
                "detector_diverged_at",
                match self.detector.diverged_at {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
            ("overflow_events", Json::Num(self.scale.overflow_events as f64)),
        ]);
        let mut w = Writer::new(&meta);
        for (name, data) in &self.params {
            w.tensor(&format!("param.{name}"), Dtype::F32, data);
        }
        let chunk = self.meta.moment_chunk.max(1);
        match moment_storage(&self.meta.m_fmt) {
            Some(fmt) => w.tensor_fp8_exact("adam.m", fmt, &self.m, chunk),
            None => w.tensor("adam.m", Dtype::F32, &self.m),
        };
        match moment_storage(&self.meta.v_fmt) {
            Some(fmt) => w.tensor_fp8_exact("adam.v", fmt, &self.v, chunk),
            None => w.tensor("adam.v", Dtype::F32, &self.v),
        };
        w.tensor("scaling.scales", Dtype::F32, &self.scale.scales);
        let mut hist_vals: Vec<f32> = Vec::new();
        let mut hist_lens: Vec<f32> = Vec::with_capacity(self.scale.histories.len());
        for h in &self.scale.histories {
            hist_lens.push(h.len() as f32);
            hist_vals.extend_from_slice(h);
        }
        w.tensor("scaling.hist_lens", Dtype::F32, &hist_lens);
        w.tensor("scaling.hist_vals", Dtype::F32, &hist_vals);
        w.finish(path)
    }

    /// Deserialize a snapshot written by [`save`](TrainState::save).
    ///
    /// Tensors are moved out of the decoded checkpoint, not cloned —
    /// resume/rollback peak memory is one copy of the state, not two.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let Checkpoint { meta, tensors: mut sections, .. } = Checkpoint::load(&path)?;
        let meta = &meta;
        if meta.str_or("kind", "") != "campaign_snapshot" {
            bail!("not a campaign snapshot (kind = '{}')", meta.str_or("kind", "?"));
        }
        let version = meta.f64_of("version").map_err(|e| anyhow!(e))?;
        if version > SNAPSHOT_VERSION {
            bail!("snapshot version {version} is newer than this binary ({SNAPSHOT_VERSION})");
        }
        let u64_of = |key: &str| -> Result<u64> {
            meta.str_of(key)
                .map_err(|e| anyhow!(e))?
                .parse::<u64>()
                .with_context(|| format!("snapshot meta field '{key}'"))
        };
        let usize_of = |key: &str| meta.usize_of(key).map_err(|e| anyhow!(e));
        let diverged_at = match meta.get("detector_diverged_at") {
            Some(Json::Num(n)) => Some(*n as usize),
            _ => None,
        };
        let detector = DetectorState {
            ema: f32::from_bits(meta.f64_of("detector_ema_bits").map_err(|e| anyhow!(e))? as u32),
            warmed: matches!(meta.get("detector_warmed"), Some(Json::Bool(true))),
            diverged_at,
        };
        let scales = take_section(&mut sections, "scaling.scales")?;
        let hist_lens = take_section(&mut sections, "scaling.hist_lens")?;
        let hist_vals = take_section(&mut sections, "scaling.hist_vals")?;
        if hist_lens.len() != scales.len() {
            bail!(
                "scaling arity mismatch: {} sites but {} history lengths",
                scales.len(),
                hist_lens.len()
            );
        }
        let mut histories = Vec::with_capacity(hist_lens.len());
        let mut off = 0usize;
        for (i, &l) in hist_lens.iter().enumerate() {
            let l = l as usize;
            if off + l > hist_vals.len() {
                bail!("site {i}: history runs past the recorded values");
            }
            histories.push(hist_vals[off..off + l].to_vec());
            off += l;
        }
        if off != hist_vals.len() {
            bail!("{} trailing history values not claimed by any site", hist_vals.len() - off);
        }
        let m = take_section(&mut sections, "adam.m")?;
        let v = take_section(&mut sections, "adam.v")?;
        let params: Vec<(String, Vec<f32>)> = sections
            .into_iter()
            .filter_map(|(name, (_, data))| {
                name.strip_prefix("param.").map(|p| (p.to_string(), data))
            })
            .collect();
        if params.is_empty() {
            bail!("snapshot holds no parameter tensors");
        }
        // pre-1.4 snapshots had no logical/physical split: their
        // streams followed dp_workers (plan pods followed `pods`, not
        // recorded — default 1 is only reached on those old files, and
        // applying them refuses anyway: the old fingerprint format
        // never matches a 1.4 binary's)
        let dp_workers = usize_of("dp_workers")?;
        Ok(Self {
            meta: SnapshotMeta {
                step: usize_of("step")?,
                recipe: meta.str_of("recipe").map_err(|e| anyhow!(e))?.to_string(),
                size: meta.str_of("size").map_err(|e| anyhow!(e))?.to_string(),
                seed: u64_of("seed")?,
                corpus_seed: u64_of("corpus_seed")?,
                dp_workers,
                streams: meta.get("streams").and_then(|v| v.as_usize()).unwrap_or(dp_workers),
                stream_pods: meta.get("stream_pods").and_then(|v| v.as_usize()).unwrap_or(1),
                grad_accum: usize_of("grad_accum")?,
                steps: usize_of("steps")?,
                warmup_steps: usize_of("warmup_steps")?,
                amax_history: usize_of("amax_history")?,
                margin_pow2: meta.f64_of("margin_pow2").map_err(|e| anyhow!(e))? as i32,
                recoveries: usize_of("recoveries")?,
                m_fmt: meta.str_or("m_fmt", "f32"),
                v_fmt: meta.str_or("v_fmt", "f32"),
                moment_chunk: meta
                    .get("moment_chunk")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(MOMENT_CHUNK),
                numerics: meta.str_of("numerics").map_err(|e| anyhow!(e))?.to_string(),
                topology: meta.str_or("topology", ""),
            },
            params,
            m,
            v,
            scale: ScaleState {
                histories,
                scales,
                overflow_events: usize_of("overflow_events")?,
            },
            detector,
        })
    }

    /// Restore this state into a trainer built from the same config.
    ///
    /// Validates the numerics fingerprint, the identity fields
    /// (recipe, size, seed, schedule length), the physical topology
    /// fingerprint, and every tensor arity before touching anything;
    /// on success the trainer's next `step()` produces exactly the
    /// outcome the snapshotted run's next step would have.
    ///
    /// Check order matters for diagnostics: numerics bails first, so a
    /// topology refusal implies the numerics already matched — its
    /// hint to rerun with `--reshard` is therefore always sound (if
    /// both differed, the operator sees the numerics refusal, where
    /// resharding would not help).
    pub fn apply_to(&self, t: &mut Trainer) -> Result<()> {
        let m = &self.meta;
        let cfg_numerics = numerics_fingerprint(&t.cfg, t.adam_chunk());
        if m.numerics != cfg_numerics {
            let diff = diff_fingerprint_terms(&m.numerics, &cfg_numerics);
            bail!(
                "snapshot/config mismatch on numerics term(s) [{}] — resuming would fork \
                 the curve, refusing",
                render_term_diff(&diff)
            );
        }
        let checks: [(&str, String, String); 6] = [
            ("recipe", m.recipe.clone(), t.cfg.recipe.clone()),
            ("size", m.size.clone(), t.cfg.size.clone()),
            ("seed", m.seed.to_string(), t.cfg.seed.to_string()),
            ("corpus_seed", m.corpus_seed.to_string(), t.cfg.corpus_seed().to_string()),
            ("grad_accum", m.grad_accum.to_string(), t.cfg.grad_accum.to_string()),
            (
                "steps/warmup",
                format!("{}/{}", m.steps, m.warmup_steps),
                format!("{}/{}", t.cfg.steps, t.cfg.warmup_steps),
            ),
        ];
        for (what, snap, cfg) in &checks {
            if snap != cfg {
                bail!(
                    "snapshot/config mismatch on {what}: snapshot has '{snap}', config has \
                     '{cfg}' — resuming would fork the curve, refusing"
                );
            }
        }
        let cfg_topology = topology_fingerprint(&t.cfg);
        if m.topology != cfg_topology {
            let diff = diff_fingerprint_terms(&m.topology, &cfg_topology);
            bail!(
                "snapshot/config mismatch on physical-topology term(s) [{}] — worker \
                 shards / pod placement / bucket partition changed. The numerics identity \
                 matches, so this snapshot can be transformed deterministically: rerun \
                 with `campaign resume --reshard`",
                render_term_diff(&diff)
            );
        }
        let total = t.params.total_elems();
        if self.m.len() != total || self.v.len() != total {
            bail!(
                "moment size mismatch: snapshot {}/{}, trainer {}/{}",
                self.m.len(),
                self.v.len(),
                total,
                total
            );
        }
        // all params present with matching sizes, before any mutation
        for (spec, tensor) in t.params.specs.iter().zip(&t.params.tensors) {
            let data = self
                .params
                .iter()
                .find(|(n, _)| n == &spec.name)
                .map(|(_, d)| d)
                .ok_or_else(|| anyhow!("snapshot missing parameter '{}'", spec.name))?;
            if data.len() != tensor.len() {
                bail!(
                    "parameter '{}' size mismatch: snapshot {}, trainer {}",
                    spec.name,
                    data.len(),
                    tensor.len()
                );
            }
        }
        // scaling arity/capacity validated up front too: nothing below
        // may touch the trainer until every check has passed (a failed
        // apply must leave the trainer exactly as it was)
        if self.scale.scales.len() != t.scale_mgr.n_sites()
            || self.scale.histories.len() != t.scale_mgr.n_sites()
        {
            bail!(
                "scaling arity mismatch: snapshot has {} sites, trainer has {}",
                self.scale.scales.len(),
                t.scale_mgr.n_sites()
            );
        }
        if m.amax_history == 0 {
            bail!("snapshot records amax_history = 0 (ring capacity must be >= 1)");
        }
        for (i, h) in self.scale.histories.iter().enumerate() {
            if h.len() > m.amax_history {
                bail!(
                    "site {i}: snapshot history has {} entries but its recorded amax_history \
                     is {} — snapshot is internally inconsistent",
                    h.len(),
                    m.amax_history
                );
            }
        }
        let policy = Policy {
            history_len: m.amax_history,
            margin_pow2: m.margin_pow2,
            ..t.scale_mgr.policy()
        };
        t.scale_mgr.reconfigure(policy);
        t.scale_mgr
            .restore_state(&self.scale)
            .map_err(|e| anyhow!("internal: pre-validated scale restore failed: {e}"))?;
        for i in 0..t.params.specs.len() {
            let name = t.params.specs[i].name.clone();
            let (_, data) = self.params.iter().find(|(n, _)| n == &name).unwrap();
            t.params.tensors[i].f32s_mut().copy_from_slice(data);
        }
        t.set_moments_flat(&self.m, &self.v);
        t.detector.restore_state(&self.detector);
        t.step = m.step;
        t.mark_state_restored();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::{
        diff_fingerprint_terms, numerics_fingerprint, render_term_diff, topology_fingerprint,
    };
    use crate::config::TrainConfig;

    #[test]
    fn fingerprint_refuses_stream_plan_changes() {
        // the numerics term pins the *effective* logical stream plan:
        // with the stream keys defaulted (0 = follow physical), a bare
        // pods or dp_workers change still alters effective S/Π and must
        // refuse — backward-compatible with the pre-split behavior
        let base = TrainConfig { dp_workers: 8, ..Default::default() };
        let fp = |c: &TrainConfig| numerics_fingerprint(c, 262_144);
        let f0 = fp(&base);
        assert_eq!(f0, fp(&base), "identical configs must agree");

        let mut pods = base.clone();
        pods.pods = 2;
        assert_ne!(f0, fp(&pods), "bare pods change shifts effective stream_pods: refuses");
        let mut dp = base.clone();
        dp.dp_workers = 4;
        assert_ne!(f0, fp(&dp), "bare dp_workers change shifts effective streams: refuses");

        // with the logical plan pinned explicitly, physical changes
        // leave the numerics term alone — they move to the topology
        // term, which is the whole point of the split
        let pinned = TrainConfig {
            dp_workers: 8,
            pods: 2,
            grad_streams: 8,
            stream_pods: 2,
            ..Default::default()
        };
        let p0 = fp(&pinned);
        let mut shrunk = pinned.clone();
        shrunk.dp_workers = 6;
        shrunk.pods = 1;
        assert_eq!(p0, fp(&shrunk), "pinned plan: physical shrink must not touch numerics");
        assert_ne!(
            topology_fingerprint(&pinned),
            topology_fingerprint(&shrunk),
            "…but it must change the topology term"
        );

        let mut intra = base.clone();
        intra.collective_fp8_intra = true;
        assert_ne!(f0, fp(&intra), "intra compression flag is numerics identity");
        let mut inter = base.clone();
        inter.collective_fp8_inter = false;
        assert_ne!(f0, fp(&inter), "inter compression flag is numerics identity");
        let mut fmt = base.clone();
        fmt.collective_fmt = "e4m3".into();
        assert_ne!(f0, fp(&fmt), "wire format is numerics identity");
        // pack_moments stays excluded: bit-preserving by construction
        let mut pk = base.clone();
        pk.pack_moments = !pk.pack_moments;
        assert_eq!(f0, fp(&pk), "pack_moments must NOT be numerics identity");
    }

    #[test]
    fn fingerprint_pins_bucket_schedule_in_topology_not_numerics() {
        // the bucket partition is bit-invisible (pinned by the
        // overlapped-pipeline tests), so since the 1.4 split it lives
        // in the reshardable topology term; toggling the overlapped
        // schedule itself stays out of both terms
        let base = TrainConfig { dp_workers: 4, ..Default::default() };
        let fp = |c: &TrainConfig| numerics_fingerprint(c, 262_144);
        let f0 = fp(&base);

        let mut bb = base.clone();
        bb.bucket_bytes = 1_048_576;
        assert_eq!(f0, fp(&bb), "bucket_bytes must NOT be numerics identity since 1.4");
        assert_ne!(
            topology_fingerprint(&base),
            topology_fingerprint(&bb),
            "changed bucket_bytes must change the topology term"
        );
        assert!(
            topology_fingerprint(&base).contains(&format!("bucket=b{}", base.bucket_bytes)),
            "the bucket key must be recorded explicitly: {}",
            topology_fingerprint(&base)
        );

        let mut ov = base.clone();
        ov.overlap_comm = !ov.overlap_comm;
        assert_eq!(f0, fp(&ov), "toggled overlap_comm must NOT refuse a resume");
        assert_eq!(
            topology_fingerprint(&base),
            topology_fingerprint(&ov),
            "overlap_comm is not topology either"
        );
    }

    #[test]
    fn fingerprint_pins_gemm_tile_and_formats_for_gemm_recipes() {
        // under an fp8_gemm recipe every per-tile pow2 grid is a
        // function of (tile, w_fmt, x_fmt, g_fmt): all four are
        // numerics identity, and a resume under any change refuses
        // with the 'gemm' term named in the diff
        let base = TrainConfig { recipe: "fp8_gemm".into(), ..Default::default() };
        let fp = |c: &TrainConfig| numerics_fingerprint(c, 262_144);
        let f0 = fp(&base);
        assert!(f0.contains("gemm=t128:we4m3:xe4m3:ge5m2"), "{f0}");

        let mut tile = base.clone();
        tile.gemm_tile = 64;
        assert_ne!(f0, fp(&tile), "tile size is numerics identity under fp8_gemm");
        let d = diff_fingerprint_terms(&f0, &fp(&tile));
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].0, "gemm", "the diff must name the gemm term: {d:?}");

        let mut gfmt = base.clone();
        gfmt.gemm_g_fmt = "e4m3".into();
        assert_ne!(f0, fp(&gfmt), "grad operand format is numerics identity");

        // non-gemm recipes pin 'gemm=off' so the gemm keys are inert
        // noise there, and switching the compute path itself diffs as
        // off → t…, not as <absent>
        let plain = TrainConfig::default();
        let p0 = fp(&plain);
        assert!(p0.contains("gemm=off"), "{p0}");
        let mut plain_tile = plain.clone();
        plain_tile.gemm_tile = 64;
        assert_eq!(p0, fp(&plain_tile), "gemm keys are inert for non-gemm recipes");
        let d2 = diff_fingerprint_terms(&p0, &f0);
        assert!(
            d2.iter().any(|(k, a, b)| k == "gemm" && a == "off" && b.starts_with("t128")),
            "{d2:?}"
        );
    }

    #[test]
    fn term_diff_reports_exactly_the_changed_keys() {
        let a = "shard=w4;topo=p2;bucket=b4194304";
        let b = "shard=w3;topo=p1;bucket=b4194304";
        let d = diff_fingerprint_terms(a, b);
        assert_eq!(
            d,
            vec![
                ("shard".into(), "w4".into(), "w3".into()),
                ("topo".into(), "p2".into(), "p1".into()),
            ]
        );
        let msg = render_term_diff(&d);
        assert!(msg.contains("shard: snapshot has 'w4', config has 'w3'"), "{msg}");
        assert!(!msg.contains("bucket"), "unchanged terms must not be reported: {msg}");

        // keys present on only one side render as <absent> — this is
        // how a pre-1.4 fingerprint's mismatch stays readable
        let d2 = diff_fingerprint_terms("a=1;old=2", "a=1;new=3");
        assert_eq!(
            d2,
            vec![
                ("old".into(), "2".into(), "<absent>".into()),
                ("new".into(), "<absent>".into(), "3".into()),
            ]
        );
        assert!(diff_fingerprint_terms(a, a).is_empty(), "equal strings: empty diff");
    }
}
