//! On-disk snapshot store: naming, discovery, and keep-last-K
//! retention for one campaign's snapshot directory.
//!
//! Snapshots are named `snap_<step:08>.ckpt`, so lexicographic order
//! is step order and `status`/`resume` can discover state with one
//! directory listing. Retention prunes oldest-first and never touches
//! the newest snapshot (the rollback/resume target).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::snapshot::TrainState;

/// A campaign's snapshot directory with its retention policy.
pub struct SnapshotStore {
    dir: PathBuf,
    keep: usize,
}

impl SnapshotStore {
    /// Open (creating if needed) the snapshot directory. `keep` is the
    /// retention depth; it is clamped to at least 1 so the rollback
    /// target always survives.
    pub fn new<P: AsRef<Path>>(dir: P, keep: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        Ok(Self { dir, keep: keep.max(1) })
    }

    /// The snapshot directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Canonical path of the snapshot for `step`.
    pub fn path_for(&self, step: usize) -> PathBuf {
        self.dir.join(format!("snap_{step:08}.ckpt"))
    }

    /// Write `state` (named by its step), prune to the retention
    /// depth, and return the snapshot path + file size.
    ///
    /// A prune failure is logged and tolerated: once the snapshot is
    /// durably in place the save has achieved its goal, and a
    /// transient cleanup error (backup scanner holding a file, fs
    /// hiccup) must not abort a multi-week campaign.
    pub fn save(&self, state: &TrainState) -> Result<(PathBuf, u64)> {
        let path = self.path_for(state.meta.step);
        let bytes = state.save(&path)?;
        if let Err(e) = self.prune() {
            eprintln!("warning: snapshot retention prune failed (continuing): {e:#}");
        }
        Ok((path, bytes))
    }

    /// All snapshots in the directory, ascending by step.
    pub fn list(&self) -> Result<Vec<(usize, PathBuf)>> {
        list_snapshots(&self.dir)
    }

    /// The newest snapshot, if any.
    pub fn latest(&self) -> Result<Option<(usize, PathBuf)>> {
        Ok(self.list()?.pop())
    }

    /// Delete oldest snapshots beyond the retention depth; returns the
    /// removed paths. Also sweeps `snap_*.tmp` orphans — a crash
    /// between `Writer::finish`'s tmp write and its rename leaves one
    /// behind, and nothing else looks at `.tmp` files.
    pub fn prune(&self) -> Result<Vec<PathBuf>> {
        let mut all = self.list()?;
        let mut removed = Vec::new();
        while all.len() > self.keep {
            let (_, path) = all.remove(0); // oldest first
            std::fs::remove_file(&path)
                .with_context(|| format!("pruning {}", path.display()))?;
            removed.push(path);
        }
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for entry in rd.flatten() {
                let name = entry.file_name();
                let is_orphan = name
                    .to_str()
                    .is_some_and(|s| s.starts_with("snap_") && s.ends_with(".tmp"));
                if is_orphan {
                    std::fs::remove_file(entry.path()).ok();
                }
            }
        }
        Ok(removed)
    }
}

/// List `snap_<step>.ckpt` files in a directory, ascending by step —
/// shared by the store and the read-only `status` tooling (which must
/// not create directories or prune anything).
pub fn list_snapshots<P: AsRef<Path>>(dir: P) -> Result<Vec<(usize, PathBuf)>> {
    let mut out: Vec<(usize, PathBuf)> = Vec::new();
    let rd = match std::fs::read_dir(dir.as_ref()) {
        Ok(rd) => rd,
        Err(_) => return Ok(out), // absent dir = no snapshots
    };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(step) = name
            .strip_prefix("snap_")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<usize>().ok())
        else {
            continue;
        };
        out.push((step, entry.path()));
    }
    out.sort_by_key(|&(step, _)| step);
    Ok(out)
}
