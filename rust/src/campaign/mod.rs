#![warn(missing_docs)]
//! Long-horizon training campaigns: checkpoint/resume with bit-exact
//! restarts plus divergence auto-recovery.
//!
//! The paper's instabilities only surface over *prolonged* runs —
//! SwiGLU outlier amplification needs hundreds of billions of tokens
//! to emerge — so the operational unit this module models is not a
//! single uninterrupted [`Trainer`] session but a **campaign**: a run
//! that survives process restarts (stop at step N, resume, and the
//! loss curve continues bit-for-bit as if never stopped) and survives
//! divergence trips (roll back to the last good snapshot, re-enter
//! with a perturbed scaling policy, log everything).
//!
//! Pieces:
//! * [`snapshot`] — the full-training-state snapshot ([`TrainState`]):
//!   params, FP8 Adam moments (chunked exact-FP8 checkpoint sections),
//!   delayed-scaling amax rings, detector EMA, LR-schedule position,
//!   data cursor. Save → load → apply reproduces every bit.
//! * [`store`] — on-disk snapshot directory with keep-last-K
//!   retention.
//! * [`journal`] — append-only machine-readable JSONL campaign journal
//!   (snapshots, divergences, rollbacks, recoveries, completion).
//! * [`recovery`] — the backoff policy: per recovery attempt, more
//!   pow2 scale margin and a shorter amax history.
//! * [`reshard`] — the deterministic elastic-topology transform:
//!   `campaign resume --reshard` re-partitions a snapshot's ZeRO-1
//!   moment state for a changed `dp_workers`/`pods`/`bucket_bytes`,
//!   roundtrip-verified bit-exact before anything touches disk.
//! * [`fleet`] — fleet observability: discover every campaign dir
//!   under a root and aggregate step/loss/divergence/recovery/reshard
//!   state across them in one O(1)-memory streaming pass per journal
//!   (the `campaign fleet status|losses|divergences|metrics` CLI,
//!   including a Prometheus-style text exposition).
//! * [`Campaign`] — the driver tying it together, used by the
//!   `campaign` CLI binary (`run / resume / status / inspect / fleet`).
//!
//! Operator docs: `rust/EXPERIMENTS.md` §Campaigns describes the
//! bit-exact-resume methodology and the divergence-injection recovery
//! drill; `rust/ARCHITECTURE.md` places this layer in the system.

pub mod fleet;
pub mod journal;
pub mod recovery;
pub mod reshard;
pub mod snapshot;
pub mod store;

pub use fleet::{CampaignView, FleetView};
pub use journal::Journal;
pub use recovery::RecoveryPolicy;
pub use reshard::{reshard_state, ReshardReport};
pub use snapshot::{SnapshotMeta, TrainState};
pub use store::SnapshotStore;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::runtime::Runtime;
use crate::scaling::Policy;
use crate::util::json::Json;

/// What a finished (or aborted) campaign reports back.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    /// true if the run reached `cfg.steps`; false on an orderly pause
    /// (`stop_after`) or on recovery-budget exhaustion — the journal's
    /// last `pause`/`abort` event has the detail
    pub completed: bool,
    /// true if the exit was an orderly `stop_after` pause (resumable,
    /// not a failure); `!completed && !paused` means aborted
    pub paused: bool,
    /// step counter at exit (== `cfg.steps` when completed)
    pub final_step: usize,
    /// divergence recoveries consumed across the campaign
    pub recoveries: usize,
    /// loss of the last executed step (NaN if no step ran)
    pub final_loss: f32,
    /// executed steps' (step, loss) in execution order — steps
    /// replayed after a rollback appear again, which is the honest
    /// record of what actually ran. Bounded to the most recent
    /// [`LOSS_RECORD_CAP`] entries so a multi-week campaign's memory
    /// stays flat; the journal + metrics sink are the durable
    /// full-history record
    pub losses: Vec<(usize, f32)>,
    /// snapshots written (entry + periodic + recovery + pause/final)
    pub snapshots: usize,
}

/// A resumable, self-healing long-horizon training run.
///
/// Construction either starts fresh ([`Campaign::new`]) or resumes
/// from the newest snapshot in the campaign directory
/// ([`Campaign::resume`]); [`Campaign::run`] then drives the trainer
/// to `cfg.steps`, snapshotting on the configured cadence and
/// auto-recovering from divergence trips until the recovery budget
/// (`cfg.max_recoveries`) is spent.
pub struct Campaign {
    /// the underlying trainer (public for tests and probes; mutating
    /// its state mid-campaign voids the bit-exactness contract)
    pub trainer: Trainer,
    /// test/drill hook: treat this step's outcome as a divergence trip
    /// exactly once, even if the detector stayed healthy (the
    /// §Campaigns recovery drill; campaign state, so it does not
    /// replay after the rollback it triggers)
    pub inject_divergence_at: Option<usize>,
    /// session step bound: pause (snapshot + `pause` journal event +
    /// orderly `completed: false` return) once the step counter
    /// reaches this, leaving the campaign resumable — the clean way to
    /// fit a long campaign into bounded sessions, and how the
    /// kill-at-step-N resume drill stops deterministically
    pub stop_after: Option<usize>,
    store: SnapshotStore,
    journal: Journal,
    recovery: RecoveryPolicy,
    /// exclusive lock on the campaign dir; released on drop (also
    /// remembers whether acquire reclaimed a dead owner's stale lock,
    /// which both entry points journal)
    lock: DirLock,
    /// scaling policy the run started under — recovery backoff is
    /// always computed relative to this, not compounded
    base_policy: Policy,
    recoveries: usize,
    injected: bool,
    snapshots_written: usize,
}

impl Campaign {
    /// Start a fresh campaign in `dir` (creating `dir/snapshots/` and
    /// `dir/journal.jsonl`).
    ///
    /// Refuses a directory that already holds snapshots: starting
    /// fresh there would interleave two campaigns in one journal and,
    /// worse, leave the old campaign's snapshots as rollback/resume
    /// targets for the new one. Use [`Campaign::resume`] to continue
    /// the existing campaign, or point `--dir` somewhere clean.
    pub fn new<P: AsRef<Path>>(rt: Arc<Runtime>, cfg: TrainConfig, dir: P) -> Result<Self> {
        let dir = dir.as_ref();
        // lock FIRST so the stale-dir checks can't race another
        // process finishing a campaign here (a refusal drops the lock
        // again); then the cheap refusals, before the expensive
        // trainer build
        let lock = Self::prepare(dir)?;
        if let Some((step, path)) = store::list_snapshots(dir.join("snapshots"))?.pop() {
            return Err(anyhow!(
                "campaign dir {} already holds snapshots (newest: step {step} at {}) — \
                 use `campaign resume` to continue it, or choose a fresh --dir \
                 (or delete the old campaign) to start over",
                dir.display(),
                path.display()
            ));
        }
        let journal_path = dir.join("journal.jsonl");
        if std::fs::metadata(&journal_path).map_or(false, |m| m.len() > 0) {
            return Err(anyhow!(
                "campaign dir {} already holds a journal (a previous run started here, \
                 even if it never snapshotted) — the journal is one campaign's single \
                 chronological record; choose a fresh --dir or delete the old campaign",
                dir.display()
            ));
        }
        let mut c = Self::build(rt, cfg, dir, lock)?;
        c.journal_lock_reclaim()?;
        c.journal.record(
            "campaign_start",
            c.trainer.step,
            vec![("config", c.trainer.cfg.to_json())],
        )?;
        Ok(c)
    }

    /// Resume a campaign from the newest snapshot in `dir`.
    ///
    /// The config must match the one the snapshot was taken under
    /// (recipe, size, seed, worker topology, schedule length — see
    /// [`TrainState::apply_to`]); the restored trainer then continues
    /// the original loss curve bit-exactly. To continue on a *changed
    /// physical topology* (node loss, pod rearrangement), use
    /// [`Campaign::resume_opts`] with [`ResumeOptions::reshard`].
    pub fn resume<P: AsRef<Path>>(rt: Arc<Runtime>, cfg: TrainConfig, dir: P) -> Result<Self> {
        Self::resume_opts(rt, cfg, dir, ResumeOptions::default())
    }

    /// [`Campaign::resume`] with options. With `reshard` set, a
    /// snapshot whose *physical topology* term differs from the config
    /// is transformed deterministically ([`reshard_state`]) and
    /// re-saved before apply: the campaign continues bit-exactly on
    /// the new worker/pod arrangement. The snapshot's pinned logical
    /// stream plan is adopted into defaulted `grad_streams`/
    /// `stream_pods` config keys first, so shrinking `dp_workers` does
    /// not silently shift the batch identity. A numerics mismatch
    /// still refuses — resharding never changes the curve.
    pub fn resume_opts<P: AsRef<Path>>(
        rt: Arc<Runtime>,
        mut cfg: TrainConfig,
        dir: P,
        opts: ResumeOptions,
    ) -> Result<Self> {
        let dir = dir.as_ref();
        let lock = Self::prepare(dir)?;
        // open the store/journal *before* building the trainer: the
        // reshard path must read the snapshot's pinned logical plan to
        // finalize the config the trainer is built from
        let store = SnapshotStore::new(dir.join("snapshots"), cfg.snapshot_keep)?;
        let mut journal = Journal::open(dir.join("journal.jsonl"))?;
        let found = newest_loadable(&store, &mut journal)?;
        let (step, path, mut st) = found.ok_or_else(|| {
            anyhow!(
                "no loadable snapshot to resume from in {} — if the campaign died before \
                 its first snapshot (or every snapshot is quarantined as .corrupt), there \
                 is nothing to continue: delete the campaign dir and start a fresh run",
                store.dir().display()
            )
        })?;
        if opts.reshard {
            // adopt the campaign's logical plan where the config left
            // it defaulted (0 = follow physical): under a changed
            // dp_workers/pods the *effective* plan must stay the
            // snapshot's, or the numerics check below would refuse —
            // correctly, but unhelpfully
            if cfg.grad_streams == 0 {
                cfg.grad_streams = st.meta.streams;
            }
            if cfg.stream_pods == 0 {
                cfg.stream_pods = st.meta.stream_pods;
            }
            // the adopted plan came from a validated captured config;
            // Trainer::new re-validates both the physical split and
            // the logical plan before anything runs
        }
        let mut c = Self::build_parts(rt, cfg, lock, store, journal)?;
        c.journal_lock_reclaim()?;
        let mut resharded = false;
        if opts.reshard && st.meta.topology != snapshot::topology_fingerprint(&c.trainer.cfg) {
            let (new_st, rep) = reshard_state(&st, &c.trainer.cfg, c.trainer.adam_chunk())?;
            // re-save at the same step: the on-disk newest snapshot now
            // matches the live topology, so a crash right after this
            // point resumes cleanly without re-resharding
            let (new_path, _) = c.store.save(&new_st)?;
            c.journal.record(
                "reshard",
                new_st.meta.step,
                vec![
                    ("snapshot_step", Json::Num(step as f64)),
                    ("snapshot", Json::Str(new_path.display().to_string())),
                    ("from_workers", Json::Num(rep.from_workers as f64)),
                    ("to_workers", Json::Num(rep.to_workers as f64)),
                    ("from_topology", Json::Str(rep.from_topology.clone())),
                    ("to_topology", Json::Str(rep.to_topology.clone())),
                ],
            )?;
            c.journal.flush()?;
            st = new_st;
            resharded = true;
        }
        st.apply_to(&mut c.trainer)?;
        if c.trainer.step >= c.trainer.cfg.steps {
            return Err(anyhow!(
                "campaign in {} is already complete (snapshot at step {} of {}) — nothing \
                 to resume; inspect it with `campaign status`, or start a new campaign in \
                 a fresh --dir",
                c.store.dir().display(),
                c.trainer.step,
                c.trainer.cfg.steps
            ));
        }
        c.recoveries = st.meta.recoveries;
        c.journal.record(
            "resume",
            c.trainer.step,
            vec![
                ("snapshot_step", Json::Num(step as f64)),
                ("snapshot", Json::Str(path.display().to_string())),
                ("recoveries", Json::Num(c.recoveries as f64)),
                ("resharded", Json::Bool(resharded)),
            ],
        )?;
        Ok(c)
    }

    /// Create the campaign dir and take its exclusive lock — the first
    /// thing both entry points do, before any state inspection.
    fn prepare(dir: &Path) -> Result<DirLock> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow!("creating campaign dir {}: {e}", dir.display()))?;
        DirLock::acquire(dir)
    }

    fn build(rt: Arc<Runtime>, cfg: TrainConfig, dir: &Path, lock: DirLock) -> Result<Self> {
        let store = SnapshotStore::new(dir.join("snapshots"), cfg.snapshot_keep)?;
        let journal = Journal::open(dir.join("journal.jsonl"))?;
        Self::build_parts(rt, cfg, lock, store, journal)
    }

    /// [`build`](Campaign::build) with the store/journal already open —
    /// the resume path opens them early to read the snapshot before
    /// the trainer exists.
    fn build_parts(
        rt: Arc<Runtime>,
        cfg: TrainConfig,
        lock: DirLock,
        store: SnapshotStore,
        journal: Journal,
    ) -> Result<Self> {
        let recovery = RecoveryPolicy::from_cfg(&cfg);
        let trainer = Trainer::new(rt, cfg)?;
        let base_policy = trainer.scale_mgr.policy();
        Ok(Self {
            trainer,
            inject_divergence_at: None,
            stop_after: None,
            store,
            journal,
            recovery,
            lock,
            base_policy,
            recoveries: 0,
            injected: false,
            snapshots_written: 0,
        })
    }

    /// Journal the stale-lock reclaim, if this campaign's acquire
    /// performed one — called by both entry points right after the
    /// journal opens, so the event lands before anything else this
    /// session writes.
    fn journal_lock_reclaim(&mut self) -> Result<()> {
        if let Some(pid) = self.lock.reclaimed_from() {
            self.journal.record(
                "lock_reclaimed",
                self.trainer.step,
                vec![("stale_pid", Json::Num(pid as f64))],
            )?;
            self.journal.flush()?;
        }
        Ok(())
    }

    /// Divergence recoveries consumed so far.
    pub fn recoveries(&self) -> usize {
        self.recoveries
    }

    /// The campaign's snapshot store (status/inspect tooling).
    pub fn store(&self) -> &SnapshotStore {
        &self.store
    }

    /// Drive the trainer to `cfg.steps`, snapshotting and
    /// auto-recovering along the way. Returns the campaign report;
    /// `Err` is reserved for infrastructure failures (artifact
    /// execution, I/O) — a divergence that exhausts the recovery
    /// budget is an orderly `completed: false` report, not an error.
    pub fn run(&mut self) -> Result<CampaignReport> {
        let total = self.trainer.cfg.steps;
        // mandatory entry snapshot: the rollback target always exists,
        // and a campaign killed before its first periodic snapshot can
        // still resume
        self.snapshot("entry", f32::NAN)?;
        let mut losses: Vec<(usize, f32)> = Vec::new();
        while self.trainer.step < total {
            if self.stop_after.is_some_and(|s| self.trainer.step >= s) {
                let last = losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN);
                self.snapshot("pause", last)?;
                self.journal.record(
                    "pause",
                    self.trainer.step,
                    vec![("stop_after", Json::Num(self.stop_after.unwrap() as f64))],
                )?;
                self.journal.flush()?;
                return Ok(self.report(false, true, losses));
            }
            let o = self.trainer.step()?;
            losses.push((o.step, o.loss));
            // amortized tail bound: drain in bulk, not per step
            if losses.len() > 2 * LOSS_RECORD_CAP {
                losses.drain(..losses.len() - LOSS_RECORD_CAP);
            }
            let injected = self.inject_divergence_at == Some(o.step) && !self.injected;
            if injected {
                self.injected = true;
            }
            if self.trainer.detector.has_diverged() || injected {
                self.journal.record(
                    "divergence",
                    o.step,
                    vec![
                        ("loss", Json::Num(o.loss as f64)),
                        ("verdict", Json::Str(format!("{:?}", o.verdict))),
                        ("injected", Json::Bool(injected)),
                        (
                            "overflow_events",
                            Json::Num(self.trainer.scale_mgr.overflow_events as f64),
                        ),
                    ],
                )?;
                if self.recoveries >= self.recovery.max_recoveries {
                    self.journal.record(
                        "abort",
                        o.step,
                        vec![(
                            "reason",
                            Json::Str(format!(
                                "recovery budget exhausted ({} used)",
                                self.recoveries
                            )),
                        )],
                    )?;
                    self.journal.flush()?;
                    return Ok(self.report(false, false, losses));
                }
                self.rollback_and_perturb()?;
                continue;
            }
            if self.trainer.cfg.snapshot_every > 0
                && (o.step + 1) % self.trainer.cfg.snapshot_every == 0
                && self.trainer.step < total
            {
                self.snapshot("periodic", o.loss)?;
            }
        }
        let final_loss = losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN);
        self.snapshot("final", final_loss)?;
        self.journal.record(
            "complete",
            self.trainer.step,
            vec![
                ("final_loss", Json::Num(final_loss as f64)),
                ("recoveries", Json::Num(self.recoveries as f64)),
            ],
        )?;
        self.journal.flush()?;
        Ok(self.report(true, false, losses))
    }

    fn report(
        &self,
        completed: bool,
        paused: bool,
        mut losses: Vec<(usize, f32)>,
    ) -> CampaignReport {
        // the in-loop drain is amortized (bounds at 2x); enforce the
        // documented cap exactly at the reporting boundary
        if losses.len() > LOSS_RECORD_CAP {
            losses.drain(..losses.len() - LOSS_RECORD_CAP);
        }
        CampaignReport {
            completed,
            paused,
            final_step: self.trainer.step,
            recoveries: self.recoveries,
            final_loss: losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
            losses,
            snapshots: self.snapshots_written,
        }
    }

    /// Write a snapshot of the current trainer state and journal it.
    fn snapshot(&mut self, reason: &str, loss: f32) -> Result<()> {
        let st = TrainState::capture(&self.trainer, self.recoveries);
        let (path, bytes) = self.store.save(&st)?;
        self.snapshots_written += 1;
        self.journal.record(
            "snapshot",
            self.trainer.step,
            vec![
                ("reason", Json::Str(reason.into())),
                ("path", Json::Str(path.display().to_string())),
                ("bytes", Json::Num(bytes as f64)),
                ("loss", Json::Num(loss as f64)),
            ],
        )?;
        self.journal.flush()?;
        Ok(())
    }

    /// Newest snapshot that actually loads — see [`newest_loadable`].
    fn newest_loadable(&mut self) -> Result<Option<(usize, PathBuf, TrainState)>> {
        newest_loadable(&self.store, &mut self.journal)
    }

    /// Roll back to the newest good snapshot and re-enter with the
    /// next backoff level's scaling policy.
    fn rollback_and_perturb(&mut self) -> Result<()> {
        let (step, _path, st) = self
            .newest_loadable()?
            .ok_or_else(|| anyhow!("divergence with no loadable snapshot to roll back to"))?;
        st.apply_to(&mut self.trainer)?;
        self.recoveries += 1;
        let pol = self.recovery.scaling_policy(self.base_policy, self.recoveries);
        self.trainer.scale_mgr.reconfigure(pol);
        // re-baseline the cumulative overflow counter: the detector
        // trips on `overflow_events > overflow_limit` over the whole
        // run, so restoring the snapshot's count would leave each
        // recovery less headroom than the last until overflow-storm
        // recoveries become futile. A rollback is a deliberate
        // intervention (the policy changed), not a bit-exact replay —
        // fresh policy, fresh overflow budget.
        self.trainer.scale_mgr.overflow_events = 0;
        self.journal.record(
            "recovery",
            step,
            vec![
                ("rolled_back_to", Json::Num(step as f64)),
                ("attempt", Json::Num(self.recoveries as f64)),
                ("margin_pow2", Json::Num(pol.margin_pow2 as f64)),
                ("amax_history", Json::Num(pol.history_len as f64)),
            ],
        )?;
        self.journal.flush()?;
        // persist the recovered state immediately: the snapshot at the
        // rollback step now carries the incremented recovery count and
        // the perturbed policy, so a crash before the next periodic
        // snapshot cannot forget the consumed budget and replay the
        // divergence under the old policy
        self.snapshot("recovery", f32::NAN)?;
        Ok(())
    }
}

/// Newest snapshot in `store` that actually loads, skipping (and
/// journaling) any damaged file on the way down — defense in depth on
/// top of the atomic `Writer::finish` rename. Free function because
/// the reshard resume path needs it *before* a [`Campaign`] exists
/// (the snapshot's pinned logical plan feeds the trainer's config).
fn newest_loadable(
    store: &SnapshotStore,
    journal: &mut Journal,
) -> Result<Option<(usize, PathBuf, TrainState)>> {
    let mut all = store.list()?;
    while let Some((step, path)) = all.pop() {
        match TrainState::load(&path) {
            Ok(st) => return Ok(Some((step, path, st))),
            Err(e) => {
                // quarantine: move the damaged file aside so it stops
                // occupying a retention slot and isn't re-tried (and
                // re-journaled) on every subsequent rollback/resume;
                // the bytes stay on disk for a post-mortem
                let aside = path.with_extension("corrupt");
                let quarantined = std::fs::rename(&path, &aside).is_ok();
                journal.record(
                    "snapshot_corrupt",
                    step,
                    vec![
                        ("path", Json::Str(path.display().to_string())),
                        ("error", Json::Str(format!("{e:#}"))),
                        ("quarantined", Json::Bool(quarantined)),
                    ],
                )?;
                journal.flush()?;
            }
        }
    }
    Ok(None)
}

/// Options for [`Campaign::resume_opts`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ResumeOptions {
    /// Transform the newest snapshot to the config's physical topology
    /// (`dp_workers`/`pods`/`bucket_bytes`) instead of refusing the
    /// mismatch — the `campaign resume --reshard` flag. Numerics
    /// mismatches still refuse.
    pub reshard: bool,
}

/// In-memory cap on [`CampaignReport::losses`] — enough for any drill
/// or test to see the full record, flat memory for multi-week runs.
pub const LOSS_RECORD_CAP: usize = 65_536;

/// Default campaign directory for a config (`<out_dir>/campaign`).
pub fn default_dir(cfg: &TrainConfig) -> PathBuf {
    PathBuf::from(&cfg.out_dir).join("campaign")
}

/// Exclusive advisory lock on a campaign directory (`<dir>/LOCK`,
/// created with `create_new` = `O_EXCL`). Two processes driving one
/// campaign would interleave journal events, prune each other's
/// snapshots, and — worst — write the same `snap_*.tmp` path
/// concurrently, publishing a corrupt file through the atomic rename.
/// The lock file holds the owner's PID; it is removed on drop.
///
/// A crashed owner no longer strands the campaign forever: on an
/// `AlreadyExists` refusal, acquire reads the recorded pid and — on
/// Linux, where `/proc/<pid>` is an authoritative liveness probe —
/// reclaims the lock when the owner is provably dead (recorded in
/// [`DirLock::reclaimed_from`] so the campaign can journal a
/// `lock_reclaimed` event). A live owner, an unparsable lock file, or
/// a non-Linux host all still refuse conservatively — the error says
/// how to recover by hand.
pub struct DirLock {
    path: PathBuf,
    reclaimed_from: Option<u32>,
}

/// If `path` is a lock file whose recorded owner is *provably* dead,
/// return that pid; `None` means "do not touch it" (owner alive, file
/// unreadable/garbage, pid 0 or our own, or no trustworthy liveness
/// probe on this platform).
fn stale_lock_owner(path: &Path) -> Option<u32> {
    let pid: u32 = std::fs::read_to_string(path).ok()?.trim().parse().ok()?;
    if pid == 0 || pid == std::process::id() {
        return None;
    }
    #[cfg(target_os = "linux")]
    {
        // /proc/<pid> exists for zombies too, so a live-but-wedged
        // owner is never reclaimed out from under
        if Path::new(&format!("/proc/{pid}")).exists() {
            None
        } else {
            Some(pid)
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = pid; // no authoritative probe here: conservative refusal
        None
    }
}

impl DirLock {
    /// Take the exclusive lock on `dir`, reclaiming a provably-stale
    /// one (dead owner) exactly once before refusing.
    pub fn acquire(dir: &Path) -> Result<Self> {
        let path = dir.join("LOCK");
        let mut reclaimed_from = None;
        for attempt in 0..2 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    use std::io::Write as _;
                    let _ = writeln!(f, "{}", std::process::id());
                    return Ok(Self { path, reclaimed_from });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if attempt == 0 {
                        if let Some(pid) = stale_lock_owner(&path) {
                            std::fs::remove_file(&path).map_err(|e| {
                                anyhow!(
                                    "removing stale campaign lock {} (dead owner pid {pid}): {e}",
                                    path.display()
                                )
                            })?;
                            reclaimed_from = Some(pid);
                            continue; // one more create_new — a raced
                                      // rival winning it is a live lock
                        }
                    }
                    return Err(anyhow!(
                        "campaign dir is locked by another process ({} exists, owner pid \
                         inside) — locks with a provably dead owner are reclaimed \
                         automatically on Linux, so this owner is alive, unverifiable, or \
                         the file is unreadable; if you are certain the process is gone, \
                         delete the file and retry",
                        path.display()
                    ));
                }
                Err(e) => return Err(anyhow!("acquiring campaign lock {}: {e}", path.display())),
            }
        }
        unreachable!("lock acquire loop always returns")
    }

    /// Pid of the dead owner whose stale lock this acquire reclaimed,
    /// if any — the campaign journals it as a `lock_reclaimed` event.
    pub fn reclaimed_from(&self) -> Option<u32> {
        self.reclaimed_from
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        std::fs::remove_file(&self.path).ok();
    }
}
