//! Fleet observability: discover campaign directories under a root
//! and aggregate step/loss/divergence/recovery/reshard state across
//! all of them — one O(1)-memory streaming pass per journal.
//!
//! The paper's instabilities only show up over *prolonged* runs, so a
//! production deployment is never one campaign: it is a fleet of
//! them, and the operator's question is "who is running, who
//! diverged, who died" across the whole root. This module answers it
//! without ever holding a journal in memory: each campaign is folded
//! event-at-a-time ([`CampaignView::fold`]) off
//! [`journal::stream::JournalStream`], so a trillion-token campaign's
//! multi-GB journal costs one line buffer.
//!
//! Directory convention (see docs/OPERATIONS.md §Fleet operations): a
//! **campaign dir** is any directory holding a `journal.jsonl`;
//! [`discover`] walks the root a few levels deep and collects them,
//! so both the flat `<root>/<name>/journal.jsonl` layout and deeper
//! groupings work. The `campaign fleet` CLI subcommand renders the
//! result as a status table, loss trails, a divergence log, or a
//! Prometheus-style text exposition for dashboard scraping.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::journal::stream::JournalStream;
use super::store;
use crate::util::json::{obj, Json};

/// Cap on the per-campaign recent-loss and recent-divergence rings —
/// the fleet scan is O(1) memory per journal, so detail buffers are
/// bounded; the journal remains the full record.
pub const RECENT_CAP: usize = 16;

/// Cap on the retained reshard (topology) history per campaign;
/// overflow is counted, not silently dropped.
pub const RESHARD_CAP: usize = 64;

/// How deep [`discover`] walks below the fleet root.
const DISCOVER_DEPTH: usize = 4;

/// Journal event kinds whose most recent full event `status` prints —
/// tracked in O(1) during the fold.
const TRACKED_KINDS: [&str; 8] = [
    "divergence",
    "recovery",
    "reshard",
    "lock_reclaimed",
    "tail_repaired",
    "pause",
    "abort",
    "complete",
];

/// One divergence event as folded out of a journal stream.
#[derive(Clone, Debug)]
pub struct DivergenceEvent {
    /// Step the verdict tripped at.
    pub step: usize,
    /// Loss at the trip (NaN when the journal line carried none).
    pub loss: f64,
    /// Whether this was an injected drill rather than a real trip.
    pub injected: bool,
    /// Wall-clock stamp of the journal line.
    pub unix_ms: f64,
}

/// One reshard (topology change) event.
#[derive(Clone, Debug)]
pub struct ReshardEvent {
    /// Step the campaign continued from.
    pub step: usize,
    /// Physical-topology fingerprint before the reshard.
    pub from: String,
    /// Physical-topology fingerprint after the reshard.
    pub to: String,
}

/// State of a campaign dir's `LOCK` file, as far as it can be probed.
#[derive(Clone, Copy, Debug)]
pub struct LockInfo {
    /// Owner pid recorded in the lock file (None: unreadable/garbage).
    pub pid: Option<u32>,
    /// Liveness of that pid: `Some(true)` alive, `Some(false)`
    /// provably dead (Linux `/proc` probe), `None` unverifiable.
    pub live: Option<bool>,
}

/// Operational phase of one campaign, derived from its lock state and
/// the last journal event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Lock held by a live process — the campaign is running now.
    Running,
    /// Lock present but its owner is provably dead (crashed run; the
    /// next resume will reclaim it).
    StaleLock,
    /// Lock present, owner liveness unverifiable on this platform.
    Locked,
    /// Journal ends in `complete`.
    Complete,
    /// Journal ends in `abort` (recovery budget spent).
    Aborted,
    /// Journal ends in `pause` (orderly `stop_after`; resumable).
    Paused,
    /// Journal exists with events but no terminal event and no lock —
    /// killed or abandoned mid-run; resumable.
    Idle,
    /// No journal events at all.
    Empty,
    /// The scan itself failed (unreadable journal, oversized line) —
    /// see [`CampaignView::error`].
    Damaged,
}

impl Phase {
    /// Stable lowercase label (table cells, Prometheus label values).
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Running => "running",
            Phase::StaleLock => "stale-lock",
            Phase::Locked => "locked",
            Phase::Complete => "complete",
            Phase::Aborted => "aborted",
            Phase::Paused => "paused",
            Phase::Idle => "idle",
            Phase::Empty => "empty",
            Phase::Damaged => "damaged",
        }
    }
}

/// Everything the fleet layer knows about one campaign after a single
/// streaming pass over its journal plus a directory listing — the
/// shared aggregate behind `campaign status` and every `fleet`
/// subcommand.
#[derive(Clone, Debug)]
pub struct CampaignView {
    /// The campaign directory.
    pub dir: PathBuf,
    /// Display name (dir relative to the fleet root, or the dir
    /// itself for a single-campaign scan).
    pub name: String,
    /// Whether `journal.jsonl` exists.
    pub has_journal: bool,
    /// Parsed journal events.
    pub events: usize,
    /// Non-blank journal lines that did not parse (torn tails, crash
    /// fragments) — 0 on a healthy journal, ~1 per hard crash; more
    /// means damage. See docs/JOURNAL.md.
    pub skipped_lines: usize,
    /// Step of the last journal event (recoveries legitimately move
    /// this backwards; `max_step` is the high-water mark).
    pub last_step: usize,
    /// Highest step any event recorded.
    pub max_step: usize,
    /// Wall-clock stamp of the last event (ms since the epoch).
    pub last_unix_ms: f64,
    /// Event count per kind.
    pub counts: BTreeMap<String, usize>,
    /// Most recent finite loss from a `snapshot`/`complete` event
    /// (NaN until one is seen).
    pub last_loss: f64,
    /// Step `last_loss` was recorded at.
    pub last_loss_step: usize,
    /// Recent (step, loss) trail from snapshot/complete events,
    /// chronological, capped at [`RECENT_CAP`].
    pub recent_losses: VecDeque<(usize, f64)>,
    /// Recent divergence trips, chronological, capped at [`RECENT_CAP`].
    pub recent_divergences: VecDeque<DivergenceEvent>,
    /// Reshard (topology-change) history, capped at [`RESHARD_CAP`].
    pub reshards: Vec<ReshardEvent>,
    /// Reshard events beyond the cap (0 in any sane campaign).
    pub reshards_dropped: usize,
    /// Current physical-topology fingerprint, if any reshard recorded
    /// one.
    pub topology: Option<String>,
    /// Most recent full event per tracked kind (what `status` prints
    /// as `last <kind>: …`).
    pub last_of: BTreeMap<&'static str, Json>,
    /// The final journal event.
    pub last_event: Option<Json>,
    /// `snap_*.ckpt` files currently on disk.
    pub snapshots_on_disk: usize,
    /// `LOCK` file state, if present.
    pub lock: Option<LockInfo>,
    /// Scan failure, if the journal could not be streamed (the fleet
    /// view degrades this campaign to [`Phase::Damaged`] instead of
    /// failing the whole fleet).
    pub error: Option<String>,
}

impl CampaignView {
    fn empty(dir: &Path) -> Self {
        Self {
            dir: dir.to_path_buf(),
            name: dir.display().to_string(),
            has_journal: false,
            events: 0,
            skipped_lines: 0,
            last_step: 0,
            max_step: 0,
            last_unix_ms: 0.0,
            counts: BTreeMap::new(),
            last_loss: f64::NAN,
            last_loss_step: 0,
            recent_losses: VecDeque::new(),
            recent_divergences: VecDeque::new(),
            reshards: Vec::new(),
            reshards_dropped: 0,
            topology: None,
            last_of: BTreeMap::new(),
            last_event: None,
            snapshots_on_disk: 0,
            lock: None,
            error: None,
        }
    }

    /// Count of one event kind.
    pub fn count(&self, kind: &str) -> usize {
        self.counts.get(kind).copied().unwrap_or(0)
    }

    /// Fold one journal event into the view — the single-pass
    /// aggregation everything in this module is built on. O(1) per
    /// event: rings are capped, `last_of` tracks a fixed kind set.
    pub fn fold(&mut self, e: Json) {
        self.events += 1;
        let kind = e.get("event").and_then(|v| v.as_str()).unwrap_or("?").to_string();
        *self.counts.entry(kind.clone()).or_insert(0) += 1;
        let step = e.get("step").and_then(|v| v.as_usize()).unwrap_or(0);
        self.last_step = step;
        self.max_step = self.max_step.max(step);
        if let Some(ms) = e.get("unix_ms").and_then(|v| v.as_f64()) {
            self.last_unix_ms = ms;
        }
        match kind.as_str() {
            "snapshot" | "complete" => {
                let field = if kind == "complete" { "final_loss" } else { "loss" };
                if let Some(l) = e.get(field).and_then(|v| v.as_f64()).filter(|l| l.is_finite())
                {
                    self.last_loss = l;
                    self.last_loss_step = step;
                    if self.recent_losses.len() == RECENT_CAP {
                        self.recent_losses.pop_front();
                    }
                    self.recent_losses.push_back((step, l));
                }
            }
            "divergence" => {
                if self.recent_divergences.len() == RECENT_CAP {
                    self.recent_divergences.pop_front();
                }
                self.recent_divergences.push_back(DivergenceEvent {
                    step,
                    loss: e.get("loss").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                    injected: e.get("injected").and_then(|v| v.as_bool()).unwrap_or(false),
                    unix_ms: self.last_unix_ms,
                });
            }
            "reshard" => {
                let ev = ReshardEvent {
                    step,
                    from: e.str_or("from_topology", "?"),
                    to: e.str_or("to_topology", "?"),
                };
                self.topology = Some(ev.to.clone());
                if self.reshards.len() == RESHARD_CAP {
                    self.reshards.remove(0); // keep the most recent
                    self.reshards_dropped += 1;
                }
                self.reshards.push(ev);
            }
            _ => {}
        }
        if let Some(&k) = TRACKED_KINDS.iter().find(|&&k| k == kind) {
            self.last_of.insert(k, e.clone());
        }
        self.last_event = Some(e);
    }

    /// Operational phase — lock state first (a held lock means a
    /// process is, or died, driving this campaign), then the last
    /// journal event.
    pub fn phase(&self) -> Phase {
        if self.error.is_some() {
            return Phase::Damaged;
        }
        if let Some(l) = self.lock {
            return match l.live {
                Some(true) => Phase::Running,
                Some(false) => Phase::StaleLock,
                None => Phase::Locked,
            };
        }
        match self.last_event.as_ref().and_then(|e| e.get("event")).and_then(|v| v.as_str()) {
            Some("complete") => Phase::Complete,
            Some("abort") => Phase::Aborted,
            Some("pause") => Phase::Paused,
            Some(_) => Phase::Idle,
            None => Phase::Empty,
        }
    }

    /// The view as a JSON object (the `--json` export shape).
    pub fn to_json(&self) -> Json {
        let counts = Json::Obj(
            self.counts.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect(),
        );
        let losses = Json::Arr(
            self.recent_losses
                .iter()
                .map(|&(s, l)| Json::Arr(vec![Json::Num(s as f64), Json::Num(l)]))
                .collect(),
        );
        let lock = match self.lock {
            None => Json::Null,
            Some(l) => obj(vec![
                ("pid", l.pid.map(|p| Json::Num(p as f64)).unwrap_or(Json::Null)),
                ("live", l.live.map(Json::Bool).unwrap_or(Json::Null)),
            ]),
        };
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("dir", Json::Str(self.dir.display().to_string())),
            ("phase", Json::Str(self.phase().as_str().into())),
            ("last_step", Json::Num(self.last_step as f64)),
            ("max_step", Json::Num(self.max_step as f64)),
            ("last_loss", Json::Num(self.last_loss)), // null when NaN
            ("last_unix_ms", Json::Num(self.last_unix_ms)),
            ("events", Json::Num(self.events as f64)),
            ("skipped_lines", Json::Num(self.skipped_lines as f64)),
            ("snapshots_on_disk", Json::Num(self.snapshots_on_disk as f64)),
            ("counts", counts),
            (
                "topology",
                self.topology.clone().map(Json::Str).unwrap_or(Json::Null),
            ),
            ("recent_losses", losses),
            ("lock", lock),
            (
                "error",
                self.error.clone().map(Json::Str).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Probe a campaign dir's `LOCK` file. The file is a few bytes (owner
/// pid), so this is the one read in the fleet layer that is not
/// streamed.
fn lock_info(dir: &Path) -> Option<LockInfo> {
    let path = dir.join("LOCK");
    if !path.exists() {
        return None;
    }
    let pid: Option<u32> =
        std::fs::read_to_string(&path).ok().and_then(|s| s.trim().parse().ok());
    let live = pid.and_then(pid_live);
    Some(LockInfo { pid, live })
}

/// `Some(alive?)` on Linux (authoritative `/proc` probe, zombies
/// count as alive), `None` elsewhere.
fn pid_live(pid: u32) -> Option<bool> {
    #[cfg(target_os = "linux")]
    {
        Some(Path::new(&format!("/proc/{pid}")).exists())
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = pid;
        None
    }
}

/// Scan one campaign dir: a directory listing for the snapshot
/// inventory, the `LOCK` probe, and one streaming pass over the
/// journal. This is `campaign status`'s data source too — status and
/// fleet share one aggregator by construction.
pub fn scan_campaign(dir: &Path) -> Result<CampaignView> {
    let mut v = CampaignView::empty(dir);
    v.snapshots_on_disk = store::list_snapshots(dir.join("snapshots"))?.len();
    v.lock = lock_info(dir);
    let jpath = dir.join("journal.jsonl");
    if jpath.is_file() {
        v.has_journal = true;
        let mut s = JournalStream::from_path(&jpath)?;
        while let Some(e) = s.next_event()? {
            v.fold(e);
        }
        v.skipped_lines = s.skipped();
    }
    Ok(v)
}

/// Campaign directories under `root`: every directory (up to a few
/// levels deep) holding a `journal.jsonl`. A campaign dir's own
/// subtree is not descended into, `snapshots/` and dot-dirs are
/// skipped, and the root itself may be a campaign dir. Sorted for a
/// stable presentation order.
pub fn discover<P: AsRef<Path>>(root: P) -> Result<Vec<PathBuf>> {
    let root = root.as_ref();
    if !root.is_dir() {
        return Err(anyhow!(
            "fleet root {} is not a directory — expected a tree of campaign dirs \
             (each holding a journal.jsonl; see docs/OPERATIONS.md §Fleet operations)",
            root.display()
        ));
    }
    let mut out = Vec::new();
    walk(root, 0, &mut out);
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, depth: usize, out: &mut Vec<PathBuf>) {
    if dir.join("journal.jsonl").is_file() {
        out.push(dir.to_path_buf());
        return;
    }
    if depth >= DISCOVER_DEPTH {
        return;
    }
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name.starts_with('.') || name == "snapshots" {
            continue;
        }
        let p = entry.path();
        if p.is_dir() {
            walk(&p, depth + 1, out);
        }
    }
}

/// Fleet-level totals (the status footer / `fp8_fleet_*` metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetTotals {
    /// Campaign dirs discovered.
    pub campaigns: usize,
    /// Campaigns whose lock is held by a live process.
    pub running: usize,
    /// Campaigns whose journal ends in `complete`.
    pub complete: usize,
    /// Campaigns whose journal ends in `abort`.
    pub aborted: usize,
    /// Campaigns that could not be scanned.
    pub damaged: usize,
    /// Divergence trips across the fleet.
    pub divergences: usize,
    /// Recoveries across the fleet.
    pub recoveries: usize,
    /// Reshards across the fleet.
    pub reshards: usize,
    /// Skipped (unparseable) journal lines across the fleet.
    pub skipped_lines: usize,
}

/// The aggregated fleet: every campaign under one root, each scanned
/// in a single streaming pass.
pub struct FleetView {
    /// The root that was scanned.
    pub root: PathBuf,
    /// Per-campaign views, sorted by directory.
    pub campaigns: Vec<CampaignView>,
}

/// Scan every campaign under `root` — [`discover`] + one
/// [`scan_campaign`] each. A campaign whose scan fails degrades to
/// [`Phase::Damaged`] (with the error preserved) instead of failing
/// the fleet: the whole point of the fleet view is seeing the sick
/// nodes next to the healthy ones.
pub fn scan_root<P: AsRef<Path>>(root: P) -> Result<FleetView> {
    let root = root.as_ref().to_path_buf();
    let mut campaigns = Vec::new();
    for dir in discover(&root)? {
        let name = dir
            .strip_prefix(&root)
            .ok()
            .map(|p| p.display().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| dir.display().to_string());
        let view = match scan_campaign(&dir) {
            Ok(mut v) => {
                v.name = name;
                v
            }
            Err(e) => {
                let mut v = CampaignView::empty(&dir);
                v.name = name;
                v.has_journal = dir.join("journal.jsonl").is_file();
                v.error = Some(format!("{e:#}"));
                v
            }
        };
        campaigns.push(view);
    }
    Ok(FleetView { root, campaigns })
}

impl FleetView {
    /// Fleet-level rollup of the per-campaign views.
    pub fn totals(&self) -> FleetTotals {
        let mut t = FleetTotals { campaigns: self.campaigns.len(), ..Default::default() };
        for c in &self.campaigns {
            match c.phase() {
                Phase::Running => t.running += 1,
                Phase::Complete => t.complete += 1,
                Phase::Aborted => t.aborted += 1,
                Phase::Damaged => t.damaged += 1,
                _ => {}
            }
            t.divergences += c.count("divergence");
            t.recoveries += c.count("recovery");
            t.reshards += c.count("reshard");
            t.skipped_lines += c.skipped_lines;
        }
        t
    }

    /// The `fleet status` table: one row per campaign plus the rollup
    /// footer, with a damage warning when any journal skipped lines.
    pub fn render_status(&self) -> String {
        let mut out = String::new();
        let t = self.totals();
        out.push_str(&format!("fleet root: {}\n", self.root.display()));
        out.push_str(&format!(
            "{:<28} {:<10} {:>9} {:>10} {:>6} {:>4} {:>4} {:>5} {:>5}  {}\n",
            "CAMPAIGN", "PHASE", "STEP", "LOSS", "SNAPS", "DIV", "REC", "RESH", "SKIP", "LAST"
        ));
        for c in &self.campaigns {
            let loss = if c.last_loss.is_finite() {
                format!("{:.4}", c.last_loss)
            } else {
                "-".to_string()
            };
            let last = c
                .last_event
                .as_ref()
                .and_then(|e| e.get("event"))
                .and_then(|v| v.as_str())
                .unwrap_or("-");
            out.push_str(&format!(
                "{:<28} {:<10} {:>9} {:>10} {:>6} {:>4} {:>4} {:>5} {:>5}  {}\n",
                clip(&c.name, 28),
                c.phase().as_str(),
                c.last_step,
                loss,
                c.snapshots_on_disk,
                c.count("divergence"),
                c.count("recovery"),
                c.count("reshard"),
                c.skipped_lines,
                last,
            ));
            if let Some(e) = &c.error {
                out.push_str(&format!("  !! {e}\n"));
            }
        }
        out.push_str(&format!(
            "fleet: {} campaigns — {} running, {} complete, {} aborted, {} damaged; \
             {} divergences, {} recoveries, {} reshards\n",
            t.campaigns,
            t.running,
            t.complete,
            t.aborted,
            t.damaged,
            t.divergences,
            t.recoveries,
            t.reshards,
        ));
        if t.skipped_lines > 0 {
            out.push_str(&format!(
                "WARNING: {} unparseable journal line(s) skipped across the fleet — one \
                 torn tail per hard crash is the expected worst case; more means damage \
                 (docs/JOURNAL.md §Damage tolerance)\n",
                t.skipped_lines
            ));
        }
        out
    }

    /// The `fleet losses` view: each campaign's recent loss trail.
    pub fn render_losses(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("fleet root: {}\n", self.root.display()));
        for c in &self.campaigns {
            if c.last_loss.is_finite() {
                out.push_str(&format!(
                    "{:<28} loss {:.4} @ step {}",
                    clip(&c.name, 28),
                    c.last_loss,
                    c.last_loss_step
                ));
                let trail: Vec<String> = c
                    .recent_losses
                    .iter()
                    .map(|&(s, l)| format!("{s}:{l:.3}"))
                    .collect();
                out.push_str(&format!("  | {}\n", trail.join(" ")));
            } else {
                out.push_str(&format!(
                    "{:<28} no loss recorded ({})\n",
                    clip(&c.name, 28),
                    c.phase().as_str()
                ));
            }
        }
        out
    }

    /// The `fleet divergences` view: recent trips across the fleet in
    /// wall-clock order, with each campaign's recovery tally.
    pub fn render_divergences(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("fleet root: {}\n", self.root.display()));
        let mut rows: Vec<(f64, &str, &DivergenceEvent)> = Vec::new();
        for c in &self.campaigns {
            for d in &c.recent_divergences {
                rows.push((d.unix_ms, &c.name, d));
            }
        }
        rows.sort_by(|a, b| a.0.total_cmp(&b.0));
        if rows.is_empty() {
            out.push_str("no divergences recorded\n");
        }
        for (_, name, d) in rows {
            let loss =
                if d.loss.is_finite() { format!("{:.4}", d.loss) } else { "-".to_string() };
            out.push_str(&format!(
                "{:<28} step {:>9}  loss {:>10}  {}\n",
                clip(name, 28),
                d.step,
                loss,
                if d.injected { "injected (drill)" } else { "real" },
            ));
        }
        for c in &self.campaigns {
            if c.count("divergence") > 0 {
                out.push_str(&format!(
                    "{:<28} {} divergence(s), {} recovery(ies), budget state: {}\n",
                    clip(&c.name, 28),
                    c.count("divergence"),
                    c.count("recovery"),
                    if c.count("abort") > 0 { "EXHAUSTED (aborted)" } else { "ok" },
                ));
            }
        }
        out
    }

    /// Prometheus-style text exposition of the fleet (the
    /// `fleet metrics` default output) — gauge/counter families keyed
    /// by a `campaign` label, suitable for a node-exporter textfile
    /// collector or any scrape-to-file cron. Format reference:
    /// docs/OPERATIONS.md §Fleet operations.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let t = self.totals();
        let fleet_gauges: [(&str, &str, f64); 4] = [
            ("fp8_fleet_campaigns", "Campaign dirs discovered under the root.", t.campaigns as f64),
            ("fp8_fleet_running", "Campaigns whose LOCK is held by a live process.", t.running as f64),
            ("fp8_fleet_damaged", "Campaigns whose scan failed.", t.damaged as f64),
            (
                "fp8_fleet_journal_skipped_lines",
                "Unparseable journal lines across the fleet (damage signal).",
                t.skipped_lines as f64,
            ),
        ];
        for (name, help, v) in fleet_gauges {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        }
        type Get = fn(&CampaignView) -> f64;
        let families: [(&str, &str, &str, Get); 9] = [
            (
                "fp8_campaign_last_step",
                "Step of the last journal event.",
                "gauge",
                (|c| c.last_step as f64) as Get,
            ),
            (
                "fp8_campaign_max_step",
                "High-water-mark step across the journal.",
                "gauge",
                |c| c.max_step as f64,
            ),
            (
                "fp8_campaign_journal_events",
                "Parsed journal events.",
                "counter",
                |c| c.events as f64,
            ),
            (
                "fp8_campaign_journal_skipped_lines",
                "Unparseable journal lines (damage signal; ~1 per hard crash).",
                "gauge",
                |c| c.skipped_lines as f64,
            ),
            (
                "fp8_campaign_divergences",
                "Divergence trips journaled.",
                "counter",
                |c| c.count("divergence") as f64,
            ),
            (
                "fp8_campaign_recoveries",
                "Rollback-and-perturb recoveries journaled.",
                "counter",
                |c| c.count("recovery") as f64,
            ),
            (
                "fp8_campaign_reshards",
                "Topology reshards journaled.",
                "counter",
                |c| c.count("reshard") as f64,
            ),
            (
                "fp8_campaign_snapshots_on_disk",
                "snap_*.ckpt files currently retained.",
                "gauge",
                |c| c.snapshots_on_disk as f64,
            ),
            (
                "fp8_campaign_last_event_unix_ms",
                "Wall-clock stamp of the last journal event.",
                "gauge",
                |c| c.last_unix_ms,
            ),
        ];
        for (name, help, ty, get) in families {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {ty}\n"));
            for c in &self.campaigns {
                out.push_str(&format!(
                    "{name}{{campaign=\"{}\"}} {}\n",
                    prom_escape(&c.name),
                    get(c)
                ));
            }
        }
        // last_loss separately: NaN (no loss yet) must be omitted, not
        // emitted — Prometheus treats NaN as a real sample
        out.push_str(
            "# HELP fp8_campaign_last_loss Most recent finite loss from a snapshot/complete \
             event.\n# TYPE fp8_campaign_last_loss gauge\n",
        );
        for c in &self.campaigns {
            if c.last_loss.is_finite() {
                out.push_str(&format!(
                    "fp8_campaign_last_loss{{campaign=\"{}\"}} {}\n",
                    prom_escape(&c.name),
                    c.last_loss
                ));
            }
        }
        // phase as a one-hot info-style series
        out.push_str(
            "# HELP fp8_campaign_phase Operational phase (one series per campaign, value 1).\
             \n# TYPE fp8_campaign_phase gauge\n",
        );
        for c in &self.campaigns {
            out.push_str(&format!(
                "fp8_campaign_phase{{campaign=\"{}\",phase=\"{}\"}} 1\n",
                prom_escape(&c.name),
                c.phase().as_str()
            ));
        }
        out
    }

    /// The whole fleet as one JSON object (the `--json` export).
    pub fn to_json(&self) -> Json {
        let t = self.totals();
        obj(vec![
            ("root", Json::Str(self.root.display().to_string())),
            (
                "campaigns",
                Json::Arr(self.campaigns.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "totals",
                obj(vec![
                    ("campaigns", Json::Num(t.campaigns as f64)),
                    ("running", Json::Num(t.running as f64)),
                    ("complete", Json::Num(t.complete as f64)),
                    ("aborted", Json::Num(t.aborted as f64)),
                    ("damaged", Json::Num(t.damaged as f64)),
                    ("divergences", Json::Num(t.divergences as f64)),
                    ("recoveries", Json::Num(t.recoveries as f64)),
                    ("reshards", Json::Num(t.reshards as f64)),
                    ("skipped_lines", Json::Num(t.skipped_lines as f64)),
                ]),
            ),
        ])
    }
}

/// Truncate a name to `max` chars for table cells (full name in JSON).
fn clip(s: &str, max: usize) -> String {
    if s.chars().count() <= max {
        s.to_string()
    } else {
        let cut: String = s.chars().take(max.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

/// Escape a Prometheus label value (`\` → `\\`, `"` → `\"`, newline →
/// `\n`, per the text-exposition spec). Shared with the serving
/// layer's `/v1/metrics` endpoint.
pub fn prom_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: &str, step: usize, extra: Vec<(&str, Json)>) -> Json {
        let mut fields = vec![
            ("event", Json::Str(kind.into())),
            ("step", Json::Num(step as f64)),
            ("unix_ms", Json::Num(1000.0 + step as f64)),
        ];
        fields.extend(extra);
        obj(fields)
    }

    #[test]
    fn fold_tracks_counts_losses_and_phase() {
        let mut v = CampaignView::empty(Path::new("/tmp/x"));
        v.fold(ev("campaign_start", 0, vec![]));
        v.fold(ev("snapshot", 10, vec![("loss", Json::Num(3.0))]));
        v.fold(ev("snapshot", 20, vec![("loss", Json::Null)])); // NaN loss → skipped
        v.fold(ev("divergence", 25, vec![("loss", Json::Num(9.9)), ("injected", Json::Bool(true))]));
        v.fold(ev("recovery", 20, vec![]));
        v.fold(ev(
            "reshard",
            20,
            vec![
                ("from_topology", Json::Str("shard=w4".into())),
                ("to_topology", Json::Str("shard=w3".into())),
            ],
        ));
        v.fold(ev("complete", 30, vec![("final_loss", Json::Num(2.5))]));
        assert_eq!(v.events, 7);
        assert_eq!(v.count("snapshot"), 2);
        assert_eq!(v.count("divergence"), 1);
        assert_eq!(v.last_loss, 2.5);
        assert_eq!(v.last_loss_step, 30);
        assert_eq!(v.recent_losses.len(), 2, "null loss excluded from the trail");
        assert_eq!(v.max_step, 30);
        assert_eq!(v.last_step, 30);
        assert_eq!(v.topology.as_deref(), Some("shard=w3"));
        assert_eq!(v.reshards.len(), 1);
        assert!(v.recent_divergences[0].injected);
        assert_eq!(v.phase(), Phase::Complete);
        assert!(v.last_of.contains_key("recovery"));
        // lock state dominates the terminal event
        v.lock = Some(LockInfo { pid: Some(1), live: Some(true) });
        assert_eq!(v.phase(), Phase::Running);
        v.lock = Some(LockInfo { pid: Some(1), live: Some(false) });
        assert_eq!(v.phase(), Phase::StaleLock);
    }

    #[test]
    fn rings_stay_bounded() {
        let mut v = CampaignView::empty(Path::new("/tmp/x"));
        for i in 0..(RECENT_CAP * 3) {
            v.fold(ev("snapshot", i, vec![("loss", Json::Num(i as f64))]));
            v.fold(ev("divergence", i, vec![("loss", Json::Num(9.0))]));
        }
        assert_eq!(v.recent_losses.len(), RECENT_CAP);
        assert_eq!(v.recent_divergences.len(), RECENT_CAP);
        assert_eq!(v.recent_losses.back().unwrap().0, RECENT_CAP * 3 - 1);
        assert_eq!(v.events, RECENT_CAP * 6);
    }

    #[test]
    fn prometheus_escaping_and_shape() {
        let mut v = CampaignView::empty(Path::new("/tmp/we\"ird"));
        v.name = "we\"ird\\name".into();
        v.fold(ev("snapshot", 5, vec![("loss", Json::Num(1.5))]));
        let fleet = FleetView { root: PathBuf::from("/tmp"), campaigns: vec![v] };
        let text = fleet.render_prometheus();
        assert!(text.contains(r#"campaign="we\"ird\\name""#), "label escaped: {text}");
        assert!(text.contains("fp8_fleet_campaigns 1"));
        assert!(text.contains("# TYPE fp8_campaign_last_step gauge"));
        assert!(text.contains("fp8_campaign_last_loss{campaign"));
        // every non-comment line is `name{labels} value` or `name value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (series, val) = line.rsplit_once(' ').expect("sample line");
            assert!(!series.is_empty());
            assert!(val.parse::<f64>().is_ok(), "bad sample value in: {line}");
        }
    }

    #[test]
    fn empty_and_idle_phases() {
        let v = CampaignView::empty(Path::new("/tmp/x"));
        assert_eq!(v.phase(), Phase::Empty);
        let mut v = CampaignView::empty(Path::new("/tmp/x"));
        v.fold(ev("campaign_start", 0, vec![]));
        assert_eq!(v.phase(), Phase::Idle);
        v.fold(ev("pause", 7, vec![]));
        assert_eq!(v.phase(), Phase::Paused);
        let mut d = CampaignView::empty(Path::new("/tmp/x"));
        d.error = Some("boom".into());
        assert_eq!(d.phase(), Phase::Damaged);
    }
}
