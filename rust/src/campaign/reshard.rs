//! Deterministic elastic resharding — `campaign resume --reshard`.
//!
//! A trillion-token campaign outlives its fleet: nodes die, pods get
//! rearranged, a worker count that was right in week one is wrong in
//! week six. The snapshot fingerprint splits the run's identity into a
//! **numerics** term (everything the loss curve is a function of —
//! pinned forever) and a **physical topology** term
//! (`shard=w…;topo=p…;bucket=b…` — provably bit-invisible). This
//! module transforms the latter: given a snapshot and a config whose
//! numerics match but whose physical topology differs, it proves the
//! snapshot's FP8 Adam moment state re-partitions bit-exactly onto the
//! new `ShardLayout` and rewrites the snapshot's topology metadata.
//!
//! Why the proof is cheap: snapshots store moments *flat* (already
//! gathered from the old shards), and the ZeRO-1 owner map is
//! chunk-aligned on the **absolute** Adam chunk grid — every per-chunk
//! FP8 scale group has exactly one owner under any worker count, so
//! scattering the flat buffer into W′ shards and gathering it back is
//! the identity on bits. The transform still *verifies* that identity
//! per moment buffer (repartition → pack exact-FP8 → gather → bit
//! compare) and refuses before anything touches disk if a single bit
//! moves — a corrupted buffer or a future layout bug produces a
//! refusal, never a forked snapshot.
//!
//! The logical stream plan (`streams`/`stream_pods` in the meta) is
//! untouched: it is numerics identity, pinned at campaign start, and
//! the resume path adopts it into the new config so the batch
//! schedule, merge order, and collective summation tree stay exactly
//! what they were on the old topology.

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::optimizer::{gather, repartition, MomentStore, ShardLayout};

use super::snapshot::{
    diff_fingerprint_terms, numerics_fingerprint, render_term_diff, topology_fingerprint,
    TrainState,
};

/// What a reshard did — journaled as the `reshard` event and echoed by
/// the CLI so the operator sees the old→new arrangement explicitly.
#[derive(Clone, Debug)]
pub struct ReshardReport {
    /// snapshot step the transform ran at
    pub step: usize,
    /// ZeRO-1 shard count the snapshot was captured under
    pub from_workers: usize,
    /// shard count it was transformed to
    pub to_workers: usize,
    /// full physical-topology fingerprint at capture
    pub from_topology: String,
    /// full physical-topology fingerprint after the transform
    pub to_topology: String,
}

/// Transform `st` to `cfg`'s physical topology. Pure — returns the new
/// state; the caller decides when (and whether) it reaches disk.
///
/// Refuses when:
/// * the numerics fingerprints differ (resharding never changes the
///   curve — a numerics change is a different run, not a topology
///   move);
/// * any identity field differs (recipe/size/seed/corpus
///   seed/grad_accum/schedule);
/// * the roundtrip verification finds a bit that does not survive the
///   re-partition (corrupt state, or a layout invariant broken).
///
/// `adam_chunk` is the live trainer's Adam artifact chunk — the grid
/// the new shard boundaries must align to. The numerics check already
/// pins it (`grid=c…`), so a mismatch with the snapshot's recorded
/// `moment_chunk` is impossible past that gate.
pub fn reshard_state(
    st: &TrainState,
    cfg: &TrainConfig,
    adam_chunk: usize,
) -> Result<(TrainState, ReshardReport)> {
    reshard_state_with(st, cfg, adam_chunk, None)
}

/// [`reshard_state`] with a corrupt-injection hook for the refusal
/// drill: `inject_corrupt_shard = Some(i)` flips one bit in the i-th
/// re-packed shard of the first moment before verification, proving
/// the roundtrip gate actually refuses. Not a production entry point.
#[doc(hidden)]
pub fn reshard_state_with(
    st: &TrainState,
    cfg: &TrainConfig,
    adam_chunk: usize,
    inject_corrupt_shard: Option<usize>,
) -> Result<(TrainState, ReshardReport)> {
    let m = &st.meta;
    let cfg_numerics = numerics_fingerprint(cfg, adam_chunk);
    if m.numerics != cfg_numerics {
        let diff = diff_fingerprint_terms(&m.numerics, &cfg_numerics);
        bail!(
            "reshard refused: numerics term(s) differ [{}] — resharding only moves \
             physical topology; a numerics change would fork the curve",
            render_term_diff(&diff)
        );
    }
    let identity: [(&str, String, String); 6] = [
        ("recipe", m.recipe.clone(), cfg.recipe.clone()),
        ("size", m.size.clone(), cfg.size.clone()),
        ("seed", m.seed.to_string(), cfg.seed.to_string()),
        ("corpus_seed", m.corpus_seed.to_string(), cfg.corpus_seed().to_string()),
        ("grad_accum", m.grad_accum.to_string(), cfg.grad_accum.to_string()),
        (
            "steps/warmup",
            format!("{}/{}", m.steps, m.warmup_steps),
            format!("{}/{}", cfg.steps, cfg.warmup_steps),
        ),
    ];
    for (what, snap, new) in &identity {
        if snap != new {
            bail!(
                "reshard refused: identity mismatch on {what} (snapshot '{snap}', config \
                 '{new}') — reshard continues the same run on new hardware, it does not \
                 start a different one"
            );
        }
    }
    let to_topology = topology_fingerprint(cfg);
    let chunk = adam_chunk.max(1);
    let layout = ShardLayout::chunk_aligned(st.m.len(), cfg.dp_workers, chunk);
    let m_store = MomentStore::from_name(&m.m_fmt);
    verify_roundtrip(&st.m, &layout, m_store, "adam.m", inject_corrupt_shard)?;
    let v_layout = ShardLayout::chunk_aligned(st.v.len(), cfg.dp_workers, chunk);
    verify_roundtrip(&st.v, &v_layout, MomentStore::from_name(&m.v_fmt), "adam.v", None)?;

    let mut new_st = st.clone();
    new_st.meta.dp_workers = cfg.dp_workers;
    new_st.meta.topology = to_topology.clone();
    let report = ReshardReport {
        step: m.step,
        from_workers: m.dp_workers,
        to_workers: cfg.dp_workers,
        from_topology: m.topology.clone(),
        to_topology,
    };
    Ok((new_st, report))
}

/// Scatter `flat` into the new layout's shards (exact-FP8 re-pack),
/// gather them back, and demand bitwise identity — the proof that the
/// new partition stores exactly the state the old one did. Runs
/// entirely in memory; a refusal here means nothing was written.
fn verify_roundtrip(
    flat: &[f32],
    layout: &ShardLayout,
    store: MomentStore,
    label: &str,
    inject_corrupt_shard: Option<usize>,
) -> Result<()> {
    let mut shards = repartition(flat, layout, store);
    if let Some(i) = inject_corrupt_shard {
        if let Some(s) = shards.get_mut(i) {
            s.corrupt_one_bit_for_test();
        }
    }
    let back = gather(&shards);
    if back.len() != flat.len() {
        bail!(
            "reshard refused: {label} roundtrip changed length ({} -> {}) — aborting \
             before writing anything",
            flat.len(),
            back.len()
        );
    }
    for (i, (a, b)) in flat.iter().zip(&back).enumerate() {
        if a.to_bits() != b.to_bits() {
            bail!(
                "reshard refused: {label}[{i}] does not survive the re-partition \
                 ({a:?} -> {b:?}, bits {:08x} -> {:08x}) — the snapshot state is not on \
                 the expected per-chunk FP8 grid (corrupt state or a layout bug); \
                 aborting before writing anything",
                a.to_bits(),
                b.to_bits()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::snapshot::{numerics_fingerprint, topology_fingerprint, SnapshotMeta};
    use crate::coordinator::DetectorState;
    use crate::scaling::ScaleState;

    /// Build a minimal in-grid TrainState for a config: moment values
    /// that are exactly representable per-chunk (zeros + small powers
    /// of two), so the exact-FP8 roundtrip must hold.
    fn state_for(cfg: &TrainConfig, chunk: usize, total: usize) -> TrainState {
        let mut m = vec![0.0f32; total];
        let mut v = vec![0.0f32; total];
        for (i, (mi, vi)) in m.iter_mut().zip(v.iter_mut()).enumerate() {
            *mi = ((i % 7) as f32) * 0.25;
            *vi = ((i % 5) as f32) * 0.5;
        }
        TrainState {
            meta: SnapshotMeta {
                step: 3,
                recipe: cfg.recipe.clone(),
                size: cfg.size.clone(),
                seed: cfg.seed,
                corpus_seed: cfg.corpus_seed(),
                dp_workers: cfg.dp_workers,
                streams: cfg.streams(),
                stream_pods: cfg.stream_pod_count(),
                grad_accum: cfg.grad_accum,
                steps: cfg.steps,
                warmup_steps: cfg.warmup_steps,
                amax_history: cfg.amax_history,
                margin_pow2: cfg.margin_pow2,
                recoveries: 0,
                m_fmt: "e4m3".into(),
                v_fmt: "e5m2".into(),
                moment_chunk: chunk,
                numerics: numerics_fingerprint(cfg, chunk),
                topology: topology_fingerprint(cfg),
            },
            params: vec![("w".into(), vec![0.0; total])],
            m,
            v,
            scale: ScaleState { histories: vec![], scales: vec![], overflow_events: 0 },
            detector: DetectorState { ema: 0.0, warmed: false, diverged_at: None },
        }
    }

    #[test]
    fn reshard_rewrites_topology_and_nothing_else() {
        let old = TrainConfig { dp_workers: 4, pods: 2, ..Default::default() };
        let chunk = 64;
        let st = state_for(&old, chunk, 64 * 5 + 17);
        // shrink to 3 workers / 1 pod, logical plan pinned to the old
        // shape (what resume_opts' adoption produces)
        let new = TrainConfig {
            dp_workers: 3,
            pods: 1,
            grad_streams: 4,
            stream_pods: 2,
            ..Default::default()
        };
        assert_eq!(st.meta.numerics, numerics_fingerprint(&new, chunk), "plan pinned");
        let (out, rep) = reshard_state(&st, &new, chunk).expect("reshard");
        assert_eq!(out.meta.dp_workers, 3);
        assert_eq!(out.meta.topology, topology_fingerprint(&new));
        assert_eq!(rep.from_workers, 4);
        assert_eq!(rep.to_workers, 3);
        // every numeric payload and every other meta field is untouched
        assert_eq!(out.m, st.m);
        assert_eq!(out.v, st.v);
        assert_eq!(out.meta.streams, st.meta.streams);
        assert_eq!(out.meta.numerics, st.meta.numerics);
        assert_eq!(out.meta.step, st.meta.step);
    }

    #[test]
    fn reshard_refuses_numerics_change_and_corrupt_shard() {
        let old = TrainConfig { dp_workers: 2, ..Default::default() };
        let chunk = 32;
        let st = state_for(&old, chunk, 32 * 3 + 5);
        // a numerics change (lr) must refuse even with --reshard
        let mut hot = TrainConfig { dp_workers: 1, grad_streams: 2, ..Default::default() };
        hot.lr *= 2.0;
        let err = reshard_state(&st, &hot, chunk).unwrap_err().to_string();
        assert!(err.contains("numerics"), "refusal must name the numerics term: {err}");
        assert!(err.contains("lr:"), "diff must name the changed key: {err}");

        // corrupt-injection: the roundtrip gate refuses, nothing forks
        let new = TrainConfig { dp_workers: 1, grad_streams: 2, ..Default::default() };
        let err = reshard_state_with(&st, &new, chunk, Some(0)).unwrap_err().to_string();
        assert!(
            err.contains("does not survive") || err.contains("roundtrip"),
            "corrupt shard must trip the roundtrip verification: {err}"
        );
        // and without injection the same transform succeeds
        reshard_state(&st, &new, chunk).expect("clean reshard");
    }
}
