//! Device descriptions for the analytic model.
//!
//! Effective rates are *achieved* (not peak) rates calibrated so the
//! BF16 row lands at the paper's measured TFLOPS (311 on 8×Gaudi2,
//! 76 on 8×A6000 — Tables 3/5); the FP8:BF16 rate ratio is the
//! architectural 2× less a de-rate for scale handling.

#[derive(Clone, Debug)]
pub struct Device {
    pub name: &'static str,
    /// achieved bf16 matmul FLOP/s for this 8-device setup
    pub bf16_flops: f64,
    /// achieved fp8 matmul FLOP/s
    pub fp8_flops: f64,
    /// fractional step-time overhead of per-tensor cast/scale handling
    pub quant_overhead: f64,
    /// additional overhead of the per-channel Smooth-SwiGLU pass
    pub smooth_overhead: f64,
}

/// 8× Intel Gaudi2 (Table 3). Calibrated: BF16 row = 311 TFLOPS with
/// a 20% non-matmul slice; achieved FP8:BF16 matmul ratio 1.52×
/// (architectural 2× de-rated for scale handling — the paper's own
/// end-to-end gain of +37% at 22% non-matmul implies this ratio).
pub const GAUDI2: Device = Device {
    name: "8x Intel Gaudi2",
    bf16_flops: 389e12,
    fp8_flops: 589e12,
    quant_overhead: 0.008,
    smooth_overhead: 0.025,
};

/// 8× NVIDIA A6000 Ada (Table 5). Calibrated: BF16 row = 76 TFLOPS.
pub const A6000_ADA: Device = Device {
    name: "8x NVIDIA A6000 Ada",
    bf16_flops: 95e12,
    fp8_flops: 144e12,
    quant_overhead: 0.008,
    smooth_overhead: 0.025,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_rate_is_achievable_fraction_of_2x() {
        for d in [&GAUDI2, &A6000_ADA] {
            let r = d.fp8_flops / d.bf16_flops;
            assert!(r > 1.3 && r < 2.0, "{}: {r}", d.name);
        }
    }
}
