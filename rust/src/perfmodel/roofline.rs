//! Structural cost model for the L1 Pallas kernels (DESIGN.md §Perf).
//!
//! Interpret-mode wall-clock says nothing about TPU behaviour, so the
//! kernels are costed from their BlockSpecs: VMEM footprint per grid
//! step (must fit the ~16 MiB/core budget with double-buffering) and
//! arithmetic intensity (FLOPs per HBM byte) against the MXU/VPU
//! roofline.

/// TPU-like per-core VMEM budget used for the estimates.
pub const VMEM_BYTES: usize = 16 * 1024 * 1024;
/// Achieved HBM bandwidth assumed by the estimates, GB/s (also the
/// rate `perfmodel::interconnect` costs on-device qdq passes at).
pub const HBM_GBPS: f64 = 800.0;
/// Achieved MXU bf16 matmul rate, TFLOP/s.
pub const MXU_BF16_TFLOPS: f64 = 180.0;
/// Achieved VPU elementwise rate, GFLOP/s.
pub const VPU_GFLOPS: f64 = 4_000.0;

/// Structural cost estimate of one Pallas kernel at a block shape.
#[derive(Clone, Debug)]
pub struct KernelEstimate {
    /// kernel + block-shape label
    pub name: String,
    /// VMEM resident bytes per grid step (single-buffered)
    pub vmem_bytes: usize,
    /// whether the double-buffered footprint fits [`VMEM_BYTES`]
    pub vmem_ok: bool,
    /// FLOPs per byte moved HBM<->VMEM
    pub arithmetic_intensity: f64,
    /// min achievable time vs the memory-bound floor (1.0 = at roofline)
    pub roofline_fraction: f64,
    /// which resource bounds the kernel ("memory" | "mxu" | "vector")
    pub bound: &'static str,
}

/// Smooth-SwiGLU fused kernel: two [bt, f] inputs + one output tile +
/// the [1, f] scale row resident; two passes over the data.
pub fn smooth_swiglu(block_tokens: usize, d_ff: usize) -> KernelEstimate {
    let tile = block_tokens * d_ff * 4;
    let vmem = 2 * tile /* a1,a2 */ + tile /* out */ + d_ff * 4 * 2 /* scales+max */;
    // per element: swish(~6 flops) + mul + max + scale + quantize(~6) ≈ 15
    // bytes: 2 passes read a1,a2 (2·2·4) + write q (4) = 20 B/elem
    let flops_per_elem = 15.0;
    let bytes_per_elem = 20.0;
    let ai = flops_per_elem / bytes_per_elem;
    // vector-bound kernel: time = max(mem, vpu)
    let t_mem = bytes_per_elem / (HBM_GBPS * 1e9);
    let t_vpu = flops_per_elem / (VPU_GFLOPS * 1e9);
    KernelEstimate {
        name: format!("smooth_swiglu[{block_tokens}x{d_ff}]"),
        vmem_bytes: vmem,
        vmem_ok: vmem * 2 <= VMEM_BYTES, // double-buffered
        arithmetic_intensity: ai,
        roofline_fraction: t_mem / t_mem.max(t_vpu),
        bound: if t_mem >= t_vpu { "memory" } else { "vector" },
    }
}

/// FP8 matmul kernel: whole-op (m, k) × (k, n) with (bm, bn, bk) VMEM
/// tiles. HBM traffic is counted at the op level (each operand read
/// once, output written once — the K-loop keeps the accumulator tile
/// resident, the BlockSpec re-reads are VMEM-side).
pub fn fp8_matmul(m: usize, n: usize, k: usize, bm: usize, bn: usize, bk: usize) -> KernelEstimate {
    let vmem = (bm * bk + bk * bn + bm * bn) * 4;
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let bytes = (m * k + k * n) as f64 * 1.0 /* fp8 operands */ + (m * n) as f64 * 4.0;
    let ai = flops / bytes;
    let t_mem = bytes / (HBM_GBPS * 1e9);
    let t_mxu = flops / (MXU_BF16_TFLOPS * 1e12 * 2.0 /* fp8 2x */);
    KernelEstimate {
        name: format!("fp8_matmul[{m}x{n}x{k} @ {bm}x{bn}x{bk}]"),
        vmem_bytes: vmem,
        vmem_ok: vmem * 2 <= VMEM_BYTES,
        arithmetic_intensity: ai,
        roofline_fraction: t_mxu / t_mxu.max(t_mem),
        bound: if t_mxu >= t_mem { "mxu" } else { "memory" },
    }
}

/// The tile-wise-scaled FP8 GEMM (`gemm::GemmConfig`) at square
/// per-tile-scale tiles: [`fp8_matmul`] with `bm = bn = bk = tile`,
/// which is also how the host reference in `gemm::matmul` walks the
/// operands. The per-tile f32 scale traffic
/// (`⌈m/t⌉·⌈k/t⌉ + ⌈k/t⌉·⌈n/t⌉` extra words) is ≤ 1/t² of the operand
/// bytes — below the model's resolution — so the estimate is the
/// plain FP8 matmul roofline at that block shape. The perf bench
/// records this next to the measured host throughput so the
/// measured-vs-predicted gap is a tracked artifact.
pub fn tiled_gemm(m: usize, n: usize, k: usize, tile: usize) -> KernelEstimate {
    let mut e = fp8_matmul(m, n, k, tile, tile, tile);
    e.name = format!("tiled_gemm[{m}x{n}x{k} @ t{tile}]");
    e
}

/// Elementwise Adam: 4 reads + 3 writes of f32 (or 1-byte moments).
pub fn adam_update(block: usize, fp8_moments: bool) -> KernelEstimate {
    let vmem = block * 4 * 7;
    let moment_bytes = if fp8_moments { 1.0 } else { 4.0 };
    let bytes = 2.0 * 4.0 /* p rw */ + 4.0 /* g */ + 4.0 * moment_bytes /* m,v rw */;
    let flops = 14.0;
    let t_mem = bytes / (HBM_GBPS * 1e9);
    let t_vpu = flops / (VPU_GFLOPS * 1e9);
    KernelEstimate {
        name: format!("adam[{block}]{}", if fp8_moments { " fp8-moments" } else { "" }),
        vmem_bytes: vmem,
        vmem_ok: vmem * 2 <= VMEM_BYTES,
        arithmetic_intensity: flops / bytes,
        roofline_fraction: t_mem / t_mem.max(t_vpu),
        bound: "memory",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn production_block_shapes_fit_vmem() {
        assert!(smooth_swiglu(128, 2048).vmem_ok);
        assert!(fp8_matmul(2048, 2048, 2048, 128, 128, 128).vmem_ok);
        assert!(adam_update(65536, true).vmem_ok);
    }

    #[test]
    fn matmul_is_compute_bound_at_model_shapes() {
        // m100's d_ff matmul: [tokens=512, d=768] x [768, 2048]
        let e = fp8_matmul(2048, 2048, 2048, 128, 128, 128);
        assert_eq!(e.bound, "mxu");
        assert!(e.roofline_fraction > 0.9);
    }

    #[test]
    fn tiled_gemm_matches_fp8_matmul_at_square_blocks() {
        let a = tiled_gemm(512, 256, 128, 128);
        let b = fp8_matmul(512, 256, 128, 128, 128, 128);
        assert_eq!(a.vmem_bytes, b.vmem_bytes);
        assert_eq!(a.bound, b.bound);
        assert!((a.roofline_fraction - b.roofline_fraction).abs() < 1e-12);
        assert!(a.name.contains("t128"), "{}", a.name);
        // the default 128-tile double-buffers comfortably in VMEM
        assert!(a.vmem_ok);
    }

    #[test]
    fn smooth_swiglu_is_memory_bound() {
        let e = smooth_swiglu(128, 2048);
        assert_eq!(e.bound, "memory");
    }

    #[test]
    fn fp8_moments_cut_adam_traffic() {
        let a = adam_update(65536, false);
        let b = adam_update(65536, true);
        assert!(b.arithmetic_intensity > a.arithmetic_intensity * 1.5);
    }
}
