//! Analytic performance models for the paper's throughput tables.
//!
//! The paper measures Llama-2-7B step throughput on 8× Gaudi2
//! (Table 3) and 8× A6000 Ada (Table 5). Neither device exists here,
//! so the tables are regenerated from a roofline model: per-step time =
//! matmul-FLOPs / effective-MME-rate + non-matmul bytes / vector rate +
//! quantization overhead, with FP8 doubling the MME rate on the
//! quantized fraction of the matmul work. The *shape* the benches
//! check is the paper's ordering and gaps (FP8 +37% > Smooth-SwiGLU
//! +34% > no-q-w3 +27% > BF16), which falls out of (a) which matmuls
//! run FP8 per config and (b) the per-channel-scaling overhead.
//!
//! [`roofline`] additionally estimates the Pallas kernel's VMEM
//! footprint and MXU occupancy (DESIGN.md §Perf — interpret-mode
//! wall-clock is not a TPU proxy, so L1 is costed structurally), and
//! [`interconnect`] models the two-level collective's links — the
//! intra-pod vs inter-pod bandwidth split that decides where FP8 wire
//! compression pays (the `collective_fp8_intra`/`collective_fp8_inter`
//! defaults come from its crossover rule).

pub mod devices;
pub mod interconnect;
pub mod roofline;

pub use devices::{Device, A6000_ADA, GAUDI2};
pub use interconnect::{fp8_crossover_gbps, fp8_pays, LinkModel, GAUDI2_LINKS};

/// Which fraction of matmul FLOPs runs at the FP8 rate per config, and
/// added vector-op overhead per token for scaling machinery.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrecisionConfig {
    Bf16,
    /// FP8 everywhere except the w3 matmul input path stays bf16
    Fp8NoQ3,
    /// FP8 everywhere + per-channel smooth scaling overhead
    Fp8Smooth,
    /// FP8 everywhere (the diverging config)
    Fp8Full,
}

impl PrecisionConfig {
    /// Human-readable row label matching the paper's tables.
    pub fn label(self) -> &'static str {
        match self {
            PrecisionConfig::Bf16 => "BF16",
            PrecisionConfig::Fp8NoQ3 => "FP8 + SwiGLU output in BF16",
            PrecisionConfig::Fp8Smooth => "FP8 + Smooth SwiGLU",
            PrecisionConfig::Fp8Full => "FP8",
        }
    }

    /// Whether the paper observed this config converging (standard
    /// FP8 — no Smooth-SwiGLU — is the diverging one).
    pub fn converges(self) -> bool {
        !matches!(self, PrecisionConfig::Fp8Full)
    }
}

/// Llama-2-7B-like workload description (matmul FLOP split by site).
#[derive(Clone, Debug)]
pub struct Workload {
    /// parameter count
    pub params: f64,
    /// tokens processed per step (batch × sequence length)
    pub tokens_per_batch: f64,
    /// fraction of matmul FLOPs in the w3 (SwiGLU-output) matmul:
    /// f·d of 4d² + 3fd ≈ 0.268 for Llama-2 (f = 2.6875 d)
    pub w3_fraction: f64,
    /// fraction of step time that is not matmul (attention core, norms,
    /// optimizer, comms) at bf16 — calibrated so BF16 lands at the
    /// paper's absolute TFLOPS on each device
    pub non_matmul_fraction: f64,
}

impl Workload {
    /// The paper's Llama-2-7B measurement workload (Tables 3/5).
    pub fn llama7b() -> Self {
        Self {
            params: 6.74e9,
            tokens_per_batch: 4096.0,
            // d=4096, f=11008: w3 share = d·f / (4d² + 3d·f) = 0.223
            w3_fraction: 0.223,
            non_matmul_fraction: 0.20,
        }
    }

    /// matmul FLOPs per step (fwd+bwd, 6·N·T rule)
    pub fn matmul_flops(&self) -> f64 {
        6.0 * self.params * self.tokens_per_batch
    }
}

/// One row of a regenerated Table 3/5-style throughput table.
#[derive(Clone, Debug)]
pub struct TableRow {
    /// the precision configuration this row measures
    pub config: PrecisionConfig,
    /// modeled throughput in samples/sec
    pub throughput: f64,
    /// speedup over the BF16 row, percent
    pub speedup_pct: f64,
    /// achieved model TFLOPS at the modeled step time
    pub tflops: f64,
    /// see [`PrecisionConfig::converges`]
    pub converges: bool,
}

/// Regenerate a Table 3/5-style table for a device.
pub fn throughput_table(dev: &Device, w: &Workload, batch: f64) -> Vec<TableRow> {
    let flops = w.matmul_flops();
    // bf16 step time: matmul at bf16 rate + fixed non-matmul slice
    let t_mm_bf16 = flops / dev.bf16_flops;
    let t_fixed = t_mm_bf16 * w.non_matmul_fraction / (1.0 - w.non_matmul_fraction);

    let step_time = |cfg: PrecisionConfig| -> f64 {
        let (fp8_frac, overhead) = match cfg {
            PrecisionConfig::Bf16 => (0.0, 0.0),
            // w3 matmul (fwd+bwd share) stays bf16; quantization of the
            // rest still pays cast overhead
            PrecisionConfig::Fp8NoQ3 => (1.0 - w.w3_fraction, dev.quant_overhead),
            // everything fp8 + per-channel max/scale pass over the
            // SwiGLU activation (vector-bound)
            PrecisionConfig::Fp8Smooth => (1.0, dev.quant_overhead + dev.smooth_overhead),
            PrecisionConfig::Fp8Full => (1.0, dev.quant_overhead),
        };
        let t_mm = flops * (1.0 - fp8_frac) / dev.bf16_flops + flops * fp8_frac / dev.fp8_flops;
        t_mm + t_fixed + t_mm_bf16 * overhead
    };

    let t_bf16 = step_time(PrecisionConfig::Bf16);
    [
        PrecisionConfig::Bf16,
        PrecisionConfig::Fp8NoQ3,
        PrecisionConfig::Fp8Smooth,
        PrecisionConfig::Fp8Full,
    ]
    .iter()
    .map(|&cfg| {
        let t = step_time(cfg);
        TableRow {
            config: cfg,
            throughput: batch / t,
            speedup_pct: (t_bf16 / t - 1.0) * 100.0,
            tflops: flops / t / 1e12,
            converges: cfg.converges(),
        }
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaudi2_reproduces_paper_ordering_and_gaps() {
        let rows = throughput_table(&GAUDI2, &Workload::llama7b(), 8.0);
        // ordering: BF16 < noq3 < smooth < fp8
        assert!(rows[0].throughput < rows[1].throughput);
        assert!(rows[1].throughput < rows[2].throughput);
        assert!(rows[2].throughput < rows[3].throughput);
        // paper gaps: +27.0%, +33.5%, +37.1% — hold within a few points
        assert!((rows[1].speedup_pct - 27.0).abs() < 5.0, "{}", rows[1].speedup_pct);
        assert!((rows[2].speedup_pct - 33.5).abs() < 5.0, "{}", rows[2].speedup_pct);
        assert!((rows[3].speedup_pct - 37.1).abs() < 5.0, "{}", rows[3].speedup_pct);
        // only standard FP8 diverges
        assert!(rows.iter().all(|r| r.converges == (r.config != PrecisionConfig::Fp8Full)));
    }

    #[test]
    fn a6000_matches_table5_shape() {
        let rows = throughput_table(&A6000_ADA, &Workload::llama7b(), 8.0);
        assert!((rows[1].speedup_pct - 27.6).abs() < 6.0);
        assert!((rows[3].speedup_pct - 37.6).abs() < 6.0);
        // absolute BF16 TFLOPS near the paper's 76 (calibration check)
        assert!((rows[0].tflops - 76.0).abs() < 15.0, "{}", rows[0].tflops);
    }
}
