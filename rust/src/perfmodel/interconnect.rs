//! Topology-aware link model for the two-level gradient collective:
//! when does FP8 wire compression *pay* on a given level?
//!
//! The two levels of `coordinator::topology` ride different wires —
//! intra-pod legs use the accelerators' fat scale-up links, inter-pod
//! legs squeeze through a few scale-out ports — so the FP8-vs-f32
//! decision is per level, and it is a genuine trade: compression
//! removes 3 of every 4 wire bytes but adds a quantize-dequantize
//! pass per leg, costed at the accelerator's HBM rate (on-device qdq
//! is memory-bound — the arithmetic is a multiply and a table lookup).
//! FP8 pays exactly when the wire seconds saved exceed the codec
//! seconds added, which reduces to a **bandwidth crossover**: below
//! [`fp8_crossover_gbps`] the level wants FP8, above it f32.
//!
//! With Gaudi2-like numbers ([`GAUDI2_LINKS`]) the crossover lands
//! between the two levels — the thin inter-pod pipe is far below it,
//! the fat intra-pod mesh above it — which is why the config defaults
//! to `collective_fp8_inter = true`, `collective_fp8_intra = false`
//! (see `docs/OPERATIONS.md` §Topology for the operator-facing rule).
//!
//! Byte counts here follow the same closed forms
//! `coordinator::allreduce::CollectiveStats` reports, with one
//! deliberate simplification: FP8 legs are costed at exactly 1
//! byte/element, dropping the 4-byte pow2 scale per chunk that the
//! stats count (`4·⌈n/chunk⌉` — under 0.002% of the payload at the
//! production 256K-element chunk). A unit test cross-checks the two
//! accountings at `chunk = n`, where the simplification collapses to
//! a single scale word; dividing a `BENCH_hotpath.json` wire-byte
//! record by these bandwidths therefore over-counts time by that same
//! sub-percent margin, nothing more.

use crate::perfmodel::roofline::HBM_GBPS;

/// Bytes of memory traffic one quantize-dequantize pass touches per
/// element on one wire leg: the encode side reads an f32 and writes a
/// byte (4 + 1), the decode side reads the byte and writes an f32
/// (1 + 4). The codec itself is memory-bound on-device, so seconds =
/// bytes / HBM rate.
pub const QDQ_BYTES_PER_ELEM_PER_LEG: f64 = 10.0;

/// Link bandwidths of one pod deployment, in GB/s per rank.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// deployment label
    pub name: &'static str,
    /// per-rank bandwidth of the intra-pod (scale-up) links, GB/s
    pub intra_gbps: f64,
    /// per-rank bandwidth of the inter-pod (scale-out) links, GB/s
    pub inter_gbps: f64,
    /// achieved HBM rate the on-device qdq passes run at, GB/s
    pub codec_gbps: f64,
}

/// Gaudi2 8-card pods: each card exposes 24×100 GbE RoCE ports, 21
/// wired all-to-all inside the pod (262.5 GB/s scale-up) and 3 into
/// the switch fabric (37.5 GB/s scale-out) — the paper's 256-card
/// deployment shape. Codec passes run at the roofline HBM rate.
pub const GAUDI2_LINKS: LinkModel = LinkModel {
    name: "Gaudi2 8-card pods (21+3 x 100GbE)",
    intra_gbps: 262.5,
    inter_gbps: 37.5,
    codec_gbps: HBM_GBPS,
};

/// Seconds one level of the hierarchical collective spends on the
/// wire for `n` elements across `ranks` participants: a ring moves
/// `(ranks-1)/ranks · n · bytes_per_elem` per rank per leg, two legs
/// (reduce-scatter + all-gather), at `gbps` per rank. Groups of the
/// same level (the pods of the intra level) run concurrently, so this
/// is per-group wall time, not pod-total bytes.
pub fn level_wire_seconds(n: usize, ranks: usize, bytes_per_elem: f64, gbps: f64) -> f64 {
    if ranks <= 1 {
        return 0.0;
    }
    let frac = (ranks - 1) as f64 / ranks as f64;
    2.0 * frac * n as f64 * bytes_per_elem / (gbps * 1e9)
}

/// Seconds the per-chunk qdq passes of one FP8-compressed level add
/// (two legs, memory-bound at `codec_gbps`); zero for an f32 level.
pub fn level_codec_seconds(n: usize, ranks: usize, fp8: bool, codec_gbps: f64) -> f64 {
    if !fp8 || ranks <= 1 {
        return 0.0;
    }
    2.0 * n as f64 * QDQ_BYTES_PER_ELEM_PER_LEG / (codec_gbps * 1e9)
}

/// Wall-clock estimate of one two-level gradient collective.
#[derive(Clone, Copy, Debug)]
pub struct CollectiveCost {
    /// wire seconds on the intra-pod level (pods run concurrently)
    pub intra_wire_s: f64,
    /// wire seconds on the inter-pod (leader) level
    pub inter_wire_s: f64,
    /// added qdq seconds across whichever levels are FP8-compressed
    pub codec_s: f64,
}

impl CollectiveCost {
    /// Total estimated wall-clock: the levels are sequential phases.
    pub fn total_s(&self) -> f64 {
        self.intra_wire_s + self.inter_wire_s + self.codec_s
    }
}

/// Cost one hierarchical collective of `n` elements on
/// `pods × workers_per_pod` ranks, with per-level compression flags —
/// the analytic twin of `coordinator::topology::hier_grad_collective`.
pub fn hier_collective_cost(
    n: usize,
    pods: usize,
    workers_per_pod: usize,
    fp8_intra: bool,
    fp8_inter: bool,
    link: &LinkModel,
) -> CollectiveCost {
    let intra_bytes = if fp8_intra { 1.0 } else { 4.0 };
    let inter_bytes = if fp8_inter { 1.0 } else { 4.0 };
    CollectiveCost {
        intra_wire_s: level_wire_seconds(n, workers_per_pod, intra_bytes, link.intra_gbps),
        inter_wire_s: level_wire_seconds(n, pods, inter_bytes, link.inter_gbps),
        codec_s: level_codec_seconds(n, workers_per_pod, fp8_intra, link.codec_gbps)
            + level_codec_seconds(n, pods, fp8_inter, link.codec_gbps),
    }
}

/// The link-bandwidth crossover (GB/s) below which FP8 compression
/// pays on a level of `ranks` participants: FP8 saves
/// `2·(ranks-1)/ranks·3` wire bytes per element and costs
/// `2·`[`QDQ_BYTES_PER_ELEM_PER_LEG`] codec bytes per element at
/// `codec_gbps`, so the break-even link rate is
/// `3·(ranks-1)/ranks · codec_gbps / QDQ_BYTES_PER_ELEM_PER_LEG`.
pub fn fp8_crossover_gbps(ranks: usize, codec_gbps: f64) -> f64 {
    if ranks <= 1 {
        return 0.0; // nothing on the wire — compression never pays
    }
    let frac = (ranks - 1) as f64 / ranks as f64;
    3.0 * frac * codec_gbps / QDQ_BYTES_PER_ELEM_PER_LEG
}

/// Whether FP8 wire compression reduces wall-clock on a level of
/// `ranks` participants riding a `link_gbps` pipe.
pub fn fp8_pays(ranks: usize, link_gbps: f64, codec_gbps: f64) -> bool {
    link_gbps < fp8_crossover_gbps(ranks, codec_gbps)
}

/// Predicted wall-clock of one bucketed, overlapped step tail (the
/// collective + the per-bucket downstream compute it hides behind),
/// from a uniform-bucket pipeline model: with `B` buckets, the span of
/// two pipelined stages of total lengths `comm_s` and `compute_s` is
/// `max + min/B` — the longer stage runs end to end, and one bucket's
/// worth of the shorter stage sticks out at a pipe end. The hidden
/// fraction this predicts is directly comparable to the measured
/// `PhaseTimers::hidden_comm_fraction` (the bench gates the two within
/// 2x of each other — see benches/perf_hotpath.rs `overlap_benches`).
#[derive(Clone, Copy, Debug)]
pub struct OverlapCost {
    /// total collective seconds across all buckets
    pub comm_s: f64,
    /// total downstream compute seconds the collective can hide behind
    pub compute_s: f64,
    /// buckets in the pipeline (1 = no overlap possible)
    pub buckets: usize,
    /// predicted pipelined span of the two stages
    pub pipelined_s: f64,
    /// predicted fraction of `comm_s` hidden behind compute, in [0, 1]
    pub hidden_fraction: f64,
}

/// The pipeline algebra on *given* stage times — the measured-input
/// form the bench gates (feed it the measured comm/compute seconds and
/// compare its predicted hidden fraction against the measured one).
pub fn overlap_from_times(comm_s: f64, compute_s: f64, buckets: usize) -> OverlapCost {
    let b = buckets.max(1) as f64;
    let (hi, lo) = if comm_s >= compute_s { (comm_s, compute_s) } else { (compute_s, comm_s) };
    let pipelined_s = hi + lo / b;
    let exposed = (pipelined_s - compute_s).max(0.0);
    let hidden_fraction = if comm_s <= 0.0 {
        1.0 // nothing on the wire — vacuously all hidden
    } else {
        (1.0 - exposed / comm_s).clamp(0.0, 1.0)
    };
    OverlapCost { comm_s, compute_s, buckets: buckets.max(1), pipelined_s, hidden_fraction }
}

/// Roofline seconds of the per-bucket downstream compute the pipeline
/// hides the collective behind: the norm fold (one f32 read per
/// element) plus the memory-bound Adam update
/// (`roofline::adam_update` traffic: p read+write, g read, m/v
/// read+write at the moment storage width), all at the HBM rate.
pub fn overlap_compute_seconds(n: usize, fp8_moments: bool) -> f64 {
    let moment_bytes = if fp8_moments { 1.0 } else { 4.0 };
    let adam_bytes = 2.0 * 4.0 + 4.0 + 4.0 * moment_bytes;
    let norm_bytes = 4.0;
    n as f64 * (norm_bytes + adam_bytes) / (HBM_GBPS * 1e9)
}

/// Predict the overlapped step tail for `n` gradient elements on a
/// `pods × workers_per_pod` deployment: the collective side is
/// [`hier_collective_cost`] (the analytic twin of the per-bucket
/// collective — bucket costs sum to the whole-buffer cost, so the
/// whole-buffer form is exact for the total), the compute side is
/// [`overlap_compute_seconds`], and the pipeline algebra is
/// [`overlap_from_times`].
pub fn overlap_cost(
    n: usize,
    pods: usize,
    workers_per_pod: usize,
    fp8_intra: bool,
    fp8_inter: bool,
    fp8_moments: bool,
    buckets: usize,
    link: &LinkModel,
) -> OverlapCost {
    let comm = hier_collective_cost(n, pods, workers_per_pod, fp8_intra, fp8_inter, link);
    overlap_from_times(comm.total_s(), overlap_compute_seconds(n, fp8_moments), buckets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::topology::{hier_grad_collective, PodTopology};
    use crate::fp8::E5M2;

    #[test]
    fn gaudi2_crossover_separates_the_levels() {
        // the deployment the defaults encode: 32 pods x 8 cards
        let l = &GAUDI2_LINKS;
        assert!(
            !fp8_pays(8, l.intra_gbps, l.codec_gbps),
            "fat intra-pod links must not want FP8 (crossover {:.0} GB/s)",
            fp8_crossover_gbps(8, l.codec_gbps)
        );
        assert!(
            fp8_pays(32, l.inter_gbps, l.codec_gbps),
            "thin inter-pod pipe must want FP8 (crossover {:.0} GB/s)",
            fp8_crossover_gbps(32, l.codec_gbps)
        );
        // the crossover itself sits strictly between the two pipes
        let x = fp8_crossover_gbps(8, l.codec_gbps);
        assert!(l.inter_gbps < x && x < l.intra_gbps, "crossover {x}");
    }

    #[test]
    fn crossover_is_monotone_in_ranks_and_codec_rate() {
        assert!(fp8_crossover_gbps(2, 800.0) < fp8_crossover_gbps(32, 800.0));
        assert!(fp8_crossover_gbps(8, 400.0) < fp8_crossover_gbps(8, 800.0));
        assert_eq!(fp8_crossover_gbps(1, 800.0), 0.0);
    }

    #[test]
    fn default_mix_beats_both_uniform_choices_on_gaudi2() {
        // intra=f32/inter=fp8 (the config default) must beat all-f32
        // AND all-fp8 at the paper's 32x8 shape
        let n = 1 << 24;
        let l = &GAUDI2_LINKS;
        let mix = hier_collective_cost(n, 32, 8, false, true, l).total_s();
        let all_f32 = hier_collective_cost(n, 32, 8, false, false, l).total_s();
        let all_fp8 = hier_collective_cost(n, 32, 8, true, true, l).total_s();
        assert!(mix < all_f32, "mix {mix} vs all-f32 {all_f32}");
        assert!(mix < all_fp8, "mix {mix} vs all-fp8 {all_fp8}");
    }

    #[test]
    fn overlap_pipeline_algebra() {
        // comm shorter than compute, many buckets: nearly all hidden
        let c = overlap_from_times(1.0, 4.0, 8);
        assert!((c.pipelined_s - (4.0 + 1.0 / 8.0)).abs() < 1e-12);
        // exposed = pipelined - compute = 1/8 -> hidden = 1 - (1/8)/1
        assert!((c.hidden_fraction - 0.875).abs() < 1e-12, "{}", c.hidden_fraction);
        // one bucket = no overlap: everything exposed
        let c = overlap_from_times(1.0, 4.0, 1);
        assert_eq!(c.hidden_fraction, 0.0);
        // comm dominates: at best `compute` seconds hide
        let c = overlap_from_times(10.0, 2.0, 1000);
        assert!(c.hidden_fraction < 0.21 && c.hidden_fraction > 0.19);
        // more buckets never hides less
        let h2 = overlap_from_times(3.0, 3.0, 2).hidden_fraction;
        let h8 = overlap_from_times(3.0, 3.0, 8).hidden_fraction;
        assert!(h8 >= h2);
        // no wire at all (W = 1): vacuously hidden, never NaN
        assert_eq!(overlap_from_times(0.0, 1.0, 4).hidden_fraction, 1.0);
    }

    #[test]
    fn overlap_cost_predicts_mostly_hidden_comms_on_gaudi2() {
        // the paper-shape deployment with the default wire mix and FP8
        // moments: the collective should be largely hideable behind
        // the norm+Adam tail once bucketed
        let c = overlap_cost(1 << 24, 32, 8, false, true, true, 16, &GAUDI2_LINKS);
        assert!(c.comm_s > 0.0 && c.compute_s > 0.0);
        let one = overlap_cost(1 << 24, 32, 8, false, true, true, 1, &GAUDI2_LINKS);
        assert!(
            c.hidden_fraction > one.hidden_fraction,
            "bucketing must hide more than the monolithic schedule \
             ({} vs {})",
            c.hidden_fraction,
            one.hidden_fraction
        );
        assert!(c.pipelined_s < one.pipelined_s);
    }

    #[test]
    fn overlap_compute_scales_with_moment_width() {
        let fp8 = overlap_compute_seconds(1 << 20, true);
        let f32_ = overlap_compute_seconds(1 << 20, false);
        assert!(f32_ > fp8, "f32 moments move more bytes");
        // exact closed forms: (4 + 12 + 4*mb) / HBM
        let want_fp8 = (1u64 << 20) as f64 * 20.0 / (HBM_GBPS * 1e9);
        assert!((fp8 - want_fp8).abs() < 1e-18);
    }

    #[test]
    fn wire_model_matches_collective_stats_byte_accounting() {
        // the analytic per-rank wire volume and CollectiveStats'
        // group-total accounting must be the same closed form:
        // stats leg bytes = groups·(ranks-1)·payload
        //                 = groups·ranks·(per-rank ring volume).
        // chunk = n pins the comparison where the model's dropped
        // per-chunk scale term is exactly one 4-byte word (see the
        // module docs for why the model omits it in general)
        let n = 4096usize;
        let (pods, p) = (2usize, 4usize);
        let topo = PodTopology::new(pods * p, pods).unwrap();
        let mut bufs: Vec<Vec<f32>> = (0..pods * p).map(|_| vec![1e-3f32; n]).collect();
        // chunk = n: one scale per leg -> payload n + 4 exactly
        let s = hier_grad_collective(&mut bufs, topo, None, Some(E5M2), n);
        let per_rank_intra = (p - 1) as f64 / p as f64 * n as f64 * 4.0;
        assert_eq!(
            s.intra.reduce_scatter as f64,
            per_rank_intra * (pods * p) as f64,
            "intra: stats total = per-rank ring volume x all ranks"
        );
        let per_rank_inter = (pods - 1) as f64 / pods as f64 * (n + 4) as f64;
        assert_eq!(s.inter.reduce_scatter as f64, per_rank_inter * pods as f64);
    }
}
