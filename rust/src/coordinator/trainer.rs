//! The training loop: grad artifact → all-reduce → clip → chunked
//! AdamW artifact → delayed-scaling update → divergence check.

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::allreduce::{allreduce_mean, clip_factor, global_norm};
use crate::coordinator::divergence::{DivergenceDetector, Verdict};
use crate::coordinator::params::ParamStore;
use crate::coordinator::schedule::LrSchedule;
use crate::data::{Batcher, Corpus, CorpusConfig};
use crate::metrics::{StepMeter, StepStats};
use crate::optimizer::{decay_groups, DecayGroup, ShardLayout};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{Artifact, Runtime};
use crate::scaling::{Policy, ScaleManager};

/// Everything one completed step reports to the caller.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    pub verdict: Verdict,
    /// per-layer [swiglu_amax, resid_amax, mlp_out_amax]
    pub monitor: Vec<[f32; 3]>,
    pub stats: StepStats,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    rt: Arc<Runtime>,
    grad_art: Arc<Artifact>,
    adam_art: Arc<Artifact>,
    pub params: ParamStore,
    pub scale_mgr: ScaleManager,
    pub detector: DivergenceDetector,
    batcher: Batcher,
    sched: LrSchedule,
    pub shards: ShardLayout,
    groups: Vec<DecayGroup>,
    /// flat AdamW moments (values lie on the recipe's fp8 grid; the
    /// checkpointer stores them as real u8 — see checkpoint::Dtype)
    pub m_flat: Vec<f32>,
    pub v_flat: Vec<f32>,
    meter: StepMeter,
    pub step: usize,
    // reusable step buffers
    worker_grads: Vec<Vec<f32>>,
}

impl Trainer {
    pub fn new(rt: Arc<Runtime>, cfg: TrainConfig) -> Result<Self> {
        let rc = cfg.recipe_config();
        let grad_name = format!("grad_{}_{}", cfg.size, rc.name);
        let grad_art = rt
            .load(&grad_name)
            .with_context(|| format!("loading grad artifact '{grad_name}'"))?;
        let man = &grad_art.manifest;
        let model = man
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("grad manifest missing model dims"))?;

        // 256K chunks: measured fastest on this runtime (the 4M variant
        // costs ~1.7x more per element through xla_extension 0.5.1, and
        // many small chunks parallelize across the shard worker pool —
        // see apply_adam and EXPERIMENTS.md §Perf)
        let adam_name = format!("adam_{}_{}_c262144", rc.m_fmt, rc.v_fmt);
        let adam_art = rt
            .load(&adam_name)
            .with_context(|| format!("loading adam artifact '{adam_name}'"))?;

        let mut params = ParamStore::init(man, cfg.seed);
        if cfg.seed_outlier_channel {
            params
                .seed_outlier_channel(cfg.seed_outlier_gain, cfg.seed)
                .context("seeding outlier channel")?;
        }

        let corpus = Corpus::new(CorpusConfig {
            vocab: model.vocab,
            order: cfg.corpus_order,
            skew: cfg.corpus_skew,
            seed: cfg.seed ^ 0xda7a,
        });
        let batcher = Batcher::new(corpus, man.batch, man.seq_len);

        let scale_mgr = ScaleManager::new(
            man.n_layers,
            &man.sites_per_layer,
            Policy {
                history_len: cfg.amax_history,
                margin_pow2: cfg.margin_pow2,
                ..Default::default()
            },
        );

        let total = params.total_elems();
        let sched = LrSchedule {
            peak: cfg.lr,
            warmup_steps: cfg.warmup_steps,
            total_steps: cfg.steps,
            min_frac: cfg.min_lr_frac,
        };
        let flops = man.flops_per_step * (cfg.dp_workers * cfg.grad_accum) as f64;
        Ok(Self {
            shards: ShardLayout::new(total, cfg.dp_workers),
            groups: decay_groups(&man.params),
            m_flat: vec![0.0; total],
            v_flat: vec![0.0; total],
            worker_grads: vec![Vec::new(); cfg.dp_workers],
            meter: StepMeter::new(flops),
            step: 0,
            params,
            scale_mgr,
            detector: DivergenceDetector::default(),
            batcher,
            sched,
            rt,
            grad_art,
            adam_art,
            cfg,
        })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.grad_art.manifest
    }

    pub fn tokens_per_step(&self) -> usize {
        let m = &self.grad_art.manifest;
        m.batch * m.seq_len * self.cfg.dp_workers * self.cfg.grad_accum
    }

    /// A training batch tensor (for probe/analysis passes that re-run
    /// the model outside the step loop).
    pub fn batch_tensor(&self, step: usize) -> HostTensor {
        HostTensor::from_i32(&self.batcher.shape(), self.batcher.batch(step, 0, 0))
    }

    /// Current scales as a tensor (probe passes).
    pub fn scales_tensor(&self) -> HostTensor {
        HostTensor::from_f32(&[self.scale_mgr.n_sites()], self.scale_mgr.scales().to_vec())
    }

    /// Run one full training step.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let man = self.grad_art.manifest.clone();
        let n_params = self.params.total_elems();
        let ns = self.scale_mgr.n_sites();
        let scales = HostTensor::from_f32(&[ns], self.scale_mgr.scales().to_vec());

        let mut loss_sum = 0.0f64;
        let mut amax = vec![0.0f32; ns];
        let mut monitor = vec![[0.0f32; 3]; man.n_layers];

        // ---- (1) per-worker microbatched grads
        for w in 0..self.cfg.dp_workers {
            let buf = &mut self.worker_grads[w];
            buf.clear();
            buf.resize(n_params, 0.0);
            for micro in 0..self.cfg.grad_accum {
                let tokens = self.batcher.batch(self.step, w, micro);
                let batch = HostTensor::from_i32(&self.batcher.shape(), tokens);
                let mut inputs: Vec<HostTensor> =
                    self.params.tensors.iter().cloned().collect();
                inputs.push(scales.clone());
                inputs.push(batch);
                let out = self.grad_art.run(&inputs)?;
                let p = man.params.len();
                loss_sum += out[0].scalar_f32() as f64;
                let mut off = 0;
                for g in &out[1..=p] {
                    let src = g.f32s();
                    for (d, s) in buf[off..off + src.len()].iter_mut().zip(src) {
                        *d += *s;
                    }
                    off += src.len();
                }
                for (a, &x) in amax.iter_mut().zip(out[p + 1].f32s()) {
                    *a = a.max(x);
                }
                for (l, row) in out[p + 2].f32s().chunks(3).enumerate() {
                    for k in 0..3 {
                        monitor[l][k] = monitor[l][k].max(row[k]);
                    }
                }
            }
            // mean over microbatches
            let inv = 1.0 / self.cfg.grad_accum as f32;
            for g in buf.iter_mut() {
                *g *= inv;
            }
        }
        let loss =
            (loss_sum / (self.cfg.dp_workers * self.cfg.grad_accum) as f64) as f32;

        // ---- (2) all-reduce
        allreduce_mean(&mut self.worker_grads);

        // ---- (3) global-norm clip. Non-finite grads either skip the
        //      update (production protection) or pass through at clip 1
        //      (exposing the paper's hard divergence), per config.
        let gnorm = global_norm(&self.worker_grads[0]);
        let clip = if !gnorm.is_finite() && !self.cfg.skip_nonfinite_updates {
            1.0
        } else {
            clip_factor(gnorm, self.cfg.grad_clip)
        };

        // ---- (4) chunked AdamW over decay groups (C-aligned so FP8
        //      moment scales are per-absolute-chunk, see optimizer::)
        let lr = self.sched.lr(self.step);
        if clip > 0.0 {
            self.apply_adam(lr, clip)?;
        }

        // ---- (5) scaling + divergence bookkeeping
        self.scale_mgr.update(&amax);
        let verdict = self
            .detector
            .observe(self.step, loss, self.scale_mgr.overflow_events);

        self.step += 1;
        let stats = self.meter.tick(self.tokens_per_step());
        Ok(StepOutcome {
            step: self.step - 1,
            loss,
            grad_norm: gnorm,
            lr,
            verdict,
            monitor,
            stats,
        })
    }

    /// Chunked AdamW through the `adam_*` artifact. Chunks are aligned
    /// to absolute multiples of the artifact chunk size so per-chunk
    /// FP8 moment scales are stable across group boundaries, and are
    /// executed **in parallel** across a worker pool — the ZeRO-1
    /// optimizer step really is embarrassingly parallel over shards,
    /// and the PJRT CPU client accepts concurrent executions.
    fn apply_adam(&mut self, lr: f32, clip: f32) -> Result<()> {
        let chunk = self.adam_art.manifest.chunk;
        let grads = std::mem::take(&mut self.worker_grads); // borrow dance
        let g_flat = &grads[0];
        let mut p_flat = Vec::new();
        self.params.flatten_into(&mut p_flat);

        // build the chunk work list: (offset, len, weight_decay)
        let mut work: Vec<(usize, usize, f32)> = Vec::new();
        for group in &self.groups {
            let wd = if group.decay { self.cfg.weight_decay } else { 0.0 };
            for &(off, len) in &group.ranges {
                let mut pos = off;
                let end = off + len;
                while pos < end {
                    let cend = (((pos / chunk) + 1) * chunk).min(end);
                    work.push((pos, cend - pos, wd));
                    pos = cend;
                }
            }
        }

        let step_f = (self.step + 1) as f32;
        let art = &self.adam_art;
        let m_flat = &self.m_flat;
        let v_flat = &self.v_flat;
        let p_ref = &p_flat;
        // 4 shard workers: enough to hide transfer latency without
        // thrashing the PJRT intra-op pool (measured; §Perf)
        let n_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(work.len().max(1))
            .min(4);

        type ChunkOut = (usize, usize, Vec<f32>, Vec<f32>, Vec<f32>);
        let next = std::sync::atomic::AtomicUsize::new(0);
        let results: Result<Vec<ChunkOut>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_threads)
                .map(|_| {
                    s.spawn(|| -> Result<Vec<ChunkOut>> {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            if i >= work.len() {
                                return Ok(out);
                            }
                            let (off, len, wd) = work[i];
                            let pad = |src: &[f32]| {
                                let mut b = Vec::with_capacity(chunk);
                                b.extend_from_slice(src);
                                b.resize(chunk, 0.0);
                                b
                            };
                            let inputs = vec![
                                HostTensor::from_f32(&[chunk], pad(&p_ref[off..off + len])),
                                HostTensor::from_f32(&[chunk], pad(&m_flat[off..off + len])),
                                HostTensor::from_f32(&[chunk], pad(&v_flat[off..off + len])),
                                HostTensor::from_f32(&[chunk], pad(&g_flat[off..off + len])),
                                HostTensor::from_f32(&[4], vec![lr, wd, step_f, clip]),
                            ];
                            let res = art.run(&inputs)?;
                            let take = |t: &HostTensor| t.f32s()[..len].to_vec();
                            out.push((off, len, take(&res[0]), take(&res[1]), take(&res[2])));
                        }
                    })
                })
                .collect();
            let mut all = Vec::with_capacity(work.len());
            for h in handles {
                all.extend(h.join().expect("adam worker panicked")?);
            }
            Ok(all)
        });

        for (off, len, p, m, v) in results? {
            p_flat[off..off + len].copy_from_slice(&p);
            self.m_flat[off..off + len].copy_from_slice(&m);
            self.v_flat[off..off + len].copy_from_slice(&v);
        }
        self.params.unflatten_from(&p_flat);
        self.worker_grads = grads;
        Ok(())
    }

    /// Held-out evaluation through an eval artifact (perplexity + top-1
    /// accuracy over `n_batches` deterministic eval batches).
    pub fn eval(&self, recipe: &str, n_batches: usize) -> Result<(f64, f64)> {
        let name = format!("eval_{}_{}", self.cfg.size, recipe);
        let art = self.rt.load(&name)?;
        let ns = self.scale_mgr.n_sites();
        let scales = HostTensor::from_f32(&[ns], self.scale_mgr.scales().to_vec());
        let (mut nll, mut correct, mut total) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..n_batches {
            let tokens = self.batcher.eval_batch(i);
            let batch = HostTensor::from_i32(&self.batcher.shape(), tokens);
            let mut inputs: Vec<HostTensor> = self.params.tensors.iter().cloned().collect();
            inputs.push(scales.clone());
            inputs.push(batch);
            let out = art.run(&inputs)?;
            nll += out[0].scalar_f32() as f64;
            correct += out[1].scalar_f32() as f64;
            total += out[2].scalar_f32() as f64;
        }
        Ok(((nll / total).exp(), correct / total))
    }

    pub fn wall_s(&self) -> f64 {
        self.meter.wall_s()
    }
}
