//! The training loop: parallel per-worker grad artifacts → gradient
//! collective → clip → sharded chunked AdamW artifact →
//! delayed-scaling update → divergence check.
//!
//! Hot-path structure (see rust/EXPERIMENTS.md §Perf, §Sharding,
//! §Overlap and §Resharding):
//! * the numerics are defined over **logical gradient streams**
//!   (`cfg.streams()`, default = `dp_workers`), not over the physical
//!   worker pool: batch identity is `(step, stream, micro)`, the loss
//!   merge divides by `streams · grad_accum`, and the collective
//!   reduces `streams` replica buffers on the **logical plan topology**
//!   (`cfg.stream_pod_count()` plan pods). The physical `dp_workers` /
//!   `pods` only decide how many threads run those streams (streams
//!   deal round-robin onto `min(W, S)` lanes; each lane runs its
//!   streams in ascending order and the merge re-sorts by stream id,
//!   so the fan-out is bit-invisible) and how the ZeRO-1 moments are
//!   sharded — which is what makes a campaign reshardable onto a
//!   different worker/pod count bit-exactly (`campaign resume
//!   --reshard`);
//! * the gradient passes run concurrently on scoped threads (the PJRT
//!   CPU client accepts concurrent executions), with a fixed-order
//!   merge of loss/amax/monitor so results are bit-identical to the
//!   serial schedule at any lane count;
//! * the gradient collective is the pod-aware two-level schedule
//!   (`topology::hier_bucket_collective` per bucket, the whole-buffer
//!   `hier_grad_collective_with` on the phased path) over the logical
//!   plan topology: deterministic intra-pod reduce-scatter → inter-pod
//!   exchange over pod leaders → intra-pod all-gather, with FP8 wire
//!   compression selectable per level (`collective_fp8_intra` /
//!   `collective_fp8_inter`, per-chunk pow2 auto-scales);
//! * the step is **bucketed and overlapped** (`overlap_comm`, default
//!   on): the flat gradient is partitioned into `bucket_bytes`-sized,
//!   Adam-chunk-aligned buckets (`pipeline::BucketSchedule`); each
//!   worker streams finished bucket windows to a dedicated comms
//!   thread over channels, the comms thread runs the two-level
//!   collective per bucket on double-buffered scratch while later
//!   buckets are still being computed, and the per-bucket norm partial
//!   (`pipeline::NormStream`) plus — when the clip factor is provably
//!   1 — the sharded Adam update for the bucket run as soon as the
//!   bucket lands. Because bucket starts sit on the absolute Adam
//!   chunk grid, every per-chunk FP8 wire/moment grid, the f32 tree
//!   reduce order, and the f64 norm fold order are exactly those of
//!   the phased schedule, so the overlapped step is bit-identical to
//!   `force_phased_step` (pinned by tests/integration.rs);
//! * optimizer state is **ZeRO-1 sharded**: the Adam moments live in
//!   per-worker `MomentBuffer` shards on a chunk-aligned owner map
//!   (`ShardLayout::chunk_aligned` over the Adam artifact chunk), each
//!   worker updates only its owned chunks, and the shards re-pack to
//!   exact-verified FP8 between steps (`pack_moments`) — per-worker
//!   resident moment bytes are `~total/W` instead of `4·total`;
//! * `apply_adam` runs on persistent per-thread scratch (chunk pads as
//!   reusable `HostTensor`s, a persistent `p_flat`, a cached chunk work
//!   list) so the steady-state step makes no per-chunk heap
//!   allocations on the coordinator side;
//! * every step reports per-phase wall timers
//!   (`pipeline::PhaseTimers` on `StepOutcome`): grad / collective /
//!   norm / adam walls plus the *exposed* (non-hidden) collective
//!   seconds, the measurement side of
//!   `perfmodel::interconnect::overlap_cost`.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::allreduce::{
    clip_factor, global_norm, CollectiveScratch, CollectiveStats,
};
use crate::coordinator::divergence::{DivergenceDetector, Verdict};
use crate::coordinator::params::ParamStore;
use crate::coordinator::pipeline::{contain_panic, BucketSchedule, NormStream, PhaseTimers};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::topology::{
    hier_bucket_collective, hier_grad_collective_with, PodTopology,
};
use crate::data::{Batcher, Corpus, CorpusConfig};
use crate::fp8::{Fp8Format, E4M3, E5M2};
use crate::gemm::GemmEngine;
use crate::metrics::{StepMeter, StepStats};
use crate::optimizer::{decay_groups, MomentBuffer, MomentStore, ShardLayout};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{Artifact, Runtime};
use crate::scaling::{Policy, ScaleManager};

/// Everything one completed step reports to the caller.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    /// the step index this outcome describes (0-based)
    pub step: usize,
    /// mean training loss over all workers × microbatches
    pub loss: f32,
    /// global L2 gradient norm before clipping
    pub grad_norm: f32,
    /// learning rate the step applied
    pub lr: f32,
    /// the divergence detector's verdict for this step
    pub verdict: Verdict,
    /// per-layer [swiglu_amax, resid_amax, mlp_out_amax]
    pub monitor: Vec<[f32; 3]>,
    /// per-phase wall timers for this step (grad/collective/norm/adam
    /// plus exposed-collective seconds; see `pipeline::PhaseTimers`)
    pub timers: PhaseTimers,
    /// throughput accounting from the step meter
    pub stats: StepStats,
}

/// One logical stream's per-step reduction state, merged in ascending
/// stream order after the (possibly parallel) passes complete. Keeping
/// the merge out of the passes is what makes thread scheduling
/// invisible to the numbers: each stream's partials depend only on its
/// own batches, and the merge re-sorts by stream id regardless of
/// which physical lane ran which stream.
struct WorkerPass {
    loss_sum: f64,
    amax: Vec<f32>,
    monitor: Vec<[f32; 3]>,
}

/// Reusable per-thread chunk pads for the Adam artifact: 4 chunk-sized
/// f32 tensors (p, m, v, g) plus the 4-scalar tensor, written in place
/// each chunk. Allocated once in `Trainer::new`, reused every step.
struct AdamScratch {
    inputs: Vec<HostTensor>,
}

impl AdamScratch {
    fn new(chunk: usize) -> Self {
        let mut inputs: Vec<HostTensor> = (0..4).map(|_| HostTensor::zeros(&[chunk])).collect();
        inputs.push(HostTensor::from_f32(&[4], vec![0.0; 4]));
        Self { inputs }
    }

    /// Load one chunk into the pads (zero-filling the tail past `len`).
    fn load(&mut self, p: &[f32], m: &[f32], v: &[f32], g: &[f32], scalars: [f32; 4]) {
        for (t, src) in self.inputs.iter_mut().zip([p, m, v, g]) {
            let d = t.f32s_mut();
            d[..src.len()].copy_from_slice(src);
            d[src.len()..].fill(0.0);
        }
        self.inputs[4].f32s_mut().copy_from_slice(&scalars);
    }
}

/// One chunk of optimizer work: disjoint mutable windows into the flat
/// param/moment buffers plus the matching gradient window.
struct AdamUnit<'a> {
    len: usize,
    wd: f32,
    p: &'a mut [f32],
    m: &'a mut [f32],
    v: &'a mut [f32],
    g: &'a [f32],
}

/// One chunk of optimizer work on the overlapped path: like
/// `AdamUnit` but without the gradient window — the grad bits for a
/// bucket only exist once its collective lands, so the window is
/// resolved against the landed bucket slice at dispatch time using the
/// chunk's absolute offset.
struct BucketUnit<'a> {
    off: usize,
    len: usize,
    wd: f32,
    p: &'a mut [f32],
    m: &'a mut [f32],
    v: &'a mut [f32],
}

/// Split `skip` then `take` elements off the front of a mutable slice
/// cursor, returning the taken window.
fn carve<'a>(cursor: &mut &'a mut [f32], skip: usize, take: usize) -> &'a mut [f32] {
    let buf = std::mem::take(cursor);
    let (_, rest) = buf.split_at_mut(skip);
    let (win, rest) = rest.split_at_mut(take);
    *cursor = rest;
    win
}

/// The read-only context one gradient worker pass needs — a plain
/// struct of borrows so the overlapped step can destructure `Trainer`
/// into disjoint field borrows and still run passes from free
/// functions on scoped threads.
struct PassCtx<'a> {
    art: &'a Artifact,
    batcher: &'a Batcher,
    params: &'a ParamStore,
    /// the tile-wise FP8 GEMM engine when an `fp8_gemm` recipe is
    /// active (None otherwise); `params` then points at its QDQ'd
    /// weight copy and each pass re-grids its gradients on exit
    gemm: Option<&'a GemmEngine>,
    grad_accum: usize,
    ns: usize,
    step: usize,
    /// tests only: stream index whose pass should deliberately panic,
    /// exercising the panic-containment path end to end
    panic_drill: Option<usize>,
}

/// One logical stream's microbatched gradient pass: accumulate grads
/// into `buf`, return the stream-local loss/amax/monitor partials.
/// Pure in the stream index — safe to run on any thread (`w` is the
/// stream id, which is also the batch-identity coordinate).
fn run_worker_pass(
    ctx: &PassCtx<'_>,
    w: usize,
    scales: &HostTensor,
    buf: &mut Vec<f32>,
) -> Result<WorkerPass> {
    if ctx.panic_drill == Some(w) {
        panic!("injected drill panic in grad worker {w} (tests only)");
    }
    let man = &ctx.art.manifest;
    let n_params = ctx.params.total_elems();
    buf.clear();
    buf.resize(n_params, 0.0);
    let mut pass = WorkerPass {
        loss_sum: 0.0,
        amax: vec![0.0; ctx.ns],
        monitor: vec![[0.0; 3]; man.n_layers],
    };
    for micro in 0..ctx.grad_accum {
        let tokens = ctx.batcher.batch(ctx.step, w, micro);
        let batch = HostTensor::from_i32(&ctx.batcher.shape(), tokens);
        // params are immutable within a step and shared by every
        // worker: borrow them (run_refs) instead of deep-cloning a
        // full model copy per worker per microbatch
        let mut inputs: Vec<&HostTensor> = Vec::with_capacity(ctx.params.tensors.len() + 2);
        inputs.extend(ctx.params.tensors.iter());
        inputs.push(scales);
        inputs.push(&batch);
        let out = ctx.art.run_refs(&inputs)?;
        let p = man.params.len();
        pass.loss_sum += out[0].scalar_f32() as f64;
        let mut off = 0;
        for g in &out[1..=p] {
            let src = g.f32s();
            for (d, s) in buf[off..off + src.len()].iter_mut().zip(src) {
                *d += *s;
            }
            off += src.len();
        }
        for (a, &x) in pass.amax.iter_mut().zip(out[p + 1].f32s()) {
            *a = a.max(x);
        }
        for (l, row) in out[p + 2].f32s().chunks(3).enumerate() {
            for k in 0..3 {
                pass.monitor[l][k] = pass.monitor[l][k].max(row[k]);
            }
        }
    }
    // mean over microbatches
    let inv = 1.0 / ctx.grad_accum as f32;
    for g in buf.iter_mut() {
        *g *= inv;
    }
    // fp8_gemm recipes: put this stream's gradient matrices onto the
    // per-tile E5M2 grid and feed the per-site amaxes. Same point in
    // every schedule — after the microbatch mean, before any merge —
    // so lane assignment and bucket overlap stay bit-invisible.
    if let Some(g) = ctx.gemm {
        g.qdq_grads(buf, &mut pass.amax);
    }
    Ok(pass)
}

/// Fixed-order merge of the per-stream partials (ascending stream
/// order — callers sort by stream id first): the f64 loss fold and
/// elementwise max folds are then independent of which thread ran
/// which stream, so any lane schedule gives these exact bits.
fn merge_passes(
    passes: &[WorkerPass],
    ns: usize,
    n_layers: usize,
    denom: usize,
) -> (f32, Vec<f32>, Vec<[f32; 3]>) {
    let mut loss_sum = 0.0f64;
    let mut amax = vec![0.0f32; ns];
    let mut monitor = vec![[0.0f32; 3]; n_layers];
    for pass in passes {
        loss_sum += pass.loss_sum;
        for (a, &x) in amax.iter_mut().zip(&pass.amax) {
            *a = a.max(x);
        }
        for (m, row) in monitor.iter_mut().zip(&pass.monitor) {
            for k in 0..3 {
                m[k] = m[k].max(row[k]);
            }
        }
    }
    ((loss_sum / denom as f64) as f32, amax, monitor)
}

/// Dispatch one landed bucket's Adam units across the scratch lanes.
/// Chunks are independent, so which lane runs a chunk never changes
/// any bit — only the per-chunk scalars and windows do, and those are
/// identical to the phased `apply_adam` dispatch for the same chunk.
fn run_bucket_adam(
    art: &Artifact,
    scratch: &mut [AdamScratch],
    units: Vec<BucketUnit<'_>>,
    g: &[f32],
    bucket_off: usize,
    lr: f32,
    step_f: f32,
    clip: f32,
) -> Result<()> {
    if units.is_empty() {
        return Ok(());
    }
    let n_lanes = scratch.len().min(units.len()).max(1);
    let mut lanes: Vec<Vec<(BucketUnit<'_>, &[f32])>> =
        (0..n_lanes).map(|_| Vec::new()).collect();
    for (i, u) in units.into_iter().enumerate() {
        let start = u.off - bucket_off;
        let gw = &g[start..start + u.len];
        lanes[i % n_lanes].push((u, gw));
    }
    std::thread::scope(|s| -> Result<()> {
        let handles: Vec<_> = lanes
            .into_iter()
            .zip(scratch.iter_mut())
            .map(|(lane, sc)| {
                s.spawn(move || -> Result<()> {
                    for (u, gw) in lane {
                        sc.load(u.p, u.m, u.v, gw, [lr, u.wd, step_f, clip]);
                        let res = art.run(&sc.inputs)?;
                        u.p.copy_from_slice(&res[0].f32s()[..u.len]);
                        u.m.copy_from_slice(&res[1].f32s()[..u.len]);
                        u.v.copy_from_slice(&res[2].f32s()[..u.len]);
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            contain_panic(h.join(), "adam worker")??;
        }
        Ok(())
    })
}

/// The training loop driver: owns every piece of run-time state one
/// step touches (params, ZeRO-1 moment shards, scaling state machine,
/// divergence detector, data cursor) and executes the step pipeline
/// described in the module docs.
pub struct Trainer {
    /// the run configuration this trainer was built from
    pub cfg: TrainConfig,
    rt: Arc<Runtime>,
    grad_art: Arc<Artifact>,
    adam_art: Arc<Artifact>,
    /// the replicated model parameters (named tensors, manifest order)
    pub params: ParamStore,
    /// the FP8 delayed-scaling state machine
    pub scale_mgr: ScaleManager,
    /// tile-wise FP8 GEMM engine (`fp8_gemm` recipes only): holds the
    /// per-step QDQ'd weight copy the grad passes read, while the f32
    /// masters in `params` stay the optimizer's source of truth. Not
    /// snapshot state — `refresh` rebuilds it from the masters every
    /// step, so a resumed run re-derives identical bits
    gemm: Option<GemmEngine>,
    /// loss-EMA / overflow divergence detector
    pub detector: DivergenceDetector,
    batcher: Batcher,
    sched: LrSchedule,
    /// ZeRO-1 owner map: the flat param space split across the
    /// **physical** `dp_workers` on boundaries aligned to the Adam
    /// artifact chunk, so every per-chunk FP8 moment grid has exactly
    /// one owner. Physical-only: because the chunk grid is absolute,
    /// re-partitioning for a different worker count never changes any
    /// bit (the reshard transform relies on this)
    pub shard_map: ShardLayout,
    /// per-worker first-moment shards (values lie on the recipe's fp8
    /// grid; exact-verified FP8 packing between steps when
    /// `pack_moments` is on — see optimizer::MomentBuffer)
    m_shards: Vec<MomentBuffer>,
    /// per-worker second-moment shards (see `m_shards`)
    v_shards: Vec<MomentBuffer>,
    /// the **logical collective plan** (validated in `new`): the
    /// two-level reduction tree over `cfg.streams()` replica buffers
    /// arranged in `cfg.stream_pod_count()` plan pods — numerics
    /// identity, pinned by the snapshot fingerprint, independent of
    /// the physical pool; plan pods = 1 is the flat collective
    topo: PodTopology,
    /// FP8 wire format of the intra-pod collective legs
    /// (None = bit-exact f32 legs, the pinned baseline)
    fp8_intra: Option<Fp8Format>,
    /// FP8 wire format of the inter-pod (pod-leader) legs
    /// (None = f32; irrelevant at `pods = 1`)
    fp8_inter: Option<Fp8Format>,
    /// wire accounting of the most recent step's gradient collective
    last_collective: CollectiveStats,
    /// reusable encode scratch for the FP8 collective (not state —
    /// snapshots never capture it)
    collective_scratch: CollectiveScratch,
    /// second scratch set for the overlapped pipeline: bucket k and
    /// bucket k+1 can be mid-flight at once (double buffering)
    collective_scratch_alt: CollectiveScratch,
    /// Adam-chunk-aligned bucket partition of the flat gradient
    /// (`bucket_bytes`, see pipeline::BucketSchedule)
    bucket_sched: BucketSchedule,
    meter: StepMeter,
    /// steps completed so far (also the LR-schedule position and the
    /// stateless data pipeline's cursor)
    pub step: usize,
    /// run the per-worker grad passes inline instead of on scoped
    /// threads — the reference schedule the parallel path must match
    /// bit-for-bit (pinned by tests/integration.rs)
    pub force_serial_workers: bool,
    /// run the old phased schedule (all grads → one whole-buffer
    /// collective → norm → adam) instead of the bucketed overlapped
    /// pipeline — the reference the overlapped schedule must match
    /// bit-for-bit (pinned by tests/integration.rs); also settable as
    /// a campaign session key
    pub force_phased_step: bool,
    /// tests only: make this stream index's grad pass panic (taking
    /// down the lane running it), to exercise panic containment (None
    /// in production)
    pub inject_worker_panic: Option<usize>,
    /// set when a failed or panicked optimizer/pipeline stage may have
    /// left state partially advanced: chunk results stream into the
    /// per-worker moment shards in place (the allocation-free design),
    /// so a mid-run failure leaves the moments partially advanced
    /// while the params are not. Retrying a step from that state would
    /// silently diverge; every later step() refuses instead.
    poisoned: bool,
    // ---- reusable step state (no steady-state allocations) ----
    worker_grads: Vec<Vec<f32>>,
    /// persistent flat-parameter scratch for apply_adam
    p_flat: Vec<f32>,
    /// chunk work list (offset, len, weight_decay), offset-sorted;
    /// depends only on groups × artifact chunk, so built once
    adam_work: Vec<(usize, usize, f32)>,
    /// per-thread chunk pads, one per Adam worker
    adam_scratch: Vec<AdamScratch>,
}

impl Trainer {
    /// Build a trainer for `cfg`: load the grad/adam artifacts, init
    /// params and the scaling/divergence/data state, carve the ZeRO-1
    /// shard layout and the bucket schedule, and validate the
    /// collective topology (`pods` must divide `dp_workers`) and wire
    /// format.
    pub fn new(rt: Arc<Runtime>, cfg: TrainConfig) -> Result<Self> {
        let rc = cfg.recipe_config();
        let grad_name = format!("grad_{}_{}", cfg.size, rc.name);
        let grad_art = rt
            .load(&grad_name)
            .with_context(|| format!("loading grad artifact '{grad_name}'"))?;
        let man = &grad_art.manifest;
        let model = man
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("grad manifest missing model dims"))?;

        // 256K chunks: measured fastest on this runtime (the 4M variant
        // costs ~1.7x more per element through xla_extension 0.5.1, and
        // many small chunks parallelize across the shard worker pool —
        // see apply_adam and EXPERIMENTS.md §Perf)
        let adam_name = format!("adam_{}_{}_c262144", rc.m_fmt, rc.v_fmt);
        let adam_art = rt
            .load(&adam_name)
            .with_context(|| format!("loading adam artifact '{adam_name}'"))?;

        let mut params = ParamStore::init(man, cfg.seed);
        if cfg.seed_outlier_channel {
            params
                .seed_outlier_channel(cfg.seed_outlier_gain, cfg.seed)
                .context("seeding outlier channel")?;
        }

        let corpus = Corpus::new(CorpusConfig {
            vocab: model.vocab,
            order: cfg.corpus_order,
            skew: cfg.corpus_skew,
            seed: cfg.corpus_seed(),
        });
        let batcher = Batcher::new(corpus, man.batch, man.seq_len);

        let scale_mgr = ScaleManager::new(
            man.n_layers,
            &man.sites_per_layer,
            Policy {
                history_len: cfg.amax_history,
                margin_pow2: cfg.margin_pow2,
                ..Default::default()
            },
        );

        // fp8_gemm recipes: the tile-wise compute path — weights
        // re-grid from the f32 masters once per step, grads re-grid
        // per stream (see gemm::GemmEngine). Config keys validated
        // here too, not only in TrainConfig::load, because tests and
        // embedders build configs programmatically.
        let gemm = if crate::config::is_gemm_recipe(&cfg.recipe) {
            let gc = cfg.gemm_config().map_err(|e| anyhow!(e))?;
            Some(GemmEngine::new(gc, man, &params))
        } else {
            None
        };

        let total = params.total_elems();
        let sched = LrSchedule {
            peak: cfg.lr,
            warmup_steps: cfg.warmup_steps,
            total_steps: cfg.steps,
            min_frac: cfg.min_lr_frac,
        };
        // work per step is logical: S stream passes run regardless of
        // how many physical lanes carry them
        let flops = man.flops_per_step * (cfg.streams() * cfg.grad_accum) as f64;

        // Chunk work list: (offset, len, weight_decay), C-aligned to
        // absolute multiples of the artifact chunk so per-chunk FP8
        // moment scales are stable across group boundaries. Sorted by
        // offset so the flat state buffers can be carved into disjoint
        // windows in one pass. Chunks are independent, so execution
        // order never matters — only the carve order does.
        let groups = decay_groups(&man.params);
        let chunk = adam_art.manifest.chunk;
        let mut adam_work: Vec<(usize, usize, f32)> = Vec::new();
        for group in &groups {
            let wd = if group.decay { cfg.weight_decay } else { 0.0 };
            for &(off, len) in &group.ranges {
                let mut pos = off;
                let end = off + len;
                while pos < end {
                    let cend = (((pos / chunk) + 1) * chunk).min(end);
                    adam_work.push((pos, cend - pos, wd));
                    pos = cend;
                }
            }
        }
        adam_work.sort_unstable_by_key(|&(off, _, _)| off);

        // 4 shard workers: enough to hide transfer latency without
        // thrashing the PJRT intra-op pool (measured; §Perf)
        let adam_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(adam_work.len().max(1))
            .min(4);
        let adam_scratch = (0..adam_threads).map(|_| AdamScratch::new(chunk)).collect();

        // ZeRO-1 state: chunk-aligned owner map + per-worker moment
        // shards in the recipe's storage format, exact-mode so packing
        // between steps is bit-preserving by construction
        let shard_map = ShardLayout::chunk_aligned(total, cfg.dp_workers, chunk);
        let m_store = MomentStore::from_name(&rc.m_fmt);
        let v_store = MomentStore::from_name(&rc.v_fmt);
        let mk_shards = |store: MomentStore| -> Vec<MomentBuffer> {
            shard_map
                .shards
                .iter()
                .map(|&(_, len)| MomentBuffer::zeros_exact(len, store, chunk))
                .collect()
        };
        // validated here too, not only in TrainConfig::load — tests and
        // embedders build configs programmatically, and a typo silently
        // mapped to a default would train on different wire numerics
        // than the snapshot fingerprint records
        let wire_fmt = match cfg.collective_fmt.as_str() {
            "e4m3" => E4M3,
            "e5m2" => E5M2,
            other => {
                return Err(anyhow!("collective_fmt must be 'e4m3' or 'e5m2' (got '{other}')"))
            }
        };
        let fp8_intra = cfg.collective_fp8_intra.then_some(wire_fmt);
        let fp8_inter = cfg.collective_fp8_inter.then_some(wire_fmt);
        // the collective plan is the LOGICAL topology (streams × plan
        // pods) — the physical pool only carries it
        let topo =
            PodTopology::new(cfg.streams(), cfg.stream_pod_count()).map_err(|e| anyhow!(e))?;
        // physical placement still has to be well-formed (equal
        // contiguous pods), validated here too because tests and
        // embedders build configs programmatically
        if cfg.pods == 0 || cfg.pods > cfg.dp_workers || cfg.dp_workers % cfg.pods != 0 {
            return Err(anyhow!(
                "pods ({}) must divide dp_workers ({}) evenly",
                cfg.pods,
                cfg.dp_workers
            ));
        }
        let bucket_sched = BucketSchedule::new(total, cfg.bucket_bytes, chunk);

        Ok(Self {
            m_shards: mk_shards(m_store),
            v_shards: mk_shards(v_store),
            shard_map,
            topo,
            fp8_intra,
            fp8_inter,
            last_collective: CollectiveStats::default(),
            collective_scratch: CollectiveScratch::default(),
            collective_scratch_alt: CollectiveScratch::default(),
            bucket_sched,
            worker_grads: vec![Vec::new(); cfg.streams()],
            p_flat: Vec::new(),
            adam_work,
            adam_scratch,
            meter: StepMeter::new(flops),
            step: 0,
            force_serial_workers: false,
            force_phased_step: false,
            inject_worker_panic: None,
            poisoned: false,
            params,
            scale_mgr,
            gemm,
            detector: DivergenceDetector::default(),
            batcher,
            sched,
            rt,
            grad_art,
            adam_art,
            cfg,
        })
    }

    /// The PJRT runtime this trainer executes artifacts on.
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// The validated **logical plan** topology the gradient collective
    /// runs on: `cfg.streams()` replicas in `cfg.stream_pod_count()`
    /// plan pods (plan pods = 1 is the flat collective). This is
    /// numerics identity — it survives a physical reshard unchanged.
    pub fn topology(&self) -> PodTopology {
        self.topo
    }

    /// The Adam-chunk-aligned bucket schedule the overlapped pipeline
    /// partitions the flat gradient into.
    pub fn bucket_schedule(&self) -> &BucketSchedule {
        &self.bucket_sched
    }

    /// Whether a failed optimizer step has left the in-memory state
    /// inconsistent (see the `poisoned` field) — `step()` refuses to
    /// run until the state is externally restored.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Mark the trainer state consistent again after a full external
    /// state restoration (campaign snapshot rollback: params, moments,
    /// scaling and detector state all rewritten). Clearing the
    /// poisoned latch without actually restoring state would silently
    /// train from corrupt moments — only `campaign::snapshot` calls
    /// this, right after `TrainState::apply_to` rewrote everything.
    pub(crate) fn mark_state_restored(&mut self) {
        self.poisoned = false;
    }

    /// The grad artifact's manifest (model dims, param specs, FLOPs).
    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.grad_art.manifest
    }

    /// Tokens consumed per optimizer step across all logical streams
    /// and microbatches (independent of the physical lane count).
    pub fn tokens_per_step(&self) -> usize {
        let m = &self.grad_art.manifest;
        m.batch * m.seq_len * self.cfg.streams() * self.cfg.grad_accum
    }

    /// The chunked Adam artifact's chunk size — the granularity at
    /// which the kernel quantizes FP8 moment outputs, and therefore
    /// the chunk size campaign snapshots must use for their exact-FP8
    /// moment sections to line up with the grids the kernel produced.
    pub fn adam_chunk(&self) -> usize {
        self.adam_art.manifest.chunk
    }

    /// Gathered full copies of the flat Adam moments, assembled from
    /// the per-worker ZeRO-1 shards in shard order (= global offset
    /// order, so the result is the exact flat layout pre-sharding code
    /// kept). Packed FP8 shards are decoded through the pure LUT path
    /// without disturbing their resident state — exact-mode packing
    /// makes the gathered bits identical to what `apply_adam` last
    /// wrote.
    pub fn moments_flat(&self) -> (Vec<f32>, Vec<f32>) {
        let total = self.params.total_elems();
        let gather = |shards: &[MomentBuffer]| -> Vec<f32> {
            let mut out = Vec::with_capacity(total);
            let mut tmp = Vec::new();
            for b in shards {
                b.snapshot_into(&mut tmp);
                out.extend_from_slice(&tmp);
            }
            out
        };
        (gather(&self.m_shards), gather(&self.v_shards))
    }

    /// Scatter full flat moments back into the per-worker shards
    /// (campaign-snapshot restore; lengths pre-validated by the
    /// caller).
    pub(crate) fn set_moments_flat(&mut self, m: &[f32], v: &[f32]) {
        for (b, &(off, len)) in self.m_shards.iter_mut().zip(&self.shard_map.shards) {
            b.load_from(&m[off..off + len]);
        }
        for (b, &(off, len)) in self.v_shards.iter_mut().zip(&self.shard_map.shards) {
            b.load_from(&v[off..off + len]);
        }
    }

    /// Resident Adam-moment bytes on the heaviest worker — the ZeRO-1
    /// per-worker memory measurement the perf bench records (compare
    /// against `8 · total_elems` for the replicated-f32 baseline).
    pub fn moment_bytes_per_worker(&self) -> usize {
        self.m_shards
            .iter()
            .zip(&self.v_shards)
            .map(|(m, v)| m.resident_bytes() + v.resident_bytes())
            .max()
            .unwrap_or(0)
    }

    /// Wire-byte accounting of the most recent step's gradient
    /// collective (zeroed until the first step completes).
    pub fn collective_stats(&self) -> CollectiveStats {
        self.last_collective
    }

    /// A training batch tensor (for probe/analysis passes that re-run
    /// the model outside the step loop).
    pub fn batch_tensor(&self, step: usize) -> HostTensor {
        HostTensor::from_i32(&self.batcher.shape(), self.batcher.batch(step, 0, 0))
    }

    /// Current scales as a tensor (probe passes).
    pub fn scales_tensor(&self) -> HostTensor {
        HostTensor::from_f32(&[self.scale_mgr.n_sites()], self.scale_mgr.scales().to_vec())
    }

    fn pass_ctx(&self) -> PassCtx<'_> {
        let gemm = self.gemm.as_ref();
        PassCtx {
            art: &self.grad_art,
            batcher: &self.batcher,
            // gemm recipes read the tile-gridded weight copy; the f32
            // masters stay with the optimizer
            params: gemm.map(|g| &g.qparams).unwrap_or(&self.params),
            gemm,
            grad_accum: self.cfg.grad_accum,
            ns: self.scale_mgr.n_sites(),
            step: self.step,
            panic_drill: self.inject_worker_panic,
        }
    }

    /// Run one full training step. Dispatches to the bucketed
    /// overlapped pipeline unless it is pinned off: the phased
    /// schedule runs when `force_phased_step` is set (session key /
    /// identity tests), when `force_serial_workers` pins the serial
    /// reference, or when `overlap_comm = false` in the config. All
    /// schedules are bit-identical (see module docs).
    pub fn step(&mut self) -> Result<StepOutcome> {
        if self.poisoned {
            return Err(anyhow!(
                "trainer state is inconsistent after a failed optimizer step \
                 (moments partially updated); restart from a checkpoint"
            ));
        }
        // fp8_gemm recipes: refresh the tile-gridded weight copy from
        // the masters once per step, before any pass — every schedule
        // then reads identical quantized weights
        if let Some(g) = self.gemm.as_mut() {
            g.refresh(&self.params);
        }
        if self.force_phased_step || self.force_serial_workers || !self.cfg.overlap_comm {
            self.step_phased()
        } else {
            self.step_overlapped()
        }
    }

    /// The phased reference schedule: all grad passes → one
    /// whole-buffer collective → norm/clip → chunked Adam. The
    /// overlapped pipeline must match this bit-for-bit.
    fn step_phased(&mut self) -> Result<StepOutcome> {
        let man = self.grad_art.manifest.clone();
        let ns = self.scale_mgr.n_sites();
        let scales = HostTensor::from_f32(&[ns], self.scale_mgr.scales().to_vec());
        let mut timers = PhaseTimers {
            buckets: 1,
            overlapped: false,
            ..Default::default()
        };

        // ---- (1) per-stream microbatched grads, the S logical
        //      streams dealt round-robin onto min(W, S) physical lanes
        //      (one scoped thread each; PJRT CPU executions are
        //      thread-safe — apply_adam already relies on this). Each
        //      lane runs its streams in ascending order and the merge
        //      re-sorts by stream id, so the lane count is invisible to
        //      the numbers. `force_serial_workers` runs the identical
        //      passes inline — same partials, same merge, so the two
        //      schedules are bit-identical.
        let t_grad = Instant::now();
        let streams = self.cfg.streams();
        let lanes_n = self.cfg.dp_workers.min(streams).max(1);
        let mut grads = std::mem::take(&mut self.worker_grads);
        let ctx = self.pass_ctx();
        let mut panic_err: Option<anyhow::Error> = None;
        let passes_res: Result<Vec<WorkerPass>> = if lanes_n == 1 || self.force_serial_workers
        {
            grads
                .iter_mut()
                .enumerate()
                .map(|(sid, buf)| run_worker_pass(&ctx, sid, &scales, buf))
                .collect()
        } else {
            let ctx_ref = &ctx;
            let scales_ref = &scales;
            let mut lane_work: Vec<Vec<(usize, &mut Vec<f32>)>> =
                (0..lanes_n).map(|_| Vec::new()).collect();
            for (sid, buf) in grads.iter_mut().enumerate() {
                lane_work[sid % lanes_n].push((sid, buf));
            }
            std::thread::scope(|s| {
                let handles: Vec<_> = lane_work
                    .into_iter()
                    .map(|work| {
                        s.spawn(move || -> Vec<(usize, Result<WorkerPass>)> {
                            work.into_iter()
                                .map(|(sid, buf)| {
                                    (sid, run_worker_pass(ctx_ref, sid, scales_ref, buf))
                                })
                                .collect()
                        })
                    })
                    .collect();
                let mut tagged: Vec<(usize, WorkerPass)> = Vec::with_capacity(streams);
                let mut first_err: Option<anyhow::Error> = None;
                for (lane, h) in handles.into_iter().enumerate() {
                    match contain_panic(h.join(), "grad worker") {
                        Ok(results) => {
                            for (sid, res) in results {
                                match res {
                                    Ok(p) => tagged.push((sid, p)),
                                    Err(e) => {
                                        first_err.get_or_insert(
                                            e.context(format!("grad stream {sid} failed")),
                                        );
                                    }
                                }
                            }
                        }
                        Err(e) => {
                            panic_err.get_or_insert(
                                e.context(format!("grad worker lane {lane} panicked")),
                            );
                        }
                    }
                }
                if let Some(e) = first_err {
                    return Err(e);
                }
                tagged.sort_unstable_by_key(|&(sid, _)| sid);
                Ok(tagged.into_iter().map(|(_, p)| p).collect())
            })
        };
        drop(ctx);
        // restore the buffers before propagating any error: a failed
        // step must leave the trainer stepable (a second step() should
        // fail or succeed cleanly, never panic on empty replica state)
        self.worker_grads = grads;
        if let Some(e) = panic_err {
            // a panicked worker may have unwound mid-write into its
            // grad buffer; nothing downstream ran, but the buffers are
            // not trustworthy and the pass partials are gone — same
            // contract as an apply_adam failure
            self.poisoned = true;
            return Err(e.context(
                "a gradient worker panicked mid-step; trainer state is poisoned — \
                 resume from the latest campaign snapshot",
            ));
        }
        let passes = passes_res?;
        timers.grad_s = t_grad.elapsed().as_secs_f64();

        let (loss, amax, monitor) =
            merge_passes(&passes, ns, man.n_layers, streams * self.cfg.grad_accum);

        // ---- (2) gradient collective: pod-aware two-level schedule —
        //      intra-pod reduce-scatter → inter-pod exchange over pod
        //      leaders → intra-pod all-gather, with per-level FP8 wire
        //      compression (per-chunk pow2 JIT scales, FP8-LM-style).
        //      Rank 0 holds the gathered average (the only copy
        //      consumed — every replica buffer is overwritten by the
        //      next step's worker pass). At pods=1 with intra
        //      compression off this is bit-identical to the rank-0
        //      reduce.
        let t_coll = Instant::now();
        self.last_collective = hier_grad_collective_with(
            &mut self.worker_grads,
            self.topo,
            self.fp8_intra,
            self.fp8_inter,
            self.shard_map.chunk,
            &mut self.collective_scratch,
        );
        timers.collective_s = t_coll.elapsed().as_secs_f64();
        // the phased schedule hides nothing: every collective second
        // is exposed stall
        timers.comm_exposed_s = timers.collective_s;

        // ---- (3) global-norm clip. Non-finite grads either skip the
        //      update (production protection) or pass through at clip 1
        //      (exposing the paper's hard divergence), per config.
        let t_norm = Instant::now();
        let gnorm = global_norm(&self.worker_grads[0]);
        timers.norm_s = t_norm.elapsed().as_secs_f64();
        let clip = if !gnorm.is_finite() && !self.cfg.skip_nonfinite_updates {
            1.0
        } else {
            clip_factor(gnorm, self.cfg.grad_clip)
        };

        // ---- (4) chunked AdamW over decay groups (C-aligned so FP8
        //      moment scales are per-absolute-chunk, see optimizer::)
        let lr = self.sched.lr(self.step);
        if clip > 0.0 {
            let t_adam = Instant::now();
            self.apply_adam(lr, clip)?;
            timers.adam_s = t_adam.elapsed().as_secs_f64();
        }

        // ---- (5) scaling + divergence bookkeeping
        self.scale_mgr.update(&amax);
        let verdict = self
            .detector
            .observe(self.step, loss, self.scale_mgr.overflow_events);

        self.step += 1;
        let stats = self.meter.tick(self.tokens_per_step());
        Ok(StepOutcome {
            step: self.step - 1,
            loss,
            grad_norm: gnorm,
            lr,
            verdict,
            monitor,
            timers,
            stats,
        })
    }

    /// The bucketed overlapped pipeline. Three thread roles inside one
    /// scope:
    ///
    /// * **grad lanes** (min(W, S) scoped threads): each lane runs its
    ///   round-robin share of the S logical streams in ascending
    ///   stream order — pass into the stream's replica buffer, then
    ///   split the buffer into the bucket windows and send each window
    ///   — in ascending bucket order — down the stream's channel to
    ///   the comms thread (channels are unbounded, so a lane never
    ///   blocks on a later stream while comms waits on an earlier one);
    /// * **comms thread**: for each bucket in order, receives all S
    ///   windows (stream order), runs the two-level per-bucket
    ///   collective over the logical plan topology on alternating
    ///   scratch sets, and ships rank-0's reduced window to the main
    ///   thread together with the wire stats and the instant the
    ///   collective started;
    /// * **main thread**: as each bucket lands, folds its norm partial
    ///   (`NormStream`, exact `global_norm` fold order) and — when the
    ///   clip factor is provably 1 before the norm exists (grad_clip
    ///   off and non-finite passthrough) — dispatches the bucket's
    ///   Adam chunks immediately; otherwise latches the windows and
    ///   runs Adam after the last bucket fixes the clip factor.
    ///
    /// Identity argument (pinned by tests): bucket starts sit on the
    /// absolute Adam-chunk grid, so per-bucket FP8 wire grids, the f32
    /// tree-reduce order, the mean scaling, the f64 norm fold, the
    /// per-chunk Adam scalars and the moment-shard carve are all
    /// exactly the phased schedule's — only wall-clock interleaving
    /// differs, and no numeric depends on it.
    fn step_overlapped(&mut self) -> Result<StepOutcome> {
        let man = self.grad_art.manifest.clone();
        let ns = self.scale_mgr.n_sites();
        let scales = HostTensor::from_f32(&[ns], self.scale_mgr.scales().to_vec());
        let n_params = self.params.total_elems();
        let streams = self.cfg.streams();
        let lanes_n = self.cfg.dp_workers.min(streams).max(1);
        let grad_accum = self.cfg.grad_accum;
        let grad_clip = self.cfg.grad_clip;
        let skip_nonfinite = self.cfg.skip_nonfinite_updates;
        let pack_moments = self.cfg.pack_moments;
        let lr = self.sched.lr(self.step);
        let step_f = (self.step + 1) as f32;
        // when clipping is off AND non-finite norms pass through, the
        // phased path's clip factor is 1.0 no matter what the norm
        // turns out to be — only then may Adam start before the norm
        // is complete. (clip_factor: norm<=max || max<=0 → 1.0;
        // non-finite && !skip → 1.0.)
        let eager_clip: Option<f32> =
            (grad_clip <= 0.0 && !skip_nonfinite).then_some(1.0);

        let mut grads = std::mem::take(&mut self.worker_grads);
        let mut p_flat = std::mem::take(&mut self.p_flat);

        // disjoint field borrows for the scoped threads
        let Trainer {
            grad_art,
            adam_art,
            params,
            batcher,
            gemm,
            scale_mgr,
            shard_map,
            m_shards,
            v_shards,
            topo,
            fp8_intra,
            fp8_inter,
            collective_scratch,
            collective_scratch_alt,
            adam_work,
            adam_scratch,
            bucket_sched,
            step: step_now,
            inject_worker_panic,
            ..
        } = self;
        let grad_art: &Artifact = &**grad_art;
        let adam_art: &Artifact = &**adam_art;
        let topo = *topo;
        let fp8_intra = *fp8_intra;
        let fp8_inter = *fp8_inter;
        let chunk = shard_map.chunk;
        let step_now = *step_now;
        let panic_drill = *inject_worker_panic;
        // step() already refreshed the engine's weight copy from the
        // masters; the passes read that copy, Adam reads the masters
        let gemm = gemm.as_ref();
        let ctx = PassCtx {
            art: grad_art,
            batcher,
            params: gemm.map(|g| &g.qparams).unwrap_or(params),
            gemm,
            grad_accum,
            ns: scale_mgr.n_sites(),
            step: step_now,
            panic_drill,
        };
        debug_assert_eq!(ns, ctx.ns);

        // flat params + unpacked moment shard views, carved into
        // per-chunk units grouped by owning bucket — the exact same
        // cursor walk as apply_adam, so every window is the phased
        // path's window
        params.flatten_into(&mut p_flat);
        let mut m_views: Vec<&mut [f32]> =
            m_shards.iter_mut().map(|b| b.as_f32().as_mut_slice()).collect();
        let mut v_views: Vec<&mut [f32]> =
            v_shards.iter_mut().map(|b| b.as_f32().as_mut_slice()).collect();
        let n_buckets = bucket_sched.len();
        let mut bucket_units: Vec<Vec<BucketUnit<'_>>> =
            (0..n_buckets).map(|_| Vec::new()).collect();
        {
            let mut pc = &mut p_flat[..];
            let mut cursor = 0usize;
            let mut pos = vec![0usize; shard_map.n_workers()];
            for &(off, len, wd) in adam_work.iter() {
                let owner = shard_map.owner_of(off);
                let local = off - shard_map.of_worker(owner).0;
                let skip = off - cursor;
                let m_win = carve(&mut m_views[owner], local - pos[owner], len);
                let v_win = carve(&mut v_views[owner], local - pos[owner], len);
                pos[owner] = local + len;
                // a unit never straddles buckets: units are C-aligned
                // sub-chunk ranges and bucket lengths are multiples of
                // the chunk, so the whole unit lives in bucket_of(off)
                bucket_units[bucket_sched.bucket_of(off)].push(BucketUnit {
                    off,
                    len,
                    wd,
                    p: carve(&mut pc, skip, len),
                    m: m_win,
                    v: v_win,
                });
                cursor = off + len;
            }
        }
        let sched: &[(usize, usize)] = &bucket_sched.buckets;

        // pipeline outcome state, written inside the scope
        let mut passes: Vec<WorkerPass> = Vec::with_capacity(streams);
        let mut worker_err: Option<anyhow::Error> = None;
        let mut panicked = false;
        let mut pipe_err: Option<anyhow::Error> = None;
        let mut adam_ran = false;
        let mut adam_failed = false;
        let mut gnorm = f32::NAN;
        let mut clip = 1.0f32;
        let mut stats_total = CollectiveStats::default();
        let mut timers = PhaseTimers {
            buckets: n_buckets,
            overlapped: true,
            ..Default::default()
        };

        std::thread::scope(|s| {
            // one channel per logical stream: whichever lane runs the
            // stream sends its bucket windows (ascending bucket order)
            // to the comms thread
            let mut bucket_txs = Vec::with_capacity(streams);
            let mut bucket_rxs = Vec::with_capacity(streams);
            for _ in 0..streams {
                let (tx, rx) = mpsc::channel::<&mut [f32]>();
                bucket_txs.push(tx);
                bucket_rxs.push(rx);
            }
            // landed buckets: comms → main
            let (land_tx, land_rx) =
                mpsc::channel::<(usize, &mut [f32], CollectiveStats, Instant)>();

            let ctx_ref = &ctx;
            let scales_ref = &scales;
            // deal the S streams round-robin onto the physical lanes;
            // a lane runs its streams sequentially in ascending order
            let mut lane_work: Vec<Vec<(usize, &mut Vec<f32>, mpsc::Sender<&mut [f32]>)>> =
                (0..lanes_n).map(|_| Vec::new()).collect();
            for ((sid, buf), tx) in grads.iter_mut().enumerate().zip(bucket_txs) {
                lane_work[sid % lanes_n].push((sid, buf, tx));
            }
            let worker_handles: Vec<_> = lane_work
                .into_iter()
                .map(|work| {
                    s.spawn(move || -> Vec<(usize, Result<WorkerPass>, f64)> {
                        let mut out = Vec::with_capacity(work.len());
                        for (sid, buf, tx) in work {
                            let t0 = Instant::now();
                            let res = run_worker_pass(ctx_ref, sid, scales_ref, &mut *buf);
                            let dt = t0.elapsed().as_secs_f64();
                            if res.is_ok() {
                                // split the replica buffer into the
                                // bucket windows and hand them to comms
                                // in order; if comms already exited
                                // (pipeline error), sends fail and we
                                // just stop
                                let mut rest = buf.as_mut_slice();
                                for &(_, len) in sched {
                                    let (win, tail) = rest.split_at_mut(len);
                                    rest = tail;
                                    if tx.send(win).is_err() {
                                        break;
                                    }
                                }
                            }
                            out.push((sid, res, dt));
                        }
                        out
                    })
                })
                .collect();

            let (scr0, scr1) = (collective_scratch, collective_scratch_alt);
            let comms_handle = s.spawn(move || -> Result<f64> {
                let mut busy = 0.0f64;
                for (k, &(off, _)) in sched.iter().enumerate() {
                    let mut wins: Vec<&mut [f32]> = Vec::with_capacity(streams);
                    for (sid, rx) in bucket_rxs.iter().enumerate() {
                        match rx.recv() {
                            Ok(win) => wins.push(win),
                            Err(_) => {
                                return Err(anyhow!(
                                    "grad stream {sid} stopped before sending bucket {k} \
                                     (its pass failed or its lane panicked)"
                                ))
                            }
                        }
                    }
                    // double-buffered scratch: bucket k encodes while
                    // the main thread may still read bucket k-1's lanes
                    let scratch = if k % 2 == 0 { &mut *scr0 } else { &mut *scr1 };
                    let started = Instant::now();
                    let stats = hier_bucket_collective(
                        &mut wins, off, topo, fp8_intra, fp8_inter, chunk, scratch,
                    );
                    busy += started.elapsed().as_secs_f64();
                    let rank0 = wins.swap_remove(0);
                    if land_tx.send((k, rank0, stats, started)).is_err() {
                        break; // main thread bailed; unwind quietly
                    }
                }
                Ok(busy)
            });

            // main thread: consume landed buckets in order
            let mut landed: Vec<Option<&mut [f32]>> = (0..n_buckets).map(|_| None).collect();
            let mut norm = NormStream::new();
            for _ in 0..n_buckets {
                let wait0 = Instant::now();
                let Ok((k, win, stats, comm_started)) = land_rx.recv() else {
                    break; // comms thread errored; its join reports why
                };
                let done = Instant::now();
                // exposed = time this bucket's collective ran while we
                // had nothing else to do: from the later of (collective
                // start, us going idle) until it landed
                let from = if comm_started > wait0 { comm_started } else { wait0 };
                timers.comm_exposed_s += done.duration_since(from).as_secs_f64();
                let t_norm = Instant::now();
                norm.push(win);
                timers.norm_s += t_norm.elapsed().as_secs_f64();
                stats_total.absorb(&stats);
                if let Some(c) = eager_clip {
                    let t_adam = Instant::now();
                    match run_bucket_adam(
                        adam_art,
                        adam_scratch,
                        std::mem::take(&mut bucket_units[k]),
                        win,
                        sched[k].0,
                        lr,
                        step_f,
                        c,
                    ) {
                        Ok(()) => adam_ran = true,
                        Err(e) => {
                            adam_failed = true;
                            pipe_err = Some(e);
                            break;
                        }
                    }
                    timers.adam_s += t_adam.elapsed().as_secs_f64();
                }
                landed[k] = Some(win);
            }

            // norm + (non-eager) Adam only when every bucket landed
            if norm.elems() == n_params && pipe_err.is_none() {
                gnorm = norm.finish();
                clip = match eager_clip {
                    Some(c) => c,
                    None => {
                        if !gnorm.is_finite() && !skip_nonfinite {
                            1.0
                        } else {
                            clip_factor(gnorm, grad_clip)
                        }
                    }
                };
                if eager_clip.is_none() && clip > 0.0 {
                    let t_adam = Instant::now();
                    for k in 0..n_buckets {
                        let win = landed[k].as_deref().expect("bucket landed");
                        match run_bucket_adam(
                            adam_art,
                            adam_scratch,
                            std::mem::take(&mut bucket_units[k]),
                            win,
                            sched[k].0,
                            lr,
                            step_f,
                            clip,
                        ) {
                            Ok(()) => adam_ran = true,
                            Err(e) => {
                                adam_failed = true;
                                pipe_err = Some(e);
                                break;
                            }
                        }
                    }
                    timers.adam_s += t_adam.elapsed().as_secs_f64();
                }
            }
            drop(land_rx); // let any still-running comms send fail fast

            let mut tagged: Vec<(usize, WorkerPass)> = Vec::with_capacity(streams);
            for (lane, h) in worker_handles.into_iter().enumerate() {
                match contain_panic(h.join(), "grad worker") {
                    Ok(results) => {
                        for (sid, res, dt) in results {
                            timers.grad_s = timers.grad_s.max(dt);
                            match res {
                                Ok(pass) => tagged.push((sid, pass)),
                                Err(e) => {
                                    worker_err.get_or_insert(
                                        e.context(format!("grad stream {sid} failed")),
                                    );
                                }
                            }
                        }
                    }
                    Err(e) => {
                        panicked = true;
                        worker_err
                            .get_or_insert(e.context(format!("grad worker lane {lane} panicked")));
                    }
                }
            }
            // ascending stream order, independent of lane assignment
            tagged.sort_unstable_by_key(|&(sid, _)| sid);
            passes.extend(tagged.into_iter().map(|(_, p)| p));
            match contain_panic(comms_handle.join(), "collective comms thread") {
                Ok(Ok(busy)) => timers.collective_s = busy,
                Ok(Err(e)) => {
                    pipe_err.get_or_insert(e);
                }
                Err(e) => {
                    panicked = true;
                    pipe_err.get_or_insert(e);
                }
            }
        });

        // the unit windows borrow p_flat / the moment shards; release
        // them before touching self again
        drop(bucket_units);
        drop(m_views);
        drop(v_views);
        drop(ctx);
        self.worker_grads = grads;
        self.p_flat = p_flat;

        // failure triage. A worker artifact Err mutates nothing
        // downstream (comms never assembles bucket 0), so it does NOT
        // poison; any panic or a failure after Adam chunks were
        // dispatched may have left state partially advanced and does.
        if panicked || adam_failed {
            self.poisoned = true;
        }
        if let Some(e) = worker_err {
            return Err(if panicked {
                e.context(
                    "a gradient worker panicked mid-step; trainer state is poisoned — \
                     resume from the latest campaign snapshot",
                )
            } else {
                e
            });
        }
        if let Some(e) = pipe_err {
            return Err(if self.poisoned {
                e.context(
                    "the overlapped step failed after optimizer chunks were dispatched; \
                     trainer state is poisoned — resume from the latest campaign snapshot",
                )
            } else {
                e
            });
        }

        self.last_collective = stats_total;
        if adam_ran {
            self.params.unflatten_from(&self.p_flat);
            // re-pack the moment shards between steps (the ZeRO-1
            // resident-memory story); exact-mode packing is
            // bit-preserving by construction
            if pack_moments {
                for b in self.m_shards.iter_mut().chain(self.v_shards.iter_mut()) {
                    b.pack();
                }
            }
        }

        let (loss, amax, monitor) =
            merge_passes(&passes, ns, man.n_layers, streams * grad_accum);
        self.scale_mgr.update(&amax);
        let verdict = self
            .detector
            .observe(self.step, loss, self.scale_mgr.overflow_events);

        self.step += 1;
        let stats = self.meter.tick(self.tokens_per_step());
        Ok(StepOutcome {
            step: self.step - 1,
            loss,
            grad_norm: gnorm,
            lr,
            verdict,
            monitor,
            timers,
            stats,
        })
    }

    /// Chunked AdamW through the `adam_*` artifact, **in parallel**
    /// across a worker pool — the ZeRO-1 optimizer step really is
    /// embarrassingly parallel over shards, and the PJRT CPU client
    /// accepts concurrent executions.
    ///
    /// Sharded state: each chunk's moments live only in its owner's
    /// `MomentBuffer` shard (the chunk-aligned `shard_map` decides the
    /// owner), so a unit's m/v windows are carved from that worker's
    /// shard while the param/grad windows stay global — the in-place
    /// param rewrite at the end is the simulated pod's parameter
    /// all-gather. Execution lanes are just threads; which lane runs a
    /// chunk never changes any bit (chunks are independent).
    ///
    /// Allocation discipline: the chunk work list is cached, the flat
    /// parameter scratch persists across steps, each thread owns a
    /// reusable `AdamScratch` pad set, and artifact outputs are copied
    /// straight into pre-carved disjoint windows of the flat state —
    /// the steady-state loop performs no per-chunk heap allocation on
    /// the coordinator side.
    fn apply_adam(&mut self, lr: f32, clip: f32) -> Result<()> {
        let grads = std::mem::take(&mut self.worker_grads); // borrow dance
        let g_flat = &grads[0];
        let mut p_flat = std::mem::take(&mut self.p_flat);
        self.params.flatten_into(&mut p_flat); // clear + refill, capacity kept

        let step_f = (self.step + 1) as f32;
        let n_threads = self.adam_scratch.len().min(self.adam_work.len().max(1));

        // unpack every worker's moment shards (no-op when already
        // resident f32); the element borrows are disjoint per worker
        let mut m_views: Vec<&mut [f32]> =
            self.m_shards.iter_mut().map(|b| b.as_f32().as_mut_slice()).collect();
        let mut v_views: Vec<&mut [f32]> =
            self.v_shards.iter_mut().map(|b| b.as_f32().as_mut_slice()).collect();

        // carve the flat buffers into per-chunk disjoint windows
        // (offset order; m/v carve from the owning worker's shard) and
        // deal them round-robin to the worker lanes; chunks are
        // uniform (C-aligned), so static assignment balances
        let mut lanes: Vec<Vec<AdamUnit>> = (0..n_threads)
            .map(|_| Vec::with_capacity(self.adam_work.len().div_ceil(n_threads.max(1))))
            .collect();
        {
            let mut pc = &mut p_flat[..];
            let mut gc = g_flat.as_slice();
            let mut cursor = 0usize;
            // per-owner consumed position (local coordinates)
            let mut pos = vec![0usize; self.shard_map.n_workers()];
            for (i, &(off, len, wd)) in self.adam_work.iter().enumerate() {
                let owner = self.shard_map.owner_of(off);
                let local = off - self.shard_map.of_worker(owner).0;
                let skip = off - cursor;
                let (g_win, g_rest) = gc[skip..].split_at(len);
                gc = g_rest;
                let m_win = carve(&mut m_views[owner], local - pos[owner], len);
                let v_win = carve(&mut v_views[owner], local - pos[owner], len);
                pos[owner] = local + len;
                lanes[i % n_threads].push(AdamUnit {
                    len,
                    wd,
                    p: carve(&mut pc, skip, len),
                    m: m_win,
                    v: v_win,
                    g: g_win,
                });
                cursor = off + len;
            }
        }

        let art = &self.adam_art;
        let run_res = std::thread::scope(|s| -> Result<()> {
            let handles: Vec<_> = lanes
                .into_iter()
                .zip(self.adam_scratch.iter_mut())
                .map(|(lane, scratch)| {
                    s.spawn(move || -> Result<()> {
                        for u in lane {
                            scratch.load(u.p, u.m, u.v, u.g, [lr, u.wd, step_f, clip]);
                            let res = art.run(&scratch.inputs)?;
                            u.p.copy_from_slice(&res[0].f32s()[..u.len]);
                            u.m.copy_from_slice(&res[1].f32s()[..u.len]);
                            u.v.copy_from_slice(&res[2].f32s()[..u.len]);
                        }
                        Ok(())
                    })
                })
                .collect();
            for h in handles {
                contain_panic(h.join(), "adam worker")??;
            }
            Ok(())
        });

        // restore the reusable buffers unconditionally (no panic on a
        // later step), but an error here means some chunks already
        // streamed their results into the moment shards while params
        // were not scattered — that state must not be stepped from
        // again
        self.p_flat = p_flat;
        self.worker_grads = grads;
        if run_res.is_err() {
            self.poisoned = true;
        }
        run_res?;
        self.params.unflatten_from(&self.p_flat);
        // re-pack the moment shards between steps (the ZeRO-1
        // resident-memory story); exact-mode packing is bit-preserving
        // by construction, so this can never change the next step's
        // numbers (integration-test pinned via `pack_moments = false`)
        if self.cfg.pack_moments {
            for b in self.m_shards.iter_mut().chain(self.v_shards.iter_mut()) {
                b.pack();
            }
        }
        Ok(())
    }

    /// Held-out evaluation through an eval artifact (perplexity + top-1
    /// accuracy over `n_batches` deterministic eval batches).
    pub fn eval(&self, recipe: &str, n_batches: usize) -> Result<(f64, f64)> {
        let name = format!("eval_{}_{}", self.cfg.size, recipe);
        let art = self.rt.load(&name)?;
        let ns = self.scale_mgr.n_sites();
        let scales = HostTensor::from_f32(&[ns], self.scale_mgr.scales().to_vec());
        let (mut nll, mut correct, mut total) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..n_batches {
            let tokens = self.batcher.eval_batch(i);
            let batch = HostTensor::from_i32(&self.batcher.shape(), tokens);
            let mut inputs: Vec<&HostTensor> =
                Vec::with_capacity(self.params.tensors.len() + 2);
            inputs.extend(self.params.tensors.iter());
            inputs.push(&scales);
            inputs.push(&batch);
            let out = art.run_refs(&inputs)?;
            nll += out[0].scalar_f32() as f64;
            correct += out[1].scalar_f32() as f64;
            total += out[2].scalar_f32() as f64;
        }
        Ok(((nll / total).exp(), correct / total))
    }

    /// Wall-clock seconds since the trainer was built (step meter).
    pub fn wall_s(&self) -> f64 {
        self.meter.wall_s()
    }
}
