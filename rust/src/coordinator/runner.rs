//! High-level experiment runner shared by the examples and the bench
//! harness: run a training curve, record the series, dump CSV.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::runtime::Runtime;
use crate::util::csv::CsvWriter;

/// One recorded training curve.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    /// series label, `<size>_<recipe>`
    pub label: String,
    /// (step, loss, grad_norm, swiglu_amax_max, overflow_events)
    pub rows: Vec<(usize, f32, f32, f32, usize)>,
    /// first step the divergence detector latched, if any
    pub diverged_at: Option<usize>,
    /// wall-clock seconds for the whole run
    pub wall_s: f64,
    /// wall-clock seconds per executed step
    pub mean_step_s: f64,
}

impl Curve {
    /// Loss of the last recorded row, or NaN for an empty curve.
    ///
    /// Invariant: equals `tail_loss(1)` whenever the curve is
    /// non-empty.
    pub fn final_loss(&self) -> f32 {
        self.rows.last().map(|r| r.1).unwrap_or(f32::NAN)
    }

    /// Mean loss over the last `k` recorded rows (noise-robust).
    ///
    /// **Saturates** when `k` exceeds the number of recorded rows: the
    /// mean is then taken over the whole curve. This makes short
    /// smoke-test curves comparable in summary tables (the historical
    /// behavior, now contractual); callers that must know whether the
    /// window was actually full should use
    /// [`tail_loss_strict`](Self::tail_loss_strict). Returns NaN on an
    /// empty curve (and for `k == 0`).
    ///
    /// # Examples
    ///
    /// ```
    /// use fp8_trainer::coordinator::runner::Curve;
    /// let mut c = Curve::default();
    /// c.rows = vec![(0, 4.0, 1.0, 0.0, 0), (1, 2.0, 1.0, 0.0, 0)];
    /// assert_eq!(c.tail_loss(1), 2.0);
    /// assert_eq!(c.tail_loss(100), 3.0); // saturates at the full curve
    /// assert!(Curve::default().tail_loss(5).is_nan());
    /// ```
    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.rows.len();
        if n == 0 || k == 0 {
            return f32::NAN;
        }
        let take = k.min(n);
        self.rows[n - take..].iter().map(|r| r.1).sum::<f32>() / take as f32
    }

    /// [`tail_loss`](Self::tail_loss) without the saturation: errors
    /// when the curve has fewer than `k` rows (or `k == 0`), instead
    /// of silently averaging a shorter window. Use this in acceptance
    /// checks where "tail over 5 rows" must mean exactly 5 rows.
    pub fn tail_loss_strict(&self, k: usize) -> Result<f32> {
        if k == 0 {
            return Err(anyhow!("tail_loss_strict: window must be >= 1"));
        }
        if self.rows.len() < k {
            return Err(anyhow!(
                "tail_loss_strict: window of {k} rows requested but curve '{}' has only {}",
                self.label,
                self.rows.len()
            ));
        }
        Ok(self.tail_loss(k))
    }
}

/// Run `cfg` to completion (or divergence), sampling every
/// `record_every` steps.
///
/// After the detector latches, up to `extra_after_divergence` further
/// steps are executed before stopping — this keeps curves comparable
/// while letting a diverging config show its spike. Invariants: the
/// returned curve always records the final executed step, so
/// [`Curve::final_loss`] reflects where the run actually ended; and
/// `record_every == 0` is treated as 1 (record every step) rather
/// than panicking on the modulus.
pub fn run_curve(
    rt: &Arc<Runtime>,
    cfg: TrainConfig,
    record_every: usize,
    extra_after_divergence: usize,
) -> Result<Curve> {
    let record_every = record_every.max(1);
    let label = format!("{}_{}", cfg.size, cfg.recipe);
    let steps = cfg.steps;
    let mut t = Trainer::new(rt.clone(), cfg)?;
    let mut curve = Curve { label, ..Default::default() };
    let mut after_div = 0usize;
    let mut last_row: Option<(usize, f32, f32, f32, usize)> = None;
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let o = t.step()?;
        let swiglu = o.monitor.iter().map(|m| m[0]).fold(0.0f32, f32::max);
        let row = (o.step, o.loss, o.grad_norm, swiglu, t.scale_mgr.overflow_events);
        last_row = Some(row);
        if o.step % record_every == 0 || o.step + 1 == steps {
            curve.rows.push(row);
        }
        if t.detector.has_diverged() {
            curve.diverged_at = curve.diverged_at.or(t.detector.diverged_at);
            after_div += 1;
            if after_div > extra_after_divergence {
                break;
            }
        }
    }
    // the divergence early-break can land between sample points: the
    // final executed step is always recorded so final_loss/tail_loss
    // reflect where the run actually ended
    if let Some(row) = last_row {
        if curve.rows.last().map_or(true, |r| r.0 != row.0) {
            curve.rows.push(row);
        }
    }
    curve.wall_s = t0.elapsed().as_secs_f64();
    curve.mean_step_s = curve.wall_s / (t.step.max(1) as f64);
    Ok(curve)
}

/// Dump curves side by side (long format: one row per recorded step
/// per series) for re-plotting.
pub fn write_curves_csv<P: AsRef<Path>>(path: P, curves: &[Curve]) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &["series", "step", "loss", "grad_norm", "swiglu_amax", "overflows"],
    )?;
    for c in curves {
        for &(step, loss, gnorm, amax, ovf) in &c.rows {
            w.row_mixed(&[
                c.label.clone(),
                step.to_string(),
                loss.to_string(),
                gnorm.to_string(),
                amax.to_string(),
                ovf.to_string(),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Pretty-print a curve summary block (what the bench harness emits so
/// the paper-vs-measured comparison is one screen). The `tail(5)`
/// column uses the saturating [`Curve::tail_loss`], so short curves
/// print their full-curve mean rather than erroring.
pub fn print_summary(title: &str, curves: &[Curve]) {
    println!("\n=== {title} ===");
    println!(
        "{:28} {:>10} {:>10} {:>12} {:>10}",
        "series", "final", "tail(5)", "diverged@", "s/step"
    );
    for c in curves {
        println!(
            "{:28} {:>10.4} {:>10.4} {:>12} {:>10.3}",
            c.label,
            c.final_loss(),
            c.tail_loss(5),
            c.diverged_at.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            c.mean_step_s,
        );
    }
}

/// Env-tunable step budget so `cargo bench` stays tractable:
/// `FP8_BENCH_STEPS` overrides the per-curve default when set to a
/// parseable integer (anything else falls back to `default`).
pub fn bench_steps(default: usize) -> usize {
    std::env::var("FP8_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(losses: &[f32]) -> Curve {
        Curve {
            label: "t".into(),
            rows: losses.iter().enumerate().map(|(i, &l)| (i, l, 1.0, 0.0, 0)).collect(),
            ..Default::default()
        }
    }

    #[test]
    fn tail_loss_saturates_documented() {
        let c = curve(&[4.0, 3.0, 2.0]);
        assert_eq!(c.tail_loss(2), 2.5);
        // k > len: documented saturation at the full curve, no panic
        assert_eq!(c.tail_loss(3), 3.0);
        assert_eq!(c.tail_loss(100), 3.0);
        assert!(c.tail_loss(0).is_nan());
        assert!(curve(&[]).tail_loss(5).is_nan());
    }

    #[test]
    fn tail_loss_strict_errors_on_short_curve() {
        let c = curve(&[4.0, 3.0, 2.0]);
        assert_eq!(c.tail_loss_strict(3).unwrap(), 3.0);
        assert_eq!(c.tail_loss_strict(1).unwrap(), 2.0);
        assert!(c.tail_loss_strict(4).is_err(), "k > len must be an error");
        assert!(c.tail_loss_strict(0).is_err(), "k == 0 must be an error");
        let msg = format!("{:#}", c.tail_loss_strict(4).unwrap_err());
        assert!(msg.contains("only 3"), "error should name the shortfall: {msg}");
    }

    #[test]
    fn final_loss_matches_tail_of_one() {
        let c = curve(&[5.0, 4.5]);
        assert_eq!(c.final_loss(), c.tail_loss(1));
        assert!(curve(&[]).final_loss().is_nan());
    }
}
