//! High-level experiment runner shared by the examples and the bench
//! harness: run a training curve, record the series, dump CSV.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::runtime::Runtime;
use crate::util::csv::CsvWriter;

/// One recorded training curve.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub label: String,
    /// (step, loss, grad_norm, swiglu_amax_max, overflow_events)
    pub rows: Vec<(usize, f32, f32, f32, usize)>,
    pub diverged_at: Option<usize>,
    pub wall_s: f64,
    pub mean_step_s: f64,
}

impl Curve {
    pub fn final_loss(&self) -> f32 {
        self.rows.last().map(|r| r.1).unwrap_or(f32::NAN)
    }

    /// Mean loss over the last k recorded rows (noise-robust).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.rows.len();
        if n == 0 {
            return f32::NAN;
        }
        let take = k.min(n);
        self.rows[n - take..].iter().map(|r| r.1).sum::<f32>() / take as f32
    }
}

/// Run `cfg` to completion (or divergence), sampling every
/// `record_every` steps. `stop_on_divergence` keeps curves comparable
/// while letting the diverging config show its spike first.
pub fn run_curve(
    rt: &Arc<Runtime>,
    cfg: TrainConfig,
    record_every: usize,
    extra_after_divergence: usize,
) -> Result<Curve> {
    let label = format!("{}_{}", cfg.size, cfg.recipe);
    let steps = cfg.steps;
    let mut t = Trainer::new(rt.clone(), cfg)?;
    let mut curve = Curve { label, ..Default::default() };
    let mut after_div = 0usize;
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let o = t.step()?;
        if o.step % record_every == 0 || o.step + 1 == steps {
            let swiglu = o.monitor.iter().map(|m| m[0]).fold(0.0f32, f32::max);
            curve.rows.push((
                o.step,
                o.loss,
                o.grad_norm,
                swiglu,
                t.scale_mgr.overflow_events,
            ));
        }
        if t.detector.has_diverged() {
            curve.diverged_at = curve.diverged_at.or(t.detector.diverged_at);
            after_div += 1;
            if after_div > extra_after_divergence {
                break;
            }
        }
    }
    curve.wall_s = t0.elapsed().as_secs_f64();
    curve.mean_step_s = curve.wall_s / (t.step.max(1) as f64);
    Ok(curve)
}

/// Dump curves side by side (long format) for re-plotting.
pub fn write_curves_csv<P: AsRef<Path>>(path: P, curves: &[Curve]) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &["series", "step", "loss", "grad_norm", "swiglu_amax", "overflows"],
    )?;
    for c in curves {
        for &(step, loss, gnorm, amax, ovf) in &c.rows {
            w.row_mixed(&[
                c.label.clone(),
                step.to_string(),
                loss.to_string(),
                gnorm.to_string(),
                amax.to_string(),
                ovf.to_string(),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Pretty-print a curve summary block (what the bench harness emits so
/// the paper-vs-measured comparison is one screen).
pub fn print_summary(title: &str, curves: &[Curve]) {
    println!("\n=== {title} ===");
    println!(
        "{:28} {:>10} {:>10} {:>12} {:>10}",
        "series", "final", "tail(5)", "diverged@", "s/step"
    );
    for c in curves {
        println!(
            "{:28} {:>10.4} {:>10.4} {:>12} {:>10.3}",
            c.label,
            c.final_loss(),
            c.tail_loss(5),
            c.diverged_at.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            c.mean_step_s,
        );
    }
}

/// Env-tunable step budget so `cargo bench` stays tractable:
/// FP8_BENCH_STEPS overrides the per-curve default.
pub fn bench_steps(default: usize) -> usize {
    std::env::var("FP8_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}
