//! Pod-aware two-level gradient collective — the topology layer over
//! the flat collective in [`allreduce`](super::allreduce).
//!
//! The paper's headline run spans 256 Gaudi2 accelerators arranged in
//! 8-card pods: links *inside* a pod are fat (the cards' scale-up
//! ports, all-to-all), links *between* pods are thin (a few scale-out
//! ports through the switch fabric). A flat W-worker ring treats both
//! the same; the hierarchical schedule every real pod deployment runs
//! is instead
//!
//! 1. **intra-pod reduce-scatter** — each pod combines its members'
//!    gradients over the fat local links;
//! 2. **inter-pod exchange over pod leaders** — one rank per pod
//!    reduce-scatters / all-gathers the pod partial sums across the
//!    thin pipe;
//! 3. **intra-pod all-gather** — leaders fan the global average back
//!    out over the local links.
//!
//! Because the two levels ride different wires, FP8 wire compression
//! is selectable **per level** (`collective_fp8_intra` /
//! `collective_fp8_inter`): FP8-LM-style per-chunk pow2 JIT scaling on
//! whichever legs are compressed, f32 accumulation everywhere. The
//! inter-pod level defaults to FP8 in the config — that is the thin
//! pipe where one byte per element pays for itself (see
//! `perfmodel::interconnect` for the crossover analysis and
//! `docs/OPERATIONS.md` §Topology for the selection rule).
//!
//! Numerics contract (pinned by `rust/tests/collective.rs`):
//!
//! * `pods = 1` **is** the flat collective — the hierarchical entry
//!   point delegates to [`grad_collective_with`], so the single-pod
//!   path is bit-identical to it by construction (and `pods = dp`
//!   degenerates the same way onto the inter level).
//! * With compression off on both levels the two-level schedule is
//!   bit-identical to the flat f32 collective whenever the pod size is
//!   a **power of two** (every realistic pod: the flat binary
//!   reduction tree decomposes exactly into per-pod subtrees followed
//!   by a leader tree when `workers_per_pod = 2^k`). Other pod sizes
//!   are still bit-deterministic — the summation order is fixed by the
//!   topology — but round differently from the flat tree; the snapshot
//!   numerics fingerprint records `pods`, so a resume across any
//!   topology change refuses either way.
//! * Each quantized leg is the same per-chunk pow2 qdq the flat FP8
//!   collective applies (`fp8::bulk` JIT scaling, absolute chunk grid,
//!   NaN-transparent), so every level is deterministic at any thread
//!   count and equal to a scalar serial reference.

use crate::coordinator::allreduce::{
    grad_collective_with, level_legs, qdq_chunks, reduce_mean_into_rank0, tree_reduce_sum,
    tree_reduce_sum_strided, tree_reduce_sum_windows, CollectiveScratch, CollectiveStats,
};
use crate::fp8::Fp8Format;

/// The pod arrangement of the data-parallel pool: `workers` ranks in
/// `pods` equal, contiguous pods (rank `r` lives in pod
/// `r / workers_per_pod`; the pod's first rank is its leader).
///
/// `pods = 1` is the flat topology — the two-level collective
/// delegates to the flat schedule, so existing single-pod configs are
/// bit-identical to the pre-topology code path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PodTopology {
    /// total data-parallel worker count (`dp_workers`)
    pub workers: usize,
    /// number of pods; must divide `workers` evenly
    pub pods: usize,
}

impl PodTopology {
    /// Validated constructor: `workers >= 1`, `pods >= 1`, and `pods`
    /// must divide `workers` evenly (ragged pods would make the leader
    /// set ambiguous and the wire accounting shape-dependent).
    pub fn new(workers: usize, pods: usize) -> Result<Self, String> {
        if workers == 0 {
            return Err("topology needs at least one worker".into());
        }
        if pods == 0 {
            return Err("pods must be >= 1 (1 = flat, no inter-pod level)".into());
        }
        if pods > workers {
            return Err(format!("pods ({pods}) cannot exceed dp_workers ({workers})"));
        }
        if workers % pods != 0 {
            return Err(format!(
                "pods ({pods}) must divide dp_workers ({workers}) evenly \
                 (ragged pods are not supported)"
            ));
        }
        Ok(Self { workers, pods })
    }

    /// The flat (single-pod) topology over `workers` ranks.
    pub fn flat(workers: usize) -> Self {
        Self { workers: workers.max(1), pods: 1 }
    }

    /// Ranks per pod (`workers / pods`; validated to divide evenly).
    pub fn workers_per_pod(&self) -> usize {
        self.workers / self.pods
    }

    /// The pod a worker rank belongs to.
    pub fn pod_of(&self, worker: usize) -> usize {
        worker / self.workers_per_pod()
    }

    /// The leader rank of a pod (its first member).
    pub fn leader_of(&self, pod: usize) -> usize {
        pod * self.workers_per_pod()
    }

    /// Whether a worker rank is its pod's leader.
    pub fn is_leader(&self, worker: usize) -> bool {
        worker % self.workers_per_pod() == 0
    }
}

/// One hierarchical gradient collective with a throwaway scratch — see
/// [`hier_grad_collective_with`] (the step loop uses that variant with
/// the trainer's persistent [`CollectiveScratch`]).
pub fn hier_grad_collective(
    buffers: &mut [Vec<f32>],
    topo: PodTopology,
    fp8_intra: Option<Fp8Format>,
    fp8_inter: Option<Fp8Format>,
    chunk: usize,
) -> CollectiveStats {
    hier_grad_collective_with(
        buffers,
        topo,
        fp8_intra,
        fp8_inter,
        chunk,
        &mut CollectiveScratch::default(),
    )
}

/// Two-level pod-aware gradient collective: deterministic intra-pod
/// reduce-scatter → inter-pod exchange over pod leaders → intra-pod
/// all-gather, with independently selectable FP8 wire compression per
/// level. On return `buffers[0]` holds the gathered global average —
/// the canonical copy the trainer consumes; like the flat collective,
/// the other replicas keep stale partial state (every replica buffer
/// is overwritten at the top of the next step).
///
/// Pipeline (W = `topo.workers`, P = `topo.workers_per_pod()`):
///
/// 1. `fp8_intra`: every member's contribution is per-chunk
///    quantize-dequantized (what the intra reduce-scatter delivers to
///    each chunk's intra-pod owner);
/// 2. each pod tree-sums its members into its leader (f32
///    accumulation, fixed pair order);
/// 3. `fp8_inter`: each leader's pod partial is quantize-dequantized
///    (the inter reduce-scatter leg over the thin pipe);
/// 4. the leader tree sums into rank 0 (f32) and scales by `1/W`;
/// 5. `fp8_inter`: the global average is quantize-dequantized once
///    more (the inter all-gather back to every leader);
/// 6. `fp8_intra`: and once more for the intra all-gather to every
///    pod member — one value is THE gradient everywhere.
///
/// Degenerate shapes take the flat path exactly: `pods = 1` delegates
/// to [`grad_collective_with`] with the **intra** setting (there is no
/// inter level), and `workers_per_pod = 1` delegates with the
/// **inter** setting relabeled onto the inter accounting (every rank
/// is a leader). `W = 1` moves no bytes and skips quantization
/// entirely.
pub fn hier_grad_collective_with(
    buffers: &mut [Vec<f32>],
    topo: PodTopology,
    fp8_intra: Option<Fp8Format>,
    fp8_inter: Option<Fp8Format>,
    chunk: usize,
    scratch: &mut CollectiveScratch,
) -> CollectiveStats {
    let w = buffers.len();
    assert_eq!(w, topo.workers, "buffer count must match the topology");
    // the fields are pub: a hand-built ragged topology (bypassing
    // PodTopology::new) would silently drop trailing ranks from the
    // sum while still scaling by 1/W — refuse loudly instead
    assert!(
        topo.pods >= 1 && topo.pods * (topo.workers / topo.pods) == topo.workers,
        "ragged topology: pods ({}) must divide workers ({}) — use PodTopology::new",
        topo.pods,
        topo.workers
    );
    let n = buffers[0].len();
    if w == 1 {
        reduce_mean_into_rank0(buffers);
        return CollectiveStats { elems: n, ..CollectiveStats::default() };
    }
    if topo.pods == 1 {
        // flat special case: one pod, no inter level — the flat
        // schedule IS the intra level (bit-identity by delegation)
        return grad_collective_with(buffers, fp8_intra, chunk, scratch);
    }
    let p = topo.workers_per_pod();
    if p == 1 {
        // every rank is its own pod leader: the collective is pure
        // inter-pod — run the flat schedule with the inter setting and
        // relabel the wire accounting onto the inter level
        let flat = grad_collective_with(buffers, fp8_inter, chunk, scratch);
        return CollectiveStats {
            elems: flat.elems,
            inter: flat.intra,
            inter_f32: flat.intra_f32,
            ..CollectiveStats::default()
        };
    }
    for b in buffers.iter() {
        assert_eq!(b.len(), n, "replica gradient size mismatch");
    }

    // (1) intra reduce-scatter leg: quantize every member's contribution
    if let Some(fmt) = fp8_intra {
        for buf in buffers.iter_mut() {
            qdq_chunks(fmt, chunk, buf, scratch);
        }
    }
    // (2) per-pod tree sums into each pod leader (f32 accumulation)
    for pod in 0..topo.pods {
        let base = pod * p;
        tree_reduce_sum(&mut buffers[base..base + p]);
    }
    // (3) inter reduce-scatter leg: quantize each leader's pod partial
    if let Some(fmt) = fp8_inter {
        for pod in 0..topo.pods {
            qdq_chunks(fmt, chunk, &mut buffers[topo.leader_of(pod)], scratch);
        }
    }
    // (4) leader tree into rank 0, then the global mean
    tree_reduce_sum_strided(buffers, p);
    let inv = 1.0 / w as f32;
    for x in buffers[0].iter_mut() {
        *x *= inv;
    }
    // (5) inter all-gather leg: the average back out to every leader
    if let Some(fmt) = fp8_inter {
        qdq_chunks(fmt, chunk, &mut buffers[0], scratch);
    }
    // (6) intra all-gather leg: leaders fan out to their pod members
    if let Some(fmt) = fp8_intra {
        qdq_chunks(fmt, chunk, &mut buffers[0], scratch);
    }

    CollectiveStats {
        elems: n,
        intra: level_legs(n, p, topo.pods, fp8_intra, chunk),
        inter: level_legs(n, topo.pods, 1, fp8_inter, chunk),
        intra_f32: level_legs(n, p, topo.pods, None, chunk),
        inter_f32: level_legs(n, topo.pods, 1, None, chunk),
    }
}

/// [`hier_grad_collective_with`] over one gradient **bucket**: one
/// mutable window per worker (all the same length), reduced in place
/// so `windows[0]` ends up holding that bucket's gathered global
/// average. The overlapped step pipeline runs this per bucket on a
/// dedicated comms thread while later buckets are still being
/// computed.
///
/// Bit-identity with the whole-buffer collective (pinned by the tests
/// below): every stage is elementwise over a fixed schedule, so
/// restricting it to a window changes nothing **provided the window
/// starts on an absolute multiple of `chunk`** — then the per-window
/// `qdq_chunks` grid (chunks are relative to the slice start) is the
/// same spans the whole-buffer grid carves, with the same per-chunk
/// scales. `pipeline::BucketSchedule` guarantees exactly that
/// alignment; the assert refuses anything else rather than silently
/// re-gridding the FP8 scales.
///
/// The returned stats are this bucket's share of the wire accounting;
/// summing them over a `BucketSchedule` reproduces the whole-buffer
/// closed forms (non-final buckets are whole-chunk multiples, so the
/// per-chunk scale words sum exactly — see `CollectiveStats::absorb`).
pub fn hier_bucket_collective(
    windows: &mut [&mut [f32]],
    bucket_off: usize,
    topo: PodTopology,
    fp8_intra: Option<Fp8Format>,
    fp8_inter: Option<Fp8Format>,
    chunk: usize,
    scratch: &mut CollectiveScratch,
) -> CollectiveStats {
    let w = windows.len();
    assert_eq!(w, topo.workers, "window count must match the topology");
    assert!(
        topo.pods >= 1 && topo.pods * (topo.workers / topo.pods) == topo.workers,
        "ragged topology: pods ({}) must divide workers ({}) — use PodTopology::new",
        topo.pods,
        topo.workers
    );
    assert!(chunk >= 1, "collective chunk size must be >= 1");
    assert_eq!(
        bucket_off % chunk,
        0,
        "bucket offset {bucket_off} must sit on the absolute {chunk}-chunk grid \
         (use pipeline::BucketSchedule) or per-bucket FP8 scales diverge from \
         the whole-buffer grid"
    );
    let n = windows[0].len();
    for win in windows.iter() {
        assert_eq!(win.len(), n, "bucket window size mismatch");
    }
    if w == 1 {
        // mirror reduce_mean_into_rank0's degenerate schedule (tree
        // no-op + scale by 1/1) so the bucketed path stays
        // bit-identical to the flat W = 1 collective
        for x in windows[0].iter_mut() {
            *x *= 1.0;
        }
        return CollectiveStats { elems: n, ..CollectiveStats::default() };
    }
    let p = topo.workers_per_pod();
    if topo.pods == 1 {
        // flat special case on windows: same stages as
        // grad_collective_with, intra accounting
        if let Some(fmt) = fp8_intra {
            for win in windows.iter_mut() {
                qdq_chunks(fmt, chunk, win, scratch);
            }
        }
        tree_reduce_sum_windows(windows, 1);
        let inv = 1.0 / w as f32;
        for x in windows[0].iter_mut() {
            *x *= inv;
        }
        if let Some(fmt) = fp8_intra {
            qdq_chunks(fmt, chunk, &mut *windows[0], scratch);
        }
        return CollectiveStats {
            elems: n,
            intra: level_legs(n, w, 1, fp8_intra, chunk),
            intra_f32: level_legs(n, w, 1, None, chunk),
            ..CollectiveStats::default()
        };
    }
    if p == 1 {
        // every rank is a pod leader: pure inter level on windows
        if let Some(fmt) = fp8_inter {
            for win in windows.iter_mut() {
                qdq_chunks(fmt, chunk, win, scratch);
            }
        }
        tree_reduce_sum_windows(windows, 1);
        let inv = 1.0 / w as f32;
        for x in windows[0].iter_mut() {
            *x *= inv;
        }
        if let Some(fmt) = fp8_inter {
            qdq_chunks(fmt, chunk, &mut *windows[0], scratch);
        }
        return CollectiveStats {
            elems: n,
            inter: level_legs(n, w, 1, fp8_inter, chunk),
            inter_f32: level_legs(n, w, 1, None, chunk),
            ..CollectiveStats::default()
        };
    }

    // full two-level schedule, stage for stage the whole-buffer path
    if let Some(fmt) = fp8_intra {
        for win in windows.iter_mut() {
            qdq_chunks(fmt, chunk, win, scratch);
        }
    }
    for pod in 0..topo.pods {
        let base = pod * p;
        tree_reduce_sum_windows(&mut windows[base..base + p], 1);
    }
    if let Some(fmt) = fp8_inter {
        for pod in 0..topo.pods {
            qdq_chunks(fmt, chunk, &mut *windows[topo.leader_of(pod)], scratch);
        }
    }
    tree_reduce_sum_windows(windows, p);
    let inv = 1.0 / w as f32;
    for x in windows[0].iter_mut() {
        *x *= inv;
    }
    if let Some(fmt) = fp8_inter {
        qdq_chunks(fmt, chunk, &mut *windows[0], scratch);
    }
    if let Some(fmt) = fp8_intra {
        qdq_chunks(fmt, chunk, &mut *windows[0], scratch);
    }

    CollectiveStats {
        elems: n,
        intra: level_legs(n, p, topo.pods, fp8_intra, chunk),
        inter: level_legs(n, topo.pods, 1, fp8_inter, chunk),
        intra_f32: level_legs(n, p, topo.pods, None, chunk),
        inter_f32: level_legs(n, topo.pods, 1, None, chunk),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{E4M3, E5M2};

    #[test]
    fn topology_validation() {
        assert!(PodTopology::new(8, 2).is_ok());
        assert!(PodTopology::new(8, 8).is_ok());
        assert!(PodTopology::new(8, 1).is_ok());
        assert!(PodTopology::new(0, 1).is_err(), "zero workers");
        assert!(PodTopology::new(8, 0).is_err(), "zero pods");
        assert!(PodTopology::new(8, 3).is_err(), "ragged pods");
        assert!(PodTopology::new(2, 4).is_err(), "more pods than workers");
    }

    #[test]
    fn pod_math() {
        let t = PodTopology::new(8, 2).unwrap();
        assert_eq!(t.workers_per_pod(), 4);
        assert_eq!(t.pod_of(0), 0);
        assert_eq!(t.pod_of(3), 0);
        assert_eq!(t.pod_of(4), 1);
        assert_eq!(t.leader_of(1), 4);
        assert!(t.is_leader(0) && t.is_leader(4));
        assert!(!t.is_leader(5));
    }

    #[test]
    #[should_panic(expected = "ragged topology")]
    fn hand_built_ragged_topology_is_refused() {
        // the struct fields are pub; bypassing PodTopology::new with a
        // non-dividing pods count must panic, not silently drop ranks
        let mut bufs: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0f32; 16]).collect();
        let ragged = PodTopology { workers: 8, pods: 3 };
        hier_grad_collective(&mut bufs, ragged, None, None, 16);
    }

    #[test]
    fn single_worker_moves_no_bytes() {
        let mut bufs = vec![vec![2.0f32, 6.0]];
        let s = hier_grad_collective(&mut bufs, PodTopology::flat(1), Some(E4M3), Some(E5M2), 64);
        assert_eq!(bufs[0], vec![2.0, 6.0]);
        assert_eq!(s.wire_bytes(), 0);
        assert_eq!(s.wire_bytes_f32(), 0);
    }

    #[test]
    fn two_level_mean_is_exact_on_exact_values() {
        // values with exact f32 sums: any summation order gives the
        // same bits, so this checks plumbing (who is summed where)
        let w = 8usize;
        let n = 33usize;
        let mut bufs: Vec<Vec<f32>> =
            (0..w).map(|r| (0..n).map(|i| (r * n + i) as f32).collect()).collect();
        let topo = PodTopology::new(w, 4).unwrap();
        let s = hier_grad_collective(&mut bufs, topo, None, None, 16);
        for (i, &x) in bufs[0].iter().enumerate() {
            let expect: f32 = (0..w).map(|r| (r * n + i) as f32).sum::<f32>() / w as f32;
            assert_eq!(x, expect, "elem {i}");
        }
        assert_eq!(s.elems, n);
    }

    #[test]
    fn bucketed_collective_bit_matches_whole_buffer() {
        use crate::coordinator::pipeline::BucketSchedule;
        // every topology shape x fp8 mix: running the collective per
        // BucketSchedule window must leave rank 0 bit-identical to the
        // monolithic collective, and the per-bucket stats must sum to
        // the whole-buffer accounting exactly
        let chunk = 64usize;
        let n = chunk * 7 + 17; // ragged tail chunk
        let shapes = [(1usize, 1usize), (2, 1), (4, 1), (4, 2), (4, 4), (8, 2)];
        let mixes = [(None, None), (Some(E4M3), None), (None, Some(E5M2)), (Some(E4M3), Some(E5M2))];
        for &(w, pods) in &shapes {
            for &(fi, fx) in &mixes {
                let topo = PodTopology::new(w, pods).unwrap();
                let mk = || -> Vec<Vec<f32>> {
                    (0..w)
                        .map(|r| (0..n).map(|i| ((r * 31 + i) as f32).sin() * 0.01).collect())
                        .collect()
                };
                let mut whole = mk();
                let want = hier_grad_collective(&mut whole, topo, fi, fx, chunk);

                let mut bufs = mk();
                let sched = BucketSchedule::new(n, chunk * 2 * 4, chunk);
                assert!(sched.len() > 1, "test wants several buckets");
                let mut scratch = CollectiveScratch::default();
                let mut got = CollectiveStats::default();
                // carve each worker buffer into the schedule's windows
                let mut rests: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                for &(off, len) in &sched.buckets {
                    let mut wins: Vec<&mut [f32]> = Vec::with_capacity(w);
                    for rest in rests.iter_mut() {
                        let (win, tail) = std::mem::take(rest).split_at_mut(len);
                        *rest = tail;
                        wins.push(win);
                    }
                    got.absorb(&hier_bucket_collective(
                        &mut wins, off, topo, fi, fx, chunk, &mut scratch,
                    ));
                }
                for (i, (x, y)) in whole[0].iter().zip(&bufs[0]).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "w={w} pods={pods} fp8=({},{}) elem {i}",
                        fi.is_some(),
                        fx.is_some()
                    );
                }
                assert_eq!(got, want, "stats must sum to the whole-buffer accounting");
            }
        }
    }

    #[test]
    #[should_panic(expected = "chunk grid")]
    fn bucket_collective_refuses_unaligned_offsets() {
        let mut bufs: Vec<Vec<f32>> = (0..2).map(|_| vec![1.0f32; 32]).collect();
        let mut wins: Vec<&mut [f32]> = bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        hier_bucket_collective(
            &mut wins,
            33, // not a multiple of 64
            PodTopology::flat(2),
            None,
            None,
            64,
            &mut CollectiveScratch::default(),
        );
    }

    #[test]
    fn wire_accounting_per_level_closed_form() {
        let n = 1000usize;
        let chunk = 64usize;
        let n_chunks = n.div_ceil(chunk) as u64; // 16
        let w = 8usize;
        let topo = PodTopology::new(w, 2).unwrap();
        let p = topo.workers_per_pod() as u64; // 4

        // intra f32 / inter fp8 (the default for pods > 1)
        let mut bufs: Vec<Vec<f32>> = (0..w).map(|_| vec![1e-3f32; n]).collect();
        let s = hier_grad_collective(&mut bufs, topo, None, Some(E5M2), chunk);
        let intra_leg = 2 * (p - 1) * n as u64 * 4; // pods·(P-1)·4n per leg
        assert_eq!(s.intra.reduce_scatter, intra_leg);
        assert_eq!(s.intra.all_gather, intra_leg);
        let inter_leg_fp8 = (2 - 1) * (n as u64 + 4 * n_chunks);
        assert_eq!(s.inter.reduce_scatter, inter_leg_fp8);
        assert_eq!(s.inter.all_gather, inter_leg_fp8);
        assert_eq!(s.inter_f32.reduce_scatter, (2 - 1) * n as u64 * 4);
        assert_eq!(s.wire_bytes(), 2 * intra_leg + 2 * inter_leg_fp8);
        // the executed config moves fewer bytes than all-f32 would
        assert!(s.wire_bytes() < s.wire_bytes_f32());

        // pods = workers: pure inter level
        let topo_pw = PodTopology::new(w, w).unwrap();
        let mut bufs: Vec<Vec<f32>> = (0..w).map(|_| vec![1e-3f32; n]).collect();
        let s = hier_grad_collective(&mut bufs, topo_pw, Some(E4M3), Some(E5M2), chunk);
        assert_eq!(s.intra, Default::default(), "no intra wire at pod size 1");
        assert_eq!(s.inter.reduce_scatter, (w as u64 - 1) * (n as u64 + 4 * n_chunks));
    }
}
