//! Smooth-SwiGLU inference folding (paper §4.4, Fig. 4): absorb the
//! per-channel training scales into the stored weights so inference
//! runs the *plain* SwiGLU graph at zero extra cost:
//!
//!   w̃1[:, i] = s_i · w1[:, i]      (linear branch pre-scaled)
//!   w̃3[i, :] = s_i⁻¹ · w3[i, :]    (undone after the product)
//!
//! The paper derives this for the quantized weights; here it is applied
//! to a checkpoint's master weights, with pow2 scales so the fold is
//! bit-exact in f32 (each element's mantissa is untouched).

use anyhow::{anyhow, Result};

/// Fold per-channel scales into stacked `[L, d, f]` w1 and `[L, f, d]`
/// w3 buffers in place. `scales[l][i]` is channel i's scale in layer l.
pub fn fold_scales(
    w1: &mut [f32],
    w3: &mut [f32],
    scales: &[Vec<f32>],
    d: usize,
    f: usize,
) -> Result<()> {
    let l = scales.len();
    if w1.len() != l * d * f || w3.len() != l * f * d {
        return Err(anyhow!(
            "shape mismatch: w1 {} vs {}, w3 {} vs {}",
            w1.len(),
            l * d * f,
            w3.len(),
            l * f * d
        ));
    }
    for (layer, s) in scales.iter().enumerate() {
        if s.len() != f {
            return Err(anyhow!("layer {layer}: {} scales for {f} channels", s.len()));
        }
        if s.iter().any(|&x| !(x > 0.0) || !x.is_finite()) {
            return Err(anyhow!("layer {layer}: non-positive/non-finite scale"));
        }
        let w1l = &mut w1[layer * d * f..(layer + 1) * d * f];
        for row in 0..d {
            for (i, &si) in s.iter().enumerate() {
                w1l[row * f + i] *= si;
            }
        }
        let w3l = &mut w3[layer * f * d..(layer + 1) * f * d];
        for (i, &si) in s.iter().enumerate() {
            let inv = 1.0 / si;
            for col in 0..d {
                w3l[i * d + col] *= inv;
            }
        }
    }
    Ok(())
}

/// Verify the fold is function-preserving: for token activations `x`
/// (shape `[t, d]`, one layer), SwiGLU(x; w̃1, w2) @ w̃3 must equal
/// SwiGLU(x; w1, w2) @ w3 — exactly for pow2 scales at f32.
pub fn fold_residual(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    w3: &[f32],
    w1f: &[f32],
    w3f: &[f32],
    t: usize,
    d: usize,
    f: usize,
    n_out: usize,
) -> f32 {
    let y0 = swiglu_mlp(x, w1, w2, w3, t, d, f, n_out);
    let y1 = swiglu_mlp(x, w1f, w2, w3f, t, d, f, n_out);
    y0.iter()
        .zip(&y1)
        .map(|(a, b)| (a - b).abs() / (a.abs() + 1e-6))
        .fold(0.0f32, f32::max)
}

fn swiglu_mlp(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    w3: &[f32],
    t: usize,
    d: usize,
    f: usize,
    n_out: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * n_out];
    for ti in 0..t {
        for j in 0..f {
            let (mut a1, mut a2) = (0.0f32, 0.0f32);
            for i in 0..d {
                a1 += x[ti * d + i] * w1[i * f + j];
                a2 += x[ti * d + i] * w2[i * f + j];
            }
            let h = a1 * a2 / (1.0 + (-a2).exp());
            for k in 0..n_out {
                out[ti * n_out + k] += h * w3[j * d + k];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn pow2_fold_is_function_preserving() {
        let (d, f, t) = (16, 8, 12);
        let mut rng = Rng::new(11);
        let mut w1 = vec![0.0f32; d * f];
        let mut w2 = vec![0.0f32; d * f];
        let mut w3 = vec![0.0f32; f * d];
        let mut x = vec![0.0f32; t * d];
        rng.fill_normal(&mut w1, 0.5);
        rng.fill_normal(&mut w2, 0.5);
        rng.fill_normal(&mut w3, 0.5);
        rng.fill_normal(&mut x, 1.0);
        let scales: Vec<f32> = (0..f).map(|i| 2f32.powi((i as i32 % 9) - 4)).collect();

        let mut w1f = w1.clone();
        let mut w3f = w3.clone();
        fold_scales(&mut w1f, &mut w3f, &[scales], d, f).unwrap();
        let res = fold_residual(&x, &w1, &w2, &w3, &w1f, &w3f, t, d, f, d);
        // pow2 scaling is exact in f32 except where swish's exp path
        // re-associates — bound tightly
        assert!(res < 1e-4, "fold residual {res}");
    }

    #[test]
    fn fold_changes_w1_w3_reciprocally() {
        let (d, f) = (4, 2);
        let mut w1 = vec![1.0f32; d * f];
        let mut w3 = vec![1.0f32; f * d];
        fold_scales(&mut w1, &mut w3, &[vec![2.0, 8.0]], d, f).unwrap();
        assert_eq!(w1[0], 2.0);
        assert_eq!(w1[1], 8.0);
        assert_eq!(w3[0], 0.5);
        assert_eq!(w3[d], 0.125);
    }

    #[test]
    fn rejects_bad_scales() {
        let (d, f) = (2, 2);
        let mut w1 = vec![1.0f32; d * f];
        let mut w3 = vec![1.0f32; f * d];
        assert!(fold_scales(&mut w1, &mut w3, &[vec![1.0, 0.0]], d, f).is_err());
        assert!(fold_scales(&mut w1, &mut w3, &[vec![1.0]], d, f).is_err());
    }
}
