//! Pure substrate of the bucketed, overlapped step pipeline: the
//! bucket partition, the streamed global-norm fold, the per-phase
//! timers, and worker-panic containment. Everything here is plain
//! data + arithmetic — the threads live in `trainer::step_overlapped`
//! — so the bit-identity arguments are testable without a runtime.
//!
//! Identity contracts (pinned by the unit tests below and by
//! `tests/integration.rs`):
//!
//! * [`BucketSchedule`] partitions the flat gradient into contiguous
//!   buckets whose starts are **absolute multiples of the Adam
//!   artifact chunk** (the same alignment rule
//!   `ShardLayout::chunk_aligned` uses). Because the per-chunk FP8
//!   grids of the collective (`allreduce::qdq_chunks`) and of the
//!   moment packing are keyed to that absolute grid, running any
//!   stage per bucket produces exactly the bits the whole-buffer
//!   stage produces — bucketing is designed to be invisible to the
//!   numbers.
//! * [`NormStream`] folds per-bucket gradient windows into the global
//!   L2 norm using **the same f64 addition sequence** as
//!   `allreduce::global_norm`: per-`NORM_CHUNK` partials, each
//!   accumulated element-first from 0.0, folded in chunk index order.
//!   A `NORM_CHUNK` span that straddles a bucket boundary carries its
//!   running partial across the boundary, so the final bits match the
//!   standalone whole-buffer norm exactly.

use crate::coordinator::allreduce::{norm_sq, NORM_CHUNK};
use crate::util::par::par_partials;

/// The bucket partition of a flat gradient: contiguous `(offset, len)`
/// windows covering `[0, total)`, every offset an absolute multiple of
/// the Adam chunk, every non-final length a chunk multiple (the last
/// bucket truncates to `total`). The partition is a pure function of
/// `(total, bucket_bytes, chunk)` — no runtime state — which is what
/// lets the snapshot fingerprint pin it with a single key.
#[derive(Clone, Debug)]
pub struct BucketSchedule {
    /// `(offset, len)` per bucket, ascending and contiguous
    pub buckets: Vec<(usize, usize)>,
    /// elements per full bucket — a chunk multiple, `>= chunk`
    pub elems_per_bucket: usize,
    /// the Adam artifact chunk the partition is aligned to
    pub chunk: usize,
}

impl BucketSchedule {
    /// Partition `total` elements into buckets of `bucket_bytes` f32
    /// bytes, rounded **up** to whole Adam chunks. Adversarial sizes
    /// degrade safely: anything smaller than one chunk becomes
    /// one-chunk buckets; anything larger than the model becomes a
    /// single bucket (the phased schedule in bucket clothing).
    pub fn new(total: usize, bucket_bytes: usize, chunk: usize) -> Self {
        assert!(chunk >= 1, "adam chunk must be >= 1");
        let raw_elems = (bucket_bytes / 4).max(1);
        let per = raw_elems.div_ceil(chunk) * chunk;
        let mut buckets = Vec::with_capacity(total.div_ceil(per));
        let mut off = 0usize;
        while off < total {
            let len = per.min(total - off);
            buckets.push((off, len));
            off += len;
        }
        Self { buckets, elems_per_bucket: per, chunk }
    }

    /// Number of buckets in the partition.
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    /// Whether the partition is empty (only for a zero-element model).
    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// The bucket a flat element offset belongs to.
    pub fn bucket_of(&self, off: usize) -> usize {
        off / self.elems_per_bucket
    }
}

/// Streaming twin of `allreduce::global_norm`: feed it the landed
/// bucket windows **in ascending bucket order** and it reproduces the
/// standalone norm's f64 summation bit for bit (see the module docs
/// for the order argument). `finish()` returns the L2 norm.
pub struct NormStream {
    /// completed `NORM_CHUNK`-span partials folded in span order
    sum: f64,
    /// running partial of the span the stream is currently inside
    span: f64,
    /// elements consumed so far
    pos: usize,
}

impl NormStream {
    /// An empty stream positioned at flat offset 0.
    pub fn new() -> Self {
        Self { sum: 0.0, span: 0.0, pos: 0 }
    }

    /// Fold the next contiguous gradient window into the norm. Windows
    /// must arrive in flat offset order with no gaps — exactly how the
    /// pipeline lands buckets.
    pub fn push(&mut self, mut win: &[f32]) {
        // finish the span a previous window left straddling: the
        // element-order fold continues from the carried partial, which
        // is the exact addition sequence the whole-buffer norm uses
        let into = self.pos % NORM_CHUNK;
        if into != 0 {
            let take = (NORM_CHUNK - into).min(win.len());
            for &x in &win[..take] {
                self.span += (x as f64) * (x as f64);
            }
            self.pos += take;
            if self.pos % NORM_CHUNK == 0 {
                self.sum += self.span;
                self.span = 0.0;
            }
            win = &win[take..];
        }
        // aligned interior: whole spans, parallel partials folded in
        // span order (par_partials pins partial i == f(span i))
        let whole = (win.len() / NORM_CHUNK) * NORM_CHUNK;
        if whole > 0 {
            for p in par_partials(&win[..whole], NORM_CHUNK, norm_sq) {
                self.sum += p;
            }
            self.pos += whole;
            win = &win[whole..];
        }
        // ragged tail: start the next straddling span
        for &x in win {
            self.span += (x as f64) * (x as f64);
        }
        self.pos += win.len();
    }

    /// Elements folded so far.
    pub fn elems(&self) -> usize {
        self.pos
    }

    /// The L2 norm of everything pushed. Bit-identical to
    /// `global_norm` over the concatenation of the pushed windows.
    pub fn finish(self) -> f32 {
        // a ragged final span is the whole-buffer norm's last partial;
        // an aligned end already folded everything into `sum`
        let total = if self.pos % NORM_CHUNK == 0 { self.sum } else { self.sum + self.span };
        total.sqrt() as f32
    }
}

impl Default for NormStream {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-phase wall-clock of one step, exposed on `StepOutcome` and in
/// `BENCH_hotpath.json`. For the phased schedule the phases are
/// sequential and `comm_exposed_s == collective_s` (nothing hides);
/// for the overlapped schedule `comm_exposed_s` counts only the spans
/// the main thread actually stalled on an in-flight collective.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimers {
    /// slowest worker's gradient pass (the compute the comms hide behind)
    pub grad_s: f64,
    /// total seconds the collective was executing (all buckets)
    pub collective_s: f64,
    /// norm fold seconds (streamed per bucket when overlapped)
    pub norm_s: f64,
    /// optimizer seconds (per-bucket dispatch when overlapped)
    pub adam_s: f64,
    /// collective seconds NOT hidden behind compute
    pub comm_exposed_s: f64,
    /// buckets the schedule ran (1 = monolithic/phased)
    pub buckets: usize,
    /// whether the overlapped schedule produced these timers
    pub overlapped: bool,
}

impl PhaseTimers {
    /// Fraction of collective time hidden behind compute, in [0, 1]
    /// (0 when the collective ran fully exposed or not at all).
    pub fn hidden_comm_fraction(&self) -> f64 {
        if self.collective_s <= 0.0 {
            return 0.0;
        }
        (1.0 - self.comm_exposed_s / self.collective_s).clamp(0.0, 1.0)
    }
}

/// Turn a `JoinHandle::join` result into an `Err` instead of
/// propagating the panic: the step pipeline must never abort the
/// process on a worker panic — it poisons the trainer and reports, so
/// the operator can resume from a snapshot (see `Trainer::step`).
pub(crate) fn contain_panic<T>(
    res: std::thread::Result<T>,
    what: &str,
) -> anyhow::Result<T> {
    res.map_err(|payload| {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "opaque panic payload".to_string());
        anyhow::anyhow!("{what} panicked: {msg}")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::allreduce::global_norm;
    use crate::util::prng::Rng;

    #[test]
    fn schedule_covers_contiguously_and_aligns() {
        let chunk = 64usize;
        for total in [1usize, 63, 64, 65, 1000, 64 * 7, 64 * 7 + 1] {
            for bytes in [0usize, 1, 3, 4, 255, 256, 4096, usize::MAX / 2] {
                let s = BucketSchedule::new(total, bytes, chunk);
                assert!(!s.is_empty(), "total={total} bytes={bytes}");
                let mut expect_off = 0usize;
                for (i, &(off, len)) in s.buckets.iter().enumerate() {
                    assert_eq!(off, expect_off, "gap at bucket {i}");
                    assert_eq!(off % chunk, 0, "unaligned start at bucket {i}");
                    assert!(len >= 1);
                    if i + 1 < s.buckets.len() {
                        assert_eq!(len % chunk, 0, "unaligned interior len");
                        assert_eq!(len, s.elems_per_bucket);
                    }
                    assert_eq!(s.bucket_of(off), i);
                    assert_eq!(s.bucket_of(off + len - 1), i);
                    expect_off = off + len;
                }
                assert_eq!(expect_off, total, "partition must cover the model");
            }
        }
    }

    #[test]
    fn schedule_adversarial_extremes() {
        // smaller than one chunk -> one-chunk buckets
        let s = BucketSchedule::new(1000, 1, 64);
        assert_eq!(s.elems_per_bucket, 64);
        assert_eq!(s.len(), 1000usize.div_ceil(64));
        // larger than the model -> a single bucket
        let s = BucketSchedule::new(1000, 1 << 30, 64);
        assert_eq!(s.len(), 1);
        assert_eq!(s.buckets[0], (0, 1000));
        // zero-element model -> empty partition, no bucket
        assert!(BucketSchedule::new(0, 4096, 64).is_empty());
    }

    #[test]
    fn norm_stream_matches_global_norm_bitwise() {
        // sizes around the NORM_CHUNK boundary x split patterns that
        // straddle it: the streamed fold must be bit-identical to the
        // standalone norm (same f64 addition sequence)
        let mut rng = Rng::new(0x6e6f726d);
        for &n in &[0usize, 1, 100, NORM_CHUNK - 1, NORM_CHUNK, NORM_CHUNK + 1, NORM_CHUNK * 3 + 777] {
            let mut flat = vec![0.0f32; n];
            rng.fill_normal(&mut flat, 0.02);
            let want = global_norm(&flat);
            for &split in &[1usize, 7, 100, NORM_CHUNK / 2 + 3, NORM_CHUNK, NORM_CHUNK + 5, n.max(1)] {
                let mut s = NormStream::new();
                for w in flat.chunks(split) {
                    s.push(w);
                }
                assert_eq!(s.elems(), n);
                let got = s.finish();
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "n={n} split={split}: streamed norm must be bit-identical"
                );
            }
        }
    }

    #[test]
    fn norm_stream_matches_on_bucket_schedule_windows() {
        // end-to-end shape: the exact windows a BucketSchedule carves
        // (chunk not a NORM_CHUNK divisor, so spans straddle buckets)
        let chunk = 24_000usize;
        let total = chunk * 11 + 13_000;
        let mut flat = vec![0.0f32; total];
        Rng::new(7).fill_normal(&mut flat, 0.01);
        let sched = BucketSchedule::new(total, chunk * 3 * 4, chunk);
        assert!(sched.len() > 2, "test wants a multi-bucket partition");
        let mut s = NormStream::new();
        for &(off, len) in &sched.buckets {
            s.push(&flat[off..off + len]);
        }
        assert_eq!(s.finish().to_bits(), global_norm(&flat).to_bits());
    }

    #[test]
    fn norm_stream_propagates_nonfinite() {
        let mut s = NormStream::new();
        s.push(&[1.0, f32::NAN, 2.0]);
        assert!(s.finish().is_nan());
        let mut s = NormStream::new();
        s.push(&[f32::MAX, f32::MAX]);
        s.push(&[f32::MAX; 7]);
        assert_eq!(s.finish().to_bits(), global_norm(&[f32::MAX; 9]).to_bits());
    }

    #[test]
    fn hidden_fraction_semantics() {
        let t = PhaseTimers {
            collective_s: 2.0,
            comm_exposed_s: 0.5,
            ..Default::default()
        };
        assert!((t.hidden_comm_fraction() - 0.75).abs() < 1e-12);
        // phased: fully exposed
        let t = PhaseTimers { collective_s: 2.0, comm_exposed_s: 2.0, ..Default::default() };
        assert_eq!(t.hidden_comm_fraction(), 0.0);
        // no collective at all (W = 1)
        assert_eq!(PhaseTimers::default().hidden_comm_fraction(), 0.0);
        // timer jitter must clamp, not escape [0, 1]
        let t = PhaseTimers { collective_s: 1.0, comm_exposed_s: 1.5, ..Default::default() };
        assert_eq!(t.hidden_comm_fraction(), 0.0);
    }

    #[test]
    fn contain_panic_reports_payloads() {
        let h = std::thread::spawn(|| panic!("boom {}", 42));
        let err = contain_panic(h.join(), "drill worker").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("drill worker panicked"), "{msg}");
        assert!(msg.contains("boom 42"), "{msg}");
        let ok: std::thread::Result<u32> = Ok(7);
        assert_eq!(contain_panic(ok, "x").unwrap(), 7);
    }
}
