//! Gradient all-reduce over the simulated data-parallel pool.
//!
//! Workers produce per-replica gradient buffers; the collective is a
//! binary-tree reduction (⌈log2 W⌉ rounds, matching how a real pod's
//! ring/tree collective combines partial sums deterministically) then
//! an average. Reduction order is *fixed* regardless of thread timing,
//! so runs are bit-reproducible at any worker count: the tree shape
//! decides which additions happen, threads only decide *where* the
//! per-element additions run.
//!
//! Three variants:
//! * [`allreduce_mean`] — sum, scale, broadcast into every replica.
//!   This mirrors collective semantics (every rank holds the result)
//!   and is what probe/analysis code should use when it reads a
//!   non-zero replica afterwards.
//! * [`reduce_mean_into_rank0`] — sum + scale only. `Trainer::step`
//!   consumes only the canonical rank-0 copy and overwrites every
//!   replica at the top of the next step, so the broadcast was W-1
//!   dead memcpys of the full gradient per step.
//! * [`grad_collective`] — the flat (single-pod) collective: a
//!   deterministic reduce-scatter → mean → all-gather that optionally
//!   compresses both wire legs to FP8 with per-chunk pow2 auto-scales
//!   (FP8-LM-style), falling back bit-exactly to the rank-0 reduce
//!   when compression is off. Returns [`CollectiveStats`] — the
//!   per-level, per-leg bytes-on-the-wire accounting the perf bench
//!   records. The step loop enters through the pod-aware two-level
//!   wrapper in [`topology`](super::topology), for which this flat
//!   path is the `pods = 1` special case.

use crate::fp8::{bulk, Fp8Format};
use crate::util::par::{max_threads, par_partials, par_zip, PAR_THRESHOLD};

/// Fixed accumulation chunk for [`global_norm`]. This is not a tuning
/// knob: it *defines* the f64 summation order (per-chunk partials,
/// folded in chunk index order), so the parallel and serial paths —
/// and therefore the clip factor — are bit-identical. Changing it
/// changes rounding in the last ulp of the norm.
pub const NORM_CHUNK: usize = 1 << 16;

/// Elementwise `dst += src`, fanned out across scoped threads above
/// the shared `util::par` threshold. Bit-deterministic: per-element
/// ops, disjoint spans.
fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "replica gradient size mismatch");
    par_zip(src, dst, |s_span, d_span| {
        for (d, x) in d_span.iter_mut().zip(s_span) {
            *d += *x;
        }
    });
}

/// Tree-reduce in place: buffers[0] ends up holding the elementwise sum.
pub fn tree_reduce_sum(buffers: &mut [Vec<f32>]) {
    assert!(!buffers.is_empty());
    let n = buffers[0].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), n, "replica gradient size mismatch");
    }
    tree_reduce_sum_strided(buffers, 1);
}

/// Tree-reduce over the subsequence of `buffers` at indices
/// `0, step, 2·step, …` — `buffers[0]` ends up holding that
/// subsequence's elementwise sum; the skipped buffers are untouched.
/// `step = 1` is exactly [`tree_reduce_sum`]'s pair order. The pair
/// schedule is the same binary tree over participant *positions*, so
/// the two-level collective's leader exchange (participants at pod
/// bases, `step = workers_per_pod`) reuses the pinned summation shape:
/// for power-of-two pod sizes, per-pod subtrees + this leader tree
/// compose into exactly the flat tree (see `coordinator::topology`).
pub(crate) fn tree_reduce_sum_strided(buffers: &mut [Vec<f32>], step: usize) {
    assert!(step >= 1);
    let k = buffers.len().div_ceil(step); // participant count
    let mut stride = 1;
    while stride < k {
        let mut i = 0;
        while i + stride < k {
            // combine participant pair (i, i+stride) — fixed order
            let (left, right) = buffers.split_at_mut((i + stride) * step);
            add_assign(&mut left[i * step], &right[0]);
            i += stride * 2;
        }
        stride *= 2;
    }
}

/// [`tree_reduce_sum_strided`] over borrowed windows instead of owned
/// buffers — the bucketed pipeline reduces per-bucket slices of the
/// workers' gradient buffers in place. Same pair schedule over
/// participant positions, same `add_assign` per pair, so reducing each
/// bucket window is elementwise identical to reducing whole buffers:
/// bucketing never changes which additions happen at an element.
pub(crate) fn tree_reduce_sum_windows(windows: &mut [&mut [f32]], step: usize) {
    assert!(step >= 1);
    let k = windows.len().div_ceil(step);
    let mut stride = 1;
    while stride < k {
        let mut i = 0;
        while i + stride < k {
            let (left, right) = windows.split_at_mut((i + stride) * step);
            let dst: &mut [f32] = &mut *left[i * step];
            add_assign(dst, &*right[0]);
            i += stride * 2;
        }
        stride *= 2;
    }
}

/// Reduce-mean without the broadcast: buffers[0] holds the average,
/// the other replicas keep their (now stale) partial-sum state. Use
/// when only the canonical copy is read before the next overwrite —
/// the training loop's case. Callers that need collective semantics
/// (every replica identical) want [`allreduce_mean`].
pub fn reduce_mean_into_rank0(buffers: &mut [Vec<f32>]) {
    let w = buffers.len() as f32;
    tree_reduce_sum(buffers);
    let inv = 1.0 / w;
    for x in buffers[0].iter_mut() {
        *x *= inv;
    }
}

/// All-reduce average: tree-sum then scale by 1/W, broadcast into all
/// replicas (the coordinator keeps one canonical copy; this mirrors
/// the collective's output being identical on every rank).
pub fn allreduce_mean(buffers: &mut [Vec<f32>]) {
    reduce_mean_into_rank0(buffers);
    let (canon, rest) = buffers.split_at_mut(1);
    for b in rest {
        b.copy_from_slice(&canon[0]);
    }
}

/// Wire bytes of one collective level split by leg — reduce-scatter
/// vs all-gather — so per-leg asymmetries (a future sparse or
/// error-fed leg, partial gathers) are never averaged away in the
/// records. For the symmetric ring schedules modeled here the two
/// legs move the same volume; the split is the accounting unit, not
/// an assumption.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LegBytes {
    /// bytes every rank of the level transmits on the reduce-scatter
    /// leg, summed over ranks (and over pods, for the intra level)
    pub reduce_scatter: u64,
    /// same accounting for the all-gather leg
    pub all_gather: u64,
}

impl LegBytes {
    /// Both legs combined.
    pub fn total(&self) -> u64 {
        self.reduce_scatter + self.all_gather
    }

    /// Add another accounting onto this one, per leg.
    pub fn accumulate(&mut self, other: &LegBytes) {
        self.reduce_scatter += other.reduce_scatter;
        self.all_gather += other.all_gather;
    }
}

/// Per-leg wire bytes of one collective level: `groups` independent
/// ring collectives of `ranks` participants each (the intra level is
/// `pods` rings of `workers_per_pod`; the inter level is one ring of
/// `pods` leaders), `n` elements end to end. Each of the `ranks`
/// participants transmits `(ranks-1)/ranks · payload` per leg, so the
/// per-leg group total is `(ranks-1) · payload`: `4n` bytes raw f32,
/// or `n + 4·⌈n/chunk⌉` when the leg is FP8-compressed (one byte per
/// element plus a 4-byte pow2 scale per chunk).
pub(crate) fn level_legs(
    n: usize,
    ranks: usize,
    groups: usize,
    fp8: Option<Fp8Format>,
    chunk: usize,
) -> LegBytes {
    let payload = match fp8 {
        None => 4 * n as u64,
        Some(_) => n as u64 + 4 * n.div_ceil(chunk) as u64,
    };
    let per_leg = groups as u64 * (ranks as u64 - 1) * payload;
    LegBytes { reduce_scatter: per_leg, all_gather: per_leg }
}

/// Bytes-on-the-wire accounting for one gradient collective, split by
/// topology level (intra-pod vs inter-pod) and by leg (reduce-scatter
/// vs all-gather), each against its raw-f32 baseline. The flat
/// collective reports everything on the intra level (one pod, no
/// leader exchange); `W = 1` moves no bytes at all.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CollectiveStats {
    /// gradient elements reduced
    pub elems: usize,
    /// executed wire bytes on the intra-pod legs, all pods combined
    pub intra: LegBytes,
    /// executed wire bytes on the inter-pod (pod-leader) legs
    pub inter: LegBytes,
    /// what a raw-f32 intra level of the same shape would move
    pub intra_f32: LegBytes,
    /// what a raw-f32 inter level of the same shape would move
    pub inter_f32: LegBytes,
}

impl CollectiveStats {
    /// Total wire bytes the executed configuration moves (both
    /// levels, both legs).
    pub fn wire_bytes(&self) -> u64 {
        self.intra.total() + self.inter.total()
    }

    /// Total wire bytes the raw-f32 collective of the same topology
    /// would move.
    pub fn wire_bytes_f32(&self) -> u64 {
        self.intra_f32.total() + self.inter_f32.total()
    }

    /// Compression ratio on the wire (1.0 for the f32 path / W = 1).
    pub fn wire_ratio(&self) -> f64 {
        if self.wire_bytes_f32() == 0 {
            1.0
        } else {
            self.wire_bytes() as f64 / self.wire_bytes_f32() as f64
        }
    }

    /// Compression ratio on the inter-pod level alone — the thin pipe
    /// the topology exists for (1.0 when the level moves no bytes).
    pub fn inter_wire_ratio(&self) -> f64 {
        if self.inter_f32.total() == 0 {
            1.0
        } else {
            self.inter.total() as f64 / self.inter_f32.total() as f64
        }
    }

    /// Fold another collective's accounting into this one. The
    /// bucketed pipeline sums per-bucket stats; because every non-final
    /// bucket is a whole-chunk multiple, the per-bucket FP8 payloads
    /// (`n + 4·⌈n/chunk⌉`) sum to exactly the whole-buffer closed form
    /// — pinned by `topology::tests`.
    pub fn absorb(&mut self, other: &CollectiveStats) {
        self.elems += other.elems;
        self.intra.accumulate(&other.intra);
        self.inter.accumulate(&other.inter);
        self.intra_f32.accumulate(&other.intra_f32);
        self.inter_f32.accumulate(&other.inter_f32);
    }
}

/// Reusable encode scratch for the FP8 collective: one byte buffer
/// per fan-out lane, grown on first use and persisted by the owner
/// (the trainer keeps one across steps) so the per-step hot path
/// allocates nothing in steady state — the same discipline as the
/// trainer's `AdamScratch`.
#[derive(Default)]
pub struct CollectiveScratch {
    lanes: Vec<Vec<u8>>,
}

/// Quantize-dequantize `buf` in place on absolute `chunk`-grid spans,
/// each with its own pow2 JIT scale (`fp8::compute_scale` from the
/// span amax — the FP8-LM auto-scaling recipe). Chunks are independent
/// and processed with a fixed grid, so the scoped-thread fan-out is
/// bit-deterministic; NaN elements ride through as NaN bytes
/// (`bulk::pack_scaled_into` propagates them without touching the
/// scale) and surface later in the global-norm clip.
pub(crate) fn qdq_chunks(
    fmt: Fp8Format,
    chunk: usize,
    buf: &mut [f32],
    scratch: &mut CollectiveScratch,
) {
    assert!(chunk >= 1, "collective chunk size must be >= 1");
    let n = buf.len();
    if n == 0 {
        return;
    }
    let n_chunks = n.div_ceil(chunk);
    let qdq_span = |span: &mut [f32], bytes: &mut Vec<u8>| {
        for c in span.chunks_mut(chunk) {
            let scale = bulk::pack_scaled_into(fmt, c, bytes);
            bulk::unpack_scaled_buf(fmt, bytes, scale, c);
        }
    };
    let threads = if n < PAR_THRESHOLD { 1 } else { max_threads().min(n_chunks).max(1) };
    if scratch.lanes.len() < threads {
        scratch.lanes.resize_with(threads, Vec::new);
    }
    if threads <= 1 {
        qdq_span(buf, &mut scratch.lanes[0]);
        return;
    }
    // deal whole chunks to threads in contiguous runs so every chunk
    // is scaled over exactly the span the serial schedule would use
    let per = n_chunks.div_ceil(threads) * chunk;
    let qdq_span = &qdq_span;
    std::thread::scope(|s| {
        let mut lanes = scratch.lanes.iter_mut();
        let mut spans = buf.chunks_mut(per);
        let inline = spans.next().zip(lanes.next());
        for (span, bytes) in spans.zip(lanes) {
            s.spawn(move || qdq_span(span, bytes));
        }
        if let Some((span, bytes)) = inline {
            qdq_span(span, bytes);
        }
    });
}

/// One data-parallel gradient collective: deterministic reduce-scatter
/// → mean → all-gather, with optional FP8 compression of both wire
/// legs (FP8-LM-style per-chunk pow2 auto-scale). On return,
/// `buffers[0]` holds the full gathered average — the canonical copy
/// the trainer consumes; like [`reduce_mean_into_rank0`], the other
/// replicas keep stale partial-sum state (every replica buffer is
/// overwritten at the top of the next step).
///
/// * `fp8 = None` — **bit-identical to [`reduce_mean_into_rank0`]**,
///   the pinned serial schedule (tree sum + 1/W scale). This is the
///   `collective_fp8_intra = false` fallback.
/// * `fp8 = Some(fmt)` — models FP8-LM's compressed collective:
///   1. every worker's contribution is quantize-dequantized on the
///      absolute `chunk` grid (what the reduce-scatter leg delivers
///      to each chunk's owner);
///   2. the tree sum + 1/W mean runs in f32 (owners accumulate
///      partial sums in full precision, as FP8-LM does);
///   3. the averaged result is quantize-dequantized per chunk again
///      (what the all-gather leg delivers to every rank — including
///      the owner, so one value is THE gradient everywhere).
///
/// Every stage is elementwise or fixed-order over a fixed chunk grid,
/// so the result is bit-deterministic at any thread count. `W = 1`
/// moves no bytes and skips quantization entirely (nothing crosses a
/// wire). Shard boundaries produced by
/// [`ShardLayout::chunk_aligned`](crate::optimizer::ShardLayout) land
/// on this same chunk grid, so per-shard and whole-buffer chunking
/// are the same partition.
pub fn grad_collective(
    buffers: &mut [Vec<f32>],
    fp8: Option<Fp8Format>,
    chunk: usize,
) -> CollectiveStats {
    grad_collective_with(buffers, fp8, chunk, &mut CollectiveScratch::default())
}

/// [`grad_collective`] with caller-owned encode scratch — the step
/// loop's entry point (the trainer persists one [`CollectiveScratch`]
/// so the per-step FP8 path performs no steady-state allocation).
pub fn grad_collective_with(
    buffers: &mut [Vec<f32>],
    fp8: Option<Fp8Format>,
    chunk: usize,
    scratch: &mut CollectiveScratch,
) -> CollectiveStats {
    let w = buffers.len();
    assert!(w >= 1);
    let n = buffers[0].len();
    if w == 1 {
        reduce_mean_into_rank0(buffers);
        return CollectiveStats { elems: n, ..CollectiveStats::default() };
    }
    let intra_f32 = level_legs(n, w, 1, None, chunk);
    match fp8 {
        None => {
            reduce_mean_into_rank0(buffers);
            CollectiveStats {
                elems: n,
                intra: intra_f32,
                intra_f32,
                ..CollectiveStats::default()
            }
        }
        Some(fmt) => {
            for buf in buffers.iter_mut() {
                qdq_chunks(fmt, chunk, buf, scratch);
            }
            reduce_mean_into_rank0(buffers);
            qdq_chunks(fmt, chunk, &mut buffers[0], scratch);
            CollectiveStats {
                elems: n,
                intra: level_legs(n, w, 1, Some(fmt), chunk),
                intra_f32,
                ..CollectiveStats::default()
            }
        }
    }
}

/// Sum of squares of one norm chunk in f64, element order (the single
/// defined partial the fixed-order norm fold consumes — also used by
/// `pipeline::NormStream` to reproduce the fold across bucket
/// boundaries).
#[inline]
pub(crate) fn norm_sq(chunk: &[f32]) -> f64 {
    chunk.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// Global L2 norm over a flat gradient (for clipping).
///
/// Accumulation is chunked at [`NORM_CHUNK`] with f64 partials folded
/// in chunk index order. The fixed chunking means the fan-out across
/// threads cannot change the result — each chunk's partial is computed
/// identically wherever it runs, and the final fold order is the chunk
/// order either way.
pub fn global_norm(flat: &[f32]) -> f32 {
    // par_partials guarantees partial i == norm_sq(chunk i) regardless
    // of scheduling; the in-order sum below is therefore the (single)
    // defined reduction order
    par_partials(flat, NORM_CHUNK, norm_sq).iter().sum::<f64>().sqrt() as f32
}

/// Clip multiplier for max-norm clipping (1.0 when under the limit).
pub fn clip_factor(norm: f32, max_norm: f32) -> f32 {
    if !norm.is_finite() {
        return 0.0; // drop the update entirely on a non-finite grad
    }
    if norm <= max_norm || max_norm <= 0.0 {
        1.0
    } else {
        max_norm / norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_matches_sequential_sum() {
        for w in 1..=9 {
            let mut bufs: Vec<Vec<f32>> =
                (0..w).map(|r| (0..17).map(|i| (r * 100 + i) as f32).collect()).collect();
            let expect: Vec<f32> = (0..17)
                .map(|i| (0..w).map(|r| (r * 100 + i) as f32).sum())
                .collect();
            tree_reduce_sum(&mut bufs);
            assert_eq!(bufs[0], expect, "w={w}");
        }
    }

    #[test]
    fn window_tree_bit_matches_buffer_tree() {
        // the window variant must use the exact pair schedule of the
        // owned-buffer variant, at stride 1 and at a leader stride
        for (w, step) in [(5usize, 1usize), (8, 1), (8, 2), (9, 3)] {
            let mk = || -> Vec<Vec<f32>> {
                (0..w)
                    .map(|r| (0..517).map(|i| ((r * 41 + i) as f32).sin() * 0.1).collect())
                    .collect()
            };
            let mut owned = mk();
            tree_reduce_sum_strided(&mut owned, step);
            let mut bufs = mk();
            let mut wins: Vec<&mut [f32]> = bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            tree_reduce_sum_windows(&mut wins, step);
            for (x, y) in owned[0].iter().zip(&bufs[0]) {
                assert_eq!(x.to_bits(), y.to_bits(), "w={w} step={step}");
            }
        }
    }

    #[test]
    fn stats_absorb_sums_every_field() {
        let a = CollectiveStats {
            elems: 10,
            intra: LegBytes { reduce_scatter: 1, all_gather: 2 },
            inter: LegBytes { reduce_scatter: 3, all_gather: 4 },
            intra_f32: LegBytes { reduce_scatter: 5, all_gather: 6 },
            inter_f32: LegBytes { reduce_scatter: 7, all_gather: 8 },
        };
        let mut acc = a;
        acc.absorb(&a);
        assert_eq!(acc.elems, 20);
        assert_eq!(acc.intra, LegBytes { reduce_scatter: 2, all_gather: 4 });
        assert_eq!(acc.inter_f32, LegBytes { reduce_scatter: 14, all_gather: 16 });
        assert_eq!(acc.wire_bytes(), 2 * a.wire_bytes());
    }

    #[test]
    fn mean_broadcasts() {
        let mut bufs = vec![vec![2.0f32, 4.0], vec![4.0, 8.0]];
        allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![3.0, 6.0]);
        assert_eq!(bufs[1], vec![3.0, 6.0]);
    }

    #[test]
    fn rank0_variant_matches_broadcast_variant_on_rank0() {
        let mk = || -> Vec<Vec<f32>> {
            (0..5)
                .map(|r| (0..97).map(|i| ((r * 31 + i) as f32).sin()).collect())
                .collect()
        };
        let mut a = mk();
        let mut b = mk();
        allreduce_mean(&mut a);
        reduce_mean_into_rank0(&mut b);
        for (x, y) in a[0].iter().zip(&b[0]) {
            assert_eq!(x.to_bits(), y.to_bits(), "rank0 must be bit-identical");
        }
    }

    #[test]
    fn clip_semantics() {
        assert_eq!(clip_factor(0.5, 1.0), 1.0);
        assert_eq!(clip_factor(2.0, 1.0), 0.5);
        assert_eq!(clip_factor(f32::NAN, 1.0), 0.0);
        assert_eq!(clip_factor(f32::INFINITY, 1.0), 0.0);
    }

    #[test]
    fn norm_is_l2() {
        assert!((global_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn collective_f32_path_bit_matches_rank0_reduce() {
        for w in [1usize, 2, 4, 5] {
            let mk = || -> Vec<Vec<f32>> {
                (0..w)
                    .map(|r| (0..313).map(|i| ((r * 37 + i) as f32).sin() * 0.01).collect())
                    .collect()
            };
            let mut a = mk();
            let mut b = mk();
            let stats = grad_collective(&mut a, None, 64);
            reduce_mean_into_rank0(&mut b);
            for (x, y) in a[0].iter().zip(&b[0]) {
                assert_eq!(x.to_bits(), y.to_bits(), "w={w}: f32 path must be bit-identical");
            }
            assert_eq!(stats.elems, 313);
            let expect_wire = if w == 1 { 0 } else { 2 * (w as u64 - 1) * 313 * 4 };
            assert_eq!(stats.wire_bytes(), expect_wire);
            assert_eq!(stats.wire_bytes_f32(), expect_wire);
            assert_eq!(stats.inter.total(), 0, "flat collective has no inter level");
            assert_eq!(stats.wire_ratio(), 1.0);
        }
    }

    #[test]
    fn collective_fp8_wire_accounting() {
        let n = 1000usize;
        let chunk = 64usize;
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.01f32; n]).collect();
        let stats = grad_collective(&mut bufs, Some(crate::fp8::E5M2), chunk);
        let n_chunks = n.div_ceil(chunk) as u64;
        assert_eq!(stats.wire_bytes(), 2 * 3 * (n as u64 + 4 * n_chunks));
        assert_eq!(stats.wire_bytes_f32(), 2 * 3 * n as u64 * 4);
        assert!(stats.wire_ratio() < 0.3, "ratio {}", stats.wire_ratio());
    }

    #[test]
    fn collective_stats_per_leg_accounting_pins_totals() {
        // per-leg split (reduce-scatter vs all-gather) must carry the
        // full totals — not an averaged aggregate. Closed forms for
        // W = 4, n = 1000, chunk = 64 (16 chunks):
        let n = 1000usize;
        let chunk = 64usize;
        let n_chunks = n.div_ceil(chunk) as u64;

        let mut f32_bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.01f32; n]).collect();
        let s = grad_collective(&mut f32_bufs, None, chunk);
        let f32_leg = 3 * n as u64 * 4; // (W-1)·4n per leg
        assert_eq!(s.intra.reduce_scatter, f32_leg);
        assert_eq!(s.intra.all_gather, f32_leg);
        assert_eq!(s.intra.total(), 2 * f32_leg);
        assert_eq!(s.inter, LegBytes::default());
        assert_eq!(s.wire_bytes(), s.intra.total());

        let mut fp8_bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.01f32; n]).collect();
        let s = grad_collective(&mut fp8_bufs, Some(crate::fp8::E5M2), chunk);
        let fp8_leg = 3 * (n as u64 + 4 * n_chunks); // (W-1)·(n + 4·⌈n/chunk⌉)
        assert_eq!(s.intra, LegBytes { reduce_scatter: fp8_leg, all_gather: fp8_leg });
        assert_eq!(s.intra_f32, LegBytes { reduce_scatter: f32_leg, all_gather: f32_leg });
        assert_eq!(s.wire_bytes(), 2 * fp8_leg);
        assert_eq!(s.wire_bytes_f32(), 2 * f32_leg);

        // W = 1: nothing crosses a wire, on any leg of any level
        let mut one = vec![vec![0.5f32; n]];
        let s = grad_collective(&mut one, Some(crate::fp8::E4M3), chunk);
        assert_eq!((s.wire_bytes(), s.wire_bytes_f32()), (0, 0));
        assert_eq!(s.wire_ratio(), 1.0);
    }

    #[test]
    fn norm_chunking_is_the_definition() {
        // > 2 chunks, ragged tail: result must equal the explicit
        // chunk-partial fold, bit for bit, no matter how many threads ran
        let n = NORM_CHUNK * 3 + 1234;
        let flat: Vec<f32> = (0..n).map(|i| ((i as f32) * 1e-3).sin() * 0.01).collect();
        let expect = flat
            .chunks(NORM_CHUNK)
            .map(norm_sq)
            .sum::<f64>()
            .sqrt() as f32;
        assert_eq!(global_norm(&flat).to_bits(), expect.to_bits());
    }
}
