//! Gradient all-reduce over the simulated data-parallel pool.
//!
//! Workers produce per-replica gradient buffers; the collective is a
//! binary-tree reduction (⌈log2 W⌉ rounds, matching how a real pod's
//! ring/tree collective combines partial sums deterministically) then
//! an average. Reduction order is *fixed* regardless of thread timing,
//! so runs are bit-reproducible at any worker count.

/// Tree-reduce in place: buffers[0] ends up holding the elementwise sum.
pub fn tree_reduce_sum(buffers: &mut [Vec<f32>]) {
    let w = buffers.len();
    assert!(w >= 1);
    let n = buffers[0].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), n, "replica gradient size mismatch");
    }
    let mut stride = 1;
    while stride < w {
        let mut i = 0;
        while i + stride < w {
            // combine pair (i, i+stride) — fixed order
            let (left, right) = buffers.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                *d += *s;
            }
            i += stride * 2;
        }
        stride *= 2;
    }
}

/// All-reduce average: tree-sum then scale by 1/W, broadcast into all
/// replicas (the coordinator keeps one canonical copy; this mirrors
/// the collective's output being identical on every rank).
pub fn allreduce_mean(buffers: &mut [Vec<f32>]) {
    let w = buffers.len() as f32;
    tree_reduce_sum(buffers);
    let inv = 1.0 / w;
    // scale rank 0 ...
    for x in buffers[0].iter_mut() {
        *x *= inv;
    }
    // ... broadcast
    let (canon, rest) = buffers.split_at_mut(1);
    for b in rest {
        b.copy_from_slice(&canon[0]);
    }
}

/// Global L2 norm over a flat gradient (for clipping).
pub fn global_norm(flat: &[f32]) -> f32 {
    (flat.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
}

/// Clip multiplier for max-norm clipping (1.0 when under the limit).
pub fn clip_factor(norm: f32, max_norm: f32) -> f32 {
    if !norm.is_finite() {
        return 0.0; // drop the update entirely on a non-finite grad
    }
    if norm <= max_norm || max_norm <= 0.0 {
        1.0
    } else {
        max_norm / norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_matches_sequential_sum() {
        for w in 1..=9 {
            let mut bufs: Vec<Vec<f32>> =
                (0..w).map(|r| (0..17).map(|i| (r * 100 + i) as f32).collect()).collect();
            let expect: Vec<f32> = (0..17)
                .map(|i| (0..w).map(|r| (r * 100 + i) as f32).sum())
                .collect();
            tree_reduce_sum(&mut bufs);
            assert_eq!(bufs[0], expect, "w={w}");
        }
    }

    #[test]
    fn mean_broadcasts() {
        let mut bufs = vec![vec![2.0f32, 4.0], vec![4.0, 8.0]];
        allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![3.0, 6.0]);
        assert_eq!(bufs[1], vec![3.0, 6.0]);
    }

    #[test]
    fn clip_semantics() {
        assert_eq!(clip_factor(0.5, 1.0), 1.0);
        assert_eq!(clip_factor(2.0, 1.0), 0.5);
        assert_eq!(clip_factor(f32::NAN, 1.0), 0.0);
        assert_eq!(clip_factor(f32::INFINITY, 1.0), 0.0);
    }

    #[test]
    fn norm_is_l2() {
        assert!((global_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
