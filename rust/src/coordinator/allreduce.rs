//! Gradient all-reduce over the simulated data-parallel pool.
//!
//! Workers produce per-replica gradient buffers; the collective is a
//! binary-tree reduction (⌈log2 W⌉ rounds, matching how a real pod's
//! ring/tree collective combines partial sums deterministically) then
//! an average. Reduction order is *fixed* regardless of thread timing,
//! so runs are bit-reproducible at any worker count: the tree shape
//! decides which additions happen, threads only decide *where* the
//! per-element additions run.
//!
//! Two averaging variants:
//! * [`allreduce_mean`] — sum, scale, broadcast into every replica.
//!   This mirrors collective semantics (every rank holds the result)
//!   and is what probe/analysis code should use when it reads a
//!   non-zero replica afterwards.
//! * [`reduce_mean_into_rank0`] — sum + scale only. `Trainer::step`
//!   consumes only the canonical rank-0 copy and overwrites every
//!   replica at the top of the next step, so the broadcast was W-1
//!   dead memcpys of the full gradient per step.

use crate::util::par::{par_partials, par_zip};

/// Fixed accumulation chunk for [`global_norm`]. This is not a tuning
/// knob: it *defines* the f64 summation order (per-chunk partials,
/// folded in chunk index order), so the parallel and serial paths —
/// and therefore the clip factor — are bit-identical. Changing it
/// changes rounding in the last ulp of the norm.
pub const NORM_CHUNK: usize = 1 << 16;

/// Elementwise `dst += src`, fanned out across scoped threads above
/// the shared `util::par` threshold. Bit-deterministic: per-element
/// ops, disjoint spans.
fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "replica gradient size mismatch");
    par_zip(src, dst, |s_span, d_span| {
        for (d, x) in d_span.iter_mut().zip(s_span) {
            *d += *x;
        }
    });
}

/// Tree-reduce in place: buffers[0] ends up holding the elementwise sum.
pub fn tree_reduce_sum(buffers: &mut [Vec<f32>]) {
    let w = buffers.len();
    assert!(w >= 1);
    let n = buffers[0].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), n, "replica gradient size mismatch");
    }
    let mut stride = 1;
    while stride < w {
        let mut i = 0;
        while i + stride < w {
            // combine pair (i, i+stride) — fixed order
            let (left, right) = buffers.split_at_mut(i + stride);
            add_assign(&mut left[i], &right[0]);
            i += stride * 2;
        }
        stride *= 2;
    }
}

/// Reduce-mean without the broadcast: buffers[0] holds the average,
/// the other replicas keep their (now stale) partial-sum state. Use
/// when only the canonical copy is read before the next overwrite —
/// the training loop's case. Callers that need collective semantics
/// (every replica identical) want [`allreduce_mean`].
pub fn reduce_mean_into_rank0(buffers: &mut [Vec<f32>]) {
    let w = buffers.len() as f32;
    tree_reduce_sum(buffers);
    let inv = 1.0 / w;
    for x in buffers[0].iter_mut() {
        *x *= inv;
    }
}

/// All-reduce average: tree-sum then scale by 1/W, broadcast into all
/// replicas (the coordinator keeps one canonical copy; this mirrors
/// the collective's output being identical on every rank).
pub fn allreduce_mean(buffers: &mut [Vec<f32>]) {
    reduce_mean_into_rank0(buffers);
    let (canon, rest) = buffers.split_at_mut(1);
    for b in rest {
        b.copy_from_slice(&canon[0]);
    }
}

#[inline]
fn norm_sq(chunk: &[f32]) -> f64 {
    chunk.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// Global L2 norm over a flat gradient (for clipping).
///
/// Accumulation is chunked at [`NORM_CHUNK`] with f64 partials folded
/// in chunk index order. The fixed chunking means the fan-out across
/// threads cannot change the result — each chunk's partial is computed
/// identically wherever it runs, and the final fold order is the chunk
/// order either way.
pub fn global_norm(flat: &[f32]) -> f32 {
    // par_partials guarantees partial i == norm_sq(chunk i) regardless
    // of scheduling; the in-order sum below is therefore the (single)
    // defined reduction order
    par_partials(flat, NORM_CHUNK, norm_sq).iter().sum::<f64>().sqrt() as f32
}

/// Clip multiplier for max-norm clipping (1.0 when under the limit).
pub fn clip_factor(norm: f32, max_norm: f32) -> f32 {
    if !norm.is_finite() {
        return 0.0; // drop the update entirely on a non-finite grad
    }
    if norm <= max_norm || max_norm <= 0.0 {
        1.0
    } else {
        max_norm / norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_matches_sequential_sum() {
        for w in 1..=9 {
            let mut bufs: Vec<Vec<f32>> =
                (0..w).map(|r| (0..17).map(|i| (r * 100 + i) as f32).collect()).collect();
            let expect: Vec<f32> = (0..17)
                .map(|i| (0..w).map(|r| (r * 100 + i) as f32).sum())
                .collect();
            tree_reduce_sum(&mut bufs);
            assert_eq!(bufs[0], expect, "w={w}");
        }
    }

    #[test]
    fn mean_broadcasts() {
        let mut bufs = vec![vec![2.0f32, 4.0], vec![4.0, 8.0]];
        allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![3.0, 6.0]);
        assert_eq!(bufs[1], vec![3.0, 6.0]);
    }

    #[test]
    fn rank0_variant_matches_broadcast_variant_on_rank0() {
        let mk = || -> Vec<Vec<f32>> {
            (0..5)
                .map(|r| (0..97).map(|i| ((r * 31 + i) as f32).sin()).collect())
                .collect()
        };
        let mut a = mk();
        let mut b = mk();
        allreduce_mean(&mut a);
        reduce_mean_into_rank0(&mut b);
        for (x, y) in a[0].iter().zip(&b[0]) {
            assert_eq!(x.to_bits(), y.to_bits(), "rank0 must be bit-identical");
        }
    }

    #[test]
    fn clip_semantics() {
        assert_eq!(clip_factor(0.5, 1.0), 1.0);
        assert_eq!(clip_factor(2.0, 1.0), 0.5);
        assert_eq!(clip_factor(f32::NAN, 1.0), 0.0);
        assert_eq!(clip_factor(f32::INFINITY, 1.0), 0.0);
    }

    #[test]
    fn norm_is_l2() {
        assert!((global_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn norm_chunking_is_the_definition() {
        // > 2 chunks, ragged tail: result must equal the explicit
        // chunk-partial fold, bit for bit, no matter how many threads ran
        let n = NORM_CHUNK * 3 + 1234;
        let flat: Vec<f32> = (0..n).map(|i| ((i as f32) * 1e-3).sin() * 0.01).collect();
        let expect = flat
            .chunks(NORM_CHUNK)
            .map(norm_sq)
            .sum::<f64>()
            .sqrt() as f32;
        assert_eq!(global_norm(&flat).to_bits(), expect.to_bits());
    }
}
