//! Parameter store: the replicated model parameters as named host
//! tensors (manifest order) plus flat-space views for the optimizer.

use anyhow::{anyhow, Result};

use crate::runtime::manifest::{Manifest, ParamSpec};
use crate::runtime::tensor::HostTensor;
use crate::util::prng::Rng;

/// The replicated model parameters: one named host tensor per
/// manifest param spec, in manifest order.
pub struct ParamStore {
    /// the manifest's param specs (names, shapes, init stds)
    pub specs: Vec<ParamSpec>,
    /// the parameter tensors, parallel to `specs`
    pub tensors: Vec<HostTensor>,
}

impl ParamStore {
    /// Deterministic init from the manifest specs: N(0, std²) per
    /// tensor (independent split streams), ones for norm gains.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let root = Rng::new(seed);
        let mut tensors = Vec::with_capacity(manifest.params.len());
        for (i, spec) in manifest.params.iter().enumerate() {
            let n = spec.numel();
            let mut data = vec![0.0f32; n];
            if spec.init_std < 0.0 {
                data.fill(1.0);
            } else {
                root.split(i as u64 + 1).fill_normal(&mut data, spec.init_std);
            }
            tensors.push(HostTensor::from_f32(&spec.shape, data));
        }
        Self { specs: manifest.params.clone(), tensors }
    }

    /// Plant a partially-aligned, large-norm SwiGLU channel in layer 0
    /// (mechanism-reproduction mode; DESIGN.md §Substitutions). Sets
    /// w2[:, ch] := gain · u and w1[:, ch] := gain · (αu + √(1-α²)v)
    /// for random unit u ⊥ v with α = 0.7 — past the Theorem-1
    /// threshold so training completes the alignment quickly.
    pub fn seed_outlier_channel(&mut self, gain: f32, seed: u64) -> Result<usize> {
        let (w1_idx, w1_shape) = self.index_of("w1")?;
        let (w2_idx, _) = self.index_of("w2")?;
        let (d, f) = (w1_shape[1], w1_shape[2]);
        let ch = f / 2;
        let mut rng = Rng::new(seed ^ 0x0071_u64).split(99);
        let mut u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        normalize(&mut u);
        // Gram-Schmidt v against u
        let dot: f32 = u.iter().zip(&v).map(|(a, b)| a * b).sum();
        for i in 0..d {
            v[i] -= dot * u[i];
        }
        normalize(&mut v);
        let alpha = 0.7f32;
        let beta = (1.0 - alpha * alpha).sqrt();
        {
            let w2 = self.tensors[w2_idx].f32s_mut();
            for i in 0..d {
                w2[i * f + ch] = gain * u[i]; // layer 0 slab
            }
        }
        {
            let w1 = self.tensors[w1_idx].f32s_mut();
            for i in 0..d {
                w1[i * f + ch] = gain * (alpha * u[i] + beta * v[i]);
            }
        }
        Ok(ch)
    }

    /// Locate a parameter by name → (tensor index, shape).
    pub fn index_of(&self, name: &str) -> Result<(usize, Vec<usize>)> {
        self.specs
            .iter()
            .position(|s| s.name == name)
            .map(|i| (i, self.specs[i].shape.clone()))
            .ok_or_else(|| anyhow!("no parameter named '{name}'"))
    }

    /// Total parameter elements across all tensors (the flat-space
    /// length the optimizer and shard layout work in).
    pub fn total_elems(&self) -> usize {
        self.specs.iter().map(|s| s.numel()).sum()
    }

    /// Copy all tensors into one flat f32 buffer (manifest order).
    pub fn flatten_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.total_elems());
        for t in &self.tensors {
            out.extend_from_slice(t.f32s());
        }
    }

    /// Scatter a flat buffer back into the named tensors.
    pub fn unflatten_from(&mut self, flat: &[f32]) {
        let mut off = 0;
        for t in self.tensors.iter_mut() {
            let n = t.len();
            t.f32s_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len(), "flat parameter size mismatch");
    }

    /// Extract a layer slice of a stacked [L, d, f] weight (for the
    /// correlation analysis).
    pub fn layer_slice(&self, name: &str, layer: usize) -> Result<(Vec<f32>, usize, usize)> {
        let (idx, shape) = self.index_of(name)?;
        if shape.len() != 3 {
            return Err(anyhow!("'{name}' is not a stacked [L, d, f] weight"));
        }
        let (d, f) = (shape[1], shape[2]);
        let per = d * f;
        let data = self.tensors[idx].f32s();
        Ok((data[layer * per..(layer + 1) * per].to_vec(), d, f))
    }
}

fn normalize(v: &mut [f32]) {
    let n = (v.iter().map(|x| x * x).sum::<f32>()).sqrt().max(1e-12);
    for x in v.iter_mut() {
        *x /= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn manifest_like() -> Manifest {
        let j = crate::util::json::Json::parse(
            r#"{"kind":"grad","params":[
                {"name":"ln_1","shape":[2,8],"init_std":-1.0},
                {"name":"w1","shape":[2,8,6],"init_std":0.02},
                {"name":"w2","shape":[2,8,6],"init_std":0.02}]}"#,
        )
        .unwrap();
        Manifest::from_json("t".into(), j).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_typed() {
        let m = manifest_like();
        let a = ParamStore::init(&m, 1);
        let b = ParamStore::init(&m, 1);
        let c = ParamStore::init(&m, 2);
        assert_eq!(a.tensors[1].f32s(), b.tensors[1].f32s());
        assert_ne!(a.tensors[1].f32s(), c.tensors[1].f32s());
        assert!(a.tensors[0].f32s().iter().all(|&x| x == 1.0), "norm gains init to 1");
    }

    #[test]
    fn flatten_roundtrip() {
        let m = manifest_like();
        let mut p = ParamStore::init(&m, 3);
        let mut flat = Vec::new();
        p.flatten_into(&mut flat);
        assert_eq!(flat.len(), p.total_elems());
        flat[0] = 42.0;
        p.unflatten_from(&flat);
        assert_eq!(p.tensors[0].f32s()[0], 42.0);
    }

    #[test]
    fn outlier_channel_is_aligned_and_large() {
        let m = manifest_like();
        let mut p = ParamStore::init(&m, 3);
        let ch = p.seed_outlier_channel(8.0, 3).unwrap();
        let (w1, d, f) = p.layer_slice("w1", 0).unwrap();
        let (w2, _, _) = p.layer_slice("w2", 0).unwrap();
        let stats = crate::analysis::correlation::channel_correlations(&w1, &w2, d, f);
        assert!(stats[ch].cosine > 0.65 && stats[ch].cosine < 0.75);
        assert!(stats[ch].norm2 > 7.0);
    }

    #[test]
    fn numel_helper() {
        let s = ParamSpec { name: "x".into(), shape: vec![3, 4, 5], init_std: 0.1 };
        assert_eq!(s.numel(), 60);
    }
}
