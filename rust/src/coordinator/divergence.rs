//! Divergence detection — how a long FP8 run knows it has hit the
//! paper's Fig. 2a failure. Signals:
//!
//! * non-finite loss (hard failure),
//! * loss exceeding a multiple of its trailing EMA (the Fig. 2a spike),
//! * sustained overflow events in the scaling manager.

/// The detector's per-step classification of the run's health.
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    /// no divergence signal this step
    Healthy,
    /// spike factor over the EMA
    LossSpike(f32),
    /// the loss came back NaN/inf — hard failure
    NonFiniteLoss,
    /// cumulative overflow events exceeded the limit (count inside)
    OverflowStorm(usize),
}

/// Watches the loss stream and the scaling manager's overflow counter
/// for the paper's Fig. 2a divergence signatures (see module docs).
#[derive(Clone, Debug)]
pub struct DivergenceDetector {
    ema: f32,
    alpha: f32,
    /// loss-over-EMA multiple that counts as a spike
    pub spike_factor: f32,
    /// cumulative overflow-event count that counts as a storm
    pub overflow_limit: usize,
    warmed: bool,
    /// step of the first divergence verdict, if any (latched)
    pub diverged_at: Option<usize>,
}

impl Default for DivergenceDetector {
    fn default() -> Self {
        Self {
            ema: 0.0,
            alpha: 0.02,
            spike_factor: 1.5,
            overflow_limit: 64,
            warmed: false,
            diverged_at: None,
        }
    }
}

impl DivergenceDetector {
    /// Ingest one step's loss + cumulative overflow count and return
    /// the verdict; the first non-healthy verdict latches
    /// [`diverged_at`](Self::diverged_at).
    pub fn observe(&mut self, step: usize, loss: f32, overflow_events: usize) -> Verdict {
        if !loss.is_finite() {
            self.diverged_at.get_or_insert(step);
            return Verdict::NonFiniteLoss;
        }
        if overflow_events > self.overflow_limit {
            self.diverged_at.get_or_insert(step);
            return Verdict::OverflowStorm(overflow_events);
        }
        let verdict = if self.warmed && loss > self.ema * self.spike_factor {
            self.diverged_at.get_or_insert(step);
            Verdict::LossSpike(loss / self.ema)
        } else {
            Verdict::Healthy
        };
        self.ema = if self.warmed { self.ema + self.alpha * (loss - self.ema) } else { loss };
        self.warmed = true;
        verdict
    }

    /// Whether any step has produced a non-healthy verdict (latched).
    pub fn has_diverged(&self) -> bool {
        self.diverged_at.is_some()
    }

    /// Export the mutable state for a campaign snapshot. The tuning
    /// knobs (`alpha`, `spike_factor`, `overflow_limit`) are config,
    /// not state — a resume re-derives them.
    pub fn export_state(&self) -> DetectorState {
        DetectorState { ema: self.ema, warmed: self.warmed, diverged_at: self.diverged_at }
    }

    /// Restore state captured by [`export_state`](Self::export_state).
    /// The EMA is restored bit-for-bit, so a resumed run's verdicts
    /// match the uninterrupted run exactly.
    pub fn restore_state(&mut self, st: &DetectorState) {
        self.ema = st.ema;
        self.warmed = st.warmed;
        self.diverged_at = st.diverged_at;
    }
}

/// Serializable snapshot of a [`DivergenceDetector`]'s mutable state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DetectorState {
    /// trailing loss EMA (bit-exact restore matters: the spike test
    /// compares against `ema * spike_factor`)
    pub ema: f32,
    /// whether the EMA has seen its first loss
    pub warmed: bool,
    /// step of the first divergence verdict, if any (latched)
    pub diverged_at: Option<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_descent() {
        let mut d = DivergenceDetector::default();
        for step in 0..100 {
            let loss = 6.0 - step as f32 * 0.01;
            assert_eq!(d.observe(step, loss, 0), Verdict::Healthy);
        }
        assert!(!d.has_diverged());
    }

    #[test]
    fn spike_detected() {
        let mut d = DivergenceDetector::default();
        for step in 0..50 {
            d.observe(step, 5.0, 0);
        }
        match d.observe(50, 9.0, 0) {
            Verdict::LossSpike(f) => assert!(f > 1.5),
            v => panic!("expected spike, got {v:?}"),
        }
        assert_eq!(d.diverged_at, Some(50));
    }

    #[test]
    fn nan_is_hard_failure() {
        let mut d = DivergenceDetector::default();
        d.observe(0, 5.0, 0);
        assert_eq!(d.observe(1, f32::NAN, 0), Verdict::NonFiniteLoss);
    }

    #[test]
    fn overflow_storm() {
        let mut d = DivergenceDetector::default();
        assert_eq!(d.observe(0, 5.0, 1000), Verdict::OverflowStorm(1000));
    }

    #[test]
    fn export_restore_reproduces_verdicts() {
        let mut a = DivergenceDetector::default();
        for step in 0..30 {
            a.observe(step, 5.0 - step as f32 * 0.01, 0);
        }
        let st = a.export_state();
        let mut b = DivergenceDetector::default();
        b.restore_state(&st);
        assert_eq!(b.export_state(), st);
        // identical observations → identical verdicts and identical EMA bits
        for step in 30..40 {
            let loss = if step == 35 { 50.0 } else { 4.7 };
            assert_eq!(a.observe(step, loss, 0), b.observe(step, loss, 0), "step {step}");
        }
        assert_eq!(a.export_state().ema.to_bits(), b.export_state().ema.to_bits());
        assert_eq!(a.diverged_at, b.diverged_at);
    }
}
