//! Divergence detection — how a long FP8 run knows it has hit the
//! paper's Fig. 2a failure. Signals:
//!
//! * non-finite loss (hard failure),
//! * loss exceeding a multiple of its trailing EMA (the Fig. 2a spike),
//! * sustained overflow events in the scaling manager.

#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    Healthy,
    /// spike factor over the EMA
    LossSpike(f32),
    NonFiniteLoss,
    OverflowStorm(usize),
}

#[derive(Clone, Debug)]
pub struct DivergenceDetector {
    ema: f32,
    alpha: f32,
    pub spike_factor: f32,
    pub overflow_limit: usize,
    warmed: bool,
    pub diverged_at: Option<usize>,
}

impl Default for DivergenceDetector {
    fn default() -> Self {
        Self {
            ema: 0.0,
            alpha: 0.02,
            spike_factor: 1.5,
            overflow_limit: 64,
            warmed: false,
            diverged_at: None,
        }
    }
}

impl DivergenceDetector {
    pub fn observe(&mut self, step: usize, loss: f32, overflow_events: usize) -> Verdict {
        if !loss.is_finite() {
            self.diverged_at.get_or_insert(step);
            return Verdict::NonFiniteLoss;
        }
        if overflow_events > self.overflow_limit {
            self.diverged_at.get_or_insert(step);
            return Verdict::OverflowStorm(overflow_events);
        }
        let verdict = if self.warmed && loss > self.ema * self.spike_factor {
            self.diverged_at.get_or_insert(step);
            Verdict::LossSpike(loss / self.ema)
        } else {
            Verdict::Healthy
        };
        self.ema = if self.warmed { self.ema + self.alpha * (loss - self.ema) } else { loss };
        self.warmed = true;
        verdict
    }

    pub fn has_diverged(&self) -> bool {
        self.diverged_at.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_descent() {
        let mut d = DivergenceDetector::default();
        for step in 0..100 {
            let loss = 6.0 - step as f32 * 0.01;
            assert_eq!(d.observe(step, loss, 0), Verdict::Healthy);
        }
        assert!(!d.has_diverged());
    }

    #[test]
    fn spike_detected() {
        let mut d = DivergenceDetector::default();
        for step in 0..50 {
            d.observe(step, 5.0, 0);
        }
        match d.observe(50, 9.0, 0) {
            Verdict::LossSpike(f) => assert!(f > 1.5),
            v => panic!("expected spike, got {v:?}"),
        }
        assert_eq!(d.diverged_at, Some(50));
    }

    #[test]
    fn nan_is_hard_failure() {
        let mut d = DivergenceDetector::default();
        d.observe(0, 5.0, 0);
        assert_eq!(d.observe(1, f32::NAN, 0), Verdict::NonFiniteLoss);
    }

    #[test]
    fn overflow_storm() {
        let mut d = DivergenceDetector::default();
        assert_eq!(d.observe(0, 5.0, 1000), Verdict::OverflowStorm(1000));
    }
}
