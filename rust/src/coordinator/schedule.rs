//! Learning-rate schedule: linear warmup + cosine decay to
//! `min_frac · peak` (the Llama-2 recipe the paper keeps).

/// Linear-warmup + cosine-decay learning-rate schedule, a pure
/// function of the step index.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    /// peak learning rate reached at the end of warmup
    pub peak: f32,
    /// linear warmup length in steps
    pub warmup_steps: usize,
    /// total schedule length (the cosine lands at the floor here)
    pub total_steps: usize,
    /// floor as a fraction of `peak`
    pub min_frac: f32,
}

impl LrSchedule {
    /// The learning rate at `step` (clamped to the floor past
    /// `total_steps`).
    pub fn lr(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.peak * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let span = self.total_steps.saturating_sub(self.warmup_steps).max(1);
        let t = (step - self.warmup_steps).min(span) as f32 / span as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        let min = self.peak * self.min_frac;
        min + (self.peak - min) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> LrSchedule {
        LrSchedule { peak: 1e-3, warmup_steps: 10, total_steps: 110, min_frac: 0.1 }
    }

    #[test]
    fn warmup_is_linear() {
        let s = sched();
        assert!((s.lr(0) - 1e-4).abs() < 1e-9);
        assert!((s.lr(9) - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn decays_to_min() {
        let s = sched();
        assert!((s.lr(10) - 1e-3).abs() < 1e-6);
        assert!((s.lr(110) - 1e-4).abs() < 1e-6);
        assert!(s.lr(500) >= 1e-4 - 1e-9, "clamps after total_steps");
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = sched();
        let mut prev = f32::MAX;
        for step in 10..=110 {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }
}
