#![warn(missing_docs)]
//! L3 coordinator: the training orchestrator.
//!
//! Per step:
//! 1. each data-parallel worker runs `grad_accum` microbatches through
//!    the grad artifact (its own shard of the deterministic corpus);
//! 2. gradients go through the pod-aware two-level collective
//!    ([`topology`]): deterministic intra-pod reduce-scatter →
//!    inter-pod exchange over pod leaders → intra-pod all-gather,
//!    with FP8 wire compression selectable per level
//!    (`collective_fp8_intra` / `collective_fp8_inter`, per-chunk
//!    pow2 auto-scales, FP8-LM-style). `pods = 1` is the flat
//!    collective, bit-identical to the plain tree reduce when
//!    compression is off;
//! 3. the global grad-norm clip factor is computed in Rust;
//! 4. each worker applies AdamW to the chunks it owns under the
//!    chunk-aligned ZeRO-1 owner map via the chunked `adam_*` artifact
//!    (its moment shard is the only copy, FP8-packed between steps per
//!    recipe) and params are all-gathered back into the replicated
//!    parameter buffer;
//! 5. the delayed-scaling manager ingests the step's amax report and
//!    emits next-step scales; the divergence detector watches the loss
//!    and overflow counters.
//!
//! The paper's contribution shows up in (5) + which artifact (1) runs.

pub mod allreduce;
pub mod divergence;
pub mod folding;
pub mod params;
pub mod runner;
pub mod schedule;
pub mod topology;
pub mod trainer;

pub use divergence::{DetectorState, DivergenceDetector};
pub use params::ParamStore;
pub use schedule::LrSchedule;
pub use topology::PodTopology;
pub use trainer::{StepOutcome, Trainer};
