#![warn(missing_docs)]
//! L3 coordinator: the training orchestrator.
//!
//! Per step (the default **bucketed overlapped pipeline**,
//! `overlap_comm = true`; `force_phased_step` runs the same stages as
//! strict sequential phases):
//! 1. each data-parallel worker runs `grad_accum` microbatches through
//!    the grad artifact (its own shard of the deterministic corpus),
//!    then streams its gradient, split into Adam-chunk-aligned
//!    `bucket_bytes` buckets ([`pipeline::BucketSchedule`]), to the
//!    comms thread;
//! 2. per bucket, gradients go through the pod-aware two-level
//!    collective ([`topology::hier_bucket_collective`]): deterministic
//!    intra-pod reduce-scatter → inter-pod exchange over pod leaders →
//!    intra-pod all-gather, with FP8 wire compression selectable per
//!    level (`collective_fp8_intra` / `collective_fp8_inter`,
//!    per-chunk pow2 auto-scales, FP8-LM-style) — running on a
//!    dedicated thread so bucket k's wire time hides behind bucket
//!    k+1's compute. `pods = 1` is the flat collective, bit-identical
//!    to the plain tree reduce when compression is off;
//! 3. the global grad-norm clip factor accumulates per landed bucket
//!    in Rust ([`pipeline::NormStream`], same f64 fold order as the
//!    whole-buffer norm);
//! 4. each worker applies AdamW to the chunks it owns under the
//!    chunk-aligned ZeRO-1 owner map via the chunked `adam_*` artifact
//!    (its moment shard is the only copy, FP8-packed between steps per
//!    recipe), starting per bucket as soon as it lands when the clip
//!    factor is provably 1, and params are all-gathered back into the
//!    replicated parameter buffer;
//! 5. the delayed-scaling manager ingests the step's amax report and
//!    emits next-step scales; the divergence detector watches the loss
//!    and overflow counters.
//!
//! Every schedule (serial / phased / overlapped, any worker count) is
//! bit-identical — bucket starts sit on the absolute Adam chunk grid,
//! so FP8 grids, reduce order and norm fold order never change.
//! The paper's contribution shows up in (5) + which artifact (1) runs.

pub mod allreduce;
pub mod divergence;
pub mod folding;
pub mod params;
pub mod pipeline;
pub mod runner;
pub mod schedule;
pub mod topology;
pub mod trainer;

pub use divergence::{DetectorState, DivergenceDetector};
pub use params::ParamStore;
pub use pipeline::{BucketSchedule, NormStream, PhaseTimers};
pub use schedule::LrSchedule;
pub use topology::PodTopology;
pub use trainer::{StepOutcome, Trainer};
