//! L3 coordinator: the training orchestrator.
//!
//! Per step:
//! 1. each data-parallel worker runs `grad_accum` microbatches through
//!    the grad artifact (its own shard of the deterministic corpus);
//! 2. gradients go through a deterministic reduce-scatter → all-gather
//!    collective (simulating the Gaudi2 pod's), optionally compressing
//!    both wire legs to FP8 with per-chunk pow2 auto-scales
//!    (`collective_fp8`, FP8-LM-style) — bit-identical to the plain
//!    tree reduce when off;
//! 3. the global grad-norm clip factor is computed in Rust;
//! 4. each worker applies AdamW to the chunks it owns under the
//!    chunk-aligned ZeRO-1 owner map via the chunked `adam_*` artifact
//!    (its moment shard is the only copy, FP8-packed between steps per
//!    recipe) and params are all-gathered back into the replicated
//!    parameter buffer;
//! 5. the delayed-scaling manager ingests the step's amax report and
//!    emits next-step scales; the divergence detector watches the loss
//!    and overflow counters.
//!
//! The paper's contribution shows up in (5) + which artifact (1) runs.

pub mod allreduce;
pub mod divergence;
pub mod folding;
pub mod params;
pub mod runner;
pub mod schedule;
pub mod trainer;

pub use divergence::{DetectorState, DivergenceDetector};
pub use params::ParamStore;
pub use schedule::LrSchedule;
pub use trainer::{StepOutcome, Trainer};
