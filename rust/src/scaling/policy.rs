//! Scale selection policies.
//!
//! * `Delayed` — TE-style: scale for step t is computed from the amax
//!   history of steps < t. This is the paper's (and production FP8's)
//!   default, and the mechanism SwiGLU outliers defeat.
//! * `JustInTime` — scale from the current step's amax (impractical on
//!   real hardware: needs a second pass over the tensor; modeled here
//!   as "history of length 1 applied retroactively" for ablations).

use crate::fp8::{compute_scale, Fp8Format};

use super::history::AmaxHistory;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    Delayed,
    JustInTime,
}

#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub mode: Mode,
    pub history_len: usize,
    /// headroom factor: scale targets fmt.max / (2^margin · amax)
    pub margin_pow2: i32,
}

impl Default for Policy {
    fn default() -> Self {
        Self { mode: Mode::Delayed, history_len: 16, margin_pow2: 0 }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleDecision {
    /// keep the previous scale (no history yet)
    Keep,
    Set(f32),
}

impl Policy {
    pub fn decide(&self, fmt: Fp8Format, history: &AmaxHistory) -> ScaleDecision {
        if history.is_empty() {
            return ScaleDecision::Keep;
        }
        let amax = match self.mode {
            Mode::Delayed => history.max(),
            Mode::JustInTime => history.max(), // caller feeds len-1 history
        };
        let mut s = compute_scale(fmt, amax);
        // apply margin as a pow2 shift (exact)
        if self.margin_pow2 > 0 {
            s /= crate::fp8::exp2i(self.margin_pow2);
        }
        ScaleDecision::Set(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::E4M3;

    #[test]
    fn empty_history_keeps_scale() {
        let p = Policy::default();
        assert_eq!(p.decide(E4M3, &AmaxHistory::new(4)), ScaleDecision::Keep);
    }

    #[test]
    fn margin_shifts_scale_down() {
        let mut h = AmaxHistory::new(4);
        h.push(1.0);
        let s0 = match Policy::default().decide(E4M3, &h) {
            ScaleDecision::Set(s) => s,
            _ => panic!(),
        };
        let s1 = match (Policy { margin_pow2: 2, ..Default::default() }).decide(E4M3, &h) {
            ScaleDecision::Set(s) => s,
            _ => panic!(),
        };
        assert_eq!(s1, s0 / 4.0);
    }
}
