//! Scale selection policies.
//!
//! * `Delayed` — TE-style: scale for step t is computed from the amax
//!   history of steps < t. This is the paper's (and production FP8's)
//!   default, and the mechanism SwiGLU outliers defeat.
//! * `JustInTime` — scale from the current step's amax (impractical on
//!   real hardware: needs a second pass over the tensor; modeled here
//!   as "history of length 1 applied retroactively" for ablations).

use crate::fp8::{compute_scale, Fp8Format};

use super::history::AmaxHistory;

/// When the amax that picks a scale was observed relative to the step
/// that uses the scale.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    /// Scale for step t comes from steps < t (production FP8; the
    /// paper's vulnerable-by-construction default).
    Delayed,
    /// Scale for step t comes from step t itself. Only reachable in
    /// ablations: the caller feeds a length-1 history containing the
    /// current amax.
    JustInTime,
}

/// Scale-selection policy for one training run.
///
/// Invariant: for any non-empty history, the selected scale `s`
/// satisfies `history.max() * s <= fmt.max()` — the policy never picks
/// a scale that would overflow the format on the values it has seen
/// (pinned by `prop_scaling_policy_covers_history`). A *fresh* spike
/// larger than the history max can still overflow; that gap is the
/// paper's instability mechanism, not a bug here.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    /// See [`Mode`].
    pub mode: Mode,
    /// Ring-buffer capacity of the per-site amax window. Shorter
    /// windows forget spikes faster (the campaign recovery backoff
    /// shrinks this; see `campaign::recovery`).
    pub history_len: usize,
    /// Headroom: the scale is divided by `2^margin_pow2` after the
    /// range fit, leaving that many binades of slack below the format
    /// max for fresh outliers. Applied as an exact pow2 shift.
    pub margin_pow2: i32,
}

impl Default for Policy {
    fn default() -> Self {
        Self { mode: Mode::Delayed, history_len: 16, margin_pow2: 0 }
    }
}

/// Outcome of one [`Policy::decide`] call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleDecision {
    /// Keep the previous scale (no history yet to decide from).
    Keep,
    /// Use this scale for the next step.
    Set(f32),
}

impl Policy {
    /// Pick the scale for a site from its amax history.
    ///
    /// Returns [`ScaleDecision::Keep`] on an empty history (cold
    /// start: the site stays at its previous scale until it reports a
    /// first amax); otherwise a pow2 scale that fits `history.max()`
    /// inside the format range with `2^margin_pow2` headroom.
    ///
    /// # Examples
    ///
    /// ```
    /// use fp8_trainer::scaling::{AmaxHistory, Policy, ScaleDecision};
    /// use fp8_trainer::fp8::E4M3;
    ///
    /// let mut h = AmaxHistory::new(4);
    /// h.push(1.0);
    /// // amax 1.0, E4M3 max 448 → largest pow2 scale ≤ 448 is 256
    /// assert_eq!(Policy::default().decide(E4M3, &h), ScaleDecision::Set(256.0));
    /// assert_eq!(
    ///     Policy::default().decide(E4M3, &AmaxHistory::new(4)),
    ///     ScaleDecision::Keep,
    /// );
    /// ```
    pub fn decide(&self, fmt: Fp8Format, history: &AmaxHistory) -> ScaleDecision {
        if history.is_empty() {
            return ScaleDecision::Keep;
        }
        let amax = match self.mode {
            Mode::Delayed => history.max(),
            Mode::JustInTime => history.max(), // caller feeds len-1 history
        };
        let mut s = compute_scale(fmt, amax);
        // apply margin as a pow2 shift (exact)
        if self.margin_pow2 > 0 {
            s /= crate::fp8::exp2i(self.margin_pow2);
        }
        ScaleDecision::Set(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::E4M3;

    #[test]
    fn empty_history_keeps_scale() {
        let p = Policy::default();
        assert_eq!(p.decide(E4M3, &AmaxHistory::new(4)), ScaleDecision::Keep);
    }

    #[test]
    fn margin_shifts_scale_down() {
        let mut h = AmaxHistory::new(4);
        h.push(1.0);
        let s0 = match Policy::default().decide(E4M3, &h) {
            ScaleDecision::Set(s) => s,
            _ => panic!(),
        };
        let s1 = match (Policy { margin_pow2: 2, ..Default::default() }).decide(E4M3, &h) {
            ScaleDecision::Set(s) => s,
            _ => panic!(),
        };
        assert_eq!(s1, s0 / 4.0);
    }
}
