//! Fixed-capacity amax ring buffer (one per quantization site).

#[derive(Clone, Debug)]
pub struct AmaxHistory {
    buf: Vec<f32>,
    head: usize,
    len: usize,
}

impl AmaxHistory {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { buf: vec![0.0; capacity], head: 0, len: 0 }
    }

    pub fn push(&mut self, amax: f32) {
        self.buf[self.head] = amax;
        self.head = (self.head + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
    }

    /// Max over the recorded window (0.0 if empty).
    pub fn max(&self) -> f32 {
        self.buf[..self.len].iter().fold(0.0f32, |a, &x| a.max(x))
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// The recorded window in push order, oldest → newest.
    ///
    /// Invariant (campaign snapshots depend on it): pushing the
    /// returned values, in order, into a fresh `AmaxHistory` of the
    /// same capacity yields a ring that behaves identically to this
    /// one under any further sequence of pushes — `max()`, `len()`,
    /// and eviction order all match. The absolute head position is
    /// deliberately *not* part of the observable state.
    pub fn ordered(&self) -> Vec<f32> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(|i| self.buf[(start + i) % cap]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_evicts_old_peaks() {
        let mut h = AmaxHistory::new(3);
        h.push(100.0);
        h.push(1.0);
        h.push(1.0);
        assert_eq!(h.max(), 100.0);
        h.push(1.0); // evicts the 100.0
        assert_eq!(h.max(), 1.0);
    }

    #[test]
    fn empty_max_is_zero() {
        assert_eq!(AmaxHistory::new(4).max(), 0.0);
    }

    #[test]
    fn len_saturates_at_capacity() {
        let mut h = AmaxHistory::new(2);
        for _ in 0..5 {
            h.push(1.0);
        }
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn ordered_is_oldest_to_newest() {
        let mut h = AmaxHistory::new(3);
        assert!(h.ordered().is_empty());
        h.push(1.0);
        h.push(2.0);
        assert_eq!(h.ordered(), vec![1.0, 2.0]);
        h.push(3.0);
        h.push(4.0); // evicts 1.0, head wrapped
        assert_eq!(h.ordered(), vec![2.0, 3.0, 4.0]);
        h.push(5.0);
        assert_eq!(h.ordered(), vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn ordered_restore_is_behaviorally_identical() {
        // push ordered() into a fresh ring, then feed both the same
        // tail — every observable must match at every point
        let mut a = AmaxHistory::new(4);
        for x in [9.0, 1.0, 7.0, 3.0, 5.0, 2.0] {
            a.push(x);
        }
        let mut b = AmaxHistory::new(a.capacity());
        for x in a.ordered() {
            b.push(x);
        }
        assert_eq!(a.max(), b.max());
        assert_eq!(a.len(), b.len());
        for x in [0.5, 8.0, 0.25, 0.125, 0.1] {
            a.push(x);
            b.push(x);
            assert_eq!(a.max().to_bits(), b.max().to_bits());
            assert_eq!(a.ordered(), b.ordered());
        }
    }
}
