//! Fixed-capacity amax ring buffer (one per quantization site).

#[derive(Clone, Debug)]
pub struct AmaxHistory {
    buf: Vec<f32>,
    head: usize,
    len: usize,
}

impl AmaxHistory {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { buf: vec![0.0; capacity], head: 0, len: 0 }
    }

    pub fn push(&mut self, amax: f32) {
        self.buf[self.head] = amax;
        self.head = (self.head + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
    }

    /// Max over the recorded window (0.0 if empty).
    pub fn max(&self) -> f32 {
        self.buf[..self.len].iter().fold(0.0f32, |a, &x| a.max(x))
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_evicts_old_peaks() {
        let mut h = AmaxHistory::new(3);
        h.push(100.0);
        h.push(1.0);
        h.push(1.0);
        assert_eq!(h.max(), 100.0);
        h.push(1.0); // evicts the 100.0
        assert_eq!(h.max(), 1.0);
    }

    #[test]
    fn empty_max_is_zero() {
        assert_eq!(AmaxHistory::new(4).max(), 0.0);
    }

    #[test]
    fn len_saturates_at_capacity() {
        let mut h = AmaxHistory::new(2);
        for _ in 0..5 {
            h.push(1.0);
        }
        assert_eq!(h.len(), 2);
    }
}
