//! The FP8 delayed-scaling state machine — the L3 half of the paper's
//! numerics. The grad artifact reports one amax per quantization site
//! per step; this module turns amax histories into the next step's
//! scales (TE-style delayed scaling with pow2 scales + margin), and is
//! exactly the component the paper shows being broken by SwiGLU
//! outliers: a fresh spike is invisible to the *current* scale, which
//! was chosen from the history.

pub mod history;
pub mod policy;

pub use history::AmaxHistory;
pub use policy::{Mode, Policy, ScaleDecision};

use crate::fp8::{Fp8Format, E4M3, E5M2};

/// The FP8 format a quantization site quantizes to, by site name:
/// gradient sites (`g_` prefix) take E5M2's range, everything else
/// (weights and activations) takes E4M3's precision — the paper's §3
/// operand split. Shared by [`ScaleManager::new`] and the tile-wise
/// GEMM engine's amax feed (`gemm::GemmEngine`) so the two layers can
/// never disagree about a site's format.
pub fn site_format_of(name: &str) -> Fp8Format {
    if name.starts_with("g_") {
        E5M2
    } else {
        E4M3
    }
}

/// Scale manager for one training run: a ring-buffer history and a
/// current scale per site.
pub struct ScaleManager {
    histories: Vec<AmaxHistory>,
    scales: Vec<f32>,
    site_fmts: Vec<Fp8Format>,
    policy: Policy,
    /// count of steps where an amax was non-finite (divergence signal)
    pub overflow_events: usize,
}

impl ScaleManager {
    /// `sites_per_layer` comes from the manifest; gradient sites (name
    /// starts with "g_") quantize to E5M2, the rest to E4M3.
    pub fn new(n_layers: usize, sites_per_layer: &[String], policy: Policy) -> Self {
        let n = n_layers * sites_per_layer.len();
        let mut site_fmts = Vec::with_capacity(n);
        for _ in 0..n_layers {
            for s in sites_per_layer {
                site_fmts.push(site_format_of(s));
            }
        }
        Self {
            histories: (0..n).map(|_| AmaxHistory::new(policy.history_len)).collect(),
            scales: vec![1.0; n],
            site_fmts,
            policy,
            overflow_events: 0,
        }
    }

    pub fn n_sites(&self) -> usize {
        self.scales.len()
    }

    /// Current scales vector (input to the grad artifact).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Ingest the amax vector reported by a step, then recompute every
    /// scale for the next step (delayed scaling).
    pub fn update(&mut self, amax: &[f32]) {
        assert_eq!(amax.len(), self.histories.len(), "amax arity mismatch");
        for (i, &a) in amax.iter().enumerate() {
            if !a.is_finite() {
                self.overflow_events += 1;
                // a non-finite amax poisons the history; record the
                // format max instead so the scale collapses safely
                self.histories[i].push(self.site_fmts[i].max());
                continue;
            }
            if a > 0.0 {
                self.histories[i].push(a);
            }
        }
        for i in 0..self.scales.len() {
            if let ScaleDecision::Set(s) =
                self.policy.decide(self.site_fmts[i], &self.histories[i])
            {
                self.scales[i] = s;
            }
        }
    }

    /// Peak amax over history for a site (monitoring / Fig. 1 data).
    pub fn site_peak(&self, idx: usize) -> f32 {
        self.histories[idx].max()
    }

    pub fn site_format(&self, idx: usize) -> Fp8Format {
        self.site_fmts[idx]
    }

    /// The active scale-selection policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Export the full delayed-scaling state for a campaign snapshot.
    ///
    /// Histories come out in push order (oldest → newest, see
    /// [`AmaxHistory::ordered`]); together with the scales vector and
    /// the overflow counter this is everything a bit-exact resume
    /// needs — the site formats and policy are re-derived from the
    /// manifest + config at restore time.
    pub fn export_state(&self) -> ScaleState {
        ScaleState {
            histories: self.histories.iter().map(|h| h.ordered()).collect(),
            scales: self.scales.clone(),
            overflow_events: self.overflow_events,
        }
    }

    /// Restore state captured by [`export_state`](Self::export_state).
    ///
    /// The manager must have been built for the same manifest and
    /// policy (site arity and ring capacity must match) — a mismatch
    /// is an error, not a silent truncation.
    pub fn restore_state(&mut self, st: &ScaleState) -> Result<(), String> {
        if st.histories.len() != self.histories.len() || st.scales.len() != self.scales.len() {
            return Err(format!(
                "scale state arity mismatch: snapshot has {} sites, manager has {}",
                st.histories.len(),
                self.histories.len()
            ));
        }
        for (i, vals) in st.histories.iter().enumerate() {
            let cap = self.histories[i].capacity();
            if vals.len() > cap {
                return Err(format!(
                    "site {i}: snapshot history has {} entries but ring capacity is {cap} \
                     (amax_history changed between save and resume?)",
                    vals.len()
                ));
            }
            let mut h = AmaxHistory::new(cap);
            for &a in vals {
                h.push(a);
            }
            self.histories[i] = h;
        }
        self.scales.copy_from_slice(&st.scales);
        self.overflow_events = st.overflow_events;
        Ok(())
    }

    /// Swap in a new policy mid-run (campaign divergence recovery).
    ///
    /// Rings are rebuilt at the new `history_len`, keeping only the
    /// *newest* entries when the window shrinks — exactly the "forget
    /// the stale pre-spike amaxes" move the recovery backoff wants.
    /// Scales are immediately re-decided from the surviving history so
    /// the very next step runs under the new margin.
    pub fn reconfigure(&mut self, policy: Policy) {
        for h in self.histories.iter_mut() {
            let vals = h.ordered();
            let keep = vals.len().min(policy.history_len);
            let mut nh = AmaxHistory::new(policy.history_len);
            for &a in &vals[vals.len() - keep..] {
                nh.push(a);
            }
            *h = nh;
        }
        self.policy = policy;
        for i in 0..self.scales.len() {
            if let ScaleDecision::Set(s) =
                self.policy.decide(self.site_fmts[i], &self.histories[i])
            {
                self.scales[i] = s;
            }
        }
    }
}

/// Serializable snapshot of a [`ScaleManager`]'s mutable state
/// (see [`ScaleManager::export_state`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ScaleState {
    /// per-site amax windows, oldest → newest
    pub histories: Vec<Vec<f32>>,
    /// per-site current scales (the next step's artifact input)
    pub scales: Vec<f32>,
    /// cumulative non-finite-amax count (divergence signal)
    pub overflow_events: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sites() -> Vec<String> {
        vec!["x_attn".into(), "w1".into(), "g_w1".into()]
    }

    #[test]
    fn formats_assigned_by_site_name() {
        let m = ScaleManager::new(2, &sites(), Policy::default());
        assert_eq!(m.site_format(0), E4M3);
        assert_eq!(m.site_format(2), E5M2);
        assert_eq!(m.site_format(5), E5M2);
    }

    #[test]
    fn scales_track_amax() {
        let mut m = ScaleManager::new(1, &sites(), Policy::default());
        m.update(&[1.0, 4.0, 0.01]);
        let s = m.scales().to_vec();
        // amax 1.0 with E4M3 max 448 -> scale 256 (pow2 <= 448)
        assert_eq!(s[0], 256.0);
        // amax 4.0 -> 64
        assert_eq!(s[1], 64.0);
        // E5M2 max 57344, amax 0.01 -> scale <= 5734400, pow2
        assert!(s[2] >= 2_097_152.0 && s[2] <= 4_194_304.0 * 2.0, "{}", s[2]);
    }

    #[test]
    fn delayed_semantics_use_history_max() {
        let mut m = ScaleManager::new(1, &sites(), Policy { history_len: 4, ..Default::default() });
        for _ in 0..4 {
            m.update(&[1.0, 1.0, 1.0]);
        }
        let s_before = m.scales()[0];
        // a single huge amax must shrink the scale on the NEXT step
        m.update(&[100.0, 1.0, 1.0]);
        assert!(m.scales()[0] < s_before);
        // ... and the old scale was what a spike in THIS step would have
        // been quantized with — the delayed-scaling vulnerability.
    }

    #[test]
    fn nonfinite_amax_counts_overflow() {
        let mut m = ScaleManager::new(1, &sites(), Policy::default());
        m.update(&[f32::NAN, 1.0, 1.0]);
        assert_eq!(m.overflow_events, 1);
        assert!(m.scales()[0] <= 1.0); // collapsed to format max
    }

    #[test]
    fn export_restore_roundtrip_is_bit_exact_forward() {
        let policy = Policy { history_len: 4, ..Default::default() };
        let mut a = ScaleManager::new(2, &sites(), policy);
        for k in 0..7 {
            let x = 1.0 + k as f32 * 0.37;
            a.update(&[x, 2.0 * x, 0.5 * x, x, x * x, 0.1]);
        }
        let st = a.export_state();
        let mut b = ScaleManager::new(2, &sites(), policy);
        b.restore_state(&st).unwrap();
        assert_eq!(b.scales(), a.scales());
        assert_eq!(b.overflow_events, a.overflow_events);
        // identical future evolution, bit for bit
        for k in 0..6 {
            let x = 0.3 + k as f32;
            let amax = [x, x, x, 2.0, 0.01, x];
            a.update(&amax);
            b.update(&amax);
            for (sa, sb) in a.scales().iter().zip(b.scales()) {
                assert_eq!(sa.to_bits(), sb.to_bits());
            }
        }
    }

    #[test]
    fn restore_rejects_arity_and_capacity_mismatch() {
        let mut m = ScaleManager::new(1, &sites(), Policy::default());
        let bad = ScaleState { histories: vec![vec![1.0]], scales: vec![1.0], overflow_events: 0 };
        assert!(m.restore_state(&bad).is_err(), "site arity mismatch must fail");
        let mut long = m.export_state();
        long.histories[0] = vec![1.0; 1000]; // > ring capacity
        assert!(m.restore_state(&long).is_err(), "oversized history must fail");
    }

    #[test]
    fn reconfigure_shrinks_window_and_redecides() {
        let mut m = ScaleManager::new(1, &sites(), Policy { history_len: 8, ..Default::default() });
        // old spike followed by small steady state
        m.update(&[100.0, 1.0, 1.0]);
        for _ in 0..5 {
            m.update(&[1.0, 1.0, 1.0]);
        }
        let spiky_scale = m.scales()[0]; // dominated by the 100.0
        m.reconfigure(Policy { history_len: 2, margin_pow2: 1, ..Default::default() });
        assert_eq!(m.policy().history_len, 2);
        // the spike fell out of the shrunken window → larger scale,
        // even with the extra margin bit
        assert!(m.scales()[0] > spiky_scale, "{} vs {spiky_scale}", m.scales()[0]);
        assert!(m.export_state().histories[0].len() <= 2);
    }
}
