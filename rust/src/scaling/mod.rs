//! The FP8 delayed-scaling state machine — the L3 half of the paper's
//! numerics. The grad artifact reports one amax per quantization site
//! per step; this module turns amax histories into the next step's
//! scales (TE-style delayed scaling with pow2 scales + margin), and is
//! exactly the component the paper shows being broken by SwiGLU
//! outliers: a fresh spike is invisible to the *current* scale, which
//! was chosen from the history.

pub mod history;
pub mod policy;

pub use history::AmaxHistory;
pub use policy::{Policy, ScaleDecision};

use crate::fp8::{Fp8Format, E4M3, E5M2};

/// Scale manager for one training run: a ring-buffer history and a
/// current scale per site.
pub struct ScaleManager {
    histories: Vec<AmaxHistory>,
    scales: Vec<f32>,
    site_fmts: Vec<Fp8Format>,
    policy: Policy,
    /// count of steps where an amax was non-finite (divergence signal)
    pub overflow_events: usize,
}

impl ScaleManager {
    /// `sites_per_layer` comes from the manifest; gradient sites (name
    /// starts with "g_") quantize to E5M2, the rest to E4M3.
    pub fn new(n_layers: usize, sites_per_layer: &[String], policy: Policy) -> Self {
        let n = n_layers * sites_per_layer.len();
        let mut site_fmts = Vec::with_capacity(n);
        for _ in 0..n_layers {
            for s in sites_per_layer {
                site_fmts.push(if s.starts_with("g_") { E5M2 } else { E4M3 });
            }
        }
        Self {
            histories: (0..n).map(|_| AmaxHistory::new(policy.history_len)).collect(),
            scales: vec![1.0; n],
            site_fmts,
            policy,
            overflow_events: 0,
        }
    }

    pub fn n_sites(&self) -> usize {
        self.scales.len()
    }

    /// Current scales vector (input to the grad artifact).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Ingest the amax vector reported by a step, then recompute every
    /// scale for the next step (delayed scaling).
    pub fn update(&mut self, amax: &[f32]) {
        assert_eq!(amax.len(), self.histories.len(), "amax arity mismatch");
        for (i, &a) in amax.iter().enumerate() {
            if !a.is_finite() {
                self.overflow_events += 1;
                // a non-finite amax poisons the history; record the
                // format max instead so the scale collapses safely
                self.histories[i].push(self.site_fmts[i].max());
                continue;
            }
            if a > 0.0 {
                self.histories[i].push(a);
            }
        }
        for i in 0..self.scales.len() {
            if let ScaleDecision::Set(s) =
                self.policy.decide(self.site_fmts[i], &self.histories[i])
            {
                self.scales[i] = s;
            }
        }
    }

    /// Peak amax over history for a site (monitoring / Fig. 1 data).
    pub fn site_peak(&self, idx: usize) -> f32 {
        self.histories[idx].max()
    }

    pub fn site_format(&self, idx: usize) -> Fp8Format {
        self.site_fmts[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sites() -> Vec<String> {
        vec!["x_attn".into(), "w1".into(), "g_w1".into()]
    }

    #[test]
    fn formats_assigned_by_site_name() {
        let m = ScaleManager::new(2, &sites(), Policy::default());
        assert_eq!(m.site_format(0), E4M3);
        assert_eq!(m.site_format(2), E5M2);
        assert_eq!(m.site_format(5), E5M2);
    }

    #[test]
    fn scales_track_amax() {
        let mut m = ScaleManager::new(1, &sites(), Policy::default());
        m.update(&[1.0, 4.0, 0.01]);
        let s = m.scales().to_vec();
        // amax 1.0 with E4M3 max 448 -> scale 256 (pow2 <= 448)
        assert_eq!(s[0], 256.0);
        // amax 4.0 -> 64
        assert_eq!(s[1], 64.0);
        // E5M2 max 57344, amax 0.01 -> scale <= 5734400, pow2
        assert!(s[2] >= 2_097_152.0 && s[2] <= 4_194_304.0 * 2.0, "{}", s[2]);
    }

    #[test]
    fn delayed_semantics_use_history_max() {
        let mut m = ScaleManager::new(1, &sites(), Policy { history_len: 4, ..Default::default() });
        for _ in 0..4 {
            m.update(&[1.0, 1.0, 1.0]);
        }
        let s_before = m.scales()[0];
        // a single huge amax must shrink the scale on the NEXT step
        m.update(&[100.0, 1.0, 1.0]);
        assert!(m.scales()[0] < s_before);
        // ... and the old scale was what a spike in THIS step would have
        // been quantized with — the delayed-scaling vulnerability.
    }

    #[test]
    fn nonfinite_amax_counts_overflow() {
        let mut m = ScaleManager::new(1, &sites(), Policy::default());
        m.update(&[f32::NAN, 1.0, 1.0]);
        assert_eq!(m.overflow_events, 1);
        assert!(m.scales()[0] <= 1.0); // collapsed to format max
    }
}
