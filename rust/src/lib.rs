//! # fp8-trainer — Scaling FP8 Training to Trillion-Token LLMs (ICLR 2025)
//!
//! Rust coordinator (L3) for the three-layer reproduction of Fishman et
//! al.'s FP8 training system. Python/JAX/Pallas exists only on the
//! build path (`python/compile` → `artifacts/*.hlo.txt`); this crate
//! owns everything at runtime:
//!
//! * [`runtime`] — PJRT CPU client: load HLO-text artifacts, execute.
//! * [`campaign`] — long-horizon runs: bit-exact checkpoint/resume,
//!   divergence auto-recovery, snapshot retention, machine-readable
//!   campaign journal (the `campaign` CLI drives it).
//! * [`scaling`] — the FP8 delayed-scaling state machine (per-tensor
//!   amax ring buffers → pow2 scales), the piece the paper's
//!   instability analysis targets.
//! * [`coordinator`] — training orchestration: data-parallel workers,
//!   the pod-aware two-level gradient collective (per-level FP8 wire
//!   compression), ZeRO-1 sharded optimizer, LR schedule, divergence
//!   detection.
//! * [`fp8`] — real u8 E4M3/E5M2 codecs (checkpoint/optimizer storage;
//!   the Table 4 memory story is measured bytes, not simulation).
//! * [`gemm`] — tile-wise-scaled FP8 matmul fwd/bwd (per-tile pow2
//!   amax scales, f32 accumulation in a pinned order) and the
//!   `fp8_gemm` recipe wiring that puts weights and grads on the tile
//!   grid every step (PAPER.md §4's compute path).
//! * [`data`] — deterministic synthetic Zipf-Markov corpus (the
//!   RedPajama stand-in; see DESIGN.md §Substitutions).
//! * [`analysis`] — w1/w2 channel correlation tracking, activation
//!   histograms (paper Figs. 1, 2, 7, 9).
//! * [`perfmodel`] — analytic Gaudi2/A6000 throughput models
//!   (Tables 3 and 5) and the Pallas kernel VMEM/MXU estimator.
//! * [`serving`] — the fourth workload layer (train / resume / observe
//!   → serve): snapshot → folded-FP8 model export gated on fold
//!   bit-exactness (paper §4.4), an FP8-resident inference engine, and
//!   a pure-std HTTP serving layer with batched generation (the
//!   `serve` CLI drives it).
//!
//! Offline-build note: only the `xla` crate's vendored closure is
//! available, so `util` re-implements the small substrates a normal
//! build would pull from crates.io (JSON, CSV, PRNG, TOML subset,
//! property testing, bench harness).

pub mod analysis;
pub mod campaign;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fp8;
pub mod gemm;
pub mod metrics;
pub mod optimizer;
pub mod perfmodel;
pub mod runtime;
pub mod scaling;
pub mod serving;
pub mod util;
