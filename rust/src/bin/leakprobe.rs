// Leak isolation probe for the PJRT execute path. Not shipped.
use fp8_trainer::runtime::{HostTensor, Runtime};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1048576.0
}

fn main() -> anyhow::Result<()> {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    let iters: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let rt = Runtime::new("artifacts")?;
    let art = rt.load("grad_tiny_bf16")?;
    let man = &art.manifest;
    let mut inputs: Vec<HostTensor> = man
        .params
        .iter()
        .map(|p| HostTensor::zeros(&p.shape))
        .collect();
    inputs.push(HostTensor::zeros(&[man.n_scales.max(1)]));
    inputs.push(HostTensor::from_i32(
        &[man.batch, man.seq_len + 1],
        vec![1; man.batch * (man.seq_len + 1)],
    ));

    println!("mode={mode} start rss={:.0}MB", rss_mb());
    match mode.as_str() {
        "literals" => {
            for i in 0..iters * 10 {
                for t in &inputs {
                    std::hint::black_box(t.to_literal()?);
                }
                if i % 500 == 0 {
                    println!("iter {i}: rss={:.0}MB", rss_mb());
                }
            }
        }
        _ => {
            for i in 0..iters {
                std::hint::black_box(art.run(&inputs)?);
                if i % 25 == 0 {
                    println!("iter {i}: rss={:.0}MB", rss_mb());
                }
            }
        }
    }
    println!("end rss={:.0}MB", rss_mb());
    Ok(())
}
