//! `serve` — inference serving on folded FP8 checkpoints.
//!
//! ```text
//! serve export --snapshot S.ckpt --out M.fp8m [--fmt e4m3|e5m2]
//!              [--probe-tokens N] [--probe-seed N]
//! serve run    --model M.fp8m [--addr A] [--port P] [--batch N]
//!              [--batch-wait-ms N] [--max-body-bytes N]
//!              [--max-new-tokens N] [--reference]
//! serve probe  --model M.fp8m --prompt 1,2,3 [--max-new N] [--reference]
//! ```
//!
//! `export` folds the Smooth-SwiGLU per-channel scales into a campaign
//! snapshot's w1/w3, quantizes to FP8, and writes a model artifact —
//! refusing unless the folded-FP8 forward is bit-identical to the
//! unfolded scaled reference on a deterministic probe (paper §4.4's
//! zero-cost-at-inference claim, proved per artifact). `run` serves the
//! artifact over HTTP (`/v1/generate`, `/v1/healthz`, `/v1/metrics`);
//! `probe` runs one in-process generation for smoke checks. The
//! `--reference` flag serves/probes in the unfolded scaled-reference
//! mode — its outputs must be bit-identical to the default folded mode
//! (the conformance suite pins this over a real socket).
//!
//! Bad usage exits 2; runtime failures (including export-gate
//! refusals) exit 1. Flags intentionally mirror the `serve_*` config
//! keys documented in docs/OPERATIONS.md §Serving.

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use fp8_trainer::serving::{
    export_snapshot, fmt_name, serve, Engine, ExportOptions, ServeConfig, ServeMode,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        usage();
        std::process::exit(2);
    };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "export" => export(rest),
        "run" => run(rest),
        "probe" => probe(rest),
        "--help" | "-h" | "help" => {
            usage();
            return;
        }
        other => {
            eprintln!("unknown subcommand '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "serve — inference serving on folded FP8 checkpoints\n\
         \n\
         serve export --snapshot S.ckpt --out M.fp8m [--fmt e4m3|e5m2]\n\
         \x20             [--probe-tokens N] [--probe-seed N]\n\
         serve run    --model M.fp8m [--addr A] [--port P] [--batch N]\n\
         \x20             [--batch-wait-ms N] [--max-body-bytes N]\n\
         \x20             [--max-new-tokens N] [--reference]\n\
         serve probe  --model M.fp8m --prompt 1,2,3 [--max-new N] [--reference]"
    );
}

/// `--flag value` pairs plus boolean `--reference`.
struct Flags {
    kv: Vec<(String, String)>,
    reference: bool,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut kv = Vec::new();
        let mut reference = false;
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--reference" {
                reference = true;
                i += 1;
                continue;
            }
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}' (flags are --name value)");
            };
            let Some(value) = args.get(i + 1) else {
                bail!("flag --{name} needs a value");
            };
            kv.push((name.to_string(), value.clone()));
            i += 2;
        }
        Ok(Self { kv, reference })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.kv.iter().rev().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    fn path(&self, name: &str) -> Result<PathBuf> {
        self.get(name).map(PathBuf::from).ok_or_else(|| anyhow!("--{name} is required"))
    }

    fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} must be an integer, got '{v}'")),
        }
    }

    fn reject_unknown(&self, known: &[&str]) -> Result<()> {
        for (n, _) in &self.kv {
            if !known.contains(&n.as_str()) {
                bail!("unknown flag --{n}");
            }
        }
        Ok(())
    }

    fn mode(&self) -> ServeMode {
        if self.reference { ServeMode::ScaledReference } else { ServeMode::Folded }
    }
}

fn export(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    flags.reject_unknown(&["snapshot", "out", "fmt", "probe-tokens", "probe-seed"])?;
    let snapshot = flags.path("snapshot")?;
    let out = flags.path("out")?;
    let mut opts = ExportOptions::default();
    if let Some(f) = flags.get("fmt") {
        opts.fmt = match f {
            "e4m3" => fp8_trainer::fp8::E4M3,
            "e5m2" => fp8_trainer::fp8::E5M2,
            other => bail!("--fmt must be 'e4m3' or 'e5m2', got '{other}'"),
        };
    }
    opts.probe_tokens = flags.usize_or("probe-tokens", opts.probe_tokens)?;
    opts.probe_seed = flags.usize_or("probe-seed", opts.probe_seed as usize)? as u64;
    let report = export_snapshot(&snapshot, &out, &opts)?;
    println!(
        "exported {} (step {}) as {} [{}]\n\
         fold gate: {} probe logits bit-identical (crc {:08x})\n\
         file {} bytes; resident FP8 {} bytes vs f32-equivalent {} bytes ({:.2}x)",
        report.size,
        report.step,
        out.display(),
        fmt_name(report.fmt),
        report.probe_len,
        report.probe_crc,
        report.file_bytes,
        report.resident_fp8_bytes,
        report.f32_equiv_bytes,
        report.f32_equiv_bytes as f64 / report.resident_fp8_bytes.max(1) as f64,
    );
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    flags.reject_unknown(&[
        "model",
        "addr",
        "port",
        "batch",
        "batch-wait-ms",
        "max-body-bytes",
        "max-new-tokens",
    ])?;
    let model = flags.path("model")?;
    let defaults = ServeConfig::default();
    let cfg = ServeConfig::from_keys(
        flags.get("addr").unwrap_or(&defaults.addr),
        flags.usize_or("port", defaults.port as usize)?,
        flags.usize_or("batch", defaults.batch)?,
        flags.usize_or("batch-wait-ms", defaults.batch_wait_ms as usize)?,
        flags.usize_or("max-body-bytes", defaults.max_body_bytes)?,
        flags.usize_or("max-new-tokens", defaults.max_new_tokens)?,
        fmt_name(defaults.fmt),
    )
    .map_err(|e| anyhow!(e))?;
    let engine = Engine::load(&model, flags.mode())?;
    let info = engine.info().clone();
    let handle = serve(engine, &cfg)?;
    println!(
        "serving {} (step {}, {}, mode {}) on http://{}/v1/generate",
        info.size,
        info.step,
        fmt_name(info.fmt),
        info.mode.as_str(),
        handle.addr()
    );
    // foreground process: the threads do the work; park until killed
    loop {
        std::thread::park();
    }
}

fn probe(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    flags.reject_unknown(&["model", "prompt", "max-new"])?;
    let model = flags.path("model")?;
    let prompt: Vec<usize> = flags
        .get("prompt")
        .ok_or_else(|| anyhow!("--prompt is required (comma-separated token ids)"))?
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("bad prompt token '{t}' (ids are integers)"))
        })
        .collect::<Result<_>>()?;
    let max_new = flags.usize_or("max-new", 8)?;
    let mut engine = Engine::load(&model, flags.mode())?;
    let results = engine.generate_batch(&[prompt], &[max_new], |_, _, _, _| {})?;
    let res = &results[0];
    println!("tokens: {:?}", res.tokens);
    println!("logits_crcs: {:?}", res.crcs.iter().map(|c| format!("{c:08x}")).collect::<Vec<_>>());
    Ok(())
}
