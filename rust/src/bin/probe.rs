// Toolchain probe: check which HLO feature files parse+compile+execute
// on xla_extension 0.5.1 CPU. Not part of the shipped library.
fn main() {
    let client = xla::PjRtClient::cpu().expect("client");
    for name in ["f8", "bitcast", "scan", "bf16"] {
        let path = format!("/tmp/probe_{name}.hlo.txt");
        let r = (|| -> Result<String, xla::Error> {
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let n: usize = if name == "scan" { 12 } else { 16 };
            let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.37 - 2.0).collect();
            let dims: &[usize] = if name == "scan" { &[3, 4] } else { &[4, 4] };
            let x = xla::Literal::vec1(&data)
                .reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?;
            let result = exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
            Ok(format!("{:?}", result.shape()?))
        })();
        match r {
            Ok(s) => println!("{name}: OK {s}"),
            Err(e) => println!("{name}: FAIL {e}"),
        }
    }
}
