//! `campaign` — the long-horizon training campaign CLI.
//!
//! ```text
//! campaign run     [--dir D] [--config FILE] [key=value ...]
//! campaign resume  [--dir D] [--config FILE] [--reshard] [key=value ...]
//! campaign status  [--dir D] [tail=N]
//! campaign inspect <snapshot.ckpt>
//! campaign fleet   <status|losses|divergences|metrics> [ROOT] [--json]
//! ```
//!
//! `run` starts a fresh campaign (snapshots + journal under `--dir`,
//! default `<out_dir>/campaign`); `resume` continues from the newest
//! snapshot bit-exactly; `status` summarizes the journal and snapshot
//! inventory without touching the runtime — the journal is *streamed*
//! event-at-a-time (`journal::stream`, O(1) memory however long the
//! campaign ran), unparseable-line counts are surfaced so a damaged
//! journal is visible, and `tail=N` appends the last N raw events
//! (seeked from the end, cost ∝ N not file size); `inspect` dumps one
//! snapshot's metadata and tensor table.
//!
//! `fleet` aggregates every campaign dir under ROOT (default `runs`,
//! any dir holding a `journal.jsonl`, a few levels deep) in one
//! streaming pass per journal: `status` is the per-campaign table,
//! `losses` the recent loss trails, `divergences` the trip log, and
//! `metrics` a Prometheus-style text exposition for dashboard
//! scraping; `--json` switches any mode to a machine-readable dump.
//! docs/OPERATIONS.md §Fleet operations is the runbook,
//! docs/JOURNAL.md the journal format specification.
//!
//! `resume --reshard` continues a campaign on a **changed physical
//! topology** (fewer/more `dp_workers`, rearranged `pods`, different
//! `bucket_bytes`): the snapshot's ZeRO-1 moment state is
//! re-partitioned deterministically, roundtrip-verified bit-exact, and
//! re-saved before the run continues — the loss curve is bit-identical
//! to the old topology's. A numerics change still refuses.
//!
//! Extra campaign-only key: `inject_divergence_at=N` (run/resume)
//! forces one divergence trip at step N — the §Campaigns recovery
//! drill (see rust/EXPERIMENTS.md).
//!
//! Session key `force_phased_step=true` runs the non-overlapped
//! (phased) step schedule for this process only — bit-identical to the
//! overlapped default, never recorded in snapshots or fingerprints.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use fp8_trainer::campaign::{self, fleet, journal, store, Campaign, ResumeOptions};
use fp8_trainer::checkpoint::Checkpoint;
use fp8_trainer::config::TrainConfig;
use fp8_trainer::runtime::Runtime;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir() -> PathBuf {
    std::env::var("FP8_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

struct Args {
    dir: Option<PathBuf>,
    config: Option<PathBuf>,
    overrides: Vec<(String, String)>,
    inject_divergence_at: Option<usize>,
    stop_after: Option<usize>,
    force_phased_step: Option<bool>,
    reshard: bool,
    tail: Option<usize>,
}

fn parse_args(args: &[String]) -> Result<Args> {
    let mut out = Args {
        dir: None,
        config: None,
        overrides: Vec::new(),
        inject_divergence_at: None,
        stop_after: None,
        force_phased_step: None,
        reshard: false,
        tail: None,
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--dir" => {
                out.dir = Some(PathBuf::from(
                    args.get(i + 1).ok_or_else(|| anyhow!("--dir needs a path"))?,
                ));
                i += 2;
            }
            "--config" => {
                out.config = Some(PathBuf::from(
                    args.get(i + 1).ok_or_else(|| anyhow!("--config needs a path"))?,
                ));
                i += 2;
            }
            "--reshard" => {
                out.reshard = true;
                i += 1;
            }
            // GNU equals forms — must match before the generic key=value
            // arm or they'd surface as "unknown config key '--dir'"
            flag if flag.starts_with("--dir=") => {
                out.dir = Some(PathBuf::from(&flag["--dir=".len()..]));
                i += 1;
            }
            flag if flag.starts_with("--config=") => {
                out.config = Some(PathBuf::from(&flag["--config=".len()..]));
                i += 1;
            }
            kv if kv.contains('=') => {
                let (k, v) = kv.split_once('=').unwrap();
                if k == "inject_divergence_at" {
                    out.inject_divergence_at =
                        Some(v.parse().map_err(|_| anyhow!("inject_divergence_at needs a step"))?);
                } else if k == "stop_after" {
                    out.stop_after =
                        Some(v.parse().map_err(|_| anyhow!("stop_after needs a step"))?);
                } else if k == "force_phased_step" {
                    out.force_phased_step = Some(
                        v.parse().map_err(|_| anyhow!("force_phased_step needs true/false"))?,
                    );
                } else if k == "tail" {
                    out.tail =
                        Some(v.parse().map_err(|_| anyhow!("tail needs an event count"))?);
                } else {
                    out.overrides.push((k.to_string(), v.to_string()));
                }
                i += 1;
            }
            other => return Err(anyhow!("unexpected argument '{other}'")),
        }
    }
    Ok(out)
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "run" | "resume" => {
            let a = parse_args(&argv[1..])?;
            let cfg = TrainConfig::load(a.config.as_deref(), &a.overrides).map_err(|e| anyhow!(e))?;
            let dir = a.dir.clone().unwrap_or_else(|| campaign::default_dir(&cfg));
            let rt = Arc::new(Runtime::new(artifacts_dir())?);
            if a.reshard && cmd != "resume" {
                return Err(anyhow!("--reshard only applies to `campaign resume`"));
            }
            let mut c = if cmd == "run" {
                Campaign::new(rt, cfg, &dir)?
            } else {
                Campaign::resume_opts(rt, cfg, &dir, ResumeOptions { reshard: a.reshard })?
            };
            c.inject_divergence_at = a.inject_divergence_at;
            c.stop_after = a.stop_after;
            if let Some(phased) = a.force_phased_step {
                c.trainer.force_phased_step = phased;
            }
            println!(
                "campaign {} in {} — {} / {} to step {}",
                cmd,
                dir.display(),
                c.trainer.cfg.size,
                c.trainer.cfg.recipe,
                c.trainer.cfg.steps
            );
            let report = c.run()?;
            let outcome = if report.completed {
                "completed"
            } else if report.paused {
                "paused (resumable — rerun with `campaign resume`)"
            } else {
                "ABORTED (recovery budget spent)"
            };
            println!(
                "{}: step {} | final loss {:.4} | {} recoveries | {} snapshots",
                outcome, report.final_step, report.final_loss, report.recoveries, report.snapshots
            );
            if !report.completed && !report.paused {
                // release <dir>/LOCK first: process::exit runs no
                // destructors, and an aborted campaign must stay
                // resumable without manual lock cleanup
                drop(c);
                std::process::exit(2);
            }
            Ok(())
        }
        "status" => {
            // honor the same config/overrides as run/resume so the
            // derived default dir points at the operator's campaign
            let a = parse_args(&argv[1..])?;
            let dir = match a.dir {
                Some(d) => d,
                None => {
                    let cfg = TrainConfig::load(a.config.as_deref(), &a.overrides)
                        .map_err(|e| anyhow!(e))?;
                    campaign::default_dir(&cfg)
                }
            };
            cmd_status(&dir, a.tail)
        }
        "inspect" => {
            let path = argv.get(1).ok_or_else(|| anyhow!("inspect needs a snapshot path"))?;
            cmd_inspect(PathBuf::from(path))
        }
        "fleet" => {
            let mut json = false;
            let mut rest: Vec<&str> = Vec::new();
            for a in &argv[1..] {
                if a == "--json" {
                    json = true;
                } else {
                    rest.push(a.as_str());
                }
            }
            let mode = rest.first().copied().ok_or_else(|| {
                anyhow!("fleet needs a mode: status | losses | divergences | metrics")
            })?;
            let root = PathBuf::from(rest.get(1).copied().unwrap_or("runs"));
            if rest.len() > 2 {
                return Err(anyhow!("unexpected fleet argument '{}'", rest[2]));
            }
            cmd_fleet(mode, &root, json)
        }
        _ => {
            println!(
                "campaign — long-horizon FP8 training with bit-exact resume and\n\
                 divergence auto-recovery\n\n\
                 usage:\n  campaign run     [--dir D] [--config FILE] [key=value ...]\n  \
                 campaign resume  [--dir D] [--config FILE] [--reshard] [key=value ...]\n  \
                 campaign status  [--dir D] [tail=N]\n  \
                 campaign inspect <snapshot.ckpt>\n  \
                 campaign fleet   <status|losses|divergences|metrics> [ROOT] [--json]\n\n\
                 campaign keys: snapshot_every=50 snapshot_keep=3 max_recoveries=4\n               \
                 recovery_margin_backoff=1 recovery_history_shrink=0.5\n\
                 session keys:  stop_after=N (pause + snapshot at step N, resumable)\n               \
                 force_phased_step=true (bit-identical non-overlapped schedule)\n               \
                 tail=N (status only: print the last N raw journal events)\n\
                 fleet:         aggregates every campaign dir under ROOT (default\n               \
                 `runs`) in one streaming pass per journal; `metrics`\n               \
                 emits a Prometheus-style text exposition, --json a\n               \
                 machine-readable dump (docs/OPERATIONS.md §Fleet operations)\n\
                 drill key:     inject_divergence_at=N\n\
                 elastic:       --reshard (resume only) continues on a changed\n               \
                 dp_workers/pods/bucket_bytes bit-exactly; grad_streams=/\n               \
                 stream_pods= pin the logical plan independently of the\n               \
                 physical workers\n\
                 train keys:    as `fp8-train train` (size=, recipe=, steps=, ...)"
            );
            Ok(())
        }
    }
}

fn cmd_status(dir: &std::path::Path, tail: Option<usize>) -> Result<()> {
    let journal_path = dir.join("journal.jsonl");
    let snaps = store::list_snapshots(dir.join("snapshots"))?;
    println!("campaign dir: {}", dir.display());
    if snaps.is_empty() {
        println!("snapshots: none");
    } else {
        println!("snapshots ({}):", snaps.len());
        for (step, path) in &snaps {
            let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
            println!("  step {:8}  {:.1} MiB  {}", step, bytes as f64 / 1048576.0, path.display());
        }
    }
    if !journal_path.is_file() {
        println!("journal: none");
        return Ok(());
    }
    // one streaming pass (journal::stream via the fleet aggregator) —
    // status stays O(1) memory however long the campaign ran
    let v = fleet::scan_campaign(dir)?;
    println!("phase: {}", v.phase().as_str());
    println!(
        "journal: {} events ({} snapshots, {} divergences, {} recoveries)",
        v.events,
        v.count("snapshot"),
        v.count("divergence"),
        v.count("recovery"),
    );
    if v.skipped_lines > 0 {
        println!(
            "  WARNING: {} unparseable line{} skipped — one torn tail per hard crash \
             is the expected worst case; more means damage (docs/JOURNAL.md)",
            v.skipped_lines,
            plural(v.skipped_lines)
        );
    }
    // topology history: every reshard in chronological order, so a
    // long elastic campaign's worker/pod trajectory is reconstructible
    // from `status` alone
    if !v.reshards.is_empty() {
        println!("topology history ({} reshard{}):", v.reshards.len(), plural(v.reshards.len()));
        for r in &v.reshards {
            println!("  step {:8}  {}  ->  {}", r.step, r.from, r.to);
        }
        if v.reshards_dropped > 0 {
            println!("  ... and {} earlier reshard(s) beyond the display cap", v.reshards_dropped);
        }
    }
    for kind in
        ["divergence", "recovery", "reshard", "lock_reclaimed", "tail_repaired", "abort", "complete"]
    {
        if let Some(e) = v.last_of.get(kind) {
            println!("  last {kind}: {}", e.to_string());
        }
    }
    if let Some(e) = &v.last_event {
        println!("  tail: {}", e.to_string());
    }
    if let Some(n) = tail {
        // seeked from the end of the file — cost ∝ n, not journal size
        let out = journal::tail(&journal_path, n)?;
        println!("last {} event{}:", out.events.len(), plural(out.events.len()));
        for e in &out.events {
            println!("  {}", e.to_string());
        }
    }
    Ok(())
}

fn cmd_fleet(mode: &str, root: &std::path::Path, json: bool) -> Result<()> {
    let view = fleet::scan_root(root)?;
    if json {
        println!("{}", view.to_json().to_string());
        return Ok(());
    }
    match mode {
        "status" => print!("{}", view.render_status()),
        "losses" => print!("{}", view.render_losses()),
        "divergences" => print!("{}", view.render_divergences()),
        "metrics" => print!("{}", view.render_prometheus()),
        other => {
            return Err(anyhow!(
                "unknown fleet mode '{other}' (expected status | losses | divergences | metrics)"
            ))
        }
    }
    Ok(())
}

fn plural(n: usize) -> &'static str {
    if n == 1 {
        ""
    } else {
        "s"
    }
}

fn cmd_inspect(path: PathBuf) -> Result<()> {
    let c = Checkpoint::load(&path)?;
    println!("{} ({:.1} MiB)", path.display(), c.file_bytes as f64 / 1048576.0);
    println!("meta: {}", c.meta.to_string());
    println!("{:32} {:>10} {:>10}", "tensor", "dtype", "elems");
    for (name, (dtype, data)) in &c.tensors {
        println!("{:32} {:>10} {:>10}", name, format!("{dtype:?}"), data.len());
    }
    Ok(())
}
