//! Tiny CSV writer for figure/table series (what the bench harness
//! emits so curves can be re-plotted outside the repo).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Self { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.w, "{}", line.join(","))
    }

    pub fn row_mixed(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        writeln!(self.w, "{}", values.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("fp8_csv_test");
        let path = dir.join("t.csv");
        {
            let mut c = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            c.row(&[1.0, 5.5]).unwrap();
            c.row(&[2.0, 5.25]).unwrap();
            c.flush().unwrap();
        }
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(s, "step,loss\n1,5.5\n2,5.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
