//! Miniature property-testing harness (offline: no proptest crate).
//!
//! Deterministic: every case derives from the run seed, and failures
//! report the case seed so they can be replayed exactly. Includes a
//! simple halving shrinker for numeric cases.

use super::prng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Self { cases: 256, seed: 0xf8f8_f8f8 }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Self { cases, ..Default::default() }
    }

    /// Run `f` over `cases` generated inputs; panics with the replay
    /// seed on the first failure.
    pub fn check<G, T, F>(&self, name: &str, mut gen: G, mut f: F)
    where
        G: FnMut(&mut Rng) -> T,
        T: std::fmt::Debug,
        F: FnMut(&T) -> bool,
    {
        let mut root = Rng::new(self.seed);
        for case in 0..self.cases {
            let case_seed = root.next_u64();
            let mut rng = Rng::new(case_seed);
            let input = gen(&mut rng);
            if !f(&input) {
                panic!(
                    "property '{name}' failed at case {case} (seed {case_seed:#x}):\n{input:#?}"
                );
            }
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    pub fn f32_any(rng: &mut Rng) -> f32 {
        // full bit-pattern coverage, including NaN/inf/subnormals
        f32::from_bits(rng.next_u64() as u32)
    }

    pub fn f32_finite(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * rng.uniform() as f32
    }

    pub fn vec_f32(rng: &mut Rng, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = 1 + rng.below(max_len as u64) as usize;
        (0..n).map(|_| f32_finite(rng, lo, hi)).collect()
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new(64).check("abs-nonneg", |r| gen::f32_finite(r, -5.0, 5.0), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn reports_failure() {
        Prop::new(8).check("always-false", |r| r.next_u64(), |_| false);
    }
}
