//! Micro-bench harness (offline: no criterion). Warmup + timed
//! iterations with mean / p50 / p95 reporting, criterion-ish output,
//! plus machine-readable `BENCH_*.json` emission so perf trajectories
//! survive across PRs (see benches/perf_hotpath.rs).

use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:40} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  {:>10.3?} min  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.min, self.iters
        );
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Record for a `BENCH_*.json` report. `extra` carries derived
    /// metrics (GB/s, speedup vs a baseline, worker count, …).
    pub fn to_json(&self, extra: Vec<(&str, Json)>) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean.as_secs_f64())),
            ("p50_s", Json::Num(self.p50.as_secs_f64())),
            ("p95_s", Json::Num(self.p95.as_secs_f64())),
            ("min_s", Json::Num(self.min.as_secs_f64())),
        ];
        fields.extend(extra);
        obj(fields)
    }
}

/// Write a `BENCH_*.json` perf report: top-level metadata + a
/// `benches` array of [`BenchResult::to_json`] records. Future PRs
/// diff these files to keep the perf trajectory machine-readable.
pub fn write_json_report<P: AsRef<Path>>(
    path: P,
    meta: Vec<(&str, Json)>,
    records: Vec<Json>,
) -> std::io::Result<()> {
    let mut fields = meta;
    fields.push(("benches", Json::Arr(records)));
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, obj(fields).to_string())
}

/// Time `f` for up to `max_iters` iterations or `budget` wall-clock,
/// whichever ends first, after `warmup` untimed runs.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    max_iters: usize,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::with_capacity(max_iters);
    for _ in 0..max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    samples.sort();
    let iters = samples.len();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        min: samples[0],
    }
}

/// Default profile for end-to-end step benches.
pub fn bench_quick<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench(name, 2, 30, Duration::from_secs(20), &mut f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_roundtrips() {
        let r = bench("x", 0, 5, Duration::from_secs(1), || {
            std::hint::black_box(1 + 1);
        });
        // per-process path: two concurrent test runs on one host must
        // not race on the write/remove of a shared fixture dir
        let dir = std::env::temp_dir().join(format!("fp8_bench_json_{}", std::process::id()));
        let path = dir.join("BENCH_test.json");
        write_json_report(
            &path,
            vec![("suite", Json::Str("t".into()))],
            vec![r.to_json(vec![("gbs", Json::Num(1.5))])],
        )
        .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.str_of("suite").unwrap(), "t");
        let b = &j.arr_of("benches").unwrap()[0];
        assert_eq!(b.str_of("name").unwrap(), "x");
        assert_eq!(b.f64_of("gbs").unwrap(), 1.5);
        assert!(b.f64_of("mean_s").unwrap() >= 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 1, 50, Duration::from_secs(1), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters > 10);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
    }
}
