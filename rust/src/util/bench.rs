//! Micro-bench harness (offline: no criterion). Warmup + timed
//! iterations with mean / p50 / p95 reporting, criterion-ish output.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:40} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  {:>10.3?} min  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.min, self.iters
        );
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Time `f` for up to `max_iters` iterations or `budget` wall-clock,
/// whichever ends first, after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, max_iters: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::with_capacity(max_iters);
    for _ in 0..max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    samples.sort();
    let iters = samples.len();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        min: samples[0],
    }
}

/// Default profile for end-to-end step benches.
pub fn bench_quick<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench(name, 2, 30, Duration::from_secs(20), &mut f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 1, 50, Duration::from_secs(1), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters > 10);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
    }
}
