//! Small from-scratch substrates (offline environment: no serde_json,
//! clap, rand, criterion or proptest on the vendored registry).

pub mod bench;
pub mod csv;
pub mod json;
pub mod par;
pub mod prng;
pub mod proptest;

/// IEEE CRC-32 (reflected, polynomial 0xEDB88320) — checkpoint
/// integrity footers. Table-driven, one lookup per byte; the 256-entry
/// table is built once per process.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut crc = i as u32;
            for _ in 0..8 {
                let mask = (crc & 1).wrapping_neg();
                crc = (crc >> 1) ^ (0xedb8_8320 & mask);
            }
            *slot = crc;
        }
        t
    });
    let mut crc = 0xffff_ffffu32;
    for &b in bytes {
        crc = (crc >> 8) ^ table[((crc ^ b as u32) & 0xff) as usize];
    }
    !crc
}

/// f32 <-> f16 (IEEE binary16) conversions for the FP16 master-weight
/// storage mode (Peng et al. 2023, adopted in Table 4).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;
    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if man != 0 { 0x200 } else { 0 };
    }
    exp -= 127 - 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        // subnormal (or zero): shift mantissa with implicit bit, RNE
        if exp < -10 {
            return sign;
        }
        let man = man | 0x80_0000;
        let shift = (14 - exp) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (man + half - 1 + ((man >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    // normal: RNE on the 13 dropped mantissa bits
    let half = 0x0fff + ((man >> 13) & 1);
    let man_r = man + half;
    if man_r & 0x80_0000 != 0 {
        // mantissa carry bumps the exponent
        let exp = exp + 1;
        if exp >= 0x1f {
            return sign | 0x7c00;
        }
        return sign | ((exp as u16) << 10);
    }
    sign | ((exp as u16) << 10) | ((man_r >> 13) as u16)
}

pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: normalize. man's top set bit at position p
            // (= 31 - lz) gives value man·2⁻²⁴ = 2^(p-24)·(man/2^p),
            // so the f32 biased exponent is p + 103 = 113 - shift.
            let shift = man.leading_zeros() - 21; // = 10 - p, p = top bit
            let exp32 = 113 - shift;
            let man32 = (man << shift) & 0x3ff; // drop the implicit bit
            sign | (exp32 << 23) | (man32 << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> bf16 (round-to-nearest-even) -> f32, for BF16 master storage.
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    if x.is_nan() {
        return x;
    }
    let half = 0x7fff + ((bits >> 16) & 1);
    f32::from_bits((bits + half) & 0xffff_0000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_exact_values() {
        // 2^-14 = min normal, 2^-24 = min subnormal (both exact)
        for &v in
            &[0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.103_515_6e-5, 5.960_464_5e-8]
        {
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            let rel = if v == 0.0 { (rt - v).abs() } else { ((rt - v) / v).abs() };
            assert!(rel < 1e-3, "v={v} rt={rt}");
        }
    }

    #[test]
    fn f16_overflow_is_inf() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e30)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(-1e30)).is_infinite());
    }

    #[test]
    fn f16_nan() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_rne_halfway() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1 + 2^-10 -> even (1.0)
        let v = 1.0 + 2f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), 1.0);
        // 1 + 3*2^-11 halfway -> rounds up to even (1 + 2^-9... check monotone)
        let v2 = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v2)), 1.0 + 2.0 * 2f32.powi(-10));
    }

    #[test]
    fn bf16_round_matches_truncation_grid() {
        for &v in &[1.0f32, 3.14159, -2.71828, 1e-20, 1e20] {
            let r = bf16_round(v);
            assert_eq!(r.to_bits() & 0xffff, 0, "mantissa must be 7 bits");
            assert!(((r - v) / v).abs() < 1.0 / 128.0);
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // the canonical IEEE check value plus edge cases
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xe8b7_be43);
        // sensitivity: one flipped bit changes the sum
        assert_ne!(crc32(b"123456789"), crc32(b"123456788"));
    }
}
