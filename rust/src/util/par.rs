//! Shared scoped-thread fan-out for the hot paths (codec, collective,
//! norms). One module owns the threshold / thread-cap / span-dealing
//! policy so the parallel paths cannot silently diverge from each
//! other — and every helper here is bit-deterministic by construction:
//! work is split at fixed positions and results land at fixed indices,
//! so thread scheduling never changes an output.

use std::sync::OnceLock;

/// Below this many elements the helpers stay single-threaded —
/// thread spawn (~10µs) would dominate the work.
pub const PAR_THRESHOLD: usize = 1 << 16;

/// Default chunk granularity for partial-based reductions
/// ([`par_partials`] callers that don't carry their own semantic
/// chunk size). Purely a scheduling constant for elementwise ops.
pub const PAR_CHUNK: usize = 1 << 16;

/// Worker cap for the scoped pools. The bulk codec and the collective
/// saturate memory bandwidth quickly; more than 8 lanes just adds
/// coherence traffic (see rust/EXPERIMENTS.md §Perf).
pub fn max_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8)
    })
}

/// Run `f` over parallel spans of `(inp, out)` above the size
/// threshold; single-threaded below it. `f` must be elementwise (it
/// receives matching subslices at matching offsets), which makes the
/// fan-out bit-deterministic by construction.
pub fn par_zip<I: Sync, O: Send>(inp: &[I], out: &mut [O], f: impl Fn(&[I], &mut [O]) + Sync) {
    debug_assert_eq!(inp.len(), out.len());
    let n = out.len();
    let threads = if n < PAR_THRESHOLD {
        1
    } else {
        max_threads().min(n.div_ceil(PAR_CHUNK)).max(1)
    };
    if threads <= 1 {
        f(inp, out);
        return;
    }
    let per = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        // keep one span for the calling thread: spawning `threads`
        // workers while this thread blocks would waste a spawn and
        // idle a core on every hot-path call
        let mut spans = inp.chunks(per).zip(out.chunks_mut(per));
        let inline = spans.next();
        for (i_span, o_span) in spans {
            s.spawn(move || f(i_span, o_span));
        }
        if let Some((i_span, o_span)) = inline {
            f(i_span, o_span);
        }
    });
}

/// Map fixed `chunk`-sized runs of `items` to partial results, in
/// parallel above the threshold. The partial at index `i` is always
/// `f(items[i*chunk .. (i+1)*chunk])` no matter how many threads ran,
/// so a caller's fold over the returned vec has a schedule-independent
/// — and, for a fixed `chunk`, fully defined — reduction order.
pub fn par_partials<T: Sync, A: Default + Clone + Send>(
    items: &[T],
    chunk: usize,
    f: impl Fn(&[T]) -> A + Sync,
) -> Vec<A> {
    assert!(chunk > 0, "partial chunk size must be positive");
    let n_chunks = items.len().div_ceil(chunk);
    let mut partials = vec![A::default(); n_chunks];
    let threads = if items.len() < PAR_THRESHOLD {
        1
    } else {
        max_threads().min(n_chunks).max(1)
    };
    if threads <= 1 {
        for (p, c) in partials.iter_mut().zip(items.chunks(chunk)) {
            *p = f(c);
        }
        return partials;
    }
    // deal whole chunks to threads in contiguous runs so each partial
    // lands at its chunk index
    let per = n_chunks.div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        let mut spans = partials.chunks_mut(per).zip(items.chunks(per * chunk));
        let inline = spans.next(); // calling thread takes one span
        for (p_span, i_span) in spans {
            s.spawn(move || {
                for (p, c) in p_span.iter_mut().zip(i_span.chunks(chunk)) {
                    *p = f(c);
                }
            });
        }
        if let Some((p_span, i_span)) = inline {
            for (p, c) in p_span.iter_mut().zip(i_span.chunks(chunk)) {
                *p = f(c);
            }
        }
    });
    partials
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_zip_matches_serial_across_threshold() {
        for n in [0usize, 5, PAR_THRESHOLD - 1, PAR_THRESHOLD + 12345] {
            let inp: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
            let mut out = vec![0.0f32; n];
            par_zip(&inp, &mut out, |i, o| {
                for (d, &x) in o.iter_mut().zip(i) {
                    *d = x * 2.0;
                }
            });
            assert!(out.iter().zip(&inp).all(|(&o, &i)| o == i * 2.0), "n={n}");
        }
    }

    #[test]
    fn par_partials_land_at_chunk_index() {
        // big enough to go parallel; values encode their position so a
        // misplaced partial is visible
        let n = PAR_THRESHOLD * 3 + 777;
        let items: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let chunk = 1000;
        let got = par_partials(&items, chunk, |c| c.iter().sum::<f64>());
        let want: Vec<f64> = items.chunks(chunk).map(|c| c.iter().sum()).collect();
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "partial {i}");
        }
    }

    #[test]
    fn par_partials_empty_and_ragged() {
        assert!(par_partials(&[] as &[f32], 64, |c| c.len()).is_empty());
        let got = par_partials(&[1.0f32; 130], 64, |c| c.len());
        assert_eq!(got, vec![64, 64, 2]);
    }
}
