//! Minimal JSON parser + emitter (offline build: no serde_json).
//!
//! Supports the full JSON grammar the artifact manifests and metrics
//! sinks need: objects, arrays, strings (with escapes), numbers, bools,
//! null. Not performance-critical — manifests are a few KiB, parsed
//! once at startup.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn usize_of(&self, key: &str) -> Result<usize, String> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| format!("manifest: missing/invalid usize field '{key}'"))
    }

    pub fn f64_of(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("manifest: missing/invalid number field '{key}'"))
    }

    pub fn str_of(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("manifest: missing/invalid string field '{key}'"))
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Json], String> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| format!("manifest: missing/invalid array field '{key}'"))
    }

    // -- emitter ---------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for JSONL metric records.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn peek(&self) -> Result<u8, String> {
        self.b.get(self.i).copied().ok_or_else(|| "unexpected end of input".into())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("expected '{word}' at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1; // '{'
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.peek()? != b':' {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(format!("expected ',' or '}}', got '{}'", c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // '['
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => return Err(format!("expected ',' or ']', got '{}'", c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.peek()? != b'"' {
            return Err(format!("expected string at byte {}", self.i));
        }
        self.i += 1;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            // surrogate pairs: manifests never emit them; map to U+FFFD
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: copy the full sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err("truncated utf-8".into());
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end]).map_err(|_| "bad utf-8")?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{s}'"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"kind":"grad","batch":8,"params":[{"name":"embed","shape":[512,128],"init_std":0.02}],"nested":{"a":[1,2.5,-3e2],"b":true,"c":null}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.str_of("kind").unwrap(), "grad");
        assert_eq!(j.usize_of("batch").unwrap(), 8);
        let p = &j.arr_of("params").unwrap()[0];
        assert_eq!(p.str_of("name").unwrap(), "embed");
        assert_eq!(p.arr_of("shape").unwrap()[1].as_usize().unwrap(), 128);
        assert_eq!(j.get("nested").unwrap().arr_of("a").unwrap()[2].as_f64().unwrap(), -300.0);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"s":"a\"b\\c\nd","arr":[],"obj":{},"n":-1.25e-3,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode() {
        let j = Json::parse(r#"{"k":"héllo é"}"#).unwrap();
        assert_eq!(j.str_of("k").unwrap(), "héllo é");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_emit_clean() {
        assert_eq!(Json::Num(8.0).to_string(), "8");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
