//! Deterministic PRNG (splitmix64 + xoshiro256**) — the repo's single
//! source of randomness: parameter init, the synthetic corpus, property
//! tests. Everything is reproducible from a u64 seed.

/// splitmix64: seeds the main generator and provides cheap stateless
/// stream splitting (worker shards, per-tensor init streams).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 2^256 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. per worker / per tensor).
    pub fn split(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xa076_1d64_78bd_642f);
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method.
        let mut m = (self.next_u64() as u128) * (n as u128);
        if (m as u64) < n {
            let t = n.wrapping_neg() % n;
            while (m as u64) < t {
                m = (self.next_u64() as u128) * (n as u128);
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        // no spare caching: keeps `split` semantics simple & deterministic
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with N(0, std^2) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = (self.normal() as f32) * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }
}
