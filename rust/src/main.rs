//! fp8-trainer CLI — the launcher.
//!
//! ```text
//! fp8-trainer train [--config FILE] [key=value ...]
//! fp8-trainer eval  [--config FILE] [key=value ...]
//! fp8-trainer tables            # analytic Tables 3/5 + memory Table 4
//! fp8-trainer artifacts         # list loadable artifacts
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::metrics::JsonlSink;
use fp8_trainer::perfmodel::{throughput_table, Workload, A6000_ADA, GAUDI2};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir() -> PathBuf {
    std::env::var("FP8_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

fn parse_args(args: &[String]) -> Result<(Option<PathBuf>, Vec<(String, String)>)> {
    let mut config = None;
    let mut overrides = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                config = Some(PathBuf::from(
                    args.get(i + 1).ok_or_else(|| anyhow!("--config needs a path"))?,
                ));
                i += 2;
            }
            kv if kv.contains('=') => {
                let (k, v) = kv.split_once('=').unwrap();
                overrides.push((k.to_string(), v.to_string()));
                i += 1;
            }
            other => return Err(anyhow!("unexpected argument '{other}'")),
        }
    }
    Ok((config, overrides))
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => {
            let (config, overrides) = parse_args(&args[1..])?;
            let cfg = TrainConfig::load(config.as_deref(), &overrides).map_err(|e| anyhow!(e))?;
            cmd_train(cfg)
        }
        "eval" => {
            let (config, overrides) = parse_args(&args[1..])?;
            let cfg = TrainConfig::load(config.as_deref(), &overrides).map_err(|e| anyhow!(e))?;
            cmd_eval(cfg)
        }
        "tables" => cmd_tables(),
        "analyze" => {
            // fp8-trainer analyze <run-dir> [out.csv]
            let dir = args.get(1).ok_or_else(|| anyhow!("analyze needs a run dir"))?;
            let out = args
                .get(2)
                .cloned()
                .unwrap_or_else(|| format!("{dir}/weight_report.csv"));
            let snaps = fp8_trainer::analysis::analyze_run(
                std::path::Path::new(dir),
                std::path::Path::new(&out),
            )?;
            println!("{:>8} {:>6} {:>8} {:>9} {:>9} {:>8} {:>10}", "step",
                     "layer", "channel", "norm1", "norm2", "cosine", "n_aligned");
            for s in &snaps {
                println!(
                    "{:>8} {:>6} {:>8} {:>9.3} {:>9.3} {:>8.3} {:>10}",
                    s.step, s.layer, s.top.channel, s.top.norm1, s.top.norm2,
                    s.top.cosine, s.n_aligned
                );
            }
            println!("report at {out}");
            Ok(())
        }
        "artifacts" => {
            let rt = Runtime::new(artifacts_dir())?;
            for name in rt.available() {
                println!("{name}");
            }
            Ok(())
        }
        _ => {
            println!(
                "fp8-trainer — FP8 LLM training coordinator (ICLR 2025 reproduction)\n\n\
                 usage:\n  fp8-trainer train [--config FILE] [key=value ...]\n  \
                 fp8-trainer eval  [--config FILE] [key=value ...]\n  \
                 fp8-trainer tables\n  fp8-trainer artifacts\n\n\
                 common keys: size=s1m recipe=fp8_full steps=1000 lr=2.5e-4\n             \
                 dp_workers=8 pods=2 (two-level collective; docs/OPERATIONS.md has all keys)\n\
                 recipes: bf16 bf16_smooth fp8 fp8_noq3 fp8_smooth fp8_full\n         \
                 fp8_adam_<m>_<v> gelu_fp8 gelu_bf16\n\n\
                 long-horizon runs (bit-exact resume, divergence auto-recovery):\n  \
                 use the `campaign` binary — campaign run/resume/status/inspect"
            );
            Ok(())
        }
    }
}

fn cmd_train(cfg: TrainConfig) -> Result<()> {
    let rt = Arc::new(Runtime::new(artifacts_dir())?);
    let mut t = Trainer::new(rt, cfg.clone())?;
    let out_dir = PathBuf::from(&cfg.out_dir);
    std::fs::create_dir_all(&out_dir)?;
    let mut sink = JsonlSink::create(out_dir.join("metrics.jsonl"))?;
    sink.record(vec![("config", cfg.to_json())])?;

    println!(
        "training {} / {} for {} steps ({} params, {} tokens/step)",
        cfg.size,
        cfg.recipe,
        cfg.steps,
        t.params.total_elems(),
        t.tokens_per_step()
    );
    for _ in 0..cfg.steps {
        let o = t.step()?;
        if o.step % cfg.log_every == 0 || o.step + 1 == cfg.steps {
            println!(
                "step {:5}  loss {:.4}  gnorm {:.3}  lr {:.2e}  {:.1} tok/s  verdict {:?}",
                o.step, o.loss, o.grad_norm, o.lr, o.stats.tokens_per_s, o.verdict
            );
            let max_swiglu = o.monitor.iter().map(|m| m[0]).fold(0.0f32, f32::max);
            sink.record(vec![
                ("step", Json::Num(o.step as f64)),
                ("loss", Json::Num(o.loss as f64)),
                ("grad_norm", Json::Num(o.grad_norm as f64)),
                ("lr", Json::Num(o.lr as f64)),
                ("tokens_per_s", Json::Num(o.stats.tokens_per_s)),
                ("swiglu_amax", Json::Num(max_swiglu as f64)),
            ])?;
        }
        if cfg.ckpt_every > 0 && (o.step + 1) % cfg.ckpt_every == 0 {
            save_checkpoint(&t, &out_dir, o.step + 1)?;
        }
    }
    sink.flush()?;
    save_checkpoint(&t, &out_dir, cfg.steps)?;
    println!("done in {:.1}s — metrics at {}", t.wall_s(), out_dir.display());
    Ok(())
}

fn save_checkpoint(t: &Trainer, out_dir: &std::path::Path, step: usize) -> Result<()> {
    use fp8_trainer::checkpoint::{Dtype, Writer};
    let rc = t.cfg.recipe_config();
    let master = Dtype::from_name(&rc.master_dtype)?;
    let m_dt = Dtype::from_name(if rc.m_fmt == "fp32" { "f32" } else { &rc.m_fmt })?;
    let v_dt = Dtype::from_name(if rc.v_fmt == "fp32" { "f32" } else { &rc.v_fmt })?;
    let meta = fp8_trainer::util::json::obj(vec![
        ("step", Json::Num(step as f64)),
        ("recipe", Json::Str(t.cfg.recipe.clone())),
        ("size", Json::Str(t.cfg.size.clone())),
    ]);
    let mut w = Writer::new(&meta);
    for (spec, tensor) in t.params.specs.iter().zip(&t.params.tensors) {
        w.tensor(&spec.name, master, tensor.f32s());
    }
    let (m, v) = t.moments_flat(); // gather the ZeRO-1 shards
    w.tensor("adam.m", m_dt, &m);
    w.tensor("adam.v", v_dt, &v);
    let path = out_dir.join(format!("step{step:06}.ckpt"));
    let bytes = w.finish(&path)?;
    println!("checkpoint {} ({:.1} MiB)", path.display(), bytes as f64 / 1048576.0);
    Ok(())
}

fn cmd_eval(cfg: TrainConfig) -> Result<()> {
    let rt = Arc::new(Runtime::new(artifacts_dir())?);
    let t = Trainer::new(rt, cfg.clone())?;
    let rc = cfg.recipe_config();
    let (ppl, acc) = t.eval(&rc.name, 8)?;
    println!("{}/{}: held-out ppl {:.3}, next-token acc {:.4}", cfg.size, cfg.recipe, ppl, acc);
    Ok(())
}

fn cmd_tables() -> Result<()> {
    let w = Workload::llama7b();
    for dev in [&GAUDI2, &A6000_ADA] {
        println!("\nThroughput model — {} (paper Tables 3/5 shape):", dev.name);
        println!(
            "{:34} {:>12} {:>10} {:>8}  status",
            "configuration", "samples/s", "speedup", "TFLOPS"
        );
        for row in throughput_table(dev, &w, 8.0) {
            println!(
                "{:34} {:>12.2} {:>9.1}% {:>8.0}  {}",
                row.config.label(),
                row.throughput,
                row.speedup_pct,
                row.tflops,
                if row.converges { "converge" } else { "DIVERGE" }
            );
        }
    }
    Ok(())
}
