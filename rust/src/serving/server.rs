//! Pure-std HTTP/1.1 serving layer over the FP8 [`Engine`].
//!
//! No new dependencies: a [`std::net::TcpListener`] acceptor, a
//! hand-rolled request parser with bounded header/body sizes (typed
//! refusals, mirroring the journal stream's `OversizedLine` — see
//! [`OversizedBody`]), a typed JSON API, and a batching queue: handler
//! threads enqueue jobs, one batcher thread owns the engine, collects
//! up to `serve_batch` requests (waiting at most `serve_batch_wait_ms`
//! after the first), runs **one** batched forward per decode step, and
//! fans results back out per request. Because the engine's batched
//! forward is bit-identical to serial (sequences never mix), batching
//! is invisible to clients except in latency — the conformance suite
//! pins exactly that.
//!
//! Endpoints:
//! * `POST /v1/generate` — `{"prompt":[ids], "max_new":n, "stream":bool}`;
//!   non-streaming returns `{"tokens", "logits_crcs", "model"}`;
//!   streaming returns chunked transfer encoding, one JSON line per
//!   token and a final `{"done":true, ...}` summary line.
//! * `GET /v1/healthz` — model identity + residency.
//! * `GET /v1/metrics` — Prometheus text exposition (label escaping
//!   shared with [`crate::campaign::fleet::prom_escape`]).
//!
//! Malformed or oversized requests get typed 4xx JSON refusals
//! (`{"error", "detail", "status"}`) — never a panic, never a dropped
//! connection without a status line.

use std::fmt;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::campaign::fleet::prom_escape;
use crate::fp8::{Fp8Format, E4M3, E5M2};
use crate::serving::engine::{fmt_name, Engine, GenResult, ModelInfo};
use crate::util::json::{obj, Json};

/// Cap on the request head (request line + headers). Refused with 431.
const MAX_HEADER_BYTES: usize = 8192;
/// How long a handler waits for its generation result before giving up.
const RESULT_TIMEOUT: Duration = Duration::from_secs(120);
/// Socket read timeout (slow-loris bound).
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Validated serving configuration (the `serve_*` config keys).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// bind address (`serve_addr`)
    pub addr: String,
    /// bind port; 0 = ephemeral (`serve_port`)
    pub port: u16,
    /// max requests coalesced into one batched forward (`serve_batch`)
    pub batch: usize,
    /// max wait for the batch to fill after the first request arrives
    /// (`serve_batch_wait_ms`)
    pub batch_wait_ms: u64,
    /// request-body byte cap — exceeding it is a typed 413
    /// (`serve_max_body_bytes`)
    pub max_body_bytes: usize,
    /// server-side cap on tokens generated per request
    /// (`serve_max_new_tokens`)
    pub max_new_tokens: usize,
    /// export quantization format (`serve_fmt`)
    pub fmt: Fp8Format,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1".into(),
            port: 0,
            batch: 8,
            batch_wait_ms: 5,
            max_body_bytes: 1_048_576,
            max_new_tokens: 64,
            fmt: E4M3,
        }
    }
}

impl ServeConfig {
    /// Validate raw config-key values into a [`ServeConfig`] (the
    /// loader/ctor gate — errors name the offending `serve_*` key).
    pub fn from_keys(
        addr: &str,
        port: usize,
        batch: usize,
        batch_wait_ms: usize,
        max_body_bytes: usize,
        max_new_tokens: usize,
        fmt: &str,
    ) -> Result<Self, String> {
        if addr.is_empty() {
            return Err("serve_addr must be a non-empty bind address".into());
        }
        if port > u16::MAX as usize {
            return Err(format!("serve_port must be <= 65535 (got {port})"));
        }
        if batch == 0 {
            return Err("serve_batch must be >= 1".into());
        }
        if max_body_bytes == 0 {
            return Err("serve_max_body_bytes must be >= 1".into());
        }
        if max_new_tokens == 0 {
            return Err("serve_max_new_tokens must be >= 1".into());
        }
        let fmt = match fmt {
            "e4m3" => E4M3,
            "e5m2" => E5M2,
            other => {
                return Err(format!("serve_fmt must be 'e4m3' or 'e5m2' (got '{other}')"))
            }
        };
        Ok(Self {
            addr: addr.to_string(),
            port: port as u16,
            batch,
            batch_wait_ms: batch_wait_ms as u64,
            max_body_bytes,
            max_new_tokens,
            fmt,
        })
    }
}

/// Typed refusal for a request body larger than `serve_max_body_bytes`
/// (the serving twin of the journal stream's `OversizedLine`): the
/// declared size and the limit it broke, surfaced as an HTTP 413.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OversizedBody {
    /// declared (or observed lower-bound) body size in bytes
    pub len_at_least: usize,
    /// the `serve_max_body_bytes` cap that was exceeded
    pub limit: usize,
}

impl fmt::Display for OversizedBody {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "request body of {}+ bytes exceeds serve_max_body_bytes = {}",
            self.len_at_least, self.limit
        )
    }
}

impl std::error::Error for OversizedBody {}

/// Serving counters, exposed at `/v1/metrics`.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// every accepted connection that produced a request
    pub requests_total: AtomicU64,
    /// requests answered with a 4xx typed refusal
    pub refusals_total: AtomicU64,
    /// batched forwards executed
    pub batches_total: AtomicU64,
    /// tokens generated across all requests
    pub generated_tokens_total: AtomicU64,
    /// fill of the most recent batch (gauge)
    pub batch_last_fill: AtomicU64,
}

impl ServeMetrics {
    /// Prometheus text exposition of the serving metrics plus model
    /// identity/residency gauges.
    pub fn render(&self, info: &ModelInfo) -> String {
        let mut out = String::new();
        let counters = [
            ("fp8_serve_requests_total", "Requests received.", &self.requests_total),
            ("fp8_serve_refusals_total", "Typed 4xx refusals.", &self.refusals_total),
            ("fp8_serve_batches_total", "Batched forwards executed.", &self.batches_total),
            (
                "fp8_serve_generated_tokens_total",
                "Tokens generated across all requests.",
                &self.generated_tokens_total,
            ),
        ];
        for (name, help, v) in counters {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {}\n",
                v.load(Ordering::Relaxed)
            ));
        }
        out.push_str(&format!(
            "# HELP fp8_serve_batch_last_fill Requests coalesced into the most recent \
             batch.\n# TYPE fp8_serve_batch_last_fill gauge\nfp8_serve_batch_last_fill {}\n",
            self.batch_last_fill.load(Ordering::Relaxed)
        ));
        out.push_str(&format!(
            "# HELP fp8_serve_resident_fp8_bytes Model bytes resident as raw FP8.\
             \n# TYPE fp8_serve_resident_fp8_bytes gauge\nfp8_serve_resident_fp8_bytes {}\n",
            info.resident_fp8_bytes
        ));
        out.push_str(&format!(
            "# HELP fp8_serve_resident_f32_bytes Model bytes resident as f32.\
             \n# TYPE fp8_serve_resident_f32_bytes gauge\nfp8_serve_resident_f32_bytes {}\n",
            info.resident_f32_bytes
        ));
        out.push_str(&format!(
            "# HELP fp8_serve_model_info Served model identity (value is always 1).\
             \n# TYPE fp8_serve_model_info gauge\n\
             fp8_serve_model_info{{size=\"{}\",recipe=\"{}\",fmt=\"{}\",mode=\"{}\"}} 1\n",
            prom_escape(&info.size),
            prom_escape(&info.recipe),
            fmt_name(info.fmt),
            info.mode.as_str()
        ));
        out
    }
}

/// One queued generation request.
struct Job {
    prompt: Vec<usize>,
    max_new: usize,
    events: Sender<Event>,
}

/// Batcher → handler notifications for one job.
enum Event {
    /// one generated token (streaming hook)
    Token { step: usize, token: usize, crc: u32 },
    /// generation finished
    Done(GenResult),
    /// the batched forward failed
    Failed(String),
}

/// A typed HTTP refusal/response error.
struct Refusal {
    status: u16,
    kind: &'static str,
    detail: String,
}

impl Refusal {
    fn new(status: u16, kind: &'static str, detail: impl Into<String>) -> Self {
        Self { status, kind, detail: detail.into() }
    }
}

struct ServerCtx {
    cfg: ServeConfig,
    info: ModelInfo,
    metrics: Arc<ServeMetrics>,
    jobs: Sender<Job>,
    stop: Arc<AtomicBool>,
}

/// Running server: background acceptor + batcher threads. Dropping the
/// handle (or calling [`ServerHandle::shutdown`]) stops both.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    jobs: Option<Sender<Job>>,
    acceptor: Option<JoinHandle<()>>,
    batcher: Option<JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
}

impl ServerHandle {
    /// The bound address (resolves the ephemeral port when
    /// `serve_port = 0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared view of the serving counters.
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Stop accepting, drain the queue, and join both threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.acceptor.is_none() && self.batcher.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // unblock the acceptor's blocking accept()
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        drop(self.jobs.take());
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Bind and start serving `engine` per `cfg`. Returns once the socket
/// is listening; request handling runs on background threads.
pub fn serve(engine: Engine, cfg: &ServeConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind((cfg.addr.as_str(), cfg.port))
        .map_err(|e| anyhow!("binding {}:{}: {e}", cfg.addr, cfg.port))?;
    let addr = listener.local_addr()?;
    let info = engine.info().clone();
    let metrics = Arc::new(ServeMetrics::default());
    let stop = Arc::new(AtomicBool::new(false));
    let (jobs_tx, jobs_rx) = mpsc::channel::<Job>();

    let batcher = {
        let cfg = cfg.clone();
        let metrics = Arc::clone(&metrics);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || run_batcher(engine, jobs_rx, &cfg, &metrics, &stop))
    };

    let ctx = Arc::new(ServerCtx {
        cfg: cfg.clone(),
        info,
        metrics: Arc::clone(&metrics),
        jobs: jobs_tx.clone(),
        stop: Arc::clone(&stop),
    });
    let acceptor = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if ctx.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let ctx = Arc::clone(&ctx);
            std::thread::spawn(move || handle(stream, &ctx));
        }
    });

    Ok(ServerHandle {
        addr,
        stop,
        jobs: Some(jobs_tx),
        acceptor: Some(acceptor),
        batcher: Some(batcher),
        metrics,
    })
}

/// The batching queue: own the engine, coalesce jobs, one batched
/// forward per decode step, fan results out.
fn run_batcher(
    mut engine: Engine,
    rx: Receiver<Job>,
    cfg: &ServeConfig,
    metrics: &ServeMetrics,
    stop: &AtomicBool,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return,
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + Duration::from_millis(cfg.batch_wait_ms);
        while jobs.len() < cfg.batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        metrics.batches_total.fetch_add(1, Ordering::Relaxed);
        metrics.batch_last_fill.store(jobs.len() as u64, Ordering::Relaxed);

        let prompts: Vec<Vec<usize>> = jobs.iter().map(|j| j.prompt.clone()).collect();
        let max_new: Vec<usize> = jobs.iter().map(|j| j.max_new).collect();
        let result = engine.generate_batch(&prompts, &max_new, |req, step, token, crc| {
            let _ = jobs[req].events.send(Event::Token { step, token, crc });
        });
        match result {
            Ok(results) => {
                let total: usize = results.iter().map(|r| r.tokens.len()).sum();
                metrics.generated_tokens_total.fetch_add(total as u64, Ordering::Relaxed);
                for (job, res) in jobs.iter().zip(results) {
                    let _ = job.events.send(Event::Done(res));
                }
            }
            Err(e) => {
                for job in &jobs {
                    let _ = job.events.send(Event::Failed(e.to_string()));
                }
            }
        }
    }
}

fn handle(mut stream: TcpStream, ctx: &ServerCtx) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    ctx.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
    if let Err(r) = handle_inner(&mut stream, ctx) {
        if r.status < 500 {
            ctx.metrics.refusals_total.fetch_add(1, Ordering::Relaxed);
        }
        let body = obj(vec![
            ("error", Json::Str(r.kind.into())),
            ("detail", Json::Str(r.detail.clone())),
            ("status", Json::Num(r.status as f64)),
        ])
        .to_string();
        let _ = write_response(&mut stream, r.status, "application/json", body.as_bytes());
    }
    let _ = stream.shutdown(Shutdown::Both);
}

fn handle_inner(stream: &mut TcpStream, ctx: &ServerCtx) -> Result<(), Refusal> {
    let (head, leftover) = read_head(stream)?;
    let (method, path, headers) = parse_head(&head)?;
    match (method.as_str(), path.as_str()) {
        ("GET", "/v1/healthz") => {
            let body = healthz_json(&ctx.info).to_string();
            write_response(stream, 200, "application/json", body.as_bytes())
                .map_err(io_refusal)
        }
        ("GET", "/v1/metrics") => {
            let body = ctx.metrics.render(&ctx.info);
            write_response(
                stream,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                body.as_bytes(),
            )
            .map_err(io_refusal)
        }
        ("POST", "/v1/generate") => {
            let body = read_body(stream, &headers, leftover, ctx.cfg.max_body_bytes)?;
            generate(stream, ctx, &body)
        }
        (_, "/v1/healthz") | (_, "/v1/metrics") | (_, "/v1/generate") => Err(Refusal::new(
            405,
            "method_not_allowed",
            format!("{method} is not supported on {path}"),
        )),
        _ => Err(Refusal::new(404, "not_found", format!("no route for {path}"))),
    }
}

fn generate(stream: &mut TcpStream, ctx: &ServerCtx, body: &[u8]) -> Result<(), Refusal> {
    let text = std::str::from_utf8(body)
        .map_err(|_| Refusal::new(400, "malformed_request", "body is not UTF-8"))?;
    let json = Json::parse(text)
        .map_err(|e| Refusal::new(400, "malformed_request", format!("body is not JSON: {e}")))?;
    let prompt_json = json
        .get("prompt")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| {
            Refusal::new(400, "malformed_request", "'prompt' must be an array of token ids")
        })?;
    let mut prompt = Vec::with_capacity(prompt_json.len());
    for v in prompt_json {
        let n = v.as_f64().filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0);
        match n {
            Some(x) => prompt.push(x as usize),
            None => {
                return Err(Refusal::new(
                    400,
                    "malformed_request",
                    format!("prompt element {v:?} is not a non-negative integer"),
                ))
            }
        }
    }
    if prompt.is_empty() {
        return Err(Refusal::new(400, "malformed_request", "prompt is empty"));
    }
    let dims = &ctx.info.dims;
    if let Some(&t) = prompt.iter().find(|&&t| t >= dims.vocab) {
        return Err(Refusal::new(
            400,
            "bad_token",
            format!("token {t} out of range for vocab {}", dims.vocab),
        ));
    }
    if prompt.len() >= dims.seq_len {
        return Err(Refusal::new(
            400,
            "prompt_too_long",
            format!(
                "prompt of {} tokens leaves no room to generate within seq_len {}",
                prompt.len(),
                dims.seq_len
            ),
        ));
    }
    let max_new = match json.get("max_new") {
        None => ctx.cfg.max_new_tokens,
        Some(v) => match v.as_f64().filter(|x| x.fract() == 0.0 && *x >= 1.0) {
            Some(x) => (x as usize).min(ctx.cfg.max_new_tokens),
            None => {
                return Err(Refusal::new(
                    400,
                    "malformed_request",
                    "'max_new' must be a positive integer",
                ))
            }
        },
    };
    let streaming = json.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);

    let (tx, rx) = mpsc::channel();
    ctx.jobs
        .send(Job { prompt, max_new, events: tx })
        .map_err(|_| Refusal::new(500, "shutting_down", "server is shutting down"))?;

    if streaming {
        stream_response(stream, &rx).map_err(io_refusal)
    } else {
        loop {
            match rx.recv_timeout(RESULT_TIMEOUT) {
                Ok(Event::Token { .. }) => continue,
                Ok(Event::Done(res)) => {
                    let body = obj(vec![
                        ("tokens", nums(&res.tokens)),
                        ("logits_crcs", crcs(&res.crcs)),
                        ("model", healthz_model(&ctx.info)),
                    ])
                    .to_string();
                    return write_response(stream, 200, "application/json", body.as_bytes())
                        .map_err(io_refusal);
                }
                Ok(Event::Failed(e)) => return Err(Refusal::new(500, "generation_failed", e)),
                Err(_) => {
                    return Err(Refusal::new(500, "timeout", "generation timed out"))
                }
            }
        }
    }
}

/// Chunked transfer encoding: one JSON line per token event, then a
/// final summary line, then the zero-length terminating chunk.
fn stream_response(stream: &mut TcpStream, rx: &Receiver<Event>) -> std::io::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
          Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
    )?;
    loop {
        match rx.recv_timeout(RESULT_TIMEOUT) {
            Ok(Event::Token { step, token, crc }) => {
                let line = obj(vec![
                    ("step", Json::Num(step as f64)),
                    ("token", Json::Num(token as f64)),
                    ("crc", Json::Num(crc as f64)),
                ])
                .to_string();
                write_chunk(stream, &line)?;
            }
            Ok(Event::Done(res)) => {
                let line = obj(vec![
                    ("done", Json::Bool(true)),
                    ("tokens", nums(&res.tokens)),
                    ("logits_crcs", crcs(&res.crcs)),
                ])
                .to_string();
                write_chunk(stream, &line)?;
                break;
            }
            Ok(Event::Failed(e)) => {
                let line = obj(vec![
                    ("done", Json::Bool(true)),
                    ("error", Json::Str(e)),
                ])
                .to_string();
                write_chunk(stream, &line)?;
                break;
            }
            Err(_) => {
                write_chunk(stream, r#"{"done":true,"error":"generation timed out"}"#)?;
                break;
            }
        }
    }
    stream.write_all(b"0\r\n\r\n")
}

fn write_chunk(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    let data = format!("{line}\n");
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data.as_bytes())?;
    stream.write_all(b"\r\n")
}

fn nums(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn crcs(xs: &[u32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn healthz_model(info: &ModelInfo) -> Json {
    obj(vec![
        ("size", Json::Str(info.size.clone())),
        ("recipe", Json::Str(info.recipe.clone())),
        ("step", Json::Num(info.step as f64)),
        ("fmt", Json::Str(fmt_name(info.fmt).into())),
        ("mode", Json::Str(info.mode.as_str().into())),
        ("vocab", Json::Num(info.dims.vocab as f64)),
        ("seq_len", Json::Num(info.dims.seq_len as f64)),
    ])
}

fn healthz_json(info: &ModelInfo) -> Json {
    obj(vec![
        ("status", Json::Str("ok".into())),
        ("model", healthz_model(info)),
        ("resident_fp8_bytes", Json::Num(info.resident_fp8_bytes as f64)),
        ("resident_f32_bytes", Json::Num(info.resident_f32_bytes as f64)),
        ("f32_equiv_bytes", Json::Num(info.f32_equiv_bytes as f64)),
    ])
}

fn io_refusal(e: std::io::Error) -> Refusal {
    Refusal::new(500, "io_error", e.to_string())
}

/// Read the request head (through `\r\n\r\n`), returning it plus any
/// body bytes that arrived in the same reads.
fn read_head(stream: &mut TcpStream) -> Result<(Vec<u8>, Vec<u8>), Refusal> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        if let Some(pos) = find_head_end(&buf) {
            let leftover = buf.split_off(pos + 4);
            return Ok((buf, leftover));
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(Refusal::new(
                431,
                "oversized_header",
                format!("request head exceeds {MAX_HEADER_BYTES} bytes"),
            ));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Refusal::new(400, "malformed_request", format!("read error: {e}")))?;
        if n == 0 {
            return Err(Refusal::new(400, "malformed_request", "truncated request head"));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse the request line and headers (header names lowercased).
#[allow(clippy::type_complexity)]
fn parse_head(head: &[u8]) -> Result<(String, String, Vec<(String, String)>), Refusal> {
    let text = std::str::from_utf8(head)
        .map_err(|_| Refusal::new(400, "malformed_request", "request head is not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(Refusal::new(
            400,
            "malformed_request",
            format!("bad request line '{request_line}'"),
        ));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(Refusal::new(
                400,
                "malformed_request",
                format!("bad header line '{line}'"),
            ));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    Ok((method, path, headers))
}

/// Read the request body. Requires `Content-Length` (411 without);
/// a declared length beyond the cap is a typed 413 ([`OversizedBody`])
/// refused **before** reading the payload.
fn read_body(
    stream: &mut TcpStream,
    headers: &[(String, String)],
    mut body: Vec<u8>,
    limit: usize,
) -> Result<Vec<u8>, Refusal> {
    let len = headers
        .iter()
        .find(|(n, _)| n == "content-length")
        .ok_or_else(|| {
            Refusal::new(411, "length_required", "Content-Length header is required")
        })?
        .1
        .parse::<usize>()
        .map_err(|_| Refusal::new(400, "malformed_request", "bad Content-Length"))?;
    if len > limit {
        let refusal = OversizedBody { len_at_least: len, limit };
        return Err(Refusal::new(413, "oversized_body", refusal.to_string()));
    }
    // over-read from the head phase can't exceed the declared length
    // on well-formed requests; tolerate trailing junk by truncating
    body.truncate(len);
    let mut chunk = [0u8; 4096];
    while body.len() < len {
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Refusal::new(400, "malformed_request", format!("read error: {e}")))?;
        if n == 0 {
            return Err(Refusal::new(400, "malformed_request", "truncated request body"));
        }
        let want = (len - body.len()).min(n);
        body.extend_from_slice(&chunk[..want]);
    }
    Ok(body)
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        _ => "Internal Server Error",
    };
    stream.write_all(
        format!(
            "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )
        .as_bytes(),
    )?;
    stream.write_all(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_keys_validates_each_field() {
        assert!(ServeConfig::from_keys("", 0, 8, 5, 1024, 64, "e4m3").is_err());
        assert!(ServeConfig::from_keys("127.0.0.1", 70000, 8, 5, 1024, 64, "e4m3")
            .unwrap_err()
            .contains("serve_port"));
        assert!(ServeConfig::from_keys("127.0.0.1", 0, 0, 5, 1024, 64, "e4m3")
            .unwrap_err()
            .contains("serve_batch"));
        assert!(ServeConfig::from_keys("127.0.0.1", 0, 8, 5, 0, 64, "e4m3")
            .unwrap_err()
            .contains("serve_max_body_bytes"));
        assert!(ServeConfig::from_keys("127.0.0.1", 0, 8, 5, 1024, 0, "e4m3")
            .unwrap_err()
            .contains("serve_max_new_tokens"));
        assert!(ServeConfig::from_keys("127.0.0.1", 0, 8, 5, 1024, 64, "fp16")
            .unwrap_err()
            .contains("serve_fmt"));
        let c = ServeConfig::from_keys("0.0.0.0", 8080, 4, 0, 1024, 8, "e5m2").unwrap();
        assert_eq!(c.port, 8080);
        assert_eq!(c.fmt, E5M2);
    }

    #[test]
    fn oversized_body_display_names_the_key() {
        let e = OversizedBody { len_at_least: 2048, limit: 1024 };
        let s = e.to_string();
        assert!(s.contains("2048") && s.contains("serve_max_body_bytes = 1024"), "{s}");
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(14));
        assert_eq!(find_head_end(b"partial\r\n"), None);
    }

    #[test]
    fn parse_head_rejects_garbage() {
        assert!(parse_head(b"\r\n").is_err());
        assert!(parse_head(b"GET /x\r\n").is_err());
        let (m, p, h) =
            parse_head(b"POST /v1/generate HTTP/1.1\r\nContent-Length: 12\r\n").unwrap();
        assert_eq!(m, "POST");
        assert_eq!(p, "/v1/generate");
        assert_eq!(h[0], ("content-length".to_string(), "12".to_string()));
    }
}
