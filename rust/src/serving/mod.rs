//! Inference serving on folded FP8 checkpoints — the fourth workload
//! layer (train / resume / observe → **serve**).
//!
//! The paper's §4.4 observation is that Smooth-SwiGLU's per-channel
//! pow2 scales fold into the stored w1/w3 weights, making the
//! training-stability fix *zero-cost at inference*. This module turns
//! that claim into a served, measured artifact path:
//!
//! * [`export`] — load a campaign snapshot ([`crate::campaign::TrainState`]),
//!   calibrate per-channel Smooth-SwiGLU scales on a deterministic
//!   probe, fold them via [`crate::coordinator::folding::fold_scales`],
//!   quantize the matrices to real FP8 bytes, and **prove the fold
//!   bit-exact before any file is written**: the folded-FP8 engine and
//!   an unfolded scaled-reference engine run the same probe and must
//!   produce bit-identical logits, else export refuses (the reshard
//!   gate pattern). The artifact is a self-describing
//!   [`crate::checkpoint`] file (CRC-32 footer, dims in the metadata).
//! * [`engine`] — keeps parameters resident as FP8 bytes and decodes
//!   them on the fly through [`crate::fp8::bulk`] into one reusable
//!   scratch buffer; all matmuls run through the pinned-order
//!   [`crate::gemm::matmul_f32`] kernel, so the two serving modes
//!   ([`ServeMode::Folded`] vs [`ServeMode::ScaledReference`]) differ
//!   only in where the pow2 scales live — the substance of the
//!   bit-identity gate.
//! * [`server`] — a pure-std `TcpListener` HTTP/1.1 layer (no new
//!   deps): typed JSON API (`/v1/generate`, `/v1/healthz`,
//!   `/v1/metrics` in Prometheus text exposition), a batching queue
//!   (collect up to `serve_batch` requests or `serve_batch_wait_ms`,
//!   one batched forward, fan the results back out), chunked streaming
//!   token responses, and bounded request bodies as a typed refusal
//!   ([`OversizedBody`], mirroring the journal stream's
//!   `OversizedLine`).
//!
//! The `serve` binary (`rust/src/bin/serve.rs`) drives all of it:
//! `serve export` / `serve run` / `serve probe`. The end-to-end
//! conformance suite lives in `rust/tests/serving.rs`; latency/QPS and
//! the FP8-resident memory floor in `benches/perf_serving.rs`.

pub mod engine;
pub mod export;
pub mod server;

pub use engine::{dims_of, fmt_name, Engine, GenResult, ModelInfo, ServeMode, Stored};
pub use export::{
    channel_scales, export_snapshot, export_state, probe_tokens_for, swiglu_products,
    ExportOptions, ExportReport,
};
pub use server::{serve, OversizedBody, ServeConfig, ServeMetrics, ServerHandle};
