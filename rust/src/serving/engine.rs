//! FP8-resident inference engine over folded model artifacts.
//!
//! Parameters stay as the artifact's raw FP8 bytes for their whole
//! lifetime; each forward decodes one weight at a time through the
//! [`crate::fp8::bulk`] LUT codec into a single reusable scratch
//! buffer (allocation-free in steady state — the scratch grows once to
//! the largest per-layer weight and is then reused), multiplies
//! through the pinned-order [`crate::gemm::matmul_f32`] kernel, and
//! discards the f32 view. Resident model memory is therefore the FP8
//! payload (~1 byte/element on every matrix) plus the f32 norm gains —
//! the FP8-LM memory/bandwidth story, measured by
//! [`Engine::resident_bytes`] and floored in `benches/perf_serving.rs`.
//!
//! The forward graph is the inference side of `python/compile/model.py`
//! (Llama-style: pre-norm RMSNorm, RoPE, causal MHA, SwiGLU, untied
//! head) with activations in plain f32 — no activation quantization,
//! exactly the "zero-cost at inference" configuration the folded
//! artifact promises. Batched decoding is layer-major: each weight is
//! decoded once per layer and applied to every sequence in the batch,
//! so batching amortizes the decode bandwidth; per-sequence math never
//! reads another sequence's state, which is why batched and serial
//! results are bit-identical (pinned by `rust/tests/serving.rs`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::fp8::{self, Fp8Format, E4M3, E5M2};
use crate::gemm::{matmul_f32, Matrix};
use crate::runtime::manifest::ModelDims;

/// RMSNorm epsilon (matches `python/compile/model.py::ModelConfig`).
const NORM_EPS: f32 = 1e-5;
/// RoPE base (ditto).
const ROPE_BASE: f32 = 10000.0;

/// The weight names a servable artifact must carry, with per-tensor
/// element counts derived from the model dims.
pub(crate) fn weight_specs(dims: &ModelDims) -> Vec<(&'static str, usize)> {
    let (v, d, l, f) = (dims.vocab, dims.d_model, dims.n_layers, dims.d_ff);
    vec![
        ("embed", v * d),
        ("head", d * v),
        ("ln_f", d),
        ("ln_1", l * d),
        ("ln_2", l * d),
        ("wq", l * d * d),
        ("wk", l * d * d),
        ("wv", l * d * d),
        ("wo", l * d * d),
        ("w1", l * d * f),
        ("w2", l * d * f),
        ("w3", l * f * d),
    ]
}

/// Weights that stay f32 in the artifact (tiny, and RMSNorm gain
/// precision is not worth one byte per element).
pub(crate) const NORM_GAINS: [&str; 3] = ["ln_f", "ln_1", "ln_2"];

/// Model dims of the known size presets (`python/compile/model.py::SIZES`).
/// Artifacts are self-describing (dims ride in the metadata), so this
/// table is only needed when *exporting* from a snapshot, whose meta
/// carries a size name.
pub fn dims_of(size: &str) -> Option<ModelDims> {
    let (vocab, d_model, n_layers, n_heads, d_ff, seq_len) = match size {
        "tiny" => (256, 64, 2, 4, 172, 64),
        "s1m" => (512, 128, 3, 4, 344, 128),
        "s8m" => (2048, 256, 4, 8, 688, 128),
        "m100" => (8192, 768, 12, 12, 2048, 256),
        _ => return None,
    };
    Some(ModelDims { vocab, d_model, n_layers, n_heads, d_ff, seq_len })
}

/// Config-file spelling of an FP8 format.
pub fn fmt_name(fmt: Fp8Format) -> &'static str {
    match fmt {
        Fp8Format::E4M3 => "e4m3",
        Fp8Format::E5M2 => "e5m2",
    }
}

/// Which algebraic form of the Smooth-SwiGLU scales the forward runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Weights as stored (scales folded into w̃1/w̃3), plain SwiGLU —
    /// the production path: zero extra work per token.
    Folded,
    /// The unfolded scaled reference: w̃1 is un-folded at load by the
    /// exact pow2 per-channel division, and the SwiGLU product is
    /// explicitly re-multiplied by the per-channel scales. Every other
    /// tensor and kernel is byte-identical to [`ServeMode::Folded`],
    /// so any output difference is a fold-exactness violation — the
    /// export gate and the conformance suite demand bit equality.
    ScaledReference,
}

impl ServeMode {
    pub fn as_str(self) -> &'static str {
        match self {
            ServeMode::Folded => "folded",
            ServeMode::ScaledReference => "scaled_reference",
        }
    }
}

/// One resident weight tensor: raw FP8 bytes (decoded on demand) or
/// plain f32 (norm gains; the unfolded w1 in reference mode).
#[derive(Clone, Debug)]
pub enum Stored {
    /// FP8 payload with the per-tensor pow2 scale chosen at export.
    Fp8 { fmt: Fp8Format, scale: f32, bytes: Vec<u8> },
    /// Raw f32 storage.
    F32(Vec<f32>),
}

impl Stored {
    pub fn numel(&self) -> usize {
        match self {
            Stored::Fp8 { bytes, .. } => bytes.len(),
            Stored::F32(v) => v.len(),
        }
    }

    /// Resident payload bytes (what this process actually holds).
    pub fn resident_bytes(&self) -> usize {
        match self {
            Stored::Fp8 { bytes, .. } => bytes.len(),
            Stored::F32(v) => v.len() * 4,
        }
    }
}

/// Static description of a loaded model, cloned out of the engine for
/// the server's request validation, health endpoint, and metrics.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub size: String,
    pub recipe: String,
    pub step: usize,
    pub fmt: Fp8Format,
    pub mode: ServeMode,
    pub dims: ModelDims,
    /// bytes held as raw FP8 payloads
    pub resident_fp8_bytes: usize,
    /// bytes held as f32 (norm gains; unfolded w1 in reference mode)
    pub resident_f32_bytes: usize,
    /// what the same parameters would occupy fully f32-resident
    pub f32_equiv_bytes: usize,
}

/// One request's generation output: greedy tokens plus a CRC-32 of the
/// last-position logits at each step — the end-to-end bit-identity
/// witness the conformance suite compares across serving modes.
#[derive(Clone, Debug, Default)]
pub struct GenResult {
    pub tokens: Vec<usize>,
    pub crcs: Vec<u32>,
}

/// The FP8-resident inference engine. Construct via [`Engine::load`]
/// (from an exported artifact) or [`Engine::from_parts`] (the export
/// gate's in-memory path).
pub struct Engine {
    info: ModelInfo,
    weights: BTreeMap<String, Stored>,
    /// per-layer per-channel Smooth-SwiGLU fold scales `[L][d_ff]`
    scales: Vec<Vec<f32>>,
    /// RoPE tables `[seq_len, head_dim/2]`
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    /// reusable weight-decode scratch (the allocation-free steady state)
    wbuf: Vec<f32>,
}

impl Engine {
    /// Build an engine from already-quantized tensors. Validates tensor
    /// presence/lengths and that every fold scale is a positive normal
    /// pow2 (the exactness precondition of the whole fold story).
    pub fn from_parts(
        dims: ModelDims,
        size: &str,
        recipe: &str,
        step: usize,
        fmt: Fp8Format,
        mut weights: BTreeMap<String, Stored>,
        scales: Vec<Vec<f32>>,
        mode: ServeMode,
    ) -> Result<Self> {
        if dims.n_heads == 0 || dims.d_model % dims.n_heads != 0 {
            bail!(
                "d_model ({}) must be a positive multiple of n_heads ({})",
                dims.d_model,
                dims.n_heads
            );
        }
        if dims.head_dim() % 2 != 0 {
            bail!("head_dim ({}) must be even for rotate-half RoPE", dims.head_dim());
        }
        if dims.seq_len == 0 || dims.vocab == 0 || dims.n_layers == 0 || dims.d_ff == 0 {
            bail!("degenerate model dims: {dims:?}");
        }
        for (name, want) in weight_specs(&dims) {
            let got = weights
                .get(name)
                .ok_or_else(|| anyhow!("model is missing weight '{name}'"))?
                .numel();
            if got != want {
                bail!("weight '{name}': {got} elements, expected {want} for dims {dims:?}");
            }
        }
        if scales.len() != dims.n_layers || scales.iter().any(|s| s.len() != dims.d_ff) {
            bail!(
                "fold scales must be [n_layers × d_ff] = [{} × {}]",
                dims.n_layers,
                dims.d_ff
            );
        }
        for (l, row) in scales.iter().enumerate() {
            for (j, &s) in row.iter().enumerate() {
                // positive normal pow2: sign 0, mantissa 0, exponent nonzero
                if !(s > 0.0) || !s.is_finite() || (s.to_bits() & 0x007f_ffff) != 0 {
                    bail!("fold scale [layer {l}, channel {j}] = {s} is not a positive pow2");
                }
            }
        }
        if mode == ServeMode::ScaledReference {
            // Un-fold w̃1 by the exact per-channel pow2 division; the
            // result is kept f32-resident (per-column scales cannot be
            // re-absorbed into one per-tensor FP8 scale).
            let (d, f) = (dims.d_model, dims.d_ff);
            let stored = weights.remove("w1").expect("validated above");
            let mut w1 = decode_all(&stored);
            for (l, row) in scales.iter().enumerate() {
                let base = l * d * f;
                for i in 0..d {
                    for (j, &s) in row.iter().enumerate() {
                        w1[base + i * f + j] /= s;
                    }
                }
            }
            weights.insert("w1".into(), Stored::F32(w1));
        }

        let (mut fp8_bytes, mut f32_bytes, mut equiv) = (0usize, 0usize, 0usize);
        for st in weights.values() {
            equiv += st.numel() * 4;
            match st {
                Stored::Fp8 { .. } => fp8_bytes += st.resident_bytes(),
                Stored::F32(_) => f32_bytes += st.resident_bytes(),
            }
        }

        let half = dims.head_dim() / 2;
        let mut rope_cos = vec![0.0f32; dims.seq_len * half];
        let mut rope_sin = vec![0.0f32; dims.seq_len * half];
        for pos in 0..dims.seq_len {
            for e in 0..half {
                let freq = ROPE_BASE.powf(-(e as f32) / half as f32);
                let angle = pos as f32 * freq;
                rope_cos[pos * half + e] = angle.cos();
                rope_sin[pos * half + e] = angle.sin();
            }
        }

        Ok(Self {
            info: ModelInfo {
                size: size.to_string(),
                recipe: recipe.to_string(),
                step,
                fmt,
                mode,
                dims,
                resident_fp8_bytes: fp8_bytes,
                resident_f32_bytes: f32_bytes,
                f32_equiv_bytes: equiv,
            },
            weights,
            scales,
            rope_cos,
            rope_sin,
            wbuf: Vec::new(),
        })
    }

    /// Load an exported `fp8_model` artifact (CRC-verified by the
    /// checkpoint layer — a flipped payload bit is a load *error*, not
    /// a silently different model). FP8 sections are adopted as raw
    /// bytes via [`Checkpoint`]'s `raw_fp8` map, so the decoded f32
    /// copies the loader produces are dropped here and steady-state
    /// residency is the FP8 payload.
    pub fn load<P: AsRef<Path>>(path: P, mode: ServeMode) -> Result<Self> {
        let path = path.as_ref();
        let mut ckpt =
            Checkpoint::load(path).with_context(|| format!("loading model {}", path.display()))?;
        let kind = ckpt.meta.str_or("kind", "");
        if kind != "fp8_model" {
            bail!(
                "{} is not an fp8_model artifact (kind '{kind}') — produce one with \
                 `serve export`",
                path.display()
            );
        }
        let dims = ModelDims {
            vocab: ckpt.meta.usize_of("vocab").map_err(|e| anyhow!(e))?,
            d_model: ckpt.meta.usize_of("d_model").map_err(|e| anyhow!(e))?,
            n_layers: ckpt.meta.usize_of("n_layers").map_err(|e| anyhow!(e))?,
            n_heads: ckpt.meta.usize_of("n_heads").map_err(|e| anyhow!(e))?,
            d_ff: ckpt.meta.usize_of("d_ff").map_err(|e| anyhow!(e))?,
            seq_len: ckpt.meta.usize_of("seq_len").map_err(|e| anyhow!(e))?,
        };
        let size = ckpt.meta.str_or("size", "?");
        let recipe = ckpt.meta.str_or("recipe", "?");
        let step = ckpt.meta.usize_of("step").map_err(|e| anyhow!(e))?;
        let fmt = match ckpt.meta.str_or("fmt", "e4m3").as_str() {
            "e5m2" => E5M2,
            _ => E4M3,
        };

        let flat = ckpt
            .tensors
            .remove("fold.scales")
            .ok_or_else(|| anyhow!("artifact missing 'fold.scales'"))?
            .1;
        if flat.len() != dims.n_layers * dims.d_ff {
            bail!(
                "fold.scales has {} values, expected n_layers*d_ff = {}",
                flat.len(),
                dims.n_layers * dims.d_ff
            );
        }
        let scales: Vec<Vec<f32>> =
            flat.chunks(dims.d_ff).map(|c| c.to_vec()).collect();

        let mut weights = BTreeMap::new();
        for (name, _) in weight_specs(&dims) {
            let key = format!("model.{name}");
            let st = if let Some((f, s, b)) = ckpt.raw_fp8.remove(&key) {
                ckpt.tensors.remove(&key); // drop the decoded copy
                Stored::Fp8 { fmt: f, scale: s, bytes: b }
            } else {
                let (_, data) = ckpt
                    .tensors
                    .remove(&key)
                    .ok_or_else(|| anyhow!("artifact missing tensor '{key}'"))?;
                Stored::F32(data)
            };
            weights.insert(name.to_string(), st);
        }
        Self::from_parts(dims, &size, &recipe, step, fmt, weights, scales, mode)
    }

    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    /// `(fp8_payload_bytes, f32_resident_bytes, f32_equivalent_bytes)`
    /// — the Table-4-style serving memory measurement.
    pub fn resident_bytes(&self) -> (usize, usize, usize) {
        (
            self.info.resident_fp8_bytes,
            self.info.resident_f32_bytes,
            self.info.f32_equiv_bytes,
        )
    }

    /// The per-layer per-channel fold scales the artifact carries.
    pub fn fold_scales(&self) -> &[Vec<f32>] {
        &self.scales
    }

    /// Full-sequence logits for each sequence in the batch (row-major
    /// `[len_i, vocab]`, flattened). Sequences are independent: the
    /// batched result is bit-identical to running each alone.
    pub fn forward_full(&mut self, seqs: &[Vec<usize>]) -> Result<Vec<Vec<f32>>> {
        self.forward_inner(seqs, None)
    }

    /// Forward that additionally collects the per-layer per-channel
    /// amax of the SwiGLU product — the export calibration signal.
    #[doc(hidden)]
    pub fn forward_collect_amax(
        &mut self,
        seqs: &[Vec<usize>],
        amax: &mut Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        self.forward_inner(seqs, Some(amax))
    }

    fn forward_inner(
        &mut self,
        seqs: &[Vec<usize>],
        mut h_amax: Option<&mut Vec<Vec<f32>>>,
    ) -> Result<Vec<Vec<f32>>> {
        let dims = self.info.dims.clone();
        let (v, d, f) = (dims.vocab, dims.d_model, dims.d_ff);
        let (nh, hd) = (dims.n_heads, dims.head_dim());
        let half = hd / 2;
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        for s in seqs {
            if s.is_empty() {
                bail!("empty sequence");
            }
            if s.len() > dims.seq_len {
                bail!("sequence length {} exceeds model seq_len {}", s.len(), dims.seq_len);
            }
            if let Some(&t) = s.iter().find(|&&t| t >= v) {
                bail!("token {t} out of range for vocab {v}");
            }
        }
        if let Some(a) = h_amax.as_mut() {
            a.clear();
            a.resize(dims.n_layers, vec![0.0f32; f]);
        }

        let mut wbuf = std::mem::take(&mut self.wbuf);

        // ---- embedding gather
        self.weight_into("embed", None, 0, &mut wbuf)?;
        let mut xs: Vec<Vec<f32>> = seqs
            .iter()
            .map(|s| {
                let mut x = Vec::with_capacity(s.len() * d);
                for &t in s {
                    x.extend_from_slice(&wbuf[t * d..(t + 1) * d]);
                }
                x
            })
            .collect();

        for l in 0..dims.n_layers {
            // ---- attention
            self.weight_into("ln_1", Some(l), d, &mut wbuf)?;
            let xn: Vec<Vec<f32>> = xs.iter().map(|x| rmsnorm(x, &wbuf, d)).collect();

            self.weight_into("wq", Some(l), d * d, &mut wbuf)?;
            let mut qs = mm_each(&xn, &wbuf, d, d)?;
            self.weight_into("wk", Some(l), d * d, &mut wbuf)?;
            let mut ks = mm_each(&xn, &wbuf, d, d)?;
            self.weight_into("wv", Some(l), d * d, &mut wbuf)?;
            let vs = mm_each(&xn, &wbuf, d, d)?;
            for m in qs.iter_mut().chain(ks.iter_mut()) {
                self.rope_in_place(m, nh, hd, half);
            }

            self.weight_into("wo", Some(l), d * d, &mut wbuf)?;
            for (si, x) in xs.iter_mut().enumerate() {
                let slen = seqs[si].len();
                let att = attention(&qs[si], &ks[si], &vs[si], slen, nh, hd);
                let y = matmul_f32(&att, slen, d, false, &wbuf, d, d, false)
                    .map_err(|e| anyhow!("wo matmul: {e}"))?;
                add_in_place(x, &y.data);
            }

            // ---- MLP
            self.weight_into("ln_2", Some(l), d, &mut wbuf)?;
            let xn2: Vec<Vec<f32>> = xs.iter().map(|x| rmsnorm(x, &wbuf, d)).collect();

            self.weight_into("w1", Some(l), d * f, &mut wbuf)?;
            let a1s = mm_each(&xn2, &wbuf, d, f)?;
            self.weight_into("w2", Some(l), d * f, &mut wbuf)?;
            let a2s = mm_each(&xn2, &wbuf, d, f)?;

            let mut hs: Vec<Vec<f32>> = Vec::with_capacity(xs.len());
            for (a1, a2) in a1s.iter().zip(&a2s) {
                let mut h = vec![0.0f32; a1.data.len()];
                for ((h, &x1), &x2) in h.iter_mut().zip(&a1.data).zip(&a2.data) {
                    // same form as coordinator::folding's reference MLP
                    *h = x1 * x2 / (1.0 + (-x2).exp());
                }
                hs.push(h);
            }
            if let Some(acc) = h_amax.as_deref_mut() {
                let row = &mut acc[l];
                for h in &hs {
                    for (j, slot) in row.iter_mut().enumerate() {
                        for t in 0..h.len() / f {
                            let a = h[t * f + j].abs();
                            if a.is_finite() && a > *slot {
                                *slot = a;
                            }
                        }
                    }
                }
            }
            if self.info.mode == ServeMode::ScaledReference {
                // re-apply the scales the folded weights carry built-in
                let row = &self.scales[l];
                for h in hs.iter_mut() {
                    for t in 0..h.len() / f {
                        for (j, &s) in row.iter().enumerate() {
                            h[t * f + j] *= s;
                        }
                    }
                }
            }

            self.weight_into("w3", Some(l), f * d, &mut wbuf)?;
            for (si, x) in xs.iter_mut().enumerate() {
                let slen = seqs[si].len();
                let y = matmul_f32(&hs[si], slen, f, false, &wbuf, f, d, false)
                    .map_err(|e| anyhow!("w3 matmul: {e}"))?;
                add_in_place(x, &y.data);
            }
        }

        // ---- final norm + head
        self.weight_into("ln_f", None, 0, &mut wbuf)?;
        let xf: Vec<Vec<f32>> = xs.iter().map(|x| rmsnorm(x, &wbuf, d)).collect();
        self.weight_into("head", None, 0, &mut wbuf)?;
        let mut out = Vec::with_capacity(xs.len());
        for (si, x) in xf.iter().enumerate() {
            let slen = seqs[si].len();
            let logits = matmul_f32(x, slen, d, false, &wbuf, d, v, false)
                .map_err(|e| anyhow!("head matmul: {e}"))?;
            out.push(logits.data);
        }

        self.wbuf = wbuf;
        Ok(out)
    }

    /// Greedy batched generation. `max_new[i]` bounds request `i`'s new
    /// tokens (additionally capped by the model's `seq_len`);
    /// `on_token(request, step, token, logits_crc)` fires per generated
    /// token in step order — the server's streaming hook.
    pub fn generate_batch<F: FnMut(usize, usize, usize, u32)>(
        &mut self,
        prompts: &[Vec<usize>],
        max_new: &[usize],
        mut on_token: F,
    ) -> Result<Vec<GenResult>> {
        if prompts.len() != max_new.len() {
            bail!("prompts/max_new length mismatch");
        }
        let v = self.info.dims.vocab;
        let seq_cap = self.info.dims.seq_len;
        let mut seqs: Vec<Vec<usize>> = prompts.to_vec();
        let targets: Vec<usize> = prompts
            .iter()
            .zip(max_new)
            .map(|(p, &mn)| (p.len() + mn).min(seq_cap))
            .collect();
        let mut results = vec![GenResult::default(); prompts.len()];
        loop {
            let active: Vec<usize> =
                (0..seqs.len()).filter(|&i| seqs[i].len() < targets[i]).collect();
            if active.is_empty() {
                break;
            }
            let batch: Vec<Vec<usize>> = active.iter().map(|&i| seqs[i].clone()).collect();
            let logits = self.forward_full(&batch)?;
            for (bi, &i) in active.iter().enumerate() {
                let s = batch[bi].len();
                let last = &logits[bi][(s - 1) * v..s * v];
                let tok = argmax(last);
                let crc = crc32_f32(last);
                seqs[i].push(tok);
                let step = results[i].tokens.len();
                results[i].tokens.push(tok);
                results[i].crcs.push(crc);
                on_token(i, step, tok, crc);
            }
        }
        Ok(results)
    }

    /// Decode a weight (or one stacked layer of it) into `out`. With
    /// `layer = Some(l)`, `per_layer` is the per-layer element count.
    fn weight_into(
        &self,
        name: &str,
        layer: Option<usize>,
        per_layer: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let st =
            self.weights.get(name).ok_or_else(|| anyhow!("missing weight '{name}'"))?;
        match st {
            Stored::Fp8 { fmt, scale, bytes } => {
                let b = match layer {
                    Some(l) => &bytes[l * per_layer..(l + 1) * per_layer],
                    None => &bytes[..],
                };
                out.clear();
                out.resize(b.len(), 0.0);
                fp8::bulk::unpack_scaled_buf(*fmt, b, *scale, &mut out[..]);
            }
            Stored::F32(v) => {
                let s = match layer {
                    Some(l) => &v[l * per_layer..(l + 1) * per_layer],
                    None => &v[..],
                };
                out.clear();
                out.extend_from_slice(s);
            }
        }
        Ok(())
    }

    /// Rotate-half RoPE in place on a `[s, d_model]` activation viewed
    /// as `[s, n_heads, head_dim]`.
    fn rope_in_place(&self, m: &mut Matrix, nh: usize, hd: usize, half: usize) {
        for pos in 0..m.rows {
            let row = &mut m.data[pos * nh * hd..(pos + 1) * nh * hd];
            for h in 0..nh {
                for e in 0..half {
                    let c = self.rope_cos[pos * half + e];
                    let s = self.rope_sin[pos * half + e];
                    let x1 = row[h * hd + e];
                    let x2 = row[h * hd + half + e];
                    row[h * hd + e] = x1 * c - x2 * s;
                    row[h * hd + half + e] = x1 * s + x2 * c;
                }
            }
        }
    }

    /// Test hook: flip one bit of a resident FP8 weight payload. The
    /// export gate uses it to prove the fold comparison actually
    /// refuses on a divergence.
    #[doc(hidden)]
    pub fn corrupt_weight_byte_for_test(&mut self, name: &str) {
        if let Some(Stored::Fp8 { bytes, .. }) = self.weights.get_mut(name) {
            if !bytes.is_empty() {
                bytes[0] ^= 0x01;
            }
        }
    }
}

/// `x * rsqrt(mean(x²) + eps) * gain` over each row of `[s, d]`.
fn rmsnorm(x: &[f32], gain: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (row_o, row_x) in out.chunks_mut(d).zip(x.chunks(d)) {
        let mut ss = 0.0f32;
        for &xi in row_x {
            ss += xi * xi;
        }
        let inv = 1.0 / (ss / d as f32 + NORM_EPS).sqrt();
        for ((o, &xi), &g) in row_o.iter_mut().zip(row_x).zip(gain) {
            *o = xi * inv * g;
        }
    }
    out
}

/// Multiply each sequence's `[s_i, k]` activation by one `[k, n]`
/// weight through the pinned-order kernel.
fn mm_each(xs: &[Vec<f32>], w: &[f32], k: usize, n: usize) -> Result<Vec<Matrix>> {
    xs.iter()
        .map(|x| {
            matmul_f32(x, x.len() / k, k, false, w, k, n, false)
                .map_err(|e| anyhow!("matmul: {e}"))
        })
        .collect()
}

/// Causal multi-head attention core on one sequence: q/k/v are
/// `[s, n_heads*head_dim]` (RoPE already applied to q/k). Scores are
/// scaled by 1/√head_dim and softmaxed over the causal prefix.
fn attention(q: &Matrix, k: &Matrix, vv: &Matrix, s: usize, nh: usize, hd: usize) -> Vec<f32> {
    let d = nh * hd;
    let scale = (hd as f32).sqrt();
    let mut out = vec![0.0f32; s * d];
    let mut scores = vec![0.0f32; s];
    for h in 0..nh {
        let off = h * hd;
        for i in 0..s {
            for (j, slot) in scores.iter_mut().enumerate().take(i + 1) {
                let mut dot = 0.0f32;
                let qr = &q.data[i * d + off..i * d + off + hd];
                let kr = &k.data[j * d + off..j * d + off + hd];
                for (qe, ke) in qr.iter().zip(kr) {
                    dot += qe * ke;
                }
                *slot = dot / scale;
            }
            let m = scores[..=i].iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut denom = 0.0f32;
            for slot in scores.iter_mut().take(i + 1) {
                *slot = (*slot - m).exp();
                denom += *slot;
            }
            let orow = &mut out[i * d + off..i * d + off + hd];
            for (j, &p) in scores.iter().enumerate().take(i + 1) {
                let w = p / denom;
                let vr = &vv.data[j * d + off..j * d + off + hd];
                for (o, &ve) in orow.iter_mut().zip(vr) {
                    *o += w * ve;
                }
            }
        }
    }
    out
}

fn add_in_place(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

/// First index of the maximum (ties and NaN resolve to the earliest
/// candidate — deterministic greedy decoding).
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// CRC-32 over the little-endian bytes of an f32 slice — the logits
/// fingerprint carried in generate responses and export reports.
pub(crate) fn crc32_f32(xs: &[f32]) -> u32 {
    let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
    crate::util::crc32(&bytes)
}

fn decode_all(st: &Stored) -> Vec<f32> {
    match st {
        Stored::Fp8 { fmt, scale, bytes } => {
            let mut out = Vec::new();
            fp8::bulk::unpack_scaled_into(*fmt, bytes, *scale, &mut out);
            out
        }
        Stored::F32(v) => v.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_dim_of(d: &ModelDims) -> usize {
        d.d_model / d.n_heads
    }

    #[test]
    fn preset_dims_are_consistent() {
        for size in ["tiny", "s1m", "s8m", "m100"] {
            let d = dims_of(size).unwrap();
            assert_eq!(d.d_model % d.n_heads, 0, "{size}");
            assert_eq!(head_dim_of(&d) % 2, 0, "{size}");
        }
        assert!(dims_of("nope").is_none());
    }

    #[test]
    fn argmax_is_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[f32::NAN, 1.0]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn rmsnorm_matches_definition() {
        let x = [3.0f32, 4.0];
        let g = [2.0f32, 0.5];
        let out = rmsnorm(&x, &g, 2);
        let inv = 1.0 / ((9.0f32 + 16.0) / 2.0 + NORM_EPS).sqrt();
        assert_eq!(out[0].to_bits(), (3.0 * inv * 2.0f32).to_bits());
        assert_eq!(out[1].to_bits(), (4.0 * inv * 0.5f32).to_bits());
    }
}

impl ModelDims {
    fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}
