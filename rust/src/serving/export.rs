//! Snapshot → FP8 model artifact export, gated on fold bit-exactness.
//!
//! The pipeline (paper §4.4 made operational):
//!
//! 1. load a campaign snapshot ([`crate::campaign::TrainState`]),
//! 2. run a deterministic probe through the master weights and collect
//!    the per-layer per-channel amax of the SwiGLU product,
//! 3. derive pow2 smoothing scales ([`crate::fp8::compute_scale`],
//!    exponent-clamped), fold them into w1/w3 via
//!    [`crate::coordinator::folding::fold_scales`],
//! 4. quantize every matrix to real FP8 bytes ([`crate::fp8::pack_scaled`]),
//! 5. **gate**: run the probe through the folded-FP8 engine *and*
//!    through the unfolded scaled-reference engine built from the same
//!    quantized bytes; refuse to write anything unless the logits are
//!    bit-identical (the PR-7 reshard-gate pattern — equivalence is
//!    proved, never assumed),
//! 6. write the self-describing artifact (dims + probe CRC in the
//!    metadata, CRC-32 footer) and re-load it for a readback check.
//!
//! A corrupted fold (injectable via
//! [`ExportOptions::corrupt_fold_for_test`]) or a non-finite snapshot
//! aborts before any file exists; a readback mismatch deletes the file.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::campaign::snapshot::{SnapshotMeta, TrainState};
use crate::checkpoint::{Dtype, Writer};
use crate::coordinator::folding::fold_scales;
use crate::coordinator::DetectorState;
use crate::fp8::{self, Fp8Format, E4M3};
use crate::runtime::manifest::ModelDims;
use crate::scaling::ScaleState;
use crate::serving::engine::{
    crc32_f32, dims_of, fmt_name, weight_specs, Engine, ServeMode, Stored, NORM_GAINS,
};
use crate::util::json::{obj, Json};
use crate::util::prng::Rng;

/// Artifact `kind` in the checkpoint metadata.
pub const ARTIFACT_KIND: &str = "fp8_model";
/// Artifact format version.
pub const ARTIFACT_VERSION: usize = 1;
/// Smoothing-scale exponents are clamped to ±[`SCALE_EXP_CLAMP`]: a
/// dead channel (amax ≈ 0) would otherwise get a ~2¹²⁷ scale and fold
/// w1 columns straight to inf.
pub const SCALE_EXP_CLAMP: i32 = 32;

/// Knobs for [`export_snapshot`] / [`export_state`].
#[derive(Clone, Debug)]
pub struct ExportOptions {
    /// weight quantization format (E4M3 default; E5M2 supported)
    pub fmt: Fp8Format,
    /// probe length in tokens (clamped to `[1, seq_len]`)
    pub probe_tokens: usize,
    /// probe PRNG seed — recorded in the artifact so the gate is
    /// replayable at load time
    pub probe_seed: u64,
    /// explicit model dims; default derives them from the snapshot's
    /// size preset via [`dims_of`]
    pub dims: Option<ModelDims>,
    /// Test hook: flip one bit of the folded engine's quantized w1
    /// *after* the reference engine is built, so the gate sees a real
    /// divergence and must refuse. Never set outside tests.
    #[doc(hidden)]
    pub corrupt_fold_for_test: bool,
}

impl Default for ExportOptions {
    fn default() -> Self {
        Self {
            fmt: E4M3,
            probe_tokens: 16,
            probe_seed: 0x5e11e,
            dims: None,
            corrupt_fold_for_test: false,
        }
    }
}

/// What an export produced — echoed by `serve export` and consumed by
/// the conformance tests.
#[derive(Clone, Debug)]
pub struct ExportReport {
    pub size: String,
    pub step: usize,
    pub fmt: Fp8Format,
    /// per-layer per-channel smoothing scales that were folded
    pub scales: Vec<Vec<f32>>,
    pub file_bytes: u64,
    pub resident_fp8_bytes: usize,
    pub f32_equiv_bytes: usize,
    /// CRC-32 of the gate probe's folded logits (also in the artifact
    /// metadata — the readback witness)
    pub probe_crc: u32,
    /// total probe positions × vocab compared by the gate
    pub probe_len: usize,
}

/// Deterministic gate-probe batch for a model: two sequences (one full
/// `n`-token, one roughly half) so the gate also exercises ragged
/// batching.
pub fn probe_tokens_for(dims: &ModelDims, seed: u64, n: usize) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed ^ 0x5e52_11e7);
    let long = n.clamp(1, dims.seq_len);
    let short = (long / 2).max(1);
    [long, short]
        .iter()
        .map(|&len| (0..len).map(|_| rng.below(dims.vocab as u64) as usize).collect())
        .collect()
}

/// Load a snapshot from disk and export it. See [`export_state`].
pub fn export_snapshot<P: AsRef<Path>, Q: AsRef<Path>>(
    snapshot: P,
    out: Q,
    opts: &ExportOptions,
) -> Result<ExportReport> {
    let st = TrainState::load(snapshot.as_ref())
        .map_err(|e| anyhow!("loading snapshot {}: {e}", snapshot.as_ref().display()))?;
    export_state(&st, out, opts)
}

/// Fold, quantize, gate, and write one snapshot as a served FP8 model
/// artifact. Refuses (without writing) on missing/ill-shaped/non-finite
/// parameters or any fold-gate bit mismatch; deletes the file on a
/// readback mismatch.
pub fn export_state<Q: AsRef<Path>>(
    st: &TrainState,
    out: Q,
    opts: &ExportOptions,
) -> Result<ExportReport> {
    let out = out.as_ref();
    let dims = match &opts.dims {
        Some(d) => d.clone(),
        None => dims_of(&st.meta.size).ok_or_else(|| {
            anyhow!(
                "unknown size preset '{}' — pass explicit model dims in ExportOptions",
                st.meta.size
            )
        })?,
    };
    let (d, f, l) = (dims.d_model, dims.d_ff, dims.n_layers);

    // ---- gather + validate parameters
    let mut params: BTreeMap<&str, &[f32]> = BTreeMap::new();
    for (name, data) in &st.params {
        params.insert(name.as_str(), data.as_slice());
    }
    let mut tensors: BTreeMap<&'static str, Vec<f32>> = BTreeMap::new();
    for (name, want) in weight_specs(&dims) {
        let data = params.get(name).copied().ok_or_else(|| {
            if name == "w2" {
                anyhow!(
                    "snapshot has no 'w2' — serving expects the SwiGLU parameterization \
                     (GeLU-recipe snapshots are not servable)"
                )
            } else {
                anyhow!("snapshot is missing parameter '{name}'")
            }
        })?;
        if data.len() != want {
            bail!(
                "parameter '{name}': {} elements, expected {want} for dims {dims:?}",
                data.len()
            );
        }
        if let Some(x) = data.iter().find(|x| !x.is_finite()) {
            bail!("parameter '{name}' contains {x} — refusing to export a diverged snapshot");
        }
        tensors.insert(name, data.to_vec());
    }

    // ---- calibration: probe through the master weights, collect the
    // SwiGLU product's per-channel amax
    let probe = probe_tokens_for(&dims, opts.probe_seed, opts.probe_tokens);
    let unit_scales = vec![vec![1.0f32; f]; l];
    let f32_weights: BTreeMap<String, Stored> = tensors
        .iter()
        .map(|(&n, v)| (n.to_string(), Stored::F32(v.clone())))
        .collect();
    let mut calib = Engine::from_parts(
        dims.clone(),
        &st.meta.size,
        &st.meta.recipe,
        st.meta.step,
        opts.fmt,
        f32_weights,
        unit_scales,
        ServeMode::Folded,
    )?;
    let mut amax = Vec::new();
    calib.forward_collect_amax(&probe, &mut amax)?;
    let scales: Vec<Vec<f32>> = amax
        .iter()
        .map(|row| row.iter().map(|&a| clamp_pow2(fp8::compute_scale(opts.fmt, a))).collect())
        .collect();
    drop(calib);

    // ---- fold into w1/w3 (exact for pow2 scales)
    let mut w1f = tensors["w1"].clone();
    let mut w3f = tensors["w3"].clone();
    fold_scales(&mut w1f, &mut w3f, &scales, d, f)?;
    for (name, w) in [("w1", &w1f), ("w3", &w3f)] {
        if let Some(x) = w.iter().find(|x| !x.is_finite()) {
            bail!("folded {name} contains {x} — smoothing scales overflow these weights");
        }
    }
    tensors.insert("w1", w1f);
    tensors.insert("w3", w3f);

    // ---- quantize matrices to FP8 bytes (norm gains stay f32)
    let mut stored: BTreeMap<String, Stored> = BTreeMap::new();
    for (&name, data) in &tensors {
        let st = if NORM_GAINS.contains(&name) {
            Stored::F32(data.clone())
        } else {
            let (bytes, scale) = fp8::pack_scaled(opts.fmt, data);
            Stored::Fp8 { fmt: opts.fmt, scale, bytes }
        };
        stored.insert(name.to_string(), st);
    }

    // ---- the gate: folded-FP8 vs unfolded scaled reference, built
    // from the SAME quantized bytes, must agree bit-for-bit
    let mk = |weights: BTreeMap<String, Stored>, mode: ServeMode| {
        Engine::from_parts(
            dims.clone(),
            &st.meta.size,
            &st.meta.recipe,
            st.meta.step,
            opts.fmt,
            weights,
            scales.clone(),
            mode,
        )
    };
    let mut reference = mk(stored.clone(), ServeMode::ScaledReference)?;
    let mut folded = mk(stored.clone(), ServeMode::Folded)?;
    if opts.corrupt_fold_for_test {
        folded.corrupt_weight_byte_for_test("w1");
    }
    let folded_logits: Vec<f32> =
        folded.forward_full(&probe)?.into_iter().flatten().collect();
    let ref_logits: Vec<f32> =
        reference.forward_full(&probe)?.into_iter().flatten().collect();
    if let Some(x) = folded_logits.iter().find(|x| !x.is_finite()) {
        bail!("folded probe logits contain {x} — refusing to export");
    }
    let total = folded_logits.len();
    let diverged: Vec<usize> = folded_logits
        .iter()
        .zip(&ref_logits)
        .enumerate()
        .filter(|(_, (a, b))| a.to_bits() != b.to_bits())
        .map(|(i, _)| i)
        .collect();
    if let Some(&first) = diverged.first() {
        bail!(
            "fold mismatch: folded-FP8 and scaled-reference forwards diverge at {}/{} \
             probe positions (first at flat index {first}: folded {:e} [bits {:08x}] vs \
             reference {:e} [bits {:08x}]) — refusing to export",
            diverged.len(),
            total,
            folded_logits[first],
            folded_logits[first].to_bits(),
            ref_logits[first],
            ref_logits[first].to_bits(),
        );
    }
    let probe_crc = crc32_f32(&folded_logits);

    // ---- write the artifact
    let meta = obj(vec![
        ("kind", Json::Str(ARTIFACT_KIND.into())),
        ("version", Json::Num(ARTIFACT_VERSION as f64)),
        ("size", Json::Str(st.meta.size.clone())),
        ("recipe", Json::Str(st.meta.recipe.clone())),
        ("step", Json::Num(st.meta.step as f64)),
        // u64 seeds ride as strings (the repo's JSON numbers are f64)
        ("seed", Json::Str(st.meta.seed.to_string())),
        ("fmt", Json::Str(fmt_name(opts.fmt).into())),
        ("vocab", Json::Num(dims.vocab as f64)),
        ("d_model", Json::Num(dims.d_model as f64)),
        ("n_layers", Json::Num(dims.n_layers as f64)),
        ("n_heads", Json::Num(dims.n_heads as f64)),
        ("d_ff", Json::Num(dims.d_ff as f64)),
        ("seq_len", Json::Num(dims.seq_len as f64)),
        ("probe_seed", Json::Str(opts.probe_seed.to_string())),
        ("probe_tokens", Json::Num(opts.probe_tokens as f64)),
        ("probe_crc", Json::Num(probe_crc as f64)),
    ]);
    let mut w = Writer::new(&meta);
    let fp8_dtype = match opts.fmt {
        Fp8Format::E4M3 => Dtype::E4M3,
        Fp8Format::E5M2 => Dtype::E5M2,
    };
    for (&name, data) in &tensors {
        let dtype = if NORM_GAINS.contains(&name) { Dtype::F32 } else { fp8_dtype };
        w.tensor(&format!("model.{name}"), dtype, data);
    }
    let flat_scales: Vec<f32> = scales.iter().flatten().copied().collect();
    w.tensor("fold.scales", Dtype::F32, &flat_scales);
    let file_bytes = w.finish(out)?;

    // ---- readback: the artifact on disk must reproduce the gate CRC
    let mut back = Engine::load(out, ServeMode::Folded)?;
    let back_logits: Vec<f32> = back.forward_full(&probe)?.into_iter().flatten().collect();
    let back_crc = crc32_f32(&back_logits);
    if back_crc != probe_crc {
        let _ = std::fs::remove_file(out);
        bail!(
            "artifact readback mismatch: probe CRC {back_crc:08x} != exported {probe_crc:08x} \
             — artifact deleted"
        );
    }
    let (fp8_bytes, _, equiv) = back.resident_bytes();

    Ok(ExportReport {
        size: st.meta.size.clone(),
        step: st.meta.step,
        fmt: opts.fmt,
        scales,
        file_bytes,
        resident_fp8_bytes: fp8_bytes,
        f32_equiv_bytes: equiv,
        probe_crc,
        probe_len: total,
    })
}

/// Clamp a pow2 scale's exponent to ±[`SCALE_EXP_CLAMP`] (exactness-
/// preserving: the result is still a pow2).
fn clamp_pow2(s: f32) -> f32 {
    let e = s.log2().round() as i32;
    fp8::exp2i(e.clamp(-SCALE_EXP_CLAMP, SCALE_EXP_CLAMP))
}

/// SwiGLU products `h[t, f] = a1 · a2 · σ(a2)` for `[t, d]` activations
/// against `[d, f]` w1/w2, in the exact accumulation order of
/// [`crate::coordinator::folding`]'s reference MLP — the unit under the
/// fold bit-exactness property tests.
pub fn swiglu_products(
    xs: &[f32],
    w1: &[f32],
    w2: &[f32],
    t: usize,
    d: usize,
    f: usize,
) -> Vec<f32> {
    let mut h = vec![0.0f32; t * f];
    for ti in 0..t {
        for j in 0..f {
            let (mut a1, mut a2) = (0.0f32, 0.0f32);
            for i in 0..d {
                a1 += xs[ti * d + i] * w1[i * f + j];
                a2 += xs[ti * d + i] * w2[i * f + j];
            }
            h[ti * f + j] = a1 * a2 / (1.0 + (-a2).exp());
        }
    }
    h
}

/// Per-channel pow2 smoothing scales for a `[t, f]` SwiGLU product
/// (amax over finite magnitudes → [`fp8::compute_scale`], clamped).
pub fn channel_scales(fmt: Fp8Format, h: &[f32], t: usize, f: usize) -> Vec<f32> {
    let mut amax = vec![0.0f32; f];
    for ti in 0..t {
        for (j, slot) in amax.iter_mut().enumerate() {
            let a = h[ti * f + j].abs();
            if a.is_finite() && a > *slot {
                *slot = a;
            }
        }
    }
    amax.into_iter().map(|a| clamp_pow2(fp8::compute_scale(fmt, a))).collect()
}

/// Fabricate a servable synthetic snapshot (deterministic N(0, std²)
/// init matching the model's init spec). Test/bench helper — real
/// exports load campaign snapshots.
#[doc(hidden)]
pub fn synth_state_for(size: &str, dims: &ModelDims, seed: u64) -> TrainState {
    let mut rng = Rng::new(seed);
    let resid_std = 0.02 / (2.0 * dims.n_layers as f32).sqrt();
    let mut params = Vec::new();
    for (name, numel) in weight_specs(dims) {
        let data = if NORM_GAINS.contains(&name) {
            vec![1.0f32; numel]
        } else {
            let std = if name == "wo" || name == "w3" { resid_std } else { 0.02 };
            let mut v = vec![0.0f32; numel];
            rng.fill_normal(&mut v, std);
            v
        };
        params.push((name.to_string(), data));
    }
    TrainState {
        meta: SnapshotMeta {
            step: 7,
            recipe: "fp8_full".into(),
            size: size.into(),
            seed,
            corpus_seed: seed ^ 0xc0ffee,
            dp_workers: 1,
            streams: 1,
            stream_pods: 1,
            grad_accum: 1,
            steps: 10,
            warmup_steps: 2,
            amax_history: 16,
            margin_pow2: 0,
            recoveries: 0,
            m_fmt: "f32".into(),
            v_fmt: "f32".into(),
            moment_chunk: 64,
            numerics: "synthetic".into(),
            topology: "shard=w1;topo=p1;bucket=b4194304".into(),
        },
        params,
        m: Vec::new(),
        v: Vec::new(),
        scale: ScaleState { histories: Vec::new(), scales: Vec::new(), overflow_events: 0 },
        detector: DetectorState { ema: 0.0, warmed: false, diverged_at: None },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_pow2_is_pow2_and_bounded() {
        for s in [fp8::exp2i(120), fp8::exp2i(-120), 1.0, 0.25, 8.0] {
            let c = clamp_pow2(s);
            assert_eq!(c.to_bits() & 0x007f_ffff, 0, "{s} -> {c} not pow2");
            let e = c.log2().round() as i32;
            assert!(e.abs() <= SCALE_EXP_CLAMP, "{s} -> {c} exceeds clamp");
        }
    }

    #[test]
    fn probe_is_deterministic_and_in_range() {
        let dims = dims_of("tiny").unwrap();
        let a = probe_tokens_for(&dims, 1, 16);
        let b = probe_tokens_for(&dims, 1, 16);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
        assert!(a.iter().all(|s| s.iter().all(|&t| t < dims.vocab)));
        assert!(a[0].len() <= dims.seq_len && !a[1].is_empty());
    }
}
