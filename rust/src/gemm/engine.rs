//! Trainer wiring for the `fp8_gemm` recipes: what the step loop does
//! with the tile-wise quantizer every step.
//!
//! The grad graph itself is an AOT-compiled artifact, so the Rust side
//! cannot swap individual matmuls inside it. What it *can* do — and
//! what this engine does — is put every weight matrix the grad pass
//! consumes onto the per-tile FP8 grid on entry, put every gradient
//! matrix the optimizer consumes onto the per-tile E5M2 grid on exit,
//! and feed the observed per-site amaxes back into the delayed-scaling
//! [`crate::scaling::ScaleManager`]. Together with the FP8 artifact
//! recipes (which quantize the activations at the in-graph sites) this
//! closes the "fully-FP8 step" loop of PAPER.md §4; the standalone
//! kernels in [`super::matmul`] are the bit-exact reference for what
//! the fused compute does to tile-gridded operands.
//!
//! Schedule invariance (the property `rust/tests/collective.rs` and
//! the trainer tests guard jealously): both hooks are defined purely
//! per stream / per step —
//!
//! * the weight QDQ happens once per step, *before* any pass, on a
//!   persistent copy of the master params (Adam keeps updating the f32
//!   masters, exactly like the master-weight discipline of the FP8
//!   recipes);
//! * the gradient QDQ happens inside each stream's own pass, after the
//!   microbatch mean — the same point for the serial, phased and
//!   overlapped schedules — so grad merge order and bucket overlap
//!   cannot observe different bits.

use crate::coordinator::params::ParamStore;
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::HostTensor;

use super::tile::qdq_tilewise;
use super::GemmConfig;

/// One quantizable weight tensor: trailing two dims form the matrices,
/// leading dims stack them (one per layer for `[L, d, f]` weights).
struct MatSpec {
    /// index into `ParamStore::tensors`
    param_idx: usize,
    /// element offset of this tensor in the flat grad space
    flat_off: usize,
    /// matrix rows (second-to-last dim)
    rows: usize,
    /// matrix cols (last dim)
    cols: usize,
    /// number of stacked matrices (product of leading dims)
    count: usize,
    /// per stacked matrix: the weight amax site, if the manifest has
    /// a quantization site named after this param
    w_sites: Vec<Option<usize>>,
    /// per stacked matrix: the gradient amax site (`g_<name>`), if any
    g_sites: Vec<Option<usize>>,
}

/// Per-step state of the tile-wise FP8 GEMM path (see module doc).
pub struct GemmEngine {
    /// the operand formats and tile size in force
    pub cfg: GemmConfig,
    /// per-tile QDQ'd copy of the params — what the grad passes read
    pub qparams: ParamStore,
    mats: Vec<MatSpec>,
    /// per-site weight amaxes observed at the last
    /// [`refresh`](Self::refresh); zero where this engine feeds nothing
    site_amax: Vec<f32>,
}

impl GemmEngine {
    /// Build the engine for a manifest + freshly-initialized params.
    ///
    /// Quantizable tensors are the normal-init weights with at least
    /// two dims; norm gains (`init_std < 0`) and vectors stay f32 —
    /// the paper keeps those high-precision too.
    pub fn new(cfg: GemmConfig, man: &Manifest, params: &ParamStore) -> Self {
        let tensors = params
            .specs
            .iter()
            .zip(&params.tensors)
            .map(|(s, t)| HostTensor::from_f32(&s.shape, t.f32s().to_vec()))
            .collect();
        let qparams = ParamStore { specs: params.specs.clone(), tensors };
        let mut mats = Vec::new();
        let mut flat_off = 0usize;
        for (idx, spec) in params.specs.iter().enumerate() {
            let numel = spec.numel();
            if spec.init_std >= 0.0 && spec.shape.len() >= 2 {
                let rows = spec.shape[spec.shape.len() - 2];
                let cols = spec.shape[spec.shape.len() - 1];
                let count = numel / (rows * cols).max(1);
                let g_name = format!("g_{}", spec.name);
                let w_sites =
                    (0..count).map(|l| man.site_index(l, &spec.name)).collect();
                let g_sites = (0..count).map(|l| man.site_index(l, &g_name)).collect();
                mats.push(MatSpec { param_idx: idx, flat_off, rows, cols, count, w_sites, g_sites });
            }
            flat_off += numel;
        }
        let n_sites = man.n_layers * man.sites_per_layer.len();
        Self { cfg, qparams, mats, site_amax: vec![0.0; n_sites] }
    }

    /// Once per step, before any pass: copy the f32 masters and put
    /// every weight matrix onto the per-tile `w_fmt` grid, recording
    /// per-matrix amaxes for the site feed. Deterministic given the
    /// masters — every stream sees the same quantized weights.
    pub fn refresh(&mut self, params: &ParamStore) {
        self.site_amax.fill(0.0);
        for (dst, src) in self.qparams.tensors.iter_mut().zip(&params.tensors) {
            dst.f32s_mut().copy_from_slice(src.f32s());
        }
        for m in &self.mats {
            let per = m.rows * m.cols;
            let data = self.qparams.tensors[m.param_idx].f32s_mut();
            for l in 0..m.count {
                let sub = &mut data[l * per..(l + 1) * per];
                let amax = qdq_tilewise(self.cfg.w_fmt, self.cfg.tile, sub, m.rows, m.cols);
                if let Some(s) = m.w_sites[l] {
                    if s < self.site_amax.len() {
                        self.site_amax[s] = self.site_amax[s].max(amax);
                    }
                }
            }
        }
    }

    /// Per stream, after the microbatch mean: put every weight-shaped
    /// gradient matrix of the flat buffer onto the per-tile `g_fmt`
    /// grid (E5M2 by default) and max-fold the observed amaxes into
    /// this pass's amax vector at the `g_*` sites, alongside the
    /// weight amaxes from the last [`refresh`](Self::refresh). The
    /// max-fold is idempotent and order-free, so merging passes in any
    /// schedule yields the same amax vector.
    pub fn qdq_grads(&self, grads: &mut [f32], amax: &mut [f32]) {
        for m in &self.mats {
            let per = m.rows * m.cols;
            for l in 0..m.count {
                let off = m.flat_off + l * per;
                if off + per > grads.len() {
                    break; // foreign (non-param) flat layout: feed nothing
                }
                let sub = &mut grads[off..off + per];
                let a = qdq_tilewise(self.cfg.g_fmt, self.cfg.tile, sub, m.rows, m.cols);
                if let Some(s) = m.g_sites[l] {
                    if s < amax.len() {
                        amax[s] = amax[s].max(a);
                    }
                }
            }
        }
        for (dst, &w) in amax.iter_mut().zip(&self.site_amax) {
            if w > 0.0 {
                *dst = dst.max(w);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn manifest() -> Manifest {
        let j = Json::parse(
            r#"{"kind":"grad","n_layers":2,
                "sites_per_layer":["w1","g_w1"],
                "params":[
                  {"name":"ln_1","shape":[2,8],"init_std":-1.0},
                  {"name":"w1","shape":[2,8,6],"init_std":0.02},
                  {"name":"head","shape":[8,4],"init_std":0.02}]}"#,
        )
        .unwrap();
        Manifest::from_json("t".into(), j).unwrap()
    }

    fn engine() -> (GemmEngine, ParamStore) {
        let man = manifest();
        let params = ParamStore::init(&man, 7);
        let cfg = GemmConfig { tile: 4, ..Default::default() };
        (GemmEngine::new(cfg, &man, &params), params)
    }

    #[test]
    fn refresh_grids_weights_and_leaves_gains_alone() {
        let (mut e, params) = engine();
        e.refresh(&params);
        // norm gains copied verbatim
        assert_eq!(e.qparams.tensors[0].f32s(), params.tensors[0].f32s());
        // w1 landed on the E4M3 tile grid: QDQ is idempotent
        let w1 = e.qparams.tensors[1].f32s().to_vec();
        let mut again = w1.clone();
        for l in 0..2 {
            qdq_tilewise(e.cfg.w_fmt, e.cfg.tile, &mut again[l * 48..(l + 1) * 48], 8, 6);
        }
        for (a, b) in w1.iter().zip(&again) {
            assert_eq!(a.to_bits(), b.to_bits(), "weight copy must already be on-grid");
        }
        // ... and differs from the masters (0.02-std weights are off-grid)
        assert_ne!(e.qparams.tensors[1].f32s(), params.tensors[1].f32s());
        // weight amax fed at the per-layer w1 sites (indices 0 and 2)
        assert!(e.site_amax[0] > 0.0 && e.site_amax[2] > 0.0);
        assert_eq!(e.site_amax[1], 0.0, "no weight feed at the g_w1 site");
    }

    #[test]
    fn refresh_is_deterministic_and_tracks_masters() {
        let (mut e, mut params) = engine();
        e.refresh(&params);
        let first = e.qparams.tensors[1].f32s().to_vec();
        e.refresh(&params);
        assert_eq!(e.qparams.tensors[1].f32s(), &first[..], "same masters, same grid");
        params.tensors[1].f32s_mut()[0] = 3.0;
        e.refresh(&params);
        assert_ne!(e.qparams.tensors[1].f32s(), &first[..], "master update must show up");
    }

    #[test]
    fn qdq_grads_grids_weight_grads_and_feeds_amax() {
        let (mut e, params) = engine();
        e.refresh(&params);
        let n: usize = params.specs.iter().map(|s| s.numel()).sum();
        let mut grads: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.37).sin() * 0.1).collect();
        let before = grads.clone();
        let mut amax = vec![0.0f32; 4];
        e.qdq_grads(&mut grads, &mut amax);
        // the ln_1 slice (first 16 elements) is untouched
        assert_eq!(&grads[..16], &before[..16]);
        // the w1 slice moved onto the E5M2 grid
        assert_ne!(&grads[16..16 + 96], &before[16..16 + 96]);
        // grad amax fed at g_w1 sites (1 and 3), weight amax at 0 and 2
        assert!(amax[1] > 0.0 && amax[3] > 0.0);
        assert!(amax[0] > 0.0 && amax[2] > 0.0);
        // idempotent: a second QDQ of the already-gridded grads is a no-op
        let mut twice = grads.clone();
        e.qdq_grads(&mut twice, &mut amax);
        for (a, b) in grads.iter().zip(&twice) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
