//! Per-tile pow2 amax quantization for the FP8 GEMM operands.
//!
//! A row-major `[rows, cols]` f32 matrix is cut into `tile × tile`
//! blocks (ragged at the right/bottom edges); every block gets its own
//! just-in-time pow2 scale and is encoded to FP8 bytes through the
//! table-driven codec in [`crate::fp8::bulk`]. The documented scale
//! rule, pinned by the property suite in `rust/tests/property.rs`:
//!
//! * **amax** is the maximum `|x|` over the tile's *finite* elements
//!   only. NaN and ±Inf are invisible to the fold, so a poisoned tile
//!   still picks a finite scale and a poisoned *matrix* never perturbs
//!   the scale of any other tile.
//! * **scale** is [`fp8::compute_scale`]`(fmt, amax)` — the same pow2
//!   policy as the delayed-scaling state machine and the Python side:
//!   `2^floor(log2(fmt.max / amax))`, halved if `amax * scale` still
//!   overshoots. An all-zero (or all-non-finite) tile has amax 0,
//!   which the `1e-12` clamp inside `compute_scale` maps to the
//!   largest representable pow2 scale — zeros encode to zero under any
//!   scale, so the choice is benign and deterministic.
//! * **non-finite elements** encode through the scalar codec with no
//!   scaling or saturation: NaN stays NaN in either format, and ±Inf
//!   becomes ±Inf in E5M2 / NaN in E4M3. Unlike the wire codec's
//!   [`fp8::bulk::pack_scaled_into`] (which clamps, because a
//!   collective must deliver *bounded* payloads), the GEMM must not
//!   turn an Inf into a plausible ±448 contribution — a poisoned tile
//!   poisons its dot products, and the divergence detector sees it.
//!
//! Dequantization is `decode(byte) / scale` with real division (not a
//! reciprocal multiply), bit-identical to the scalar reference
//! `Fp8Format::decode` for every code — the differential suite in
//! `rust/tests/gemm.rs` holds the fast and reference paths to equality
//! bit for bit.

use crate::fp8::{self, bulk, Fp8Format};

/// A tile-quantized matrix: FP8 bytes in the source's row-major layout
/// plus one pow2 scale (and the finite amax it was chosen from) per
/// `tile × tile` block.
#[derive(Clone, Debug)]
pub struct TileQuant {
    /// element format of `bytes`
    pub fmt: Fp8Format,
    /// tile edge length (blocks are `tile × tile`, ragged at the edges)
    pub tile: usize,
    /// matrix rows
    pub rows: usize,
    /// matrix cols
    pub cols: usize,
    /// FP8 codes, row-major `[rows, cols]` (same layout as the input)
    pub bytes: Vec<u8>,
    /// per-tile pow2 scales, row-major `[tile_rows, tile_cols]`
    pub scales: Vec<f32>,
    /// per-tile finite amaxes the scales were chosen from (same layout)
    pub amaxes: Vec<f32>,
}

#[inline]
fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Finite-only amax over one tile of a row-major matrix.
#[inline]
fn tile_finite_amax(data: &[f32], cols: usize, r0: usize, r1: usize, c0: usize, c1: usize) -> f32 {
    let mut a = 0.0f32;
    for i in r0..r1 {
        for &x in &data[i * cols + c0..i * cols + c1] {
            if x.is_finite() {
                a = a.max(x.abs());
            }
        }
    }
    a
}

/// One element through the tile encoder: finite values are scaled,
/// clamped to the format range and encoded on the hot path; non-finite
/// values go straight through the scalar codec (no scale, no clamp —
/// see the module doc on Inf propagation).
#[inline]
fn encode_elem(fmt: Fp8Format, p: bulk::EncodeParams, max: f32, scale: f32, x: f32) -> u8 {
    if x.is_finite() {
        bulk::encode_one(fmt, p, (x * scale).clamp(-max, max))
    } else {
        fmt.encode(x)
    }
}

impl TileQuant {
    /// Quantize a row-major `[rows, cols]` f32 matrix with per-tile
    /// pow2 scaling (see the module doc for the exact scale rule).
    pub fn quantize(fmt: Fp8Format, tile: usize, data: &[f32], rows: usize, cols: usize) -> Self {
        assert!(tile >= 1, "gemm tile must be >= 1");
        assert_eq!(data.len(), rows * cols, "matrix data length mismatch");
        let (tr, tc) = (ceil_div(rows, tile.max(1)), ceil_div(cols, tile.max(1)));
        let mut scales = vec![1.0f32; tr * tc];
        let mut amaxes = vec![0.0f32; tr * tc];
        let mut bytes = vec![0u8; data.len()];
        let p = bulk::EncodeParams::of(fmt);
        let max = fmt.max();
        for ti in 0..tr {
            let (r0, r1) = (ti * tile, (ti * tile + tile).min(rows));
            for tj in 0..tc {
                let (c0, c1) = (tj * tile, (tj * tile + tile).min(cols));
                let a = tile_finite_amax(data, cols, r0, r1, c0, c1);
                let s = fp8::compute_scale(fmt, a);
                amaxes[ti * tc + tj] = a;
                scales[ti * tc + tj] = s;
                for i in r0..r1 {
                    for j in c0..c1 {
                        bytes[i * cols + j] = encode_elem(fmt, p, max, s, data[i * cols + j]);
                    }
                }
            }
        }
        Self { fmt, tile, rows, cols, bytes, scales, amaxes }
    }

    /// Tile-grid shape `(tile_rows, tile_cols)`.
    pub fn tiles(&self) -> (usize, usize) {
        (ceil_div(self.rows, self.tile), ceil_div(self.cols, self.tile))
    }

    /// The pow2 scale governing element `(i, j)`.
    pub fn scale_at(&self, i: usize, j: usize) -> f32 {
        let tc = ceil_div(self.cols, self.tile);
        self.scales[(i / self.tile) * tc + j / self.tile]
    }

    /// Scalar-reference decode of element `(i, j)`:
    /// `Fp8Format::decode(byte) / scale`. The differential tests pin
    /// [`dequantize_buf`](Self::dequantize_buf) to this, bit for bit.
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.fmt.decode(self.bytes[i * self.cols + j]) / self.scale_at(i, j)
    }

    /// Bulk decode (LUT + per-tile descale division) into an
    /// exact-size `[rows * cols]` buffer.
    pub fn dequantize_buf(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols, "dequantize buffer size mismatch");
        let lut = bulk::decode_lut(self.fmt);
        let (tr, tc) = self.tiles();
        for ti in 0..tr {
            let (r0, r1) = (ti * self.tile, (ti * self.tile + self.tile).min(self.rows));
            for tj in 0..tc {
                let (c0, c1) = (tj * self.tile, (tj * self.tile + self.tile).min(self.cols));
                let s = self.scales[ti * tc + tj];
                for i in r0..r1 {
                    for j in c0..c1 {
                        out[i * self.cols + j] = lut[self.bytes[i * self.cols + j] as usize] / s;
                    }
                }
            }
        }
    }

    /// Finite amax of the whole matrix (max over the per-tile amaxes) —
    /// the value the trainer feeds back into the delayed-scaling
    /// [`crate::scaling::ScaleManager`] for this operand's site.
    pub fn amax(&self) -> f32 {
        self.amaxes.iter().fold(0.0f32, |a, &x| a.max(x))
    }
}

/// In-place tile-wise quantize–dequantize: every element is replaced
/// by its FP8 tile-grid representative, without materializing the byte
/// matrix. Returns the matrix finite amax (max over tile amaxes).
///
/// Bit-identical to `TileQuant::quantize(..).dequantize_buf(..)` — the
/// two share the private `encode_elem` helper and the LUT/division
/// decode — which the
/// inline tests below and `rust/tests/gemm.rs` pin. This is the
/// allocation-free path the trainer uses on weight copies and per-
/// stream gradient buffers every step.
pub fn qdq_tilewise(fmt: Fp8Format, tile: usize, data: &mut [f32], rows: usize, cols: usize) -> f32 {
    assert!(tile >= 1, "gemm tile must be >= 1");
    assert_eq!(data.len(), rows * cols, "matrix data length mismatch");
    let p = bulk::EncodeParams::of(fmt);
    let lut = bulk::decode_lut(fmt);
    let max = fmt.max();
    let (tr, tc) = (ceil_div(rows, tile), ceil_div(cols, tile));
    let mut mat_amax = 0.0f32;
    for ti in 0..tr {
        let (r0, r1) = (ti * tile, (ti * tile + tile).min(rows));
        for tj in 0..tc {
            let (c0, c1) = (tj * tile, (tj * tile + tile).min(cols));
            let a = tile_finite_amax(data, cols, r0, r1, c0, c1);
            let s = fp8::compute_scale(fmt, a);
            mat_amax = mat_amax.max(a);
            for i in r0..r1 {
                for x in &mut data[i * cols + c0..i * cols + c1] {
                    *x = lut[encode_elem(fmt, p, max, s, *x) as usize] / s;
                }
            }
        }
    }
    mat_amax
}

/// Multiply every element by the exact power of two `2^e` (ldexp) —
/// the building block of the Smooth-SwiGLU fold
/// ([`crate::coordinator::folding`]). Pow2 multiplication only shifts
/// the f32 exponent, so it commutes with the tile quantization grid:
/// `qdq(x · 2^e) == qdq(x) · 2^e` bit for bit as long as neither side
/// over/underflows f32 (pinned by `rust/tests/property.rs`).
pub fn scale_pow2(data: &mut [f32], e: i32) {
    let s = fp8::exp2i(e);
    for x in data.iter_mut() {
        *x *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{E4M3, E5M2};

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.731).sin() * 3.0).collect()
    }

    #[test]
    fn qdq_tilewise_matches_quantize_dequantize() {
        for fmt in [E4M3, E5M2] {
            for (rows, cols, tile) in [(7, 5, 3), (8, 8, 4), (1, 9, 4), (9, 1, 2), (16, 16, 16)] {
                let data = ramp(rows * cols);
                let q = TileQuant::quantize(fmt, tile, &data, rows, cols);
                let mut fast = vec![0.0f32; rows * cols];
                q.dequantize_buf(&mut fast);
                let mut inplace = data.clone();
                let amax = qdq_tilewise(fmt, tile, &mut inplace, rows, cols);
                for (a, b) in fast.iter().zip(&inplace) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?} {rows}x{cols} t{tile}");
                }
                assert_eq!(amax.to_bits(), q.amax().to_bits());
            }
        }
    }

    #[test]
    fn scalar_get_matches_bulk_dequantize() {
        let (rows, cols, tile) = (10, 13, 4);
        let data = ramp(rows * cols);
        for fmt in [E4M3, E5M2] {
            let q = TileQuant::quantize(fmt, tile, &data, rows, cols);
            let mut out = vec![0.0f32; rows * cols];
            q.dequantize_buf(&mut out);
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(q.get(i, j).to_bits(), out[i * cols + j].to_bits());
                }
            }
        }
    }

    #[test]
    fn tiles_pick_independent_scales() {
        // one huge element in the top-left tile must not move the
        // bottom-right tile's scale
        let (rows, cols, tile) = (8, 8, 4);
        let mut data = vec![0.01f32; rows * cols];
        data[0] = 400.0;
        let q = TileQuant::quantize(E4M3, tile, &data, rows, cols);
        assert_eq!(q.tiles(), (2, 2));
        assert!(q.scales[0] < q.scales[3], "outlier tile scale {} !< {}", q.scales[0], q.scales[3]);
        assert_eq!(q.scale_at(0, 0), q.scales[0]);
        assert_eq!(q.scale_at(7, 7), q.scales[3]);
    }

    #[test]
    fn nonfinite_elements_propagate_without_scale_damage() {
        let (rows, cols, tile) = (4, 8, 4);
        let mut data = ramp(rows * cols);
        let clean = TileQuant::quantize(E4M3, tile, &data, rows, cols);
        data[1] = f32::NAN;
        data[2] = f32::INFINITY;
        let q = TileQuant::quantize(E4M3, tile, &data, rows, cols);
        // scales identical to the clean matrix: non-finite invisible
        for (a, b) in clean.scales.iter().zip(&q.scales) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(q.get(0, 1).is_nan(), "NaN survives");
        assert!(q.get(0, 2).is_nan(), "E4M3 has no Inf: encodes to NaN");
        let q5 = TileQuant::quantize(E5M2, tile, &data, rows, cols);
        assert!(q5.get(0, 2).is_infinite(), "E5M2 keeps Inf as Inf");
        // a finite neighbor in the same tile is still fine
        assert!((q.get(0, 3) - data[3]).abs() <= data[3].abs() * 0.08 + 1e-3);
    }

    #[test]
    fn zero_tile_has_documented_scale_and_roundtrips_to_zero() {
        let data = vec![0.0f32; 16];
        for fmt in [E4M3, E5M2] {
            let q = TileQuant::quantize(fmt, 4, &data, 4, 4);
            assert_eq!(q.amaxes[0], 0.0);
            assert_eq!(q.scales[0], fp8::compute_scale(fmt, 0.0));
            for i in 0..4 {
                for j in 0..4 {
                    assert_eq!(q.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn scale_pow2_is_exact() {
        let mut a = ramp(64);
        let b = a.clone();
        scale_pow2(&mut a, 3);
        scale_pow2(&mut a, -3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
