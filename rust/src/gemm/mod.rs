//! Tile-wise-scaled FP8 GEMM — the paper's *compute* path, in Rust.
//!
//! PAPER.md §4 claims stable FP8 compute over trillion-token horizons;
//! until this module, the repo exercised FP8 only in the optimizer
//! moments, the checkpoints and on the wire, while the grad passes
//! accumulated in f32 end to end. This subsystem adds the missing
//! column, following "Towards Fully FP8 GEMM LLM Training at Scale"
//! (PAPERS.md): per-tile (default 128 × 128, matching the MXU systolic
//! array) pow2 amax scaling, E4M3 weights/activations, E5M2 gradients,
//! and f32 accumulation in a pinned summation order so bit-exactness
//! is testable rather than aspirational.
//!
//! Layout of the subsystem:
//!
//! * [`tile`] — the per-tile quantizer ([`TileQuant`],
//!   [`qdq_tilewise`]): finite-only amax per tile, pow2 scale via
//!   [`crate::fp8::compute_scale`], NaN/Inf transparent, encode/decode
//!   through the table-driven [`crate::fp8::bulk`] codec.
//! * [`matmul`] — forward `Y = X·W` and backward `dX = dY·Wᵀ`,
//!   `dW = Xᵀ·dY` kernels, each with a scalar serial reference the
//!   fast path must match bit for bit (`rust/tests/gemm.rs`).
//! * [`engine`] — the trainer wiring for the `fp8_gemm` /
//!   `fp8_gemm_smooth` recipes: per-tile QDQ of the weight copy the
//!   grad passes consume, per-stream E5M2 QDQ of the accumulated
//!   gradients, and per-site amax feedback into the delayed-scaling
//!   [`crate::scaling::ScaleManager`].
//!
//! Smooth-SwiGLU's per-channel pow2 scales
//! ([`crate::coordinator::folding`], `examples/smooth_swiglu_inference.rs`)
//! commute with the tile quantization grid — multiplying by 2^e only
//! shifts the f32 exponent, so `qdq(x · 2^e) == qdq(x) · 2^e` bit for
//! bit inside the safe exponent band (pinned by the property suite).
//! That commutation is exactly why folding the scales into `w1`/`w3`
//! costs nothing in quantization fidelity.

pub mod engine;
pub mod matmul;
pub mod tile;

pub use engine::GemmEngine;
pub use matmul::{
    fp8_linear_bwd, fp8_linear_fwd, matmul_f32, matmul_f32_naive, matmul_fp8, matmul_fp8_ref,
    Matrix,
};
pub use tile::{qdq_tilewise, scale_pow2, TileQuant};

use crate::fp8::{Fp8Format, E4M3, E5M2};

/// Per-operand configuration of the tile-wise FP8 GEMM path, built
/// from the `gemm_*` config keys (see docs/OPERATIONS.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmConfig {
    /// tile edge length (tiles are `tile × tile`; default 128)
    pub tile: usize,
    /// weight operand format (default E4M3)
    pub w_fmt: Fp8Format,
    /// activation operand format (default E4M3)
    pub x_fmt: Fp8Format,
    /// gradient operand format (default E5M2 — gradients need range)
    pub g_fmt: Fp8Format,
}

impl Default for GemmConfig {
    fn default() -> Self {
        Self { tile: 128, w_fmt: E4M3, x_fmt: E4M3, g_fmt: E5M2 }
    }
}

impl GemmConfig {
    /// Build from the raw config-key values, validating tile and
    /// format names (`"e4m3"` / `"e5m2"`). Shared by the config
    /// loader's validation and `Trainer::new` so both reject the same
    /// inputs.
    pub fn from_keys(tile: usize, w_fmt: &str, x_fmt: &str, g_fmt: &str) -> Result<Self, String> {
        if tile < 1 {
            return Err("gemm_tile must be >= 1".into());
        }
        Ok(Self {
            tile,
            w_fmt: parse_fmt(w_fmt)?,
            x_fmt: parse_fmt(x_fmt)?,
            g_fmt: parse_fmt(g_fmt)?,
        })
    }
}

/// Parse an FP8 format name as the `gemm_*_fmt` config keys spell it.
pub fn parse_fmt(name: &str) -> Result<Fp8Format, String> {
    match name {
        "e4m3" => Ok(E4M3),
        "e5m2" => Ok(E5M2),
        other => Err(format!("unknown FP8 format '{other}' (expected e4m3 or e5m2)")),
    }
}

/// Canonical config-key spelling of an FP8 format (inverse of
/// [`parse_fmt`]; used by the numerics fingerprint).
pub fn fmt_name(fmt: Fp8Format) -> &'static str {
    match fmt {
        Fp8Format::E4M3 => "e4m3",
        Fp8Format::E5M2 => "e5m2",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_from_keys_validates() {
        let c = GemmConfig::from_keys(64, "e4m3", "e4m3", "e5m2").unwrap();
        assert_eq!(c, GemmConfig { tile: 64, w_fmt: E4M3, x_fmt: E4M3, g_fmt: E5M2 });
        assert!(GemmConfig::from_keys(0, "e4m3", "e4m3", "e5m2").is_err());
        assert!(GemmConfig::from_keys(64, "fp16", "e4m3", "e5m2").is_err());
        assert_eq!(GemmConfig::default(), GemmConfig::from_keys(128, "e4m3", "e4m3", "e5m2").unwrap());
    }

    #[test]
    fn fmt_name_roundtrips() {
        for fmt in [E4M3, E5M2] {
            assert_eq!(parse_fmt(fmt_name(fmt)).unwrap(), fmt);
        }
    }
}
