//! Tile-wise-scaled FP8 matmul, forward and backward, with f32
//! accumulation in a **pinned summation order**.
//!
//! The order contract, which every kernel in this module obeys and the
//! differential suite in `rust/tests/gemm.rs` enforces bit for bit:
//!
//! > each output element `C[i, j]` is one f32 accumulator, fed the
//! > products `op(A)[i, k] · op(B)[k, j]` in ascending `k`, starting
//! > from `0.0`.
//!
//! Tiles therefore affect only the *quantization grid* of the
//! operands, never the summation order: the cache-friendly `i-k-j`
//! kernel below feeds every `C[i, j]` in exactly the same order as the
//! naive `i-j-k` triple loop, so the fast path and the scalar serial
//! reference are bit-identical by construction (f32 addition is not
//! associative — pinning the order is what makes "bit-exact" a
//! meaningful test rather than a tolerance).
//!
//! FP8 operands decode as `decode(byte) / tile_scale` (real division;
//! see [`super::tile`]), are never re-rounded, and accumulate in f32 —
//! the recipe of "Towards Fully FP8 GEMM LLM Training at Scale" and
//! PAPER.md §4's compute path. NaN is transparent: a poisoned operand
//! element poisons exactly the output row/column pairs whose dot
//! products consume it.

use super::tile::TileQuant;
use super::GemmConfig;

/// A dense row-major f32 result matrix.
#[derive(Clone, Debug)]
pub struct Matrix {
    /// row-major `[rows, cols]` values
    pub data: Vec<f32>,
    /// result rows
    pub rows: usize,
    /// result cols
    pub cols: usize,
}

impl Matrix {
    /// Element accessor (row-major).
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
}

/// Dims of `op(M)` for a `[rows, cols]` operand under an optional
/// transpose.
#[inline]
fn op_dims(rows: usize, cols: usize, trans: bool) -> (usize, usize) {
    if trans {
        (cols, rows)
    } else {
        (rows, cols)
    }
}

/// Materialize `op(M)` as a row-major copy (gather transpose).
fn gather(src: &[f32], rows: usize, cols: usize, trans: bool) -> Vec<f32> {
    if !trans {
        return src.to_vec();
    }
    let mut out = vec![0.0f32; src.len()];
    for i in 0..rows {
        for j in 0..cols {
            out[j * rows + i] = src[i * cols + j];
        }
    }
    out
}

/// Shared shape check: `op(A)` must be `[m, k]`, `op(B)` `[k, n]`.
fn check_shapes(
    (ar, ac): (usize, usize),
    ta: bool,
    (br, bc): (usize, usize),
    tb: bool,
) -> Result<(usize, usize, usize), String> {
    let (m, k) = op_dims(ar, ac, ta);
    let (kb, n) = op_dims(br, bc, tb);
    if k != kb {
        return Err(format!(
            "gemm shape mismatch: op(A) is [{m}, {k}] but op(B) is [{kb}, {n}]"
        ));
    }
    Ok((m, n, k))
}

/// The pinned-order f32 kernel over pre-materialized row-major
/// operands: `i-k-j` loop order, one accumulator per output element,
/// ascending `k` — see the module doc for why this is bit-identical to
/// the naive `i-j-k` reference.
fn kernel_f32(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// f32-mode tiled GEMM: `C = op(A) · op(B)` over plain f32 operands
/// under the pinned accumulation order. Used as the bf16-free baseline
/// in benches and as the carrier kernel of [`matmul_fp8`].
pub fn matmul_f32(
    a: &[f32],
    a_rows: usize,
    a_cols: usize,
    trans_a: bool,
    b: &[f32],
    b_rows: usize,
    b_cols: usize,
    trans_b: bool,
) -> Result<Matrix, String> {
    assert_eq!(a.len(), a_rows * a_cols, "operand A length mismatch");
    assert_eq!(b.len(), b_rows * b_cols, "operand B length mismatch");
    let (m, n, k) = check_shapes((a_rows, a_cols), trans_a, (b_rows, b_cols), trans_b)?;
    let ae = gather(a, a_rows, a_cols, trans_a);
    let be = gather(b, b_rows, b_cols, trans_b);
    Ok(Matrix { data: kernel_f32(&ae, &be, m, n, k), rows: m, cols: n })
}

/// Naive serial f32 reference: direct `i-j-k` triple loop indexing the
/// original (untransposed) operand storage. The accumulation order per
/// output element is identical to [`matmul_f32`]'s — ascending `k`
/// into one f32 accumulator — which the differential tests hold to
/// bit-equality.
pub fn matmul_f32_naive(
    a: &[f32],
    a_rows: usize,
    a_cols: usize,
    trans_a: bool,
    b: &[f32],
    b_rows: usize,
    b_cols: usize,
    trans_b: bool,
) -> Result<Matrix, String> {
    assert_eq!(a.len(), a_rows * a_cols, "operand A length mismatch");
    assert_eq!(b.len(), b_rows * b_cols, "operand B length mismatch");
    let (m, n, k) = check_shapes((a_rows, a_cols), trans_a, (b_rows, b_cols), trans_b)?;
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                let av = if trans_a { a[kk * a_cols + i] } else { a[i * a_cols + kk] };
                let bv = if trans_b { b[j * b_cols + kk] } else { b[kk * b_cols + j] };
                acc += av * bv;
            }
            c[i * n + j] = acc;
        }
    }
    Ok(Matrix { data: c, rows: m, cols: n })
}

/// Tile-wise-scaled FP8 GEMM: bulk-decode both operands on their tile
/// grids (`LUT / scale`, bit-identical to the scalar decode) and run
/// the pinned-order f32 kernel. `C = op(A) · op(B)`.
pub fn matmul_fp8(
    a: &TileQuant,
    trans_a: bool,
    b: &TileQuant,
    trans_b: bool,
) -> Result<Matrix, String> {
    let (m, n, k) = check_shapes((a.rows, a.cols), trans_a, (b.rows, b.cols), trans_b)?;
    let mut ad = vec![0.0f32; a.rows * a.cols];
    a.dequantize_buf(&mut ad);
    let mut bd = vec![0.0f32; b.rows * b.cols];
    b.dequantize_buf(&mut bd);
    let ae = gather(&ad, a.rows, a.cols, trans_a);
    let be = gather(&bd, b.rows, b.cols, trans_b);
    Ok(Matrix { data: kernel_f32(&ae, &be, m, n, k), rows: m, cols: n })
}

/// Scalar serial FP8 reference: decodes each element on the fly
/// through the scalar codec ([`TileQuant::get`]) inside a naive
/// `i-j-k` loop. [`matmul_fp8`] must match this bit for bit across
/// every shape × format × transpose combination (pinned by
/// `rust/tests/gemm.rs`).
pub fn matmul_fp8_ref(
    a: &TileQuant,
    trans_a: bool,
    b: &TileQuant,
    trans_b: bool,
) -> Result<Matrix, String> {
    let (m, n, k) = check_shapes((a.rows, a.cols), trans_a, (b.rows, b.cols), trans_b)?;
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                let av = if trans_a { a.get(kk, i) } else { a.get(i, kk) };
                let bv = if trans_b { b.get(j, kk) } else { b.get(kk, j) };
                acc += av * bv;
            }
            c[i * n + j] = acc;
        }
    }
    Ok(Matrix { data: c, rows: m, cols: n })
}

/// Forward pass of a linear layer `Y = X · W` with per-tile
/// quantization of both operands (`X` in `cfg.x_fmt`, `W` in
/// `cfg.w_fmt`). Returns the output along with the quantized operands
/// so the backward pass can reuse them — exactly the buffers a real
/// kernel would keep resident.
pub fn fp8_linear_fwd(
    cfg: &GemmConfig,
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
) -> Result<(Matrix, TileQuant, TileQuant), String> {
    let xq = TileQuant::quantize(cfg.x_fmt, cfg.tile, x, m, k);
    let wq = TileQuant::quantize(cfg.w_fmt, cfg.tile, w, k, n);
    let y = matmul_fp8(&xq, false, &wq, false)?;
    Ok((y, xq, wq))
}

/// Backward pass of `Y = X · W` given the upstream gradient `dY`
/// (quantized per tile to `cfg.g_fmt`, E5M2 by default — gradients
/// need E5M2's range, PAPER.md §3):
///
/// * `dX = dY · Wᵀ`
/// * `dW = Xᵀ · dY`
///
/// Both are tile-wise-scaled FP8 GEMMs under the pinned f32
/// accumulation order.
pub fn fp8_linear_bwd(
    cfg: &GemmConfig,
    dy: &[f32],
    xq: &TileQuant,
    wq: &TileQuant,
) -> Result<(Matrix, Matrix), String> {
    let (m, n) = (xq.rows, wq.cols);
    assert_eq!(dy.len(), m * n, "dY length mismatch");
    let dyq = TileQuant::quantize(cfg.g_fmt, cfg.tile, dy, m, n);
    let dx = matmul_fp8(&dyq, false, wq, true)?;
    let dw = matmul_fp8(xq, true, &dyq, false)?;
    Ok((dx, dw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{E4M3, E5M2};

    fn ramp(n: usize, phase: f32) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.173 + phase).sin() * 2.0).collect()
    }

    #[test]
    fn f32_tiled_matches_naive_bitwise() {
        let (m, k, n) = (9, 7, 11);
        let a = ramp(m * k, 0.0);
        let b = ramp(k * n, 1.0);
        let fast = matmul_f32(&a, m, k, false, &b, k, n, false).unwrap();
        let slow = matmul_f32_naive(&a, m, k, false, &b, k, n, false).unwrap();
        for (x, y) in fast.data.iter().zip(&slow.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn fp8_fast_matches_scalar_reference_bitwise() {
        let (m, k, n) = (10, 6, 8);
        let a = TileQuant::quantize(E4M3, 4, &ramp(m * k, 0.2), m, k);
        let b = TileQuant::quantize(E5M2, 4, &ramp(k * n, 0.9), k, n);
        let fast = matmul_fp8(&a, false, &b, false).unwrap();
        let slow = matmul_fp8_ref(&a, false, &b, false).unwrap();
        for (x, y) in fast.data.iter().zip(&slow.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let a = ramp(6, 0.0);
        let b = ramp(6, 0.0);
        assert!(matmul_f32(&a, 2, 3, false, &b, 2, 3, false).is_err());
        assert!(matmul_f32(&a, 2, 3, false, &b, 3, 2, false).is_ok());
        assert!(matmul_f32(&a, 2, 3, true, &b, 2, 3, false).is_ok());
    }

    #[test]
    fn linear_fwd_bwd_shapes_and_nan_transparency() {
        let cfg = GemmConfig::default();
        let (m, k, n) = (5, 4, 3);
        let mut x = ramp(m * k, 0.1);
        let w = ramp(k * n, 0.7);
        x[k] = f32::NAN; // poisons row 1 of Y
        let (y, xq, wq) = fp8_linear_fwd(&cfg, &x, m, k, &w, n).unwrap();
        assert_eq!((y.rows, y.cols), (m, n));
        assert!((0..n).all(|j| y.at(1, j).is_nan()), "poisoned row is NaN");
        assert!(y.at(0, 0).is_finite(), "other rows unharmed");
        let dy = ramp(m * n, 0.4);
        let (dx, dw) = fp8_linear_bwd(&cfg, &dy, &xq, &wq).unwrap();
        assert_eq!((dx.rows, dx.cols), (m, k));
        assert_eq!((dw.rows, dw.cols), (k, n));
    }
}
