//! Analysis tooling for the paper's diagnostic figures.
//!
//! * [`correlation`] — per-channel w1/w2 alignment tracking (Theorem 1
//!   empirics; Figs. 2b-d and 7).
//! * [`histogram`] — log-scale histograms (|w2ᵀx| distribution, Fig. 9;
//!   activation-max landscapes, Fig. 1).
//! * [`outliers`] — channel outlier scanner over monitor traces.

pub mod correlation;
pub mod histogram;
pub mod outliers;
pub mod report;

pub use correlation::{channel_correlations, ChannelStats};
pub use histogram::LogHistogram;
pub use outliers::OutlierScanner;
pub use report::{analyze_checkpoint, analyze_run};
