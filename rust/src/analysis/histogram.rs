//! Log-scale histograms (Fig. 9's |w2ᵀx| distribution and weight-value
//! histograms, Fig. 2d).

#[derive(Clone, Debug)]
pub struct LogHistogram {
    pub log_min: f64,
    pub log_max: f64,
    pub bins: Vec<u64>,
    pub underflow: u64, // zeros / below-range
    pub total: u64,
}

impl LogHistogram {
    /// Natural-log bins over [e^log_min, e^log_max] (Fig. 9 uses ln x).
    pub fn new(log_min: f64, log_max: f64, n_bins: usize) -> Self {
        assert!(log_max > log_min && n_bins > 0);
        Self { log_min, log_max, bins: vec![0; n_bins], underflow: 0, total: 0 }
    }

    pub fn add(&mut self, x: f32) {
        self.total += 1;
        let a = x.abs() as f64;
        if a <= 0.0 {
            self.underflow += 1;
            return;
        }
        let l = a.ln();
        if l < self.log_min {
            self.underflow += 1;
            return;
        }
        let idx = ((l - self.log_min) / (self.log_max - self.log_min)
            * self.bins.len() as f64) as usize;
        let idx = idx.min(self.bins.len() - 1);
        self.bins[idx] += 1;
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Fraction of samples with |x| < threshold — the paper's Fig. 9
    /// metric (≈1% of |w2ᵀx| below 1 for the outlier channel).
    pub fn fraction_below(&self, threshold: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let lt = threshold.ln();
        let mut count = self.underflow;
        for (i, &c) in self.bins.iter().enumerate() {
            let bin_hi = self.log_min
                + (i as f64 + 1.0) / self.bins.len() as f64 * (self.log_max - self.log_min);
            if bin_hi <= lt {
                count += c;
            }
        }
        count as f64 / self.total as f64
    }

    /// (bin_center_ln, count) rows for CSV export.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        let w = (self.log_max - self.log_min) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.log_min + (i as f64 + 0.5) * w, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fraction_below_threshold() {
        let mut h = LogHistogram::new(-10.0, 10.0, 200);
        // 10 values below 1.0, 90 above
        for i in 0..10 {
            h.add(0.01 + i as f32 * 0.05);
        }
        for i in 0..90 {
            h.add(2.0 + i as f32);
        }
        let f = h.fraction_below(1.0);
        assert!((f - 0.1).abs() < 0.03, "fraction {f}");
    }

    #[test]
    fn zeros_counted_as_underflow() {
        let mut h = LogHistogram::new(-5.0, 5.0, 10);
        h.add(0.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.total, 1);
    }
}
