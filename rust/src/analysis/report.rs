//! Checkpoint analysis reports: the offline half of the Fig. 2/7
//! diagnostics — load a run's checkpoints, track per-channel w1/w2
//! statistics over time, rank outlier channels, and emit CSV.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::checkpoint::Checkpoint;
use crate::util::csv::CsvWriter;

use super::correlation::{channel_correlations, strongest_channel, ChannelStats};

/// Per-checkpoint snapshot of one layer's SwiGLU weight pairing.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub step: usize,
    pub layer: usize,
    pub top: ChannelStats,
    pub mean_abs_cosine: f32,
    pub n_aligned: usize, // |cos| > 0.9
}

/// Analyze one checkpoint file: w1/w2 channel stats for every layer.
///
/// Works on any checkpoint written by the trainer (stacked `[L, d, f]`
/// weights named `w1`/`w2`); errors on GeLU models (no w2).
pub fn analyze_checkpoint(path: &Path) -> Result<Vec<Snapshot>> {
    let ckpt = Checkpoint::load(path)?;
    let step = ckpt.meta.get("step").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
    let w1 = ckpt.tensor("w1")?;
    let w2 = ckpt.tensor("w2")?;
    // infer [L, d, f] from the model echo if present, else fail loudly
    let (l, d, f) = dims_from_meta(&ckpt)
        .ok_or_else(|| anyhow!("checkpoint meta lacks model dims (size '{}')",
                               ckpt.meta.str_or("size", "?")))?;
    if w1.len() != l * d * f {
        return Err(anyhow!("w1 numel {} != L·d·f {}", w1.len(), l * d * f));
    }
    let mut out = Vec::with_capacity(l);
    for layer in 0..l {
        let s = layer * d * f;
        let stats = channel_correlations(&w1[s..s + d * f], &w2[s..s + d * f], d, f);
        let mean_abs = stats.iter().map(|c| c.cosine.abs()).sum::<f32>() / f as f32;
        let n_aligned = stats.iter().filter(|c| c.cosine.abs() > 0.9).count();
        out.push(Snapshot {
            step,
            layer,
            top: strongest_channel(&stats).clone(),
            mean_abs_cosine: mean_abs,
            n_aligned,
        });
    }
    Ok(out)
}

fn dims_from_meta(ckpt: &Checkpoint) -> Option<(usize, usize, usize)> {
    // the trainer writes size names; map through the known presets
    let (d, f, l) = match ckpt.meta.str_or("size", "").as_str() {
        "tiny" => (64, 172, 2),
        "s1m" => (128, 344, 3),
        "s8m" => (256, 688, 4),
        "m100" => (768, 2048, 12),
        _ => return None,
    };
    Some((l, d, f))
}

/// Analyze every `step*.ckpt` in a run directory → CSV + the top
/// outlier trajectory (the Fig. 2b series).
pub fn analyze_run(dir: &Path, out_csv: &Path) -> Result<Vec<Snapshot>> {
    let mut paths: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|s| s.to_str())
                .map(|s| s.starts_with("step") && s.ends_with(".ckpt"))
                .unwrap_or(false)
        })
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(anyhow!("no step*.ckpt files in {}", dir.display()));
    }
    let mut csv = CsvWriter::create(
        out_csv,
        &["step", "layer", "top_channel", "norm1", "norm2", "cosine",
          "mean_abs_cosine", "n_aligned"],
    )?;
    let mut all = Vec::new();
    for p in &paths {
        for snap in analyze_checkpoint(p)? {
            csv.row(&[
                snap.step as f64,
                snap.layer as f64,
                snap.top.channel as f64,
                snap.top.norm1 as f64,
                snap.top.norm2 as f64,
                snap.top.cosine as f64,
                snap.mean_abs_cosine as f64,
                snap.n_aligned as f64,
            ])?;
            all.push(snap);
        }
    }
    csv.flush()?;
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{Dtype, Writer};
    use crate::util::json::{obj, Json};
    use crate::util::prng::Rng;

    fn write_fake_ckpt(dir: &Path, step: usize, cosine_boost: f32) {
        // tiny preset dims: L=2, d=64, f=172
        let (l, d, f) = (2, 64, 172);
        let mut rng = Rng::new(step as u64);
        let mut w1 = vec![0.0f32; l * d * f];
        let mut w2 = vec![0.0f32; l * d * f];
        rng.fill_normal(&mut w1, 0.1);
        rng.fill_normal(&mut w2, 0.1);
        // plant an aligned channel in layer 1 whose strength grows
        for i in 0..d {
            let v = (i as f32 * 0.1).sin() * (2.0 + cosine_boost);
            w1[d * f + i * f + 7] = v;
            w2[d * f + i * f + 7] = v;
        }
        let meta = obj(vec![
            ("step", Json::Num(step as f64)),
            ("size", Json::Str("tiny".into())),
        ]);
        let mut w = Writer::new(&meta);
        w.tensor("w1", Dtype::F32, &w1).tensor("w2", Dtype::F32, &w2);
        w.finish(dir.join(format!("step{step:06}.ckpt"))).unwrap();
    }

    #[test]
    fn finds_planted_outlier_and_orders_steps() {
        let dir = std::env::temp_dir().join("fp8_report_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        write_fake_ckpt(&dir, 10, 0.0);
        write_fake_ckpt(&dir, 20, 5.0);
        let out = dir.join("report.csv");
        let snaps = analyze_run(&dir, &out).unwrap();
        assert_eq!(snaps.len(), 4); // 2 ckpts x 2 layers
        let late_l1 = snaps.iter().find(|s| s.step == 20 && s.layer == 1).unwrap();
        assert_eq!(late_l1.top.channel, 7);
        assert!(late_l1.top.cosine > 0.95);
        assert!(late_l1.n_aligned >= 1);
        let csv = std::fs::read_to_string(&out).unwrap();
        assert!(csv.lines().count() == 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_empty_dir() {
        let dir = std::env::temp_dir().join("fp8_report_empty");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(analyze_run(&dir, &dir.join("x.csv")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
