//! Outlier detection over monitor traces (the per-layer SwiGLU-product
//! amax the grad artifact reports every step — Fig. 1's raw data).

/// Streaming detector: keeps a robust baseline (EMA of the median-ish
//  layer amax) and flags steps whose amax jumps a factor above it.
#[derive(Clone, Debug)]
pub struct OutlierScanner {
    pub factor: f32,
    ema: Vec<f32>,
    alpha: f32,
    pub events: Vec<OutlierEvent>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct OutlierEvent {
    pub step: usize,
    pub layer: usize,
    pub amax: f32,
    pub baseline: f32,
}

impl OutlierScanner {
    pub fn new(n_layers: usize, factor: f32) -> Self {
        Self { factor, ema: vec![0.0; n_layers], alpha: 0.05, events: Vec::new() }
    }

    /// Feed one step's per-layer amax vector; returns events fired now.
    pub fn observe(&mut self, step: usize, per_layer_amax: &[f32]) -> usize {
        assert_eq!(per_layer_amax.len(), self.ema.len());
        let mut fired = 0;
        for (layer, &a) in per_layer_amax.iter().enumerate() {
            let base = self.ema[layer];
            if base > 0.0 && a > base * self.factor {
                self.events.push(OutlierEvent { step, layer, amax: a, baseline: base });
                fired += 1;
                // don't fold the spike into the baseline at full weight
                self.ema[layer] = base + self.alpha * (base * self.factor - base);
            } else {
                self.ema[layer] = if base == 0.0 { a } else { base + self.alpha * (a - base) };
            }
        }
        fired
    }

    pub fn baseline(&self, layer: usize) -> f32 {
        self.ema[layer]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_spikes_only() {
        let mut s = OutlierScanner::new(2, 8.0);
        for step in 0..50 {
            assert_eq!(s.observe(step, &[1.0, 2.0]), 0);
        }
        assert_eq!(s.observe(50, &[20.0, 2.0]), 1);
        assert_eq!(s.events.len(), 1);
        assert_eq!(s.events[0].layer, 0);
        assert_eq!(s.events[0].step, 50);
    }

    #[test]
    fn baseline_tracks_slow_growth() {
        let mut s = OutlierScanner::new(1, 8.0);
        for step in 0..200 {
            let v = 1.0 + step as f32 * 0.01; // slow drift: never flagged
            assert_eq!(s.observe(step, &[v]), 0, "step {step}");
        }
    }
}
