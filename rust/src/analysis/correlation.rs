//! Per-channel w1/w2 alignment statistics (paper §4.2–4.3).
//!
//! For SwiGLU weights w1, w2 ∈ R^{d×f} (stored row-major [d, f]),
//! channel j is the column pair (w1[:, j], w2[:, j]). Theorem 1 says
//! training with ℓ2 drives cos(w1_j, w2_j) → ±1 for driven channels;
//! these are the series Figs. 2b/2c/7 plot.

#[derive(Clone, Debug)]
pub struct ChannelStats {
    pub channel: usize,
    pub norm1: f32,
    pub norm2: f32,
    pub cosine: f32,
}

/// Compute per-channel stats for column-paired weights.
///
/// `w1`, `w2`: row-major `[d, f]` flats.
pub fn channel_correlations(w1: &[f32], w2: &[f32], d: usize, f: usize) -> Vec<ChannelStats> {
    assert_eq!(w1.len(), d * f, "w1 shape");
    assert_eq!(w2.len(), d * f, "w2 shape");
    let mut out = Vec::with_capacity(f);
    for j in 0..f {
        let (mut n1, mut n2, mut dot) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..d {
            let a = w1[i * f + j] as f64;
            let b = w2[i * f + j] as f64;
            n1 += a * a;
            n2 += b * b;
            dot += a * b;
        }
        let n1 = n1.sqrt();
        let n2 = n2.sqrt();
        out.push(ChannelStats {
            channel: j,
            norm1: n1 as f32,
            norm2: n2 as f32,
            cosine: (dot / (n1 * n2 + 1e-30)) as f32,
        });
    }
    out
}

/// The channel with the strongest |cosine|·norm product — the "outlier
/// channel" the paper tracks.
pub fn strongest_channel(stats: &[ChannelStats]) -> &ChannelStats {
    stats
        .iter()
        .max_by(|a, b| {
            let ka = a.cosine.abs() * a.norm1 * a.norm2;
            let kb = b.cosine.abs() * b.norm1 * b.norm2;
            ka.partial_cmp(&kb).unwrap()
        })
        .expect("non-empty stats")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_channel_detected() {
        let d = 8;
        let f = 3;
        let mut w1 = vec![0.0f32; d * f];
        let mut w2 = vec![0.0f32; d * f];
        for i in 0..d {
            // channel 0: aligned; channel 1: anti-aligned; channel 2: orthogonal-ish
            w1[i * f] = i as f32 + 1.0;
            w2[i * f] = 2.0 * (i as f32 + 1.0);
            w1[i * f + 1] = i as f32 + 1.0;
            w2[i * f + 1] = -(i as f32 + 1.0);
            w1[i * f + 2] = if i % 2 == 0 { 1.0 } else { 0.0 };
            w2[i * f + 2] = if i % 2 == 1 { 1.0 } else { 0.0 };
        }
        let s = channel_correlations(&w1, &w2, d, f);
        assert!((s[0].cosine - 1.0).abs() < 1e-6);
        assert!((s[1].cosine + 1.0).abs() < 1e-6);
        assert!(s[2].cosine.abs() < 1e-6);
        assert_eq!(strongest_channel(&s).channel, 0);
    }

    #[test]
    fn norms_match() {
        let s = channel_correlations(&[3.0, 4.0], &[1.0, 1.0], 2, 1);
        assert!((s[0].norm1 - 5.0).abs() < 1e-6);
    }
}
