//! Optimizer state management: the flat parameter space, AdamW moment
//! storage (f32 or packed-u8 FP8), weight-decay groups, and the ZeRO-1
//! shard layout — everything around the `adam_*` compute artifact.
//!
//! Storage formats follow the paper §5 / Table 4: moments optionally
//! live as **one real byte per element** (E4M3 first moment, E5M2
//! second moment, per-chunk pow2 scales) and the memory accounting
//! below is what the Table 4 bench measures.

use crate::fp8::{self, Fp8Format, E4M3, E5M2};
use crate::runtime::manifest::ParamSpec;

/// How a moment buffer is stored between steps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MomentStore {
    F32,
    Fp8(Fp8Format),
}

impl MomentStore {
    pub fn from_name(name: &str) -> Self {
        match name {
            "e4m3" => MomentStore::Fp8(E4M3),
            "e5m2" => MomentStore::Fp8(E5M2),
            _ => MomentStore::F32,
        }
    }

    pub fn bytes_per_elem(self) -> f64 {
        match self {
            MomentStore::F32 => 4.0,
            // 1 byte + amortized per-chunk f32 scale
            MomentStore::Fp8(_) => 1.0,
        }
    }
}

/// One packed chunk of a [`MomentBuffer`]: FP8 bytes + scale on the
/// hot path, raw f32 when exact-mode verification rejected the FP8
/// roundtrip. Both payload vecs persist (empty but with capacity)
/// across pack/unpack cycles so steady-state repacking allocates
/// nothing; the invariant is that at most one of them is non-empty.
struct ChunkSlot {
    bytes: Vec<u8>,
    raw: Vec<f32>,
    scale: f32,
}

impl ChunkSlot {
    fn empty() -> Self {
        Self { bytes: Vec::new(), raw: Vec::new(), scale: 1.0 }
    }
}

/// A moment buffer: f32 working view + optional packed storage.
///
/// The artifact consumes/produces f32 values that lie exactly on the
/// fp8 grid (the kernel quantizes them); `pack()` converts to real u8
/// between steps and `unpack()` restores before the next step, so the
/// resident set matches the paper's memory story.
///
/// Two packing disciplines:
/// * [`zeros`](MomentBuffer::zeros) — JIT-scaled FP8 pack, lossy for
///   off-grid data (analysis/storage uses);
/// * [`zeros_exact`](MomentBuffer::zeros_exact) — each chunk is
///   verified at pack time (`fp8::bulk::pack_scaled_exact_into`, the
///   same check the checkpoint layer's exact-FP8 sections use) and
///   falls back to raw f32 when the roundtrip is not bit-exact, so
///   `unpack(pack(x))` is the identity **by construction**. The
///   trainer's resident ZeRO-1 moment shards use this mode: packing
///   between steps can never change the numbers.
pub struct MomentBuffer {
    pub store: MomentStore,
    pub chunk: usize,
    /// chunks stored as FP8 only when bit-exact, else raw f32
    exact: bool,
    /// packed representation (chunked); unused for the f32 store
    slots: Vec<ChunkSlot>,
    f32_buf: Vec<f32>,
    len: usize,
}

impl MomentBuffer {
    pub fn zeros(len: usize, store: MomentStore, chunk: usize) -> Self {
        Self {
            store,
            chunk,
            exact: false,
            slots: Vec::new(),
            f32_buf: vec![0.0; len],
            len,
        }
    }

    /// Like [`zeros`](MomentBuffer::zeros) but with per-chunk
    /// write-time roundtrip verification: packing is guaranteed
    /// bit-preserving (FP8 when on-grid, raw-f32 fallback otherwise).
    pub fn zeros_exact(len: usize, store: MomentStore, chunk: usize) -> Self {
        Self { exact: true, ..Self::zeros(len, store, chunk) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Working f32 view (unpacks if needed). Bulk LUT decode straight
    /// into the flat buffer — no per-chunk temporaries.
    pub fn as_f32(&mut self) -> &mut Vec<f32> {
        if self.f32_buf.is_empty() && self.len > 0 {
            // unpack
            let fmt = match self.store {
                MomentStore::Fp8(f) => f,
                MomentStore::F32 => unreachable!("f32 store never packs"),
            };
            let mut out = vec![0.0f32; self.len];
            let mut off = 0;
            for slot in &self.slots {
                let stored = if slot.raw.is_empty() { slot.bytes.len() } else { slot.raw.len() };
                let n = stored.min(self.len - off);
                if slot.raw.is_empty() {
                    fp8::bulk::unpack_scaled_buf(
                        fmt,
                        &slot.bytes[..n],
                        slot.scale,
                        &mut out[off..off + n],
                    );
                } else {
                    out[off..off + n].copy_from_slice(&slot.raw[..n]);
                }
                off += n;
            }
            self.f32_buf = out;
            // keep the payload capacities for the next pack()
            for slot in self.slots.iter_mut() {
                slot.bytes.clear();
                slot.raw.clear();
            }
        }
        &mut self.f32_buf
    }

    /// Pack to the storage format (no-op for f32). Reuses the packed
    /// payload vectors across pack/unpack cycles; only the f32 working
    /// buffer is released (that release *is* the Table 4 story).
    pub fn pack(&mut self) {
        let fmt = match self.store {
            MomentStore::F32 => return,
            MomentStore::Fp8(f) => f,
        };
        if self.f32_buf.is_empty() {
            return; // already packed (or empty)
        }
        let n_chunks = self.len.div_ceil(self.chunk).max(1);
        self.slots.resize_with(n_chunks, ChunkSlot::empty);
        for (c, slot) in self.f32_buf.chunks(self.chunk).zip(self.slots.iter_mut()) {
            if self.exact {
                match fp8::bulk::pack_scaled_exact_into(fmt, c, &mut slot.bytes) {
                    Some(s) => {
                        slot.scale = s;
                        slot.raw.clear();
                    }
                    None => {
                        slot.bytes.clear();
                        slot.scale = 1.0;
                        slot.raw.clear();
                        slot.raw.extend_from_slice(c);
                    }
                }
            } else {
                slot.scale = fp8::bulk::pack_scaled_into(fmt, c, &mut slot.bytes);
                slot.raw.clear();
            }
        }
        self.f32_buf = Vec::new();
    }

    /// Copy the current contents into `out` (cleared + refilled)
    /// **without disturbing the resident state** — decodes packed
    /// chunks through the pure LUT path. This is the campaign-snapshot
    /// gather: capture takes `&Trainer`, so it cannot unpack in place.
    pub fn snapshot_into(&self, out: &mut Vec<f32>) {
        out.clear();
        if self.f32_buf.len() == self.len {
            out.extend_from_slice(&self.f32_buf);
            return;
        }
        let fmt = match self.store {
            MomentStore::Fp8(f) => f,
            MomentStore::F32 => unreachable!("f32 store never packs"),
        };
        out.resize(self.len, 0.0);
        let mut off = 0;
        for slot in &self.slots {
            let stored = if slot.raw.is_empty() { slot.bytes.len() } else { slot.raw.len() };
            let n = stored.min(self.len - off);
            if slot.raw.is_empty() {
                fp8::bulk::unpack_scaled_buf(
                    fmt,
                    &slot.bytes[..n],
                    slot.scale,
                    &mut out[off..off + n],
                );
            } else {
                out[off..off + n].copy_from_slice(&slot.raw[..n]);
            }
            off += n;
        }
    }

    /// Overwrite the contents from a flat slice (campaign-snapshot
    /// scatter). Leaves the buffer in the unpacked state; payload
    /// capacities are retained for the next `pack()`.
    ///
    /// # Panics
    ///
    /// Panics if `src.len()` differs from the buffer length — callers
    /// validate arity before any mutation (snapshot `apply_to`).
    pub fn load_from(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.len, "moment shard size mismatch");
        self.f32_buf.clear();
        self.f32_buf.extend_from_slice(src);
        for slot in self.slots.iter_mut() {
            slot.bytes.clear();
            slot.raw.clear();
        }
    }

    /// CRC-32 digest of the canonical packed representation (packs
    /// first if needed — a no-op for the f32 store). Two buffers with
    /// the same store/chunk and the same packed bytes digest equal;
    /// the reshard property tests use this to pin "W→W′→W reproduces
    /// the original shard bytes" without holding both byte sets.
    pub fn packed_digest(&mut self) -> u32 {
        self.pack();
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(&(self.len as u64).to_le_bytes());
        bytes.extend_from_slice(&(self.chunk as u64).to_le_bytes());
        match self.store {
            MomentStore::F32 => {
                bytes.push(2); // store tag
                for x in &self.f32_buf {
                    bytes.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            MomentStore::Fp8(_) => {
                for slot in &self.slots {
                    // tag keeps an FP8 chunk and a raw-fallback chunk
                    // with identical payload bytes from colliding
                    bytes.push(u8::from(!slot.raw.is_empty()));
                    bytes.extend_from_slice(&slot.scale.to_bits().to_le_bytes());
                    if slot.raw.is_empty() {
                        bytes.extend_from_slice(&slot.bytes);
                    } else {
                        for x in &slot.raw {
                            bytes.extend_from_slice(&x.to_bits().to_le_bytes());
                        }
                    }
                }
            }
        }
        crate::util::crc32(&bytes)
    }

    /// Test hook for the reshard corrupt-injection drill: flip one bit
    /// of the packed payload (packing first if needed) so the
    /// roundtrip verification sees a shard that no longer reproduces
    /// the source bits. Not part of any production path.
    #[doc(hidden)]
    pub fn corrupt_one_bit_for_test(&mut self) {
        self.pack();
        match self.store {
            MomentStore::F32 => {
                if let Some(x) = self.f32_buf.first_mut() {
                    *x = f32::from_bits(x.to_bits() ^ 1);
                }
            }
            MomentStore::Fp8(_) => {
                for slot in self.slots.iter_mut() {
                    if !slot.bytes.is_empty() {
                        slot.bytes[0] ^= 1;
                        return;
                    }
                    if !slot.raw.is_empty() {
                        slot.raw[0] = f32::from_bits(slot.raw[0].to_bits() ^ 1);
                        return;
                    }
                }
            }
        }
    }

    /// Resident bytes in the packed state (the Table 4 measurement).
    pub fn resident_bytes(&self) -> usize {
        match self.store {
            MomentStore::F32 => self.len * 4,
            MomentStore::Fp8(_) => {
                // the packed slots persist across unpack (capacity
                // reuse), so "currently packed" is keyed off the f32
                // working buffer, not off `slots` being non-empty
                if !self.f32_buf.is_empty() || self.slots.is_empty() {
                    self.len // nominal packed size (1 byte/elem target)
                } else {
                    self.slots
                        .iter()
                        .map(|s| s.bytes.len() + s.raw.len() * 4 + 4)
                        .sum()
                }
            }
        }
    }
}

/// Weight-decay groups: Llama-2 decays matmul weights but not norm
/// gains (or embeddings, in most configs). The coordinator calls the
/// adam artifact once per (shard × group) with the group's wd scalar.
#[derive(Clone, Debug, PartialEq)]
pub struct DecayGroup {
    pub decay: bool,
    /// (offset, len) ranges into the flat parameter space
    pub ranges: Vec<(usize, usize)>,
}

pub fn decay_groups(params: &[ParamSpec]) -> Vec<DecayGroup> {
    let mut decay = Vec::new();
    let mut no_decay = Vec::new();
    let mut off = 0;
    for p in params {
        let n = p.numel();
        // norm gains (ln_*) are the no-decay set, matching Llama-2
        if p.name.starts_with("ln_") {
            no_decay.push((off, n));
        } else {
            decay.push((off, n));
        }
        off += n;
    }
    vec![
        DecayGroup { decay: true, ranges: decay },
        DecayGroup { decay: false, ranges: no_decay },
    ]
}

/// ZeRO-1 shard layout: the flat space split into `n_workers`
/// contiguous ranges (optimizer state lives only on its owner).
///
/// [`chunk_aligned`](ShardLayout::chunk_aligned) builds the owner map
/// the trainer uses: shard boundaries land on absolute multiples of
/// the Adam artifact chunk, so every per-chunk FP8 moment grid (and
/// every exact-FP8 checkpoint section chunk) has exactly one owner and
/// gathering the shards back to a flat buffer reproduces the global
/// chunk grid unchanged.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    pub total: usize,
    /// alignment grain of the shard boundaries (1 for the legacy
    /// elementwise split)
    pub chunk: usize,
    pub shards: Vec<(usize, usize)>, // (offset, len)
}

impl ShardLayout {
    /// Elementwise balanced split (no alignment guarantee).
    pub fn new(total: usize, n_workers: usize) -> Self {
        assert!(n_workers >= 1);
        let base = total / n_workers;
        let rem = total % n_workers;
        let mut shards = Vec::with_capacity(n_workers);
        let mut off = 0;
        for w in 0..n_workers {
            let len = base + usize::from(w < rem);
            shards.push((off, len));
            off += len;
        }
        Self { total, chunk: 1, shards }
    }

    /// Balanced split in whole `chunk`-sized units: every boundary
    /// between non-empty shards is a multiple of `chunk`, shards stay
    /// contiguous and ascending, and the imbalance between any two
    /// workers is at most one chunk. Workers past the chunk supply get
    /// empty shards; those (and only those) sit at offset `total`,
    /// which the ragged final chunk may leave off-grid.
    pub fn chunk_aligned(total: usize, n_workers: usize, chunk: usize) -> Self {
        assert!(n_workers >= 1 && chunk >= 1);
        let n_chunks = total.div_ceil(chunk);
        let base = n_chunks / n_workers;
        let rem = n_chunks % n_workers;
        let mut shards = Vec::with_capacity(n_workers);
        let mut off = 0;
        for w in 0..n_workers {
            let c = base + usize::from(w < rem);
            let len = (c * chunk).min(total - off);
            shards.push((off, len));
            off += len;
        }
        Self { total, chunk, shards }
    }

    pub fn of_worker(&self, w: usize) -> (usize, usize) {
        self.shards[w]
    }

    pub fn n_workers(&self) -> usize {
        self.shards.len()
    }

    /// The worker owning flat offset `off` (`off < total`). Shards are
    /// contiguous and ascending, so this is a binary partition point.
    pub fn owner_of(&self, off: usize) -> usize {
        assert!(off < self.total, "offset {off} past total {}", self.total);
        self.shards.partition_point(|&(o, n)| o + n <= off)
    }

    /// Largest per-worker shard length (the per-worker memory bound).
    pub fn max_shard_elems(&self) -> usize {
        self.shards.iter().map(|&(_, n)| n).max().unwrap_or(0)
    }
}

/// Re-partition an already-gathered flat moment vector into the packed
/// per-worker shards of `layout` — the scatter half of the campaign
/// reshard transform. Each shard is built in exact mode
/// ([`MomentBuffer::zeros_exact`]) and packed immediately, so the
/// result is exactly what a freshly-constructed trainer on the new
/// topology would hold after its first `pack()`.
///
/// Because `layout` boundaries land on absolute multiples of
/// `layout.chunk` (see [`ShardLayout::chunk_aligned`]) and the FP8
/// scale grid is per-absolute-chunk, re-partitioning never moves an
/// element across a chunk boundary: the packed bytes of every chunk
/// are independent of which worker owns it.
///
/// # Panics
///
/// Panics if `flat.len() != layout.total` — callers validate arity
/// before invoking the transform.
pub fn repartition(flat: &[f32], layout: &ShardLayout, store: MomentStore) -> Vec<MomentBuffer> {
    assert_eq!(flat.len(), layout.total, "flat moment length vs shard layout total");
    let mut shards = Vec::with_capacity(layout.n_workers());
    for &(off, len) in &layout.shards {
        let mut buf = MomentBuffer::zeros_exact(len, store, layout.chunk);
        buf.load_from(&flat[off..off + len]);
        buf.pack();
        shards.push(buf);
    }
    shards
}

/// Gather packed shards back into one flat vector (the inverse of
/// [`repartition`]) without disturbing the shards' resident state —
/// pure-LUT decode via [`MomentBuffer::snapshot_into`].
pub fn gather(shards: &[MomentBuffer]) -> Vec<f32> {
    let total: usize = shards.iter().map(|s| s.len()).sum();
    let mut flat = Vec::with_capacity(total);
    let mut tmp = Vec::new();
    for s in shards {
        s.snapshot_into(&mut tmp);
        flat.extend_from_slice(&tmp);
    }
    flat
}

/// Memory accounting for one training configuration (Table 4).
#[derive(Clone, Debug)]
pub struct MemoryModel {
    pub params: usize,
    pub master_bytes_per_param: f64,
    pub m_store: MomentStore,
    pub v_store: MomentStore,
    pub dp_workers: usize,
    /// compute copy of the weights (bf16 on device)
    pub weight_bytes_per_param: f64,
    /// gradient buffer (bf16/fp8 hybrid on device; bf16 here)
    pub grad_bytes_per_param: f64,
}

impl MemoryModel {
    /// Optimizer-state bytes per worker. Matching the paper's
    /// DeepSpeed ZeRO-1 measurement (Table 4): the Adam *moments* are
    /// sharded across workers; the master-weight copy is replicated
    /// (this is what reproduces the 63.25 → 44.08 GB/HPU numbers —
    /// 14 GB saved by FP32→FP16 master, ~5.25 GB by FP32→FP8 sharded
    /// moments on 7B/8 workers).
    pub fn optimizer_bytes_per_worker(&self) -> f64 {
        let moments = self.m_store.bytes_per_elem() + self.v_store.bytes_per_elem();
        self.master_bytes_per_param * self.params as f64
            + moments * self.params as f64 / self.dp_workers as f64
    }

    /// Total model-state bytes per worker (weights + grads + optimizer).
    pub fn total_bytes_per_worker(&self) -> f64 {
        (self.weight_bytes_per_param + self.grad_bytes_per_param) * self.params as f64
            + self.optimizer_bytes_per_worker()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, numel: usize) -> ParamSpec {
        ParamSpec { name: name.into(), shape: vec![numel], init_std: 0.02 }
    }

    #[test]
    fn decay_groups_split_norms() {
        let specs = vec![spec("embed", 10), spec("ln_1", 4), spec("wq", 16)];
        let gs = decay_groups(&specs);
        assert_eq!(gs[0].ranges, vec![(0, 10), (14, 16)]);
        assert_eq!(gs[1].ranges, vec![(10, 4)]);
    }

    #[test]
    fn shards_cover_everything() {
        for total in [10usize, 11, 1000] {
            for w in [1usize, 3, 8] {
                let l = ShardLayout::new(total, w);
                let sum: usize = l.shards.iter().map(|&(_, n)| n).sum();
                assert_eq!(sum, total);
                let mut off = 0;
                for &(o, n) in &l.shards {
                    assert_eq!(o, off);
                    off += n;
                }
            }
        }
    }

    #[test]
    fn moment_pack_roundtrip_error() {
        let mut m = MomentBuffer::zeros(1000, MomentStore::Fp8(E4M3), 256);
        for (i, x) in m.as_f32().iter_mut().enumerate() {
            *x = (i as f32 - 500.0) * 1e-4;
        }
        let before = m.as_f32().clone();
        m.pack();
        assert!(m.resident_bytes() < 1100); // ~1 byte/elem + scales
        let after = m.as_f32().clone();
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() <= a.abs() * 0.07 + 1e-6);
        }
    }

    #[test]
    fn chunk_aligned_shards_cover_and_align() {
        for total in [0usize, 10, 1000, 262_144 * 3 + 17] {
            for w in [1usize, 2, 3, 8] {
                for chunk in [64usize, 256, 262_144] {
                    let l = ShardLayout::chunk_aligned(total, w, chunk);
                    assert_eq!(l.shards.len(), w);
                    let sum: usize = l.shards.iter().map(|&(_, n)| n).sum();
                    assert_eq!(sum, total, "coverage");
                    let mut off = 0;
                    for &(o, n) in &l.shards {
                        assert_eq!(o, off, "contiguous");
                        // empty trailing shards sit at `total`, which a
                        // ragged final chunk may leave off-grid
                        assert!(o % chunk == 0 || o == total, "boundary alignment");
                        off += n;
                    }
                    // balance: at most one chunk of skew between workers
                    let lens: Vec<usize> = l.shards.iter().map(|&(_, n)| n).collect();
                    let max = *lens.iter().max().unwrap();
                    let full_min =
                        lens.iter().filter(|&&n| n > 0).min().copied().unwrap_or(0);
                    assert!(
                        max <= full_min.div_ceil(chunk) * chunk + chunk,
                        "balance: {lens:?} chunk {chunk}"
                    );
                    assert_eq!(l.max_shard_elems(), max);
                    // owner map consistency at every boundary ± 1
                    for (w_idx, &(o, n)) in l.shards.iter().enumerate() {
                        if n == 0 {
                            continue;
                        }
                        assert_eq!(l.owner_of(o), w_idx);
                        assert_eq!(l.owner_of(o + n - 1), w_idx);
                    }
                }
            }
        }
    }

    #[test]
    fn moment_pack_exact_is_bit_preserving() {
        // on-grid data (what the chunked Adam artifact emits) and
        // off-grid data (forces the raw-f32 fallback) must both
        // survive pack()/as_f32() bit-for-bit in exact mode
        let chunk = 64usize;
        let n = chunk * 3 + 17;
        let mut m = MomentBuffer::zeros_exact(n, MomentStore::Fp8(E4M3), chunk);
        for (i, x) in m.as_f32().iter_mut().enumerate() {
            *x = if i < chunk * 2 {
                // per-chunk grid: code wheel over a pow2 scale
                E4M3.decode(((i % 120) * 2) as u8) / 4.0
            } else {
                // off-grid irrationals
                ((i as f32) * 0.7311).sin() * 3.7
            };
        }
        let before = m.as_f32().clone();
        m.pack();
        // on-grid chunks pack at ~1 byte/elem, fallback chunks at 4
        let resident = m.resident_bytes();
        assert!(
            resident < chunk * 2 + (n - chunk * 2) * 4 + 6 * 4 + 16,
            "resident {resident}"
        );
        let mut snap = Vec::new();
        m.snapshot_into(&mut snap); // gather without unpacking
        let after = m.as_f32().clone();
        for (i, (a, b)) in before.iter().zip(&after).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "unpack i={i}");
        }
        for (i, (a, b)) in before.iter().zip(&snap).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "snapshot i={i}");
        }
        // scatter path: load_from then re-read
        let src: Vec<f32> = (0..n).map(|i| (i as f32) * 1e-3).collect();
        m.load_from(&src);
        assert_eq!(m.as_f32().as_slice(), src.as_slice());
    }

    #[test]
    fn repartition_gather_roundtrip_is_bit_exact_across_worker_counts() {
        // mixed data: on-grid chunks (the steady-state Adam output)
        // plus off-grid chunks (forces the raw-f32 fallback) — the
        // reshard transform must survive both, for any worker count,
        // because chunk grids are absolute.
        let chunk = 64usize;
        let total = chunk * 7 + 13; // ragged tail
        let flat: Vec<f32> = (0..total)
            .map(|i| {
                if (i / chunk) % 2 == 0 {
                    E4M3.decode(((i % 120) * 2) as u8) / 8.0
                } else {
                    ((i as f32) * 0.7311).sin() * 3.7
                }
            })
            .collect();
        for store in [MomentStore::Fp8(E4M3), MomentStore::Fp8(E5M2), MomentStore::F32] {
            let mut digests_by_w: Vec<Vec<(usize, u32)>> = Vec::new();
            for w in [1usize, 2, 3, 5] {
                let layout = ShardLayout::chunk_aligned(total, w, chunk);
                let mut shards = repartition(&flat, &layout, store);
                let back = gather(&shards);
                assert_eq!(back.len(), flat.len());
                for (i, (a, b)) in flat.iter().zip(&back).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "w={w} i={i}");
                }
                digests_by_w.push(
                    shards
                        .iter_mut()
                        .zip(&layout.shards)
                        .map(|(s, &(off, _))| (off, s.packed_digest()))
                        .collect(),
                );
            }
            // determinism: re-running the same partition digests equal
            let layout = ShardLayout::chunk_aligned(total, 3, chunk);
            let again: Vec<(usize, u32)> = repartition(&flat, &layout, store)
                .iter_mut()
                .zip(&layout.shards)
                .map(|(s, &(off, _))| (off, s.packed_digest()))
                .collect();
            assert_eq!(again, digests_by_w[2], "repartition must be deterministic");
        }
    }

    #[test]
    fn memory_model_matches_paper_ratio() {
        // 7B params, 8 workers, ZeRO-1: fp32 moments + f32 master vs
        // fp8 moments + f16 master — expect roughly the paper's ~30%
        // total reduction given fixed weight+grad overhead.
        let base = MemoryModel {
            params: 7_000_000_000,
            master_bytes_per_param: 4.0,
            m_store: MomentStore::F32,
            v_store: MomentStore::F32,
            dp_workers: 8,
            weight_bytes_per_param: 2.0,
            grad_bytes_per_param: 2.0,
        };
        let ours = MemoryModel {
            master_bytes_per_param: 2.0,
            m_store: MomentStore::Fp8(E4M3),
            v_store: MomentStore::Fp8(E5M2),
            ..base.clone()
        };
        let r = ours.total_bytes_per_worker() / base.total_bytes_per_worker();
        // paper: 44.08 / 63.25 = 0.697
        assert!(r < 0.75 && r > 0.62, "reduction ratio {r}");
    }
}
