//! Optimizer state management: the flat parameter space, AdamW moment
//! storage (f32 or packed-u8 FP8), weight-decay groups, and the ZeRO-1
//! shard layout — everything around the `adam_*` compute artifact.
//!
//! Storage formats follow the paper §5 / Table 4: moments optionally
//! live as **one real byte per element** (E4M3 first moment, E5M2
//! second moment, per-chunk pow2 scales) and the memory accounting
//! below is what the Table 4 bench measures.

use crate::fp8::{self, Fp8Format, E4M3, E5M2};
use crate::runtime::manifest::ParamSpec;

/// How a moment buffer is stored between steps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MomentStore {
    F32,
    Fp8(Fp8Format),
}

impl MomentStore {
    pub fn from_name(name: &str) -> Self {
        match name {
            "e4m3" => MomentStore::Fp8(E4M3),
            "e5m2" => MomentStore::Fp8(E5M2),
            _ => MomentStore::F32,
        }
    }

    pub fn bytes_per_elem(self) -> f64 {
        match self {
            MomentStore::F32 => 4.0,
            // 1 byte + amortized per-chunk f32 scale
            MomentStore::Fp8(_) => 1.0,
        }
    }
}

/// A moment buffer: f32 working view + optional packed storage.
///
/// The artifact consumes/produces f32 values that lie exactly on the
/// fp8 grid (the kernel quantizes them); `pack()` converts to real u8
/// between steps and `unpack()` restores before the next step, so the
/// resident set matches the paper's memory story.
pub struct MomentBuffer {
    pub store: MomentStore,
    pub chunk: usize,
    /// packed representation (chunked) or f32, depending on `store`
    packed: Vec<(Vec<u8>, f32)>,
    f32_buf: Vec<f32>,
    len: usize,
}

impl MomentBuffer {
    pub fn zeros(len: usize, store: MomentStore, chunk: usize) -> Self {
        Self {
            store,
            chunk,
            packed: Vec::new(),
            f32_buf: vec![0.0; len],
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Working f32 view (unpacks if needed). Bulk LUT decode straight
    /// into the flat buffer — no per-chunk temporaries.
    pub fn as_f32(&mut self) -> &mut Vec<f32> {
        if self.f32_buf.is_empty() && self.len > 0 {
            // unpack
            let fmt = match self.store {
                MomentStore::Fp8(f) => f,
                MomentStore::F32 => unreachable!("f32 store never packs"),
            };
            let mut out = vec![0.0f32; self.len];
            let mut off = 0;
            for (bytes, scale) in &self.packed {
                let n = bytes.len().min(self.len - off);
                fp8::bulk::unpack_scaled_buf(fmt, &bytes[..n], *scale, &mut out[off..off + n]);
                off += n;
            }
            self.f32_buf = out;
            // keep the byte vec capacities for the next pack()
            for (bytes, _) in self.packed.iter_mut() {
                bytes.clear();
            }
        }
        &mut self.f32_buf
    }

    /// Pack to the storage format (no-op for f32). Reuses the packed
    /// byte vectors across pack/unpack cycles; only the f32 working
    /// buffer is released (that release *is* the Table 4 story).
    pub fn pack(&mut self) {
        let fmt = match self.store {
            MomentStore::F32 => return,
            MomentStore::Fp8(f) => f,
        };
        if self.f32_buf.is_empty() {
            return; // already packed
        }
        let n_chunks = self.len.div_ceil(self.chunk).max(1);
        self.packed.resize_with(n_chunks, || (Vec::new(), 1.0));
        for (c, slot) in self.f32_buf.chunks(self.chunk).zip(self.packed.iter_mut()) {
            slot.1 = fp8::bulk::pack_scaled_into(fmt, c, &mut slot.0);
        }
        self.f32_buf = Vec::new();
    }

    /// Resident bytes in the packed state (the Table 4 measurement).
    pub fn resident_bytes(&self) -> usize {
        match self.store {
            MomentStore::F32 => self.len * 4,
            MomentStore::Fp8(_) => {
                // the packed slots persist across unpack (capacity
                // reuse), so "currently packed" is keyed off the f32
                // working buffer, not off `packed` being non-empty
                if !self.f32_buf.is_empty() || self.packed.is_empty() {
                    self.len // would-be packed size
                } else {
                    self.packed.iter().map(|(b, _)| b.len() + 4).sum()
                }
            }
        }
    }
}

/// Weight-decay groups: Llama-2 decays matmul weights but not norm
/// gains (or embeddings, in most configs). The coordinator calls the
/// adam artifact once per (shard × group) with the group's wd scalar.
#[derive(Clone, Debug, PartialEq)]
pub struct DecayGroup {
    pub decay: bool,
    /// (offset, len) ranges into the flat parameter space
    pub ranges: Vec<(usize, usize)>,
}

pub fn decay_groups(params: &[ParamSpec]) -> Vec<DecayGroup> {
    let mut decay = Vec::new();
    let mut no_decay = Vec::new();
    let mut off = 0;
    for p in params {
        let n = p.numel();
        // norm gains (ln_*) are the no-decay set, matching Llama-2
        if p.name.starts_with("ln_") {
            no_decay.push((off, n));
        } else {
            decay.push((off, n));
        }
        off += n;
    }
    vec![
        DecayGroup { decay: true, ranges: decay },
        DecayGroup { decay: false, ranges: no_decay },
    ]
}

/// ZeRO-1 shard layout: the flat space split into `n_workers`
/// contiguous ranges (optimizer state lives only on its owner).
#[derive(Clone, Debug)]
pub struct ShardLayout {
    pub total: usize,
    pub shards: Vec<(usize, usize)>, // (offset, len)
}

impl ShardLayout {
    pub fn new(total: usize, n_workers: usize) -> Self {
        assert!(n_workers >= 1);
        let base = total / n_workers;
        let rem = total % n_workers;
        let mut shards = Vec::with_capacity(n_workers);
        let mut off = 0;
        for w in 0..n_workers {
            let len = base + usize::from(w < rem);
            shards.push((off, len));
            off += len;
        }
        Self { total, shards }
    }

    pub fn of_worker(&self, w: usize) -> (usize, usize) {
        self.shards[w]
    }
}

/// Memory accounting for one training configuration (Table 4).
#[derive(Clone, Debug)]
pub struct MemoryModel {
    pub params: usize,
    pub master_bytes_per_param: f64,
    pub m_store: MomentStore,
    pub v_store: MomentStore,
    pub dp_workers: usize,
    /// compute copy of the weights (bf16 on device)
    pub weight_bytes_per_param: f64,
    /// gradient buffer (bf16/fp8 hybrid on device; bf16 here)
    pub grad_bytes_per_param: f64,
}

impl MemoryModel {
    /// Optimizer-state bytes per worker. Matching the paper's
    /// DeepSpeed ZeRO-1 measurement (Table 4): the Adam *moments* are
    /// sharded across workers; the master-weight copy is replicated
    /// (this is what reproduces the 63.25 → 44.08 GB/HPU numbers —
    /// 14 GB saved by FP32→FP16 master, ~5.25 GB by FP32→FP8 sharded
    /// moments on 7B/8 workers).
    pub fn optimizer_bytes_per_worker(&self) -> f64 {
        let moments = self.m_store.bytes_per_elem() + self.v_store.bytes_per_elem();
        self.master_bytes_per_param * self.params as f64
            + moments * self.params as f64 / self.dp_workers as f64
    }

    /// Total model-state bytes per worker (weights + grads + optimizer).
    pub fn total_bytes_per_worker(&self) -> f64 {
        (self.weight_bytes_per_param + self.grad_bytes_per_param) * self.params as f64
            + self.optimizer_bytes_per_worker()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, numel: usize) -> ParamSpec {
        ParamSpec { name: name.into(), shape: vec![numel], init_std: 0.02 }
    }

    #[test]
    fn decay_groups_split_norms() {
        let specs = vec![spec("embed", 10), spec("ln_1", 4), spec("wq", 16)];
        let gs = decay_groups(&specs);
        assert_eq!(gs[0].ranges, vec![(0, 10), (14, 16)]);
        assert_eq!(gs[1].ranges, vec![(10, 4)]);
    }

    #[test]
    fn shards_cover_everything() {
        for total in [10usize, 11, 1000] {
            for w in [1usize, 3, 8] {
                let l = ShardLayout::new(total, w);
                let sum: usize = l.shards.iter().map(|&(_, n)| n).sum();
                assert_eq!(sum, total);
                let mut off = 0;
                for &(o, n) in &l.shards {
                    assert_eq!(o, off);
                    off += n;
                }
            }
        }
    }

    #[test]
    fn moment_pack_roundtrip_error() {
        let mut m = MomentBuffer::zeros(1000, MomentStore::Fp8(E4M3), 256);
        for (i, x) in m.as_f32().iter_mut().enumerate() {
            *x = (i as f32 - 500.0) * 1e-4;
        }
        let before = m.as_f32().clone();
        m.pack();
        assert!(m.resident_bytes() < 1100); // ~1 byte/elem + scales
        let after = m.as_f32().clone();
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() <= a.abs() * 0.07 + 1e-6);
        }
    }

    #[test]
    fn memory_model_matches_paper_ratio() {
        // 7B params, 8 workers, ZeRO-1: fp32 moments + f32 master vs
        // fp8 moments + f16 master — expect roughly the paper's ~30%
        // total reduction given fixed weight+grad overhead.
        let base = MemoryModel {
            params: 7_000_000_000,
            master_bytes_per_param: 4.0,
            m_store: MomentStore::F32,
            v_store: MomentStore::F32,
            dp_workers: 8,
            weight_bytes_per_param: 2.0,
            grad_bytes_per_param: 2.0,
        };
        let ours = MemoryModel {
            master_bytes_per_param: 2.0,
            m_store: MomentStore::Fp8(E4M3),
            v_store: MomentStore::Fp8(E5M2),
            ..base.clone()
        };
        let r = ours.total_bytes_per_worker() / base.total_bytes_per_worker();
        // paper: 44.08 / 63.25 = 0.697
        assert!(r < 0.75 && r > 0.62, "reduction ratio {r}");
    }
}
