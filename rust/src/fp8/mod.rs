//! Real u8 FP8 codecs — E4M3 (fn variant) and E5M2.
//!
//! The Python layer simulates FP8 on f32 value grids; *this* module is
//! where FP8 becomes one actual byte: optimizer-moment storage and
//! checkpoints are packed through these codecs, so the Table 4 memory
//! reduction is measured, not estimated. Conversion semantics match
//! ml_dtypes/XLA exactly (RNE; E4M3 overflow → NaN, E5M2 overflow → ±inf),
//! which `python/tests/test_formats.py` pins on the Python side and
//! `rust/tests/hotpath.rs` pins here.
//!
//! Two implementations, one semantics: [`format`] is the scalar
//! reference codec; [`bulk`] is the table-driven slice codec the hot
//! paths use (LUT decode, integer-rounding encode, scoped-thread
//! fan-out), required bit-equivalent to the reference by test.

pub mod bulk;
pub mod format;
pub mod stochastic;
pub use format::{Fp8Format, E4M3, E5M2};
pub use stochastic::{encode_sr, qdq_sr};

/// Encode an f32 to the format's u8 representation (RNE).
pub fn encode(fmt: Fp8Format, x: f32) -> u8 {
    fmt.encode(x)
}

/// Decode a u8 back to f32.
pub fn decode(fmt: Fp8Format, b: u8) -> f32 {
    fmt.decode(b)
}

/// Quantize-dequantize on the f32 grid (must agree with the Python
/// `formats.quantize_grid`).
pub fn qdq(fmt: Fp8Format, x: f32) -> f32 {
    fmt.decode(fmt.encode(x))
}

/// Pack a slice of f32 (assumed to lie on `scale`-scaled fp8 grid or
/// not — values are rounded) into bytes. Returns (bytes, scale) where
/// scale is the pow2 JIT scale chosen from the slice amax, matching
/// `python/compile/formats.compute_scale`.
///
/// Runs on the table-driven [`bulk`] codec (parallel above the size
/// threshold); NaN elements encode to the format's NaN byte rather
/// than folding into the amax. Allocation-sensitive callers should use
/// [`bulk::pack_scaled_into`] directly with a reused buffer.
pub fn pack_scaled(fmt: Fp8Format, xs: &[f32]) -> (Vec<u8>, f32) {
    let mut bytes = Vec::new();
    let scale = bulk::pack_scaled_into(fmt, xs, &mut bytes);
    (bytes, scale)
}

/// Unpack bytes produced by [`pack_scaled`] (bulk LUT decode).
pub fn unpack_scaled(fmt: Fp8Format, bytes: &[u8], scale: f32, out: &mut Vec<f32>) {
    bulk::unpack_scaled_into(fmt, bytes, scale, out);
}

/// Pow2 JIT scale positioning `amax` inside the format range — the
/// same policy as the Python side and `scaling::policy`.
pub fn compute_scale(fmt: Fp8Format, amax: f32) -> f32 {
    let amax = amax.max(1e-12);
    let e = (fmt.max() / amax).log2().floor() as i32;
    let s = exp2i(e);
    if amax * s > fmt.max() {
        s * 0.5
    } else {
        s
    }
}

/// Exact 2^e for f32 (ldexp).
pub fn exp2i(e: i32) -> f32 {
    let e = e.clamp(-126, 127);
    f32::from_bits(((e + 127) as u32) << 23)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qdq_fixed_points() {
        for fmt in [E4M3, E5M2] {
            for code in 0u16..=255 {
                let v = fmt.decode(code as u8);
                if v.is_finite() {
                    assert_eq!(qdq(fmt, v).to_bits(), v.to_bits(), "{fmt:?} code={code}");
                }
            }
        }
    }

    #[test]
    fn known_values_e4m3() {
        assert_eq!(qdq(E4M3, 448.0), 448.0);
        assert!(qdq(E4M3, 1000.0).is_nan()); // overflow -> NaN (fn variant)
        assert_eq!(qdq(E4M3, 0.3), 0.3125);
        assert_eq!(qdq(E4M3, 2f32.powi(-9)), 2f32.powi(-9)); // min subnormal
        assert_eq!(qdq(E4M3, 2f32.powi(-10)), 0.0); // ties to even -> 0
    }

    #[test]
    fn known_values_e5m2() {
        assert_eq!(qdq(E5M2, 57344.0), 57344.0);
        assert!(qdq(E5M2, 1e9).is_infinite()); // overflow -> inf
        assert_eq!(qdq(E5M2, 2f32.powi(-16)), 2f32.powi(-16));
        assert_eq!(qdq(E5M2, 1000.0), 1024.0);
    }

    #[test]
    fn pack_roundtrip_error_bounded() {
        let xs: Vec<f32> = (0..1000).map(|i| ((i as f32) * 0.37 - 180.0) * 1e-4).collect();
        for fmt in [E4M3, E5M2] {
            let (bytes, scale) = pack_scaled(fmt, &xs);
            let mut out = Vec::new();
            unpack_scaled(fmt, &bytes, scale, &mut out);
            let step = 2f32.powi(-(fmt.man_bits() as i32));
            for (&x, &y) in xs.iter().zip(&out) {
                let tol = x.abs() * step + fmt.min_subnormal() / scale;
                assert!((x - y).abs() <= tol, "{fmt:?}: {x} -> {y}");
            }
        }
    }

    #[test]
    fn pack_scaled_propagates_nan() {
        // regression: a NaN element is invisible to the amax fold
        // (f32::max drops NaN) — it must still come back as NaN, and
        // must not perturb the scale its finite neighbors get.
        let mut xs: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.1).collect();
        xs[7] = f32::NAN;
        for fmt in [E4M3, E5M2] {
            let (bytes, scale) = pack_scaled(fmt, &xs);
            assert!(fmt.decode(bytes[7]).is_nan(), "{fmt:?}: NaN must survive packing");
            let clean: Vec<f32> =
                xs.iter().enumerate().filter(|&(i, _)| i != 7).map(|(_, &x)| x).collect();
            let (_, clean_scale) = pack_scaled(fmt, &clean);
            assert_eq!(scale, clean_scale, "{fmt:?}: NaN must not move the scale");
            let mut out = Vec::new();
            unpack_scaled(fmt, &bytes, scale, &mut out);
            assert!(out[7].is_nan());
            assert!((out[6] - xs[6]).abs() < 0.05, "{fmt:?}: neighbors unharmed");
        }
    }

    #[test]
    fn compute_scale_is_pow2_and_in_range() {
        for fmt in [E4M3, E5M2] {
            for amax in [1e-9f32, 1e-3, 1.0, 447.9, 448.0, 1e7] {
                let s = compute_scale(fmt, amax);
                assert_eq!(s, exp2i(s.log2().round() as i32), "pow2");
                assert!(amax * s <= fmt.max() * 1.000001);
                assert!(amax * s > fmt.max() / 4.0);
            }
        }
    }
}
