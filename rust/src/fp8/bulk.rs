//! Table-driven bulk FP8 codec — the hot-path counterpart to the
//! scalar reference implementation in [`super::format`].
//!
//! Three ideas, in order of payoff:
//!
//! 1. **Decode is a 256-entry LUT** per format, built once behind a
//!    `OnceLock` from the scalar codec (so the table is correct by
//!    construction). Bulk decode is one indexed load per byte — no
//!    exponent branches, no `exp2` — and auto-vectorizes.
//! 2. **Encode rounds in integer bit arithmetic** with a single range
//!    check per element on the normal path. Adding the RNE bias to the
//!    raw f32 bits lets the mantissa carry propagate into the exponent
//!    field for free, and one rebias subtraction produces the fp8 code
//!    directly. Subnormals, zeros, NaN/inf and overflow fall through to
//!    the scalar codec, which stays the single source of truth for the
//!    cold cases. The hot range is chosen so the bit trick is *provably*
//!    identical to `Fp8Format::encode` (see the equivalence tests in
//!    `rust/tests/hotpath.rs`: all 256 codes plus a 1M-point PRNG sweep).
//! 3. **Slice APIs write into caller-owned buffers** and fan out across
//!    a small scoped-thread pool above `util::par::PAR_THRESHOLD`
//!    elements. All operations are elementwise (or fixed-order folds),
//!    so the parallel result is bit-identical to the serial one.

use std::sync::OnceLock;

use crate::util::par::{par_partials, par_zip, PAR_CHUNK};

use super::format::Fp8Format;

/// The 256-entry decode table for `fmt`, built once per process.
pub fn decode_lut(fmt: Fp8Format) -> &'static [f32; 256] {
    static E4M3_LUT: OnceLock<[f32; 256]> = OnceLock::new();
    static E5M2_LUT: OnceLock<[f32; 256]> = OnceLock::new();
    let cell = match fmt {
        Fp8Format::E4M3 => &E4M3_LUT,
        Fp8Format::E5M2 => &E5M2_LUT,
    };
    cell.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (code, slot) in t.iter_mut().enumerate() {
            *slot = fmt.decode(code as u8);
        }
        t
    })
}

/// Precomputed constants for the branch-light encode path.
///
/// Hot range (on |x| as raw f32 bits): `[hot_lo, hot_hi)` where
/// `hot_lo` is the format's min normal and `hot_hi` is the first
/// magnitude whose *rounded* exponent would escape the fp8 exponent
/// field. Inside the range the integer formula below reproduces the
/// scalar encoder exactly, including the overflow codes: E4M3 values
/// in (464, 496) round onto the NaN pattern 0x7f, E5M2 values in
/// (61440, 65536) carry into biased exponent 31 with mantissa 0 —
/// which *is* the ±inf code 0x7c.
#[derive(Clone, Copy)]
pub struct EncodeParams {
    shift: u32,
    rebias: u32,
    hot_lo: u32,
    hot_hi: u32,
}

impl EncodeParams {
    /// The encode constants for `fmt` (hoist out of per-element loops).
    pub fn of(fmt: Fp8Format) -> Self {
        match fmt {
            // shift = 23 - man_bits; rebias = (127 - bias) << man_bits
            Fp8Format::E4M3 => EncodeParams {
                shift: 20,
                rebias: 120 << 3,
                hot_lo: 0x3c80_0000, // 2^-6
                hot_hi: 0x43f8_0000, // 496.0 = first magnitude rounding past e=8
            },
            Fp8Format::E5M2 => EncodeParams {
                shift: 21,
                rebias: 112 << 2,
                hot_lo: 0x3880_0000, // 2^-14
                hot_hi: 0x4780_0000, // 65536.0 = 2^16
            },
        }
    }
}

/// One element through the table-driven encoder. Exactly equivalent to
/// `fmt.encode(x)` for every f32 bit pattern (pinned by tests). Public
/// for callers with their own scaling policy (the tile-wise GEMM
/// quantizer in [`crate::gemm`]); slice-at-a-time callers should prefer
/// [`encode_slice_into`] / [`pack_scaled_into`].
#[inline]
pub fn encode_one(fmt: Fp8Format, p: EncodeParams, x: f32) -> u8 {
    let bits = x.to_bits();
    let abs = bits & 0x7fff_ffff;
    if abs >= p.hot_lo && abs < p.hot_hi {
        // RNE bias addition: half = 2^(shift-1) - 1 + lsb. A mantissa
        // carry rolls into the exponent field of `abs` itself, which is
        // precisely the "rounded up a binade" case; the rebias
        // subtraction then converts the IEEE-754 biased exponent to the
        // fp8 one in the same move.
        let sign = ((bits >> 24) & 0x80) as u8;
        let lsb = (abs >> p.shift) & 1;
        let rounded = abs + ((1u32 << (p.shift - 1)) - 1) + lsb;
        sign | ((rounded >> p.shift) - p.rebias) as u8
    } else {
        // cold: zero, subnormal, NaN/inf, far overflow — the scalar
        // codec is the reference for all of these
        fmt.encode(x)
    }
}

/// Bulk encode into a caller-owned buffer (cleared + resized).
pub fn encode_slice_into(fmt: Fp8Format, xs: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.resize(xs.len(), 0);
    let p = EncodeParams::of(fmt);
    par_zip(xs, &mut out[..], |xs, out| {
        for (d, &x) in out.iter_mut().zip(xs) {
            *d = encode_one(fmt, p, x);
        }
    });
}

/// Bulk decode into a caller-owned buffer (cleared + resized).
pub fn decode_slice_into(fmt: Fp8Format, bytes: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.resize(bytes.len(), 0.0);
    decode_slice_buf(fmt, bytes, &mut out[..]);
}

/// Bulk decode into an exact-size destination slice.
pub fn decode_slice_buf(fmt: Fp8Format, bytes: &[u8], out: &mut [f32]) {
    let lut = decode_lut(fmt);
    par_zip(bytes, out, |bytes, out| {
        for (d, &b) in out.iter_mut().zip(bytes) {
            *d = lut[b as usize];
        }
    });
}

/// Amax of a slice, NaN-ignoring (`f32::max` drops NaN operands): the
/// JIT scale must stay finite even on a poisoned buffer. NaN *elements*
/// are propagated explicitly by [`pack_scaled_into`] instead of being
/// folded into the scale.
pub fn slice_amax(xs: &[f32]) -> f32 {
    // chunked partial maxes: max is associative/commutative over the
    // non-NaN values, so the grouping cannot change the result — the
    // partials exist purely so the fold can fan out
    par_partials(xs, PAR_CHUNK, |span| span.iter().fold(0.0f32, |a, &x| a.max(x.abs())))
        .into_iter()
        .fold(0.0f32, f32::max)
}

/// Bulk [`super::pack_scaled`]: amax → pow2 JIT scale → scaled encode,
/// writing into a caller-owned byte buffer. Returns the scale.
///
/// NaN elements encode to the format's NaN byte *explicitly* — they are
/// invisible to the amax fold (see [`slice_amax`]), so without this
/// branch a NaN would be quantized against whatever scale its finite
/// neighbors chose. (`x * scale` keeps NaN NaN, so the scalar encoder
/// happens to do the right thing — the branch makes the contract
/// load-bearing rather than incidental, and the regression test in
/// `rust/tests/hotpath.rs` pins it.)
pub fn pack_scaled_into(fmt: Fp8Format, xs: &[f32], out: &mut Vec<u8>) -> f32 {
    let amax = slice_amax(xs);
    let scale = super::compute_scale(fmt, amax);
    let max = fmt.max();
    let p = EncodeParams::of(fmt);
    out.clear();
    out.resize(xs.len(), 0);
    par_zip(xs, &mut out[..], |xs, out| {
        for (d, &x) in out.iter_mut().zip(xs) {
            *d = if x.is_nan() {
                fmt.encode(x) // sign | NaN code, independent of scale
            } else {
                encode_one(fmt, p, (x * scale).clamp(-max, max))
            };
        }
    });
    scale
}

/// [`pack_scaled_into`] accepted only when the roundtrip is **bit
/// exact**: encodes `xs` into `out` with the per-slice pow2 auto scale
/// and returns `Some(scale)` iff `decode(bytes) / scale` reproduces
/// every f32 bit of `xs`; otherwise clears `out` and returns `None`.
///
/// This is the write-time verification shared by the checkpoint
/// layer's exact-FP8 sections ([`crate::checkpoint::Writer`]) and the
/// optimizer's resident moment shards
/// ([`crate::optimizer::MomentBuffer`]): data on a per-slice FP8 grid
/// (chunked Adam moment outputs) packs at 1 byte/element, anything
/// else — including NaNs, whose payload bits a decode cannot
/// reproduce — must fall back to raw f32 at the caller.
pub fn pack_scaled_exact_into(fmt: Fp8Format, xs: &[f32], out: &mut Vec<u8>) -> Option<f32> {
    let scale = pack_scaled_into(fmt, xs, out);
    if !scale.is_finite() {
        out.clear();
        return None;
    }
    let lut = decode_lut(fmt);
    let exact = xs
        .iter()
        .zip(out.iter())
        .all(|(&x, &b)| (lut[b as usize] / scale).to_bits() == x.to_bits());
    if exact {
        Some(scale)
    } else {
        out.clear();
        None
    }
}

/// Bulk [`super::unpack_scaled`]: LUT decode + descale into a
/// caller-owned buffer (cleared + resized).
pub fn unpack_scaled_into(fmt: Fp8Format, bytes: &[u8], scale: f32, out: &mut Vec<f32>) {
    out.clear();
    out.resize(bytes.len(), 0.0);
    unpack_scaled_buf(fmt, bytes, scale, &mut out[..]);
}

/// Bulk unpack into an exact-size destination slice (the
/// `MomentBuffer` unpack path decodes chunk-by-chunk into one flat
/// buffer without an intermediate Vec).
pub fn unpack_scaled_buf(fmt: Fp8Format, bytes: &[u8], scale: f32, out: &mut [f32]) {
    let lut = decode_lut(fmt);
    // division (not reciprocal multiply) to stay bit-identical with the
    // scalar reference `decode(b) / scale` for any scale value
    par_zip(bytes, out, |bytes, out| {
        for (d, &b) in out.iter_mut().zip(bytes) {
            *d = lut[b as usize] / scale;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{E4M3, E5M2};

    #[test]
    fn lut_matches_scalar_decode() {
        for fmt in [E4M3, E5M2] {
            let lut = decode_lut(fmt);
            for code in 0u16..=255 {
                let a = lut[code as usize];
                let b = fmt.decode(code as u8);
                assert!(
                    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
                    "{fmt:?} code {code:#x}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn encode_one_matches_scalar_on_boundaries() {
        // the seams of the hot range, both sides, both signs
        let probes = [
            0.0f32,
            -0.0,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE / 2.0,
            2f32.powi(-6),
            2f32.powi(-6) - 2f32.powi(-20),
            2f32.powi(-9),
            2f32.powi(-14),
            2f32.powi(-16),
            447.9,
            448.0,
            463.9,
            464.0,
            464.1,
            495.9,
            496.0,
            512.0,
            1000.0,
            57344.0,
            61439.9,
            61440.0,
            61440.1,
            65535.9,
            65536.0,
            1e9,
        ];
        for fmt in [E4M3, E5M2] {
            let p = EncodeParams::of(fmt);
            for &v in &probes {
                for x in [v, -v] {
                    assert_eq!(
                        encode_one(fmt, p, x),
                        fmt.encode(x),
                        "{fmt:?} x={x}"
                    );
                }
            }
        }
    }

    #[test]
    fn slice_apis_roundtrip() {
        let xs: Vec<f32> = (0..5000).map(|i| ((i as f32) - 2500.0) * 0.01).collect();
        for fmt in [E4M3, E5M2] {
            let mut bytes = Vec::new();
            encode_slice_into(fmt, &xs, &mut bytes);
            assert_eq!(bytes.len(), xs.len());
            let mut back = Vec::new();
            decode_slice_into(fmt, &bytes, &mut back);
            for (i, (&x, &y)) in xs.iter().zip(&back).enumerate() {
                assert_eq!(y.to_bits(), fmt.decode(fmt.encode(x)).to_bits(), "{fmt:?} i={i}");
            }
        }
    }

    #[test]
    fn pack_scaled_exact_accepts_grid_rejects_offgrid() {
        for fmt in [E4M3, E5M2] {
            // on-grid: decode every finite code at a pow2 scale — the
            // JIT scale must land back on a grid the codes reproduce
            let scale = 0.25f32;
            let xs: Vec<f32> = (0..=255u8)
                .map(|c| fmt.decode(c))
                .filter(|v| v.is_finite())
                .map(|v| v / scale)
                .collect();
            let mut bytes = Vec::new();
            let got = pack_scaled_exact_into(fmt, &xs, &mut bytes);
            assert!(got.is_some(), "{fmt:?}: grid data must pack exactly");
            assert_eq!(bytes.len(), xs.len());
            let mut back = Vec::new();
            unpack_scaled_into(fmt, &bytes, got.unwrap(), &mut back);
            for (a, b) in xs.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?}: roundtrip must be bit-exact");
            }
            // off-grid: arbitrary irrationals cannot roundtrip
            let off: Vec<f32> = (0..100).map(|i| ((i as f32) * 0.7311).sin() * 3.7).collect();
            assert!(pack_scaled_exact_into(fmt, &off, &mut bytes).is_none());
            assert!(bytes.is_empty(), "{fmt:?}: rejected pack must clear the buffer");
            // NaN payload bits cannot survive a decode — must reject
            let nans = [f32::from_bits(0x7fc0_1234), 1.0, 2.0];
            assert!(pack_scaled_exact_into(fmt, &nans, &mut bytes).is_none());
        }
    }

    #[test]
    fn amax_ignores_nan_and_matches_fold() {
        let mut xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin()).collect();
        xs[500] = f32::NAN;
        let expect = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        assert_eq!(slice_amax(&xs), expect);
        assert!(slice_amax(&xs).is_finite());
    }
}
