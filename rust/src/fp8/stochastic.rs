//! Stochastic rounding for FP8 — an extension beyond the paper.
//!
//! The paper's scheme uses round-to-nearest-even everywhere. For the
//! *optimizer moments* (§5), SR is the natural next step: RNE
//! systematically loses sub-ulp gradient mass in the first-moment EMA
//! (`β·m` barely moves for |Δ| below half an ulp), whereas SR is
//! unbiased in expectation. This module provides an SR encoder wired
//! to the deterministic PRNG so runs stay reproducible, plus the
//! statistical machinery the ablation bench uses.

use crate::util::prng::Rng;

use super::format::Fp8Format;

/// Stochastically round `x` onto the fp8 grid: the two bracketing grid
/// values are chosen with probability proportional to proximity.
/// Overflow saturates to ±max (SR between max and inf is meaningless).
pub fn encode_sr(fmt: Fp8Format, x: f32, rng: &mut Rng) -> u8 {
    if x.is_nan() {
        return fmt.encode(x);
    }
    let max = fmt.max();
    let x = x.clamp(-max, max);
    let lo = round_down(fmt, x);
    let lo_v = fmt.decode(lo);
    if lo_v == x {
        return lo;
    }
    let hi = next_up(fmt, lo);
    let hi_v = fmt.decode(hi);
    let t = ((x - lo_v) / (hi_v - lo_v)) as f64;
    if rng.uniform() < t {
        hi
    } else {
        lo
    }
}

/// qdq with stochastic rounding.
pub fn qdq_sr(fmt: Fp8Format, x: f32, rng: &mut Rng) -> f32 {
    fmt.decode(encode_sr(fmt, x, rng))
}

/// Largest grid value ≤ x (x finite, |x| ≤ max).
fn round_down(fmt: Fp8Format, x: f32) -> u8 {
    // encode rounds to nearest; step down if it overshot
    let e = fmt.encode(x);
    let v = fmt.decode(e);
    if v <= x {
        e
    } else {
        prev_down(fmt, e)
    }
}

/// Next representable value above the one encoded by `b` (same sign
/// walk on the code wheel; crosses zero correctly).
fn next_up(fmt: Fp8Format, b: u8) -> u8 {
    let v = fmt.decode(b);
    // monotone scan is fine at 256 codes; called on the cold path only
    let mut best = b;
    let mut best_v = f32::INFINITY;
    for c in 0u16..=255 {
        let w = fmt.decode(c as u8);
        if w.is_finite() && w > v && w < best_v {
            best = c as u8;
            best_v = w;
        }
    }
    best
}

fn prev_down(fmt: Fp8Format, b: u8) -> u8 {
    let v = fmt.decode(b);
    let mut best = b;
    let mut best_v = f32::NEG_INFINITY;
    for c in 0u16..=255 {
        let w = fmt.decode(c as u8);
        if w.is_finite() && w < v && w > best_v {
            best = c as u8;
            best_v = w;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{E4M3, E5M2};

    #[test]
    fn sr_hits_only_bracketing_values() {
        let mut rng = Rng::new(1);
        let x = 0.3f32; // between 0.28125 and 0.3125 on E4M3
        for _ in 0..100 {
            let v = qdq_sr(E4M3, x, &mut rng);
            assert!(v == 0.28125 || v == 0.3125, "{v}");
        }
    }

    #[test]
    fn sr_is_unbiased() {
        let mut rng = Rng::new(2);
        let x = 0.29f32;
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| qdq_sr(E4M3, x, &mut rng) as f64).sum::<f64>() / n as f64;
        assert!(
            (mean - x as f64).abs() < 3e-4,
            "SR must be unbiased: mean {mean} vs {x}"
        );
    }

    #[test]
    fn sr_exact_values_stay_fixed() {
        let mut rng = Rng::new(3);
        for fmt in [E4M3, E5M2] {
            for code in 0u16..=255 {
                let v = fmt.decode(code as u8);
                if v.is_finite() {
                    assert_eq!(qdq_sr(fmt, v, &mut rng).to_bits(), v.to_bits());
                }
            }
        }
    }

    #[test]
    fn sr_saturates_overflow() {
        let mut rng = Rng::new(4);
        assert_eq!(qdq_sr(E4M3, 1e9, &mut rng), 448.0);
        assert_eq!(qdq_sr(E4M3, -1e9, &mut rng), -448.0);
    }

    #[test]
    fn sr_ema_preserves_small_updates_where_rne_stalls() {
        // the motivating property: EMA m' = 0.9 m + 0.1 g with g one
        // tenth of an ulp — RNE freezes, SR drifts toward the target
        let fmt = E4M3;
        let m0 = 1.0f32;
        let g = 1.0 + 8.0 * 0.125; // target far above
        let step = |m: f32, rng: &mut Option<&mut Rng>| {
            let raw = 0.9 * m + 0.1 * g;
            match rng {
                Some(r) => qdq_sr(fmt, raw, r),
                None => fmt.decode(fmt.encode(raw)),
            }
        };
        let mut rng = Rng::new(5);
        let mut m_sr = m0;
        let mut m_rne = m0;
        for _ in 0..200 {
            m_sr = step(m_sr, &mut Some(&mut rng));
            m_rne = step(m_rne, &mut None);
        }
        // both should approach g; SR must get at least as close
        assert!((m_sr - g).abs() <= (m_rne - g).abs() + 1e-6);
        assert!((m_sr - g).abs() < 0.3, "SR EMA must track: {m_sr} vs {g}");
    }
}
