//! Bit-level E4M3/E5M2 encode/decode.
//!
//! E4M3 is the "fn" (finite + NaN) variant standardized in Micikevicius
//! et al. 2022: no infinities, one NaN pattern (S.1111.111), max 448.
//! E5M2 follows IEEE-754 conventions: inf at S.11111.00, NaNs above,
//! max 57344.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fp8Format {
    E4M3,
    E5M2,
}

pub const E4M3: Fp8Format = Fp8Format::E4M3;
pub const E5M2: Fp8Format = Fp8Format::E5M2;

impl Fp8Format {
    pub const fn exp_bits(self) -> u32 {
        match self {
            Fp8Format::E4M3 => 4,
            Fp8Format::E5M2 => 5,
        }
    }

    pub const fn man_bits(self) -> u32 {
        match self {
            Fp8Format::E4M3 => 3,
            Fp8Format::E5M2 => 2,
        }
    }

    pub const fn bias(self) -> i32 {
        match self {
            Fp8Format::E4M3 => 7,
            Fp8Format::E5M2 => 15,
        }
    }

    pub fn max(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }

    pub fn min_normal(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 2f32.powi(-6),
            Fp8Format::E5M2 => 2f32.powi(-14),
        }
    }

    pub fn min_subnormal(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 2f32.powi(-9),
            Fp8Format::E5M2 => 2f32.powi(-16),
        }
    }

    pub const fn has_inf(self) -> bool {
        matches!(self, Fp8Format::E5M2)
    }

    /// f32 → fp8 byte, round-to-nearest-even, ml_dtypes-compatible
    /// overflow semantics (E4M3 → NaN 0x7f/0xff, E5M2 → ±inf).
    pub fn encode(self, x: f32) -> u8 {
        let mb = self.man_bits();
        let bias = self.bias();
        let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
        if x.is_nan() {
            return sign | self.nan_code();
        }
        if x.is_infinite() {
            return sign | if self.has_inf() { 0x7c } else { self.nan_code() };
        }
        let ax = x.abs();
        if ax == 0.0 {
            return sign;
        }

        // Scale into the fp8 subnormal grid to round once, exactly:
        // units of min_subnormal for the subnormal range; normals get
        // mantissa rounding at their own binade below.
        if ax < self.min_normal() {
            // subnormal: round ax / min_subnormal RNE to an integer
            let q = rne_round(ax / self.min_subnormal());
            if q == 0 {
                return sign;
            }
            if q < (1 << mb) {
                return sign | q as u8;
            }
            // rounded up into the first normal binade
            return sign | (1 << mb);
        }

        // normal path: decompose into exponent + mantissa
        let bits = ax.to_bits();
        let e32 = ((bits >> 23) & 0xff) as i32 - 127;
        let man32 = bits & 0x7f_ffff;
        // RNE the 23-bit mantissa down to mb bits
        let shift = 23 - mb;
        let lsb = (man32 >> shift) & 1;
        let half = (1u32 << (shift - 1)) - 1 + lsb;
        let mut man = (man32 + half) >> shift;
        let mut e = e32;
        if man == (1 << mb) {
            man = 0;
            e += 1;
        }
        let emax = match self {
            Fp8Format::E4M3 => 8,  // 448 = 2^8 * 1.75
            Fp8Format::E5M2 => 15, // 57344 = 2^15 * 1.75
        };
        if e > emax || (e == emax && self.is_overflow_mantissa(man)) {
            return sign | if self.has_inf() { 0x7c } else { self.nan_code() };
        }
        let biased = (e + bias) as u32;
        sign | ((biased << mb) as u8) | (man as u8)
    }

    fn is_overflow_mantissa(self, man: u32) -> bool {
        // E4M3: exponent 8 with mantissa 111 is the NaN pattern, so the
        // largest finite is 1.110 * 2^8 = 448; mantissa 111 overflows.
        // E5M2: exponent 15 with any mantissa is inf/NaN, so *all*
        // mantissas overflow at e=15 except... 1.11*2^15 = 57344 uses
        // biased exponent 30 (e=15): representable. Overflow only past
        // the all-ones biased exponent.
        match self {
            Fp8Format::E4M3 => man == 0b111,
            Fp8Format::E5M2 => false,
        }
    }

    fn nan_code(self) -> u8 {
        match self {
            Fp8Format::E4M3 => 0x7f,
            Fp8Format::E5M2 => 0x7e, // a quiet NaN pattern (exp=31, man!=0)
        }
    }

    /// fp8 byte → f32 (exact).
    pub fn decode(self, b: u8) -> f32 {
        let mb = self.man_bits();
        let eb = self.exp_bits();
        let bias = self.bias();
        let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
        let exp = ((b >> mb) & ((1 << eb) - 1)) as i32;
        let man = (b & ((1 << mb) - 1)) as u32;

        match self {
            Fp8Format::E4M3 => {
                if exp == 0b1111 && man == 0b111 {
                    return f32::NAN;
                }
            }
            Fp8Format::E5M2 => {
                if exp == 0b11111 {
                    return if man == 0 { sign * f32::INFINITY } else { f32::NAN };
                }
            }
        }
        if exp == 0 {
            return sign * (man as f32) * self.min_subnormal();
        }
        let frac = 1.0 + (man as f32) / (1 << mb) as f32;
        sign * frac * exp2f(exp - bias)
    }
}

fn exp2f(e: i32) -> f32 {
    if (-126..=127).contains(&e) {
        f32::from_bits(((e + 127) as u32) << 23)
    } else {
        (e as f32).exp2()
    }
}

fn rne_round(x: f32) -> u32 {
    let fl = x.floor();
    let frac = x - fl;
    let base = fl as u32;
    if frac > 0.5 {
        base + 1
    } else if frac < 0.5 {
        base
    } else if base % 2 == 0 {
        base
    } else {
        base + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_wheel() {
        // decode every code, re-encode, expect identity (except NaN)
        for code in 0u16..=255 {
            let v = E4M3.decode(code as u8);
            if v.is_nan() {
                continue;
            }
            let back = E4M3.encode(v);
            assert_eq!(back, code as u8, "code {code:#x} -> {v} -> {back:#x}");
        }
    }

    #[test]
    fn e5m2_wheel() {
        for code in 0u16..=255 {
            let v = E5M2.decode(code as u8);
            if v.is_nan() {
                continue;
            }
            let back = E5M2.encode(v);
            if v.is_infinite() {
                assert_eq!(back & 0x7f, 0x7c);
                assert_eq!(back & 0x80, (code as u8) & 0x80);
            } else {
                assert_eq!(back, code as u8, "code {code:#x} -> {v} -> {back:#x}");
            }
        }
    }

    #[test]
    fn midpoint_rounding_even() {
        // between 1.0 (mantissa 000) and 1.125 (mantissa 001) for e4m3:
        // midpoint 1.0625 must round to even mantissa -> 1.0
        assert_eq!(E4M3.decode(E4M3.encode(1.0625)), 1.0);
        // between 1.125 and 1.25 midpoint 1.1875 -> 1.25 (odd -> up to even)
        assert_eq!(E4M3.decode(E4M3.encode(1.1875)), 1.25);
    }

    #[test]
    fn subnormal_boundary() {
        // largest e4m3 subnormal: 7 * 2^-9; min normal 2^-6
        let sub_max = 7.0 * 2f32.powi(-9);
        assert_eq!(E4M3.decode(E4M3.encode(sub_max)), sub_max);
        // halfway between sub_max and min_normal rounds to even (min normal)
        let mid = (sub_max + 2f32.powi(-6)) / 2.0;
        assert_eq!(E4M3.decode(E4M3.encode(mid)), 2f32.powi(-6));
    }

    #[test]
    fn signs() {
        assert_eq!(E4M3.encode(-0.0) & 0x80, 0x80);
        assert_eq!(E4M3.decode(0x80), 0.0);
        assert!(E4M3.decode(0x80).is_sign_negative());
        assert_eq!(E5M2.encode(-1e9) & 0x80, 0x80);
        assert!(E5M2.decode(E5M2.encode(-1e9)).is_infinite());
    }
}
