//! Executable loading + execution: the `/opt/xla-example/load_hlo`
//! pattern hardened into a cached runtime.
//!
//! One [`Runtime`] owns the PJRT CPU client and a lazy cache of
//! compiled [`Artifact`]s keyed by name. Artifacts are HLO **text**
//! (see aot.py for why) compiled once per process; execution is
//! positional literals in, tuple of literals out, with the manifest
//! defining both orders.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;
use super::tensor::HostTensor;

pub struct Artifact {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

// SAFETY: the PJRT API is thread-safe (the TFRT CPU client serializes
// internally; executions and buffer transfers may be issued from any
// thread). The `xla` crate just wraps raw pointers without declaring
// this, so the auto-traits are opted into here once for the runtime.
unsafe impl Send for Artifact {}
unsafe impl Sync for Artifact {}

impl Artifact {
    /// Execute with positional inputs; returns the flattened output
    /// tuple in manifest order.
    ///
    /// Inputs go through `buffer_from_host_literal` + `execute_b`
    /// rather than `execute`: the crate's C++ `execute` wrapper leaks
    /// every input device buffer (`buffer.release()` with no matching
    /// free — ~80 MB/step at s1m, found with rust/src/bin/leakprobe.rs).
    /// With `execute_b` the buffers are owned on the Rust side and
    /// freed on drop after the synchronous output transfer completes.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// [`run`](Self::run) over borrowed inputs. Execution only reads
    /// the tensors to build literals, so callers with a large shared
    /// input prefix (the replicated parameters, identical for every
    /// data-parallel worker) can pass references instead of deep
    /// `HostTensor` clones.
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        // input literals must outlive execute_b: BufferFromHostLiteral's
        // host->device copy is asynchronous and reads the literal memory
        let mut lits = Vec::with_capacity(inputs.len());
        let mut bufs = Vec::with_capacity(inputs.len());
        for &t in inputs {
            let lit = t.to_literal().context("building input literal")?;
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, &lit)
                    .context("host->device transfer")?,
            );
            lits.push(lit);
        }
        let out = self
            .exe
            .execute_b(&bufs)
            .with_context(|| format!("executing artifact '{}'", self.manifest.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .context("device->host transfer")?;
        // safe to release inputs: the output transfer synchronized the run
        drop(bufs);
        drop(lits);
        let parts = lit.to_tuple().context("untupling outputs")?;
        parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Artifact>>>,
}

// SAFETY: see `Artifact` above.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create the PJRT CPU client rooted at an artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(anyhow!(
                "artifacts directory '{}' not found — run `make artifacts` first",
                dir.display()
            ));
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir, cache: Mutex::new(HashMap::new()) })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile (cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let man = self.dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let artifact =
            std::sync::Arc::new(Artifact { manifest, exe, client: self.client.clone() });
        self.cache.lock().unwrap().insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Names of all artifacts present on disk.
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        e.file_name()
                            .to_str()
                            .and_then(|s| s.strip_suffix(".hlo.txt"))
                            .map(str::to_string)
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }
}
