//! Runtime: load AOT artifacts (HLO text) and execute them on the PJRT
//! CPU client. Python never runs here — the artifacts directory is the
//! entire interface to L1/L2.

pub mod executable;
pub mod manifest;
pub mod tensor;

pub use executable::{Artifact, Runtime};
pub use manifest::Manifest;
pub use tensor::HostTensor;
