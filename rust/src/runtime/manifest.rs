//! Artifact manifests: the JSON contract emitted by `python/compile/aot.py`
//! alongside every HLO module. Parsing is strict — a manifest/HLO
//! mismatch must fail loudly at load time, not corrupt a training run.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// N(0, std²) init; std < 0 means "init to ones" (norm gains)
    pub init_std: f32,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub kind: String,
    pub name: String,
    pub size: String,
    pub recipe: String,
    pub batch: usize,
    pub seq_len: usize,
    pub n_scales: usize,
    pub n_layers: usize,
    pub sites_per_layer: Vec<String>,
    pub params: Vec<ParamSpec>,
    pub model: Option<ModelDims>,
    pub param_count: usize,
    pub flops_per_step: f64,
    /// adam artifacts
    pub chunk: usize,
    pub m_fmt: String,
    pub v_fmt: String,
    /// probe artifacts
    pub layer: usize,
    pub raw: Json,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("manifest {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest {}: {e}", path.display()))?;
        let name = path
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or("")
            .trim_end_matches(".manifest.json")
            .to_string();
        Self::from_json(name, j).map_err(|e| anyhow!("manifest {}: {e}", path.display()))
    }

    pub fn from_json(name: String, j: Json) -> Result<Self, String> {
        let kind = j.str_of("kind")?.to_string();
        let params = match j.get("params") {
            Some(Json::Arr(a)) => a
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.str_of("name")?.to_string(),
                        shape: p
                            .arr_of("shape")?
                            .iter()
                            .map(|d| d.as_usize().ok_or("bad shape dim".to_string()))
                            .collect::<Result<_, _>>()?,
                        init_std: p.f64_of("init_std")? as f32,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?,
            _ => Vec::new(),
        };
        let model = j.get("model").map(|m| -> Result<ModelDims, String> {
            Ok(ModelDims {
                vocab: m.usize_of("vocab")?,
                d_model: m.usize_of("d_model")?,
                n_layers: m.usize_of("n_layers")?,
                n_heads: m.usize_of("n_heads")?,
                d_ff: m.usize_of("d_ff")?,
                seq_len: m.usize_of("seq_len")?,
            })
        });
        let model = match model {
            Some(Ok(m)) => Some(m),
            Some(Err(e)) => return Err(e),
            None => None,
        };
        let sites = j
            .get("sites_per_layer")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|s| s.as_str().map(str::to_string)).collect())
            .unwrap_or_default();
        Ok(Self {
            kind,
            name,
            size: j.str_or("size", ""),
            recipe: j.str_or("recipe", ""),
            batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
            seq_len: j.get("seq_len").and_then(|v| v.as_usize()).unwrap_or(0),
            n_scales: j.get("n_scales").and_then(|v| v.as_usize()).unwrap_or(0),
            n_layers: j.get("n_layers").and_then(|v| v.as_usize()).unwrap_or(0),
            sites_per_layer: sites,
            params,
            model,
            param_count: j.get("param_count").and_then(|v| v.as_usize()).unwrap_or(0),
            flops_per_step: j.get("flops_per_step").and_then(|v| v.as_f64()).unwrap_or(0.0),
            chunk: j.get("chunk").and_then(|v| v.as_usize()).unwrap_or(0),
            m_fmt: j.str_or("m_fmt", ""),
            v_fmt: j.str_or("v_fmt", ""),
            layer: j.get("layer").and_then(|v| v.as_usize()).unwrap_or(0),
            raw: j,
        })
    }

    /// Total parameter element count (from the specs, not the echo).
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Flat-space offset table in manifest (sorted-name) order.
    pub fn param_offsets(&self) -> Vec<(String, usize, usize)> {
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0;
        for p in &self.params {
            out.push((p.name.clone(), off, p.numel()));
            off += p.numel();
        }
        out
    }

    /// Global site index for (layer, site-name).
    pub fn site_index(&self, layer: usize, site: &str) -> Option<usize> {
        let local = self.sites_per_layer.iter().position(|s| s == site)?;
        Some(layer * self.sites_per_layer.len() + local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{"kind":"grad","size":"tiny","recipe":"fp8","batch":2,"seq_len":64,
                "n_scales":32,"n_layers":2,
                "sites_per_layer":["x_attn","wq","g_qkv"],
                "params":[{"name":"embed","shape":[256,64],"init_std":0.02},
                           {"name":"head","shape":[64,256],"init_std":0.02}],
                "model":{"vocab":256,"d_model":64,"n_layers":2,"n_heads":4,
                          "d_ff":172,"seq_len":64,"name":"tiny","rope_base":10000.0,
                          "norm_eps":1e-5},
                "param_count":100000,"flops_per_step":1.0e9}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_grad_manifest() {
        let m = Manifest::from_json("grad_tiny_fp8".into(), sample()).unwrap();
        assert_eq!(m.kind, "grad");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.total_params(), 256 * 64 + 64 * 256);
        assert_eq!(m.param_offsets()[1].1, 256 * 64);
        assert_eq!(m.site_index(1, "wq"), Some(4));
        assert_eq!(m.model.as_ref().unwrap().d_ff, 172);
    }

    #[test]
    fn missing_kind_fails() {
        let j = Json::parse(r#"{"batch":2}"#).unwrap();
        assert!(Manifest::from_json("x".into(), j).is_err());
    }
}
