//! Host-side tensors: flat f32/i32 buffers + shape, with conversions
//! to/from `xla::Literal`. Kept deliberately simple — the coordinator
//! moves data through PJRT as raw bytes, no ndarray dependency.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32s(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match self {
            HostTensor::I32 { data, .. } => data,
            _ => panic!("expected i32 tensor"),
        }
    }

    /// Build the XLA literal (copies; PJRT owns its buffer after
    /// transfer anyway).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                l.reshape(&dims)?
            }
            HostTensor::I32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                l.reshape(&dims)?
            }
        };
        Ok(lit)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.shape()?;
        match shape {
            xla::Shape::Array(a) => {
                let dims: Vec<usize> = a.dims().iter().map(|&d| d as usize).collect();
                match a.ty() {
                    xla::ElementType::F32 => {
                        Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
                    }
                    xla::ElementType::S32 => {
                        Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
                    }
                    ty => bail!("unsupported literal element type {ty:?}"),
                }
            }
            s => bail!("expected array literal, got {s:?}"),
        }
    }

    pub fn scalar_f32(&self) -> f32 {
        self.f32s()[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = HostTensor::from_f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        HostTensor::from_f32(&[2, 3], vec![0.0; 5]);
    }
}
