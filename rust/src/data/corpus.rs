//! Zipf-Markov synthetic corpus.
//!
//! Token frequencies follow a Zipf law (skew `s`), and each token's
//! successor distribution is a deterministic pseudo-random mixture:
//! given context hash c, the next token is drawn from the Zipf marginal
//! but re-ranked by a context-dependent permutation, giving the chain
//! real mutual information between context and next token (so a
//! transformer can reduce loss below the unigram entropy) without any
//! stored transition table (O(1) memory at any vocab).

use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Markov order (context length that determines the next-token law)
    pub order: usize,
    /// Zipf exponent (1.0–1.5 is natural-language-like)
    pub skew: f64,
    pub seed: u64,
}

#[derive(Clone)]
pub struct Corpus {
    cfg: CorpusConfig,
    /// cumulative Zipf distribution for inverse-CDF sampling
    cdf: Vec<f64>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        assert!(cfg.vocab >= 2);
        let mut weights: Vec<f64> =
            (1..=cfg.vocab).map(|r| 1.0 / (r as f64).powf(cfg.skew)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        Self { cfg, cdf: weights }
    }

    pub fn seed(&self) -> u64 {
        self.cfg.seed
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    /// Draw from the Zipf marginal via inverse CDF.
    fn zipf(&self, u: f64) -> usize {
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cfg.vocab - 1),
        }
    }

    /// Next token given the rolling context hash. Half the draws come
    /// straight from the global Zipf law (keeping the corpus marginal
    /// heavy-tailed, like natural text); the other half from a
    /// context-rotated Zipf law (giving P(next | context) real mutual
    /// information with the context, so a transformer can beat the
    /// unigram entropy).
    fn next_token(&self, rng: &mut Rng, ctx_hash: u64) -> usize {
        let rank = self.zipf(rng.uniform());
        if rng.uniform() < 0.5 {
            return rank;
        }
        let rot = (ctx_hash % self.cfg.vocab as u64) as usize;
        (rank + rot) % self.cfg.vocab
    }

    /// Append `len` tokens of a fresh document to `out`.
    pub fn fill_sequence(&self, rng: &mut Rng, len: usize, out: &mut Vec<i32>) {
        let mut ctx: Vec<usize> = Vec::with_capacity(self.cfg.order);
        for _ in 0..len {
            let h = self.ctx_hash(&ctx);
            let t = self.next_token(rng, h);
            out.push(t as i32);
            if self.cfg.order > 0 {
                if ctx.len() == self.cfg.order {
                    ctx.remove(0);
                }
                ctx.push(t);
            }
        }
    }

    fn ctx_hash(&self, ctx: &[usize]) -> u64 {
        let mut h: u64 = 0x9e37_79b9_7f4a_7c15 ^ self.cfg.seed;
        for &t in ctx {
            h ^= t as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
            h ^= h >> 29;
        }
        h
    }

    /// Unigram entropy of the Zipf marginal in nats — the loss floor a
    /// context-blind model can reach; the Markov structure puts the
    /// true conditional entropy below this.
    pub fn unigram_entropy(&self) -> f64 {
        let mut prev = 0.0;
        let mut h = 0.0;
        for &c in &self.cdf {
            let p = c - prev;
            prev = c;
            if p > 0.0 {
                h -= p * p.ln();
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig { vocab: 128, order: 2, skew: 1.2, seed: 3 })
    }

    #[test]
    fn zipf_marginal_is_skewed() {
        let c = corpus();
        let mut rng = Rng::new(1);
        let mut counts = vec![0u32; 128];
        let mut seq = Vec::new();
        c.fill_sequence(&mut rng, 50_000, &mut seq);
        for &t in &seq {
            counts[t as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let median = {
            let mut s = counts.clone();
            s.sort();
            s[64] as f64
        };
        assert!(max / median.max(1.0) > 5.0, "distribution should be heavy-tailed");
    }

    #[test]
    fn context_carries_information() {
        // successor distributions for two different contexts must differ
        let c = corpus();
        let h1 = c.ctx_hash(&[1, 2]);
        let h2 = c.ctx_hash(&[3, 4]);
        assert_ne!(h1 % 128, h2 % 128, "contexts should rotate differently (seed-dependent)");
    }

    #[test]
    fn entropy_positive_and_below_uniform() {
        let c = corpus();
        let h = c.unigram_entropy();
        assert!(h > 0.0 && h < (128f64).ln());
    }

    #[test]
    fn deterministic_given_rng() {
        let c = corpus();
        let mut a = Vec::new();
        let mut b = Vec::new();
        c.fill_sequence(&mut Rng::new(5), 64, &mut a);
        c.fill_sequence(&mut Rng::new(5), 64, &mut b);
        assert_eq!(a, b);
    }
}
