//! Synthetic data pipeline — the RedPajama stand-in (DESIGN.md
//! §Substitutions).
//!
//! The corpus is an order-k Markov chain over a Zipf-distributed token
//! alphabet: unbounded (every step sees fresh tokens — the paper's
//! N ≫ k under-parameterized regime, which Theorem 1 needs), learnable
//! (the chain's transition structure gives the model something real to
//! fit, so loss curves are informative), and deterministic (seeded;
//! worker shards use split PRNG streams so data-parallel runs are
//! reproducible at any worker count).

pub mod corpus;

pub use corpus::{Corpus, CorpusConfig};

use crate::util::prng::Rng;

/// Batch sampler: deterministic sharding of the token stream across
/// data-parallel workers.
pub struct Batcher {
    corpus: Corpus,
    batch: usize,
    seq_plus1: usize,
}

impl Batcher {
    pub fn new(corpus: Corpus, batch: usize, seq_len: usize) -> Self {
        Self { corpus, batch, seq_plus1: seq_len + 1 }
    }

    /// Batch for (step, worker, microbatch): i32 [batch, seq_len+1].
    /// Each row is an independent document stream.
    pub fn batch(&self, step: usize, worker: usize, micro: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.seq_plus1);
        for row in 0..self.batch {
            let stream = ((step as u64) << 24)
                ^ ((worker as u64) << 16)
                ^ ((micro as u64) << 8)
                ^ row as u64;
            let mut rng = Rng::new(self.corpus.seed()).split(stream);
            self.corpus.fill_sequence(&mut rng, self.seq_plus1, &mut out);
        }
        out
    }

    pub fn shape(&self) -> [usize; 2] {
        [self.batch, self.seq_plus1]
    }

    /// Held-out split: same generator family, disjoint stream ids.
    pub fn eval_batch(&self, index: usize) -> Vec<i32> {
        self.batch(0x00e1_0000 + index, 0xff, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher() -> Batcher {
        let c = Corpus::new(CorpusConfig { vocab: 512, order: 2, skew: 1.2, seed: 7 });
        Batcher::new(c, 4, 16)
    }

    #[test]
    fn deterministic_batches() {
        let b = batcher();
        assert_eq!(b.batch(3, 0, 0), b.batch(3, 0, 0));
        assert_ne!(b.batch(3, 0, 0), b.batch(4, 0, 0));
        assert_ne!(b.batch(3, 0, 0), b.batch(3, 1, 0));
    }

    #[test]
    fn tokens_in_range() {
        let b = batcher();
        for &t in &b.batch(0, 0, 0) {
            assert!((0..512).contains(&t));
        }
    }

    #[test]
    fn eval_disjoint_from_train() {
        let b = batcher();
        assert_ne!(b.eval_batch(0), b.batch(0, 0, 0));
    }
}
