//! Binary checkpoints with format-true storage.
//!
//! Layout (little-endian):
//! ```text
//! magic "FP8CKPT1" | meta_len u32 | meta JSON |
//!   per tensor: name_len u16 | name | dtype u8 | scale f32 | len u64 | payload
//! ```
//! dtype: 0 = f32, 1 = f16, 2 = bf16 (stored as u16), 3 = E4M3 u8,
//! 4 = E5M2 u8. FP8 payloads are **real bytes** — checkpoint sizes are
//! the Table 4 measurement, and the w1/w2 correlation analysis
//! (Figs. 2, 7) reads checkpoints through this module.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::fp8::{self, E4M3, E5M2};
use crate::util::json::Json;
use crate::util::{bf16_round, f16_bits_to_f32, f32_to_f16_bits};

const MAGIC: &[u8; 8] = b"FP8CKPT1";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    Bf16,
    E4M3,
    E5M2,
}

impl Dtype {
    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "f16" => Dtype::F16,
            "bf16" => Dtype::Bf16,
            "e4m3" => Dtype::E4M3,
            "e5m2" => Dtype::E5M2,
            _ => bail!("unknown checkpoint dtype '{s}'"),
        })
    }

    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F16 => 1,
            Dtype::Bf16 => 2,
            Dtype::E4M3 => 3,
            Dtype::E5M2 => 4,
        }
    }

    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::F16,
            2 => Dtype::Bf16,
            3 => Dtype::E4M3,
            4 => Dtype::E5M2,
            _ => bail!("bad dtype code {c}"),
        })
    }

    pub fn bytes_per_elem(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 | Dtype::Bf16 => 2,
            Dtype::E4M3 | Dtype::E5M2 => 1,
        }
    }
}

pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new(meta: &Json) -> Self {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        let meta_s = meta.to_string();
        buf.extend_from_slice(&(meta_s.len() as u32).to_le_bytes());
        buf.extend_from_slice(meta_s.as_bytes());
        Self { buf }
    }

    pub fn tensor(&mut self, name: &str, dtype: Dtype, data: &[f32]) -> &mut Self {
        self.buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
        self.buf.extend_from_slice(name.as_bytes());
        self.buf.push(dtype.code());
        let (scale, payload): (f32, Vec<u8>) = match dtype {
            Dtype::F32 => (1.0, data.iter().flat_map(|x| x.to_le_bytes()).collect()),
            Dtype::F16 => (
                1.0,
                data.iter().flat_map(|&x| f32_to_f16_bits(x).to_le_bytes()).collect(),
            ),
            Dtype::Bf16 => (
                1.0,
                data.iter()
                    .flat_map(|&x| ((bf16_round(x).to_bits() >> 16) as u16).to_le_bytes())
                    .collect(),
            ),
            // pack_scaled runs on the bulk table-driven codec (fp8::bulk)
            Dtype::E4M3 => {
                let (b, s) = fp8::pack_scaled(E4M3, data);
                (s, b)
            }
            Dtype::E5M2 => {
                let (b, s) = fp8::pack_scaled(E5M2, data);
                (s, b)
            }
        };
        self.buf.extend_from_slice(&scale.to_le_bytes());
        self.buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(&payload);
        self
    }

    pub fn finish<P: AsRef<Path>>(&self, path: P) -> Result<u64> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        f.write_all(&self.buf)?;
        Ok(self.buf.len() as u64)
    }

    pub fn size_bytes(&self) -> usize {
        self.buf.len()
    }
}

pub struct Checkpoint {
    pub meta: Json,
    pub tensors: BTreeMap<String, (Dtype, Vec<f32>)>,
    pub file_bytes: u64,
}

impl Checkpoint {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        let file_bytes = buf.len() as u64;
        if buf.len() < 12 || &buf[..8] != MAGIC {
            bail!("not an FP8CKPT1 file");
        }
        let meta_len = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        let mut i = 12 + meta_len;
        let meta = Json::parse(
            std::str::from_utf8(&buf[12..i]).context("meta utf8")?,
        )
        .map_err(|e| anyhow!("meta json: {e}"))?;

        let mut tensors = BTreeMap::new();
        while i < buf.len() {
            let name_len = u16::from_le_bytes(buf[i..i + 2].try_into().unwrap()) as usize;
            i += 2;
            let name = String::from_utf8(buf[i..i + name_len].to_vec())?;
            i += name_len;
            let dtype = Dtype::from_code(buf[i])?;
            i += 1;
            let scale = f32::from_le_bytes(buf[i..i + 4].try_into().unwrap());
            i += 4;
            let n = u64::from_le_bytes(buf[i..i + 8].try_into().unwrap()) as usize;
            i += 8;
            let nbytes = n * dtype.bytes_per_elem();
            if i + nbytes > buf.len() {
                bail!("truncated tensor '{name}'");
            }
            let payload = &buf[i..i + nbytes];
            i += nbytes;
            let data: Vec<f32> = match dtype {
                Dtype::F32 => payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
                Dtype::F16 => payload
                    .chunks_exact(2)
                    .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                    .collect(),
                Dtype::Bf16 => payload
                    .chunks_exact(2)
                    .map(|c| {
                        f32::from_bits((u16::from_le_bytes(c.try_into().unwrap()) as u32) << 16)
                    })
                    .collect(),
                Dtype::E4M3 | Dtype::E5M2 => {
                    // bulk LUT decode (parallel above the size
                    // threshold) — checkpoints are the largest fp8
                    // buffers in the system
                    let fmt = if dtype == Dtype::E4M3 { E4M3 } else { E5M2 };
                    let mut out = Vec::new();
                    fp8::bulk::unpack_scaled_into(fmt, payload, scale, &mut out);
                    out
                }
            };
            tensors.insert(name, (dtype, data));
        }
        Ok(Self { meta, tensors, file_bytes })
    }

    pub fn tensor(&self, name: &str) -> Result<&[f32]> {
        self.tensors
            .get(name)
            .map(|(_, d)| d.as_slice())
            .ok_or_else(|| anyhow!("checkpoint missing tensor '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    #[test]
    fn roundtrip_all_dtypes() {
        let dir = std::env::temp_dir().join("fp8_ckpt_test");
        let path = dir.join("t.ckpt");
        let data: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.037).collect();
        let meta = obj(vec![("step", Json::Num(7.0))]);
        let mut w = Writer::new(&meta);
        w.tensor("a_f32", Dtype::F32, &data)
            .tensor("b_f16", Dtype::F16, &data)
            .tensor("c_bf16", Dtype::Bf16, &data)
            .tensor("d_e4m3", Dtype::E4M3, &data)
            .tensor("e_e5m2", Dtype::E5M2, &data);
        w.finish(&path).unwrap();

        let c = Checkpoint::load(&path).unwrap();
        assert_eq!(c.meta.f64_of("step").unwrap(), 7.0);
        assert_eq!(c.tensor("a_f32").unwrap(), data.as_slice());
        for (name, tol) in [("b_f16", 1e-3), ("c_bf16", 1e-2), ("d_e4m3", 0.07), ("e_e5m2", 0.13)] {
            let got = c.tensor(name).unwrap();
            for (x, y) in data.iter().zip(got) {
                assert!((x - y).abs() <= x.abs() as f64 as f32 * tol as f32 + 1e-4,
                        "{name}: {x} vs {y}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fp8_payload_is_one_byte_per_elem() {
        let data = vec![0.5f32; 1000];
        let mut w = Writer::new(&obj(vec![]));
        let before = w.size_bytes();
        w.tensor("m", Dtype::E4M3, &data);
        let delta = w.size_bytes() - before;
        assert!(delta < 1000 + 64, "fp8 tensor must store ~1 byte/elem, got {delta}");
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("fp8_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"nope").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
