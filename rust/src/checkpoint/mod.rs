//! Binary checkpoints with format-true storage.
//!
//! Layout (little-endian):
//! ```text
//! magic "FP8CKPT1" | meta_len u32 | meta JSON |
//!   per tensor: name_len u16 | name | dtype u8 | scale f32 | len u64 | payload
//! | footer "FP8CRC32" + crc32 u32   (over every preceding byte)
//! ```
//! dtype: 0 = f32, 1 = f16, 2 = bf16 (stored as u16), 3 = E4M3 u8,
//! 4 = E5M2 u8, 5 = chunked exact-FP8 (see below). FP8 payloads are
//! **real bytes** — checkpoint sizes are the Table 4 measurement, and
//! the w1/w2 correlation analysis (Figs. 2, 7) reads checkpoints
//! through this module.
//!
//! ## Extended manifest: chunked exact-FP8 sections (dtype 5)
//!
//! Campaign snapshots need *bit-exact* restore, but the plain E4M3 /
//! E5M2 sections quantize through one global scale — lossy in general.
//! Dtype 5 stores a tensor chunk-by-chunk with a per-chunk pow2 scale
//! (mirroring how the chunked Adam artifact quantizes its moment
//! outputs), and **verifies each chunk at write time**: a chunk is
//! stored as FP8 bytes only if decode(encode(chunk)) reproduces every
//! f32 bit; otherwise that chunk falls back to raw f32. Roundtrip
//! bit-exactness is therefore guaranteed by construction, while
//! on-grid data (FP8 Adam moments) still stores at ~1 byte/element.
//!
//! Payload layout for dtype 5:
//! ```text
//! fmt u8 (3=E4M3 | 4=E5M2) | chunk u64 |
//!   per chunk: flag u8 (1=fp8, 0=f32) | scale f32 | bytes
//! ```
//! where `bytes` is `clen` u8 codes (flag 1) or `clen` f32 LE values
//! (flag 0), and `clen = min(chunk, remaining)`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::fp8::{self, E4M3, E5M2};
use crate::util::json::Json;
use crate::util::{bf16_round, f16_bits_to_f32, f32_to_f16_bits};

const MAGIC: &[u8; 8] = b"FP8CKPT1";
/// Integrity footer: `FP8CRC32` + CRC-32 (LE) over every preceding
/// byte. Written by [`Writer::finish`]; verified (when present) by
/// [`Checkpoint::load`], so silent payload corruption — a flipped bit
/// that still decodes to a plausible f32 — lands in the error path
/// the campaign corrupt-snapshot fallback handles, instead of
/// silently forking a "bit-exact" resume. Files without the footer
/// (pre-footer writers, hand-crafted tests) still load.
const CRC_MAGIC: &[u8; 8] = b"FP8CRC32";
const FOOTER_LEN: usize = 12;

/// Storage format of one checkpoint tensor section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// Raw f32 — lossless.
    F32,
    /// IEEE binary16 — the paper's FP16 master-weight storage.
    F16,
    /// bfloat16 (RNE truncation of f32).
    Bf16,
    /// One real E4M3 byte per element with a single global scale.
    E4M3,
    /// One real E5M2 byte per element with a single global scale.
    E5M2,
    /// Chunked exact-FP8 with per-chunk scales and verified f32
    /// fallback (campaign snapshots; see the module docs). Written via
    /// [`Writer::tensor_fp8_exact`], never via [`Writer::tensor`].
    Fp8Exact,
}

impl Dtype {
    /// Parse a config-file dtype name (`"f32" | "f16" | "bf16" |
    /// "e4m3" | "e5m2"`).
    ///
    /// # Examples
    ///
    /// ```
    /// use fp8_trainer::checkpoint::Dtype;
    /// assert_eq!(Dtype::from_name("bf16").unwrap(), Dtype::Bf16);
    /// assert!(Dtype::from_name("fp64").is_err());
    /// ```
    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "f16" => Dtype::F16,
            "bf16" => Dtype::Bf16,
            "e4m3" => Dtype::E4M3,
            "e5m2" => Dtype::E5M2,
            _ => bail!("unknown checkpoint dtype '{s}'"),
        })
    }

    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F16 => 1,
            Dtype::Bf16 => 2,
            Dtype::E4M3 => 3,
            Dtype::E5M2 => 4,
            Dtype::Fp8Exact => 5,
        }
    }

    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::F16,
            2 => Dtype::Bf16,
            3 => Dtype::E4M3,
            4 => Dtype::E5M2,
            5 => Dtype::Fp8Exact,
            _ => bail!("bad dtype code {c}"),
        })
    }

    /// Nominal payload bytes per element. Invariant: exact for every
    /// fixed-width dtype; for [`Dtype::Fp8Exact`] this is the 1
    /// byte/element *target* (per-chunk headers and any f32-fallback
    /// chunks add to the real on-disk size).
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 | Dtype::Bf16 => 2,
            Dtype::E4M3 | Dtype::E5M2 | Dtype::Fp8Exact => 1,
        }
    }
}

/// Streaming checkpoint builder: construct with the run metadata, add
/// tensors, then [`finish`](Writer::finish) to a file.
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Start a checkpoint with a JSON metadata header (step, recipe,
    /// … — whatever the caller wants to find again at load time).
    ///
    /// # Examples
    ///
    /// ```
    /// use fp8_trainer::checkpoint::{Dtype, Writer};
    /// use fp8_trainer::util::json::{obj, Json};
    /// let mut w = Writer::new(&obj(vec![("step", Json::Num(7.0))]));
    /// w.tensor("weights", Dtype::F32, &[1.0, 2.0]);
    /// assert!(w.size_bytes() > 0);
    /// ```
    pub fn new(meta: &Json) -> Self {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        let meta_s = meta.to_string();
        buf.extend_from_slice(&(meta_s.len() as u32).to_le_bytes());
        buf.extend_from_slice(meta_s.as_bytes());
        Self { buf }
    }

    /// Append one named tensor in the given fixed-width storage format.
    ///
    /// Invariants: `Dtype::F32` roundtrips bit-exactly; the reduced
    /// formats are lossy (f16/bf16 rounding; the E4M3/E5M2 sections
    /// quantize through one global pow2 scale chosen from the tensor
    /// amax). For guaranteed-exact FP8 storage use
    /// [`tensor_fp8_exact`](Writer::tensor_fp8_exact).
    ///
    /// # Panics
    ///
    /// Panics if called with [`Dtype::Fp8Exact`] — that layout carries
    /// per-chunk state that only `tensor_fp8_exact` can produce.
    pub fn tensor(&mut self, name: &str, dtype: Dtype, data: &[f32]) -> &mut Self {
        self.section_header(name, dtype);
        let (scale, payload): (f32, Vec<u8>) = match dtype {
            Dtype::F32 => (1.0, data.iter().flat_map(|x| x.to_le_bytes()).collect()),
            Dtype::F16 => (
                1.0,
                data.iter().flat_map(|&x| f32_to_f16_bits(x).to_le_bytes()).collect(),
            ),
            Dtype::Bf16 => (
                1.0,
                data.iter()
                    .flat_map(|&x| ((bf16_round(x).to_bits() >> 16) as u16).to_le_bytes())
                    .collect(),
            ),
            // pack_scaled runs on the bulk table-driven codec (fp8::bulk)
            Dtype::E4M3 => {
                let (b, s) = fp8::pack_scaled(E4M3, data);
                (s, b)
            }
            Dtype::E5M2 => {
                let (b, s) = fp8::pack_scaled(E5M2, data);
                (s, b)
            }
            Dtype::Fp8Exact => {
                panic!("use Writer::tensor_fp8_exact for chunked exact-FP8 sections")
            }
        };
        self.buf.extend_from_slice(&scale.to_le_bytes());
        self.buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(&payload);
        self
    }

    /// Append one named tensor as a chunked exact-FP8 section
    /// ([`Dtype::Fp8Exact`]).
    ///
    /// Each `chunk`-sized span gets its own pow2 JIT scale (the same
    /// `fp8::compute_scale` policy the chunked Adam artifact applies
    /// to its moment outputs) and is written as FP8 bytes **only if**
    /// the roundtrip reproduces every f32 bit of the span; otherwise
    /// the span is stored as raw f32. Loading therefore always
    /// reproduces `data` bit-for-bit, and data already on a per-chunk
    /// FP8 grid (Adam moments under the fp8 recipes) stores at
    /// ~1 byte/element.
    ///
    /// Use the Adam artifact's chunk size for moment tensors so the
    /// storage chunks line up with the grids the kernel produced.
    ///
    /// # Panics
    ///
    /// Panics if `chunk == 0`.
    pub fn tensor_fp8_exact(
        &mut self,
        name: &str,
        fmt: fp8::Fp8Format,
        data: &[f32],
        chunk: usize,
    ) -> &mut Self {
        assert!(chunk > 0, "fp8-exact chunk size must be >= 1");
        self.section_header(name, Dtype::Fp8Exact);
        self.buf.extend_from_slice(&1.0f32.to_le_bytes()); // frame scale: unused
        self.buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
        self.buf.push(if fmt == E4M3 { 3 } else { 4 });
        self.buf.extend_from_slice(&(chunk as u64).to_le_bytes());
        let mut bytes: Vec<u8> = Vec::new();
        for span in data.chunks(chunk) {
            // shared write-time verification with the optimizer's
            // resident moment shards: FP8 only when bit-exact
            match fp8::bulk::pack_scaled_exact_into(fmt, span, &mut bytes) {
                Some(scale) => {
                    self.buf.push(1);
                    self.buf.extend_from_slice(&scale.to_le_bytes());
                    self.buf.extend_from_slice(&bytes);
                }
                None => {
                    self.buf.push(0);
                    self.buf.extend_from_slice(&1.0f32.to_le_bytes());
                    for x in span {
                        self.buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        self
    }

    /// Name + dtype only — the scale and element count follow, written
    /// by each section kind itself.
    fn section_header(&mut self, name: &str, dtype: Dtype) {
        self.buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
        self.buf.extend_from_slice(name.as_bytes());
        self.buf.push(dtype.code());
    }

    /// Write the assembled checkpoint to `path` (creating parent
    /// directories) and return the file size in bytes.
    ///
    /// The write is atomic: bytes go to a `.tmp` sibling first and are
    /// renamed into place, so a crash mid-write can never leave a
    /// truncated checkpoint at `path` — it either has the old
    /// contents or the new ones. Campaign rollback/resume targets
    /// depend on this.
    pub fn finish<P: AsRef<Path>>(&self, path: P) -> Result<u64> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&self.buf)?;
            f.write_all(CRC_MAGIC)?;
            f.write_all(&crate::util::crc32(&self.buf).to_le_bytes())?;
            f.sync_all().ok(); // best-effort durability before the rename
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("moving {} into place", tmp.display()))?;
        Ok(self.size_bytes() as u64)
    }

    /// Current in-memory size plus the integrity footer — equals the
    /// eventual file size, so the Table 4 measurement can be taken
    /// without touching disk.
    pub fn size_bytes(&self) -> usize {
        self.buf.len() + FOOTER_LEN
    }
}

/// A loaded checkpoint: metadata plus every tensor decoded back to
/// f32 (tagged with the dtype it was stored as).
pub struct Checkpoint {
    /// the JSON metadata header the writer was constructed with
    pub meta: Json,
    /// name → (storage dtype, decoded f32 data)
    pub tensors: BTreeMap<String, (Dtype, Vec<f32>)>,
    /// For fixed-width FP8 sections ([`Dtype::E4M3`] / [`Dtype::E5M2`])
    /// only: name → (format, global scale, raw payload bytes). Lets
    /// FP8-resident consumers (the serving engine) adopt the stored
    /// bytes verbatim instead of round-tripping through the decoded
    /// f32 copy in [`Checkpoint::tensors`]. Decoding the payload with
    /// [`crate::fp8::bulk::unpack_scaled_buf`] reproduces the
    /// `tensors` entry bit-for-bit.
    pub raw_fp8: BTreeMap<String, (fp8::Fp8Format, f32, Vec<u8>)>,
    /// on-disk size (the Table 4 measurement)
    pub file_bytes: u64,
}

impl Checkpoint {
    /// Load and decode a checkpoint file.
    ///
    /// Invariant: for sections written as `Dtype::F32` or
    /// `Dtype::Fp8Exact`, the decoded data is bit-identical to what
    /// the writer was given; the other dtypes decode to their rounded
    /// grids. Truncated or malformed files return an error, never a
    /// partial checkpoint.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        let file_bytes = buf.len() as u64;
        if buf.len() < 12 || &buf[..8] != MAGIC {
            bail!("not an FP8CKPT1 file");
        }
        // verify + strip the integrity footer when present (absent on
        // pre-footer files, which still load on structure alone)
        let mut end = buf.len();
        if end >= 12 + FOOTER_LEN && &buf[end - FOOTER_LEN..end - 4] == CRC_MAGIC {
            let stored = u32::from_le_bytes(buf[end - 4..end].try_into().unwrap());
            let actual = crate::util::crc32(&buf[..end - FOOTER_LEN]);
            if stored != actual {
                bail!(
                    "checkpoint checksum mismatch (stored {stored:08x}, computed \
                     {actual:08x}) — the file is corrupt"
                );
            }
            end -= FOOTER_LEN;
        }
        let buf = &buf[..end];
        let meta_len = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        if 12 + meta_len > buf.len() {
            bail!("truncated metadata header");
        }
        let mut i = 12 + meta_len;
        let meta = Json::parse(
            std::str::from_utf8(&buf[12..i]).context("meta utf8")?,
        )
        .map_err(|e| anyhow!("meta json: {e}"))?;

        let mut tensors = BTreeMap::new();
        let mut raw_fp8 = BTreeMap::new();
        while i < buf.len() {
            let name_len = read_u16(&buf, &mut i)? as usize;
            if i + name_len > buf.len() {
                bail!("truncated tensor name");
            }
            let name = String::from_utf8(buf[i..i + name_len].to_vec())?;
            i += name_len;
            if i >= buf.len() {
                bail!("truncated tensor '{name}'");
            }
            let dtype = Dtype::from_code(buf[i])?;
            i += 1;
            let scale = read_f32(&buf, &mut i)?;
            let n = read_u64(&buf, &mut i)? as usize;
            let data: Vec<f32> = if dtype == Dtype::Fp8Exact {
                read_fp8_exact(&buf, &mut i, n)
                    .with_context(|| format!("fp8-exact tensor '{name}'"))?
            } else {
                // the length field is untrusted on-disk data: checked
                // mul (no wrap-around to a short read) and a bounds
                // check BEFORE any allocation sized from it
                let nbytes = n
                    .checked_mul(dtype.bytes_per_elem())
                    .filter(|&nb| nb <= buf.len() - i)
                    .ok_or_else(|| anyhow!("truncated tensor '{name}'"))?;
                let payload = &buf[i..i + nbytes];
                i += nbytes;
                match dtype {
                    Dtype::E4M3 => {
                        raw_fp8.insert(name.clone(), (E4M3, scale, payload.to_vec()));
                    }
                    Dtype::E5M2 => {
                        raw_fp8.insert(name.clone(), (E5M2, scale, payload.to_vec()));
                    }
                    _ => {}
                }
                decode_fixed_width(dtype, payload, scale)
            };
            tensors.insert(name, (dtype, data));
        }
        Ok(Self { meta, tensors, raw_fp8, file_bytes })
    }

    /// Borrow a tensor's decoded f32 data by name (error if absent).
    pub fn tensor(&self, name: &str) -> Result<&[f32]> {
        self.tensors
            .get(name)
            .map(|(_, d)| d.as_slice())
            .ok_or_else(|| anyhow!("checkpoint missing tensor '{name}'"))
    }
}

fn read_u16(buf: &[u8], i: &mut usize) -> Result<u16> {
    if *i + 2 > buf.len() {
        bail!("truncated field");
    }
    let v = u16::from_le_bytes(buf[*i..*i + 2].try_into().unwrap());
    *i += 2;
    Ok(v)
}

fn read_f32(buf: &[u8], i: &mut usize) -> Result<f32> {
    if *i + 4 > buf.len() {
        bail!("truncated field");
    }
    let v = f32::from_le_bytes(buf[*i..*i + 4].try_into().unwrap());
    *i += 4;
    Ok(v)
}

fn read_u64(buf: &[u8], i: &mut usize) -> Result<u64> {
    if *i + 8 > buf.len() {
        bail!("truncated field");
    }
    let v = u64::from_le_bytes(buf[*i..*i + 8].try_into().unwrap());
    *i += 8;
    Ok(v)
}

fn decode_fixed_width(dtype: Dtype, payload: &[u8], scale: f32) -> Vec<f32> {
    match dtype {
        Dtype::F32 => payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        Dtype::F16 => payload
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
            .collect(),
        Dtype::Bf16 => payload
            .chunks_exact(2)
            .map(|c| {
                f32::from_bits((u16::from_le_bytes(c.try_into().unwrap()) as u32) << 16)
            })
            .collect(),
        Dtype::E4M3 | Dtype::E5M2 => {
            // bulk LUT decode (parallel above the size threshold) —
            // checkpoints are the largest fp8 buffers in the system
            let fmt = if dtype == Dtype::E4M3 { E4M3 } else { E5M2 };
            let mut out = Vec::new();
            fp8::bulk::unpack_scaled_into(fmt, payload, scale, &mut out);
            out
        }
        Dtype::Fp8Exact => unreachable!("handled by read_fp8_exact"),
    }
}

fn read_fp8_exact(buf: &[u8], i: &mut usize, n: usize) -> Result<Vec<f32>> {
    // untrusted length: every element occupies at least one payload
    // byte, so bound n against the remaining bytes before allocating
    // (a garbage length must be an error, not an OOM abort)
    if n > buf.len().saturating_sub(*i) {
        bail!("element count {n} exceeds remaining file bytes");
    }
    if *i >= buf.len() {
        bail!("truncated header");
    }
    let fmt = match buf[*i] {
        3 => E4M3,
        4 => E5M2,
        c => bail!("bad fp8-exact format code {c}"),
    };
    *i += 1;
    let chunk = read_u64(buf, i)? as usize;
    if chunk == 0 && n > 0 {
        bail!("zero chunk size");
    }
    let mut data = vec![0.0f32; n];
    let mut off = 0;
    while off < n {
        let clen = chunk.min(n - off);
        if *i >= buf.len() {
            bail!("truncated chunk header");
        }
        let flag = buf[*i];
        *i += 1;
        let scale = read_f32(buf, i)?;
        match flag {
            1 => {
                if *i + clen > buf.len() {
                    bail!("truncated fp8 chunk");
                }
                fp8::bulk::unpack_scaled_buf(
                    fmt,
                    &buf[*i..*i + clen],
                    scale,
                    &mut data[off..off + clen],
                );
                *i += clen;
            }
            0 => {
                if *i + clen * 4 > buf.len() {
                    bail!("truncated f32 chunk");
                }
                for (k, d) in data[off..off + clen].iter_mut().enumerate() {
                    let at = *i + k * 4;
                    *d = f32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
                }
                *i += clen * 4;
            }
            c => bail!("bad chunk flag {c}"),
        }
        off += clen;
    }
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    #[test]
    fn roundtrip_all_dtypes() {
        let dir = std::env::temp_dir().join("fp8_ckpt_test");
        let path = dir.join("t.ckpt");
        let data: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.037).collect();
        let meta = obj(vec![("step", Json::Num(7.0))]);
        let mut w = Writer::new(&meta);
        w.tensor("a_f32", Dtype::F32, &data)
            .tensor("b_f16", Dtype::F16, &data)
            .tensor("c_bf16", Dtype::Bf16, &data)
            .tensor("d_e4m3", Dtype::E4M3, &data)
            .tensor("e_e5m2", Dtype::E5M2, &data);
        w.finish(&path).unwrap();

        let c = Checkpoint::load(&path).unwrap();
        assert_eq!(c.meta.f64_of("step").unwrap(), 7.0);
        assert_eq!(c.tensor("a_f32").unwrap(), data.as_slice());
        for (name, tol) in [("b_f16", 1e-3), ("c_bf16", 1e-2), ("d_e4m3", 0.07), ("e_e5m2", 0.13)] {
            let got = c.tensor(name).unwrap();
            for (x, y) in data.iter().zip(got) {
                assert!((x - y).abs() <= x.abs() as f64 as f32 * tol as f32 + 1e-4,
                        "{name}: {x} vs {y}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn raw_fp8_bytes_decode_to_the_tensors_entry_bitwise() {
        let dir = std::env::temp_dir().join("fp8_ckpt_raw");
        let path = dir.join("t.ckpt");
        let data: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.91).sin() * 2.3).collect();
        let mut w = Writer::new(&obj(vec![]));
        w.tensor("q", Dtype::E4M3, &data).tensor("r", Dtype::E5M2, &data).tensor(
            "s",
            Dtype::F32,
            &data,
        );
        w.finish(&path).unwrap();
        let c = Checkpoint::load(&path).unwrap();
        // f32 sections have no raw entry; FP8 sections carry exactly
        // the stored payload, whose decode matches the decoded tensor
        assert!(!c.raw_fp8.contains_key("s"));
        for name in ["q", "r"] {
            let (fmt, scale, bytes) = c.raw_fp8.get(name).unwrap();
            assert_eq!(bytes.len(), data.len());
            let mut dec = vec![0.0f32; bytes.len()];
            fp8::bulk::unpack_scaled_buf(*fmt, bytes, *scale, &mut dec);
            let stored = c.tensor(name).unwrap();
            for (a, b) in dec.iter().zip(stored) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}: {a} vs {b}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fp8_payload_is_one_byte_per_elem() {
        let data = vec![0.5f32; 1000];
        let mut w = Writer::new(&obj(vec![]));
        let before = w.size_bytes();
        w.tensor("m", Dtype::E4M3, &data);
        let delta = w.size_bytes() - before;
        assert!(delta < 1000 + 64, "fp8 tensor must store ~1 byte/elem, got {delta}");
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("fp8_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"nope").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fp8_exact_roundtrips_on_grid_data_compactly() {
        // data that lies exactly on a per-chunk E4M3 grid (the Adam
        // moment case): one byte per element, bit-exact restore. Each
        // chunk uses its own pow2 scale s and contains the value
        // 448/s, so the writer's JIT scale lands back on exactly s.
        let chunk = 64usize;
        let mut data = Vec::new();
        for c in 0..4i32 {
            let s = 2f32.powi(c); // per-chunk scale
            for k in 0..chunk {
                let code = (k * 2) as u8; // finite positive codes, incl. 0x7e = 448
                data.push(E4M3.decode(code) / s);
            }
        }
        let dir = std::env::temp_dir().join("fp8_ckpt_exact_grid");
        let path = dir.join("t.ckpt");
        let mut w = Writer::new(&obj(vec![]));
        let before = w.size_bytes();
        w.tensor_fp8_exact("m", E4M3, &data, chunk);
        let delta = w.size_bytes() - before;
        w.finish(&path).unwrap();
        let c = Checkpoint::load(&path).unwrap();
        let got = c.tensor("m").unwrap();
        assert_eq!(got.len(), data.len());
        for (a, b) in data.iter().zip(got) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(c.tensors.get("m").unwrap().0, Dtype::Fp8Exact);
        // ~1 byte/elem + per-chunk headers + section header
        assert!(delta < data.len() + 5 * 5 + 64, "on-grid data must pack, got {delta}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fp8_exact_falls_back_to_f32_off_grid() {
        // arbitrary f32s (not on any fp8 grid): every chunk must fall
        // back, and the roundtrip must still be bit-exact — including
        // NaN payload bits and signed zero
        let mut data: Vec<f32> = (0..150).map(|i| ((i as f32) * 0.7311).sin() * 3.7).collect();
        data[3] = f32::from_bits(0x7fc0_1234); // NaN with payload
        data[77] = -0.0;
        data[78] = f32::INFINITY;
        let dir = std::env::temp_dir().join("fp8_ckpt_exact_fallback");
        let path = dir.join("t.ckpt");
        let mut w = Writer::new(&obj(vec![]));
        w.tensor_fp8_exact("x", E5M2, &data, 64);
        w.finish(&path).unwrap();
        let c = Checkpoint::load(&path).unwrap();
        let got = c.tensor("x").unwrap();
        for (i, (a, b)) in data.iter().zip(got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "i={i}: {a} vs {b}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checksum_catches_silent_payload_corruption() {
        // a flipped payload bit decodes to a perfectly plausible f32 —
        // only the CRC footer can catch it
        let dir = std::env::temp_dir().join("fp8_ckpt_crc");
        let path = dir.join("t.ckpt");
        let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.25).collect();
        let mut w = Writer::new(&obj(vec![]));
        w.tensor("x", Dtype::F32, &data);
        let reported = w.finish(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len() as u64, reported, "size_bytes must match the file");
        assert!(Checkpoint::load(&path).is_ok(), "pristine file must verify");
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10; // silent corruption inside a payload
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("checksum"), "must fail the CRC, got: {err}");
        // footer-less files (pre-footer writers) still load on structure
        bytes[mid] ^= 0x10; // restore the original payload
        let body_len = bytes.len() - 12;
        std::fs::write(&path, &bytes[..body_len]).unwrap();
        let c = Checkpoint::load(&path).unwrap();
        assert_eq!(c.tensor("x").unwrap(), data.as_slice());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fp8_exact_empty_and_ragged_tail() {
        let dir = std::env::temp_dir().join("fp8_ckpt_exact_edge");
        let path = dir.join("t.ckpt");
        let ragged: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let mut w = Writer::new(&obj(vec![]));
        w.tensor_fp8_exact("empty", E4M3, &[], 8)
            .tensor_fp8_exact("ragged", E4M3, &ragged, 8);
        w.finish(&path).unwrap();
        let c = Checkpoint::load(&path).unwrap();
        assert!(c.tensor("empty").unwrap().is_empty());
        let got = c.tensor("ragged").unwrap();
        for (a, b) in ragged.iter().zip(got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
