//! TOML-subset parser (offline build: no toml crate).
//!
//! Supports what launcher configs need: `[section]` headers (flattened
//! to `section.key`), `key = value` with string / integer / float /
//! bool values, comments, and blank lines. No arrays-of-tables, dates,
//! or multi-line strings — config files in `configs/` stay inside this
//! subset by construction.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<String, String> {
        match self {
            TomlValue::Str(s) => Ok(s.clone()),
            v => Err(format!("expected string, got {v:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            v => Err(format!("expected non-negative integer, got {v:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            v => Err(format!("expected number, got {v:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            v => Err(format!("expected bool, got {v:?}")),
        }
    }
}

/// Parse a scalar the way a TOML value position would (used for CLI
/// `key=value` overrides).
pub fn parse_scalar(s: &str) -> TomlValue {
    let t = s.trim();
    if t == "true" {
        return TomlValue::Bool(true);
    }
    if t == "false" {
        return TomlValue::Bool(false);
    }
    if let Ok(i) = t.parse::<i64>() {
        return TomlValue::Int(i);
    }
    if let Ok(f) = t.parse::<f64>() {
        return TomlValue::Float(f);
    }
    let t = t.trim_matches('"').trim_matches('\'');
    TomlValue::Str(t.to_string())
}

pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {}: malformed section header", lineno + 1));
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, parse_scalar(v));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let src = r#"
# run config
[train]
size = "s1m"     # model preset
steps = 2000
lr = 2.5e-4
seed_outlier_channel = true

[scaling]
margin = 1.0
"#;
        let kv = parse(src).unwrap();
        assert_eq!(kv["train.size"], TomlValue::Str("s1m".into()));
        assert_eq!(kv["train.steps"], TomlValue::Int(2000));
        assert_eq!(kv["train.lr"], TomlValue::Float(2.5e-4));
        assert_eq!(kv["train.seed_outlier_channel"], TomlValue::Bool(true));
        assert_eq!(kv["scaling.margin"], TomlValue::Float(1.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let kv = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(kv["name"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn malformed_rejected() {
        assert!(parse("[oops").is_err());
        assert!(parse("keyonly").is_err());
    }
}
