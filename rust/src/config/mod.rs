//! Configuration system: a TOML-subset parser plus typed configs for
//! model size, precision recipe, and the training run.
//!
//! Configs compose like the launcher configs of Megatron/MaxText-style
//! frameworks: a `[model]`/`[train]`/`[precision]` file (see
//! `configs/*.toml`) plus CLI `key=value` overrides.

pub mod toml;

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;
use toml::TomlValue;

/// The paper's precision configurations (mirrors `python/compile/model.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct RecipeConfig {
    /// recipe name as exported (selects the grad/eval artifact)
    pub name: String,
    /// Adam moment formats: "fp32" | "e4m3" | "e5m2" (selects adam artifact)
    pub m_fmt: String,
    pub v_fmt: String,
    /// master-weight storage in checkpoints: "f32" | "f16" | "bf16"
    pub master_dtype: String,
}

impl RecipeConfig {
    pub fn by_name(name: &str) -> Self {
        let (m, v, master) = match name {
            // FP8(2): Smooth-SwiGLU + both Adam moments FP8 + f16 master
            "fp8_full" => ("e4m3", "e5m2", "f16"),
            "fp8_full_nosat" => {
                return Self {
                    name: "fp8_smooth_nosat".into(),
                    m_fmt: "e4m3".into(),
                    v_fmt: "e5m2".into(),
                    master_dtype: "f16".into(),
                }
            }
            n if n.starts_with("fp8_adam_") => {
                // fp8_adam_<mfmt>_<vfmt>
                let rest = &n["fp8_adam_".len()..];
                let (m, v) = rest.split_once('_').unwrap_or(("e4m3", "e5m2"));
                return Self {
                    name: "fp8_smooth".into(), // shares the grad artifact
                    m_fmt: m.into(),
                    v_fmt: v.into(),
                    master_dtype: "f32".into(),
                };
            }
            _ => ("fp32", "fp32", "f32"),
        };
        Self {
            name: grad_recipe_of(name).into(),
            m_fmt: m.into(),
            v_fmt: v.into(),
            master_dtype: master.into(),
        }
    }
}

/// The grad artifact a logical recipe runs on (fp8_full trains on the
/// fp8_smooth graph — moment formats only affect the optimizer artifact).
///
/// The `fp8_gemm*` pair routes host-side compute through the tile-wise
/// FP8 GEMM path (`gemm::GemmEngine`) on top of the matching FP8
/// graphs: `fp8_gemm` runs the plain-SwiGLU `fp8` graph (the
/// configuration Fig. 2 shows destabilizing) and `fp8_gemm_smooth`
/// the Smooth-SwiGLU `fp8_smooth` graph. Moments stay f32 so the two
/// differ *only* in the compute recipe.
pub fn grad_recipe_of(name: &str) -> &str {
    match name {
        "fp8_full" => "fp8_smooth",
        n if n.starts_with("fp8_adam_") => "fp8_smooth",
        "fp8_gemm" => "fp8",
        "fp8_gemm_smooth" => "fp8_smooth",
        n => n,
    }
}

/// Whether a logical recipe routes the step through the tile-wise FP8
/// GEMM path (per-tile weight/grad quantization + amax feedback; see
/// `gemm::GemmEngine`). These recipes carry the `gemm_*` keys into the
/// snapshot numerics fingerprint.
pub fn is_gemm_recipe(name: &str) -> bool {
    matches!(name, "fp8_gemm" | "fp8_gemm_smooth")
}

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub size: String,
    pub recipe: String,
    pub steps: usize,
    pub warmup_steps: usize,
    pub lr: f32,
    pub min_lr_frac: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
    pub seed: u64,
    /// data-parallel worker count (simulated Gaudi2 pool). Since the
    /// logical/physical split this is **physical** topology: how many
    /// thread lanes run the gradient streams and how many ZeRO-1
    /// shards the moments are partitioned into. The loss curve is a
    /// function of [`TrainConfig::streams`], not of this knob, so a
    /// campaign can be resharded onto a different `dp_workers`
    /// bit-exactly (`campaign resume --reshard`).
    pub dp_workers: usize,
    /// gradient-accumulation microbatches per step
    pub grad_accum: usize,
    /// **logical** gradient-stream count — the data-parallel width the
    /// numerics are defined over: batch identity (`(step, stream,
    /// micro)`), the merge denominator, and the replica count of the
    /// gradient collective. `0` (default) follows `dp_workers`, which
    /// reproduces the historical behaviour where logical and physical
    /// width coincide. Pinned in the snapshot numerics fingerprint for
    /// the life of a campaign; `campaign resume --reshard` carries it
    /// across a `dp_workers` change automatically.
    pub grad_streams: usize,
    /// **logical** pod count of the collective reduction plan: with
    /// [`TrainConfig::streams`] it fixes the two-level summation tree
    /// and which legs get FP8 wire compression — i.e. the gradient
    /// *bits*. `0` (default) follows `pods`. Must divide the effective
    /// stream count. Pinned in the numerics fingerprint; `--reshard`
    /// carries it across a `pods` change.
    pub stream_pods: usize,
    /// delayed-scaling amax history length
    pub amax_history: usize,
    /// scale margin: 2^margin headroom below the format max (TE-style)
    pub margin_pow2: i32,
    /// synthetic-corpus knobs (see data::corpus)
    pub corpus_order: usize,
    pub corpus_skew: f64,
    /// plant a partially-aligned SwiGLU channel at init (mechanism
    /// reproduction mode; see DESIGN.md §Substitutions)
    pub seed_outlier_channel: bool,
    pub seed_outlier_gain: f32,
    /// skip optimizer updates whose global grad-norm is non-finite
    /// (production protection). Disable to expose the paper's hard
    /// divergence: one poisoned update permanently corrupts training.
    pub skip_nonfinite_updates: bool,
    /// number of pods the `dp_workers` pool is arranged in (must
    /// divide `dp_workers` evenly). `1` = flat topology, the pinned
    /// baseline; `> 1` enables the two-level collective — intra-pod
    /// reduce-scatter → inter-pod exchange over pod leaders →
    /// intra-pod all-gather (`coordinator::topology`).
    pub pods: usize,
    /// compress the **intra-pod** wire legs of the gradient collective
    /// to FP8 with per-chunk pow2 auto-scales (FP8-LM-style). `false`
    /// keeps the bit-exact f32 schedule on the fat local links — the
    /// pinned baseline. (`collective_fp8` is accepted as a legacy
    /// alias: with `pods = 1` the intra level *is* the whole
    /// collective.)
    pub collective_fp8_intra: bool,
    /// compress the **inter-pod** (pod-leader) wire legs to FP8.
    /// Defaults to `true` — the inter-pod pipe is the thin one, where
    /// one byte per element pays for itself (see
    /// `perfmodel::interconnect` for the crossover rule). Irrelevant
    /// at `pods = 1`, where no inter level exists.
    pub collective_fp8_inter: bool,
    /// FP8 wire format for whichever collective levels are compressed
    /// ("e4m3" | "e5m2")
    pub collective_fmt: String,
    /// keep the ZeRO-1 Adam moment shards FP8-packed between steps.
    /// Packing is exact-verified per chunk (raw-f32 fallback), so this
    /// never changes the numbers — only per-worker resident bytes.
    pub pack_moments: bool,
    /// bucket size (in f32 bytes) of the overlapped gradient pipeline:
    /// the flat gradient is partitioned into buckets of
    /// `ceil(bucket_bytes/4)` elements rounded up to whole Adam
    /// chunks, and each bucket's collective overlaps the remaining
    /// compute. The partition changes per-bucket wire framing (and is
    /// recorded in the snapshot fingerprint), never the step's bits.
    pub bucket_bytes: usize,
    /// run the bucketed overlapped step pipeline (default). `false`
    /// forces the phased schedule — bit-identical, just slower; the
    /// snapshot fingerprint ignores this knob.
    pub overlap_comm: bool,
    /// log / checkpoint cadence
    pub log_every: usize,
    pub ckpt_every: usize,
    pub out_dir: String,
    /// campaign: periodic full-state snapshot cadence in steps
    /// (0 = only the mandatory step-0 and final snapshots)
    pub snapshot_every: usize,
    /// campaign: snapshot retention — keep the newest K snapshots
    /// (the rollback target is always among them; min 1)
    pub snapshot_keep: usize,
    /// campaign: give up after this many divergence recoveries
    pub max_recoveries: usize,
    /// campaign: extra pow2 scale margin added per recovery attempt
    /// (scale backoff — each rollback re-enters with more headroom)
    pub recovery_margin_backoff: i32,
    /// campaign: multiplicative amax-history shrink per recovery
    /// attempt (shorter window forgets the pre-spike amaxes faster);
    /// effective history never drops below 2
    pub recovery_history_shrink: f64,
    /// tile edge of the tile-wise FP8 GEMM path (`gemm::TileQuant`):
    /// operands are quantized in `gemm_tile × gemm_tile` blocks, each
    /// with its own pow2 amax scale. Only consumed by the `fp8_gemm*`
    /// recipes, where it enters the numerics fingerprint — changing it
    /// mid-campaign refuses to resume.
    pub gemm_tile: usize,
    /// FP8 format of the GEMM weight operand ("e4m3" | "e5m2")
    pub gemm_w_fmt: String,
    /// FP8 format of the GEMM activation operand ("e4m3" | "e5m2") —
    /// consumed by the host-side GEMM API and benches; in-graph
    /// activations keep their per-site delayed scales
    pub gemm_x_fmt: String,
    /// FP8 format of the GEMM gradient operand ("e4m3" | "e5m2";
    /// default e5m2 — gradients need the range, PAPER.md §3)
    pub gemm_g_fmt: String,
    /// serving: bind address of the `serve run` HTTP layer
    pub serve_addr: String,
    /// serving: bind port (0 = OS-assigned ephemeral port)
    pub serve_port: usize,
    /// serving: max requests coalesced into one batched forward
    pub serve_batch: usize,
    /// serving: max milliseconds to wait for the batch to fill after
    /// the first request arrives
    pub serve_batch_wait_ms: usize,
    /// serving: request-body byte cap — larger bodies get a typed 413
    /// refusal (`serving::OversizedBody`)
    pub serve_max_body_bytes: usize,
    /// serving: server-side cap on tokens generated per request
    pub serve_max_new_tokens: usize,
    /// serving: export quantization format ("e4m3" | "e5m2")
    pub serve_fmt: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            size: "s1m".into(),
            recipe: "bf16".into(),
            steps: 500,
            warmup_steps: 50,
            lr: 2.5e-4,
            min_lr_frac: 0.1,
            weight_decay: 0.1,
            grad_clip: 1.0,
            seed: 20260711,
            dp_workers: 1,
            grad_accum: 1,
            grad_streams: 0,
            stream_pods: 0,
            amax_history: 16,
            margin_pow2: 1,
            corpus_order: 2,
            corpus_skew: 1.2,
            seed_outlier_channel: false,
            seed_outlier_gain: 3.0,
            skip_nonfinite_updates: true,
            pods: 1,
            collective_fp8_intra: false,
            collective_fp8_inter: true,
            collective_fmt: "e5m2".into(),
            pack_moments: true,
            bucket_bytes: 4_194_304,
            overlap_comm: true,
            log_every: 10,
            ckpt_every: 0,
            out_dir: "runs/default".into(),
            snapshot_every: 50,
            snapshot_keep: 3,
            max_recoveries: 4,
            recovery_margin_backoff: 1,
            recovery_history_shrink: 0.5,
            gemm_tile: 128,
            gemm_w_fmt: "e4m3".into(),
            gemm_x_fmt: "e4m3".into(),
            gemm_g_fmt: "e5m2".into(),
            serve_addr: "127.0.0.1".into(),
            serve_port: 0,
            serve_batch: 8,
            serve_batch_wait_ms: 5,
            serve_max_body_bytes: 1_048_576,
            serve_max_new_tokens: 64,
            serve_fmt: "e4m3".into(),
        }
    }
}

impl TrainConfig {
    /// Load from a TOML file then apply `key=value` overrides.
    pub fn load(path: Option<&Path>, overrides: &[(String, String)]) -> Result<Self, String> {
        let mut kv: BTreeMap<String, TomlValue> = BTreeMap::new();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| format!("config {}: {e}", p.display()))?;
            kv = toml::parse(&text)?;
        }
        for (k, v) in overrides {
            kv.insert(k.clone(), toml::parse_scalar(v));
        }
        Self::from_kv(&kv)
    }

    pub fn from_kv(kv: &BTreeMap<String, TomlValue>) -> Result<Self, String> {
        let mut c = Self::default();
        for (k, v) in kv {
            match k.as_str() {
                "train.size" | "size" => c.size = v.as_str()?,
                "train.recipe" | "recipe" => c.recipe = v.as_str()?,
                "train.steps" | "steps" => c.steps = v.as_usize()?,
                "train.warmup_steps" | "warmup_steps" => c.warmup_steps = v.as_usize()?,
                "train.lr" | "lr" => c.lr = v.as_f64()? as f32,
                "train.min_lr_frac" | "min_lr_frac" => c.min_lr_frac = v.as_f64()? as f32,
                "train.weight_decay" | "weight_decay" => c.weight_decay = v.as_f64()? as f32,
                "train.grad_clip" | "grad_clip" => c.grad_clip = v.as_f64()? as f32,
                "train.seed" | "seed" => c.seed = v.as_usize()? as u64,
                "train.dp_workers" | "dp_workers" => c.dp_workers = v.as_usize()?,
                "train.grad_accum" | "grad_accum" => c.grad_accum = v.as_usize()?,
                "train.grad_streams" | "grad_streams" => c.grad_streams = v.as_usize()?,
                "collective.stream_pods" | "stream_pods" => c.stream_pods = v.as_usize()?,
                "scaling.amax_history" | "amax_history" => c.amax_history = v.as_usize()?,
                "scaling.margin_pow2" | "margin_pow2" => c.margin_pow2 = v.as_f64()? as i32,
                "data.corpus_order" | "corpus_order" => c.corpus_order = v.as_usize()?,
                "data.corpus_skew" | "corpus_skew" => c.corpus_skew = v.as_f64()?,
                "train.seed_outlier_channel" | "seed_outlier_channel" => {
                    c.seed_outlier_channel = v.as_bool()?
                }
                "train.seed_outlier_gain" | "seed_outlier_gain" => {
                    c.seed_outlier_gain = v.as_f64()? as f32
                }
                "train.skip_nonfinite_updates" | "skip_nonfinite_updates" => {
                    c.skip_nonfinite_updates = v.as_bool()?
                }
                "collective.pods" | "pods" => c.pods = v.as_usize()?,
                // legacy spelling: before the topology layer there was
                // one flat level, so the old flag maps onto intra
                "collective.fp8" | "collective_fp8" | "collective.fp8_intra"
                | "collective_fp8_intra" => c.collective_fp8_intra = v.as_bool()?,
                "collective.fp8_inter" | "collective_fp8_inter" => {
                    c.collective_fp8_inter = v.as_bool()?
                }
                "collective.fmt" | "collective_fmt" => c.collective_fmt = v.as_str()?,
                "train.pack_moments" | "pack_moments" => c.pack_moments = v.as_bool()?,
                "collective.bucket_bytes" | "bucket_bytes" => c.bucket_bytes = v.as_usize()?,
                "collective.overlap_comm" | "overlap_comm" => {
                    c.overlap_comm = v.as_bool()?
                }
                "train.log_every" | "log_every" => c.log_every = v.as_usize()?,
                "train.ckpt_every" | "ckpt_every" => c.ckpt_every = v.as_usize()?,
                "train.out_dir" | "out_dir" => c.out_dir = v.as_str()?,
                "campaign.snapshot_every" | "snapshot_every" => {
                    c.snapshot_every = v.as_usize()?
                }
                "campaign.snapshot_keep" | "snapshot_keep" => c.snapshot_keep = v.as_usize()?,
                "campaign.max_recoveries" | "max_recoveries" => {
                    c.max_recoveries = v.as_usize()?
                }
                "campaign.recovery_margin_backoff" | "recovery_margin_backoff" => {
                    let f = v.as_f64()?;
                    if !(f >= 0.0 && f.fract() == 0.0 && f <= i32::MAX as f64) {
                        return Err(format!(
                            "recovery_margin_backoff must be a non-negative integer \
                             (got {f}): each recovery must add headroom, not remove it"
                        ));
                    }
                    c.recovery_margin_backoff = f as i32
                }
                "campaign.recovery_history_shrink" | "recovery_history_shrink" => {
                    c.recovery_history_shrink = v.as_f64()?
                }
                "gemm.tile" | "gemm_tile" => c.gemm_tile = v.as_usize()?,
                "gemm.w_fmt" | "gemm_w_fmt" => c.gemm_w_fmt = v.as_str()?,
                "gemm.x_fmt" | "gemm_x_fmt" => c.gemm_x_fmt = v.as_str()?,
                "gemm.g_fmt" | "gemm_g_fmt" => c.gemm_g_fmt = v.as_str()?,
                "serve.addr" | "serve_addr" => c.serve_addr = v.as_str()?,
                "serve.port" | "serve_port" => c.serve_port = v.as_usize()?,
                "serve.batch" | "serve_batch" => c.serve_batch = v.as_usize()?,
                "serve.batch_wait_ms" | "serve_batch_wait_ms" => {
                    c.serve_batch_wait_ms = v.as_usize()?
                }
                "serve.max_body_bytes" | "serve_max_body_bytes" => {
                    c.serve_max_body_bytes = v.as_usize()?
                }
                "serve.max_new_tokens" | "serve_max_new_tokens" => {
                    c.serve_max_new_tokens = v.as_usize()?
                }
                "serve.fmt" | "serve_fmt" => c.serve_fmt = v.as_str()?,
                _ => return Err(format!("unknown config key '{k}'")),
            }
        }
        if c.dp_workers == 0 || c.grad_accum == 0 {
            return Err("dp_workers and grad_accum must be >= 1".into());
        }
        if c.pods == 0 {
            return Err("pods must be >= 1 (1 = flat, no inter-pod level)".into());
        }
        if c.pods > c.dp_workers || c.dp_workers % c.pods != 0 {
            return Err(format!(
                "pods ({}) must divide dp_workers ({}) evenly \
                 (equal contiguous pods; ragged pods are not supported)",
                c.pods, c.dp_workers
            ));
        }
        let s = c.streams();
        let sp = c.stream_pod_count();
        if sp > s || s % sp != 0 {
            return Err(format!(
                "stream_pods ({sp}) must divide grad_streams ({s}) evenly — the \
                 logical collective plan needs equal contiguous pods (effective \
                 values; 0 means follow pods/dp_workers)"
            ));
        }
        if c.snapshot_keep == 0 {
            return Err("snapshot_keep must be >= 1 (the rollback target)".into());
        }
        if !(c.recovery_history_shrink > 0.0 && c.recovery_history_shrink <= 1.0) {
            return Err("recovery_history_shrink must be in (0, 1]".into());
        }
        if c.bucket_bytes == 0 {
            return Err(
                "bucket_bytes must be >= 1 (it rounds up to whole Adam chunks; \
                 use a huge value to get a single monolithic bucket)"
                    .into(),
            );
        }
        if !matches!(c.collective_fmt.as_str(), "e4m3" | "e5m2") {
            return Err(format!(
                "collective_fmt must be 'e4m3' or 'e5m2' (got '{}')",
                c.collective_fmt
            ));
        }
        // the gemm keys validate even when no gemm recipe is active, so
        // a typo'd format cannot lurk until someone flips the recipe
        c.gemm_config()?;
        // same for the serve keys: `serve run` must not discover a
        // typo'd format hours after the training campaign finished
        c.serve_config()?;
        Ok(c)
    }

    pub fn recipe_config(&self) -> RecipeConfig {
        RecipeConfig::by_name(&self.recipe)
    }

    /// The tile-wise GEMM operand configuration built from the
    /// `gemm_*` keys (validated — see [`crate::gemm::GemmConfig`]).
    pub fn gemm_config(&self) -> Result<crate::gemm::GemmConfig, String> {
        crate::gemm::GemmConfig::from_keys(
            self.gemm_tile,
            &self.gemm_w_fmt,
            &self.gemm_x_fmt,
            &self.gemm_g_fmt,
        )
    }

    /// The serving configuration built from the `serve_*` keys
    /// (validated — see [`crate::serving::ServeConfig`]). Not part of
    /// the snapshot numerics fingerprint: serving never changes
    /// training bits.
    pub fn serve_config(&self) -> Result<crate::serving::ServeConfig, String> {
        crate::serving::ServeConfig::from_keys(
            &self.serve_addr,
            self.serve_port,
            self.serve_batch,
            self.serve_batch_wait_ms,
            self.serve_max_body_bytes,
            self.serve_max_new_tokens,
            &self.serve_fmt,
        )
    }

    /// Effective **logical** gradient-stream count: the data-parallel
    /// width the loss curve is defined over. Every numerics-bearing
    /// consumer (batch identity, merge denominator, collective replica
    /// count) must go through this accessor, never `dp_workers`.
    pub fn streams(&self) -> usize {
        if self.grad_streams == 0 { self.dp_workers } else { self.grad_streams }
    }

    /// Effective **logical** pod count of the collective reduction
    /// plan (pairs with [`TrainConfig::streams`] the way `pods` pairs
    /// with `dp_workers`).
    pub fn stream_pod_count(&self) -> usize {
        if self.stream_pods == 0 { self.pods } else { self.stream_pods }
    }

    /// The derived corpus PRNG root seed — the single number that,
    /// together with a step index, determines every training batch
    /// (the data pipeline is stateless: batches are pure functions of
    /// `(corpus_seed, step, worker, micro)`). Campaign snapshots
    /// record it as the data cursor and validate it on resume.
    pub fn corpus_seed(&self) -> u64 {
        self.seed ^ 0xda7a
    }

    /// JSON echo for run metadata.
    pub fn to_json(&self) -> Json {
        crate::util::json::obj(vec![
            ("size", Json::Str(self.size.clone())),
            ("recipe", Json::Str(self.recipe.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("weight_decay", Json::Num(self.weight_decay as f64)),
            ("grad_clip", Json::Num(self.grad_clip as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("dp_workers", Json::Num(self.dp_workers as f64)),
            ("grad_accum", Json::Num(self.grad_accum as f64)),
            ("grad_streams", Json::Num(self.streams() as f64)),
            ("stream_pods", Json::Num(self.stream_pod_count() as f64)),
            ("amax_history", Json::Num(self.amax_history as f64)),
            ("seed_outlier_channel", Json::Bool(self.seed_outlier_channel)),
            ("pods", Json::Num(self.pods as f64)),
            ("collective_fp8_intra", Json::Bool(self.collective_fp8_intra)),
            ("collective_fp8_inter", Json::Bool(self.collective_fp8_inter)),
            ("collective_fmt", Json::Str(self.collective_fmt.clone())),
            ("pack_moments", Json::Bool(self.pack_moments)),
            ("bucket_bytes", Json::Num(self.bucket_bytes as f64)),
            ("overlap_comm", Json::Bool(self.overlap_comm)),
            ("snapshot_every", Json::Num(self.snapshot_every as f64)),
            ("snapshot_keep", Json::Num(self.snapshot_keep as f64)),
            ("max_recoveries", Json::Num(self.max_recoveries as f64)),
            ("recovery_margin_backoff", Json::Num(self.recovery_margin_backoff as f64)),
            ("recovery_history_shrink", Json::Num(self.recovery_history_shrink)),
            ("gemm_tile", Json::Num(self.gemm_tile as f64)),
            ("gemm_w_fmt", Json::Str(self.gemm_w_fmt.clone())),
            ("gemm_x_fmt", Json::Str(self.gemm_x_fmt.clone())),
            ("gemm_g_fmt", Json::Str(self.gemm_g_fmt.clone())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let c = TrainConfig::load(None, &[("lr".into(), "0.001".into()),
                                          ("recipe".into(), "fp8_full".into())]).unwrap();
        assert_eq!(c.lr, 0.001);
        assert_eq!(c.recipe, "fp8_full");
        let rc = c.recipe_config();
        assert_eq!(rc.name, "fp8_smooth"); // grad artifact aliasing
        assert_eq!(rc.m_fmt, "e4m3");
        assert_eq!(rc.v_fmt, "e5m2");
        assert_eq!(rc.master_dtype, "f16");
    }

    #[test]
    fn adam_grid_recipes() {
        let rc = RecipeConfig::by_name("fp8_adam_e5m2_e4m3");
        assert_eq!(rc.name, "fp8_smooth");
        assert_eq!(rc.m_fmt, "e5m2");
        assert_eq!(rc.v_fmt, "e4m3");
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(TrainConfig::load(None, &[("nope".into(), "1".into())]).is_err());
    }

    #[test]
    fn gemm_recipes_select_the_matching_graphs() {
        // fp8_gemm runs the plain-SwiGLU fp8 graph — the configuration
        // Fig. 2 destabilizes — while fp8_gemm_smooth runs fp8_smooth;
        // moments stay f32 so the pair differs only in compute
        assert_eq!(grad_recipe_of("fp8_gemm"), "fp8");
        assert_eq!(grad_recipe_of("fp8_gemm_smooth"), "fp8_smooth");
        for name in ["fp8_gemm", "fp8_gemm_smooth"] {
            assert!(is_gemm_recipe(name));
            let rc = RecipeConfig::by_name(name);
            assert_eq!(rc.name, grad_recipe_of(name));
            assert_eq!(rc.m_fmt, "fp32");
            assert_eq!(rc.v_fmt, "fp32");
            assert_eq!(rc.master_dtype, "f32");
        }
        for name in ["bf16", "fp8", "fp8_smooth", "fp8_full", "fp8_adam_e4m3_e5m2"] {
            assert!(!is_gemm_recipe(name), "{name} must not gate the gemm path");
        }
    }

    #[test]
    fn gemm_keys_parse_and_validate() {
        let d = TrainConfig::default();
        assert_eq!(d.gemm_tile, 128, "MXU-shaped tiles by default");
        assert_eq!((d.gemm_w_fmt.as_str(), d.gemm_x_fmt.as_str()), ("e4m3", "e4m3"));
        assert_eq!(d.gemm_g_fmt, "e5m2", "grads need E5M2 range by default");
        d.gemm_config().unwrap();
        let c = TrainConfig::load(
            None,
            &[
                ("gemm.tile".into(), "64".into()),
                ("gemm_w_fmt".into(), "e5m2".into()),
                ("gemm.g_fmt".into(), "e4m3".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.gemm_tile, 64);
        assert_eq!(c.gemm_w_fmt, "e5m2");
        assert_eq!(c.gemm_g_fmt, "e4m3");
        let gc = c.gemm_config().unwrap();
        assert_eq!(gc.tile, 64);
        assert!(
            TrainConfig::load(None, &[("gemm_tile".into(), "0".into())]).is_err(),
            "a zero tile cannot partition a matrix"
        );
        assert!(
            TrainConfig::load(None, &[("gemm_x_fmt".into(), "bf16".into())]).is_err(),
            "only the two FP8 formats exist as GEMM operands"
        );
    }

    #[test]
    fn collective_keys_parse_and_validate() {
        let c = TrainConfig::load(
            None,
            &[
                ("collective.fp8".into(), "true".into()),
                ("collective_fmt".into(), "e4m3".into()),
                ("pack_moments".into(), "false".into()),
            ],
        )
        .unwrap();
        assert!(c.collective_fp8_intra, "legacy collective_fp8 maps onto the intra level");
        assert_eq!(c.collective_fmt, "e4m3");
        assert!(!c.pack_moments);
        let d = TrainConfig::default();
        assert!(!d.collective_fp8_intra, "bit-exact f32 intra collective must be the default");
        assert!(d.collective_fp8_inter, "the thin inter-pod pipe defaults to FP8");
        assert_eq!(d.pods, 1, "flat topology must be the default");
        assert!(d.pack_moments, "sharded FP8 residency is the default memory story");
        assert!(
            TrainConfig::load(None, &[("collective_fmt".into(), "fp16".into())]).is_err(),
            "only the two FP8 wire formats exist"
        );
    }

    #[test]
    fn topology_keys_parse_and_validate() {
        let c = TrainConfig::load(
            None,
            &[
                ("dp_workers".into(), "8".into()),
                ("collective.pods".into(), "2".into()),
                ("collective_fp8_intra".into(), "true".into()),
                ("collective.fp8_inter".into(), "false".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.pods, 2);
        assert!(c.collective_fp8_intra);
        assert!(!c.collective_fp8_inter);
        assert!(
            TrainConfig::load(None, &[("pods".into(), "0".into())]).is_err(),
            "zero pods is meaningless"
        );
        assert!(
            TrainConfig::load(
                None,
                &[("dp_workers".into(), "4".into()), ("pods".into(), "3".into())]
            )
            .is_err(),
            "ragged pods must refuse"
        );
        assert!(
            TrainConfig::load(None, &[("pods".into(), "2".into())]).is_err(),
            "pods cannot exceed dp_workers (default 1)"
        );
    }

    #[test]
    fn stream_keys_follow_physical_by_default() {
        let d = TrainConfig::default();
        assert_eq!(d.grad_streams, 0, "0 = follow dp_workers");
        assert_eq!(d.stream_pods, 0, "0 = follow pods");
        let c = TrainConfig::load(
            None,
            &[("dp_workers".into(), "4".into()), ("pods".into(), "2".into())],
        )
        .unwrap();
        assert_eq!(c.streams(), 4, "defaulted streams track the worker pool");
        assert_eq!(c.stream_pod_count(), 2, "defaulted plan pods track physical pods");
        // the elastic case: plan pinned wider than the surviving pool
        let c = TrainConfig::load(
            None,
            &[
                ("dp_workers".into(), "3".into()),
                ("train.grad_streams".into(), "4".into()),
                ("collective.stream_pods".into(), "2".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.streams(), 4);
        assert_eq!(c.stream_pod_count(), 2);
        assert!(
            TrainConfig::load(
                None,
                &[("grad_streams".into(), "4".into()), ("stream_pods".into(), "3".into())]
            )
            .is_err(),
            "ragged logical pods must refuse like ragged physical pods"
        );
        assert!(
            TrainConfig::load(
                None,
                &[("dp_workers".into(), "4".into()), ("stream_pods".into(), "8".into())]
            )
            .is_err(),
            "plan pods cannot exceed the effective stream count"
        );
    }

    #[test]
    fn overlap_keys_parse_and_validate() {
        let d = TrainConfig::default();
        assert!(d.overlap_comm, "the overlapped pipeline is the default schedule");
        assert_eq!(d.bucket_bytes, 4_194_304, "4 MiB buckets by default");
        let c = TrainConfig::load(
            None,
            &[
                ("collective.bucket_bytes".into(), "1048576".into()),
                ("overlap_comm".into(), "false".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.bucket_bytes, 1_048_576);
        assert!(!c.overlap_comm);
        assert!(
            TrainConfig::load(None, &[("bucket_bytes".into(), "0".into())]).is_err(),
            "a zero-byte bucket cannot partition anything"
        );
    }

    #[test]
    fn serve_keys_parse_and_validate() {
        let d = TrainConfig::default();
        assert_eq!(d.serve_addr, "127.0.0.1", "loopback by default — serving is opt-in");
        assert_eq!(d.serve_port, 0, "ephemeral port by default");
        assert_eq!(d.serve_batch, 8);
        assert_eq!(d.serve_batch_wait_ms, 5);
        assert_eq!(d.serve_max_body_bytes, 1_048_576);
        assert_eq!(d.serve_max_new_tokens, 64);
        assert_eq!(d.serve_fmt, "e4m3");
        d.serve_config().unwrap();
        let c = TrainConfig::load(
            None,
            &[
                ("serve.addr".into(), "0.0.0.0".into()),
                ("serve_port".into(), "8080".into()),
                ("serve.batch".into(), "32".into()),
                ("serve_batch_wait_ms".into(), "0".into()),
                ("serve.max_body_bytes".into(), "4096".into()),
                ("serve_max_new_tokens".into(), "16".into()),
                ("serve.fmt".into(), "e5m2".into()),
            ],
        )
        .unwrap();
        let sc = c.serve_config().unwrap();
        assert_eq!(sc.addr, "0.0.0.0");
        assert_eq!(sc.port, 8080);
        assert_eq!(sc.batch, 32);
        assert_eq!(sc.batch_wait_ms, 0);
        assert_eq!(sc.max_body_bytes, 4096);
        assert_eq!(sc.max_new_tokens, 16);
        assert!(
            TrainConfig::load(None, &[("serve_batch".into(), "0".into())]).is_err(),
            "an empty batch cannot coalesce anything"
        );
        assert!(
            TrainConfig::load(None, &[("serve_port".into(), "70000".into())]).is_err(),
            "ports are u16"
        );
        assert!(
            TrainConfig::load(None, &[("serve_fmt".into(), "bf16".into())]).is_err(),
            "only the two FP8 formats exist as export targets"
        );
        assert!(
            TrainConfig::load(None, &[("serve_max_body_bytes".into(), "0".into())]).is_err(),
            "a zero body cap refuses every request"
        );
    }

    #[test]
    fn campaign_keys_parse_and_validate() {
        let c = TrainConfig::load(
            None,
            &[
                ("campaign.snapshot_every".into(), "25".into()),
                ("snapshot_keep".into(), "5".into()),
                ("max_recoveries".into(), "2".into()),
                ("recovery_margin_backoff".into(), "2".into()),
                ("recovery_history_shrink".into(), "0.25".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.snapshot_every, 25);
        assert_eq!(c.snapshot_keep, 5);
        assert_eq!(c.max_recoveries, 2);
        assert_eq!(c.recovery_margin_backoff, 2);
        assert_eq!(c.recovery_history_shrink, 0.25);
        assert!(
            TrainConfig::load(None, &[("snapshot_keep".into(), "0".into())]).is_err(),
            "retention must keep at least the rollback target"
        );
        assert!(
            TrainConfig::load(None, &[("recovery_history_shrink".into(), "0".into())]).is_err(),
            "shrink factor 0 would empty the amax window"
        );
        assert!(
            TrainConfig::load(None, &[("recovery_margin_backoff".into(), "-2".into())]).is_err(),
            "negative backoff would REMOVE headroom per attempt"
        );
        assert!(
            TrainConfig::load(None, &[("recovery_margin_backoff".into(), "1.9".into())]).is_err(),
            "fractional backoff must not silently truncate"
        );
    }
}
