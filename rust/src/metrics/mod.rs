//! Metrics: step meter (throughput/TFLOPS estimates) + JSONL sink.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use crate::util::json::{obj, Json};

/// Rolling throughput meter.
pub struct StepMeter {
    start: Instant,
    last: Instant,
    pub steps: usize,
    pub tokens: usize,
    flops_per_step: f64,
}

impl StepMeter {
    pub fn new(flops_per_step: f64) -> Self {
        let now = Instant::now();
        Self { start: now, last: now, steps: 0, tokens: 0, flops_per_step }
    }

    pub fn tick(&mut self, tokens: usize) -> StepStats {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.steps += 1;
        self.tokens += tokens;
        StepStats {
            step_time_s: dt,
            tokens_per_s: tokens as f64 / dt.max(1e-9),
            tflops: self.flops_per_step / dt.max(1e-9) / 1e12,
        }
    }

    pub fn wall_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub step_time_s: f64,
    pub tokens_per_s: f64,
    pub tflops: f64,
}

/// Append-only JSONL metrics file (one JSON object per record).
pub struct JsonlSink {
    w: BufWriter<File>,
}

impl JsonlSink {
    pub fn create<P: AsRef<Path>>(path: P) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self { w: BufWriter::new(f) })
    }

    pub fn record(&mut self, fields: Vec<(&str, Json)>) -> std::io::Result<()> {
        writeln!(self.w, "{}", obj(fields).to_string())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_counts() {
        let mut m = StepMeter::new(1e9);
        let s = m.tick(1024);
        assert!(s.step_time_s >= 0.0);
        assert!(s.tokens_per_s > 0.0);
        assert_eq!(m.steps, 1);
        assert_eq!(m.tokens, 1024);
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let dir = std::env::temp_dir().join("fp8_jsonl_test");
        let path = dir.join("m.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut s = JsonlSink::create(&path).unwrap();
            s.record(vec![("step", Json::Num(1.0)), ("loss", Json::Num(5.5))]).unwrap();
            s.record(vec![("step", Json::Num(2.0)), ("loss", Json::Num(5.4))]).unwrap();
            s.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            assert!(Json::parse(l).is_ok());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
